"""Round-health report CLI: ``python -m repro.obs.report <run.obs.jsonl>``.

Renders the JSONL event log written by :meth:`repro.obs.Obs.flush` into
a terminal summary:

  * run meta + kernel dispatch counts (pallas/interpret/ref per op),
  * per-round table (loss, quant-error norm, update norm, wire bytes)
    with the **quality-per-wire-MB trajectory** — cumulative loss drop
    divided by cumulative wire MB, the paper's headline trade-off,
  * async flush health: staleness histogram, stale/dropped upload
    fractions, peak in-flight bytes,
  * serve latency (p50/p95, swap stall) when a serve record is present,
  * span summary per clock (count / total / mean wall or virtual time).

Pure stdlib + the JSONL — no jax import — so it runs anywhere, including
on CI artifacts pulled from another machine.
"""

from __future__ import annotations

import argparse
import sys
from typing import Any, Dict, List, Optional

from repro.obs.export import read_jsonl


def _fmt(v: Any) -> str:
    if isinstance(v, float):
        return f"{v:.5g}"
    return str(v)


def _table(rows: List[Dict[str, Any]], cols: List[str],
           out) -> None:
    if not rows:
        return
    widths = {c: max(len(c), *(len(_fmt(r.get(c, ""))) for r in rows))
              for c in cols}
    print("  " + "  ".join(c.rjust(widths[c]) for c in cols), file=out)
    for r in rows:
        print("  " + "  ".join(_fmt(r.get(c, "")).rjust(widths[c])
                               for c in cols), file=out)


def _histogram(values: List[float], bins: int = 8) -> List[str]:
    if not values:
        return []
    lo, hi = min(values), max(values)
    if hi <= lo:
        return [f"  [{_fmt(lo)}] {'#' * min(len(values), 40)} {len(values)}"]
    step = (hi - lo) / bins
    counts = [0] * bins
    for v in values:
        i = min(int((v - lo) / step), bins - 1)
        counts[i] += 1
    peak = max(counts)
    lines = []
    for i, c in enumerate(counts):
        bar = "#" * max(1, int(40 * c / peak)) if c else ""
        lines.append(
            f"  [{_fmt(lo + i * step):>8} – {_fmt(lo + (i + 1) * step):>8}]"
            f" {bar} {c}"
        )
    return lines


def render(records: List[Dict[str, Any]], out=None) -> None:
    out = out if out is not None else sys.stdout
    by_kind: Dict[str, List[Dict[str, Any]]] = {}
    for r in records:
        by_kind.setdefault(str(r.get("kind", "?")), []).append(r)

    for meta in by_kind.get("meta", []):
        print(f"== run: {meta.get('run', '?')} ==", file=out)
        counts = meta.get("dispatch_counts") or {}
        if counts:
            print("kernel dispatch (traces per op.backend):", file=out)
            for key in sorted(counts):
                print(f"  {key}: {counts[key]}", file=out)

    rounds = by_kind.get("round", [])
    if rounds:
        print(f"\n== rounds ({len(rounds)}) ==", file=out)
        cum_mb = 0.0
        loss0: Optional[float] = None
        rows = []
        for r in rounds:
            loss = r.get("loss")
            if loss0 is None and loss is not None:
                loss0 = float(loss)
            mb = (float(r.get("down_bytes", 0)) +
                  float(r.get("up_bytes", 0))) / 1e6
            cum_mb += mb
            row = dict(r)
            row["wire_mb"] = mb
            if loss0 is not None and loss is not None and cum_mb > 0:
                row["qual_per_mb"] = (loss0 - float(loss)) / cum_mb
            rows.append(row)
        cols = ["round", "loss", "qerr_norm", "update_norm", "ef_norm",
                "alive", "wire_mb", "qual_per_mb"]
        cols = [c for c in cols if any(c in r for r in rows)]
        _table(rows, cols, out)
        if rows and "qual_per_mb" in rows[-1]:
            print(f"  final quality-per-wire-MB: "
                  f"{_fmt(rows[-1]['qual_per_mb'])}", file=out)

    flushes = by_kind.get("flush", [])
    if flushes:
        print(f"\n== async flushes ({len(flushes)}) ==", file=out)
        stal: List[float] = []
        for f in flushes:
            stal.extend(float(s) for s in f.get("staleness", []))
        if stal:
            print("staleness histogram (rounds behind at flush):", file=out)
            for line in _histogram(stal):
                print(line, file=out)
        last = flushes[-1]
        for key in ("stale_fraction", "dropped_fraction",
                    "peak_in_flight_bytes", "up_bytes", "down_bytes"):
            if key in last:
                print(f"  {key}: {_fmt(last[key])}", file=out)

    serves = by_kind.get("serve", [])
    if serves:
        print(f"\n== serve ==", file=out)
        for s in serves:
            for key in ("queries", "query_ms_p50", "query_ms_p95",
                        "swap_ms_mean", "swap_stall_ratio"):
                if key in s:
                    print(f"  {key}: {_fmt(s[key])}", file=out)

    spans = by_kind.get("span", [])
    if spans:
        print(f"\n== spans ({len(spans)}) ==", file=out)
        agg: Dict[str, Dict[str, float]] = {}
        for s in spans:
            key = f"{s.get('cat', 'wall')}:{s.get('name', '?')}"
            rec = agg.setdefault(key, {"count": 0.0, "total_s": 0.0})
            rec["count"] += 1
            rec["total_s"] += float(s.get("dur", 0.0))
        rows = [
            {"span": k, "count": int(v["count"]),
             "total_s": v["total_s"],
             "mean_ms": 1e3 * v["total_s"] / max(v["count"], 1.0)}
            for k, v in sorted(agg.items())
        ]
        _table(rows, ["span", "count", "total_s", "mean_ms"], out)

    logs = by_kind.get("log", [])
    if logs:
        print(f"\n== log ({len(logs)} records) ==", file=out)


def main(argv: Optional[List[str]] = None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.obs.report",
        description="Render a round-health summary from an obs JSONL log.",
    )
    ap.add_argument("jsonl", help="path to a <run>.obs.jsonl event log")
    args = ap.parse_args(argv)
    try:
        records = read_jsonl(args.jsonl)
    except OSError as e:
        print(f"error: cannot read {args.jsonl}: {e}", file=sys.stderr)
        return 1
    render(records)
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
