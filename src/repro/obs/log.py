"""Thin structured logger for CLIs (DESIGN.md §15 satellite).

Replaces raw ``print()`` in the launch/demo entry points with one
funnel: human-readable text on stderr (so artifact JSON on stdout stays
machine-clean), plus an optional mirror into an :class:`Obs` sink as
``kind=log`` JSONL records.  ``--quiet`` silences the text stream only —
the JSONL record is cheap and always kept when a sink is attached.

    log = Logger(quiet=args.quiet, obs=obs)
    log.info("round complete", round=r, loss=loss)
"""

from __future__ import annotations

import sys
from typing import Any, Optional, TextIO


class Logger:
    """stderr text + optional structured mirror into an obs sink."""

    def __init__(self, quiet: bool = False, obs: Optional[Any] = None,
                 stream: Optional[TextIO] = None) -> None:
        self.quiet = bool(quiet)
        self.obs = obs
        self.stream = stream if stream is not None else sys.stderr

    def _emit(self, level: str, msg: str, **fields: Any) -> None:
        if self.obs is not None:
            self.obs.record("log", level=level, msg=msg, **fields)
        if self.quiet:
            return
        if fields:
            kv = " ".join(f"{k}={_fmt(v)}" for k, v in fields.items())
            line = f"[{level}] {msg} {kv}"
        else:
            line = f"[{level}] {msg}"
        print(line, file=self.stream)

    def info(self, msg: str, **fields: Any) -> None:
        self._emit("info", msg, **fields)

    def warn(self, msg: str, **fields: Any) -> None:
        self._emit("warn", msg, **fields)

    def result(self, msg: str, **fields: Any) -> None:
        """Final-outcome lines (kept terse; still silenced by --quiet)."""
        self._emit("result", msg, **fields)


def _fmt(v: Any) -> str:
    if isinstance(v, float):
        return f"{v:.6g}"
    return str(v)
