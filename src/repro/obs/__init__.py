"""``repro.obs`` — telemetry for every training/serving path (DESIGN.md §15).

One handle, three layers:

  * **metrics** (:mod:`repro.obs.metrics`) — scalar bundles (loss,
    per-leaf quant-error norms, EF residual norms, alive counts)
    assembled host-side from program outputs and folded into a
    :class:`~repro.obs.metrics.MetricsSink`,
  * **tracing** (:mod:`repro.obs.trace`) — wall-clock spans (compile,
    dispatch, flush, hot-swap) plus virtual-clock spans for the async
    engine's simulated timeline,
  * **export** (:mod:`repro.obs.export`) — JSONL event log +
    Chrome-trace/Perfetto JSON under ``experiments/obs/``, rendered by
    ``python -m repro.obs.report``.

The contract every instrumented call site honors: ``obs=None`` (the
default everywhere) must be a **true no-op** — no extra program outputs,
no spans, no files — so the tier-1 bit-identity gates between paths are
untouched; and with ``obs`` *enabled*, compiled round programs only
expose values they already compute (the cohort mean) as extra outputs —
all bundle math (update/quant-error/EF norms) runs **eagerly on the
host** after the program returns, so the compiled round math is
untouched and trained trees and wire ledgers stay bit/byte-identical
(gated in tier-1).

Typical use::

    obs = Obs(run_name="engine_c8")
    storage, hist = run_training_vectorized(..., obs=obs)
    paths = obs.flush()          # experiments/obs/engine_c8.{obs.jsonl,perfetto.json}
    # python -m repro.obs.report experiments/obs/engine_c8.obs.jsonl
"""

from __future__ import annotations

import os
from contextlib import contextmanager
from typing import Any, Dict, Iterator, Optional

from repro.obs.metrics import Bundle, MetricsSink
from repro.obs.trace import Span, Tracer, maybe_span

__all__ = [
    "Obs", "MetricsSink", "Tracer", "Span", "Bundle",
    "maybe_span", "null_span",
]

DEFAULT_OUT_DIR = os.path.join("experiments", "obs")


class Obs:
    """Per-run telemetry handle: a sink + a tracer + export plumbing.

    ``metrics=False`` keeps the compiled programs bundle-free (spans
    only); ``trace=False`` drops span recording.  Call sites must accept
    ``obs=None`` and treat it as fully disabled.
    """

    def __init__(self, run_name: str = "run", out_dir: Optional[str] = None,
                 *, metrics: bool = True, trace: bool = True) -> None:
        self.run_name = str(run_name)
        self.out_dir = out_dir if out_dir is not None else DEFAULT_OUT_DIR
        self.sink = MetricsSink()
        self.tracer: Optional[Tracer] = Tracer() if trace else None
        self._metrics = bool(metrics)

    @property
    def collect_metrics(self) -> bool:
        """Whether compiled programs should emit metric bundles."""
        return self._metrics

    def record(self, kind: str, bundle: Optional[Bundle] = None,
               **fields: Any) -> Dict[str, Any]:
        return self.sink.record(kind, bundle, **fields)

    @contextmanager
    def span(self, name: str, **args: Any) -> Iterator[Dict[str, Any]]:
        with maybe_span(self.tracer, name, **args) as a:
            yield a

    def vspan(self, name: str, ts: float, dur: float, **args: Any) -> None:
        if self.tracer is not None:
            self.tracer.vspan(name, ts, dur, **args)

    def flush(self) -> Dict[str, str]:
        """Write the JSONL (+ Perfetto when tracing) artifacts; return paths.

        Prepends a ``kind=meta`` record carrying the run name and the
        kernel dispatch counters accumulated so far (``kernels/ops.py``),
        so a single JSONL is a self-contained health record.
        """
        from repro.kernels import ops as kernel_ops
        from repro.obs.export import export_run

        meta = {
            "kind": "meta",
            "run": self.run_name,
            "dispatch_counts": kernel_ops.dispatch_counts(),
        }
        return export_run(
            self.out_dir, self.run_name,
            [meta] + self.sink.records(), self.tracer,
        )


@contextmanager
def null_span(obs: Optional[Obs], name: str,
              **args: Any) -> Iterator[Dict[str, Any]]:
    """``obs.span`` tolerant of ``obs=None`` — for instrumented call sites."""
    if obs is None:
        yield args
    else:
        with obs.span(name, **args) as a:
            yield a
