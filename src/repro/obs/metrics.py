"""Round metric bundles, assembled OUTSIDE the compiled round programs.

A **metric bundle** is a flat ``{name: f32 scalar}`` dict — a pytree of
0-d arrays — computed from a round program's ordinary outputs
(DESIGN.md §15).  The contract that makes it safe to leave on in
production paths:

  * the round program itself never gains metric math: with
    ``collect_metrics=True`` it only exposes the cohort mean it already
    computes as an extra output, and every derived statistic here runs
    *after* the program returns, as a **separate** jitted helper — extra
    consumers inside the round program would shift XLA fusion/FMA
    boundaries and change the trained tree bitwise; a separate program
    cannot (the tier-1 gate in ``tests/test_obs.py`` asserts
    bit-identity with obs on vs off on every path),
  * no host callbacks ride in the hot path: the bundle crosses the
    device boundary once per round/flush (one ``device_get`` in
    :func:`finalize_bundle`) and the host-side :class:`MetricsSink`
    folds it into a record.

Bundle keys (schema used by ``repro.obs.report``):

  * ``loss`` / ``alive`` — the round's weighted loss and survivor count,
  * ``update_norm`` — L2 of the applied server step (new − old, f32 view),
  * ``qerr_norm`` — L2 of the server requantization error: what the
    policy re-compress threw away this round (``qerr/<var>`` per leaf),
  * ``ef_norm`` — L2 of the cohort's updated error-feedback residual rows
    (only when training under an EF strategy, DESIGN.md §12).

Helpers here only import :mod:`repro.core`, so every training path can
depend on them without cycles.
"""

from __future__ import annotations

from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp

from repro.core.policy import path_str
from repro.core.store import decompress_tree, is_compressed
from repro.models.common import ParamSpec

Bundle = Dict[str, jax.Array]


def tree_sq_sum(tree) -> jax.Array:
    """Σ x² over every leaf of an f32 pytree (0-d f32)."""
    leaves = jax.tree_util.tree_leaves(tree)
    tot = jnp.float32(0.0)
    for x in leaves:
        tot = tot + jnp.sum(jnp.square(x.astype(jnp.float32)))
    return tot


def _server_round_bundle_impl(
    specs, old, new_storage, mean_model, server_lr: float,
    per_leaf: bool,
) -> Bundle:
    old_f32 = decompress_tree(old)  # pass-through when already f32
    new_f32 = decompress_tree(new_storage)
    out: Bundle = {
        "update_norm": jnp.sqrt(
            tree_sq_sum(
                jax.tree_util.tree_map(jnp.subtract, new_f32, old_f32)
            )
        )
    }
    if mean_model is None:
        return out
    ideal = jax.tree_util.tree_map(
        lambda o, m: o + server_lr * (m - o), old_f32, mean_model
    )
    qerr_sq = jnp.float32(0.0)

    def visit(path, spec, srv, new_leaf, ideal_leaf):
        nonlocal qerr_sq
        if not is_compressed(srv):
            return srv  # exact leaves: requantization error is identically 0
        sq = jnp.sum(jnp.square(new_leaf - ideal_leaf))
        qerr_sq = qerr_sq + sq
        if per_leaf:
            out[f"qerr/{path_str(path)}"] = jnp.sqrt(sq)
        return srv

    jax.tree_util.tree_map_with_path(
        visit, specs, new_storage, new_f32, ideal,
        is_leaf=lambda s: isinstance(s, ParamSpec),
    )
    out["qerr_norm"] = jnp.sqrt(qerr_sq)
    return out


_BUNDLE_JIT_CACHE: Dict[Any, Any] = {}


def _bundle_cache_key(specs, server_lr: float, per_leaf: bool,
                      with_mean: bool):
    paths = jax.tree_util.tree_flatten_with_path(
        specs, is_leaf=lambda s: isinstance(s, ParamSpec)
    )[0]
    return (tuple(path_str(p) for p, _ in paths),
            float(server_lr), bool(per_leaf), bool(with_mean))


def server_round_bundle(
    specs,
    old,
    new_storage,
    mean_model,
    server_lr: float,
    *,
    per_leaf: bool = True,
) -> Bundle:
    """Bundle for one server round (any path — loop, engine, async, tree).

    ``old`` is the pre-round server tree — compressed storage or f32;
    it is decompressed *inside* the jitted bundle program, so call sites
    must not pay an eager per-leaf decompress.
    ``mean_model`` is the f32 cohort mean the server interpolated toward;
    the *ideal* (pre-requantization) new state is
    ``old + lr·(mean − old)``, so per-variable ``qerr`` measures exactly
    the error the policy re-compress introduced.  ``mean_model=None``
    (compressed-domain flushes that never materialize a mean) degrades to
    the update norm alone.

    Compiled as its own jitted program, cached per (spec paths, lr,
    per_leaf, mean-ness): this is what keeps the §15 overhead budget —
    one dispatch per round instead of one per leaf op — while remaining
    a *separate* program from the round itself, so the round's XLA
    fusion (and therefore the trained tree) cannot be perturbed.
    """
    key = _bundle_cache_key(specs, server_lr, per_leaf, mean_model is not None)
    fn = _BUNDLE_JIT_CACHE.get(key)
    if fn is None:
        if mean_model is None:
            fn = jax.jit(lambda o, n: _server_round_bundle_impl(
                specs, o, n, None, server_lr, per_leaf))
        else:
            fn = jax.jit(lambda o, n, m: _server_round_bundle_impl(
                specs, o, n, m, server_lr, per_leaf))
        _BUNDLE_JIT_CACHE[key] = fn
    if mean_model is None:
        return fn(old, new_storage)
    return fn(old, new_storage, mean_model)


def ef_rows_norm(rows: Optional[Dict[str, jax.Array]]) -> jax.Array:
    """L2 over a cohort's updated EF residual rows (0 when EF is off)."""
    if not rows:
        return jnp.float32(0.0)
    return jnp.sqrt(tree_sq_sum(rows))


def chunk_partial_bundle(server_f32, stacked_masked, w) -> Bundle:
    """Streamed-path partials (DESIGN.md §14): per-chunk weighted sums.

    Returned by the fixed-capacity partial-aggregate program alongside
    ``(Σ w·x, Σ w, Σ w·loss)``; :func:`fold_partial_bundles` reduces the
    chunks and the round bundle is finished at the root combine.
    ``update_sq_wsum`` is ``Σ_c w_c·‖model_c − server‖²`` — the cohort's
    update dispersion, the quantity staleness-adaptive control needs.
    """
    tot = jnp.float32(0.0)
    for s, x in zip(jax.tree_util.tree_leaves(server_f32),
                    jax.tree_util.tree_leaves(stacked_masked)):
        wb = w.reshape((-1,) + (1,) * (x.ndim - 1))
        d = x - jnp.where(wb > 0, s[None], 0.0)
        tot = tot + jnp.sum(jnp.square(d) * wb)
    return {"update_sq_wsum": tot}


def fold_partial_bundles(acc: Optional[Bundle], part: Bundle) -> Bundle:
    if acc is None:
        return dict(part)
    return {k: acc[k] + part[k] for k in acc}


def finalize_bundle(bundle: Bundle) -> Dict[str, float]:
    """Host-side: materialize a device bundle into plain floats.

    One ``device_get`` for the whole dict — a single transfer/sync per
    record, not one blocking fetch per scalar.
    """
    return {k: float(v) for k, v in jax.device_get(bundle).items()}


class MetricsSink:
    """Host-side fold of per-round/per-event records (DESIGN.md §15).

    One sink per run.  ``record(kind, ...)`` appends a plain-dict record
    (bundles are materialized to floats here — the only device→host sync,
    once per round); :meth:`records` hands the ordered list to the
    exporters.  The sink never feeds anything back into training.
    """

    def __init__(self) -> None:
        self._records: list[Dict[str, Any]] = []

    def __len__(self) -> int:
        return len(self._records)

    def record(self, kind: str, bundle: Optional[Bundle] = None,
               **fields: Any) -> Dict[str, Any]:
        rec: Dict[str, Any] = {"kind": str(kind)}
        rec.update(fields)
        if bundle:
            rec.update(finalize_bundle(bundle))
        self._records.append(rec)
        return rec

    def records(self, kind: Optional[str] = None) -> list:
        if kind is None:
            return list(self._records)
        return [r for r in self._records if r.get("kind") == kind]
