"""Exporters: JSONL event log + Chrome-trace/Perfetto JSON (DESIGN.md §15).

Two artifacts per run, both under ``experiments/obs/`` by default:

  * ``<run>.obs.jsonl`` — one JSON object per line; every object has a
    ``kind`` key (``meta`` | ``round`` | ``flush`` | ``serve`` | ``span``
    | ``log``).  This is the canonical record ``repro.obs.report`` reads.
  * ``<run>.perfetto.json`` — Chrome trace-event format (``ph: "X"``
    complete events, microsecond timestamps) loadable in Perfetto UI /
    ``chrome://tracing``.  Wall and virtual clocks export as separate
    ``pid`` tracks so the simulated timeline never interleaves with host
    time.
"""

from __future__ import annotations

import json
import os
from typing import Any, Dict, Iterable, List, Optional

from repro.obs.trace import Span, Tracer, VIRTUAL

#: pid assignments for the two clock tracks in the Chrome trace
WALL_PID = 1
VIRTUAL_PID = 2

JSONL_KINDS = ("meta", "round", "flush", "serve", "span", "log")


def span_record(span: Span) -> Dict[str, Any]:
    """JSONL form of a span (kind=span; seconds, not µs)."""
    rec: Dict[str, Any] = {
        "kind": "span",
        "name": span.name,
        "cat": span.cat,
        "ts": span.ts,
        "dur": span.dur,
    }
    if span.args:
        rec["args"] = _plain(span.args)
    return rec


def _plain(obj: Any) -> Any:
    """Best-effort conversion to JSON-serializable plain types."""
    if isinstance(obj, dict):
        return {str(k): _plain(v) for k, v in obj.items()}
    if isinstance(obj, (list, tuple)):
        return [_plain(v) for v in obj]
    if isinstance(obj, (str, int, bool)) or obj is None:
        return obj
    try:
        return float(obj)
    except (TypeError, ValueError):
        return str(obj)


def to_trace_events(spans: Iterable[Span]) -> List[Dict[str, Any]]:
    """Chrome trace-event list: one ``ph:"X"`` complete event per span."""
    events: List[Dict[str, Any]] = []
    for s in spans:
        virtual = s.cat == VIRTUAL
        events.append({
            "name": s.name,
            "ph": "X",
            "ts": s.ts * 1e6,      # trace-event timestamps are microseconds
            "dur": s.dur * 1e6,
            "pid": VIRTUAL_PID if virtual else WALL_PID,
            "tid": 0,
            "cat": s.cat,
            "args": _plain(s.args),
        })
    return events


def to_perfetto(spans: Iterable[Span]) -> Dict[str, Any]:
    """Full Chrome-trace JSON document with named clock tracks."""
    meta = [
        {"name": "process_name", "ph": "M", "pid": WALL_PID,
         "args": {"name": "wall clock"}},
        {"name": "process_name", "ph": "M", "pid": VIRTUAL_PID,
         "args": {"name": "virtual clock"}},
    ]
    return {
        "traceEvents": meta + to_trace_events(spans),
        "displayTimeUnit": "ms",
    }


def write_jsonl(path: str, records: Iterable[Dict[str, Any]]) -> str:
    os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
    with open(path, "w") as f:
        for rec in records:
            f.write(json.dumps(_plain(rec), sort_keys=True) + "\n")
    return path


def read_jsonl(path: str) -> List[Dict[str, Any]]:
    out: List[Dict[str, Any]] = []
    with open(path) as f:
        for line in f:
            line = line.strip()
            if line:
                out.append(json.loads(line))
    return out


def write_perfetto(path: str, tracer: Tracer) -> str:
    os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
    with open(path, "w") as f:
        json.dump(to_perfetto(tracer.spans()), f)
    return path


def export_run(
    out_dir: str,
    run_name: str,
    records: List[Dict[str, Any]],
    tracer: Optional[Tracer] = None,
) -> Dict[str, str]:
    """Write both artifacts; returns ``{"jsonl": ..., "perfetto": ...}``.

    Sink records come first in the JSONL (meta, rounds, ...), followed by
    one ``kind=span`` line per recorded span so the log is self-contained
    even without the Perfetto file.
    """
    lines = list(records)
    paths: Dict[str, str] = {}
    if tracer is not None:
        lines.extend(span_record(s) for s in tracer.spans())
    paths["jsonl"] = write_jsonl(
        os.path.join(out_dir, f"{run_name}.obs.jsonl"), lines
    )
    if tracer is not None:
        paths["perfetto"] = write_perfetto(
            os.path.join(out_dir, f"{run_name}.perfetto.json"), tracer
        )
    return paths
