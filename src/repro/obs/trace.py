"""Span tracer: wall-clock and virtual-clock timing (DESIGN.md §15).

Two clocks, one span type:

  * **wall** spans time host-side phases — compile, dispatch, flush,
    hot-swap, payload encode/decode — with ``time.perf_counter``.
  * **virtual** spans carry the async engine's simulated clock: a client
    round is a span at its check-in timestamp with the sampled latency as
    duration.  Virtual spans are *constructed*, never timed — the async
    event loop already knows both endpoints when the event fires.

The tracer is append-only and cheap (one list append per span); export
to Chrome-trace/Perfetto JSON lives in :mod:`repro.obs.export` so the
hot path never touches the filesystem.
"""

from __future__ import annotations

import time
from contextlib import contextmanager
from dataclasses import dataclass, field
from typing import Any, Dict, Iterator, List, Optional

#: span categories (the ``cat`` field) — keep in sync with DESIGN.md §15
WALL = "wall"
VIRTUAL = "virtual"


@dataclass(frozen=True)
class Span:
    """One closed interval on either clock.

    ``ts``/``dur`` are **seconds** on the span's own clock: wall spans use
    the tracer's epoch (first span at ~0), virtual spans use the async
    engine's simulated time directly.
    """

    name: str
    ts: float
    dur: float
    cat: str = WALL
    args: Dict[str, Any] = field(default_factory=dict)

    @property
    def end(self) -> float:
        return self.ts + self.dur


class Tracer:
    """Collects :class:`Span`\\ s for one run; thread-unsafe by design.

    All recording funnels through :meth:`add`; :meth:`span` is the
    wall-clock context manager and :meth:`vspan` the virtual-clock
    constructor.  ``tracer=None`` call sites use
    :func:`maybe_span`, which degrades to a no-op.
    """

    def __init__(self) -> None:
        self._spans: List[Span] = []
        self._epoch = time.perf_counter()

    def __len__(self) -> int:
        return len(self._spans)

    def now(self) -> float:
        """Seconds since this tracer's epoch (wall clock)."""
        return time.perf_counter() - self._epoch

    def add(self, span: Span) -> Span:
        self._spans.append(span)
        return span

    @contextmanager
    def span(self, name: str, **args: Any) -> Iterator[Dict[str, Any]]:
        """Wall-clock span around a ``with`` body.

        Yields the mutable ``args`` dict so the body can attach results
        (e.g. byte counts) before the span closes.
        """
        t0 = self.now()
        try:
            yield args
        finally:
            self.add(Span(name=name, ts=t0, dur=self.now() - t0, args=args))

    def vspan(self, name: str, ts: float, dur: float, **args: Any) -> Span:
        """Record a virtual-clock span at simulated time ``ts``."""
        return self.add(
            Span(name=name, ts=float(ts), dur=float(dur), cat=VIRTUAL,
                 args=args)
        )

    def spans(self, cat: Optional[str] = None,
              name: Optional[str] = None) -> List[Span]:
        out = self._spans
        if cat is not None:
            out = [s for s in out if s.cat == cat]
        if name is not None:
            out = [s for s in out if s.name == name]
        return list(out)

    def summary(self) -> Dict[str, Dict[str, float]]:
        """Per-(cat, name) count/total/mean seconds — the benchmark view."""
        agg: Dict[str, Dict[str, float]] = {}
        for s in self._spans:
            key = f"{s.cat}:{s.name}"
            rec = agg.setdefault(key, {"count": 0.0, "total_s": 0.0})
            rec["count"] += 1
            rec["total_s"] += s.dur
        for rec in agg.values():
            rec["mean_s"] = rec["total_s"] / max(rec["count"], 1.0)
        return agg


@contextmanager
def maybe_span(tracer: Optional[Tracer], name: str,
               **args: Any) -> Iterator[Dict[str, Any]]:
    """``tracer.span(...)`` when tracing, else a free no-op (§15 rule)."""
    if tracer is None:
        yield args
    else:
        with tracer.span(name, **args) as a:
            yield a
