"""Three-term roofline from the compiled dry-run (no real hardware).

  compute    = HLO_FLOPs   / (chips x peak_FLOP/s)
  memory     = HLO_bytes   / (chips x HBM_bw)
  collective = wire_bytes  / (chips x link_bw)

FLOPs / bytes come from ``compiled.cost_analysis()``.  Collective bytes are
NOT in cost_analysis: we parse the post-SPMD optimized HLO text and sum the
wire traffic of every all-gather / all-reduce / reduce-scatter / all-to-all
/ collective-permute, using a ring-model byte count per participating
device:

  all-gather        (n-1)/n x output_bytes          ~= output_bytes
  reduce-scatter    (n-1)/n x input_bytes
  all-reduce        2 (n-1)/n x bytes               (RS + AG phases)
  all-to-all        (n-1)/n x bytes
  collective-permute  bytes (point-to-point)

Shapes in the optimized HLO are already *per-device* (post-partitioning), so
the sums are per-device wire bytes — exactly the numerator the collective
term needs.
"""

from __future__ import annotations

import dataclasses
import re
from typing import Dict, Optional, Tuple

import numpy as np

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2,
    "s32": 4, "u32": 4, "s64": 8, "u64": 8, "f8e4m3fn": 1, "f8e5m2": 1,
    "bf16": 2, "f16": 2, "f32": 4, "f64": 8, "c64": 8, "c128": 16,
    "token": 0,
}

# e.g. "u16[80,512,128]{2,1,0}" or "f32[]"; tuple types handled separately
_SHAPE_RE = re.compile(r"(\w+)\[([0-9,]*)\]")

_COLL_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?[\w.\-]+\s*=\s*(\([^)]*\)|[\w\[\],{}]+)\s+"
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute|"
    r"all-gather-start|all-reduce-start|collective-permute-start)\b",
    re.MULTILINE,
)

_REPLICA_GROUPS_RE = re.compile(r"replica_groups=\{\{([0-9,]+)\}")
_REPLICA_GROUPS_IOTA_RE = re.compile(r"\[(\d+),(\d+)\]<=\[")


def _shape_bytes(shape_str: str) -> int:
    total = 0
    for m in _SHAPE_RE.finditer(shape_str):
        dt, dims = m.group(1), m.group(2)
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def _group_size(line: str) -> int:
    m = _REPLICA_GROUPS_IOTA_RE.search(line)
    if m:
        # iota format [groups, group_size]<=[...]
        return int(m.group(2))
    m = _REPLICA_GROUPS_RE.search(line)
    if m:
        return len(m.group(1).split(","))
    return 2  # unknown -> conservative small group


def collective_bytes(hlo_text: str) -> Dict[str, float]:
    """Per-device wire bytes by collective kind (ring model)."""
    out: Dict[str, float] = {}
    for m in _COLL_RE.finditer(hlo_text):
        shape_str, op = m.group(1), m.group(2)
        op = op.replace("-start", "")
        line = hlo_text[m.start(): hlo_text.find("\n", m.start())]
        n = max(_group_size(line), 1)
        ring = (n - 1) / n
        size = _shape_bytes(shape_str)
        if op == "all-gather":
            wire = ring * size  # output is the gathered (per-device) result
        elif op == "reduce-scatter":
            wire = ring * size * n  # output is the scattered shard
        elif op == "all-reduce":
            wire = 2 * ring * size
        elif op == "all-to-all":
            wire = ring * size
        else:  # collective-permute
            wire = size
        out[op] = out.get(op, 0.0) + wire
        out["total"] = out.get("total", 0.0) + wire
    return out


def collective_counts(hlo_text: str) -> Dict[str, int]:
    counts: Dict[str, int] = {}
    for m in _COLL_RE.finditer(hlo_text):
        op = m.group(2).replace("-start", "")
        counts[op] = counts.get(op, 0) + 1
    return counts


@dataclasses.dataclass
class RooflineTerms:
    compute_s: float
    memory_s: float
    collective_s: float
    hlo_flops: float
    hlo_bytes: float
    wire_bytes: float
    per_collective: Dict[str, float]
    collective_ops: Dict[str, int]
    model_flops: float = 0.0
    top_collectives: list = dataclasses.field(default_factory=list)
    top_bytes: list = dataclasses.field(default_factory=list)
    xla_cost_analysis_flops: float = 0.0

    @property
    def dominant(self) -> str:
        terms = dict(compute=self.compute_s, memory=self.memory_s,
                     collective=self.collective_s)
        return max(terms, key=terms.get)

    @property
    def step_time_s(self) -> float:
        """No-overlap estimate: sum of terms (upper bound)."""
        return self.compute_s + self.memory_s + self.collective_s

    @property
    def step_time_overlap_s(self) -> float:
        """Perfect-overlap estimate: max of terms (lower bound)."""
        return max(self.compute_s, self.memory_s, self.collective_s)

    @property
    def useful_flops_ratio(self) -> float:
        """MODEL_FLOPS / HLO_FLOPs — remat/redundancy waste detector."""
        return self.model_flops / self.hlo_flops if self.hlo_flops else 0.0

    @property
    def mfu_bound(self) -> float:
        """Model-FLOPs utilization at the overlap-optimistic step time."""
        if self.step_time_overlap_s == 0:
            return 0.0
        return (self.model_flops and
                (self.model_flops / self.hlo_flops) * self.compute_s
                / self.step_time_overlap_s) or 0.0

    def to_dict(self):
        d = dataclasses.asdict(self)
        d.update(dominant=self.dominant, step_time_s=self.step_time_s,
                 step_time_overlap_s=self.step_time_overlap_s,
                 useful_flops_ratio=self.useful_flops_ratio)
        return d


def analyze_compiled(
    compiled,
    n_chips: int,
    *,
    peak_flops: float = 197e12,
    hbm_bw: float = 819e9,
    link_bw: float = 50e9,
    model_flops_total: float = 0.0,
    hlo_text: Optional[str] = None,
) -> RooflineTerms:
    """Derive the three roofline terms from a compiled executable.

    Costs come from the structured HLO model (repro.roofline.hlo_cost) which
    multiplies while-loop bodies by their trip counts — XLA's own
    cost_analysis counts scan bodies once and under-reports a layer-scanned
    model by ~L x (kept in the output as ``xla_cost_analysis`` for
    cross-checking).  All HLO-model numbers are per-device-per-step
    (post-SPMD shapes), so the per-chip roofline terms divide by nothing.
    """
    from .hlo_cost import HloCostModel

    text = hlo_text if hlo_text is not None else compiled.as_text()
    model = HloCostModel(text)
    cost = model.cost()
    counts = collective_counts(text)
    wire = cost.coll.get("total", 0.0)
    xla_flops = 0.0
    try:
        ca = compiled.cost_analysis()
        if isinstance(ca, (list, tuple)):
            ca = ca[0]
        xla_flops = float(ca.get("flops", 0.0))
    except Exception:
        pass
    terms = RooflineTerms(
        compute_s=cost.flops / peak_flops,
        memory_s=cost.bytes / hbm_bw,
        collective_s=wire / link_bw,
        hlo_flops=cost.flops * n_chips,  # whole-program, for MODEL_FLOPS ratio
        hlo_bytes=cost.bytes * n_chips,
        wire_bytes=wire,
        per_collective={k: v for k, v in cost.coll.items()},
        collective_ops=counts,
        model_flops=model_flops_total,
    )
    terms.top_collectives = model.top_collectives()
    terms.top_bytes = model.top_bytes()
    terms.xla_cost_analysis_flops = xla_flops
    return terms


# ---------------------------------------------------------------------------
# Minimal-HBM-byte bounds for the compressed-domain kernels (DESIGN.md §13).
# `benchmarks/kernels_micro.py` compares each kernel's *actual* padded buffer
# traffic (the `*_moved_bytes` helpers in repro.kernels) against these and
# asserts the ratio stays <= 2x — the acceptance gate that tile padding and
# superblock rounding never silently dominate the wire-path byte budget.
# ---------------------------------------------------------------------------


def packbits_bound_bytes(n: int, width: int) -> int:
    """Minimal HBM bytes to (un)pack ``n`` ``width``-bit codes.

    One read of the u32-lane code plane plus one write of the exact
    ``ceil(n*width/32)``-word bitstream (or the reverse); no padding.
    """
    from repro.core.packing import packed_words

    return 4 * n + 4 * packed_words(n, width)


def fused_aggregate_bound_bytes(cohort: int, n: int,
                                container_bytes: int) -> int:
    """Minimal HBM bytes for one fused compressed-domain server round.

    Reads the server plane and ``cohort`` client code planes once, writes the
    new server plane once — ``(C + 2) * n`` container elements; the per-client
    scalars are O(C) and ignored.  The unfused path moves ``(C + 1)`` extra
    *f32* round trips of the variable on top of this.
    """
    return (cohort + 2) * n * container_bytes


def model_flops(arch_mod, cfg, shape) -> float:
    """MODEL_FLOPS: 6·N·D for training, 2·N·D for inference (N = active)."""
    n = (cfg.active_param_count() if hasattr(cfg, "active_param_count")
         else cfg.param_count())
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        return 6.0 * n * tokens
    if shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        return 2.0 * n * tokens
    # decode: one token per sequence
    return 2.0 * n * shape.global_batch
