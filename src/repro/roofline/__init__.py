"""Roofline analysis from compiled dry-run artifacts."""

from .analysis import (
    RooflineTerms,
    analyze_compiled,
    collective_bytes,
    model_flops,
)
