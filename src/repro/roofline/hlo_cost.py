"""Structured HLO cost model with while-loop trip multipliers.

``compiled.cost_analysis()`` counts a ``while`` (lax.scan) body ONCE — a
layer-scanned transformer under-reports FLOPs/bytes/collectives by ~L x.
This module parses the post-SPMD optimized HLO text into computations,
resolves operand shapes, and accumulates costs bottom-up with each while's
``known_trip_count`` multiplier (fallback: the LT-compare constant in the
loop condition; else 1):

  * flops       — dot ops: 2 x |output| x |contracting dims|  (matmul work;
                  elementwise flops are bandwidth-bound and land in bytes)
  * bytes       — HBM traffic model: per *top-level* instruction, operand
                  bytes + output bytes for compute/copy ops (fusion internals
                  live in registers/VMEM and are excluded); slice/update ops
                  count only the moved window
  * collectives — ring-model wire bytes per device, by kind (matches
                  roofline.analysis), x trip multipliers

All shapes in the optimized HLO are already per-device (post-partitioning),
so every number is per-device-per-step.
"""

from __future__ import annotations

import dataclasses
import json
import re
from typing import Dict, List, Optional, Tuple

_DTYPE_BYTES = {
    "pred": 1, "s2": 1, "u2": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1,
    "s16": 2, "u16": 2, "s32": 4, "u32": 4, "s64": 8, "u64": 8,
    "f8e4m3fn": 1, "f8e5m2": 1, "bf16": 2, "f16": 2, "f32": 4, "f64": 8,
    "c64": 8, "c128": 16, "token": 0, "opaque": 0,
}

_COMP_HEADER_RE = re.compile(
    r"^(?:ENTRY\s+)?%?([\w.\-_]+)\s*\(.*->.*\{\s*$"
)
# type group: tuple types contain no nested parens but DO contain
# /*index=N*/ comments — match any paren-free run inside parens.
_INSTR_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?([\w.\-_]+)\s*=\s*((?:\([^()]*\))|(?:[\w\[\],{}]+))\s+"
    r"([\w\-]+)\((.*)$"
)
_SHAPE_RE = re.compile(r"(\w+)\[([0-9,]*)\]")
_TRIP_RE = re.compile(r'"known_trip_count":\{"n":"(\d+)"\}')
_CALLS_RE = re.compile(r"(?:calls|to_apply)=%?([\w.\-_]+)")
_COND_BODY_RE = re.compile(r"condition=%?([\w.\-_]+), body=%?([\w.\-_]+)")
_OPERAND_RE = re.compile(r"%([\w.\-_]+)")
_CONTRACT_RE = re.compile(r"lhs_contracting_dims=\{([0-9,]*)\}")
_CONST_RE = re.compile(r"constant\((\d+)\)")
_GROUPS_ITER_RE = re.compile(r"\[(\d+),(\d+)\]<=\[")
_GROUPS_LIST_RE = re.compile(r"replica_groups=\{\{([0-9,]+)\}")

_SKIP_BYTES_OPS = {
    "tuple", "get-tuple-element", "bitcast", "parameter", "constant",
    "after-all", "add-dependency", "iota", "broadcast", "reshape",
    "get-dimension-size", "partition-id", "replica-id", "rng-get-and-update-state",
    "opt-barrier", "domain",
}
_WINDOW_OPS = {"dynamic-slice", "dynamic-update-slice", "slice", "pad", "gather",
               "scatter"}
_COLLECTIVES = {"all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute"}


def _shape_list(type_str: str) -> List[Tuple[str, int]]:
    out = []
    for m in _SHAPE_RE.finditer(type_str):
        dt, dims = m.group(1), m.group(2)
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        out.append((dt, n))
    return out


def _type_bytes(type_str: str) -> int:
    return sum(n * _DTYPE_BYTES[dt] for dt, n in _shape_list(type_str))


@dataclasses.dataclass
class Instr:
    name: str
    type_str: str
    opcode: str
    rest: str  # args + attrs tail

    @property
    def out_bytes(self) -> int:
        return _type_bytes(self.type_str)


@dataclasses.dataclass
class CompCost:
    flops: float = 0.0
    bytes: float = 0.0
    coll: Optional[Dict[str, float]] = None
    coll_list: Optional[List] = None  # (kind, bytes, where) largest collectives
    bytes_list: Optional[List] = None  # (op, bytes, shape/meta) largest HBM ops

    def __post_init__(self):
        if self.coll is None:
            self.coll = {}
        if self.coll_list is None:
            self.coll_list = []
        if self.bytes_list is None:
            self.bytes_list = []


def parse_computations(hlo: str) -> Dict[str, List[Instr]]:
    comps: Dict[str, List[Instr]] = {}
    cur: Optional[str] = None
    for line in hlo.splitlines():
        if cur is None:
            m = _COMP_HEADER_RE.match(line)
            if m:
                cur = m.group(1)
                comps[cur] = []
            continue
        if line.startswith("}"):
            cur = None
            continue
        m = _INSTR_RE.match(line)
        if m:
            comps[cur].append(Instr(m.group(1), m.group(2), m.group(3),
                                    m.group(4)))
    return comps


def _dot_flops(instr: Instr, shapes: Dict[str, str]) -> float:
    out = _shape_list(instr.type_str)
    out_n = out[0][1] if out else 0
    ops = _OPERAND_RE.findall(instr.rest)
    if not ops:
        return 0.0
    lhs_type = shapes.get(ops[0], "")
    m = _SHAPE_RE.search(lhs_type)
    if not m:
        return 0.0
    dims = [int(d) for d in m.group(2).split(",")] if m.group(2) else []
    cm = _CONTRACT_RE.search(instr.rest)
    k = 1
    if cm and cm.group(1):
        for ci in cm.group(1).split(","):
            ci = int(ci)
            if ci < len(dims):
                k *= dims[ci]
    return 2.0 * out_n * k


def _group_size(rest: str) -> int:
    m = _GROUPS_ITER_RE.search(rest)
    if m:
        return int(m.group(2))
    m = _GROUPS_LIST_RE.search(rest)
    if m:
        return len(m.group(1).split(","))
    return 2


def _collective_wire(instr: Instr, shapes: Dict[str, str]) -> float:
    n = max(_group_size(instr.rest), 1)
    ring = (n - 1) / n
    size = _type_bytes(instr.type_str)
    op = instr.opcode.replace("-start", "")
    if op == "all-gather":
        return ring * size
    if op == "reduce-scatter":
        return ring * size * n
    if op == "all-reduce":
        return 2 * ring * size
    if op == "all-to-all":
        return ring * size
    return size  # collective-permute


def _operand_bytes(instr: Instr, shapes: Dict[str, str]) -> int:
    total = 0
    # strip attrs: operands appear before the first "), " ... simpler: scan
    # all %refs but stop counting refs inside calls=/condition=/body= attrs.
    args = instr.rest.split("), ")[0] if "), " in instr.rest else instr.rest
    for name in _OPERAND_RE.findall(args):
        t = shapes.get(name)
        if t:
            total += _type_bytes(t)
    return total


def _trip_count(instr: Instr, comps, shapes_by_comp) -> int:
    m = _TRIP_RE.search(instr.rest)
    if m:
        return int(m.group(1))
    # Fallback: the loop condition is `compare(induction, constant(N), LT)`,
    # possibly wrapped in a fusion.  Trace the ROOT's constant operand.
    cb = _COND_BODY_RE.search(instr.rest)
    if not cb:
        return 1
    cond = comps.get(cb.group(1), [])
    consts = {}
    for ins in cond:
        c = _CONST_RE.search(ins.rest)
        if ins.opcode == "constant" and c:
            consts[ins.name] = int(c.group(1))
    root = cond[-1] if cond else None
    if root is None:
        return 1
    for name in _OPERAND_RE.findall(root.rest):
        if name in consts:
            return consts[name]
    # ROOT may be a fusion: look for a compare-with-constant in its body
    cm = _CALLS_RE.search(root.rest)
    if cm:
        for sub in comps.get(cm.group(1), []):
            c = _CONST_RE.search(sub.rest)
            if sub.opcode == "constant" and c:
                return int(c.group(1))
    if len(consts) == 1:
        return next(iter(consts.values()))
    return 1


class HloCostModel:
    def __init__(self, hlo_text: str):
        self.comps = parse_computations(hlo_text)
        self.shapes: Dict[str, Dict[str, str]] = {
            c: {i.name: i.type_str for i in instrs}
            for c, instrs in self.comps.items()
        }
        self._memo: Dict[Tuple[str, bool], CompCost] = {}
        self.entry = self._find_entry(hlo_text)

    def _find_entry(self, hlo: str) -> str:
        m = re.search(r"^ENTRY\s+%?([\w.\-_]+)", hlo, re.MULTILINE)
        return m.group(1) if m else next(iter(self.comps))

    def cost(self, comp: Optional[str] = None, *, fused: bool = False) -> CompCost:
        comp = comp or self.entry
        key = (comp, fused)
        if key in self._memo:
            return self._memo[key]
        total = CompCost()
        shapes = self.shapes.get(comp, {})

        def push_bytes(nbytes, instr):
            if nbytes > 1e6:
                meta = instr.opcode + " " + instr.type_str[:48]
                m = re.search(r'op_name="([^"]*)"', instr.rest)
                if m:
                    meta += " @" + m.group(1)[-60:]
                total.bytes_list.append((instr.opcode, float(nbytes), meta))

        for instr in self.comps.get(comp, []):
            op = instr.opcode
            if op == "while":
                cb = _COND_BODY_RE.search(instr.rest)
                trips = _trip_count(instr, self.comps, self.shapes)
                if cb:
                    body = self.cost(cb.group(2))
                    cond = self.cost(cb.group(1))
                    total.flops += trips * (body.flops + cond.flops)
                    total.bytes += trips * (body.bytes + cond.bytes)
                    for k, v in {**body.coll, **{}}.items():
                        total.coll[k] = total.coll.get(k, 0.0) + trips * v
                    for kind, b, where in body.coll_list:
                        total.coll_list.append((kind, trips * b,
                                                f"{where} x{trips}"))
                    for kind, b, where in body.bytes_list:
                        total.bytes_list.append((kind, trips * b,
                                                 f"{where} x{trips}"))
                continue
            if op in ("fusion", "call", "async-start", "custom-call"):
                cm = _CALLS_RE.search(instr.rest)
                called = cm.group(1) if cm and cm.group(1) in self.comps else None
                if called:
                    sub = self.cost(called, fused=True)
                    total.flops += sub.flops
                    for k, v in sub.coll.items():
                        total.coll[k] = total.coll.get(k, 0.0) + v
                    total.coll_list.extend(sub.coll_list)
                if not fused:
                    if called:
                        nb = (self._fusion_write_bytes(instr, called)
                              + self._fusion_read_bytes(instr, called, shapes))
                    else:
                        nb = instr.out_bytes + _operand_bytes(instr, shapes)
                    total.bytes += nb
                    push_bytes(nb, instr)
                continue
            if op == "conditional":
                # count the max-cost branch (upper bound)
                branches = [self.cost(c) for c in _OPERAND_RE.findall(
                    instr.rest.split("branch_computations={")[-1].split("}")[0])
                    if c in self.comps]
                if branches:
                    best = max(branches, key=lambda c: c.flops + c.bytes)
                    total.flops += best.flops
                    total.bytes += best.bytes
                continue
            base = op.replace("-start", "")
            if base in _COLLECTIVES:
                wire = _collective_wire(instr, shapes)
                total.coll[base] = total.coll.get(base, 0.0) + wire
                total.coll["total"] = total.coll.get("total", 0.0) + wire
                total.coll_list.append((base, wire, instr.type_str[:64]))
                if not fused:
                    total.bytes += instr.out_bytes
                continue
            if op.startswith("dot"):
                total.flops += _dot_flops(instr, shapes)
                if not fused:
                    nb = instr.out_bytes + _operand_bytes(instr, shapes)
                    total.bytes += nb
                    push_bytes(nb, instr)
                continue
            if op == "convolution":
                # window flops ~ 2 x out x (k x Cin): approximate via operand
                total.flops += 2.0 * instr.out_bytes / 4 * 1  # conservative
                if not fused:
                    total.bytes += instr.out_bytes + _operand_bytes(instr, shapes)
                continue
            if fused or op in _SKIP_BYTES_OPS:
                continue
            if op in _WINDOW_OPS:
                if op == "dynamic-update-slice":
                    ops_ = _OPERAND_RE.findall(instr.rest)
                    upd = self.shapes.get(comp, {}).get(ops_[1]) if len(ops_) > 1 else None
                    nb = 2 * (_type_bytes(upd) if upd else instr.out_bytes)
                else:
                    nb = 2 * instr.out_bytes
                total.bytes += nb
                push_bytes(nb, instr)
                continue
            nb = instr.out_bytes + _operand_bytes(instr, shapes)
            total.bytes += nb
            push_bytes(nb, instr)
        self._memo[key] = total
        return total

    def _fusion_read_bytes(self, instr: Instr, called: str,
                           shapes: Dict[str, str]) -> int:
        """Operand bytes actually *read* by a fusion.

        A fused computation whose parameter is consumed only through
        dynamic-slice/slice/gather windows (the lax.scan layer-slice
        pattern) reads the window, not the whole stacked operand — count
        the window size.  Pass-through bitcast/reshape/copy chains are
        followed one level deep.
        """
        instrs = self.comps.get(called, [])
        by_idx: Dict[int, str] = {}
        for ins in instrs:
            if ins.opcode == "parameter":
                m = re.match(r"(\d+)", ins.rest)
                if m:
                    by_idx[int(m.group(1))] = ins.name
        args = instr.rest.split("), ")[0] if "), " in instr.rest else instr.rest
        operand_names = _OPERAND_RE.findall(args)
        total = 0
        for idx, opname in enumerate(operand_names):
            t_full = shapes.get(opname)
            full = _type_bytes(t_full) if t_full else 0
            pname = by_idx.get(idx)
            if pname is None:
                total += full
                continue
            names = {pname}
            sliced = 0
            only_window = True
            seen = False
            for ins in instrs:
                if ins.opcode == "parameter":
                    continue
                a = ins.rest.split("), ")[0] if "), " in ins.rest else ins.rest
                refs = set(_OPERAND_RE.findall(a))
                if names & refs:
                    seen = True
                    if ins.opcode in ("bitcast", "reshape", "copy"):
                        names.add(ins.name)
                    elif ins.opcode in ("dynamic-slice", "slice", "gather"):
                        sliced += ins.out_bytes
                    elif ins.opcode == "dynamic-update-slice":
                        # operand 0 of dus is aliased, not read; the update
                        # window comes from elsewhere.  Contributes 0 reads.
                        ops_ = _OPERAND_RE.findall(
                            ins.rest.split("), ")[0] if "), " in ins.rest
                            else ins.rest)
                        if ops_ and ops_[0] in names:
                            sliced += 1  # nonzero sentinel: window-only use
                        else:
                            only_window = False
                            break
                    else:
                        only_window = False
                        break
            total += sliced if (seen and only_window and sliced > 0) else full
        return total

    def _fusion_write_bytes(self, instr: Instr, called: str) -> int:
        """Output bytes actually *written* by a fusion.

        A fusion rooted at dynamic-update-slice aliases its input buffer and
        writes only the update window (the lax.scan ys/grad accumulation
        pattern) — counting the whole buffer per iteration overstates scan
        accumulators by the trip count.
        """
        instrs = self.comps.get(called, [])
        dus = [i for i in instrs if i.opcode == "dynamic-update-slice"]
        if not dus:
            return instr.out_bytes
        win = 0
        shapes = self.shapes.get(called, {})
        for i in dus:
            ops_ = _OPERAND_RE.findall(i.rest)
            upd = shapes.get(ops_[1]) if len(ops_) > 1 else None
            win += _type_bytes(upd) if upd else i.out_bytes
        return win

    def top_collectives(self, k: int = 12):
        c = self.cost()
        return sorted(c.coll_list, key=lambda t: -t[1])[:k]

    def top_bytes(self, k: int = 16):
        c = self.cost()
        return sorted(c.bytes_list, key=lambda t: -t[1])[:k]


def analyze_hlo(hlo_text: str) -> CompCost:
    return HloCostModel(hlo_text).cost()
