import os
os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=512 "
    + os.environ.get("XLA_FLAGS", "")
)

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

This proves the distribution config is coherent without real hardware: a
sharding mismatch, OOM-at-compile or unsupported collective fails here.
Outputs per cell: memory_analysis, cost_analysis, collective schedule and
the three roofline terms -> JSON under experiments/dryrun/.

Usage:
    python -m repro.launch.dryrun --arch qwen2.5-3b --shape train_4k
    python -m repro.launch.dryrun --all            # every assigned cell
    python -m repro.launch.dryrun --arch ... --shape ... --multi-pod
    python -m repro.launch.dryrun ... --fp32-baseline   # paper FP32 control
"""

import argparse
import json
import time
import traceback
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp

from repro.configs.registry import ASSIGNED, get_arch
from repro.configs.shapes import SHAPES, Shape
from repro.core.omc import OMCConfig
from repro.federated.round import make_round_fn, make_serve_fns
from repro.federated.state import init_state
from repro.launch import specs as S
from repro.launch.mesh import HBM_BW, ICI_BW, PEAK_FLOPS_BF16, make_production_mesh
from repro.models.common import activate_mesh
from repro.models.registry import get_family
from repro.obs.log import Logger
from repro.optim import fedavg
from repro.roofline.analysis import analyze_compiled, model_flops

OUT_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                       "experiments", "dryrun")

# module-level so run_cell keeps its signature for programmatic callers;
# main() rebinds it from --quiet
log = Logger()


def build_and_lower(arch_id: str, shape_name: str, *, multi_pod: bool = False,
                    fmt: str = "S1E4M14", fp32_baseline: bool = False,
                    compute_dtype: str = "bf16", overrides=None):
    """Returns (lowered, n_chips, cfg, shape, extras)."""
    arch = get_arch(arch_id)
    shape = SHAPES[shape_name]
    if shape.sub_quadratic_only and not arch.LONG_CONTEXT_OK:
        raise SystemExit(
            f"SKIP {arch_id} x {shape_name}: full-attention arch, long-context "
            f"decode requires sub-quadratic state (DESIGN.md §6)"
        )
    mesh = make_production_mesh(multi_pod=multi_pod)
    n_chips = mesh.devices.size
    family = get_family(arch.FAMILY)
    cfg = S.maybe_ep_partitions(arch.config(), mesh)
    if overrides:
        import dataclasses as _dc
        typed = {}
        for k, v in overrides.items():
            cur = getattr(cfg, k)
            typed[k] = type(cur)(v) if cur is not None and not isinstance(cur, bool) else (
                v in ("1", "true", "True") if isinstance(cur, bool) else v)
        cfg = _dc.replace(cfg, **typed)
    omc = OMCConfig.parse("S1E8M23" if fp32_baseline else fmt)
    cdt = jnp.bfloat16 if compute_dtype == "bf16" else jnp.float32

    with activate_mesh(mesh):
        batch = S.annotate_batch(S.batch_specs(arch, cfg, shape), mesh)
        if shape.kind == "train":
            opt = fedavg(1.0)
            state_struct = jax.eval_shape(
                lambda k: init_state(k, family, cfg, omc, opt),
                jax.random.PRNGKey(0),
            )
            state_in = S.annotate_state(state_struct, family.param_specs(cfg), mesh)
            round_fn = make_round_fn(family, cfg, omc, opt, client_lr=1e-2,
                                     compute_dtype=cdt)
            fn = jax.jit(round_fn, donate_argnums=(0,))
            lowered = fn.lower(state_in, batch)
        else:
            params_struct = jax.eval_shape(
                lambda k: init_state(k, family, cfg, omc, fedavg(1.0)).params,
                jax.random.PRNGKey(0),
            )
            params_in = S.annotate_tree(params_struct, family.param_specs(cfg), mesh)
            prefill_fn, decode_fn = make_serve_fns(family, cfg, compute_dtype=cdt)
            cache_struct = jax.eval_shape(
                lambda: family.init_decode_state(cfg, shape.global_batch,
                                                 shape.seq_len)
            )
            cache_in = S.annotate_cache(cache_struct, arch.FAMILY, cfg, mesh)
            if shape.kind == "prefill":
                fn = jax.jit(prefill_fn, donate_argnums=(2,))
                lowered = fn.lower(params_in, batch, cache_in)
            else:
                fn = jax.jit(decode_fn, donate_argnums=(1,))
                lowered = fn.lower(params_in, cache_in, batch["tokens"])
    return lowered, n_chips, cfg, shape, dict(mesh_shape=tuple(mesh.devices.shape),
                                              arch=arch, family=family)


def run_cell(arch_id: str, shape_name: str, *, multi_pod: bool = False,
             fmt: str = "S1E4M14", fp32_baseline: bool = False,
             out_dir: Optional[str] = None, tag: str = "",
             overrides=None) -> Dict[str, Any]:
    t0 = time.time()
    lowered, n_chips, cfg, shape, ex = build_and_lower(
        arch_id, shape_name, multi_pod=multi_pod, fmt=fmt,
        fp32_baseline=fp32_baseline, overrides=overrides,
    )
    t_lower = time.time() - t0
    t0 = time.time()
    compiled = lowered.compile()
    t_compile = time.time() - t0

    mem = {}
    try:
        ma = compiled.memory_analysis()
        for k in ("argument_size_in_bytes", "output_size_in_bytes",
                  "temp_size_in_bytes", "generated_code_size_in_bytes",
                  "alias_size_in_bytes"):
            v = getattr(ma, k, None)
            if v is not None:
                mem[k] = int(v)
    except Exception as e:  # CPU backend may not support it
        mem["error"] = str(e)

    hlo_text = compiled.as_text()
    terms = analyze_compiled(
        compiled, n_chips,
        peak_flops=PEAK_FLOPS_BF16, hbm_bw=HBM_BW, link_bw=ICI_BW,
        model_flops_total=model_flops(ex["arch"], cfg, shape),
    )
    result = dict(
        arch=arch_id, shape=shape_name, mesh=list(ex["mesh_shape"]),
        n_chips=n_chips, fmt=("S1E8M23" if fp32_baseline else fmt),
        lower_s=round(t_lower, 1), compile_s=round(t_compile, 1),
        memory_analysis=mem,
        roofline=terms.to_dict(),
        hlo_bytes_len=len(hlo_text),
    )
    od = out_dir or OUT_DIR
    os.makedirs(od, exist_ok=True)
    mesh_tag = "multipod" if multi_pod else "pod"
    suffix = f"_{tag}" if tag else ("_fp32" if fp32_baseline else "")
    path = os.path.join(od, f"{arch_id}_{shape_name}_{mesh_tag}{suffix}.json")
    with open(path, "w") as f:
        json.dump(result, f, indent=1)
    log.result(
        f"OK {arch_id} x {shape_name} [{mesh_tag}] "
        f"lower={t_lower:.0f}s compile={t_compile:.0f}s "
        f"dominant={terms.dominant} "
        f"terms=({terms.compute_s*1e3:.1f}, {terms.memory_s*1e3:.1f}, "
        f"{terms.collective_s*1e3:.1f}) ms -> {path}",
        arch=arch_id, shape=shape_name, mesh=mesh_tag,
        lower_s=round(t_lower, 1), compile_s=round(t_compile, 1),
        dominant=terms.dominant, path=path,
    )
    return result


def main():
    global log
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--fmt", default="S1E4M14")
    ap.add_argument("--fp32-baseline", action="store_true")
    ap.add_argument("--tag", default="")
    ap.add_argument("--out-dir", default=None)
    ap.add_argument("--set", action="append", default=[],
                    help="config override key=value (repeatable)")
    ap.add_argument("--quiet", action="store_true",
                    help="suppress stderr text")
    args = ap.parse_args()
    log = Logger(quiet=args.quiet)
    overrides = dict(s.split("=", 1) for s in args.set) or None

    if args.all:
        failures = []
        for arch_id in ASSIGNED:
            for shape_name, shape in SHAPES.items():
                arch = get_arch(arch_id)
                if shape.sub_quadratic_only and not arch.LONG_CONTEXT_OK:
                    log.warn(f"SKIP {arch_id} x {shape_name} (full attention)",
                             arch=arch_id, shape=shape_name)
                    continue
                try:
                    run_cell(arch_id, shape_name, multi_pod=args.multi_pod,
                             fmt=args.fmt, fp32_baseline=args.fp32_baseline,
                             out_dir=args.out_dir, tag=args.tag)
                except Exception:
                    traceback.print_exc()
                    failures.append((arch_id, shape_name))
        if failures:
            raise SystemExit(f"FAILED cells: {failures}")
        log.result("ALL CELLS PASSED")
        return
    run_cell(args.arch, args.shape, multi_pod=args.multi_pod, fmt=args.fmt,
             fp32_baseline=args.fp32_baseline, out_dir=args.out_dir,
             tag=args.tag, overrides=overrides)


if __name__ == "__main__":
    main()
