"""End-to-end federated training driver with checkpoint/restart.

Runs the jit-able federated round (compressed-state OMC by default) on a
synthetic LM/frame task, checkpointing atomically every ``--ckpt-every``
rounds and resuming from the latest checkpoint if one exists (fault
tolerance: kill the process at any point and rerun the same command).

Examples:
    # CPU-scale smoke run
    PYTHONPATH=src python -m repro.launch.train --arch qwen2.5-3b --smoke \
        --rounds 30 --batch 8 --seq 64

    # ~100M-parameter end-to-end run (real hardware scale)
    PYTHONPATH=src python -m repro.launch.train --arch conformer_s \
        --rounds 300 --batch 16

    # paper FP32 control
    ... --fmt S1E8M23
"""

from __future__ import annotations

import argparse
import json
import os
import time

import jax
import jax.numpy as jnp

from repro import checkpoint as ck
from repro.configs.registry import get_arch
from repro.core.omc import OMCConfig
from repro.data.synthetic import make_frame_task, make_lm_task
from repro.federated.round import make_round_fn
from repro.federated.state import init_state, state_bytes_report
from repro.models.registry import get_family
from repro.optim import fedavg


def make_task(arch, cfg, seq: int, num_clients: int, iid: bool, seed: int):
    fam = arch.FAMILY
    if fam == "conformer":
        task = make_frame_task(d_in=cfg.d_in, n_classes=cfg.n_classes,
                               seq_len=seq, num_clients=num_clients, iid=iid,
                               seed=seed)
        return lambda c, r, s, b: task.batch(c, r, s, b)
    if fam in ("transformer", "moe", "xlstm", "griffin"):
        task = make_lm_task(vocab=min(cfg.vocab, 4096), seq_len=seq,
                            num_clients=num_clients, iid=iid, seed=seed)
        return lambda c, r, s, b: task.batch(c, r, s, b)
    raise SystemExit(f"train driver supports LM/conformer tasks, not {fam}")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="conformer_s")
    ap.add_argument("--smoke", action="store_true",
                    help="use the reduced config (CPU scale)")
    ap.add_argument("--fmt", default="S1E4M14")
    ap.add_argument("--rounds", type=int, default=50)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=48)
    ap.add_argument("--clients", type=int, default=16)
    ap.add_argument("--non-iid", action="store_true")
    ap.add_argument("--client-lr", type=float, default=0.05)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=20)
    ap.add_argument("--log-every", type=int, default=5)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    arch = get_arch(args.arch)
    cfg = arch.smoke_config() if args.smoke else arch.config()
    family = get_family(arch.FAMILY)
    omc = OMCConfig.parse(args.fmt)
    opt = fedavg(1.0)

    state = init_state(jax.random.PRNGKey(args.seed), family, cfg, omc, opt)
    rep = state_bytes_report(state.params)
    print(f"arch={args.arch} fmt={args.fmt} params={rep['num_params'] / 1e6:.1f}M "
          f"container={rep['container_ratio']:.0%} packed={rep['packed_ratio']:.0%} of FP32")

    start_round = 0
    if args.ckpt_dir:
        found = ck.latest_checkpoint(args.ckpt_dir)
        if found:
            state, manifest = ck.restore_state(found[0], state)
            start_round = manifest["step"]
            print(f"resumed from {found[0]} at round {start_round}")

    data_fn = make_task(arch, cfg, args.seq, args.clients, not args.non_iid,
                        args.seed)
    round_fn = jax.jit(make_round_fn(family, cfg, omc, opt,
                                     client_lr=args.client_lr))

    t0 = time.time()
    for r in range(start_round, args.rounds):
        batch = data_fn(r % args.clients, r, 0, args.batch)
        state, metrics = round_fn(state, batch)
        if (r + 1) % args.log_every == 0 or r == start_round:
            dt = time.time() - t0
            print(f"round {r + 1}/{args.rounds} loss={float(metrics['loss']):.4f} "
                  f"gnorm={float(metrics['grad_norm']):.3f} "
                  f"({(r + 1 - start_round) / max(dt, 1e-9):.2f} rounds/s)")
        if args.ckpt_dir and (r + 1) % args.ckpt_every == 0:
            path = ck.save_state(args.ckpt_dir, r + 1, state)
            print(f"checkpointed -> {path}")
    if args.ckpt_dir:
        ck.save_state(args.ckpt_dir, args.rounds, state)
    print("done")


if __name__ == "__main__":
    main()
