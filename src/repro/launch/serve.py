"""Serving driver: batched generation over OMC-compressed weights.

Weights stay compressed in memory (the paper's storage model); each layer
decompresses on the fly inside the jitted decode step.  The driver runs on
a :class:`repro.api.session.ServeSession`, the same abstraction the wire
demo hot-swaps payloads into — so what this benchmarks is exactly the
serve path a federated deployment would run between rounds (DESIGN.md §7).
Reports prefill and per-token decode latency/throughput.

    PYTHONPATH=src python -m repro.launch.serve --arch qwen2.5-3b --smoke \
        --batch 4 --prompt-len 32 --gen 16 --fmt S1E3M7

``--wire-roundtrip`` additionally pushes the weights through the wire codec
(encode -> decode -> hot_swap) before serving, proving the payload path is
bit-transparent to generation.
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from repro.api.codecs import encode_payload
from repro.api.session import ServeSession
from repro.configs.registry import get_arch
from repro.core.omc import OMCConfig
from repro.federated.state import compress_params
from repro.models.registry import get_family, is_servable
from repro.obs.log import Logger


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2.5-3b")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--fmt", default="S1E3M7")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=16)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--wire-roundtrip", action="store_true",
                    help="serialize weights through the wire codec first")
    ap.add_argument("--quiet", action="store_true",
                    help="suppress stderr text")
    args = ap.parse_args()
    log = Logger(quiet=args.quiet)

    arch = get_arch(args.arch)
    if not is_servable(arch.FAMILY):
        raise SystemExit(f"{args.arch} ({arch.FAMILY}) has no decode step")
    cfg = arch.smoke_config() if args.smoke else arch.config()
    family = get_family(arch.FAMILY)
    omc = OMCConfig.parse(args.fmt)

    key = jax.random.PRNGKey(args.seed)
    params = family.init(key, cfg)
    storage = compress_params(params, family.param_specs(cfg), omc)
    sess = ServeSession(family, cfg, storage)
    if args.wire_roundtrip:
        t0 = time.time()
        payload = encode_payload(storage)
        sess.hot_swap(payload)
        log.info(f"wire roundtrip: {len(payload)} B payload in "
                 f"{(time.time() - t0) * 1e3:.1f} ms",
                 payload_bytes=len(payload),
                 roundtrip_ms=(time.time() - t0) * 1e3)

    b, s = args.batch, args.prompt_len
    toks = jax.random.randint(jax.random.fold_in(key, 1), (b, s), 0, cfg.vocab)
    batch = dict(tokens=toks)
    if arch.FAMILY == "vlm":
        batch["patches"] = jax.random.normal(
            jax.random.fold_in(key, 2), (b, cfg.prefix_embeds, cfg.d_model))
    if arch.FAMILY == "encdec":
        batch["frames"] = jax.random.normal(
            jax.random.fold_in(key, 2), (b, 4 * (s + args.gen), cfg.d_model))

    cache = sess.init_cache(b, 4 * (s + args.gen), dtype=jnp.float32)
    t0 = time.time()
    cache, logits = jax.block_until_ready(sess.prefill(batch, cache))
    t_prefill = time.time() - t0
    log.info(f"prefill [{b}x{s}] in {t_prefill * 1e3:.1f} ms",
             batch=b, prompt_len=s, prefill_ms=t_prefill * 1e3)

    out_tokens = []
    tok = jnp.argmax(logits[:, -1], axis=-1)[:, None]
    t0 = time.time()
    for i in range(args.gen):
        cache, logits = sess.decode_step(cache, tok)
        tok = jnp.argmax(logits[:, -1], axis=-1)[:, None]
        out_tokens.append(tok)
    jax.block_until_ready(tok)
    dt = time.time() - t0
    log.result(
        f"decoded {args.gen} tokens x {b} seqs in {dt * 1e3:.1f} ms "
        f"({args.gen * b / dt:.1f} tok/s, {dt / args.gen * 1e3:.2f} ms/tok)",
        gen_tokens=args.gen, batch=b, decode_ms=dt * 1e3,
        tok_per_s=args.gen * b / dt,
    )
    gen = jnp.concatenate(out_tokens, axis=1)
    log.info(f"sample token ids: {gen[0, :12].tolist()}")


if __name__ == "__main__":
    main()
