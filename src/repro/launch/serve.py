"""Serving driver: batched generation over OMC-compressed weights.

Weights stay compressed in memory (the paper's storage model); each layer
decompresses on the fly inside the jitted decode step.  Reports prefill and
per-token decode latency/throughput.

    PYTHONPATH=src python -m repro.launch.serve --arch qwen2.5-3b --smoke \
        --batch 4 --prompt-len 32 --gen 16 --fmt S1E3M7
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from repro.configs.registry import get_arch
from repro.core.omc import OMCConfig
from repro.federated.round import make_serve_fns
from repro.federated.state import compress_params
from repro.models.registry import get_family, is_servable


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2.5-3b")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--fmt", default="S1E3M7")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=16)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    arch = get_arch(args.arch)
    if not is_servable(arch.FAMILY):
        raise SystemExit(f"{args.arch} ({arch.FAMILY}) has no decode step")
    cfg = arch.smoke_config() if args.smoke else arch.config()
    family = get_family(arch.FAMILY)
    omc = OMCConfig.parse(args.fmt)

    key = jax.random.PRNGKey(args.seed)
    params = family.init(key, cfg)
    storage = compress_params(params, family.param_specs(cfg), omc)
    prefill_fn, decode_fn = make_serve_fns(family, cfg)
    prefill_fn = jax.jit(prefill_fn)
    decode_fn = jax.jit(decode_fn)

    b, s = args.batch, args.prompt_len
    toks = jax.random.randint(jax.random.fold_in(key, 1), (b, s), 0, cfg.vocab)
    batch = dict(tokens=toks)
    if arch.FAMILY == "vlm":
        batch["patches"] = jax.random.normal(
            jax.random.fold_in(key, 2), (b, cfg.prefix_embeds, cfg.d_model))
    if arch.FAMILY == "encdec":
        batch["frames"] = jax.random.normal(
            jax.random.fold_in(key, 2), (b, 4 * (s + args.gen), cfg.d_model))

    cache = family.init_decode_state(cfg, b, 4 * (s + args.gen),
                                     dtype=jnp.float32)
    t0 = time.time()
    cache, logits = jax.block_until_ready(prefill_fn(storage, batch, cache))
    t_prefill = time.time() - t0
    print(f"prefill [{b}x{s}] in {t_prefill * 1e3:.1f} ms")

    out_tokens = []
    tok = jnp.argmax(logits[:, -1], axis=-1)[:, None]
    t0 = time.time()
    for i in range(args.gen):
        cache, logits = decode_fn(storage, cache, tok)
        tok = jnp.argmax(logits[:, -1], axis=-1)[:, None]
        out_tokens.append(tok)
    jax.block_until_ready(tok)
    dt = time.time() - t0
    print(f"decoded {args.gen} tokens x {b} seqs in {dt * 1e3:.1f} ms "
          f"({args.gen * b / dt:.1f} tok/s, {dt / args.gen * 1e3:.2f} ms/tok)")
    gen = jnp.concatenate(out_tokens, axis=1)
    print("sample token ids:", gen[0, :12].tolist())


if __name__ == "__main__":
    main()
