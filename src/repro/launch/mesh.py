"""Production mesh definition (TPU v5e target).

A FUNCTION, not a module-level constant: importing this module never touches
jax device state (the dry-run must set XLA_FLAGS before any jax init).

Besides the 2-D/3-D production meshes (DESIGN.md §4) this module owns the
1-D ``("clients",)`` population mesh that `repro.scale` shards per-client
state over (DESIGN.md §14).
"""

from __future__ import annotations

import jax


def compat_make_mesh(shape, axes):
    """jax.make_mesh with explicit Auto axis types where the installed jax
    supports them (jax.sharding.AxisType landed after 0.4.37; older
    versions default every axis to Auto anyway)."""
    kw = {}
    if hasattr(jax.sharding, "AxisType"):
        kw["axis_types"] = (jax.sharding.AxisType.Auto,) * len(axes)
    return jax.make_mesh(shape, axes, **kw)


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 single-pod (256 chips) or 2x16x16 multi-pod (512 chips)."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return compat_make_mesh(shape, axes)


def make_host_mesh(data: int = 1, model: int = 1):
    """Small host mesh for tests (requires >= data*model local devices)."""
    return compat_make_mesh((data, model), ("data", "model"))


def make_population_mesh(num_shards=None):
    """1-D ``("clients",)`` mesh for sharded population state (DESIGN.md §14).

    Per-client server state (EF residuals, counters —
    :class:`repro.scale.store.PopulationStore`) partitions along one
    logical ``clients`` axis; this mesh maps that axis onto the local
    devices.  ``num_shards`` is clamped to the available device count —
    the *logical* shard count (``ShardLayout.num_shards``) may exceed it,
    in which case multiple logical shards share a device (the single-CPU
    test topology runs every shard on one device).
    """
    n = len(jax.devices())
    if num_shards is not None:
        n = max(1, min(int(num_shards), n))
    return compat_make_mesh((n,), ("clients",))


# TPU v5e hardware constants (per chip) — used by the roofline analysis.
PEAK_FLOPS_BF16 = 197e12  # FLOP/s
HBM_BW = 819e9  # bytes/s
ICI_BW = 50e9  # bytes/s per link
