"""ShapeDtypeStruct input stand-ins + sharding annotation for the dry-run.

``input_specs(arch, shape)`` returns weak-type-correct, shardable,
zero-allocation stand-ins for every model input of that (arch x shape) cell;
``annotate`` attaches NamedShardings so ``jit(...).lower(*specs)`` sees the
production sharding layout without touching device memory.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs.shapes import Shape
from repro.models.common import ParamSpec, _pad_spec, resolve_spec


def maybe_ep_partitions(cfg, mesh) -> Any:
    """MoE: set ep_partitions so stored experts divide the model axis."""
    if not hasattr(cfg, "n_experts") or mesh is None:
        return cfg
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    m = sizes.get("model", 1)
    if cfg.n_experts % m == 0 or m % cfg.n_experts != 0:
        return cfg
    return dataclasses.replace(cfg, ep_partitions=m // cfg.n_experts)


def batch_specs(arch_mod, cfg, shape: Shape) -> Dict[str, jax.ShapeDtypeStruct]:
    """Model-input stand-ins for one cell (no params / caches)."""
    b, s = shape.global_batch, shape.seq_len
    fam = arch_mod.FAMILY
    i32, f32 = jnp.int32, jnp.float32
    if fam in ("transformer", "moe", "xlstm", "griffin"):
        if shape.kind == "train":
            return dict(tokens=jax.ShapeDtypeStruct((b, s), i32),
                        labels=jax.ShapeDtypeStruct((b, s), i32))
        n = s if shape.kind == "prefill" else 1
        return dict(tokens=jax.ShapeDtypeStruct((b, n), i32))
    if fam == "vlm":
        npatch = cfg.prefix_embeds
        nt = s - npatch
        if shape.kind == "train":
            return dict(
                patches=jax.ShapeDtypeStruct((b, npatch, cfg.d_model), f32),
                tokens=jax.ShapeDtypeStruct((b, nt), i32),
                labels=jax.ShapeDtypeStruct((b, nt), i32),
            )
        if shape.kind == "prefill":
            return dict(
                patches=jax.ShapeDtypeStruct((b, npatch, cfg.d_model), f32),
                tokens=jax.ShapeDtypeStruct((b, nt), i32),
            )
        return dict(tokens=jax.ShapeDtypeStruct((b, 1), i32))
    if fam == "encdec":
        sd = s // cfg.dec_ratio
        if shape.kind == "train":
            return dict(
                frames=jax.ShapeDtypeStruct((b, s, cfg.d_model), f32),
                tokens=jax.ShapeDtypeStruct((b, sd), i32),
                labels=jax.ShapeDtypeStruct((b, sd), i32),
            )
        if shape.kind == "prefill":
            return dict(
                frames=jax.ShapeDtypeStruct((b, s, cfg.d_model), f32),
                tokens=jax.ShapeDtypeStruct((b, sd), i32),
            )
        return dict(tokens=jax.ShapeDtypeStruct((b, 1), i32))
    raise ValueError(f"no input specs for family {fam}")


_BATCH_AXES = {
    "tokens": ("batch", None),
    "labels": ("batch", None),
    "mask": ("batch", None),
    "patches": ("batch", None, None),
    "frames": ("batch", None, None),
}


def annotate_batch(specs: Dict[str, jax.ShapeDtypeStruct], mesh):
    out = {}
    for k, v in specs.items():
        pspec = resolve_spec(_BATCH_AXES[k][: len(v.shape)], v.shape, mesh)
        out[k] = jax.ShapeDtypeStruct(
            v.shape, v.dtype, sharding=NamedSharding(mesh, pspec)
        )
    return out


def _leaf_sharding(mesh, axes, shape):
    return NamedSharding(mesh, resolve_spec(_pad_spec(axes, len(shape)), shape, mesh))


def annotate_tree(struct_tree, specs_tree, mesh):
    """Attach storage NamedShardings to an eval_shape pytree.

    specs_tree: ParamSpec tree (prefix of struct_tree: CompressedVariable
    leaves sit under one ParamSpec).  Leaves without a spec (opt counters,
    rng, scalars) are replicated.
    """
    from repro.core.store import is_compressed

    def ann(leaf, axes):
        if not hasattr(leaf, "shape"):
            return leaf
        sh = (
            _leaf_sharding(mesh, axes, leaf.shape)
            if axes is not None
            else NamedSharding(mesh, P())
        )
        return jax.ShapeDtypeStruct(leaf.shape, leaf.dtype, sharding=sh)

    def f(spec, sub):
        axes = spec.storage if isinstance(spec, ParamSpec) else None
        if is_compressed(sub):
            return type(sub)(
                codes=ann(sub.codes, axes),
                s=ann(sub.s, None),
                b=ann(sub.b, None),
                fmt=sub.fmt,
            )
        return jax.tree_util.tree_map(lambda l: ann(l, axes), sub)

    if specs_tree is None:
        return jax.tree_util.tree_map(
            lambda l: ann(l, None), struct_tree, is_leaf=is_compressed
        )
    return jax.tree_util.tree_map(
        f, specs_tree, struct_tree,
        is_leaf=lambda s: isinstance(s, ParamSpec),
    )


def annotate_state(state_struct, specs, mesh):
    """Storage shardings for a TrainState eval_shape tree."""
    from repro.federated.state import TrainState

    return TrainState(
        params=annotate_tree(state_struct.params, specs, mesh),
        opt_state=annotate_tree(state_struct.opt_state, None, mesh),
        round=annotate_tree(state_struct.round, None, mesh),
        rng=annotate_tree(state_struct.rng, None, mesh),
    )


def population_sharding(mesh, ndim: int, leading: int = 0):
    """NamedSharding for stacked per-client state: shard axis 0 on "clients".

    ``leading`` is the size of axis 0 when known; if the mesh lacks a
    ``clients`` axis, or the axis size does not divide ``leading``, the
    array is replicated (correct, just not distributed) — single-device
    test topologies always take this fallback.
    """
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    n = sizes.get("clients", 1)
    if "clients" not in sizes or n <= 1 or (leading and leading % n != 0):
        return NamedSharding(mesh, P())
    return NamedSharding(mesh, P("clients", *(None,) * (ndim - 1)))


def annotate_population(tree, mesh):
    """device_put a stacked [num_clients, ...] pytree with client sharding."""
    return jax.tree_util.tree_map(
        lambda x: jax.device_put(
            x, population_sharding(mesh, jnp.ndim(x), jnp.shape(x)[0])
        ),
        tree,
    )


_KV = (None, "batch", "kv_seq", "tensor", None)  # [L, B, S, KVH, hd]
_KVPOS = (None, "batch", "kv_seq")


def decode_state_axes(family: str, cfg, struct):
    """Logical-axes tree matching each family's decode-state structure.

    Mirrors the models' own ``state_shard_hint`` layouts (attention cache:
    batch->data, cache-seq->model; recurrent state: batch->data,
    feature->dstate; scalars replicated).
    """
    from repro.models import attention as attn

    if family in ("transformer", "vlm", "moe"):
        return attn.KVCache(k=_KV, v=_KV, pos=_KVPOS, length=())
    if family == "encdec":
        return dict(
            self_kv=attn.KVCache(k=_KV, v=_KV, pos=_KVPOS, length=()),
            cross_k=_KV, cross_v=_KV, cross_pos=_KVPOS, length=(),
        )
    if family == "xlstm":
        m = dict(
            conv=(None, None, "batch", None, "dstate"),
            C=(None, None, "batch", None, "dstate", None),
            n=(None, None, "batch", None, None),
            m=(None, None, "batch", None),
        )
        axes = dict(
            mlstm=m,
            slstm=dict(c=(None, "batch", None, None), n=(None, "batch", None, None),
                       m=(None, "batch", None, None), h=(None, "batch", None, None)),
            length=(),
        )
        if "extra_m" in struct:
            axes["extra_m"] = {k: v[1:] for k, v in m.items()}
        return axes
    if family == "griffin":
        axes = dict(
            rec=dict(conv=(None, None, "batch", None, "dstate"),
                     h=(None, None, "batch", "dstate")),
            att=dict(k=_KV, v=_KV, pos=_KVPOS),
            length=(),
        )
        if "extra_rec" in struct:
            axes["extra_rec"] = dict(conv=(None, "batch", None, "dstate"),
                                     h=(None, "batch", "dstate"))
        return axes
    raise ValueError(f"no decode-state axes for family {family}")


def annotate_cache(cache_struct, family: str, cfg, mesh):
    """Attach storage NamedShardings to a decode-state eval_shape tree."""
    axes_tree = decode_state_axes(family, cfg, cache_struct)

    def ann(axes, leaf):
        if not hasattr(leaf, "shape"):
            return leaf
        sh = NamedSharding(mesh, resolve_spec(axes[: leaf.ndim], leaf.shape, mesh))
        return jax.ShapeDtypeStruct(leaf.shape, leaf.dtype, sharding=sh)

    return jax.tree_util.tree_map(
        ann, axes_tree, cache_struct,
        is_leaf=lambda x: isinstance(x, tuple) and all(
            a is None or isinstance(a, str) for a in x
        ),
    )
