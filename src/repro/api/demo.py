"""Loopback wire-format demo: download → train → upload → aggregate.

Runs the full client/server boundary in one process: the server
(``FLSession``) hands out compressed wire payloads, loopback clients
(``FLClient``) decode them, run local SGD on their synthetic shard, and
upload delta-encoded payloads; the server aggregates and re-compresses.
After the rounds a ``ServeSession`` hot-swaps the final model payload and
generates a few tokens over the compressed weights.

    PYTHONPATH=src python -m repro.api.demo --smoke

Prints a per-round payload-bytes report and checks it reconciles with
``tree_bytes_report`` (the compressed download must be <= 60% of the f32
baseline for S1E3M7 — the paper's ~59% reduction claim; DESIGN.md §7).
"""

from __future__ import annotations

import argparse
import json
import os

import jax
import jax.numpy as jnp

from repro.core.omc import OMCConfig
from repro.core.store import tree_bytes_report
from repro.data.synthetic import make_lm_task
from repro.federated.cohort import CohortPlan
from repro.federated.state import state_bytes_report
from repro.models import transformer as tr
from repro.models.common import IDENTITY_MAT

from repro.obs import Obs
from repro.obs.log import Logger

from .codecs import payload_bytes_report
from .session import FLClient, FLSession, ServeSession


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--smoke", action="store_true",
                    help="tiny model + 2 rounds (CI-sized)")
    ap.add_argument("--fmt", default="S1E3M7")
    ap.add_argument("--rounds", type=int, default=None)
    ap.add_argument("--clients", type=int, default=8)
    ap.add_argument("--cohort", type=int, default=4)
    ap.add_argument("--local-steps", type=int, default=2)
    ap.add_argument("--client-lr", type=float, default=0.05)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--quiet", action="store_true",
                    help="suppress stderr text (structured records still "
                         "flow to --obs)")
    ap.add_argument("--obs", action="store_true",
                    help="record telemetry (obs JSONL + Perfetto trace "
                         "under experiments/obs/)")
    args = ap.parse_args(argv)
    rounds = args.rounds or (2 if args.smoke else 8)
    obs = Obs(run_name="api_demo") if args.obs else None
    log = Logger(quiet=args.quiet, obs=obs)

    if args.smoke:
        cfg = tr.TransformerConfig(n_layers=2, d_model=64, n_heads=4,
                                   n_kv_heads=2, d_ff=128, vocab=256)
    else:
        cfg = tr.TransformerConfig(n_layers=4, d_model=128, n_heads=8,
                                   n_kv_heads=4, d_ff=256, vocab=512)
    omc = OMCConfig.parse(args.fmt)
    task = make_lm_task(vocab=cfg.vocab, seq_len=32, num_clients=args.clients)

    @jax.jit
    def local_sgd(params, batches):
        def step(p, batch):
            loss, g = jax.value_and_grad(
                lambda q: tr.loss(cfg, q, batch, IDENTITY_MAT)
            )(p)
            p = jax.tree_util.tree_map(
                lambda w, gg: w - args.client_lr * gg, p, g
            )
            return p, loss
        params, losses = jax.lax.scan(step, params, batches)
        return params, losses.mean()

    losses = {}

    def train_fn(params, client_id, round_index):
        batches = jax.tree_util.tree_map(
            lambda *xs: jnp.stack(xs),
            *[task.batch(client_id, round_index, s, args.batch)
              for s in range(args.local_steps)],
        )
        trained, loss = local_sgd(params, batches)
        losses[client_id] = float(loss)
        return trained

    plan = CohortPlan(num_clients=args.clients, cohort_size=args.cohort)
    server = FLSession(tr, cfg, omc, plan=plan, seed=args.seed, obs=obs)
    clients = {
        cid: FLClient(cid, tr, cfg, omc, train_fn)
        for cid in range(args.clients)
    }

    # reconcile the codec's byte accounting with the core reports: exact
    # against state_bytes_report (both count 8 B per PVT (s, b) entry), and
    # within the per-variable-vs-per-entry PVT overhead of tree_bytes_report
    wire = payload_bytes_report(server.storage)
    state_rep = state_bytes_report(server.storage)
    theory = tree_bytes_report(
        tr.init(jax.random.PRNGKey(args.seed), cfg), omc.fmt, omc.policy,
        fraction=1.0,
    )
    assert wire["wire_bytes"] == state_rep["packed_bytes"], (wire, state_rep)
    assert abs(wire["wire_bytes"] - theory["packed_bytes"]) <= (
        0.01 * theory["packed_bytes"]
    ), (wire, theory)
    log.info(f"model: {wire['num_params'] / 1e6:.2f} M params, "
             f"fmt {omc.fmt.name}",
             params_m=wire["num_params"] / 1e6, fmt=omc.fmt.name)
    log.info(f"wire body (codec):        {wire['wire_bytes']:>9d} B "
             f"({wire['wire_ratio']:.1%} of f32)",
             wire_bytes=wire["wire_bytes"], wire_ratio=wire["wire_ratio"])
    log.info(f"state_bytes_report packed: {state_rep['packed_bytes']:>8d} B "
             f"(exact)", packed_bytes=state_rep["packed_bytes"])
    log.info(f"tree_bytes_report packed:  {theory['packed_bytes']:>8d} B "
             f"({theory['packed_ratio']:.1%} of f32)",
             theory_bytes=theory["packed_bytes"])

    serve = None
    for r in range(rounds):
        if r == rounds - 1:
            # snapshot the pre-final-round model into a serving session; the
            # final round's delta payload will hot-swap against exactly it
            serve = ServeSession.from_payload(tr, cfg, server.server_payload())
        ticket = server.begin_round()
        up_bytes = []
        for cid in ticket.client_ids:
            upload = clients[cid].run_round(ticket)
            info = server.ingest(cid, upload)
            up_bytes.append(info.total_bytes)
        down_b = list(ticket.issued_bytes)
        n_delta = ticket.issued_delta
        m = server.close_round()
        fp32 = wire["fp32_bytes"]
        mean_loss = sum(losses[c] for c in ticket.client_ids) / len(ticket.client_ids)
        mean_down = sum(down_b) // len(down_b)
        log.info(f"round {m['round']}: loss={mean_loss:.4f} "
                 f"reports={m['reports']}/{m['invited']} "
                 f"down={mean_down}B/client ({mean_down / fp32:.1%} of f32, "
                 f"{n_delta}/{len(down_b)} delta) "
                 f"up={sum(up_bytes) // len(up_bytes)}B/client",
                 round=m["round"], loss=mean_loss, reports=m["reports"],
                 down_bytes=mean_down,
                 up_bytes=sum(up_bytes) // len(up_bytes))

    t = server.traffic
    down_ratio = t["down_bytes"] / max(t["down_fp32_bytes"], 1)
    up_ratio = t["up_bytes"] / max(t["up_fp32_bytes"], 1)
    log.result(f"totals: down {t['down_bytes']}B ({down_ratio:.1%} of f32), "
               f"up {t['up_bytes']}B ({up_ratio:.1%} of f32)",
               down_bytes=t["down_bytes"], up_bytes=t["up_bytes"],
               down_ratio=down_ratio, up_ratio=up_ratio)

    # serve over the wire: hot-swap the final round's delta payload into the
    # session snapshotted before that round, then generate on the new weights
    info = serve.hot_swap(server.server_payload(delta=True))
    cache = serve.init_cache(2, 64)
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 16), 0, cfg.vocab)
    _, gen = serve.generate(dict(tokens=toks), cache, 8)
    log.info(f"serve: hot-swapped round-{info.round_index} payload "
             f"({info.total_bytes}B, delta={info.is_delta}); generated "
             f"{gen.shape[1]} tokens/seq over compressed weights",
             swap_round=info.round_index, swap_bytes=info.total_bytes,
             generated=int(gen.shape[1]))

    ok = down_ratio <= 0.60
    enforced = omc.fmt.name == "S1E3M7"
    log.result(f"payload check: download {down_ratio:.1%} of f32 "
               f"({'<=' if ok else '>'} 60% target; "
               f"{'enforced for' if enforced else 'informational for'} "
               f"{omc.fmt.name})", ok=ok, enforced=enforced)
    if args.smoke:
        # CI artifact (benchmarks/README.md): the smoke run's traffic record
        out_dir = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                               "experiments", "bench")
        os.makedirs(out_dir, exist_ok=True)
        path = os.path.join(out_dir, "api_demo_smoke.json")
        with open(path, "w") as f:
            json.dump(dict(fmt=omc.fmt.name, rounds=rounds,
                           down_ratio=round(down_ratio, 4),
                           up_ratio=round(up_ratio, 4),
                           wire_bytes=wire["wire_bytes"],
                           fp32_bytes=wire["fp32_bytes"],
                           **{k: int(v) for k, v in t.items()}), f, indent=1)
        log.info(f"wrote {os.path.normpath(path)}", path=os.path.normpath(path))
    if obs is not None:
        paths = obs.flush()
        log.info(f"wrote {paths['jsonl']} and {paths['perfetto']}", **paths)
    if not ok and enforced:
        return 1
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
