"""FL and serving sessions over the wire codec (DESIGN.md §7).

``FLSession`` is the server side of the paper's training loop expressed at
the client/server boundary: the server state is *compressed at rest*
(``CompressedVariable`` leaves), each round it hands out a wire payload of
that state (full, or sparse-delta against the previous round for clients
that held it), ingests client uploads (themselves wire payloads, usually
delta-encoded against the download), aggregates with cohort-aware weighting
(:mod:`repro.federated.cohort` semantics — failures and stragglers drop
reports), and re-compresses.  No persistent f32 master exists between
rounds, matching :mod:`repro.federated.simulate` numerics.

``ServeSession`` is the inference side: batched prefill/decode over the
compressed weights via ``make_serve_fns``, with ``hot_swap`` ingesting a new
round's payload *without recompiling* — the storage pytree keeps its
treedef/shapes/dtypes, so the jitted functions are reused as-is.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core.omc import OMCConfig
from repro.core.store import decompress_tree
from repro.federated import cohort as cohort_lib
from repro.federated.async_engine import flush_weights
from repro.federated.round import make_serve_fns
from repro.federated.state import compress_params, state_bytes_report
from repro.obs import null_span

from . import codecs


def _resolve_strategy(strategy):
    """Accept a CompressionStrategy, a registry name, or None."""
    if strategy is None or not isinstance(strategy, str):
        return strategy
    from repro.compress import get_strategy

    return get_strategy(strategy)


def _reported_model(tree, base_storage, strategy):
    """Server-side view of one decoded upload (DESIGN.md §12).

    ``strategy=None``: the classic OMC path — dequantize the report.
    Upload-only strategies ship the client's *update*; reconstruct
    ``base + update`` so sparse frames (zeros off-support) never shrink
    the aggregated model.  Dense strategies ship the full model."""
    from repro.compress import decode_tree

    if strategy is None:
        return decompress_tree(tree)
    decoded = decode_tree(tree)
    if not strategy.upload_only:
        return decoded
    base_f32 = decompress_tree(base_storage)
    return jax.tree_util.tree_map(jnp.add, base_f32, decoded)


@dataclasses.dataclass
class RoundTicket:
    """What the server hands a transport for one round of downloads.

    ``profiles`` maps each invited client to its device-profile name
    (heterogeneous-tier cohorts, DESIGN.md §9): the download payload is the
    same server-format model for every tier, but the transport uses the
    profile to anticipate the client's upload format and the engine's wire
    accounting budgets per-tier bytes from it."""

    round_index: int
    client_ids: List[int]
    payload: bytes  # full payload (new / fallen-behind clients)
    delta_payload: Optional[bytes]  # vs the previous round's model, if any
    delta_base_digest: int = 0  # tree_digest the delta applies to (0: none)
    issued_bytes: List[int] = dataclasses.field(default_factory=list)
    issued_delta: int = 0  # how many clients actually took the delta
    profiles: Dict[int, str] = dataclasses.field(default_factory=dict)

    def payload_for(self, *, has_previous_round: bool) -> bytes:
        """Pick the download for one client and record its size (the
        session folds ``issued_bytes`` into traffic at close_round)."""
        if has_previous_round and self.delta_payload is not None:
            blob = self.delta_payload
            self.issued_delta += 1
        else:
            blob = self.payload
        self.issued_bytes.append(len(blob))
        return blob


@dataclasses.dataclass
class AsyncTicket:
    """A version-stamped download handed to one checking-in client.

    The async counterpart of :class:`RoundTicket` (DESIGN.md §10): instead
    of a per-round cohort broadcast, each ticket belongs to exactly one
    client and records the ``server_version`` whose state it carries — the
    upload that eventually comes back is decoded against *that* version's
    storage and its staleness is ``current_version - server_version``.
    ``delta_payload`` (vs the version the client said it holds) is taken
    only when the client's digest matches; the session folds the actually
    issued bytes into traffic at ingestion.
    """

    client_id: int
    server_version: int
    payload: bytes  # full state at server_version
    delta_payload: Optional[bytes] = None  # vs the client's held version
    delta_base_digest: int = 0
    issued_bytes: int = 0
    took_delta: bool = False

    def payload_for(self, *, held_digest: int = 0) -> bytes:
        """Pick delta when the client verifiably holds the base, else full."""
        if (self.delta_payload is not None
                and held_digest == self.delta_base_digest):
            blob = self.delta_payload
            self.took_delta = True
        else:
            blob = self.payload
        self.issued_bytes = len(blob)
        return blob


class FLSession:
    """Server-side federated session over compressed wire payloads.

    Lifecycle per round::

        ticket = sess.begin_round()            # cohort ids + download payload
        for cid in ticket.client_ids:          # transport delivers payloads,
            blob = client_train(...)           # clients train and upload
            sess.ingest(cid, blob)
        metrics = sess.close_round()           # aggregate + re-compress

    ``ingest`` accepts uploads delta-encoded against this round's download
    (the normal case) or full payloads; ``close_round`` FedAvg-aggregates
    whatever reports arrived (report-goal semantics: a partial cohort is
    fine) and applies the server update with learning rate ``server_lr``.

    ``strategy`` (a :class:`repro.compress.CompressionStrategy` or registry
    name) switches the *upload* direction to a zoo compressor (DESIGN.md
    §12): clients send strategy-encoded payloads — for upload-only
    strategies the payload carries the client's *update* and ``ingest``
    reconstructs ``download + update`` — while downloads stay the
    compressed-at-rest OMC state either way.
    """

    def __init__(
        self,
        family,
        cfg,
        omc: OMCConfig,
        *,
        plan: Optional[cohort_lib.CohortPlan] = None,
        server_lr: float = 1.0,
        seed: int = 0,
        init_params=None,
        profile_fn: Optional[Callable[[int], str]] = None,
        strategy=None,
        obs=None,
    ):
        self.family = family
        self.cfg = cfg
        self.omc = omc
        self.plan = plan
        self.strategy = _resolve_strategy(strategy)
        # telemetry (DESIGN.md §15): payload encode/decode + flush spans;
        # obs=None records nothing and changes nothing
        self.obs = obs
        # client id -> device-profile name (engine.PROFILES keys); stamped
        # onto every RoundTicket so transports know each client's tier
        self.profile_fn = profile_fn
        self.server_lr = float(server_lr)
        self.specs = family.param_specs(cfg)
        key = jax.random.PRNGKey(seed)
        params = family.init(key, cfg) if init_params is None else init_params
        self.storage = (
            compress_params(params, self.specs, omc) if omc.enabled else params
        )
        self._prev_storage = None  # round r-1 model: delta base for downloads
        self._cohort_key = jax.random.fold_in(key, 0xC047)
        self.round_index = 0
        self._reports: Dict[int, Any] = {}
        self._ticket: Optional[RoundTicket] = None
        # f32 baseline depends only on leaf shapes — constant for the session
        self._fp32_bytes = state_bytes_report(self.storage)["fp32_bytes"]
        self.traffic = dict(down_bytes=0, up_bytes=0, down_fp32_bytes=0,
                            up_fp32_bytes=0)

    # -- payload side -------------------------------------------------------

    def server_payload(self, *, delta: bool = False) -> bytes:
        """Wire payload of the current server model (optionally vs round-1)."""
        base = self._prev_storage if delta else None
        with null_span(self.obs, "encode_payload", delta=delta) as a:
            blob = codecs.encode_payload(
                self.storage, base=base, round_index=self.round_index
            )
            a["bytes"] = len(blob)
        return blob

    def begin_round(self) -> RoundTicket:
        """Sample the round's cohort and build its download payload(s)."""
        if self._ticket is not None:
            raise RuntimeError("round already open; call close_round() first")
        if self.plan is not None:
            ids = [
                int(i)
                for i in cohort_lib.sample_cohort(
                    self._cohort_key, self.plan, self.round_index
                )
            ]
        else:
            ids = [0]
        full = self.server_payload()
        delta = (
            self.server_payload(delta=True) if self._prev_storage is not None
            else None
        )
        self._ticket = RoundTicket(
            self.round_index, ids, full, delta,
            delta_base_digest=(
                codecs.header_base_digest(delta) if delta is not None else 0
            ),
            profiles=(
                {cid: self.profile_fn(cid) for cid in ids}
                if self.profile_fn is not None else {}
            ),
        )
        self._reports = {}
        return self._ticket

    def ingest(self, client_id: int, blob: bytes) -> codecs.PayloadInfo:
        """Accept one client upload (delta vs this round's download, or full)."""
        if self._ticket is None:
            raise RuntimeError("no open round; call begin_round() first")
        if client_id not in self._ticket.client_ids:
            raise KeyError(f"client {client_id} is not in this round's cohort")
        with null_span(self.obs, "decode_payload", client=client_id,
                       bytes=len(blob)):
            tree, info = codecs.decode_payload(blob, base=self.storage)
        self._reports[client_id] = _reported_model(
            tree, self.storage, self.strategy
        )
        self.traffic["up_bytes"] += info.total_bytes
        self.traffic["up_fp32_bytes"] += self._fp32_bytes
        return info

    def close_round(self) -> Dict[str, Any]:
        """Aggregate the received reports, apply the server step, re-compress."""
        if self._ticket is None:
            raise RuntimeError("no open round; call begin_round() first")
        if not self._reports:
            raise RuntimeError("round closed with zero reports")
        models = list(self._reports.values())
        weights = jnp.ones((len(models),), jnp.float32)
        stacked = jax.tree_util.tree_map(lambda *xs: jnp.stack(xs), *models)
        mean_model = cohort_lib.aggregate_weighted(stacked, weights)
        server_f32 = decompress_tree(self.storage)
        new_f32 = jax.tree_util.tree_map(
            lambda old, new: old + self.server_lr * (new - old),
            server_f32,
            mean_model,
        )
        self._prev_storage = self.storage
        self.storage = (
            compress_params(new_f32, self.specs, self.omc)
            if self.omc.enabled
            else new_f32
        )
        self.traffic["down_bytes"] += sum(self._ticket.issued_bytes)
        self.traffic["down_fp32_bytes"] += (
            self._fp32_bytes * len(self._ticket.issued_bytes)
        )
        metrics = dict(
            round=self.round_index,
            reports=len(models),
            invited=len(self._ticket.client_ids),
            **{k: int(v) for k, v in self.traffic.items()},
        )
        self.round_index += 1
        self._ticket = None
        self._reports = {}
        return metrics

    # -- async (buffered, version-stamped) side -----------------------------

    def enable_async(self, buffer_goal: int, *, decay: float = 0.0,
                     decay_mode: str = "poly",
                     delta_horizon: int = 4) -> None:
        """Switch the session to the non-barrier protocol (DESIGN.md §10).

        ``buffer_goal`` (K) — aggregate whenever K uploads accumulate —
        passes the same validation gate as the sync report goal.  After
        this, drive the session with :meth:`checkin` / :meth:`ingest_async`
        instead of the begin/ingest/close round cycle; the server applies a
        staleness-weighted FedBuff step at each flush and bumps
        ``server_version``.  ``delta_horizon`` bounds how many past version
        storages are kept as delta bases for returning clients (versions a
        pending ticket still references are always kept — uploads decode
        against their ticket's exact base).
        """
        cohort_lib.validate_report_goal(
            buffer_goal,
            self.plan.cohort_size if self.plan is not None else buffer_goal,
            what="buffer_goal",
        )
        if self._ticket is not None:
            raise RuntimeError("close the open sync round before enable_async")
        self.async_cfg = dict(buffer_goal=int(buffer_goal), decay=float(decay),
                              decay_mode=decay_mode,
                              delta_horizon=int(delta_horizon))
        self.server_version = 0
        self._full_cache: Optional[Tuple[int, bytes]] = None
        self._version_storages: Dict[int, Any] = {0: self.storage}
        self._async_pending: Dict[int, AsyncTicket] = {}
        self._async_buffer: List[Tuple[int, int, Any]] = []  # (cid, base, f32)
        self.async_history: List[Dict[str, Any]] = []

    def checkin(self, client_id: int,
                held_version: Optional[int] = None) -> AsyncTicket:
        """Issue one client a version-stamped download ticket.

        The full payload always carries the *current* state; if the client
        reports a ``held_version`` still in the delta window, a sparse
        delta against that version's storage rides along (digest-verified
        at the client, exactly like sync :class:`RoundTicket` routing).
        """
        if not hasattr(self, "async_cfg"):
            raise RuntimeError("call enable_async() first")
        if client_id in self._async_pending:
            raise RuntimeError(f"client {client_id} already has an open ticket")
        # the full payload is identical for every check-in under one server
        # version — encode it once per version, not once per client
        if self._full_cache is None or self._full_cache[0] != self.server_version:
            self._full_cache = (self.server_version, codecs.encode_payload(
                self.storage, round_index=self.server_version))
        full = self._full_cache[1]
        delta = None
        digest = 0
        base = (self._version_storages.get(held_version)
                if held_version is not None else None)
        if base is not None:
            delta = codecs.encode_payload(self.storage, base=base,
                                          round_index=self.server_version)
            digest = codecs.header_base_digest(delta)
        ticket = AsyncTicket(client_id, self.server_version, full, delta,
                             delta_base_digest=digest)
        self._async_pending[client_id] = ticket
        return ticket

    def ingest_async(self, client_id: int, blob: bytes) -> codecs.PayloadInfo:
        """Accept one upload against its ticket's base version; flush at K.

        The upload is decoded against the storage *at the ticket's version*
        (kept alive until the upload lands), so a stale client's delta
        still decodes exactly; its staleness is charged at aggregation
        time through the session's decay weights.
        """
        ticket = self._async_pending.pop(client_id, None)
        if ticket is None:
            raise KeyError(f"client {client_id} has no open ticket")
        base = self._version_storages[ticket.server_version]
        with null_span(self.obs, "decode_payload", client=client_id,
                       bytes=len(blob)):
            tree, info = codecs.decode_payload(blob, base=base)
        self._async_buffer.append(
            (client_id, ticket.server_version,
             _reported_model(tree, base, self.strategy))
        )
        self.traffic["up_bytes"] += info.total_bytes
        self.traffic["up_fp32_bytes"] += self._fp32_bytes
        self.traffic["down_bytes"] += ticket.issued_bytes
        self.traffic["down_fp32_bytes"] += self._fp32_bytes
        if len(self._async_buffer) >= self.async_cfg["buffer_goal"]:
            self._flush_async()
        return info

    def _flush_async(self) -> None:
        with null_span(self.obs, "flush",
                       version=getattr(self, "server_version", 0)):
            self._flush_async_inner()

    def _flush_async_inner(self) -> None:
        entries = self._async_buffer[: self.async_cfg["buffer_goal"]]
        self._async_buffer = self._async_buffer[self.async_cfg["buffer_goal"]:]
        staleness = jnp.asarray(
            [self.server_version - base for _, base, _ in entries],
            jnp.float32,
        )
        w = flush_weights(staleness, self.async_cfg["decay"],
                          self.async_cfg["decay_mode"])
        stacked = jax.tree_util.tree_map(
            lambda *xs: jnp.stack(xs), *[m for _, _, m in entries]
        )
        mean_model = cohort_lib.aggregate_weighted(stacked, w)
        server_f32 = decompress_tree(self.storage)
        new_f32 = jax.tree_util.tree_map(
            lambda old, new: old + self.server_lr * (new - old),
            server_f32, mean_model,
        )
        self.storage = (
            compress_params(new_f32, self.specs, self.omc)
            if self.omc.enabled else new_f32
        )
        self.server_version += 1
        self._version_storages[self.server_version] = self.storage
        self._gc_version_storages()
        self.async_history.append(dict(
            version=self.server_version,
            buffer=len(entries),
            staleness_max=int(staleness.max()),
            **{k: int(v) for k, v in self.traffic.items()},
        ))

    def _gc_version_storages(self) -> None:
        keep = {t.server_version for t in self._async_pending.values()}
        keep.add(self.server_version)
        horizon = self.server_version - self.async_cfg["delta_horizon"]
        for v in [v for v in self._version_storages
                  if v not in keep and v < horizon]:
            del self._version_storages[v]


class FLClient:
    """Loopback client: decode download, train, upload a delta payload.

    ``train_fn(params_f32, client_id, round_index) -> params_f32`` is the
    local optimization (the demo uses a few SGD steps on the client's
    synthetic shard).  The client caches the last model it decoded and takes
    the delta download only when the delta's base digest matches that cache
    (a cohort-skipped client holds a stale model and falls back to the full
    payload — never a silent wrong-base decode).  The upload is
    re-compressed under the session policy (transport compression, paper §2)
    and delta-encoded against the *received* model, so unchanged codes cost
    ~0 wire bytes.

    With a ``strategy`` (matching the session's — DESIGN.md §12) the upload
    is strategy-encoded instead: dense strategies send the full trained
    model, upload-only strategies send the *update* ``trained - received``
    — with a host-side error-feedback residual carried across this
    client's rounds when the strategy opts in (the residual is exactly
    ``compensated - decode(encode(compensated))``, so the client and the
    server can never disagree about what was dropped).
    """

    def __init__(self, client_id: int, family, cfg, omc: OMCConfig,
                 train_fn: Callable[[Any, int, int], Any], strategy=None):
        self.client_id = client_id
        self.specs = family.param_specs(cfg)
        self.omc = omc
        self.train_fn = train_fn
        self.strategy = _resolve_strategy(strategy)
        self._cache = None  # last decoded download tree (this client's model)
        self._cache_digest = 0
        self._residual = None  # error-feedback accumulator (EF strategies)

    def run_round(self, ticket: RoundTicket) -> bytes:
        use_delta = (
            ticket.delta_payload is not None
            and self._cache is not None
            and ticket.delta_base_digest == self._cache_digest
        )
        blob = ticket.payload_for(has_previous_round=use_delta)
        tree, _ = codecs.decode_payload(
            blob, base=self._cache if use_delta else None
        )
        self._cache = tree
        self._cache_digest = codecs.tree_digest(tree)
        params = decompress_tree(tree)
        trained = self.train_fn(params, self.client_id, ticket.round_index)
        if self.strategy is not None:
            return self._strategy_upload(params, trained, ticket.round_index)
        upload_tree = (
            compress_params(trained, self.specs, self.omc)
            if self.omc.enabled
            else trained
        )
        return codecs.encode_payload(
            upload_tree, base=tree, round_index=ticket.round_index
        )

    def _strategy_upload(self, received, trained, round_index: int) -> bytes:
        from repro.compress import decode_tree, encode_tree

        tmap = jax.tree_util.tree_map
        if not self.strategy.upload_only:
            upload_tree = encode_tree(self.strategy, trained, self.omc,
                                      self.specs)
            return codecs.encode_payload(upload_tree,
                                         round_index=round_index)
        comp = tmap(jnp.subtract, trained, received)
        if self.strategy.error_feedback:
            if self._residual is None:
                self._residual = tmap(jnp.zeros_like, comp)
            comp = tmap(jnp.add, comp, self._residual)
        upload_tree = encode_tree(self.strategy, comp, self.omc, self.specs)
        if self.strategy.error_feedback:
            self._residual = tmap(jnp.subtract, comp,
                                  decode_tree(upload_tree))
        return codecs.encode_payload(upload_tree, round_index=round_index)


class ServeSession:
    """Batched decode over compressed weights with payload hot-swap.

    Wraps ``make_serve_fns``: prefill/decode are jitted once; ``hot_swap``
    replaces the storage tree from a wire payload between rounds without
    touching the compiled functions (same treedef/shapes/dtypes).
    """

    def __init__(self, family, cfg, storage, compute_dtype=jnp.float32,
                 obs=None):
        self.family = family
        self.cfg = cfg
        self.storage = storage
        self.obs = obs
        prefill_fn, decode_fn = make_serve_fns(family, cfg, compute_dtype)
        self._prefill = jax.jit(prefill_fn)
        self._decode = jax.jit(decode_fn)
        self.swaps = 0
        self.queries = 0
        # per-swap wall ms (decode payload + materialize the new storage) —
        # the serve-under-swap driver (repro.scale.serve_driver) reads these
        self.swap_ms: List[float] = []

    @classmethod
    def from_payload(cls, family, cfg, payload: bytes, **kw) -> "ServeSession":
        storage, _ = codecs.decode_payload(payload)
        return cls(family, cfg, storage, **kw)

    def hot_swap(self, payload: bytes) -> codecs.PayloadInfo:
        """Ingest a new round's model; delta payloads apply against the
        currently-served tree (digest-verified — a wrong-round payload
        raises rather than corrupting the served weights).  Swap wall time
        (decode + materialized new storage) lands in ``swap_ms``."""
        import time

        t0 = time.perf_counter()
        with null_span(self.obs, "hot_swap", swap=int(self.swaps),
                       bytes=len(payload)):
            self.storage, info = codecs.decode_payload(
                payload, base=self.storage
            )
            jax.block_until_ready(
                [l for l in jax.tree_util.tree_leaves(self.storage)
                 if hasattr(l, "block_until_ready")]
            )
        self.swaps += 1
        self.swap_ms.append((time.perf_counter() - t0) * 1e3)
        return info

    def init_cache(self, batch: int, max_len: int, dtype=jnp.float32):
        return self.family.init_decode_state(self.cfg, batch, max_len,
                                             dtype=dtype)

    def prefill(self, batch, cache):
        return self._prefill(self.storage, batch, cache)

    def decode_step(self, cache, tokens):
        return self._decode(self.storage, cache, tokens)

    def generate(self, batch, cache, steps: int, *,
                 sample: Callable[[jax.Array], jax.Array] = None):
        """Greedy (or ``sample``-driven) generation; returns (cache, tokens)."""
        if steps < 1:
            raise ValueError(f"steps must be >= 1, got {steps}")
        pick = sample or (lambda logits: jnp.argmax(logits, axis=-1))
        cache, logits = self.prefill(batch, cache)
        tok = pick(logits[:, -1])[:, None]
        out = [tok]
        for _ in range(steps - 1):
            cache, logits = self.decode_step(cache, tok)
            tok = pick(logits[:, -1])[:, None]
            out.append(tok)
        self.queries += 1
        return cache, jnp.concatenate(out, axis=1)

    def serve_stats(self) -> Dict[str, Any]:
        """Swap/query telemetry for serve-under-swap reporting."""
        return dict(
            swaps=int(self.swaps),
            queries=int(self.queries),
            swap_ms_mean=(float(jnp.mean(jnp.asarray(self.swap_ms)))
                          if self.swap_ms else 0.0),
            swap_ms_max=(float(max(self.swap_ms)) if self.swap_ms else 0.0),
        )
