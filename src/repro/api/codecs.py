"""Versioned binary wire codec for compressed parameter trees (DESIGN.md §7).

A *payload* is the serialized form of a storage pytree (the thing
``compress_tree`` / ``compress_params`` produce): ``CompressedVariable``
leaves travel as their exact-width packed bitstream (11 bits/param for
S1E3M7 — the paper's communication saving), everything else travels raw.
The codec is host-side (numpy) and bit-exact: ``decode(encode(t)) == t``
code-for-code, so wire transport composes with the storage-mode numerics
without introducing a second rounding step.

Frame layout (little-endian, version 1)::

    magic     4s   b"OMCW"
    version   u16
    flags     u16  bit 0: payload is a delta against a base tree
    round     u32  producer round index (informational)
    mlen      u32  manifest length in bytes
    blen      u64  body length in bytes
    crc       u32  zlib.crc32(manifest + body)
    digest    u32  tree_digest of the delta base (0 for full payloads);
                   decode verifies the receiver's base tree against it, so
                   applying a delta to the wrong round's model fails loudly
    manifest  mlen bytes of JSON (tagged leaf paths — dict/list/tuple
              containers are preserved — kinds, shapes, modes)
    body      blen bytes (per-leaf sections in manifest order)

Per-leaf body sections:

  * ``omc``/``full``:  s (f32), b (f32), packed codes (u32 words).
  * ``omc``/``delta``: s, b, sorted u32 indices of changed codes, packed
    XOR-of-codes for those indices.  The XOR is against the *base* tree's
    codes (round r-1 for a repeat download); after a small server step most
    codes are unchanged, so the sparse form shrinks repeat downloads.
  * ``raw``/``full``:  the array bytes.
  * ``raw``/``delta``: sorted u32 indices + u32 XOR words over the array's
    32-bit bitview (4-byte dtypes only).

The encoder picks ``delta`` per leaf only when it is actually smaller than
``full`` (a dense update degenerates to full — no silent size regression),
so ``encode_payload(tree, base=prev)`` is never worse than
``encode_payload(tree)`` by more than the per-leaf mode flag.

Strategy leaves (DESIGN.md §11): the ``omc`` and ``raw`` kinds above are
built in; the compression-strategy zoo (:mod:`repro.compress`) registers
additional leaf kinds (``topk``, ``ternary``, ``pipeline``) through
:func:`register_leaf_codec`, and payloads carrying them are stamped with a
*strategy tag* + per-strategy wire version in the manifest.  ``decode``
verifies the tag against the registered zoo — an unknown strategy or a
version mismatch is a loud :class:`CodecError`, never silent corruption.
The sparse XOR-delta above is the OMC strategy's delta rule; registered
kinds travel full-only unless their codec implements its own delta.

Byte accounting: for a full payload the body is exactly
``packed_bytes(n, fmt) + 8·s.size`` per compressed leaf plus ``itemsize·n``
per raw leaf — the same accounting ``tree_bytes_report`` /
``state_bytes_report`` call ``packed_bytes`` — so wire measurements and the
paper-table byte columns reconcile by construction
(:func:`payload_bytes_report` computes it without serializing).
"""

from __future__ import annotations

import dataclasses
import json
import struct
import zlib
from typing import Any, Dict, List, Optional, Sequence, Tuple

import jax.numpy as jnp
import numpy as np

from repro.core import packing
from repro.core.formats import FloatFormat
from repro.core.store import CompressedVariable, is_compressed

MAGIC = b"OMCW"
WIRE_VERSION = 1
SUPPORTED_VERSIONS = (1,)

FLAG_DELTA = 1 << 0

# magic, version, flags, round, manifest len, body len, crc, base digest
_HEADER = struct.Struct("<4sHHIIQII")
_PVT_BYTES_PER_ENTRY = 8  # s and b, f32 each


class CodecError(ValueError):
    """Malformed, corrupt, or version-incompatible payload."""


# ---------------------------------------------------------------------------
# strategy leaf-codec registry (DESIGN.md §11).  repro.compress registers the
# zoo's kinds at import; decode lazily imports it on first contact with a
# strategy payload so a fresh process can always decode.
# ---------------------------------------------------------------------------

_LEAF_CODECS: Dict[str, Tuple[type, Any, Any]] = {}


def register_leaf_codec(kind: str, leaf_type: type, encode_fn, decode_fn) -> None:
    """Register a strategy leaf kind: ``encode_fn(leaf, base) -> (meta,
    [chunks])`` and ``decode_fn(meta, body, off, base) -> (leaf, off)``.
    The body section MUST measure exactly ``leaf.wire_body_bytes()`` bytes
    so every ledger reconciles (§11 byte-accounting obligation)."""
    if kind in ("omc", "raw"):
        raise ValueError(f"leaf kind {kind!r} is built in")
    prev = _LEAF_CODECS.get(kind)
    if prev is not None and prev[0] is not leaf_type:
        raise ValueError(f"leaf kind {kind!r} already registered")
    _LEAF_CODECS[kind] = (leaf_type, encode_fn, decode_fn)


def _ensure_strategy_codecs() -> None:
    """Import the zoo (idempotent) so its leaf codecs are registered."""
    import repro.compress  # noqa: F401  (registration happens at import)


def _leaf_kind(leaf) -> Optional[str]:
    for kind, (leaf_type, _, _) in _LEAF_CODECS.items():
        if isinstance(leaf, leaf_type):
            return kind
    return None


def _check_strategy_tag(manifest: Dict[str, Any]) -> None:
    """Reject unknown strategy tags / wire-version mismatches (CodecError)."""
    name = manifest.get("strategy")
    if name is None:
        return
    _ensure_strategy_codecs()
    from repro.compress import available_strategies, strategy_class

    try:
        cls = strategy_class(name)
    except KeyError:
        raise CodecError(
            f"unknown compression strategy tag {name!r}; "
            f"registered zoo: {available_strategies()}"
        ) from None
    sver = int(manifest.get("strategy_version", 0))
    if sver != cls.wire_version:
        raise CodecError(
            f"strategy {name!r} wire version mismatch: payload carries "
            f"v{sver}, this zoo speaks v{cls.wire_version}"
        )


@dataclasses.dataclass(frozen=True)
class PayloadInfo:
    """Parsed frame metadata (available without decoding the body)."""

    version: int
    flags: int
    round_index: int
    header_bytes: int  # fixed header + manifest
    body_bytes: int
    total_bytes: int
    num_leaves: int
    num_compressed: int
    num_delta: int
    base_digest: int  # tree_digest of the delta base; 0 for full payloads
    strategy: Optional[str] = None  # zoo strategy tag (None: plain OMC frame)
    strategy_version: int = 0  # per-strategy wire version (0: untagged)

    @property
    def is_delta(self) -> bool:
        return bool(self.flags & FLAG_DELTA)


def negotiate_version(peer_versions: Sequence[int]) -> int:
    """Highest wire version both ends speak (server calls this per client)."""
    common = set(SUPPORTED_VERSIONS) & set(int(v) for v in peer_versions)
    if not common:
        raise CodecError(
            f"no common wire version: we speak {SUPPORTED_VERSIONS}, "
            f"peer speaks {tuple(peer_versions)}"
        )
    return max(common)


# ---------------------------------------------------------------------------
# pytree <-> flat (path, leaf) list.  Wire trees are nested dict/list/tuple
# containers (what every model family's init() produces).  Container types
# are recorded in the path tags ('k' dict key, 'i' list index, 't' tuple
# index) so decode rebuilds the exact treedef — tuples stay tuples.
# ---------------------------------------------------------------------------


def _flatten(tree) -> List[Tuple[List[Any], Any]]:
    out: List[Tuple[List[Any], Any]] = []

    def walk(node, prefix):
        if is_compressed(node):
            out.append((prefix, node))
        elif isinstance(node, dict):
            if not node:
                raise CodecError("empty dict container is not serializable")
            for k in sorted(node):  # jax tree order: sorted dict keys
                if not isinstance(k, str):
                    raise CodecError(f"non-string dict key {k!r} in wire tree")
                walk(node[k], prefix + [["k", k]])
        elif isinstance(node, (list, tuple)):
            if not node:
                raise CodecError("empty sequence container is not serializable")
            tag = "i" if isinstance(node, list) else "t"
            for j, v in enumerate(node):
                walk(v, prefix + [[tag, j]])
        else:
            out.append((prefix, node))

    walk(tree, [])
    return out


class _Node:
    __slots__ = ("tag", "kids")

    def __init__(self, tag):
        self.tag = tag
        self.kids: Dict[Any, Any] = {}


def _unflatten(entries: List[Tuple[List[Any], Any]]):
    """Rebuild nested dicts/lists/tuples from tagged paths."""
    if not entries:
        return {}
    if not entries[0][0]:
        if len(entries) != 1:
            raise CodecError("multiple leaves with an empty path")
        return entries[0][1]
    root = _Node(entries[0][0][0][0])
    for parts, leaf in entries:
        node = root
        for depth, (tag, key) in enumerate(parts):
            if node.tag != tag:
                raise CodecError("inconsistent container tags in manifest")
            if depth == len(parts) - 1:
                node.kids[key] = leaf
            else:
                child = node.kids.get(key)
                if not isinstance(child, _Node):
                    child = _Node(parts[depth + 1][0])
                    node.kids[key] = child
                node = child

    def materialize(n):
        if not isinstance(n, _Node):
            return n
        if n.tag == "k":
            return {k: materialize(v) for k, v in n.kids.items()}
        try:
            seq = [materialize(n.kids[i]) for i in range(len(n.kids))]
        except KeyError as e:
            raise CodecError(f"missing sequence index in manifest: {e}") from e
        return seq if n.tag == "i" else tuple(seq)

    return materialize(root)


def _path_key(parts: List[Any]) -> str:
    return "/".join(str(v) for _, v in parts)


def tree_digest(tree) -> int:
    """crc32 fingerprint of a storage tree (paths + codes + PVT scalars).

    Delta payloads embed the digest of the base they were encoded against;
    decode verifies the receiver's base matches, so applying a delta to the
    wrong round's model is a loud `CodecError`, not silent corruption.
    """
    h = 0
    for parts, leaf in _flatten(tree):
        h = zlib.crc32(_path_key(parts).encode(), h)
        kind = _leaf_kind(leaf)
        if kind is not None:
            # strategy leaves: hash the canonical wire chunks (deterministic)
            meta, chunks = _LEAF_CODECS[kind][1](leaf, None)
            h = zlib.crc32(json.dumps(meta, separators=(",", ":"),
                                      sort_keys=True).encode(), h)
            for c in chunks:
                h = zlib.crc32(c, h)
        elif is_compressed(leaf):
            h = zlib.crc32(np.ascontiguousarray(np.asarray(leaf.codes)).tobytes(), h)
            h = zlib.crc32(
                np.ascontiguousarray(np.asarray(leaf.s, np.float32)).tobytes(), h
            )
            h = zlib.crc32(
                np.ascontiguousarray(np.asarray(leaf.b, np.float32)).tobytes(), h
            )
            h = zlib.crc32(leaf.fmt.name.encode(), h)
        else:
            h = zlib.crc32(np.ascontiguousarray(np.asarray(leaf)).tobytes(), h)
    return h


# ---------------------------------------------------------------------------
# per-leaf encoding
# ---------------------------------------------------------------------------


def _codes_np(cv: CompressedVariable) -> np.ndarray:
    return np.asarray(cv.codes).reshape(-1)


def _pack_np(codes_flat: np.ndarray, bits: int) -> np.ndarray:
    return np.asarray(packing.pack(codes_flat, bits), np.uint32)


def _encode_omc(cv: CompressedVariable, base) -> Tuple[Dict[str, Any], List[bytes]]:
    fmt = cv.fmt
    s = np.ascontiguousarray(np.asarray(cv.s, np.float32))
    b = np.ascontiguousarray(np.asarray(cv.b, np.float32))
    codes = _codes_np(cv)
    meta = dict(
        kind="omc",
        fmt=fmt.name,
        shape=list(cv.codes.shape),
        # np.ascontiguousarray promotes 0-d to 1-d — record the true shape
        # so scalar (per-tensor) PVT params survive the roundtrip and a
        # hot-swapped tree keeps the exact jit-cache signature
        sb_shape=list(np.shape(cv.s)),
        mode="full",
    )
    full_words = _pack_np(codes, fmt.bits)
    chunks = [s.tobytes(), b.tobytes()]
    if (
        base is not None
        and is_compressed(base)
        and base.fmt == fmt
        and tuple(base.codes.shape) == tuple(cv.codes.shape)
    ):
        xor = codes.astype(np.uint32) ^ _codes_np(base).astype(np.uint32)
        (idx,) = np.nonzero(xor)
        delta_bytes = 4 * idx.size + 4 * packing.packed_words(max(idx.size, 1), fmt.bits)
        if idx.size and delta_bytes < 4 * full_words.size:
            meta["mode"] = "delta"
            meta["nnz"] = int(idx.size)
            chunks.append(np.ascontiguousarray(idx.astype(np.uint32)).tobytes())
            chunks.append(_pack_np(xor[idx], fmt.bits).tobytes())
            return meta, chunks
        if idx.size == 0:
            meta["mode"] = "delta"
            meta["nnz"] = 0
            return meta, chunks
    chunks.append(full_words.tobytes())
    return meta, chunks


def _encode_raw(leaf, base) -> Tuple[Dict[str, Any], List[bytes]]:
    arr = np.ascontiguousarray(np.asarray(leaf))
    meta = dict(
        kind="raw",
        dtype=arr.dtype.str,
        shape=list(arr.shape),
        mode="full",
    )
    if (
        base is not None
        and not is_compressed(base)
        and hasattr(base, "dtype")
        and np.asarray(base).dtype == arr.dtype
        and np.asarray(base).shape == arr.shape
        and arr.dtype.itemsize == 4
    ):
        xor = arr.view(np.uint32).reshape(-1) ^ np.ascontiguousarray(
            np.asarray(base)
        ).view(np.uint32).reshape(-1)
        (idx,) = np.nonzero(xor)
        if 8 * idx.size < arr.nbytes:
            meta["mode"] = "delta"
            meta["nnz"] = int(idx.size)
            return meta, [
                np.ascontiguousarray(idx.astype(np.uint32)).tobytes(),
                np.ascontiguousarray(xor[idx]).tobytes(),
            ]
    return meta, [arr.tobytes()]


def _decode_omc(meta: Dict[str, Any], body: memoryview, off: int, base):
    fmt = FloatFormat.parse(meta["fmt"])
    shape = tuple(meta["shape"])
    sb_shape = tuple(meta.get("sb_shape", ()))
    n = int(np.prod(shape)) if shape else 1
    n_sb = int(np.prod(sb_shape)) if sb_shape else 1
    s = np.frombuffer(body, np.float32, n_sb, off).reshape(sb_shape)
    off += 4 * n_sb
    b = np.frombuffer(body, np.float32, n_sb, off).reshape(sb_shape)
    off += 4 * n_sb
    if meta["mode"] == "delta":
        if base is None or not is_compressed(base):
            raise CodecError(
                "delta leaf but no compressed base variable was supplied"
            )
        if base.fmt != fmt or tuple(base.codes.shape) != shape:
            raise CodecError("delta base mismatch (format or shape)")
        codes = _codes_np(base).astype(np.uint32).copy()
        nnz = int(meta["nnz"])
        if nnz:
            idx = np.frombuffer(body, np.uint32, nnz, off)
            off += 4 * nnz
            nwords = packing.packed_words(nnz, fmt.bits)
            words = np.frombuffer(body, np.uint32, nwords, off)
            off += 4 * nwords
            xor = np.asarray(packing.unpack(words, fmt.bits, nnz), np.uint32)
            codes[idx] ^= xor
    else:
        nwords = packing.packed_words(n, fmt.bits)
        words = np.frombuffer(body, np.uint32, nwords, off)
        off += 4 * nwords
        codes = np.asarray(packing.unpack(words, fmt.bits, n), np.uint32)
    cv = CompressedVariable(
        jnp.asarray(codes.reshape(shape).astype(np.dtype(fmt.container_dtype))),
        jnp.asarray(s.reshape(sb_shape), jnp.float32),
        jnp.asarray(b.reshape(sb_shape), jnp.float32),
        fmt,
    )
    return cv, off


def _decode_raw(meta: Dict[str, Any], body: memoryview, off: int, base):
    dtype = np.dtype(meta["dtype"])
    shape = tuple(meta["shape"])
    n = int(np.prod(shape)) if shape else 1
    if meta["mode"] == "delta":
        if base is None or is_compressed(base):
            raise CodecError("delta leaf but no matching raw base was supplied")
        barr = np.ascontiguousarray(np.asarray(base))
        if barr.dtype != dtype or barr.shape != shape:
            raise CodecError("delta base mismatch (dtype or shape)")
        bits = barr.view(np.uint32).reshape(-1).copy()
        nnz = int(meta["nnz"])
        if nnz:
            idx = np.frombuffer(body, np.uint32, nnz, off)
            off += 4 * nnz
            xor = np.frombuffer(body, np.uint32, nnz, off)
            off += 4 * nnz
            bits[idx] ^= xor
        arr = bits.view(dtype).reshape(shape)
    else:
        arr = np.frombuffer(body, dtype, n, off).reshape(shape)
        off += dtype.itemsize * n
    return jnp.asarray(arr), off


# ---------------------------------------------------------------------------
# public API
# ---------------------------------------------------------------------------


def encode_payload(tree, *, base=None, round_index: int = 0,
                   strategy=None) -> bytes:
    """Serialize a storage pytree to a wire payload.

    ``base`` (the tree the receiver already holds, e.g. the previous round's
    model) switches each leaf to sparse XOR-delta encoding when that is
    smaller; the receiver must then pass the same base to
    :func:`decode_payload`.

    ``strategy`` (a :class:`repro.compress.CompressionStrategy` instance or
    registered name) stamps the frame with the strategy tag + its wire
    version; payloads containing registered strategy leaves are stamped
    automatically.  Untagged frames (the plain OMC path) stay
    byte-identical to wire version 1 payloads.
    """
    base_leaves: Dict[str, Any] = {}
    if base is not None:
        base_leaves = {_path_key(p): leaf for p, leaf in _flatten(base)}

    manifest: List[Dict[str, Any]] = []
    chunks: List[bytes] = []
    any_delta = False
    kinds_seen = set()
    for parts, leaf in _flatten(tree):
        bleaf = base_leaves.get(_path_key(parts))
        if is_compressed(leaf):
            meta, ch = _encode_omc(leaf, bleaf)
        elif (kind := _leaf_kind(leaf)) is not None:
            meta, ch = _LEAF_CODECS[kind][1](leaf, bleaf)
            kinds_seen.add(kind)
        else:
            meta, ch = _encode_raw(leaf, bleaf)
        any_delta |= meta["mode"] == "delta"
        meta["path"] = parts
        manifest.append(meta)
        chunks.extend(ch)

    frame: Dict[str, Any] = dict(leaves=manifest)
    tag = _strategy_tag(strategy, kinds_seen)
    if tag is not None:
        frame["strategy"], frame["strategy_version"] = tag
    mjson = json.dumps(frame, separators=(",", ":")).encode()
    body = b"".join(chunks)
    flags = FLAG_DELTA if any_delta else 0
    digest = tree_digest(base) if any_delta else 0
    crc = zlib.crc32(body, zlib.crc32(mjson))
    header = _HEADER.pack(
        MAGIC, WIRE_VERSION, flags, int(round_index), len(mjson), len(body),
        crc, digest,
    )
    return header + mjson + body


def _strategy_tag(strategy, kinds_seen) -> Optional[Tuple[str, int]]:
    """Resolve the frame's (strategy, wire_version) stamp, if any."""
    if strategy is not None:
        if isinstance(strategy, str):
            _ensure_strategy_codecs()
            from repro.compress import strategy_class

            cls = strategy_class(strategy)
            return cls.name, cls.wire_version
        return strategy.name, strategy.wire_version
    if kinds_seen:
        if len(kinds_seen) > 1:
            raise CodecError(
                f"tree mixes strategy leaf kinds {sorted(kinds_seen)}; pass "
                f"strategy= explicitly to tag the frame"
            )
        _ensure_strategy_codecs()
        from repro.compress import strategy_class

        cls = strategy_class(next(iter(kinds_seen)))
        return cls.name, cls.wire_version
    return None


def _parse_frame(data: bytes) -> Tuple[PayloadInfo, Dict[str, Any], memoryview]:
    """Validate framing + checksum; parse the manifest exactly once."""
    if len(data) < _HEADER.size:
        raise CodecError(f"payload truncated: {len(data)} bytes")
    magic, ver, flags, rnd, mlen, blen, crc, digest = _HEADER.unpack_from(data)
    if magic != MAGIC:
        raise CodecError(f"bad magic {magic!r}")
    if ver not in SUPPORTED_VERSIONS:
        raise CodecError(
            f"unsupported wire version {ver}; supported: {SUPPORTED_VERSIONS}"
        )
    if len(data) != _HEADER.size + mlen + blen:
        raise CodecError(
            f"length mismatch: header says {_HEADER.size + mlen + blen}, "
            f"got {len(data)}"
        )
    mview = memoryview(data)
    payload = mview[_HEADER.size:]
    if zlib.crc32(payload) != crc:
        raise CodecError("checksum mismatch: payload corrupt")
    try:
        manifest = json.loads(bytes(payload[:mlen]).decode())
        leaves = manifest["leaves"]
    except Exception as e:  # malformed manifest despite valid crc framing
        raise CodecError(f"malformed manifest: {e}") from e
    _check_strategy_tag(manifest)
    info = PayloadInfo(
        version=ver,
        flags=flags,
        round_index=rnd,
        header_bytes=_HEADER.size + mlen,
        body_bytes=blen,
        total_bytes=len(data),
        num_leaves=len(leaves),
        num_compressed=sum(1 for l in leaves if l["kind"] != "raw"),
        num_delta=sum(1 for l in leaves if l["mode"] == "delta"),
        base_digest=digest,
        strategy=manifest.get("strategy"),
        strategy_version=int(manifest.get("strategy_version", 0)),
    )
    return info, manifest, mview[info.header_bytes :]


def peek_payload(data: bytes) -> PayloadInfo:
    """Validate framing + checksum and return sizes, without decoding."""
    return _parse_frame(data)[0]


def header_base_digest(data: bytes) -> int:
    """Base digest straight from the header — no checksum scan.  For cheap
    delta-vs-full routing decisions; integrity is still enforced at decode."""
    if len(data) < _HEADER.size:
        raise CodecError(f"payload truncated: {len(data)} bytes")
    magic, _, flags, _, _, _, _, digest = _HEADER.unpack_from(data)
    if magic != MAGIC:
        raise CodecError(f"bad magic {magic!r}")
    return digest if flags & FLAG_DELTA else 0


def decode_payload(data: bytes, *, base=None) -> Tuple[Any, PayloadInfo]:
    """Payload bytes -> (storage pytree, PayloadInfo).  Bit-exact inverse of
    :func:`encode_payload`.

    Delta payloads require the encoder's ``base`` and verify it by digest —
    supplying a different tree (e.g. the wrong round's model) raises
    `CodecError` instead of silently producing corrupt parameters.  For full
    payloads ``base`` is ignored, so callers may always pass what they hold.
    """
    info, manifest, body = _parse_frame(data)
    if info.is_delta:
        if base is None:
            raise CodecError(
                "delta payload requires the base tree it was built on"
            )
        if tree_digest(base) != info.base_digest:
            raise CodecError(
                "delta base mismatch: payload was encoded against a different "
                "tree than the one supplied (stale or wrong-round base)"
            )
    base_leaves: Dict[str, Any] = {}
    if base is not None:
        base_leaves = {_path_key(p): leaf for p, leaf in _flatten(base)}

    entries = []
    off = 0
    for meta in manifest["leaves"]:
        parts = [list(p) for p in meta["path"]]
        bleaf = base_leaves.get(_path_key(parts))
        if meta["kind"] == "omc":
            leaf, off = _decode_omc(meta, body, off, bleaf)
        elif meta["kind"] == "raw":
            leaf, off = _decode_raw(meta, body, off, bleaf)
        else:
            if meta["kind"] not in _LEAF_CODECS:
                _ensure_strategy_codecs()
            if meta["kind"] not in _LEAF_CODECS:
                raise CodecError(f"unknown leaf kind {meta['kind']!r}")
            leaf, off = _LEAF_CODECS[meta["kind"]][2](meta, body, off, bleaf)
        entries.append((parts, leaf))
    if off != info.body_bytes:
        raise CodecError(f"body length mismatch: consumed {off}, have {info.body_bytes}")
    return _unflatten(entries), info


def payload_bytes_report(tree) -> Dict[str, Any]:
    """Theoretical full-payload body size for a storage tree.

    Uses the exact accounting the store layer uses (``packed_bytes`` + 8
    bytes of PVT scalars per entry for ``omc`` leaves, each strategy leaf's
    ``wire_body_bytes`` otherwise), so for any tree
    ``payload_bytes_report(t)["wire_bytes"] ==
    state_bytes_report(t)["packed_bytes"]`` (pure OMC trees) and a
    serialized full payload's ``body_bytes`` equals it for every strategy.

    ``per_strategy`` breaks the body down by leaf kind — payload bytes,
    index bytes (positions), and metadata bytes (PVT / scale scalars) —
    the rows wire-accounting reconciliation tests assert against
    (DESIGN.md §11).
    """
    wire = fp32 = n_params = n_comp = 0
    per: Dict[str, Dict[str, int]] = {}

    def bucket(kind: str) -> Dict[str, int]:
        return per.setdefault(kind, dict(
            payload_bytes=0, index_bytes=0, meta_bytes=0,
            num_leaves=0, num_params=0,
        ))

    for _, leaf in _flatten(tree):
        if is_compressed(leaf):
            n = int(leaf.codes.size)
            meta = _PVT_BYTES_PER_ENTRY * int(np.asarray(leaf.s).size)
            body = packing.packed_bytes(n, leaf.fmt) + meta
            n_comp += n
            b = bucket("omc")
            b["meta_bytes"] += meta
        elif (kind := _leaf_kind(leaf)) is not None:
            n = int(np.prod(leaf.shape)) if leaf.shape else 1
            body = int(leaf.wire_body_bytes())
            n_comp += n
            b = bucket(kind)
            b["index_bytes"] += int(leaf.index_bytes())
            b["meta_bytes"] += int(leaf.meta_bytes())
        else:
            arr = np.asarray(leaf)
            n = int(arr.size)
            body = int(arr.nbytes)
            b = bucket("raw")
        n_params += n
        fp32 += 4 * n
        wire += body
        b["payload_bytes"] += body
        b["num_leaves"] += 1
        b["num_params"] += n
    return dict(
        num_params=n_params,
        num_compressed=n_comp,
        fp32_bytes=fp32,
        wire_bytes=wire,
        wire_ratio=wire / max(fp32, 1),
        per_strategy=per,
    )
