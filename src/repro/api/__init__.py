"""Wire-format API: compressed payloads and FL/serve sessions (DESIGN.md §7).

The paper's premise is that model parameters live *compressed* at rest and on
the wire.  ``repro.core.store`` provides the at-rest form; this package is the
on-the-wire form and the client/server boundary built on it:

  * :mod:`repro.api.codecs` — versioned binary payload codec.  Serializes a
    storage pytree (``CompressedVariable`` leaves at the exact packed
    bitwidth, everything else raw f32) to bytes and back, bit-exactly, with
    crc32 integrity, version negotiation, and a round-over-round sparse
    XOR-delta mode for repeat downloads.
  * :mod:`repro.api.session` — ``FLSession`` (server side: owns compressed
    state, hands out per-round cohort payloads, ingests client uploads,
    aggregates and re-compresses) and ``ServeSession`` (inference side:
    batched decode over compressed weights with payload hot-swap between
    rounds).
  * ``python -m repro.api.demo --smoke`` — a loopback
    download→train→upload→aggregate driver exercising the full wire path.
"""

from .codecs import (  # noqa: F401
    CodecError,
    PayloadInfo,
    WIRE_VERSION,
    decode_payload,
    encode_payload,
    negotiate_version,
    payload_bytes_report,
    peek_payload,
    register_leaf_codec,
    tree_digest,
)
from .session import (  # noqa: F401
    AsyncTicket,
    FLClient,
    FLSession,
    RoundTicket,
    ServeSession,
)
