"""Atomic, reshardable checkpoints of federated server state."""

from .ckpt import (
    latest_checkpoint,
    restore_state,
    save_state,
    gc_checkpoints,
)
