"""Atomic, reshardable checkpoints of federated server state.

Sync state goes through :func:`save_state` / :func:`restore_state`;
the async runtime's full mid-buffer snapshot (server storage + buffer +
version-stamped pending tickets) through :func:`save_async_state` /
:func:`restore_async_state` (DESIGN.md §10).
"""

from .ckpt import (
    latest_checkpoint,
    restore_state,
    restore_async_state,
    save_state,
    save_async_state,
    gc_checkpoints,
)
