"""Atomic, reshardable checkpoints of federated server state.

Sync state goes through :func:`save_state` / :func:`restore_state`;
the async runtime's full mid-buffer snapshot (server storage + buffer +
version-stamped pending tickets) through :func:`save_async_state` /
:func:`restore_async_state` (DESIGN.md §10); sharded population state
(counters + at-rest-compressed EF residuals, layout-stamped) through
:func:`save_population_state` / :func:`restore_population_state`
(DESIGN.md §14).
"""

from .ckpt import (
    latest_checkpoint,
    restore_state,
    restore_async_state,
    restore_population_state,
    save_state,
    save_async_state,
    save_population_state,
    gc_checkpoints,
)
