"""Checkpoint/restart for the federated server state (DESIGN.md §5).

Design:
  * **Atomic**: write to ``<dir>/tmp.<step>``, fsync, then ``os.replace`` to
    ``<dir>/ckpt_<step>`` — a crash mid-write never corrupts the latest
    checkpoint.
  * **Logical layout**: arrays are saved *unsharded* (np arrays in an .npz)
    with a JSON manifest of the pytree structure, compressed-variable
    formats, round counter and RNG.  Restore re-shards onto whatever mesh is
    active — elastic scale-up/down across restarts needs no resharding tool.
  * **Keep-K GC** + ``latest_checkpoint`` resume discovery.
  * **Multi-host ready**: the manifest records ``process_index``; only
    process 0 writes (all processes hold identical global views under jit).

The CompressedVariable codes are stored as their uint containers — a
checkpoint of an OMC state is itself compressed (~the paper's parameter
memory ratio on disk).

Sharded-population state (DESIGN.md §14) checkpoints through
:func:`save_population_state` / :func:`restore_population_state`: the
manifest stamps the :class:`repro.scale.store.ShardLayout` identity and
the EF at-rest format, and restore *refuses* a cross-layout load — a
residual row silently landing on the wrong client would corrupt error
feedback invisibly.  Async checkpoints of population-backed runners stamp
the same ``population_layout`` and save counters as dense arrays.
"""

from __future__ import annotations

import dataclasses
import json
import os
import re
import shutil
import tempfile
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.formats import FloatFormat
from repro.core.store import CompressedVariable, is_compressed

_CKPT_RE = re.compile(r"^ckpt_(\d+)$")


def _flatten_state(state) -> Tuple[Dict[str, np.ndarray], Any]:
    """Pytree -> (flat name->np.ndarray, manifest-treedef description)."""
    leaves, treedef = jax.tree_util.tree_flatten(
        state, is_leaf=is_compressed
    )
    arrays: Dict[str, np.ndarray] = {}
    kinds: List[Dict[str, Any]] = []
    for i, leaf in enumerate(leaves):
        if is_compressed(leaf):
            arrays[f"a{i}_codes"] = np.asarray(jax.device_get(leaf.codes))
            arrays[f"a{i}_s"] = np.asarray(jax.device_get(leaf.s))
            arrays[f"a{i}_b"] = np.asarray(jax.device_get(leaf.b))
            kinds.append(dict(kind="compressed", fmt=leaf.fmt.name))
        else:
            arrays[f"a{i}"] = np.asarray(jax.device_get(leaf))
            kinds.append(dict(kind="array"))
    return arrays, (treedef, kinds)


def save_state(ckpt_dir: str, step: int, state, keep: int = 3,
               extra: Optional[Dict[str, Any]] = None) -> str:
    """Atomically save `state` as ckpt_<step>.  Returns the final path."""
    if jax.process_index() != 0:
        return os.path.join(ckpt_dir, f"ckpt_{step}")
    os.makedirs(ckpt_dir, exist_ok=True)
    arrays, (treedef, kinds) = _flatten_state(state)
    manifest = dict(
        step=int(step),
        kinds=kinds,
        treedef=str(treedef),
        process_index=jax.process_index(),
        extra=extra or {},
    )
    tmp = tempfile.mkdtemp(prefix=f"tmp.{step}.", dir=ckpt_dir)
    try:
        with open(os.path.join(tmp, "arrays.npz"), "wb") as f:
            np.savez(f, **arrays)
            f.flush()
            os.fsync(f.fileno())
        with open(os.path.join(tmp, "manifest.json"), "w") as f:
            json.dump(manifest, f)
            f.flush()
            os.fsync(f.fileno())
        final = os.path.join(ckpt_dir, f"ckpt_{step}")
        if os.path.exists(final):
            shutil.rmtree(final)
        os.replace(tmp, final)
    except BaseException:
        shutil.rmtree(tmp, ignore_errors=True)
        raise
    gc_checkpoints(ckpt_dir, keep)
    return final


def latest_checkpoint(ckpt_dir: str) -> Optional[Tuple[str, int]]:
    if not os.path.isdir(ckpt_dir):
        return None
    best = None
    for name in os.listdir(ckpt_dir):
        m = _CKPT_RE.match(name)
        if m and os.path.exists(os.path.join(ckpt_dir, name, "manifest.json")):
            step = int(m.group(1))
            if best is None or step > best[1]:
                best = (os.path.join(ckpt_dir, name), step)
    return best


def gc_checkpoints(ckpt_dir: str, keep: int) -> None:
    entries = []
    for name in os.listdir(ckpt_dir):
        m = _CKPT_RE.match(name)
        if m:
            entries.append((int(m.group(1)), name))
    entries.sort(reverse=True)
    for _, name in entries[keep:]:
        shutil.rmtree(os.path.join(ckpt_dir, name), ignore_errors=True)
    # stale tmp dirs from crashes
    for name in os.listdir(ckpt_dir):
        if name.startswith("tmp."):
            shutil.rmtree(os.path.join(ckpt_dir, name), ignore_errors=True)


def _async_state_tree(runner) -> Any:
    """The runner's array-bearing state as one pytree (DESIGN.md §10).

    Buffer models, the per-version download storages pending tickets still
    reference, and the lazily-trained-but-not-yet-uploaded cache all ride
    along with the server storage, so a killed async run resumes *mid
    buffer* with nothing retrained and nothing re-downloaded.  Training
    under an error-feedback strategy (DESIGN.md §12) adds the per-client
    residual state ``runner.ef`` — a resume must carry the residuals of
    already-trained-but-unflushed updates or EF's no-coordinate-ever-lost
    invariant breaks.
    """
    tree = dict(
        storage=runner.storage,
        buffer=[e.model for e in runner.buffer],
        versions={str(v): s for v, s in sorted(runner.version_storages.items())},
        trained={f"{v}|{c}": m
                 for (v, c), (m, _) in sorted(runner.trained.items())},
    )
    if getattr(runner, "ef", None) is not None:
        tree["ef"] = dict(runner.ef)
    if getattr(runner, "population", None) is not None:
        # dense counter arrays ride in the npz — a large population's
        # counters as manifest JSON would be multi-MB of boxed ints (§14)
        tree["counters"] = dict(
            round=runner.population.round_counters,
            event=runner.population.event_counters,
        )
    return tree


def save_async_state(ckpt_dir: str, runner, keep: int = 3) -> str:
    """Checkpoint an :class:`repro.federated.async_engine.AsyncRunner`.

    Array state goes through the same atomic npz+manifest path as
    :func:`save_state`; the event-loop scalars (virtual clock, version,
    pending version-stamped tickets, trace event counters, wire ledger)
    travel in the manifest's ``extra`` — everything a deterministic resume
    needs, since traces are pure functions of their checkpointed counters.
    The step counter is ``events_processed`` (monotone across a run).
    """
    pop = getattr(runner, "population", None)
    extra = dict(
        kind="async_runner",
        version=int(runner.version),
        clock=float(runner.clock),
        events_processed=int(runner.events_processed),
        completed=int(runner.completed),
        dropped_stale=int(runner.dropped_stale),
        buffer_meta=[[int(e.client_id), int(e.base_version), float(e.loss)]
                     for e in runner.buffer],
        pending=[[int(c), int(p.base_version), int(p.round_index),
                  float(p.upload_at)]
                 for c, p in runner.pending.items()],
        idle=[[int(c), float(t)] for c, t in runner.idle.items()],
        version_keys=sorted(int(v) for v in runner.version_storages),
        # population-backed counters travel as arrays in the state tree
        event_counters=(None if pop is not None else
                        {str(c): int(k)
                         for c, k in runner.event_counters.items()}),
        round_counters=(None if pop is not None else
                        {str(c): int(k)
                         for c, k in runner.round_counters.items()}),
        population_layout=(pop.layout.describe() if pop is not None
                           else None),
        trained_losses={f"{v}|{c}": float(l)
                        for (v, c), (_, l) in runner.trained.items()},
        has_ef=getattr(runner, "ef", None) is not None,
        fused_agg=bool(getattr(runner, "fused_agg", False)),
        history=runner.history,
        stats=(
            dict(snapshot=runner.stats.snapshot(),
                 pending={str(c): int(b)
                          for c, b in runner.stats._pending.items()})
            if runner.stats is not None else None
        ),
    )
    return save_state(ckpt_dir, runner.events_processed,
                      _async_state_tree(runner), keep=keep, extra=extra)


def restore_async_state(path: str, runner) -> Dict[str, Any]:
    """Restore a checkpoint from :func:`save_async_state` into ``runner``.

    ``runner`` must be a freshly-constructed AsyncRunner with the same
    family/config/trace/data — its storage provides the leaf templates;
    every mutable field is then overwritten in place.  Returns the
    manifest ``extra``.
    """
    with open(os.path.join(path, "manifest.json")) as f:
        extra = json.load(f)["extra"]
    if extra.get("kind") != "async_runner":
        raise ValueError(f"not an async-runner checkpoint: {path}")
    fused = bool(extra.get("fused_agg"))
    if fused != bool(getattr(runner, "fused_agg", False)):
        raise ValueError(
            "fused_agg mismatch: checkpoint was written with "
            f"fused_agg={fused} but the runner has "
            f"fused_agg={bool(getattr(runner, 'fused_agg', False))} — "
            "construct the runner the same way (DESIGN.md §13)"
        )
    pop = getattr(runner, "population", None)
    ck_layout = extra.get("population_layout")
    my_layout = pop.layout.describe() if pop is not None else None
    if ck_layout != my_layout:
        raise ValueError(
            "population layout mismatch: checkpoint was written with "
            f"layout={ck_layout} but the runner has layout={my_layout} — "
            "construct the runner with the same ShardLayout (or None); "
            "cross-layout restore needs an offline reshard (DESIGN.md §14)"
        )
    # fused buffers/trained caches hold transport-encoded uploads, whose
    # tree structure matches the storage tree; unfused ones are f32 trees
    entry_t = runner.storage if fused else _decompressed_template(runner.storage)
    template = dict(
        storage=runner.storage,
        buffer=[entry_t] * len(extra["buffer_meta"]),
        versions={str(v): runner.storage for v in extra["version_keys"]},
        trained={k: entry_t for k in sorted(extra["trained_losses"])},
    )
    has_ef = bool(extra.get("has_ef"))
    if has_ef != (runner.ef is not None):
        raise ValueError(
            "error-feedback state mismatch: checkpoint "
            f"{'has' if has_ef else 'lacks'} residuals but the runner "
            f"{'lacks' if has_ef else 'has'} them — construct the runner "
            "with the same strategy= the checkpointed run used"
        )
    if has_ef:
        template["ef"] = dict(runner.ef)
    if pop is not None:
        template["counters"] = dict(round=pop.round_counters,
                                    event=pop.event_counters)
    state, _ = restore_state(path, template)

    from repro.federated.async_engine import _BufferEntry, _Pending

    runner.storage = state["storage"]
    runner.version = int(extra["version"])
    runner.clock = float(extra["clock"])
    runner.events_processed = int(extra["events_processed"])
    runner.completed = int(extra["completed"])
    runner.dropped_stale = int(extra["dropped_stale"])
    runner.buffer = [
        _BufferEntry(int(c), int(b), m, float(l))
        for (c, b, l), m in zip(extra["buffer_meta"], state["buffer"])
    ]
    runner.pending = {
        int(c): _Pending(int(b), int(r), float(t))
        for c, b, r, t in extra["pending"]
    }
    runner.idle = {int(c): float(t) for c, t in extra["idle"]}
    if pop is not None:
        # in-place writes keep the runner's ArrayCounters views bound
        pop.round_counters[:] = np.asarray(
            jax.device_get(state["counters"]["round"]), np.int64
        )
        pop.event_counters[:] = np.asarray(
            jax.device_get(state["counters"]["event"]), np.int64
        )
    else:
        runner.event_counters = {
            int(c): int(k) for c, k in extra["event_counters"].items()
        }
        runner.round_counters = {
            int(c): int(k) for c, k in extra["round_counters"].items()
        }
    runner.version_storages = {
        int(v): s for v, s in state["versions"].items()
    }
    runner.trained = {
        (int(k.split("|")[0]), int(k.split("|")[1])):
            (state["trained"][k], float(l))
        for k, l in extra["trained_losses"].items()
    }
    if has_ef:
        runner.ef = dict(state["ef"])
    runner.history = list(extra["history"])
    if extra["stats"] is not None and runner.stats is not None:
        snap = extra["stats"]["snapshot"]
        for field in ("down_bytes", "up_bytes", "stale_up_bytes",
                      "dropped_up_bytes", "in_flight_bytes",
                      "peak_in_flight_bytes", "n_downloads", "n_uploads",
                      "n_stale", "n_dropped"):
            setattr(runner.stats, field, int(snap[field]))
        runner.stats._pending = {
            int(c): int(b) for c, b in extra["stats"]["pending"].items()
        }
    runner._rebuild_heap()
    return extra


def save_population_state(ckpt_dir: str, step: int, store,
                          keep: int = 3) -> str:
    """Checkpoint a :class:`repro.scale.store.PopulationStore` (§14).

    Counters and residual payloads (f32 rows, or packed words + per-row
    PVT params — the at-rest compression survives on disk) go through the
    atomic npz path; the manifest stamps the shard-layout identity and the
    EF format so :func:`restore_population_state` can refuse a mismatched
    load instead of silently reassigning rows to the wrong clients.
    """
    extra = dict(
        kind="population_store",
        layout=store.layout.describe(),
        ef=store.describe_ef(),
    )
    return save_state(ckpt_dir, step, store.state_tree(), keep=keep,
                      extra=extra)


def restore_population_state(path: str, store) -> Dict[str, Any]:
    """Restore a :func:`save_population_state` checkpoint into ``store``.

    ``store`` must be freshly constructed with the *same* ShardLayout and
    ``init_ef`` configuration the checkpointed run used; any mismatch in
    layout, EF variable set, or at-rest format raises ValueError.
    """
    with open(os.path.join(path, "manifest.json")) as f:
        extra = json.load(f)["extra"]
    if extra.get("kind") != "population_store":
        raise ValueError(f"not a population-store checkpoint: {path}")
    if extra["layout"] != store.layout.describe():
        raise ValueError(
            "population layout mismatch: checkpoint was written with "
            f"layout={extra['layout']} but the store has "
            f"layout={store.layout.describe()} — cross-layout restore "
            "needs an offline reshard (DESIGN.md §14)"
        )
    want_ef = store.describe_ef()
    have_ef = extra.get("ef")
    if have_ef != (
        dict(fmt=want_ef["fmt"],
             vars={k: list(v) for k, v in want_ef["vars"].items()})
        if want_ef is not None else None
    ):
        raise ValueError(
            "population EF state mismatch: checkpoint has "
            f"{have_ef} but the store has {want_ef} — call init_ef with "
            "the same selection policy and ef_fmt before restoring"
        )
    state, _ = restore_state(path, store.state_tree())
    store.load_state_tree(state)
    return extra


def _decompressed_template(storage):
    """f32 template tree matching a trained client model's structure."""
    from repro.core.store import decompress_tree

    return jax.eval_shape(decompress_tree, storage)


def restore_state(path: str, template, shardings=None):
    """Restore into the structure of `template` (same treedef).

    `shardings`: optional pytree of NamedSharding (matching `template`
    flattened with CompressedVariable leaves) — arrays are device_put onto
    it, re-sharding the logical arrays onto the *current* mesh (elastic
    restore).  Without it arrays land on the default device.
    """
    with open(os.path.join(path, "manifest.json")) as f:
        manifest = json.load(f)
    data = np.load(os.path.join(path, "arrays.npz"))
    leaves, treedef = jax.tree_util.tree_flatten(template, is_leaf=is_compressed)
    shard_leaves = (
        jax.tree_util.tree_flatten(shardings, is_leaf=lambda x: x is None
                                   or hasattr(x, "spec"))[0]
        if shardings is not None else [None] * len(leaves)
    )
    if len(manifest["kinds"]) != len(leaves):
        raise ValueError(
            f"checkpoint has {len(manifest['kinds'])} leaves, template has "
            f"{len(leaves)} — structure mismatch"
        )

    def put(arr, tmpl_leaf, sh):
        want = tuple(getattr(tmpl_leaf, "shape", arr.shape))
        if tuple(arr.shape) != want:
            raise ValueError(
                f"checkpoint array shape {arr.shape} != template {want} "
                f"— wrong config for this checkpoint"
            )
        if sh is not None:
            return jax.device_put(jnp.asarray(arr), sh)
        return jnp.asarray(arr)

    out = []
    for i, (kind, leaf) in enumerate(zip(manifest["kinds"], leaves)):
        sh = shard_leaves[i] if i < len(shard_leaves) else None
        if kind["kind"] == "compressed":
            if not is_compressed(leaf):
                raise ValueError(f"leaf {i}: checkpoint compressed, template not")
            fmt = FloatFormat.parse(kind["fmt"])
            out.append(CompressedVariable(
                codes=put(data[f"a{i}_codes"], leaf, sh),
                s=jnp.asarray(data[f"a{i}_s"]),
                b=jnp.asarray(data[f"a{i}_b"]),
                fmt=fmt,
            ))
        else:
            out.append(put(data[f"a{i}"], leaf, sh))
    return jax.tree_util.tree_unflatten(treedef, out), manifest
