"""Checkpoint/restart for the federated server state (DESIGN.md §5).

Design:
  * **Atomic**: write to ``<dir>/tmp.<step>``, fsync, then ``os.replace`` to
    ``<dir>/ckpt_<step>`` — a crash mid-write never corrupts the latest
    checkpoint.
  * **Logical layout**: arrays are saved *unsharded* (np arrays in an .npz)
    with a JSON manifest of the pytree structure, compressed-variable
    formats, round counter and RNG.  Restore re-shards onto whatever mesh is
    active — elastic scale-up/down across restarts needs no resharding tool.
  * **Keep-K GC** + ``latest_checkpoint`` resume discovery.
  * **Multi-host ready**: the manifest records ``process_index``; only
    process 0 writes (all processes hold identical global views under jit).

The CompressedVariable codes are stored as their uint containers — a
checkpoint of an OMC state is itself compressed (~the paper's parameter
memory ratio on disk).
"""

from __future__ import annotations

import dataclasses
import json
import os
import re
import shutil
import tempfile
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.formats import FloatFormat
from repro.core.store import CompressedVariable, is_compressed

_CKPT_RE = re.compile(r"^ckpt_(\d+)$")


def _flatten_state(state) -> Tuple[Dict[str, np.ndarray], Any]:
    """Pytree -> (flat name->np.ndarray, manifest-treedef description)."""
    leaves, treedef = jax.tree_util.tree_flatten(
        state, is_leaf=is_compressed
    )
    arrays: Dict[str, np.ndarray] = {}
    kinds: List[Dict[str, Any]] = []
    for i, leaf in enumerate(leaves):
        if is_compressed(leaf):
            arrays[f"a{i}_codes"] = np.asarray(jax.device_get(leaf.codes))
            arrays[f"a{i}_s"] = np.asarray(jax.device_get(leaf.s))
            arrays[f"a{i}_b"] = np.asarray(jax.device_get(leaf.b))
            kinds.append(dict(kind="compressed", fmt=leaf.fmt.name))
        else:
            arrays[f"a{i}"] = np.asarray(jax.device_get(leaf))
            kinds.append(dict(kind="array"))
    return arrays, (treedef, kinds)


def save_state(ckpt_dir: str, step: int, state, keep: int = 3,
               extra: Optional[Dict[str, Any]] = None) -> str:
    """Atomically save `state` as ckpt_<step>.  Returns the final path."""
    if jax.process_index() != 0:
        return os.path.join(ckpt_dir, f"ckpt_{step}")
    os.makedirs(ckpt_dir, exist_ok=True)
    arrays, (treedef, kinds) = _flatten_state(state)
    manifest = dict(
        step=int(step),
        kinds=kinds,
        treedef=str(treedef),
        process_index=jax.process_index(),
        extra=extra or {},
    )
    tmp = tempfile.mkdtemp(prefix=f"tmp.{step}.", dir=ckpt_dir)
    try:
        with open(os.path.join(tmp, "arrays.npz"), "wb") as f:
            np.savez(f, **arrays)
            f.flush()
            os.fsync(f.fileno())
        with open(os.path.join(tmp, "manifest.json"), "w") as f:
            json.dump(manifest, f)
            f.flush()
            os.fsync(f.fileno())
        final = os.path.join(ckpt_dir, f"ckpt_{step}")
        if os.path.exists(final):
            shutil.rmtree(final)
        os.replace(tmp, final)
    except BaseException:
        shutil.rmtree(tmp, ignore_errors=True)
        raise
    gc_checkpoints(ckpt_dir, keep)
    return final


def latest_checkpoint(ckpt_dir: str) -> Optional[Tuple[str, int]]:
    if not os.path.isdir(ckpt_dir):
        return None
    best = None
    for name in os.listdir(ckpt_dir):
        m = _CKPT_RE.match(name)
        if m and os.path.exists(os.path.join(ckpt_dir, name, "manifest.json")):
            step = int(m.group(1))
            if best is None or step > best[1]:
                best = (os.path.join(ckpt_dir, name), step)
    return best


def gc_checkpoints(ckpt_dir: str, keep: int) -> None:
    entries = []
    for name in os.listdir(ckpt_dir):
        m = _CKPT_RE.match(name)
        if m:
            entries.append((int(m.group(1)), name))
    entries.sort(reverse=True)
    for _, name in entries[keep:]:
        shutil.rmtree(os.path.join(ckpt_dir, name), ignore_errors=True)
    # stale tmp dirs from crashes
    for name in os.listdir(ckpt_dir):
        if name.startswith("tmp."):
            shutil.rmtree(os.path.join(ckpt_dir, name), ignore_errors=True)


def restore_state(path: str, template, shardings=None):
    """Restore into the structure of `template` (same treedef).

    `shardings`: optional pytree of NamedSharding (matching `template`
    flattened with CompressedVariable leaves) — arrays are device_put onto
    it, re-sharding the logical arrays onto the *current* mesh (elastic
    restore).  Without it arrays land on the default device.
    """
    with open(os.path.join(path, "manifest.json")) as f:
        manifest = json.load(f)
    data = np.load(os.path.join(path, "arrays.npz"))
    leaves, treedef = jax.tree_util.tree_flatten(template, is_leaf=is_compressed)
    shard_leaves = (
        jax.tree_util.tree_flatten(shardings, is_leaf=lambda x: x is None
                                   or hasattr(x, "spec"))[0]
        if shardings is not None else [None] * len(leaves)
    )
    if len(manifest["kinds"]) != len(leaves):
        raise ValueError(
            f"checkpoint has {len(manifest['kinds'])} leaves, template has "
            f"{len(leaves)} — structure mismatch"
        )

    def put(arr, tmpl_leaf, sh):
        want = tuple(getattr(tmpl_leaf, "shape", arr.shape))
        if tuple(arr.shape) != want:
            raise ValueError(
                f"checkpoint array shape {arr.shape} != template {want} "
                f"— wrong config for this checkpoint"
            )
        if sh is not None:
            return jax.device_put(jnp.asarray(arr), sh)
        return jnp.asarray(arr)

    out = []
    for i, (kind, leaf) in enumerate(zip(manifest["kinds"], leaves)):
        sh = shard_leaves[i] if i < len(shard_leaves) else None
        if kind["kind"] == "compressed":
            if not is_compressed(leaf):
                raise ValueError(f"leaf {i}: checkpoint compressed, template not")
            fmt = FloatFormat.parse(kind["fmt"])
            out.append(CompressedVariable(
                codes=put(data[f"a{i}_codes"], leaf, sh),
                s=jnp.asarray(data[f"a{i}_s"]),
                b=jnp.asarray(data[f"a{i}_b"]),
                fmt=fmt,
            ))
        else:
            out.append(put(data[f"a{i}"], leaf, sh))
    return jax.tree_util.tree_unflatten(treedef, out), manifest
