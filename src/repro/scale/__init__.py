"""Sharded population runtime: toward 1M simulated clients (DESIGN.md §14).

Layers (each usable alone):

  * :mod:`repro.scale.store` — :class:`ShardLayout` + :class:`PopulationStore`:
    per-client server state (EF residuals, counters) sharded by client-id
    blocks and kept compressed at rest,
  * :mod:`repro.scale.stream` — the fixed-capacity compiled
    partial-aggregate program (peak memory bounded by ``capacity``, not
    cohort size),
  * :mod:`repro.scale.hierarchy` — two-level tree aggregation
    (per-shard leaf partials → root combine), equivalence-gated against
    the flat engine,
  * :mod:`repro.scale.serve_driver` — hot-swap under sustained query
    traffic (the serving half of the scale story).
"""

from .hierarchy import (
    make_root_fn,
    run_round_sharded,
    run_training_sharded,
    tree_aggregate,
)
from .serve_driver import run_serve_under_swap, synthetic_token_batch
from .store import ArrayCounters, PopulationStore, ShardLayout
from .stream import iter_chunks, make_stream_fn, pad_chunk

__all__ = [
    "ArrayCounters",
    "PopulationStore",
    "ShardLayout",
    "iter_chunks",
    "make_root_fn",
    "make_stream_fn",
    "pad_chunk",
    "run_round_sharded",
    "run_serve_under_swap",
    "run_training_sharded",
    "synthetic_token_batch",
    "tree_aggregate",
]
