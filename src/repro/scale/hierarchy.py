"""Two-level tree aggregation over a sharded population (DESIGN.md §14).

The flat engine aggregates a round in one reduction over the stacked
cohort.  At population scale the cohort's members live in different shards
(:class:`repro.scale.store.ShardLayout`) — potentially on different hosts —
so aggregation goes through a two-level tree instead:

  * **leaves** — each shard streams its cohort members through the
    fixed-capacity partial-aggregate program
    (:func:`repro.scale.stream.make_stream_fn`), producing the shard's
    weighted sums ``(Σ w·model, Σ w, Σ w·loss)``,
  * **root** — the per-shard sums are added and normalized **once**
    (``mean = Σ_shards Σ w·x / max(Σ w, 1e-9)`` — algebraically identical
    to :func:`repro.federated.cohort.aggregate_weighted` on the flat
    stack), then the ordinary server step runs: interpolate toward the
    mean with ``sim.server_lr`` and re-compress
    (:func:`repro.federated.engine.apply_server_step` — the exact helper
    the flat engine's ``finish`` uses).

Equivalence contract (tier-1, ``tests/test_scale.py``): with the same
``key``/``round_index`` the sharded round consumes the *identical* cohort
sample and survival mask as ``engine.run_round_vectorized`` (both defer to
:mod:`repro.federated.cohort`), and the treed result matches the flat
round within one quantization step — the only differences are f32
reassociation across chunk/shard boundaries (the documented engine-vs-loop
tolerance) and, under ``fused_agg``, the same single transport-RNE per
upload the fused flat path applies.  Wire ledgers are byte-exact: the
bytes a client uploads do not depend on which shard aggregates it, so
metrics reuse :func:`repro.federated.engine.round_wire_metrics` unchanged.
"""

from __future__ import annotations

from typing import Any, Callable, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.omc import OMCConfig
from repro.core.store import decompress_tree
from repro.federated import accounting
from repro.federated import cohort as cohort_lib
from repro.federated import engine, simulate
from repro.federated.simulate import SimConfig
from repro.federated.state import compress_params
from repro.obs import metrics as obs_metrics
from repro.obs import null_span

from .store import PopulationStore, ShardLayout
from .stream import iter_chunks, make_stream_fn, pad_chunk


def tree_aggregate(stacked, weights, num_shards: int):
    """Pure two-level weighted mean (the tree-aggregation algebra).

    Splits the leading client axis into ``num_shards`` contiguous balanced
    groups (the :class:`~repro.scale.store.ShardLayout` partition rule),
    computes per-group weighted sums, then combines and normalizes at the
    root.  Equals :func:`repro.federated.cohort.aggregate_weighted` up to
    f32 reassociation — the unit-testable core of the sharded round.
    """
    w = jnp.asarray(weights, jnp.float32)
    layout = ShardLayout(int(w.shape[0]), num_shards)
    starts = layout.starts
    wtot = jnp.maximum(w.sum(), 1e-9)

    def leaf(x):
        parts = []
        for i in range(num_shards):
            lo, hi = int(starts[i]), int(starts[i + 1])
            wb = w[lo:hi].reshape((-1,) + (1,) * (x.ndim - 1))
            parts.append((x[lo:hi] * wb).sum(0))
        root = parts[0]
        for p in parts[1:]:
            root = root + p
        return root / wtot

    return jax.tree_util.tree_map(leaf, stacked)


def make_root_fn(specs, omc: OMCConfig, sim: SimConfig):
    """Jitted root combine: ``(storage, wsum_tree, wtot) -> new storage``.

    Normalizes the accumulated partial sums into the cohort mean and
    applies the same server step as the flat engine
    (:func:`repro.federated.engine.apply_server_step`: interpolation with
    ``sim.server_lr`` + policy re-compress) — one requantization per round,
    matching the flat paths' error profile.

    The round metric bundle (DESIGN.md §15) is *not* computed here:
    :func:`run_round_sharded` assembles it eagerly on the host from the
    same ``wsum``/``wtot`` accumulators, so this program is identical with
    metrics on or off.
    """

    @jax.jit
    def root_fn(storage, wsum, wtot):
        server_f32 = decompress_tree(storage)
        mean = jax.tree_util.tree_map(
            lambda p: p / jnp.maximum(wtot, 1e-9), wsum
        )
        return engine.apply_server_step(server_f32, mean, specs, omc,
                                        sim.server_lr)

    return root_fn


def _add_trees(a, b):
    if a is None:
        return b
    return jax.tree_util.tree_map(jnp.add, a, b)


def run_round_sharded(
    family,
    cfg,
    specs,
    omc: OMCConfig,
    sim: SimConfig,
    server_params,  # storage tree (CompressedVariable | f32)
    data_fn,
    plan: cohort_lib.CohortPlan,
    layout: ShardLayout,
    round_index: int,
    key: jax.Array,
    *,
    capacity: Optional[int] = None,
    stream_fn=None,
    root_fn=None,
    strategy=None,
    ste: bool = False,
    fused_agg: bool = False,
    store: Optional[PopulationStore] = None,
    wire_table: Optional[accounting.WireTable] = None,
    ledger: Optional[accounting.StreamLedger] = None,
    on_chunk: Optional[Callable[[int, int, int], None]] = None,
    obs=None,
) -> Tuple[Any, Dict[str, float]]:
    """One tree-aggregated round over a sharded population.

    Samples the identical cohort the flat engine would (homogeneous
    :func:`repro.federated.cohort.sample_cohort` + ``survival_mask`` under
    the same key), groups members by their owning shard, streams each
    shard's members through the fixed-capacity program in
    ``capacity``-sized chunks, and root-combines the shard partials.
    Returns ``(new server storage, metrics)`` with the engine's metric
    schema plus ``shards`` / ``chunks`` / ``stream_capacity``.

    ``store`` supplies per-client state: error-feedback residual rows are
    gathered per chunk and scattered back alive-masked (compressed at rest
    when the store packs them), and participation counters advance.
    ``ledger`` (a :class:`repro.federated.accounting.StreamLedger`) asserts
    the bounded-memory contract; ``on_chunk(shard, n_real, chunk_index)``
    is an instrumentation hook (the population benchmark samples live
    device bytes from it).

    ``obs`` (DESIGN.md §15): chunk metric partials fold across shards and
    the round bundle is assembled eagerly on the host from the same
    ``wsum``/``wtot`` accumulators the root combine consumes; a cached
    ``stream_fn`` must have been built with matching ``collect_metrics``
    (``root_fn`` is metric-free either way).  ``obs=None`` leaves the
    programs untouched.
    """
    takes_ef = simulate.ef_lib.takes_residual(omc, strategy)
    if plan.num_clients != layout.num_clients:
        raise ValueError(
            f"plan covers {plan.num_clients} clients but the layout shards "
            f"{layout.num_clients}"
        )
    if takes_ef and (store is None or not store.has_ef):
        raise ValueError(
            f"strategy {strategy.label!r} uses error feedback: pass a "
            f"PopulationStore with init_ef() applied (DESIGN.md §14)"
        )
    collect = obs is not None and obs.collect_metrics
    if capacity is None:
        capacity = min(plan.cohort_size, 64)
    if stream_fn is None:
        stream_fn = make_stream_fn(family, cfg, specs, omc, sim, data_fn,
                                   capacity, strategy=strategy, ste=ste,
                                   fused_agg=fused_agg,
                                   collect_metrics=collect)
    if root_fn is None:
        root_fn = make_root_fn(specs, omc, sim)

    ids = cohort_lib.sample_cohort(key, plan, round_index)
    alive = cohort_lib.survival_mask(key, plan, round_index)
    ids_np = np.asarray(ids, np.int64)
    alive_np = np.asarray(alive, bool)
    shard_of = layout.shard_of(ids_np)

    wsum = None
    wtot = jnp.float32(0.0)
    loss_wsum = jnp.float32(0.0)
    chunk_bundles = None
    n_chunks = 0
    shards_used = 0
    r = jnp.int32(round_index)
    for shard in range(layout.num_shards):
        pos = np.flatnonzero(shard_of == shard)
        if pos.size == 0:
            continue
        shards_used += 1
        for chunk_pos in iter_chunks(pos, capacity):
            cids, w = pad_chunk(ids_np[chunk_pos], alive_np[chunk_pos],
                                capacity)
            n_real = int(chunk_pos.size)
            if takes_ef:
                res = stream_fn(
                    server_params, jnp.asarray(cids), jnp.asarray(w), r,
                    store.gather_ef(cids)
                )
                pw, pwt, pl, new_rows = res[:4]
                real_rows = {
                    k: v[:n_real] for k, v in new_rows.items()
                }
                store.scatter_ef(cids[:n_real], real_rows,
                                 mask=alive_np[chunk_pos])
            else:
                res = stream_fn(
                    server_params, jnp.asarray(cids), jnp.asarray(w), r
                )
                pw, pwt, pl = res[:3]
            if collect:
                chunk_bundles = obs_metrics.fold_partial_bundles(
                    chunk_bundles, res[-1]
                )
            wsum = _add_trees(wsum, pw)
            wtot = wtot + pwt
            loss_wsum = loss_wsum + pl
            n_chunks += 1
            if ledger is not None:
                ledger.on_chunk(n_real)
            if on_chunk is not None:
                on_chunk(shard, n_real, n_chunks)

    new_storage = root_fn(server_params, wsum, wtot)
    n_alive = int(alive_np.sum())
    loss = float(loss_wsum / jnp.maximum(wtot, 1.0))
    bundle = None
    if collect:
        # eager host-side bundle (DESIGN.md §15): the same accumulators the
        # root combine consumed yield the cohort mean, so no metric math
        # ever runs inside a compiled program
        mean = jax.tree_util.tree_map(
            lambda p: p / jnp.maximum(wtot, 1e-9), wsum
        )
        bundle = obs_metrics.server_round_bundle(
            specs, server_params, new_storage, mean, sim.server_lr,
        )
        bundle["loss"] = jnp.float32(loss)
        bundle["alive"] = jnp.float32(n_alive)
        if chunk_bundles is not None:
            bundle.update(chunk_bundles)
    if store is not None:
        store.note_round(ids_np, alive_np)
    metrics: Dict[str, Any] = dict(
        loss=loss,
        cohort=n_alive,
        dropped=int(plan.cohort_size - n_alive),
        shards=shards_used,
        chunks=n_chunks,
        stream_capacity=int(capacity),
    )
    if wire_table is not None:
        metrics.update(
            engine.round_wire_metrics(wire_table, omc, [omc], [ids], alive,
                                      round_index, strategy=strategy)
        )
    if obs is not None:
        obs.record("round", bundle, round=int(round_index), **metrics)
    return new_storage, metrics


def run_training_sharded(
    family,
    cfg,
    omc: OMCConfig,
    sim: SimConfig,
    plan: cohort_lib.CohortPlan,
    layout: ShardLayout,
    data_fn,
    init_key,
    num_rounds: int,
    *,
    capacity: Optional[int] = None,
    strategy=None,
    ste: bool = False,
    fused_agg: bool = False,
    store: Optional[PopulationStore] = None,
    wire: bool = True,
    init_params=None,
    log: Optional[Callable[[str], None]] = None,
    obs=None,
) -> Tuple[Any, List[Dict[str, Any]], Optional[accounting.StreamLedger]]:
    """Sharded mirror of :func:`repro.federated.engine.run_training_vectorized`.

    Builds the stream/root programs once, derives the round key with the
    same ``fold_in(init_key, 0xC047)`` as both flat training loops (so all
    paths sample identical cohorts from one seed), and returns
    ``(final storage, history, ledger)``.  A ``store`` is allocated
    automatically when the strategy needs error feedback (f32-at-rest, the
    equivalence mode); pass one explicitly to choose packed-at-rest rows
    or to keep counters across calls.
    """
    specs = family.param_specs(cfg)
    params = family.init(init_key, cfg) if init_params is None else init_params
    storage = compress_params(params, specs, omc) if omc.enabled else params
    if capacity is None:
        capacity = min(plan.cohort_size, 64)
    takes_ef = simulate.ef_lib.takes_residual(omc, strategy)
    if takes_ef and store is None:
        store = PopulationStore(layout)
        store.init_ef(params, specs, omc)
    collect = obs is not None and obs.collect_metrics
    stream_fn = make_stream_fn(family, cfg, specs, omc, sim, data_fn,
                               capacity, strategy=strategy, ste=ste,
                               fused_agg=fused_agg, collect_metrics=collect)
    root_fn = make_root_fn(specs, omc, sim)
    table = accounting.build_wire_table(params, specs, omc) if wire else None
    ledger = (
        accounting.StreamLedger(table, omc, capacity)
        if table is not None else None
    )
    key = jax.random.fold_in(init_key, 0xC047)
    history: List[Dict[str, Any]] = []
    for r in range(num_rounds):
        with null_span(obs, "round", round=r):
            storage, metrics = run_round_sharded(
                family, cfg, specs, omc, sim, storage, data_fn, plan, layout,
                r, key, capacity=capacity, stream_fn=stream_fn,
                root_fn=root_fn, strategy=strategy, ste=ste,
                fused_agg=fused_agg, store=store,
                wire_table=table, ledger=ledger, obs=obs,
            )
        history.append(dict(round=r, **metrics))
        if log and ((r + 1) % 10 == 0 or r == 0):
            log(f"round {r + 1}/{num_rounds}: " +
                ", ".join(f"{k}={v:.4g}" if isinstance(v, float)
                          else f"{k}={v}" for k, v in metrics.items()))
    return storage, history, ledger
