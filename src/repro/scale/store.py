"""Sharded population state, compressed at rest (DESIGN.md §14).

Everything the server holds *per client* — error-feedback residuals
(:mod:`repro.compress.feedback`), round counters, trace event counters —
lives here as one :class:`PopulationStore`, partitioned into contiguous
client-id shards by a :class:`ShardLayout`.  The layout is *logical*: it
defines which shard owns which client rows, independently of how many
devices exist.  When a ``launch/mesh`` population mesh is available,
:meth:`PopulationStore.device_ef` places rows across it with the
spec-driven ``NamedSharding`` from :func:`repro.launch.specs.population_sharding`;
on a single CPU the same layout drives the host-side shard grouping of
:mod:`repro.scale.hierarchy`.

The memory story is the paper's online-compression storage model applied
to *server-held client state*: residual rows can be kept as OMC minifloat
bitstreams (``core.packing`` words + one PVT ``(s, b)`` pair per client
row) instead of f32, so a 100k–1M-client population's residual state
shrinks by the same ~bits/32 factor as the model itself.  ``ef_fmt=None``
keeps rows f32 (bit-exact with the engines' dense EF state — the
equivalence-gate mode); a :class:`~repro.core.formats.FloatFormat` packs
rows at rest at the cost of one extra quantization step per scatter
(bounded, tested in ``tests/test_scale.py``).
"""

from __future__ import annotations

import dataclasses
from typing import Any, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import packing
from repro.core.formats import FloatFormat, decode, encode, value_quantize
from repro.core.omc import OMCConfig
from repro.core.pvt import pvt_apply, pvt_solve_fast


@dataclasses.dataclass(frozen=True)
class ShardLayout:
    """Contiguous balanced partition of ``num_clients`` into ``num_shards``.

    Shard ``i`` owns the id block ``[starts[i], starts[i+1])``; the first
    ``num_clients % num_shards`` shards are one client larger.  Contiguous
    blocks keep every per-shard gather a slice (no permutation indices to
    store) and make the layout describable by two integers — which is what
    the checkpoint stamp (:func:`repro.checkpoint.save_population_state`)
    records and refuses to silently reshape across.
    """

    num_clients: int
    num_shards: int

    def __post_init__(self):
        if not 1 <= self.num_shards <= self.num_clients:
            raise ValueError(
                f"num_shards must satisfy 1 <= num_shards <= "
                f"{self.num_clients}, got {self.num_shards}"
            )

    @property
    def shard_sizes(self) -> Tuple[int, ...]:
        base, rem = divmod(self.num_clients, self.num_shards)
        return tuple(base + (1 if i < rem else 0)
                     for i in range(self.num_shards))

    @property
    def starts(self) -> np.ndarray:
        """int64[num_shards + 1]: shard i owns [starts[i], starts[i+1])."""
        return np.concatenate(
            [[0], np.cumsum(self.shard_sizes)]
        ).astype(np.int64)

    def shard_of(self, client_ids) -> np.ndarray:
        """int64[...]: owning shard per client id (vectorized)."""
        ids = np.asarray(client_ids, np.int64)
        if ids.size and (ids.min() < 0 or ids.max() >= self.num_clients):
            raise ValueError(
                f"client ids must be in [0, {self.num_clients}), got range "
                f"[{ids.min()}, {ids.max()}]"
            )
        return np.searchsorted(self.starts, ids, side="right") - 1

    def clients_of(self, shard: int) -> np.ndarray:
        s = self.starts
        return np.arange(s[shard], s[shard + 1], dtype=np.int64)

    def describe(self) -> Dict[str, int]:
        """The checkpoint-stamped identity of this layout."""
        return dict(num_clients=int(self.num_clients),
                    num_shards=int(self.num_shards))


@dataclasses.dataclass
class _EFVar:
    """One selected variable's population residuals, f32 or packed at rest."""

    name: str
    shape: Tuple[int, ...]  # per-client row shape
    raw: Optional[np.ndarray] = None  # f32 [N, *shape] (exact mode)
    words: Optional[np.ndarray] = None  # uint32 [N, n_words] (packed mode)
    s: Optional[np.ndarray] = None  # f32 [N] per-row PVT scale
    b: Optional[np.ndarray] = None  # f32 [N] per-row PVT bias

    @property
    def n(self) -> int:
        return int(np.prod(self.shape)) if self.shape else 1

    def at_rest_bytes(self) -> int:
        if self.raw is not None:
            return int(self.raw.nbytes)
        return int(self.words.nbytes + self.s.nbytes + self.b.nbytes)


class PopulationStore:
    """All server-held per-client state for one simulated population.

    Counters are dense host arrays (8 B + 8 B per client); residual state is
    optional and attached by :meth:`init_ef`.  The row API
    (:meth:`gather_ef` / :meth:`scatter_ef`) is what the streaming round
    program consumes — gathers decompress on the way out, scatters
    re-compress on the way in, so rows only ever exist decompressed for the
    cohort chunk currently in flight (bounded by the stream capacity, never
    by the population).
    """

    def __init__(self, layout: ShardLayout):
        self.layout = layout
        n = layout.num_clients
        # rounds started / trace events per client — the async runtime's
        # dict counters, as arrays (ArrayCounters adapts them back)
        self.round_counters = np.zeros((n,), np.int64)
        self.event_counters = np.zeros((n,), np.int64)
        self.ef_fmt: Optional[FloatFormat] = None
        self._ef: Dict[str, _EFVar] = {}
        self._codecs: Dict[str, Tuple[Any, Any]] = {}

    # -- counters -----------------------------------------------------------

    def round_view(self) -> "ArrayCounters":
        return ArrayCounters(self.round_counters)

    def event_view(self) -> "ArrayCounters":
        return ArrayCounters(self.event_counters)

    def note_round(self, client_ids, alive=None) -> None:
        """Sync-path trace accounting: invited clients start a round;
        survivors (``alive`` mask) complete an upload event."""
        ids = np.asarray(client_ids, np.int64)
        self.round_counters[ids] += 1
        if alive is not None:
            self.event_counters[ids[np.asarray(alive, bool)]] += 1

    # -- error-feedback rows ------------------------------------------------

    @property
    def has_ef(self) -> bool:
        return bool(self._ef)

    @property
    def ef_names(self) -> List[str]:
        return list(self._ef)

    def init_ef(self, params_f32, specs, omc: OMCConfig,
                ef_fmt: Optional[FloatFormat] = None) -> None:
        """Allocate zeroed residuals for every policy-selected variable.

        Same canonical :func:`repro.federated.accounting.walk_selected`
        order (and therefore the same dict keys) as
        :func:`repro.compress.feedback.init_ef_state` — a store-backed run
        and a dense-EF run index the identical state.  ``ef_fmt=None``
        keeps rows f32; a format packs them at rest (zero encodes to zero
        codes with ``s=1, b=0``, so a fresh store is exact either way).
        """
        from repro.federated import accounting

        if isinstance(ef_fmt, str):
            ef_fmt = FloatFormat.parse(ef_fmt)
        self.ef_fmt = ef_fmt
        sel, _ = accounting.walk_selected(params_f32, specs, omc)
        n = self.layout.num_clients
        self._ef = {}
        for name, _, leaf in sel:
            shape = tuple(leaf.shape)
            var = _EFVar(name, shape)
            if ef_fmt is None:
                var.raw = np.zeros((n,) + shape, np.float32)
            else:
                nw = packing.packed_words(var.n, ef_fmt.bits)
                var.words = np.zeros((n, nw), np.uint32)
                var.s = np.ones((n,), np.float32)
                var.b = np.zeros((n,), np.float32)
            self._ef[name] = var
        self._codecs = {}

    def _codec(self, name: str):
        """Jitted per-variable row codecs (cached; one trace per chunk width)."""
        if name not in self._codecs:
            var = self._ef[name]
            fmt, n = self.ef_fmt, var.n

            @jax.jit
            def dec(words, s, b):
                codes = jax.vmap(lambda w: packing.unpack(w, fmt.bits, n))(
                    words
                )
                vals = pvt_apply(decode(codes.astype(fmt.container_dtype),
                                        fmt), s[:, None], b[:, None])
                return vals.reshape((-1,) + var.shape)

            @jax.jit
            def enc(rows):
                flat = rows.reshape((rows.shape[0], n))
                vq = value_quantize(flat, fmt)
                s, b = pvt_solve_fast(flat, vq, 1)  # broadcastable [C, 1]
                codes = encode(vq, fmt, quantize=False)
                words = jax.vmap(lambda c: packing.pack(c, fmt.bits))(codes)
                return words, s[:, 0], b[:, 0]

            self._codecs[name] = (dec, enc)
        return self._codecs[name]

    def gather_ef(self, client_ids) -> Dict[str, jax.Array]:
        """Decompressed residual rows ``{name: f32[C, *shape]}`` for a chunk."""
        ids = np.asarray(client_ids, np.int64)
        out = {}
        for name, var in self._ef.items():
            if var.raw is not None:
                out[name] = jnp.asarray(var.raw[ids])
            else:
                dec, _ = self._codec(name)
                out[name] = dec(jnp.asarray(var.words[ids]),
                                jnp.asarray(var.s[ids]),
                                jnp.asarray(var.b[ids]))
        return out

    def scatter_ef(self, client_ids, rows: Dict[str, jax.Array],
                   mask=None) -> None:
        """Write updated rows back (re-compressing in packed mode).

        ``mask`` (bool[C]) keeps un-masked clients' previous residuals —
        the alive-masked scatter the engines apply (a dead client never
        uploaded, so its residual must not move).
        """
        ids = np.asarray(client_ids, np.int64)
        keep = np.ones(ids.shape, bool) if mask is None else np.asarray(
            mask, bool
        )
        ids = ids[keep]
        if ids.size == 0:
            return
        for name, var in self._ef.items():
            new = rows[name]
            new = new[np.flatnonzero(keep)] if not keep.all() else new
            if var.raw is not None:
                var.raw[ids] = np.asarray(jax.device_get(new), np.float32)
            else:
                _, enc = self._codec(name)
                words, s, b = enc(jnp.asarray(new))
                var.words[ids] = np.asarray(jax.device_get(words))
                var.s[ids] = np.asarray(jax.device_get(s))
                var.b[ids] = np.asarray(jax.device_get(b))

    def device_ef(self, mesh, client_ids=None) -> Dict[str, jax.Array]:
        """Residual rows placed on a population mesh (``clients`` axis
        partitioned via :func:`repro.launch.specs.population_sharding`)."""
        from repro.launch import specs as launch_specs

        rows = self.gather_ef(
            np.arange(self.layout.num_clients) if client_ids is None
            else client_ids
        )
        return {
            k: jax.device_put(
                v, launch_specs.population_sharding(mesh, v.ndim)
            )
            for k, v in rows.items()
        }

    # -- accounting / checkpointing -----------------------------------------

    def bytes_report(self) -> Dict[str, Any]:
        """Host bytes at rest vs the f32-dense baseline the engines hold."""
        counter_bytes = int(self.round_counters.nbytes
                            + self.event_counters.nbytes)
        ef_rest = sum(v.at_rest_bytes() for v in self._ef.values())
        ef_fp32 = sum(4 * self.layout.num_clients * v.n
                      for v in self._ef.values())
        total = counter_bytes + ef_rest
        return dict(
            num_clients=self.layout.num_clients,
            num_shards=self.layout.num_shards,
            counter_bytes=counter_bytes,
            ef_at_rest_bytes=int(ef_rest),
            ef_fp32_bytes=int(ef_fp32),
            ef_fmt=self.ef_fmt.name if self.ef_fmt is not None else None,
            total_bytes=int(total),
            fp32_equivalent_bytes=int(counter_bytes + ef_fp32),
        )

    def describe_ef(self) -> Optional[Dict[str, Any]]:
        if not self._ef:
            return None
        return dict(
            fmt=self.ef_fmt.name if self.ef_fmt is not None else None,
            vars={name: list(v.shape) for name, v in self._ef.items()},
        )

    def state_tree(self) -> Dict[str, Any]:
        """Array state for :func:`repro.checkpoint.save_population_state`."""
        ef: Dict[str, Any] = {}
        for name, var in self._ef.items():
            if var.raw is not None:
                ef[name] = dict(raw=var.raw)
            else:
                ef[name] = dict(words=var.words, s=var.s, b=var.b)
        return dict(round_counters=self.round_counters,
                    event_counters=self.event_counters, ef=ef)

    def load_state_tree(self, tree: Dict[str, Any]) -> None:
        """Inverse of :meth:`state_tree` (layout already validated)."""
        self.round_counters = np.asarray(
            jax.device_get(tree["round_counters"]), np.int64
        )
        self.event_counters = np.asarray(
            jax.device_get(tree["event_counters"]), np.int64
        )
        for name, var in self._ef.items():
            entry = tree["ef"][name]
            if var.raw is not None:
                var.raw = np.asarray(jax.device_get(entry["raw"]), np.float32)
            else:
                var.words = np.asarray(jax.device_get(entry["words"]),
                                       np.uint32)
                var.s = np.asarray(jax.device_get(entry["s"]), np.float32)
                var.b = np.asarray(jax.device_get(entry["b"]), np.float32)


class ArrayCounters:
    """Mutable-mapping view over a dense per-client counter array.

    The async runtime (:class:`repro.federated.async_engine.AsyncRunner`)
    keeps ``{client_id: int}`` counter dicts; at 1M clients two Python
    dicts of boxed ints cost ~100 MB and serialize as multi-MB JSON.  This
    adapter exposes a :class:`PopulationStore` counter array through the
    same mapping surface (``c[cid]``, ``c[cid] = v``, ``.items()``), so the
    runner's event loop is unchanged while the state lives in one numpy
    array and checkpoints as such.
    """

    def __init__(self, arr: np.ndarray):
        self.arr = arr

    def __getitem__(self, cid) -> int:
        return int(self.arr[cid])

    def __setitem__(self, cid, value) -> None:
        self.arr[cid] = int(value)

    def __contains__(self, cid) -> bool:
        return 0 <= int(cid) < len(self.arr)

    def __len__(self) -> int:
        return len(self.arr)

    def __iter__(self):
        return iter(range(len(self.arr)))

    def get(self, cid, default=0) -> int:
        return self[cid] if cid in self else default

    def items(self):
        for c in range(len(self.arr)):
            yield c, int(self.arr[c])
