"""Serve-side scale driver: hot-swap under sustained query traffic (§14).

The "millions of users" half of the scale story: while the sharded runtime
lands training rounds, the serving fleet must keep answering queries and
ingest each new round's payload *without* recompiling or pausing.
:func:`run_serve_under_swap` drives a
:class:`repro.api.session.ServeSession` with a synthetic query stream,
periodically hot-swapping freshly-produced payloads, and measures what a
deployment cares about:

  * steady-state query latency (p50/p95 over the whole run),
  * swap wall time (payload decode + new storage materialized),
  * **swap stall** — the latency of the first query after each swap
    relative to the steady-state median (the jitted serve fns are reused
    across swaps, so this should be ~1x; a recompile would show up as a
    massive ratio, which the benchmark asserts against).

Used by ``benchmarks/population_scale.py`` (committed artifact) and the
``examples/population_scale.py`` CLI.
"""

from __future__ import annotations

import time
from typing import Any, Callable, Dict, Iterable, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.obs import null_span


def _percentile(xs: List[float], q: float) -> float:
    return float(np.percentile(np.asarray(xs, np.float64), q)) if xs else 0.0


def synthetic_token_batch(batch: int, prefill_len: int, vocab: int,
                          seed: int = 0) -> Dict[str, jax.Array]:
    """Deterministic token-model query batch (transformer-family inputs)."""
    rng = np.random.default_rng(seed)
    toks = rng.integers(0, vocab, size=(batch, prefill_len))
    return dict(tokens=jnp.asarray(toks, jnp.int32))


def run_serve_under_swap(
    session,
    payloads: Iterable[bytes],
    *,
    make_query: Callable[[int], Dict[str, jax.Array]],
    queries_per_swap: int = 8,
    batch: int = 1,
    max_len: int = 32,
    decode_steps: int = 4,
    warmup_queries: int = 2,
    obs=None,
) -> Dict[str, Any]:
    """Interleave query traffic with payload hot-swaps; return latency stats.

    ``payloads`` is the stream of wire payloads training produces (full or
    delta — :meth:`~repro.api.session.ServeSession.hot_swap` handles both);
    between consecutive swaps the driver issues ``queries_per_swap``
    generate calls built by ``make_query(query_index)``.  Every latency is
    wall time to *materialized tokens* (``block_until_ready``), so jit
    cache hits and misses are both visible.

    ``obs`` (DESIGN.md §15) records a wall span per query and per
    hot-swap plus one ``kind=serve`` record carrying the returned stats.
    """
    if queries_per_swap < 1:
        raise ValueError(
            f"queries_per_swap must be >= 1, got {queries_per_swap}"
        )
    q_ms: List[float] = []
    first_after_swap_ms: List[float] = []
    qi = 0

    def one_query(record: Optional[List[float]] = None) -> float:
        nonlocal qi
        cache = session.init_cache(batch, max_len)
        t0 = time.perf_counter()
        with null_span(obs, "query", index=qi):
            _, toks = session.generate(make_query(qi), cache, decode_steps)
            toks.block_until_ready()
        ms = (time.perf_counter() - t0) * 1e3
        qi += 1
        if record is not None:
            record.append(ms)
        return ms

    for _ in range(max(warmup_queries, 1)):  # compile prefill/decode once
        one_query()

    swaps_before = session.swaps
    for payload in payloads:
        for _ in range(queries_per_swap - 1):
            one_query(q_ms)
        with null_span(obs, "hot_swap", swap=int(session.swaps)):
            session.hot_swap(payload)
        first_after_swap_ms.append(one_query(q_ms))

    p50 = _percentile(q_ms, 50)
    stats = session.serve_stats()
    result = dict(
        queries=len(q_ms),
        swaps=int(session.swaps - swaps_before),
        query_ms_p50=p50,
        query_ms_p95=_percentile(q_ms, 95),
        swap_ms_mean=stats["swap_ms_mean"],
        swap_ms_max=stats["swap_ms_max"],
        first_query_after_swap_ms_p50=_percentile(first_after_swap_ms, 50),
        # swap stall: post-swap first-query latency vs steady-state median —
        # ~1x when the compiled serve fns survive the swap (they must)
        swap_stall_ratio=(
            _percentile(first_after_swap_ms, 50) / p50 if p50 > 0 else 0.0
        ),
    )
    if obs is not None:
        obs.record("serve", **result)
    return result
