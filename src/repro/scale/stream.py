"""Fixed-capacity cohort streaming (DESIGN.md §14).

The engine's round program materializes the whole cohort's stacked client
models at once — ``client_chunk`` bounds the *training* working set via
``lax.map``, but the program's inputs/outputs still scale with cohort size,
so "cohort = the population" is out of reach.  This module generalizes
that chunking across the program boundary: one compiled *partial-aggregate*
program of fixed client width ``capacity`` is fed arbitrarily many chunks,
each returning only the weighted **sums** (``Σ w·model``, ``Σ w``,
``Σ w·loss``), which the caller accumulates.  Peak live bytes are then
``O(capacity)`` per chunk plus one accumulator tree — independent of how
many clients stream through (asserted by the
:class:`repro.federated.accounting.StreamLedger` bound and measured in
``benchmarks/population_scale.py``).

Padding contract (same as the async runtime's padded train program): a
short final chunk repeats a real client id in the pad lanes with weight 0;
dead/pad rows are zeroed *before* the weighted sum, so a diverged dead
client (NaN update) cannot poison the partials — exactly the sync engine's
``finish`` guard.

``fused_agg=True`` mirrors the fused engine's transport semantics (§13):
each selected variable's chunk stack is transport-encoded
(:func:`repro.federated.engine.transport_encode_stacked` — one RNE
quantization of each upload) and decoded before entering the partial sum,
so the streamed result carries the same one-quantization-step error profile
as the fused flat round, while partials stay f32 (requantization happens
once, at the root combine in :mod:`repro.scale.hierarchy`).
"""

from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.omc import OMCConfig
from repro.core.store import CompressedVariable, decompress_tree, is_compressed
from repro.federated import engine, simulate
from repro.federated.simulate import SimConfig
from repro.federated.state import n_stack_axes
from repro.models.common import ParamSpec
from repro.obs import metrics as obs_metrics


def pad_chunk(client_ids, alive, capacity: int
              ) -> Tuple[np.ndarray, np.ndarray]:
    """Pad a (possibly short) chunk to the program's fixed width.

    Returns ``(cids int32[capacity], w float32[capacity])``: pad lanes
    repeat the chunk's first (real) client with weight 0 — they train
    redundantly and contribute exactly nothing to the partial sums.
    """
    ids = np.asarray(client_ids, np.int64)
    a = np.asarray(alive, bool)
    if ids.size == 0 or ids.size > capacity:
        raise ValueError(
            f"chunk must hold 1..{capacity} clients, got {ids.size}"
        )
    pad = capacity - ids.size
    cids = np.concatenate([ids, np.full((pad,), ids[0], np.int64)])
    w = np.concatenate([a.astype(np.float32), np.zeros((pad,), np.float32)])
    return cids.astype(np.int32), w


def make_stream_fn(family, cfg, specs, omc: OMCConfig, sim: SimConfig,
                   data_fn, capacity: int, *, strategy=None,
                   ste: bool = False, fused_agg: bool = False,
                   takes_residual: Optional[bool] = None,
                   collect_metrics: bool = False):
    """Build the compiled fixed-capacity partial-aggregate program.

    Jitted ``(storage, cids[cap], w[cap], round_index) ->
    (wsum_tree, wtot, loss_wsum)``; with error feedback
    (``takes_residual``) a residual-rows dict rides as a fifth argument
    and the updated rows come back as a fourth output (pad lanes recompute
    a real client's rows — the caller scatters only real, alive lanes, via
    :meth:`repro.scale.store.PopulationStore.scatter_ef`).

    The client body is the same
    :func:`repro.federated.simulate.make_client_fn` all three existing
    paths run; ``data_fn`` must be traceable ("vmap" data mode — the
    synthetic tasks and partitioned batch fns are).  One program instance
    serves every chunk of every shard of every round — capacity is the
    only shape.

    ``collect_metrics=True`` (DESIGN.md §15) appends a per-chunk metric
    *partial* bundle (``update_sq_wsum`` — the cohort's weighted update
    dispersion) as the program's final output; the caller folds chunk
    partials with :func:`repro.obs.metrics.fold_partial_bundles` and the
    round-level bundle is finished at the root combine.  Off by default:
    the program signature is unchanged and the main outputs are
    bit-identical either way (tier-1 gated in tests/test_obs.py).
    """
    if capacity < 1:
        raise ValueError(f"capacity must be >= 1, got {capacity}")
    if fused_agg and (strategy is not None or not omc.enabled):
        raise ValueError(
            "fused_agg=True needs OMC enabled and no zoo strategy "
            "(DESIGN.md §13/§14)"
        )
    if takes_residual is None:
        takes_residual = simulate.ef_lib.takes_residual(omc, strategy)
    one = simulate.make_client_fn(family, cfg, specs, omc, sim, strategy,
                                  ste, takes_residual=takes_residual)
    steps = jnp.arange(sim.local_steps)

    def partials(storage, stacked, losses, w):
        mask = w > 0

        def leaf(path, spec_t, srv, stack):
            x = jnp.where(
                mask.reshape((-1,) + (1,) * (stack.ndim - 1)), stack, 0.0
            )
            if fused_agg and is_compressed(srv):
                # transport-encode each upload row (§13): the one RNE step
                # the fused kernel's compressed-domain path applies
                ba = n_stack_axes(spec_t, srv.codes)
                codes, s, b = engine.transport_encode_stacked(
                    x, srv.fmt, omc.pvt, ba
                )
                if not omc.pvt:
                    s = s.reshape((-1,) + (1,) * (x.ndim - 1))
                    b = b.reshape((-1,) + (1,) * (x.ndim - 1))
                x = CompressedVariable(codes, s, b, srv.fmt).dequantize()
            wb = w.reshape((-1,) + (1,) * (x.ndim - 1))
            return (x * wb).sum(0)

        wsum = jax.tree_util.tree_map_with_path(
            leaf, specs, storage, stacked,
            is_leaf=lambda s: isinstance(s, ParamSpec),
        )
        loss_wsum = (jnp.where(mask, losses, 0.0) * w).sum()
        bundle = None
        if collect_metrics:
            masked = jax.tree_util.tree_map(
                lambda x: jnp.where(
                    mask.reshape((-1,) + (1,) * (x.ndim - 1)), x, 0.0
                ),
                stacked,
            )
            bundle = obs_metrics.chunk_partial_bundle(
                decompress_tree(storage), masked, w
            )
        return wsum, w.sum(), loss_wsum, bundle

    def train(storage, cids, round_index, ef_rows):
        server_f32 = decompress_tree(storage)
        batches = jax.vmap(
            lambda c: jax.vmap(lambda s: data_fn(c, round_index, s))(steps)
        )(cids)
        if takes_residual:
            return jax.vmap(
                lambda b, c, e: one(server_f32, b, round_index, c, e)
            )(batches, cids, ef_rows)
        return jax.vmap(
            lambda b, c: one(server_f32, b, round_index, c)
        )(batches, cids)

    if takes_residual:

        @jax.jit
        def stream_fn_ef(storage, cids, w, round_index, ef_rows):
            models, losses, rows = train(storage, cids, round_index, ef_rows)
            wsum, wtot, lw, bundle = partials(storage, models, losses, w)
            out = (wsum, wtot, lw, rows)
            return out + (bundle,) if collect_metrics else out

        return stream_fn_ef

    @jax.jit
    def stream_fn(storage, cids, w, round_index):
        models, losses = train(storage, cids, round_index, None)
        wsum, wtot, lw, bundle = partials(storage, models, losses, w)
        out = (wsum, wtot, lw)
        return out + (bundle,) if collect_metrics else out

    return stream_fn


def iter_chunks(positions: np.ndarray, capacity: int):
    """Yield fixed-capacity slices of a shard's cohort positions."""
    for i in range(0, len(positions), capacity):
        yield positions[i:i + capacity]
