"""qwen1.5-110b [dense]: 80L d=8192 64H (GQA kv=8) ff=49152 vocab=152064.

QKV bias.  [hf:Qwen/Qwen1.5-0.5B family; hf]  Full attention ->
``long_500k`` SKIPPED.
"""

from repro.models.transformer import TransformerConfig

ID = "qwen1.5-110b"
FAMILY = "transformer"
LONG_CONTEXT_OK = False


def config() -> TransformerConfig:
    return TransformerConfig(
        n_layers=80, d_model=8192, n_heads=64, n_kv_heads=8, d_ff=49152,
        vocab=152_064, head_dim=128, qkv_bias=True,
    )


def smoke_config() -> TransformerConfig:
    return TransformerConfig(
        n_layers=3, d_model=64, n_heads=8, n_kv_heads=2, d_ff=192,
        vocab=512, head_dim=8, qkv_bias=True,
    )
