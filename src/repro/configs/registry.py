"""Architecture registry: ``--arch <id>`` -> (config module, family)."""

from __future__ import annotations

from types import ModuleType
from typing import Dict

from . import (
    conformer_s,
    dbrx_132b,
    h2o_danube3_4b,
    internvl2_1b,
    mistral_nemo_12b,
    mixtral_8x7b,
    qwen1_5_110b,
    qwen2_5_3b,
    recurrentgemma_2b,
    seamless_m4t_medium,
    xlstm_350m,
)

_MODULES = [
    qwen2_5_3b, h2o_danube3_4b, qwen1_5_110b, mistral_nemo_12b,
    internvl2_1b, seamless_m4t_medium, dbrx_132b, mixtral_8x7b,
    xlstm_350m, recurrentgemma_2b, conformer_s,
]

ARCHS: Dict[str, ModuleType] = {m.ID: m for m in _MODULES}

# the 10 assigned dry-run architectures (conformer_s is benchmark-only)
ASSIGNED = [m.ID for m in _MODULES if m is not conformer_s]


def get_arch(arch_id: str) -> ModuleType:
    if arch_id not in ARCHS:
        raise KeyError(f"unknown arch {arch_id!r}; have {sorted(ARCHS)}")
    return ARCHS[arch_id]


def list_archs():
    return list(ARCHS)
