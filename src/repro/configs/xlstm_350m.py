"""xlstm-350m [ssm]: 24L d=1024 4H d_ff=0 vocab=50304 — sLSTM + mLSTM blocks.

xLSTM[7:1] ratio (1-in-8 blocks sLSTM).  Constant-size recurrent state ->
``long_500k`` RUNS.  [arXiv:2405.04517; unverified]
"""

from repro.models.xlstm import XLSTMConfig

ID = "xlstm-350m"
FAMILY = "xlstm"
LONG_CONTEXT_OK = True


def config() -> XLSTMConfig:
    return XLSTMConfig(
        n_layers=24, d_model=1024, n_heads=4, vocab=50_304, slstm_every=8,
    )


def smoke_config() -> XLSTMConfig:
    return XLSTMConfig(
        n_layers=5, d_model=32, n_heads=2, vocab=256, slstm_every=2,
    )
