"""qwen2.5-3b [dense]: 36L d=2048 16H (GQA kv=2) ff=11008 vocab=151936.

GQA with QKV bias, RoPE, tied embeddings.  [hf:Qwen/Qwen2.5-0.5B family; hf]
Full attention -> ``long_500k`` is SKIPPED (DESIGN.md §6).
"""

from repro.models.transformer import TransformerConfig

ID = "qwen2.5-3b"
FAMILY = "transformer"
LONG_CONTEXT_OK = False


def config() -> TransformerConfig:
    return TransformerConfig(
        n_layers=36, d_model=2048, n_heads=16, n_kv_heads=2, d_ff=11008,
        vocab=151_936, head_dim=128, qkv_bias=True, tie_embeddings=True,
        rope_theta=1_000_000.0,
    )


def smoke_config() -> TransformerConfig:
    return TransformerConfig(
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, d_ff=160,
        vocab=512, head_dim=16, qkv_bias=True, tie_embeddings=True,
    )
