"""mistral-nemo-12b [dense]: 40L d=5120 32H (GQA kv=8) ff=14336 vocab=131072.

128k-context full attention, head_dim 128 (projection dim 4096 != d_model).
[hf:mistralai/Mistral-Nemo-Base-2407; hf]  ``long_500k`` SKIPPED
(quadratic attention, unbounded KV).
"""

from repro.models.transformer import TransformerConfig

ID = "mistral-nemo-12b"
FAMILY = "transformer"
LONG_CONTEXT_OK = False


def config() -> TransformerConfig:
    return TransformerConfig(
        n_layers=40, d_model=5120, n_heads=32, n_kv_heads=8, d_ff=14336,
        vocab=131_072, head_dim=128, rope_theta=1_000_000.0,
    )


def smoke_config() -> TransformerConfig:
    return TransformerConfig(
        n_layers=2, d_model=96, n_heads=4, n_kv_heads=2, d_ff=192,
        vocab=512, head_dim=16,
    )
