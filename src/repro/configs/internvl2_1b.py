"""internvl2-1b [vlm]: 24L d=896 14H (GQA kv=2) ff=4864 vocab=151655.

InternViT frontend + Qwen2-0.5B LM backbone.  The ViT is a STUB per the
brief: ``input_specs`` provides 1024 precomputed patch embeddings prepended
to the token stream (``prefix_embeds``).  [arXiv:2404.16821; hf]
Full attention -> ``long_500k`` SKIPPED.
"""

from repro.models.transformer import TransformerConfig

ID = "internvl2-1b"
FAMILY = "vlm"
LONG_CONTEXT_OK = False
N_PATCHES = 1024


def config() -> TransformerConfig:
    return TransformerConfig(
        n_layers=24, d_model=896, n_heads=14, n_kv_heads=2, d_ff=4864,
        vocab=151_808,  # padded from 151655 to a 256-multiple (embedding sharding) head_dim=64, qkv_bias=True, tie_embeddings=True,
        prefix_embeds=N_PATCHES,
    )


def smoke_config() -> TransformerConfig:
    return TransformerConfig(
        n_layers=2, d_model=56, n_heads=7, n_kv_heads=1, d_ff=128,
        vocab=512, head_dim=8, qkv_bias=True, tie_embeddings=True,
        prefix_embeds=8,
    )
