"""conformer_s — the paper's own model family (streaming Conformer, §3.1).

Used by the paper-table benchmarks at reduced scale (CPU-trainable); the
full-size streaming Conformer is ~130M params (17L d=512 8H).  Not part of
the 40 assigned dry-run cells.
"""

from repro.models.conformer import ConformerConfig

ID = "conformer_s"
FAMILY = "conformer"
LONG_CONTEXT_OK = False


def config() -> ConformerConfig:
    """~130M streaming Conformer (paper's production-grade variant)."""
    return ConformerConfig(
        n_layers=17, d_model=512, n_heads=8, d_ff=2048, n_classes=1024,
        d_in=80, conv_kernel=32, window=128, causal_conv=True,
    )


def smoke_config() -> ConformerConfig:
    """CPU-benchmark scale (paper-table reproductions train this)."""
    return ConformerConfig(
        n_layers=2, d_model=48, n_heads=4, d_ff=96, n_classes=32,
        d_in=16, conv_kernel=4, window=16, causal_conv=True,
    )
