"""h2o-danube-3-4b [dense]: 24L d=3840 32H (GQA kv=8) ff=10240 vocab=32000.

llama+mistral mix with sliding-window attention (window 4096, uniform).
[arXiv:2401.16818; unverified]  SWA bounds the decode cache ->
``long_500k`` RUNS (ring-buffer KV of width 4096).
"""

from repro.models.transformer import TransformerConfig

ID = "h2o-danube-3-4b"
FAMILY = "transformer"
LONG_CONTEXT_OK = True


def config() -> TransformerConfig:
    return TransformerConfig(
        n_layers=24, d_model=3840, n_heads=32, n_kv_heads=8, d_ff=10240,
        vocab=32_000, head_dim=120, window=4096,
    )


def smoke_config() -> TransformerConfig:
    return TransformerConfig(
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, d_ff=160,
        vocab=512, head_dim=16, window=16,
    )
