"""mixtral-8x7b [moe]: 32L d=4096 32H (GQA kv=8) ff=14336 vocab=32000, 8e top-2.

SWA window 4096 -> ``long_500k`` RUNS (ring-buffer KV).  On a 16-way model
axis each expert is co-owned by 2 shards splitting the FFN dim
(``ep_partitions=2``, set by the launcher).  [arXiv:2401.04088; hf]
"""

from repro.models.moe import MoEConfig

ID = "mixtral-8x7b"
FAMILY = "moe"
LONG_CONTEXT_OK = True


def config() -> MoEConfig:
    return MoEConfig(
        n_layers=32, d_model=4096, n_heads=32, n_kv_heads=8, d_ff=14336,
        vocab=32_000, head_dim=128, n_experts=8, top_k=2, window=4096,
    )


def smoke_config() -> MoEConfig:
    return MoEConfig(
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, d_ff=96,
        vocab=512, head_dim=16, n_experts=4, top_k=2, capacity_factor=8.0, window=16,
    )
