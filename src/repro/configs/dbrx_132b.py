"""dbrx-132b [moe]: 40L d=6144 48H (GQA kv=8) ff=10752 vocab=100352, 16e top-4.

Fine-grained MoE, 16 experts top-4 -> expert-parallel over the 16-way model
axis (1 expert per shard).  [hf:databricks/dbrx-base; unverified]
Full attention -> ``long_500k`` SKIPPED.
"""

from repro.models.moe import MoEConfig

ID = "dbrx-132b"
FAMILY = "moe"
LONG_CONTEXT_OK = False


def config() -> MoEConfig:
    return MoEConfig(
        n_layers=40, d_model=6144, n_heads=48, n_kv_heads=8, d_ff=10752,
        vocab=100_352, head_dim=128, n_experts=16, top_k=4,
    )


def smoke_config() -> MoEConfig:
    return MoEConfig(
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, d_ff=96,
        vocab=512, head_dim=16, n_experts=4, top_k=2, capacity_factor=8.0,
    )
