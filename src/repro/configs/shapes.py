"""The four assigned input-shape cells for every LM-family architecture."""

from __future__ import annotations

import dataclasses
from typing import Dict


@dataclasses.dataclass(frozen=True)
class Shape:
    name: str
    kind: str  # "train" | "prefill" | "decode"
    seq_len: int
    global_batch: int
    sub_quadratic_only: bool = False  # long_500k: skip for full-attention archs


SHAPES: Dict[str, Shape] = {
    "train_4k": Shape("train_4k", "train", 4_096, 256),
    "prefill_32k": Shape("prefill_32k", "prefill", 32_768, 32),
    "decode_32k": Shape("decode_32k", "decode", 32_768, 128),
    "long_500k": Shape("long_500k", "decode", 524_288, 1, sub_quadratic_only=True),
}
