"""recurrentgemma-2b [hybrid]: 26L d=2560 10H (MQA kv=1) ff=7680 vocab=256000.

RG-LRU + local attention in a (rec, rec, attn) 1:2 pattern; window 2048;
O(1) recurrent state + O(window) attention cache -> ``long_500k`` RUNS.
[arXiv:2402.19427; hf]
"""

from repro.models.griffin import GriffinConfig

ID = "recurrentgemma-2b"
FAMILY = "griffin"
LONG_CONTEXT_OK = True


def config() -> GriffinConfig:
    return GriffinConfig(
        n_layers=26, d_model=2560, n_heads=10, n_kv_heads=1, d_ff=7680,
        vocab=256_000, lru_width=2560, window=2048, pattern_period=3,
    )


def smoke_config() -> GriffinConfig:
    return GriffinConfig(
        n_layers=5, d_model=40, n_heads=2, n_kv_heads=1, d_ff=96,
        vocab=256, lru_width=40, window=16, pattern_period=3,
    )
