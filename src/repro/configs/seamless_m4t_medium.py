"""seamless-m4t-medium [audio]: 12+12L d=1024 16H (MHA kv=16) ff=4096 vocab=256206.

Encoder-decoder; the audio frontend is a STUB (``input_specs`` provides
precomputed frame embeddings).  Decoder length = seq_len // 4 (speech-to-
text ratio, DESIGN.md §6).  [arXiv:2308.11596; hf]  Full attention ->
``long_500k`` SKIPPED.
"""

from repro.models.encdec import EncDecConfig

ID = "seamless-m4t-medium"
FAMILY = "encdec"
LONG_CONTEXT_OK = False


def config() -> EncDecConfig:
    return EncDecConfig(
        n_enc_layers=12, n_dec_layers=12, d_model=1024, n_heads=16,
        n_kv_heads=16, d_ff=4096,
        vocab=256_256,  # padded from 256206 to a 256-multiple (embedding sharding) dec_ratio=4,
    )


def smoke_config() -> EncDecConfig:
    return EncDecConfig(
        n_enc_layers=2, n_dec_layers=2, d_model=64, n_heads=4,
        n_kv_heads=4, d_ff=128, vocab=512, dec_ratio=4,
    )
