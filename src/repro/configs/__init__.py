"""Assigned-architecture configs (``--arch <id>``) + the paper's Conformer."""

from .registry import ARCHS, get_arch, list_archs
from .shapes import SHAPES, Shape
