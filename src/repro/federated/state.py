"""Server training state: compressed-at-rest parameters + optimizer state.

``init_state`` applies the OMC policy to a freshly-initialized f32 param
tree: selected variables become ``CompressedVariable`` (this is the paper's
storage model — no persistent f32 master exists between rounds; the decoded
values are transient).  The number of PVT batch axes per leaf (stacked
layers / experts) is derived from the ParamSpec: stacked axes are exactly
the leading axes not covered by the spec.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp

from repro.core import packing
from repro.core.formats import FloatFormat
from repro.core.omc import OMCConfig
from repro.core.policy import path_str
from repro.core.store import CompressedVariable, compress_variable, is_compressed
from repro.models.common import ParamSpec


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class TrainState:
    params: Any  # pytree: CompressedVariable | f32 leaves
    opt_state: Any
    round: jax.Array  # [] int32
    rng: jax.Array  # PRNGKey


def n_stack_axes(spec: ParamSpec, leaf) -> int:
    """Leading stacked axes = rank beyond what the spec describes."""
    return max(leaf.ndim - len(spec.storage), 0)


def effective_ndim(spec: ParamSpec, leaf) -> int:
    return leaf.ndim - n_stack_axes(spec, leaf)


def selected(omc: OMCConfig, path: str, spec: ParamSpec, leaf) -> bool:
    """Weights-only policy with stacked-axis awareness (paper §2.4)."""
    if not omc.enabled:
        return False
    pol = omc.policy
    if not hasattr(leaf, "shape") or not jnp.issubdtype(leaf.dtype, jnp.floating):
        return False
    if pol.weights_only and effective_ndim(spec, leaf) < pol.min_ndim:
        return False
    if leaf.size < pol.min_size:
        return False
    import re
    for pat in pol.exclude_re:
        if re.search(pat, path):
            return False
    if pol.include_re is not None:
        return any(re.search(p, path) for p in pol.include_re)
    return True


def compress_params(params, specs, omc: OMCConfig, fast: bool = True):
    """f32 tree -> storage tree (selected leaves CompressedVariable)."""

    def f(path, spec, leaf):
        if selected(omc, path_str(path), spec, leaf):
            return compress_variable(
                leaf, omc.fmt, pvt=omc.pvt, batch_axes=n_stack_axes(spec, leaf),
                fast=fast,
            )
        return leaf

    return jax.tree_util.tree_map_with_path(
        f, specs, params, is_leaf=lambda s: isinstance(s, ParamSpec)
    )


def init_state(key, family, cfg, omc: OMCConfig, server_opt) -> TrainState:
    """Initialize params (f32), compress per policy, set up the server opt."""
    params = family.init(key, cfg)
    specs = family.param_specs(cfg)
    storage = compress_params(params, specs, omc) if omc.enabled else params
    opt_state = server_opt.init(
        jax.tree_util.tree_map(
            lambda v: jnp.zeros(v.codes.shape, jnp.float32) if is_compressed(v) else v,
            storage,
            is_leaf=is_compressed,
        )
    )
    return TrainState(
        params=storage,
        opt_state=opt_state,
        round=jnp.zeros((), jnp.int32),
        rng=jax.random.fold_in(key, 0xF3D),
    )


# ---------------------------------------------------------------------------
# byte accounting over the *actual* state (backs §3.4-style measured tables)
# ---------------------------------------------------------------------------


def state_bytes_report(params) -> Dict[str, Any]:
    total = dict(fp32_bytes=0, container_bytes=0, packed_bytes=0,
                 num_params=0, num_compressed=0)

    def visit(leaf):
        if is_compressed(leaf):
            n = int(leaf.codes.size)
            total["num_params"] += n
            total["num_compressed"] += n
            total["fp32_bytes"] += 4 * n
            total["container_bytes"] += (
                n * leaf.fmt.container_bytes_per_value + 8 * int(leaf.s.size)
            )
            total["packed_bytes"] += (
                packing.packed_bytes(n, leaf.fmt) + 8 * int(leaf.s.size)
            )
        elif hasattr(leaf, "size") and jnp.issubdtype(leaf.dtype, jnp.floating):
            n = int(leaf.size)
            total["num_params"] += n
            total["fp32_bytes"] += 4 * n
            total["container_bytes"] += 4 * n
            total["packed_bytes"] += 4 * n

    for leaf in jax.tree_util.tree_leaves(params, is_leaf=is_compressed):
        visit(leaf)
    total["container_ratio"] = total["container_bytes"] / max(total["fp32_bytes"], 1)
    total["packed_ratio"] = total["packed_bytes"] / max(total["fp32_bytes"], 1)
    return total
