"""Vectorized heterogeneous-cohort simulation engine (DESIGN.md §9).

The reference loop (:mod:`repro.federated.simulate`) runs one jitted client
at a time — perfect for auditing paper numerics, quadratically painful for
production-scale cohorts.  This module executes the whole round — built
from the *same* single-client round body
(:func:`repro.federated.simulate.make_client_fn`) — as ONE compiled XLA
program (:func:`make_round_fn`):

  * **stacked client states** — client ids, per-client RNG-derived PPQ mask
    bits, and local batches all carry a leading cohort axis; the client
    update is ``vmap``-ped over it (optionally chunked through ``lax.map``
    — a scan of vmapped blocks — to bound peak memory at huge cohorts),
  * **heterogeneous device tiers** — a cohort may mix bitwidths (e.g.
    S1E3M7 / S1E4M3 / f32 clients).  Tier populations are disjoint
    (round-robin over client ids) and the server samples a fixed per-tier
    quota each round (stratified sampling — how production FL hits per-tier
    report goals), so each tier is a static-shape segment of the round
    program and nothing recompiles as cohort composition varies,
  * **wire-byte accounting** — per-round download/upload bytes from the
    shared :mod:`repro.federated.accounting` table, reconciled exactly
    against :mod:`repro.api.codecs` payload sizes.

Equivalence contract (tested in ``tests/test_engine.py``): with a single
default tier, the engine consumes the same cohort sample, survival mask,
PPQ masks, and data stream as the reference loop; client models differ only
by batched-op reassociation (documented tolerance), and wire-byte
accounting matches the loop path bit-for-bit.  See DESIGN.md §9 for the
layout and the loop-vs-vectorized decision guide.

With ``fused_agg=True`` the server half of the round runs in the compressed
domain: client uploads are transport-encoded and aggregated by the fused
Pallas dequant→masked-weighted-accumulate→requant kernel without ever
materializing f32 cohort state for selected variables — contract and gating
rules in DESIGN.md §13.

Every round here is still a hard barrier — the program returns when the
whole cohort has trained.  When the fleet is straggler-dominated (heavy-tail
latency, diurnal availability), use the event-driven non-barrier runtime
:mod:`repro.federated.async_engine` (DESIGN.md §10), which batches its
local training through the same ``make_client_fn`` body.

Both this engine and the async runtime still stack the whole cohort in one
program, so cohort size is bounded by device memory.  For populations far
beyond that — 100k–1M simulated clients streamed through fixed memory with
two-level tree aggregation over a :class:`repro.scale.store.ShardLayout` —
use :mod:`repro.scale` (DESIGN.md §14), which chunks the same client body
through :func:`repro.scale.stream.make_stream_fn` and reuses this module's
:func:`mask_dead_rows` / :func:`apply_server_step` so the server algebra
cannot drift between the flat and treed paths.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.formats import FloatFormat, value_quantize, encode
from repro.core.omc import OMCConfig
from repro.core.partial import ppq_mask
from repro.core.policy import path_str
from repro.core.pvt import pvt_solve_fast
from repro.core.store import CompressedVariable, compress_variable, \
    decompress_tree, is_compressed
from repro.kernels import ops as kernel_ops
from repro.models.common import ParamSpec
from repro.obs import metrics as obs_metrics
from repro.obs import null_span

from . import accounting
from . import cohort as cohort_lib
from . import simulate
from .simulate import SimConfig
from .state import compress_params, n_stack_axes


# ---------------------------------------------------------------------------
# Device profiles — per-client bitwidth tiers
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class DeviceProfile:
    """One device tier: how its clients quantize compute and transport.

    ``fmt`` / ``quantize_fraction`` override the server's base
    :class:`OMCConfig` for clients of this tier; ``None`` inherits.  A tier
    with the identity format and fraction 1.0 runs f32 end to end (its
    uploads travel uncompressed — the "new flagship phone" tier).
    """

    name: str = "default"
    fmt: Optional[str] = None  # e.g. "S1E4M3"; None -> server format
    quantize_fraction: Optional[float] = None  # None -> server fraction

    def resolve(self, base: OMCConfig) -> OMCConfig:
        kw: Dict[str, Any] = {}
        if self.fmt is not None:
            kw["fmt"] = FloatFormat.parse(self.fmt)
        if self.quantize_fraction is not None:
            kw["quantize_fraction"] = float(self.quantize_fraction)
        return dataclasses.replace(base, **kw) if kw else base


#: Ready-made tiers for the scenario cookbook (README) and benchmarks.
PROFILES: Dict[str, DeviceProfile] = {
    "default": DeviceProfile(),
    "f32": DeviceProfile("f32", fmt="S1E8M23", quantize_fraction=1.0),
    "s1e3m7": DeviceProfile("s1e3m7", fmt="S1E3M7"),
    "s1e4m3": DeviceProfile("s1e4m3", fmt="S1E4M3"),
    "s1e4m14": DeviceProfile("s1e4m14", fmt="S1E4M14"),
}


def profile(name: str) -> DeviceProfile:
    try:
        return PROFILES[name]
    except KeyError:
        raise KeyError(
            f"unknown device profile {name!r}; known: {sorted(PROFILES)}"
        ) from None


# ---------------------------------------------------------------------------
# Cohort spec — plan + tiers + per-tier quotas
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class CohortSpec:
    """A cohort plan plus its device-tier composition.

    With no ``tiers`` the cohort is homogeneous under the server's OMC
    config and the engine reproduces the reference loop's sampling exactly.
    With tiers, the population is partitioned round-robin (client ``i``
    belongs to tier ``i % n_tiers``) and each round samples ``quotas[t]``
    clients from tier ``t``'s population without replacement — every tier
    is a static-shape segment of the single compiled round program.
    """

    plan: cohort_lib.CohortPlan
    tiers: Tuple[DeviceProfile, ...] = ()
    quotas: Optional[Tuple[int, ...]] = None  # default: even split
    client_chunk: Optional[int] = None  # lax.map chunk; None -> pure vmap

    def __post_init__(self):
        if self.tiers:
            n = len(self.tiers)
            if self.quotas is None:
                base, rem = divmod(self.plan.cohort_size, n)
                object.__setattr__(
                    self, "quotas",
                    tuple(base + (1 if t < rem else 0) for t in range(n)),
                )
            if len(self.quotas) != n:
                raise ValueError("quotas must have one entry per tier")
            if sum(self.quotas) != self.plan.cohort_size:
                raise ValueError(
                    f"quotas {self.quotas} must sum to cohort_size "
                    f"{self.plan.cohort_size}"
                )
            for t, q in enumerate(self.quotas):
                pop = self.tier_population(t).shape[0]
                if q > pop:
                    raise ValueError(
                        f"tier {t} quota {q} exceeds its population {pop}"
                    )
        elif self.quotas is not None:
            raise ValueError("quotas given but no tiers")
        for q in self.group_sizes:
            # mirror the runtime gate: a segment is only chunked when it is
            # larger than the chunk (smaller quotas run as pure vmap)
            if self.client_chunk and q > self.client_chunk and (
                q % self.client_chunk
            ):
                raise ValueError(
                    f"client_chunk {self.client_chunk} must divide tier "
                    f"quotas larger than it (got {q})"
                )

    @property
    def n_tiers(self) -> int:
        return max(len(self.tiers), 1)

    @property
    def is_hetero(self) -> bool:
        return bool(self.tiers)

    @property
    def group_sizes(self) -> Tuple[int, ...]:
        return self.quotas if self.is_hetero else (self.plan.cohort_size,)

    def tier_population(self, t: int) -> np.ndarray:
        return np.arange(t, self.plan.num_clients, self.n_tiers,
                         dtype=np.int32)

    def tier_omcs(self, base: OMCConfig) -> List[OMCConfig]:
        tiers = self.tiers or (DeviceProfile(),)
        return [p.resolve(base) for p in tiers]


def sample_tiered_cohort(
    key: jax.Array, spec: CohortSpec, round_index
) -> List[jax.Array]:
    """Per-tier int32 id arrays (concat order = survival-mask order).

    Homogeneous specs defer to :func:`repro.federated.cohort.sample_cohort`
    so the engine sees the identical cohort the reference loop would.
    """
    if not spec.is_hetero:
        return [cohort_lib.sample_cohort(key, spec.plan, round_index)]
    k = jax.random.fold_in(key, round_index)
    out = []
    for t, q in enumerate(spec.quotas):
        pop = jnp.asarray(spec.tier_population(t))
        perm = jax.random.permutation(
            jax.random.fold_in(k, 0x7E0 + t), pop.shape[0]
        )
        out.append(pop[perm[:q]].astype(jnp.int32))
    return out


# ---------------------------------------------------------------------------
# Compressed-domain (fused) aggregation — DESIGN.md §13
# ---------------------------------------------------------------------------


def fused_aggregation_supported(spec: "CohortSpec", omc: OMCConfig,
                                strategy=None) -> bool:
    """When the fused compressed-domain server path can be picked (§13).

    Requires a homogeneous cohort (mixed tiers stack containers of different
    dtypes), OMC enabled, and no zoo strategy (strategies define their own
    decode/aggregate algebra, incl. error feedback).
    """
    return omc.enabled and not spec.is_hetero and strategy is None


def transport_encode_stacked(stacked_leaf, fmt: FloatFormat, pvt: bool,
                             batch_axes: int):
    """Encode a [C, ...] stack of client uploads to transport form.

    The wire-path math of ``compress_variable(..., fast=True)`` per client
    row, batched: (codes, s, b) with the client axis leading.  Dead-client
    rows may hold garbage — they encode to garbage codes/scalars, and the
    fused kernel's ``where(w > 0, ·, 0)`` guard discards them exactly.
    """
    vq = value_quantize(stacked_leaf, fmt)
    if pvt:
        s, b = pvt_solve_fast(stacked_leaf, vq, batch_axes + 1)
    else:
        c = stacked_leaf.shape[0]
        s = jnp.ones((c,), jnp.float32)
        b = jnp.zeros((c,), jnp.float32)
    return encode(vq, fmt, quantize=False), s, b


# ---------------------------------------------------------------------------
# Server-side round algebra — shared with the sharded runtime (repro.scale)
# ---------------------------------------------------------------------------


def mask_dead_rows(stacked, alive):
    """Zero dead clients' rows in a ``[C, ...]`` stack (NaN-safe FedAvg).

    The reference loop never computes dropped clients; the engine computes
    them and weights them 0.  ``0·x`` annihilates exactly for finite x, but
    a diverged dead client (non-finite update) would poison the mean as
    ``0·inf = NaN`` — zero dead entries outright so the paths stay
    equivalent even when clients blow up.  The streamed partial-aggregate
    program (:mod:`repro.scale.stream`) applies the identical guard before
    its weighted sums.
    """
    return jax.tree_util.tree_map(
        lambda x: jnp.where(
            jnp.asarray(alive).reshape((-1,) + (1,) * (x.ndim - 1)), x, 0.0
        ),
        stacked,
    )


def apply_server_step(server_f32, mean_model, specs, omc: OMCConfig,
                      server_lr: float):
    """The server half of every unfused round, in one place.

    Interpolate toward the cohort mean with ``server_lr`` and re-compress
    under the policy — used verbatim by this engine's ``finish`` and by
    the sharded root combine (:func:`repro.scale.hierarchy.make_root_fn`),
    so flat and tree-aggregated rounds share one requantization step and
    one interpolation formula by construction.
    """
    new_f32 = jax.tree_util.tree_map(
        lambda old, new: old + server_lr * (new - old),
        server_f32, mean_model,
    )
    return compress_params(new_f32, specs, omc) if omc.enabled else new_f32


# ---------------------------------------------------------------------------
# The compiled round: data gen + vmapped clients + aggregation + re-compress,
# all tiers, one XLA program.
# ---------------------------------------------------------------------------


def _run_cohort(one, server_f32, batches, round_index, ids,
                client_chunk: Optional[int], ef_rows=None):
    """vmap (or chunked lax.map) of the client body over one tier segment.

    ``ef_rows`` (a ``{name: [q, *shape]}`` dict of error-feedback residual
    rows, DESIGN.md §12) switches to the residual-threading client
    signature and adds the updated rows as a third output."""
    if ef_rows is not None:
        run3 = lambda b, c, e: one(server_f32, b, round_index, c, e)
        if client_chunk and ids.shape[0] > client_chunk:
            g = ids.shape[0] // client_chunk
            bs = jax.tree_util.tree_map(
                lambda x: x.reshape((g, client_chunk) + x.shape[1:]), batches
            )
            cs = ids.reshape(g, client_chunk)
            es = jax.tree_util.tree_map(
                lambda x: x.reshape((g, client_chunk) + x.shape[1:]), ef_rows
            )
            models, losses, rows = jax.lax.map(
                lambda xs: jax.vmap(run3)(*xs), (bs, cs, es)
            )
            unchunk = lambda x: x.reshape((-1,) + x.shape[2:])
            return (jax.tree_util.tree_map(unchunk, models),
                    losses.reshape(-1),
                    jax.tree_util.tree_map(unchunk, rows))
        return jax.vmap(run3)(batches, ids, ef_rows)
    run = lambda b, c: one(server_f32, b, round_index, c)
    if client_chunk and ids.shape[0] > client_chunk:
        # scan of vmapped blocks: same results, bounded live memory
        g = ids.shape[0] // client_chunk
        bs = jax.tree_util.tree_map(
            lambda x: x.reshape((g, client_chunk) + x.shape[1:]), batches
        )
        cs = ids.reshape(g, client_chunk)
        models, losses = jax.lax.map(
            lambda xs: jax.vmap(run)(*xs), (bs, cs)
        )
        models = jax.tree_util.tree_map(
            lambda x: x.reshape((-1,) + x.shape[2:]), models
        )
        return models, losses.reshape(-1)
    return jax.vmap(run)(batches, ids)


def make_round_fn(
    family,
    cfg,
    specs,
    omc: OMCConfig,
    sim: SimConfig,
    spec: CohortSpec,
    data_fn: Callable[[Any, Any, Any], Any],
    data_mode: str = "vmap",
    strategy=None,
    ste: bool = False,
    fused_agg: bool = False,
    collect_metrics: bool = False,
):
    """Build the engine's compiled round.

    ``(storage, ids_per_tier, alive, round_index) ->
    (new_storage, mean_loss, n_alive)`` — the whole round is ONE XLA
    program: server decompress, per-tier data generation, the ``vmap``-ped
    client updates, zero-weight FedAvg aggregation, the server
    interpolation step, and the re-compress of the new state.  The
    reference loop runs the identical ops eagerly at client granularity;
    here nothing leaves the runtime between rounds, which is where the
    order-of-magnitude throughput gap at large cohorts comes from
    (``benchmarks/cohort_scale.py``).

    ``data_mode="vmap"`` traces ``data_fn`` inside the program (it must be
    a pure function of traced ``(client_id, round_index, step)`` — the
    synthetic tasks and partitioned batch fns are); ``"host"`` takes
    pre-stacked per-tier batches as an extra argument, for data sources
    that cannot be traced (:func:`run_round_vectorized` stacks them).

    ``strategy``/``ste`` train under a zoo compression strategy
    (DESIGN.md §12): every tier's client body applies the strategy's qdq
    under its own tier OMC config.  When the strategy threads an
    error-feedback residual, the round program takes the population
    residual state ``ef`` as a final argument and returns the updated
    state as a fourth output — gather, per-client update, and the
    alive-masked scatter all stay inside the one compiled program.

    ``fused_agg=True`` aggregates selected variables entirely in the
    compressed domain (DESIGN.md §13): client uploads are transport-encoded
    and the server round runs the fused dequant→masked-weighted-accumulate→
    requant kernel (``repro.kernels.agg`` via ``kernels.ops``) — the server
    never materializes f32 cohort state for those variables.  Requires
    :func:`fused_aggregation_supported`; results match the unfused path
    within one quantization step with byte-identical wire ledgers
    (gated in tests/test_engine.py).

    ``collect_metrics=True`` appends the cohort mean the round already
    computes as the program's **final** output (``None`` on the fused
    path, where no f32 mean exists); :func:`run_round_vectorized` builds
    the metric bundle (DESIGN.md §15) eagerly on the host from that mean
    plus the round's outputs, so the compiled round math is identical
    with metrics on or off — main outputs stay bit-identical (gated in
    tests/test_obs.py).  Off by default so the program signature is
    unchanged for every existing caller.
    """
    if data_mode not in ("vmap", "host"):
        raise ValueError(f"data_mode must be 'vmap' or 'host', got {data_mode!r}")
    if fused_agg and not fused_aggregation_supported(spec, omc, strategy):
        raise ValueError(
            "fused_agg=True needs a homogeneous cohort, OMC enabled, and no "
            "zoo strategy (DESIGN.md §13)"
        )
    takes_ef = simulate.ef_lib.takes_residual(omc, strategy)
    ones = [
        simulate.make_client_fn(family, cfg, specs, omc_t, sim,
                                strategy, ste, takes_residual=takes_ef)
        for omc_t in spec.tier_omcs(omc)
    ]
    steps = jnp.arange(sim.local_steps)

    def finish(server_f32, stacked, loss_c, alive):
        w = alive.astype(jnp.float32)
        stacked = mask_dead_rows(stacked, alive)
        loss_c = jnp.where(alive, loss_c, 0.0)
        mean_model = cohort_lib.aggregate_weighted(stacked, w)
        new_storage = apply_server_step(server_f32, mean_model, specs, omc,
                                        sim.server_lr)
        n_alive = w.sum()
        loss = (loss_c * w).sum() / jnp.maximum(n_alive, 1.0)
        # collect_metrics: expose the cohort mean (already computed above)
        # so the host can build the metric bundle eagerly AFTER the round —
        # bundle math never runs inside this program, so the main outputs
        # compile identically with metrics on or off (DESIGN.md §15)
        aux = mean_model if collect_metrics else None
        return new_storage, loss, n_alive, aux

    def finish_fused(storage, stacked, loss_c, alive):
        # Compressed-domain server round (§13): selected variables never
        # exist as an f32 cohort stack on the server — each client row is
        # transport-encoded and the fused kernel aggregates codes directly.
        w = alive.astype(jnp.float32)
        loss_c = jnp.where(alive, loss_c, 0.0)
        n_alive = w.sum()
        loss = (loss_c * w).sum() / jnp.maximum(n_alive, 1.0)

        def f(path, spec_t, srv, stack):
            if is_compressed(srv):
                ba = n_stack_axes(spec_t, srv.codes)
                codes_c, s_c, b_c = transport_encode_stacked(
                    stack, srv.fmt, omc.pvt, ba
                )
                new_codes, s, b = kernel_ops.fused_aggregate(
                    srv.codes, srv.s, srv.b, codes_c, s_c, b_c, w,
                    sim.server_lr, srv.fmt, batch_axes=ba, pvt=omc.pvt,
                )
                return CompressedVariable(new_codes, s, b, srv.fmt)
            # Unselected leaves keep the classic f32 mean + interpolation.
            x = jnp.where(
                alive.reshape((-1,) + (1,) * (stack.ndim - 1)), stack, 0.0
            )
            mean = cohort_lib.aggregate_weighted(x, w)
            return srv + sim.server_lr * (mean - srv)

        new_storage = jax.tree_util.tree_map_with_path(
            f, specs, storage, stacked,
            is_leaf=lambda s: isinstance(s, ParamSpec),
        )
        # compressed-domain round: no f32 cohort mean exists — the host-side
        # bundle degrades to the update norm (DESIGN.md §15)
        return new_storage, loss, n_alive, None

    def body(storage, ids_per_tier, batches_per_tier, alive, round_index, ef):
        server_f32 = decompress_tree(storage)
        models, losses, rows = [], [], []
        for t, (one, ids_t) in enumerate(zip(ones, ids_per_tier)):
            if batches_per_tier is None:
                batches = jax.vmap(
                    lambda c: jax.vmap(
                        lambda s: data_fn(c, round_index, s)
                    )(steps)
                )(ids_t)
            else:
                batches = batches_per_tier[t]
            if takes_ef:
                rows_t = {k: v[ids_t] for k, v in ef.items()}
                m, l, nr = _run_cohort(one, server_f32, batches, round_index,
                                       ids_t, spec.client_chunk, rows_t)
                rows.append(nr)
            else:
                m, l = _run_cohort(one, server_f32, batches, round_index,
                                   ids_t, spec.client_chunk)
            models.append(m)
            losses.append(l)
        stacked = jax.tree_util.tree_map(
            lambda *xs: jnp.concatenate(xs, 0), *models
        )
        if fused_agg:
            new_storage, loss, n_alive, aux = finish_fused(
                storage, stacked, jnp.concatenate(losses), alive
            )
        else:
            new_storage, loss, n_alive, aux = finish(
                server_f32, stacked, jnp.concatenate(losses), alive
            )
        out: Tuple[Any, ...] = (new_storage, loss, n_alive)
        if takes_ef:
            # scatter the cohort's updated residual rows back into the
            # population state; dead clients keep their previous residual
            # (they never uploaded — the loop path skips them entirely)
            ids_all = jnp.concatenate(list(ids_per_tier), 0)
            new_ef = {}
            for k, old in ef.items():
                nr = jnp.concatenate([r[k] for r in rows], 0)
                keep = alive.reshape((-1,) + (1,) * (nr.ndim - 1))
                new_ef[k] = old.at[ids_all].set(
                    jnp.where(keep, nr, old[ids_all])
                )
            out = out + (new_ef,)
        if collect_metrics:
            out = out + (aux,)
        return out

    if data_mode == "vmap":
        if takes_ef:

            @jax.jit
            def round_fn_ef(storage, ids_per_tier, alive, round_index, ef):
                return body(storage, ids_per_tier, None, alive, round_index,
                            ef)

            return round_fn_ef

        @jax.jit
        def round_fn(storage, ids_per_tier, alive, round_index):
            return body(storage, ids_per_tier, None, alive, round_index, None)

        return round_fn

    if takes_ef:

        @jax.jit
        def round_fn_host_ef(storage, ids_per_tier, batches_per_tier, alive,
                             round_index, ef):
            return body(storage, ids_per_tier, batches_per_tier, alive,
                        round_index, ef)

        return round_fn_host_ef

    @jax.jit
    def round_fn_host(storage, ids_per_tier, batches_per_tier, alive,
                      round_index):
        return body(storage, ids_per_tier, batches_per_tier, alive,
                    round_index, None)

    return round_fn_host


def _host_batches(data_fn, ids_per_tier, round_index, local_steps):
    out = []
    for ids_t in ids_per_tier:
        per_client = [
            jax.tree_util.tree_map(
                lambda *xs: jnp.stack(xs),
                *[data_fn(int(c), int(round_index), s)
                  for s in range(local_steps)],
            )
            for c in np.asarray(ids_t)
        ]
        out.append(
            jax.tree_util.tree_map(lambda *xs: jnp.stack(xs), *per_client)
        )
    return out


# ---------------------------------------------------------------------------
# Rounds and training
# ---------------------------------------------------------------------------


def run_round_vectorized(
    family,
    cfg,
    specs,
    omc: OMCConfig,
    sim: SimConfig,
    server_params,  # storage tree (CompressedVariable | f32)
    data_fn,
    spec: CohortSpec,
    round_index: int,
    key: jax.Array,
    round_fn=None,
    wire_table: Optional[accounting.WireTable] = None,
    data_mode: str = "vmap",
    strategy=None,
    ste: bool = False,
    ef=None,
    fused_agg: bool = False,
    obs=None,
) -> Tuple[Any, Dict[str, float]]:
    """One vectorized round.  Returns (new server storage, metrics).

    Semantics match :func:`repro.federated.simulate.run_round`: dead clients
    contribute weight 0 to the FedAvg mean (numerically identical to
    dropping them — zero-weight terms vanish exactly), the server
    interpolates toward the cohort mean and re-compresses.  Pass a cached
    ``round_fn`` (from :func:`make_round_fn`) when looping — building it
    here costs a compile.  ``strategy``/``ste``/``ef`` mirror the loop path
    (§12); the error-feedback state dict is updated in place.

    ``obs`` (a :class:`repro.obs.Obs` or None, DESIGN.md §15): when set
    and ``obs.collect_metrics``, the round program additionally returns the
    cohort mean it already computes, and the metric bundle (quantization
    error, update norm, EF residual norm) is assembled eagerly on the host
    AFTER the round — the compiled round math itself is untouched, so with
    obs enabled the trained trees and ledgers stay bit/byte-identical to
    ``obs=None`` (tier-1 gated).  A cached ``round_fn`` must have been
    built with matching ``collect_metrics``.
    """
    takes_ef = simulate.ef_lib.takes_residual(omc, strategy)
    collect = obs is not None and obs.collect_metrics
    if round_fn is None:
        round_fn = make_round_fn(family, cfg, specs, omc, sim, spec, data_fn,
                                 data_mode, strategy=strategy, ste=ste,
                                 fused_agg=fused_agg, collect_metrics=collect)
    if takes_ef and ef is None:
        raise ValueError(
            f"strategy {strategy.label!r} uses error feedback: pass the "
            f"ef= state (repro.compress.feedback.init_ef_state)"
        )
    ids_per_tier = sample_tiered_cohort(key, spec, round_index)
    alive = cohort_lib.survival_mask(key, spec.plan, round_index)

    args = [server_params, ids_per_tier]
    if data_mode == "host":
        args.append(_host_batches(data_fn, ids_per_tier, round_index,
                                  sim.local_steps))
    args += [alive, jnp.int32(round_index)]
    with null_span(obs, "round", round=int(round_index)):
        res = round_fn(*args, ef) if takes_ef else round_fn(*args)
    base = 4 if takes_ef else 3
    mean_model = res[base] if len(res) > base else None
    if takes_ef:
        new_storage, loss, n_alive, new_ef = res[:4]
        for k in ef:
            ef[k] = new_ef[k]
    else:
        new_storage, loss, n_alive = res[:3]

    bundle = None
    if collect:
        # eager host-side bundle from the round's outputs (DESIGN.md §15):
        # the compiled program is never asked to compute metric values, so
        # enabling obs cannot perturb the trained tree
        bundle = obs_metrics.server_round_bundle(
            specs, server_params, new_storage, mean_model, sim.server_lr,
        )
        bundle["loss"] = loss
        bundle["alive"] = n_alive
        if takes_ef:
            ids_all = jnp.concatenate(
                [jnp.asarray(i) for i in ids_per_tier], 0
            )
            bundle["ef_norm"] = obs_metrics.ef_rows_norm(
                {k: v[ids_all] for k, v in ef.items()}
            )

    n_alive = int(n_alive)
    metrics: Dict[str, float] = dict(
        loss=float(loss),
        cohort=n_alive,
        dropped=int(spec.plan.cohort_size - n_alive),
    )
    if wire_table is not None:
        metrics.update(
            round_wire_metrics(wire_table, omc, spec.tier_omcs(omc),
                               ids_per_tier, alive, round_index,
                               strategy=strategy)
        )
    if obs is not None:
        obs.record("round", bundle, round=int(round_index), **metrics)
    return new_storage, metrics


def round_wire_metrics(
    table: accounting.WireTable,
    omc: OMCConfig,
    tier_omcs: Sequence[OMCConfig],
    ids_per_tier: Sequence[jax.Array],
    alive: jax.Array,
    round_index,
    strategy=None,
) -> Dict[str, int]:
    """Exact per-round wire bytes: every invited client downloads the full
    compressed server state; every *surviving* client uploads its
    PPQ-masked, tier-format transport payload.  With ``strategy`` the
    per-client upload sizes come from the strategy's plan (§12) — raises
    for data-dependent strategies (train those with ``wire=False``)."""
    invited = sum(int(np.asarray(i).shape[0]) for i in ids_per_tier)
    down = accounting.download_bytes_train(table, omc, strategy) * invited
    alive_np = np.asarray(alive, bool)
    up = 0
    off = 0
    for omc_t, ids_t in zip(tier_omcs, ids_per_tier):
        q = int(np.asarray(ids_t).shape[0])
        if strategy is None:
            per_client = accounting.cohort_upload_bytes(
                table, omc_t, round_index, ids_t
            )
        else:
            per_client = accounting.cohort_upload_bytes_strategy(
                table, omc_t, strategy, round_index, ids_t
            )
        up += int(per_client[alive_np[off:off + q]].sum())
        off += q
    return dict(down_bytes=int(down), up_bytes=int(up))


def run_training_vectorized(
    family,
    cfg,
    omc: OMCConfig,
    sim: SimConfig,
    spec: CohortSpec,
    data_fn,
    init_key,
    num_rounds: int,
    eval_fn: Optional[Callable[[Any, int], float]] = None,
    eval_every: int = 10,
    init_params=None,
    log: Optional[Callable[[str], None]] = None,
    data_mode: str = "vmap",
    wire: bool = True,
    strategy=None,
    ste: bool = False,
    ef=None,
    fused_agg: bool = False,
    obs=None,
):
    """Vectorized mirror of :func:`repro.federated.simulate.run_training`.

    The round program compiles once (round 0) and is reused; history rows
    carry per-round ``down_bytes`` / ``up_bytes`` when ``wire=True``.
    Unlike the loop mirror (which defaults to ``wire=False`` — scalar
    accounting costs a host round-trip per client), the engine's batched
    accounting is a few ms per round, so it is on by default; pass
    ``wire=False`` for history rows schema-identical to the loop's default.
    ``strategy``/``ste``/``ef`` mirror the loop path (§12); ``obs``
    attaches telemetry (§15) — a host-assembled metric bundle per round
    plus a wall span per round (the round-0 span includes the XLA
    compile).
    """
    specs = family.param_specs(cfg)
    params = family.init(init_key, cfg) if init_params is None else init_params
    storage = compress_params(params, specs, omc) if omc.enabled else params
    collect = obs is not None and obs.collect_metrics
    round_fn = make_round_fn(family, cfg, specs, omc, sim, spec, data_fn,
                             data_mode, strategy=strategy, ste=ste,
                             fused_agg=fused_agg, collect_metrics=collect)
    if ef is None and simulate.ef_lib.takes_residual(omc, strategy):
        ef = simulate.ef_lib.init_ef_state(params, specs, omc,
                                           spec.plan.num_clients)
    table = accounting.build_wire_table(params, specs, omc) if wire else None
    key = jax.random.fold_in(init_key, 0xC047)
    history = []
    for r in range(num_rounds):
        storage, metrics = run_round_vectorized(
            family, cfg, specs, omc, sim, storage, data_fn, spec, r, key,
            round_fn=round_fn, wire_table=table, data_mode=data_mode,
            strategy=strategy, ste=ste, ef=ef, obs=obs,
        )
        if eval_fn is not None and (r + 1) % eval_every == 0:
            metrics["eval"] = float(eval_fn(decompress_tree(storage), r))
        history.append(dict(round=r, **metrics))
        if log and ((r + 1) % eval_every == 0 or r == 0):
            log(f"round {r + 1}/{num_rounds}: " +
                ", ".join(f"{k}={v:.4g}" if isinstance(v, float) else f"{k}={v}"
                          for k, v in metrics.items()))
    return storage, history


# ---------------------------------------------------------------------------
# Codec reconciliation helper — what a client's upload actually serializes
# ---------------------------------------------------------------------------


def masked_upload_tree(trained_f32, specs, omc: OMCConfig, round_index,
                       client_id):
    """Storage tree of one client's transport payload: PPQ-selected
    variables compressed under ``omc.fmt``, everything else f32.  Feeding it
    to :func:`repro.api.codecs.encode_payload` / ``payload_bytes_report``
    must reproduce :func:`repro.federated.accounting.client_upload_bytes`
    exactly (asserted in ``tests/test_engine.py``)."""
    if not omc.enabled:
        return trained_f32
    names = accounting.selected_names(trained_f32, specs, omc)
    if not names:
        return trained_f32
    mask = np.asarray(
        ppq_mask(omc.ppq_key(), round_index, client_id, len(names),
                 omc.quantize_fraction),
        bool,
    )
    index = {n: i for i, n in enumerate(names)}

    def f(path, spec, leaf):
        i = index.get(path_str(path))
        if i is None or not mask[i]:
            return leaf
        return compress_variable(
            leaf, omc.fmt, pvt=omc.pvt,
            batch_axes=n_stack_axes(spec, leaf), fast=True,
        )

    return jax.tree_util.tree_map_with_path(
        f, specs, trained_f32, is_leaf=lambda s: isinstance(s, ParamSpec)
    )
