"""OMC materialization inside the distributed step (DESIGN.md §2/§4).

The server state stores policy-selected variables as ``CompressedVariable``
(uint bitfield codes + PVT scalars).  Inside the jitted round each scanned
layer slice is materialized:

  1. the *codes* are all-gathered over the fsdp axis (u8/u16/u32 on the wire
     — the paper's compressed server->client transport, 6–19 bits/param
     instead of 32),
  2. decoded + PVT-corrected to f32 — a transient that remat frees after the
     layer consumes it (the paper's decompress-on-the-fly, Fig. 1),
  3. grafted onto a zero-valued f32 "gradient sink" so that
     ``jax.grad(loss)(sinks)`` yields d loss / d W_effective — the client
     delta — without a persistent f32 master copy ever existing.

The graft is the straight-through identity
    w = stop_grad(decoded) + sink - stop_grad(sink)
whose forward value is exactly ``decoded`` (sink is zeros) and whose
backward routes the full cotangent into ``sink``.  No custom_vjp is needed
and no gradient ever flows into the integer codes.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Optional

import jax
import jax.numpy as jnp

from repro.core.formats import decode
from repro.core.pvt import pvt_apply
from repro.core.store import CompressedVariable, is_compressed
from repro.models.common import Materializer, ParamSpec, _pad_spec, shard_hint


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class QParam:
    """Storage-form parameter paired with its gradient sink.

    value: CompressedVariable (selected vars) or f32 array (the rest).
    sink:  f32 zeros of the decompressed shape; grad(loss)(sinks) = client
           delta.  None in inference mode (no grads wanted).
    """

    value: Any
    sink: Optional[jax.Array] = None


def _is_leaf(x):
    return is_compressed(x) or isinstance(x, QParam)


def make_sinks(params, specs=None):
    """f32 zero tree shaped like the decompressed params (created in-jit —
    XLA keeps them as broadcast constants, no memory).

    With ``specs`` the zeros carry the *storage* sharding constraint: the
    cotangent of each per-layer graft then lands on a storage-sharded
    accumulator, so GSPMD reduce-scatters the client-delta mean inside the
    backward scan instead of accumulating full-size replicated grads (which
    would be ~4 bytes/param *per device* — fatal at 110 B scale).
    """

    def zero(leaf):
        if is_compressed(leaf):
            return jnp.zeros(leaf.codes.shape, jnp.float32)
        return jnp.zeros(leaf.shape, jnp.float32)

    if specs is None:
        return jax.tree_util.tree_map(zero, params, is_leaf=_is_leaf)

    def zero_spec(spec, leaf):
        z = zero(leaf)
        return shard_hint(z, *_pad_spec(spec.storage, z.ndim))

    return jax.tree_util.tree_map(
        zero_spec, specs, params,
        is_leaf=lambda s: isinstance(s, ParamSpec),
    )


def pack_qparams(params, sinks=None):
    """Zip storage params with sinks into a QParam tree (model input)."""
    if sinks is None:
        return jax.tree_util.tree_map(
            lambda v: QParam(v, None), params, is_leaf=_is_leaf
        )
    return jax.tree_util.tree_map(
        lambda v, s: QParam(v, s), params, sinks, is_leaf=_is_leaf
    )


class OMCMaterializer(Materializer):
    """Materializer that understands QParam / CompressedVariable leaves.

    Per leaf:
      * CompressedVariable: gather codes (compressed collective) -> decode ->
        PVT affine -> graft sink.
      * f32 array: gather (f32 collective — unselected vars travel at full
        precision, as in the paper) -> graft sink.
    """

    def __init__(self, spec_tree=None, compute_dtype=jnp.float32):
        super().__init__(spec_tree)
        self.compute_dtype = compute_dtype

    def __call__(self, subtree, spec_subtree=None):
        spec_subtree = spec_subtree if spec_subtree is not None else self.spec_tree
        if spec_subtree is None:
            return jax.tree_util.tree_map(
                lambda q: self._leaf(q, None), subtree, is_leaf=_is_leaf
            )
        return jax.tree_util.tree_map(
            lambda sp, q: self._leaf(q, sp),
            spec_subtree,
            subtree,
            is_leaf=lambda s: isinstance(s, ParamSpec),
        )

    def leaf(self, x):
        return self._leaf(x, None)

    def _leaf(self, q, spec: Optional[ParamSpec]):
        if not isinstance(q, QParam):
            # plain leaf (e.g. fp32 baseline without sinks)
            if is_compressed(q):
                codes = self._gather(q.codes, spec)
                return pvt_apply(decode(codes, q.fmt), q.s, q.b).astype(
                    self.compute_dtype
                )
            return self._gather(q, spec).astype(self.compute_dtype)
        v = q.value
        if is_compressed(v):
            codes = self._gather(v.codes, spec)
            w = pvt_apply(decode(codes, v.fmt), v.s, v.b)
        else:
            w = self._gather(v, spec)
        if q.sink is not None:
            w = jax.lax.stop_gradient(w) + (q.sink - jax.lax.stop_gradient(q.sink))
        return w.astype(self.compute_dtype)

    @staticmethod
    def _gather(x, spec: Optional[ParamSpec]):
        if spec is None:
            return x
        return shard_hint(x, *_pad_spec(spec.gathered, x.ndim))
