"""Cohort sampling, client failures, and straggler semantics (DESIGN.md §5).

Production FL over-provisions: the server invites ``cohort_size`` clients but
closes the round once ``report_goal`` reports arrive (deadline semantics).
Simulation reproduces this with a per-round survival mask; FedAvg weighting
renormalizes over survivors so partial cohorts stay unbiased.
"""

from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import jax
import jax.numpy as jnp


def validate_report_goal(goal: int, cohort_size: int, *,
                         what: str = "report_goal") -> int:
    """Shared gate for "close after N reports" knobs: ``1 <= N <= cohort``.

    Used by :class:`CohortPlan` (sync deadline semantics) and by the async
    runtime's buffer goal K (:class:`repro.federated.async_engine.AsyncConfig`
    — flush after K uploads) so both ends of the async-vs-sync axis reject
    the same degenerate values (0 or negative would mean "aggregate nothing
    forever"; above the population the goal can never be met).
    """
    goal = int(goal)
    if not 1 <= goal <= cohort_size:
        raise ValueError(
            f"{what} must satisfy 1 <= {what} <= {cohort_size}, got {goal}"
        )
    return goal


@dataclasses.dataclass(frozen=True)
class CohortPlan:
    num_clients: int  # population size
    cohort_size: int  # invited per round
    report_goal: Optional[int] = None  # round closes at this many reports
    failure_rate: float = 0.0  # iid client dropout probability
    straggler_rate: float = 0.0  # fraction dropped at the deadline (slowest)

    def __post_init__(self):
        if self.cohort_size < 1 or self.cohort_size > self.num_clients:
            raise ValueError(
                f"cohort_size must satisfy 1 <= cohort_size <= "
                f"{self.num_clients}, got {self.cohort_size}"
            )
        if self.report_goal is None:
            object.__setattr__(self, "report_goal", self.cohort_size)
        validate_report_goal(self.report_goal, self.cohort_size)


def sample_cohort(key: jax.Array, plan: CohortPlan, round_index) -> jax.Array:
    """int32[cohort_size] client ids, sampled without replacement."""
    k = jax.random.fold_in(key, round_index)
    perm = jax.random.permutation(k, plan.num_clients)
    return perm[: plan.cohort_size].astype(jnp.int32)


def survival_mask(key: jax.Array, plan: CohortPlan, round_index) -> jax.Array:
    """bool[cohort_size]: True = client's report arrives in time.

    Failures are iid drops; stragglers are an additional slowest-k cut at the
    report deadline (simulated with random latencies).  At least one client
    always survives (a round with zero reports is retried in production; we
    model the retry as the fastest client making it).
    """
    k = jax.random.fold_in(jax.random.fold_in(key, round_index), 0x57A6)
    kf, kl = jax.random.split(k)
    alive = jax.random.uniform(kf, (plan.cohort_size,)) >= plan.failure_rate
    raw_latency = jax.random.uniform(kl, (plan.cohort_size,))
    latency = jnp.where(alive, raw_latency, jnp.inf)
    n_keep = max(
        1,
        min(plan.report_goal,
            int(round(plan.cohort_size * (1.0 - plan.straggler_rate)))),
    )
    order = jnp.argsort(latency)
    keep = jnp.zeros((plan.cohort_size,), bool).at[order[:n_keep]].set(True)
    keep = keep & alive
    # guarantee >= 1 survivor: when `alive` is all-False (e.g. at
    # failure_rate=1.0) the masked latency is uniformly inf and argmin over
    # it would always elect client 0 — the retried report must come from the
    # *fastest* client, so the fallback ranks by the raw latency.
    any_alive = keep.any()
    keep = jnp.where(any_alive, keep,
                     jnp.zeros_like(keep).at[jnp.argmin(raw_latency)].set(True))
    return keep


def aggregate_weighted(deltas: jax.Array, weights: jax.Array):
    """Weighted mean over the leading client axis, per-leaf.

    deltas: pytree with leaves [C, ...]; weights: [C] (0 for dropped
    clients).  Renormalizes by the surviving weight sum.
    """
    wsum = jnp.maximum(weights.sum(), 1e-9)

    def f(x):
        w = weights.reshape((-1,) + (1,) * (x.ndim - 1))
        return (x * w).sum(0) / wsum

    return jax.tree_util.tree_map(f, deltas)
