"""Event-driven asynchronous federated runtime (DESIGN.md §10).

Both sync execution paths (:mod:`repro.federated.simulate`,
:mod:`repro.federated.engine`) are hard-barrier: every round waits for the
slowest invited client, so one heavy-tail straggler caps the whole fleet's
throughput.  This module removes the barrier.  Clients *check in* against a
virtual clock driven by a pluggable :mod:`repro.federated.traces` model,
download the server state stamped with its current **version**, train
locally, and upload whenever they finish; the server runs **buffered
aggregation** (FedBuff-style): an aggregate is applied whenever
``buffer_goal`` (K) uploads accumulate, each weighted by a configurable
decay of its **staleness** ``server_version - base_version``.  Nothing ever
blocks on a straggler — its update simply lands in a later buffer with a
smaller weight.

The hot path stays compiled: clients that checked in under the same server
version downloaded the *same* state, so their local training batches
through the existing vmapped single-client body
(:func:`repro.federated.simulate.make_client_fn` — the very body the sync
engine vmaps) in one fixed-capacity XLA program per buffer flush; the flush
itself (staleness-weighted aggregate + server step + re-compress) is a
second compiled program.  The event loop only moves virtual time and
Python-level bookkeeping.

Equivalence contract (DESIGN.md §10, tested in
``tests/test_async_engine.py``): with ``buffer_goal == cohort size``, a
zero-jitter :class:`~repro.federated.traces.FixedTrace`, and staleness
decay disabled, every version's buffer holds exactly one fresh update per
client, and the runtime reproduces the sync engine's server tree within
the documented one-quantization-step tolerance, with wire bytes
reconciling byte-exactly.

With ``fused_agg=True`` the buffer stores transport-encoded uploads
(CompressedVariable leaves — ~11/32 the resident bytes at S1E3M7) and the
flush aggregates selected variables in the compressed domain through the
fused Pallas kernel (``repro.kernels.agg`` via ``kernels.ops``) — contract
and gating rules in DESIGN.md §13.

Checkpoint/resume of the full runtime state (buffer, version storages,
pending tickets, trace counters) lives in
:func:`repro.checkpoint.save_async_state` /
:func:`repro.checkpoint.restore_async_state`.

At population scale (DESIGN.md §14) the per-client dict counters become the
bottleneck; pass ``population=`` (a
:class:`repro.scale.store.PopulationStore`) to back ``event_counters`` /
``round_counters`` with the store's dense sharded arrays — the event loop
is unchanged (the store adapts them through
:class:`repro.scale.store.ArrayCounters`), and checkpoints stamp the
population layout.  The sharded synchronous round program itself lives in
:mod:`repro.scale.hierarchy`.
"""

from __future__ import annotations

import dataclasses
import heapq
from typing import Any, Callable, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.omc import OMCConfig
from repro.core.store import CompressedVariable, decompress_tree, is_compressed
from repro.kernels import ops as kernel_ops
from repro.models.common import ParamSpec
from repro.obs import metrics as obs_metrics
from repro.obs import null_span

from . import accounting
from . import cohort as cohort_lib
from . import simulate
from .simulate import SimConfig
from .state import compress_params, n_stack_axes
from .traces import ClientTrace, FixedTrace

_PRIO_UPLOAD = 0  # at equal times, uploads (and their flush) land first
_PRIO_CHECKIN = 1


# ---------------------------------------------------------------------------
# Staleness weighting
# ---------------------------------------------------------------------------


def staleness_weights(staleness, decay: float, mode: str = "poly"):
    """Un-normalized buffer weights ``w(s)`` for staleness ``s >= 0``.

    ``poly``: ``(1 + s)^-decay`` (FedBuff/FedAsync polynomial decay);
    ``exp``: ``e^(-decay * s)``.  Both satisfy the weight contract
    (DESIGN.md §10): ``w(0) = 1``, ``0 < w(s) <= 1``, monotone
    non-increasing in ``s``.  ``decay = 0`` disables staleness weighting —
    every update weighs 1 and buffered aggregation reduces exactly to the
    sync engine's zero-weight FedAvg.
    """
    s = jnp.asarray(staleness, jnp.float32)
    if decay < 0:
        raise ValueError(f"decay must be >= 0, got {decay}")
    if mode not in ("poly", "exp"):
        raise ValueError(f"decay_mode must be 'poly' or 'exp', got {mode!r}")
    if decay == 0:
        return jnp.ones_like(s)
    if mode == "poly":
        return (1.0 + s) ** (-decay)
    return jnp.exp(-decay * s)


def buffer_weights(staleness, decay: float, mode: str = "poly"):
    """Normalized per-buffer weights (non-negative, sum to 1).

    Computed in log space shifted by the freshest entry (the softmax
    trick): mathematically ``w(s_i) / sum_j w(s_j)``, but immune to the
    raw-weight underflow a uniformly-stale buffer hits at large
    ``decay * staleness`` (``exp(-200) == 0`` in f32 — raw normalization
    would be 0/0).
    """
    w = staleness_weights(staleness, decay, mode)  # validates args
    s = jnp.asarray(staleness, jnp.float32)
    if decay == 0:
        return w / w.sum()
    logw = -decay * (jnp.log1p(s) if mode == "poly" else s)
    logw = logw - logw.max()
    e = jnp.exp(logw)
    return e / e.sum()


def flush_weights(staleness, decay: float, mode: str = "poly"):
    """Weights a buffer flush hands to ``aggregate_weighted``.

    ``decay == 0`` returns exact 1.0s — bit-for-bit the sync engine's
    all-alive FedAvg weights (the equivalence gate rests on this);
    otherwise the stable normalized weights (``aggregate_weighted``
    renormalizes, so the scale difference is immaterial).
    """
    s = jnp.asarray(staleness, jnp.float32)
    if decay == 0:
        staleness_weights(s, decay, mode)  # still validate mode
        return jnp.ones_like(s)
    return buffer_weights(s, decay, mode)


@dataclasses.dataclass(frozen=True)
class AsyncConfig:
    """Buffered-aggregation knobs.

    ``buffer_goal`` (K) is validated against the participating population
    with the same gate as the sync report goal
    (:func:`repro.federated.cohort.validate_report_goal`) at runner
    construction.  ``train_capacity`` is the padded vmap width of the
    compiled training program (default: K — one program per flush in the
    steady state); groups larger than it run in multiple calls of the same
    program, never a recompile.
    """

    buffer_goal: int
    decay: float = 0.0
    decay_mode: str = "poly"
    max_staleness: Optional[int] = None  # drop (don't aggregate) staler
    train_capacity: Optional[int] = None

    def __post_init__(self):
        staleness_weights(jnp.zeros((1,)), self.decay, self.decay_mode)
        if self.max_staleness is not None and self.max_staleness < 0:
            raise ValueError(
                f"max_staleness must be >= 0, got {self.max_staleness}"
            )
        if self.train_capacity is not None and self.train_capacity < 1:
            raise ValueError(
                f"train_capacity must be >= 1, got {self.train_capacity}"
            )

    @property
    def capacity(self) -> int:
        return self.train_capacity or self.buffer_goal


# ---------------------------------------------------------------------------
# Compiled pieces: batched client training + the buffer flush
# ---------------------------------------------------------------------------


def make_batch_train_fn(family, cfg, specs, omc: OMCConfig, sim: SimConfig,
                        data_fn, capacity: int, strategy=None,
                        ste: bool = False, takes_residual: bool = False):
    """Jitted ``(storage, cids[cap], rounds[cap]) -> (models, losses)``.

    The same single-client body the sync engine vmaps, over a *padded*
    fixed-width client axis: every check-in epoch trains through this one
    program regardless of how many clients shared the version (pad slots
    repeat a real client and are discarded host-side).  ``rounds`` is each
    client's own round counter — NOT the server version: a fast client
    running twice under one version must draw fresh data and a fresh PPQ
    mask both times (under the degenerate equivalence trace the two
    coincide).  ``data_fn`` is traced inside (synthetic tasks and
    partitioned batch fns are traceable pure functions of
    ``(client_id, round_index, step)``).

    ``strategy``/``ste`` train under a zoo compression strategy
    (DESIGN.md §12); with ``takes_residual`` the program takes the cohort's
    error-feedback residual rows as a fourth argument and returns the
    updated rows as a third output (pad lanes recompute a real client's
    rows — the caller scatters only the real lanes back).
    """
    one = simulate.make_client_fn(family, cfg, specs, omc, sim,
                                  strategy, ste, takes_residual)
    steps = jnp.arange(sim.local_steps)

    if takes_residual:

        @jax.jit
        def batch_fn_ef(storage, cids, rounds, ef_rows):
            server_f32 = decompress_tree(storage)
            batches = jax.vmap(
                lambda c, r: jax.vmap(lambda s: data_fn(c, r, s))(steps)
            )(cids, rounds)
            return jax.vmap(
                lambda b, r, c, e: one(server_f32, b, r, c, e)
            )(batches, rounds, cids, ef_rows)

        return batch_fn_ef

    @jax.jit
    def batch_fn(storage, cids, rounds):
        server_f32 = decompress_tree(storage)
        batches = jax.vmap(
            lambda c, r: jax.vmap(lambda s: data_fn(c, r, s))(steps)
        )(cids, rounds)
        return jax.vmap(
            lambda b, r, c: one(server_f32, b, r, c)
        )(batches, rounds, cids)

    return batch_fn


def make_flush_fn(specs, omc: OMCConfig, sim: SimConfig, buffer_goal: int,
                  collect_metrics: bool = False):
    """Jitted ``(storage, stacked[K,...], weights[K]) -> new storage``.

    Staleness-weighted FedBuff step: weighted mean over the buffer
    (renormalized — :func:`repro.federated.cohort.aggregate_weighted`, the
    same aggregation op as both sync paths), server interpolation with
    ``sim.server_lr``, re-compress.  With unit weights this is bit-for-bit
    the sync engine's ``finish`` on an all-alive cohort of size K.

    ``collect_metrics=True`` (DESIGN.md §15) returns
    ``(new_storage, mean_model)`` — the buffer mean the flush already
    computes, exposed so the runtime can assemble the metric bundle
    eagerly on the host; no metric math runs inside the program, so the
    storage result is bit-identical either way (tier-1 gated in
    tests/test_obs.py).
    """
    del buffer_goal  # shape is carried by the traced arguments

    @jax.jit
    def flush_fn(storage, stacked, weights):
        server_f32 = decompress_tree(storage)
        mean_model = cohort_lib.aggregate_weighted(stacked, weights)
        new_f32 = jax.tree_util.tree_map(
            lambda old, new: old + sim.server_lr * (new - old),
            server_f32, mean_model,
        )
        new_storage = (
            compress_params(new_f32, specs, omc) if omc.enabled else new_f32
        )
        if collect_metrics:
            return new_storage, mean_model
        return new_storage

    return flush_fn


def make_fused_flush_fn(specs, omc: OMCConfig, sim: SimConfig,
                        buffer_goal: int, collect_metrics: bool = False):
    """Compressed-domain flush (DESIGN.md §13): jitted
    ``(storage, stacked compressed entries[K, ...], weights[K]) -> storage``.

    Buffer entries arrive already transport-encoded (``fused_agg=True``
    stores codes, not f32 trees — an S1E3M7 buffer holds ~11/32 the bytes);
    selected variables aggregate through the fused dequant→weighted-mean→
    requant kernel without materializing an f32 buffer stack, unselected
    leaves take the classic weighted mean + interpolation.
    """
    del buffer_goal  # shape is carried by the traced arguments

    @jax.jit
    def flush_fn(storage, stacked, weights):
        def f(path, spec_t, srv, stk):
            if is_compressed(srv):
                ba = n_stack_axes(spec_t, srv.codes)
                new_codes, s, b = kernel_ops.fused_aggregate(
                    srv.codes, srv.s, srv.b, stk.codes, stk.s, stk.b,
                    weights, sim.server_lr, srv.fmt,
                    batch_axes=ba, pvt=omc.pvt,
                )
                return CompressedVariable(new_codes, s, b, srv.fmt)
            mean = cohort_lib.aggregate_weighted(stk, weights)
            return srv + sim.server_lr * (mean - srv)

        new_storage = jax.tree_util.tree_map_with_path(
            f, specs, storage, stacked,
            is_leaf=lambda s: isinstance(s, ParamSpec),
        )
        if collect_metrics:
            # no f32 buffer mean exists in the compressed domain — the
            # host-side bundle degrades to the update norm (DESIGN.md §15)
            return new_storage, None
        return new_storage

    return flush_fn


# ---------------------------------------------------------------------------
# The runtime
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class _Pending:
    """An in-flight client round: version-stamped ticket + upload time.

    ``round_index`` is the client's own round counter — it keys the data
    stream and the PPQ/transport mask, while ``base_version`` keys the
    downloaded state and the staleness computation.
    """

    base_version: int
    round_index: int
    upload_at: float


@dataclasses.dataclass
class _BufferEntry:
    client_id: int
    base_version: int
    # trained client model: f32 tree, or — with fused_agg — the transport-
    # encoded upload (CompressedVariable leaves at selected vars, §13)
    model: Any
    loss: float


class AsyncRunner:
    """The event-driven server: virtual clock, tickets, buffer, flushes.

    Drive it with :meth:`step` (one event), :meth:`run_until` (a
    condition), or the :func:`run_async_training` convenience.  All
    mutable state is exposed as plain attributes so
    :mod:`repro.checkpoint` can serialize a mid-buffer snapshot and
    :func:`~repro.checkpoint.restore_async_state` can resume it
    deterministically (traces are counter-based; see
    :mod:`repro.federated.traces`).
    """

    def __init__(
        self,
        family,
        cfg,
        omc: OMCConfig,
        sim: SimConfig,
        acfg: AsyncConfig,
        trace: Optional[ClientTrace] = None,
        *,
        num_clients: int,
        data_fn: Callable[[Any, Any, Any], Any],
        init_key=None,
        init_params=None,
        wire: bool = True,
        strategy=None,
        ste: bool = False,
        fused_agg: bool = False,
        population=None,
        obs=None,
    ):
        if init_key is None and init_params is None:
            raise ValueError("need init_key or init_params")
        if population is not None and (
            population.layout.num_clients != int(num_clients)
        ):
            raise ValueError(
                f"population store holds {population.layout.num_clients} "
                f"clients but the runner was given num_clients={num_clients}"
            )
        if fused_agg and (strategy is not None or not omc.enabled):
            raise ValueError(
                "fused_agg=True needs OMC enabled and no zoo strategy "
                "(DESIGN.md §13)"
            )
        cohort_lib.validate_report_goal(acfg.buffer_goal, num_clients,
                                        what="buffer_goal")
        self.family, self.cfg, self.omc, self.sim = family, cfg, omc, sim
        self.acfg = acfg
        self.trace = trace if trace is not None else FixedTrace()
        self.num_clients = int(num_clients)
        self.specs = family.param_specs(cfg)
        params = (family.init(init_key, cfg) if init_params is None
                  else init_params)
        self.storage = (
            compress_params(params, self.specs, omc) if omc.enabled else params
        )
        # training-under-strategy (DESIGN.md §12): the batched client body
        # applies the strategy's qdq; EF residuals live per client here and
        # are checkpointed with the rest of the runtime state
        self.strategy, self.ste = strategy, ste
        takes_ef = simulate.ef_lib.takes_residual(omc, strategy)
        self.ef = (
            simulate.ef_lib.init_ef_state(params, self.specs, omc,
                                          self.num_clients)
            if takes_ef else None
        )
        self._batch_fn = make_batch_train_fn(
            family, cfg, self.specs, omc, sim, data_fn, acfg.capacity,
            strategy=strategy, ste=ste, takes_residual=takes_ef,
        )
        # telemetry handle (DESIGN.md §15): obs=None is a strict no-op —
        # same flush program, no spans, no records (tier-1 gated)
        self.obs = obs
        collect = obs is not None and obs.collect_metrics
        # fused mode (§13): buffer entries live transport-encoded and the
        # flush aggregates in the compressed domain
        self.fused_agg = bool(fused_agg)
        if self.fused_agg:
            self._encode_fn = jax.jit(jax.vmap(
                lambda m: compress_params(m, self.specs, omc, fast=True)
            ))
            self._flush_fn = make_fused_flush_fn(self.specs, omc, sim,
                                                 acfg.buffer_goal,
                                                 collect_metrics=collect)
        else:
            self._flush_fn = make_flush_fn(self.specs, omc, sim,
                                           acfg.buffer_goal,
                                           collect_metrics=collect)
        self._collect_metrics = collect
        self.stats = (
            accounting.AsyncWireStats(
                accounting.build_wire_table(params, self.specs, omc),
                strategy=strategy,
            ) if wire else None
        )

        # --- mutable runtime state (checkpointed as a unit) ---------------
        self.version = 0
        self.clock = 0.0
        self.events_processed = 0
        self.completed = 0  # uploads aggregated into some buffer
        self.dropped_stale = 0
        self.buffer: List[_BufferEntry] = []
        self.pending: Dict[int, _Pending] = {}  # cid -> in-flight round
        self.idle: Dict[int, float] = {  # cid -> next check-in time
            c: self.trace.first_checkin(c) for c in range(self.num_clients)
        }
        # per-client counters: plain dicts, or — with ``population=`` — the
        # store's dense sharded arrays behind the same mapping surface (§14)
        self.population = population
        if population is not None:
            self.event_counters: Any = population.event_view()
            self.round_counters: Any = population.round_view()
        else:
            self.event_counters = {c: 0 for c in range(self.num_clients)}
            self.round_counters = {  # cid -> rounds started
                c: 0 for c in range(self.num_clients)
            }
        self.version_storages: Dict[int, Any] = {}  # v -> storage at v
        self.trained: Dict[Tuple[int, int], Tuple[Any, float]] = {}
        self.history: List[Dict[str, Any]] = []
        self._rebuild_heap()

    # -- event loop ---------------------------------------------------------

    def _rebuild_heap(self) -> None:
        """(Re)build the event heap from ``pending`` + ``idle`` — the dicts
        are the source of truth (checkpointed; heap entries are lazily
        invalidated against them), so a restored runner re-derives the
        identical schedule."""
        self._heap: List[Tuple[float, int, int]] = [
            (p.upload_at, _PRIO_UPLOAD, c) for c, p in self.pending.items()
        ] + [(t, _PRIO_CHECKIN, c) for c, t in self.idle.items()]
        heapq.heapify(self._heap)

    def _heap_valid(self, ev: Tuple[float, int, int]) -> bool:
        t, prio, c = ev
        if prio == _PRIO_UPLOAD:
            p = self.pending.get(c)
            return p is not None and p.upload_at == t
        return self.idle.get(c) == t

    def _next_event(self) -> Optional[Tuple[float, int, int]]:
        """(time, prio, client) of the earliest event, or None if quiescent.

        Ties break (prio, client): at equal times uploads precede
        check-ins — the buffer flush a K-th upload triggers must land
        before a same-instant check-in downloads the state.  O(log N) via
        a lazily-invalidated heap; keys are fully state-derived (no
        insertion sequence), so restore reproduces the exact order.
        """
        while self._heap:
            ev = self._heap[0]
            if self._heap_valid(ev):
                return ev
            heapq.heappop(self._heap)  # stale entry: superseded schedule
        return None

    def step(self) -> Dict[str, Any]:
        """Process one event; returns a small record of what happened."""
        ev = self._next_event()
        if ev is None:
            raise RuntimeError("no schedulable events (empty population?)")
        heapq.heappop(self._heap)
        t, prio, cid = ev
        self.clock = max(self.clock, t)
        self.events_processed += 1
        if prio == _PRIO_CHECKIN:
            return self._on_checkin(cid, t)
        return self._on_upload(cid, t)

    def _on_checkin(self, cid: int, t: float) -> Dict[str, Any]:
        del self.idle[cid]
        base = self.version
        self.version_storages.setdefault(base, self.storage)
        rnd = self.round_counters[cid]
        self.round_counters[cid] = rnd + 1
        k = self.event_counters[cid]
        latency = self.trace.round_latency(cid, k, t)
        self.event_counters[cid] = k + 1
        self.pending[cid] = _Pending(base, rnd, t + latency)
        heapq.heappush(self._heap, (t + latency, _PRIO_UPLOAD, cid))
        if self.stats is not None:
            self.stats.start_round(self.omc, rnd, cid)
        if self.obs is not None:
            # virtual-clock span (§15): the event loop knows both endpoints
            # at check-in, so the span is constructed, never timed
            self.obs.vspan("client_round", t, latency,
                           client=cid, version=base, round=rnd)
        return dict(event="checkin", client=cid, t=t, version=base,
                    round=rnd, latency=latency)

    def _on_upload(self, cid: int, t: float) -> Dict[str, Any]:
        p = self.pending[cid]
        base, rnd = p.base_version, p.round_index
        staleness = self.version - base
        model, loss = self._train(cid, base)
        del self.pending[cid]
        dropped = (self.acfg.max_staleness is not None
                   and staleness > self.acfg.max_staleness)
        if self.stats is not None:
            self.stats.finish_round(self.omc, rnd, cid, staleness,
                                    dropped=dropped)
        if dropped:
            self.dropped_stale += 1
        else:
            self.buffer.append(_BufferEntry(cid, base, model, loss))
            self.completed += 1
        self._gc_versions()
        k = self.event_counters[cid]
        delay = self.trace.checkin_delay(cid, k, t)
        self.event_counters[cid] = k + 1
        self.idle[cid] = t + delay
        heapq.heappush(self._heap, (t + delay, _PRIO_CHECKIN, cid))
        flushed = False
        if len(self.buffer) >= self.acfg.buffer_goal:
            self._flush()
            flushed = True
        return dict(event="upload", client=cid, t=t, staleness=staleness,
                    dropped=dropped, flushed=flushed)

    # -- lazy batched training ---------------------------------------------

    def _train(self, cid: int, base: int) -> Tuple[Any, float]:
        """Trained model for (cid, base), batching every still-untrained
        client that downloaded the same version into padded calls of the
        one compiled program (each lane keyed by its client's own round
        counter — see :func:`make_batch_train_fn`)."""
        key = (base, cid)
        if key not in self.trained:
            group = [(c, p.round_index) for c, p in self.pending.items()
                     if p.base_version == base and (base, c) not in self.trained]
            storage = self.version_storages[base]
            cap = self.acfg.capacity
            for i in range(0, len(group), cap):
                chunk = group[i:i + cap]
                padded = chunk + [chunk[-1]] * (cap - len(chunk))
                cids = jnp.asarray([c for c, _ in padded], jnp.int32)
                rnds = jnp.asarray([r for _, r in padded], jnp.int32)
                if self.ef is not None:
                    rows = {k: v[cids] for k, v in self.ef.items()}
                    with null_span(self.obs, "dispatch", version=base,
                                   lanes=len(chunk)):
                        models, losses, new_rows = self._batch_fn(
                            storage, cids, rnds, rows
                        )
                    # scatter only the real lanes back — pad lanes duplicate
                    # chunk[-1] and must not double-apply its residual
                    real_ids = jnp.asarray([c for c, _ in chunk], jnp.int32)
                    for k in self.ef:
                        self.ef[k] = self.ef[k].at[real_ids].set(
                            new_rows[k][:len(chunk)]
                        )
                else:
                    with null_span(self.obs, "dispatch", version=base,
                                   lanes=len(chunk)):
                        models, losses = self._batch_fn(storage, cids, rnds)
                if self.fused_agg:
                    # transport-encode every lane (§13): the cached upload —
                    # and later the buffer — holds codes, not f32 trees
                    models = self._encode_fn(models)
                for j, (c, _) in enumerate(chunk):
                    m = jax.tree_util.tree_map(lambda x: x[j], models)
                    self.trained[(base, c)] = (m, float(losses[j]))
        return self.trained.pop(key)

    def _gc_versions(self) -> None:
        live = {p.base_version for p in self.pending.values()}
        live.add(self.version)
        for v in [v for v in self.version_storages if v not in live]:
            del self.version_storages[v]
        for k in [k for k in self.trained if k[0] not in live]:
            del self.trained[k]

    # -- buffered aggregation ----------------------------------------------

    def _flush(self) -> None:
        entries = self.buffer[:self.acfg.buffer_goal]
        self.buffer = self.buffer[self.acfg.buffer_goal:]
        staleness = np.asarray(
            [self.version - e.base_version for e in entries], np.float32
        )
        w = flush_weights(staleness, self.acfg.decay, self.acfg.decay_mode)
        stacked = jax.tree_util.tree_map(
            lambda *xs: jnp.stack(xs), *[e.model for e in entries]
        )
        bundle = None
        with null_span(self.obs, "flush", version=self.version,
                       buffer=len(entries)):
            if self._collect_metrics:
                old_storage = self.storage
                self.storage, mean_model = self._flush_fn(
                    self.storage, stacked, w
                )
                # bundle assembled eagerly AFTER the compiled flush
                # (DESIGN.md §15) — the program never computes metric
                # values, so obs cannot perturb the trained storage
                bundle = obs_metrics.server_round_bundle(
                    self.specs, old_storage, self.storage,
                    mean_model, self.sim.server_lr,
                )
            else:
                self.storage = self._flush_fn(self.storage, stacked, w)
        self.version += 1
        rec = dict(
            version=self.version,
            clock=round(float(self.clock), 6),
            buffer=len(entries),
            loss=float(np.mean([e.loss for e in entries])),
            staleness_mean=float(staleness.mean()),
            staleness_max=int(staleness.max()),
            completed=self.completed,
            dropped_stale=self.dropped_stale,
        )
        if self.stats is not None:
            rec.update(self.stats.snapshot())
        self.history.append(rec)
        if self.obs is not None:
            self.obs.record(
                "flush", bundle,
                staleness=[float(s) for s in staleness], **rec,
            )
        self._gc_versions()

    # -- driving ------------------------------------------------------------

    def run_until(self, *, flushes: Optional[int] = None,
                  uploads: Optional[int] = None,
                  time_limit: Optional[float] = None,
                  max_events: int = 10_000_000) -> None:
        """Advance the virtual clock until a target is reached (whichever
        of ``flushes`` / ``uploads`` / ``time_limit`` comes first)."""
        if flushes is None and uploads is None and time_limit is None:
            raise ValueError("need flushes, uploads, or time_limit")
        target_v = self.version + flushes if flushes is not None else None
        target_u = self.completed + uploads if uploads is not None else None
        for _ in range(max_events):
            if target_v is not None and self.version >= target_v:
                return
            if target_u is not None and self.completed >= target_u:
                return
            nxt = self._next_event()
            if nxt is None or (time_limit is not None and nxt[0] > time_limit):
                return
            self.step()
        raise RuntimeError(f"run_until exceeded max_events={max_events}")

    def server_params(self):
        """Decompressed f32 view of the current server model."""
        return decompress_tree(self.storage)


def run_async_training(
    family, cfg, omc: OMCConfig, sim: SimConfig, acfg: AsyncConfig,
    trace: ClientTrace, data_fn, init_key, *, num_clients: int,
    flushes: int, wire: bool = True,
    log: Optional[Callable[[str], None]] = None,
    strategy=None, ste: bool = False, fused_agg: bool = False,
    obs=None,
) -> Tuple[Any, List[Dict[str, Any]], AsyncRunner]:
    """Async mirror of :func:`repro.federated.engine.run_training_vectorized`.

    Runs the event loop for ``flushes`` buffer flushes and returns
    ``(final storage, history, runner)`` — one history row per flush, with
    virtual-clock timing, staleness distribution, and (``wire=True``) the
    cumulative :class:`~repro.federated.accounting.AsyncWireStats` ledger.
    ``strategy``/``ste`` train under a zoo compression strategy (§12); the
    runner's per-client error-feedback residuals are on ``runner.ef``.
    """
    runner = AsyncRunner(
        family, cfg, omc, sim, acfg, trace, num_clients=num_clients,
        data_fn=data_fn, init_key=init_key, wire=wire,
        strategy=strategy, ste=ste, fused_agg=fused_agg, obs=obs,
    )
    for i in range(flushes):
        runner.run_until(flushes=1)
        if log and (i == 0 or (i + 1) % max(flushes // 4, 1) == 0):
            h = runner.history[-1]
            log(f"flush {i + 1}/{flushes}: " +
                ", ".join(f"{k}={v:.4g}" if isinstance(v, float) else f"{k}={v}"
                          for k, v in h.items()))
    return runner.storage, runner.history, runner
