"""Faithful federated simulation (paper semantics, client granularity).

This is the numerics-reference path used by every paper-table benchmark:

  * the server stores the model in OMC form (CompressedVariable leaves),
  * each round a cohort is sampled; each client
      1. receives the decompressed server model,
      2. applies *its own* PPQ mask (per round, per client — paper §2.5):
         selected vars pass through quantize->dequantize(+PVT), the rest
         stay at the received full-precision values,
      3. runs ``local_steps`` of SGD on its local batch,
      4. re-quantizes the *updated* variables under the same mask (the
         transport compression: what travels client->server), and
  * the server aggregates the (decompressed) client models weighted by
    surviving-client example counts and re-compresses its state.

The per-client loop is a Python loop; inside it everything is jitted.  That
makes this module the *numerics reference*: easy to audit, client by client,
against the paper.  For cohorts beyond a few dozen clients use
:mod:`repro.federated.engine` — the vectorized path that ``vmap``s the very
same single-client update (``make_client_fn``) over stacked client states
and is equivalence-tested against this loop (DESIGN.md §9 documents the
stacked-state layout, the tolerance contract, and when to use which path).
Client failures / stragglers drop reports through
:mod:`repro.federated.cohort`.  Both this loop and the engine are
barrier-synchronous; the event-driven buffered-aggregation runtime
(:mod:`repro.federated.async_engine`, DESIGN.md §10) lifts the barrier for
straggler-dominated fleets while reusing this module's ``make_client_fn``.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core.omc import OMCConfig, qdq_pvt_leaf
from repro.core.partial import ppq_mask
from repro.core.policy import path_str
from repro.core.store import decompress_tree, is_compressed
from repro.models.common import IDENTITY_MAT, ParamSpec

from . import accounting
from . import cohort as cohort_lib
from .state import compress_params


def _selected_names(params_f32, specs, omc: OMCConfig):
    # the canonical PPQ mask-index order — shared with the engine and the
    # wire accounting so mask bits can never desynchronize between them
    return accounting.selected_names(params_f32, specs, omc)


def client_view(params_f32, specs, omc: OMCConfig, round_index, client_id):
    """Apply the client's PPQ-masked quantize->dequantize(+PVT) view."""
    if not omc.enabled:
        return params_f32
    names = _selected_names(params_f32, specs, omc)
    if not names:
        return params_f32
    mask = ppq_mask(omc.ppq_key(), round_index, client_id, len(names),
                    omc.quantize_fraction)
    index = {n: i for i, n in enumerate(names)}

    def f(path, spec, leaf):
        i = index.get(path_str(path))
        if i is None:
            return leaf
        return jnp.where(mask[i], qdq_pvt_leaf(leaf, omc), leaf)

    return jax.tree_util.tree_map_with_path(
        f, specs, params_f32, is_leaf=lambda s: isinstance(s, ParamSpec)
    )


@dataclasses.dataclass
class SimConfig:
    local_steps: int = 1
    client_lr: float = 0.05
    server_lr: float = 1.0


def make_client_fn(family, cfg, specs, omc: OMCConfig, sim: SimConfig):
    """Un-jitted: (server_f32, batch_stack, round, client_id) -> client model.

    The single-client round body.  The reference loop jits it as-is
    (:func:`make_client_update`); the vectorized engine ``vmap``s it over a
    stacked cohort (:mod:`repro.federated.engine`) — one definition, two
    execution strategies, which is what the engine's equivalence guarantee
    rests on (DESIGN.md §9)."""

    def client_update(server_f32, batches, round_index, client_id):
        eff = client_view(server_f32, specs, omc, round_index, client_id)

        def step(params, batch):
            loss, g = jax.value_and_grad(
                lambda p: family.loss(cfg, p, batch, IDENTITY_MAT)
            )(params)
            params = jax.tree_util.tree_map(
                lambda p, gg: p - sim.client_lr * gg, params, g
            )
            return params, loss

        trained, losses = jax.lax.scan(step, eff, batches)
        # transport compression: re-quantize under the same client mask
        out = client_view(trained, specs, omc, round_index, client_id)
        return out, losses.mean()

    return client_update


def make_client_update(family, cfg, specs, omc: OMCConfig, sim: SimConfig):
    """jitted: (server_f32, batch_stack, round, client_id) -> client model."""
    return jax.jit(make_client_fn(family, cfg, specs, omc, sim))


def run_round(
    family,
    cfg,
    specs,
    omc: OMCConfig,
    sim: SimConfig,
    server_params,  # storage tree (CompressedVariable | f32)
    data_fn: Callable[[int, int, int], Any],  # (client_id, round, step)->batch
    plan: cohort_lib.CohortPlan,
    round_index: int,
    key: jax.Array,
    client_update=None,
    wire_table=None,
) -> Tuple[Any, Dict[str, float]]:
    """One faithful federated round.  Returns (new server storage, metrics).

    ``wire_table`` (an :class:`repro.federated.accounting.WireTable`) adds
    exact per-round ``down_bytes`` / ``up_bytes`` to the metrics, computed
    one scalar PPQ mask at a time — the loop-granularity counterpart of the
    engine's batched accounting, asserted byte-identical in the engine
    equivalence tests."""
    server_f32 = decompress_tree(server_params)
    ids = cohort_lib.sample_cohort(key, plan, round_index)
    alive = cohort_lib.survival_mask(key, plan, round_index)
    if client_update is None:
        client_update = make_client_update(family, cfg, specs, omc, sim)

    models, weights, losses = [], [], []
    up_bytes = 0
    for j in range(plan.cohort_size):
        cid = int(ids[j])
        if not bool(alive[j]):
            continue
        batches = jax.tree_util.tree_map(
            lambda *xs: jnp.stack(xs),
            *[data_fn(cid, round_index, s) for s in range(sim.local_steps)],
        )
        m, l = client_update(server_f32, batches,
                             jnp.int32(round_index), jnp.int32(cid))
        models.append(m)
        weights.append(1.0)
        losses.append(float(l))
        if wire_table is not None:
            up_bytes += accounting.client_upload_bytes(
                wire_table, omc, round_index, cid
            )

    w = jnp.asarray(weights, jnp.float32)
    stacked = jax.tree_util.tree_map(lambda *xs: jnp.stack(xs), *models)
    mean_model = cohort_lib.aggregate_weighted(stacked, w)
    # server step: interpolate towards the cohort mean, then re-compress
    new_f32 = jax.tree_util.tree_map(
        lambda old, new: old + sim.server_lr * (new - old), server_f32, mean_model
    )
    new_storage = compress_params(new_f32, specs, omc) if omc.enabled else new_f32
    metrics = dict(
        loss=float(jnp.mean(jnp.asarray(losses))),
        cohort=len(models),
        dropped=int(plan.cohort_size - len(models)),
    )
    if wire_table is not None:
        metrics["down_bytes"] = (
            wire_table.download_bytes(omc) * plan.cohort_size
        )
        metrics["up_bytes"] = int(up_bytes)
    return new_storage, metrics


def run_training(
    family, cfg, omc: OMCConfig, sim: SimConfig, plan: cohort_lib.CohortPlan,
    data_fn, init_key, num_rounds: int,
    eval_fn: Optional[Callable[[Any, int], float]] = None,
    eval_every: int = 10,
    init_params=None,
    log: Optional[Callable[[str], None]] = None,
    wire: bool = False,
):
    """Full simulation loop.  Returns (final storage params, history).

    ``wire=True`` adds exact per-round wire-byte accounting to the history
    rows (see :func:`run_round`)."""
    specs = family.param_specs(cfg)
    params = family.init(init_key, cfg) if init_params is None else init_params
    storage = compress_params(params, specs, omc) if omc.enabled else params
    client_update = make_client_update(family, cfg, specs, omc, sim)
    wire_table = accounting.build_wire_table(params, specs, omc) if wire else None
    key = jax.random.fold_in(init_key, 0xC047)
    history = []
    for r in range(num_rounds):
        storage, metrics = run_round(
            family, cfg, specs, omc, sim, storage, data_fn, plan, r, key,
            client_update=client_update, wire_table=wire_table,
        )
        if eval_fn is not None and (r + 1) % eval_every == 0:
            metrics["eval"] = float(eval_fn(decompress_tree(storage), r))
        history.append(dict(round=r, **metrics))
        if log and ((r + 1) % eval_every == 0 or r == 0):
            log(f"round {r + 1}/{num_rounds}: " +
                ", ".join(f"{k}={v:.4g}" if isinstance(v, float) else f"{k}={v}"
                          for k, v in metrics.items()))
    return storage, history
