"""Faithful federated simulation (paper semantics, client granularity).

This is the numerics-reference path used by every paper-table benchmark:

  * the server stores the model in OMC form (CompressedVariable leaves),
  * each round a cohort is sampled; each client
      1. receives the decompressed server model,
      2. applies *its own* PPQ mask (per round, per client — paper §2.5):
         selected vars pass through quantize->dequantize(+PVT), the rest
         stay at the received full-precision values,
      3. runs ``local_steps`` of SGD on its local batch,
      4. re-quantizes the *updated* variables under the same mask (the
         transport compression: what travels client->server), and
  * the server aggregates the (decompressed) client models weighted by
    surviving-client example counts and re-compresses its state.

The per-client loop is a Python loop; inside it everything is jitted.  That
makes this module the *numerics reference*: easy to audit, client by client,
against the paper.  For cohorts beyond a few dozen clients use
:mod:`repro.federated.engine` — the vectorized path that ``vmap``s the very
same single-client update (``make_client_fn``) over stacked client states
and is equivalence-tested against this loop (DESIGN.md §9 documents the
stacked-state layout, the tolerance contract, and when to use which path).
Client failures / stragglers drop reports through
:mod:`repro.federated.cohort`.  Both this loop and the engine are
barrier-synchronous; the event-driven buffered-aggregation runtime
(:mod:`repro.federated.async_engine`, DESIGN.md §10) lifts the barrier for
straggler-dominated fleets while reusing this module's ``make_client_fn``.

Every entry point also accepts ``strategy=`` (a
:class:`repro.compress.CompressionStrategy`) to train under a zoo
compressor instead of the hardcoded OMC qdq — DESIGN.md §12 is the
contract.  ``strategy=None`` is bit-for-bit today's path, and
``strategy=get_strategy("omc")`` (matching ``omc``) is *gated* to stay
bit-identical to it (``tests/test_train_strategy.py``).  Dense strategies
replace the masked qdq view in both directions; sparse upload-only
strategies (top-k / ternary / pipeline) train on the dense download and
compress the *update* ``trained - received`` on the way back up, with an
optional per-client error-feedback residual
(:mod:`repro.compress.feedback`).
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from typing import TYPE_CHECKING

from repro.core.omc import OMCConfig, qdq_pvt_leaf
from repro.core.partial import ppq_mask
from repro.core.policy import path_str
from repro.core.store import decompress_tree, is_compressed
from repro.models.common import IDENTITY_MAT, ParamSpec
from repro.obs import metrics as obs_metrics
from repro.obs import null_span

from . import accounting
from . import cohort as cohort_lib
from .state import compress_params, n_stack_axes

if TYPE_CHECKING:  # pragma: no cover - annotations only
    from repro.compress import CompressionStrategy


def _ef():
    # lazy: repro.compress pulls in the api wire codecs, which import this
    # package — a module-level import here would be circular
    from repro.compress import feedback
    return feedback


class _LazyEF:
    """Module-level stand-in for :mod:`repro.compress.feedback`."""

    def __getattr__(self, name):
        return getattr(_ef(), name)


ef_lib = _LazyEF()


def _selected_names(params_f32, specs, omc: OMCConfig):
    # the canonical PPQ mask-index order — shared with the engine and the
    # wire accounting so mask bits can never desynchronize between them
    return accounting.selected_names(params_f32, specs, omc)


def client_view(params_f32, specs, omc: OMCConfig, round_index, client_id,
                strategy: Optional[CompressionStrategy] = None,
                ste: bool = False):
    """Apply the client's PPQ-masked quantize->dequantize(+PVT) view.

    With a zoo ``strategy`` the masked variables pass through its
    ``train_qdq_leaf`` (or the STE variant) instead of the hardcoded OMC
    qdq — same PPQ mask, same selection, only the lossy transform swaps
    (DESIGN.md §12).  Upload-only strategies never compress the download
    direction, so the view is the identity for them."""
    if not omc.enabled:
        return params_f32
    if strategy is not None and strategy.upload_only:
        return params_f32
    names = _selected_names(params_f32, specs, omc)
    if not names:
        return params_f32
    mask = ppq_mask(omc.ppq_key(), round_index, client_id, len(names),
                    omc.quantize_fraction)
    index = {n: i for i, n in enumerate(names)}

    def f(path, spec, leaf):
        i = index.get(path_str(path))
        if i is None:
            return leaf
        if strategy is None:
            q = qdq_pvt_leaf(leaf, omc)
        else:
            qdq = strategy.train_qdq_ste_leaf if ste else strategy.train_qdq_leaf
            q = qdq(leaf, batch_axes=n_stack_axes(spec, leaf))
        return jnp.where(mask[i], q, leaf)

    return jax.tree_util.tree_map_with_path(
        f, specs, params_f32, is_leaf=lambda s: isinstance(s, ParamSpec)
    )


def strategy_upload(trained, received, residual, specs, omc: OMCConfig,
                    strategy: CompressionStrategy, round_index, client_id,
                    ste: bool = False):
    """Upload-direction rule for sparse (upload-only) strategies (§12).

    The client sends its *update* ``delta = trained - received`` through
    the strategy's qdq under its PPQ mask; the server-visible model is
    ``received + sent``.  With error feedback, ``residual`` (this client's
    rows of the :mod:`repro.compress.feedback` state, ``{name: array}``)
    is added pre-compression and the dropped part is returned as the new
    residual; without it the second return is ``residual`` unchanged.

    Returns ``(out_model, new_residual)``; traceable (jit/vmap-safe).
    """
    if not omc.enabled:
        return trained, dict(residual or {})
    names = _selected_names(trained, specs, omc)
    if not names:
        return trained, dict(residual or {})
    mask = ppq_mask(omc.ppq_key(), round_index, client_id, len(names),
                    omc.quantize_fraction)
    index = {n: i for i, n in enumerate(names)}
    use_ef = bool(strategy.error_feedback) and residual is not None
    new_residual: Dict[str, Any] = {}

    def f(path, spec, t, rcv):
        name = path_str(path)
        i = index.get(name)
        if i is None:
            return t  # unselected vars travel f32: arrive exact
        delta = t - rcv
        ax = n_stack_axes(spec, t)
        if use_ef:
            sent, resid = ef_lib.compensate_leaf(
                strategy, delta, residual[name], mask[i],
                batch_axes=ax, ste=ste,
            )
            new_residual[name] = resid
        else:
            qdq = (strategy.train_qdq_ste_leaf if ste
                   else strategy.train_qdq_leaf)
            sent = jnp.where(mask[i], qdq(delta, batch_axes=ax), delta)
        return rcv + sent

    out = jax.tree_util.tree_map_with_path(
        f, specs, trained, received,
        is_leaf=lambda s: isinstance(s, ParamSpec),
    )
    return out, (new_residual if use_ef else dict(residual or {}))


@dataclasses.dataclass
class SimConfig:
    local_steps: int = 1
    client_lr: float = 0.05
    server_lr: float = 1.0


def make_client_fn(family, cfg, specs, omc: OMCConfig, sim: SimConfig,
                   strategy: Optional[CompressionStrategy] = None,
                   ste: bool = False,
                   takes_residual: Optional[bool] = None):
    """Un-jitted single-client round body.

    Signature without error feedback:
    ``(server_f32, batch_stack, round, client_id) -> (model, loss)``;
    with it (``takes_residual``) a residual-rows dict is threaded through:
    ``(..., residual) -> (model, loss, new_residual)``.

    The reference loop jits it as-is (:func:`make_client_update`); the
    vectorized engine ``vmap``s it over a stacked cohort
    (:mod:`repro.federated.engine`) — one definition, two execution
    strategies, which is what the engine's equivalence guarantee rests on
    (DESIGN.md §9).  ``strategy``/``ste`` select the §12 training-under-
    strategy semantics; ``takes_residual`` defaults to
    :func:`repro.compress.feedback.takes_residual` and exists so the
    engine can force one signature across heterogeneous tiers (a tier
    whose ``omc`` is disabled passes the residual rows through
    unchanged)."""
    if takes_residual is None:
        takes_residual = ef_lib.takes_residual(omc, strategy)
    sparse = strategy is not None and strategy.upload_only

    def _train(eff, batches):
        def step(params, batch):
            loss, g = jax.value_and_grad(
                lambda p: family.loss(cfg, p, batch, IDENTITY_MAT)
            )(params)
            params = jax.tree_util.tree_map(
                lambda p, gg: p - sim.client_lr * gg, params, g
            )
            return params, loss

        return jax.lax.scan(step, eff, batches)

    if takes_residual:

        def client_update(server_f32, batches, round_index, client_id,
                          residual):
            eff = client_view(server_f32, specs, omc, round_index, client_id,
                              strategy, ste)
            trained, losses = _train(eff, batches)
            out, new_residual = strategy_upload(
                trained, eff, residual, specs, omc, strategy,
                round_index, client_id, ste,
            )
            return out, losses.mean(), new_residual

        return client_update

    def client_update(server_f32, batches, round_index, client_id):
        eff = client_view(server_f32, specs, omc, round_index, client_id,
                          strategy, ste)
        trained, losses = _train(eff, batches)
        if sparse and omc.enabled:
            # sparse strategy without EF: compress the raw update
            out, _ = strategy_upload(
                trained, eff, None, specs, omc, strategy,
                round_index, client_id, ste,
            )
        else:
            # transport compression: re-quantize under the same client mask
            out = client_view(trained, specs, omc, round_index, client_id,
                              strategy, ste)
        return out, losses.mean()

    return client_update


def make_client_update(family, cfg, specs, omc: OMCConfig, sim: SimConfig,
                       strategy: Optional[CompressionStrategy] = None,
                       ste: bool = False,
                       takes_residual: Optional[bool] = None):
    """jitted :func:`make_client_fn` (same signature rules)."""
    return jax.jit(make_client_fn(
        family, cfg, specs, omc, sim, strategy, ste, takes_residual
    ))


def run_round(
    family,
    cfg,
    specs,
    omc: OMCConfig,
    sim: SimConfig,
    server_params,  # storage tree (CompressedVariable | f32)
    data_fn: Callable[[int, int, int], Any],  # (client_id, round, step)->batch
    plan: cohort_lib.CohortPlan,
    round_index: int,
    key: jax.Array,
    client_update=None,
    wire_table=None,
    strategy: Optional[CompressionStrategy] = None,
    ste: bool = False,
    ef=None,
    obs=None,
) -> Tuple[Any, Dict[str, float]]:
    """One faithful federated round.  Returns (new server storage, metrics).

    ``wire_table`` (an :class:`repro.federated.accounting.WireTable`) adds
    exact per-round ``down_bytes`` / ``up_bytes`` to the metrics, computed
    one scalar PPQ mask at a time — the loop-granularity counterpart of the
    engine's batched accounting, asserted byte-identical in the engine
    equivalence tests.  ``strategy``/``ste`` train under a zoo compressor
    (§12); ``ef`` is the population error-feedback state
    (:func:`repro.compress.feedback.init_ef_state`), updated in place for
    the surviving cohort rows.  ``obs`` (DESIGN.md §15) folds the same
    metric bundle the engine emits into ``obs.sink`` — computed eagerly
    here (this is the eager reference path), never altering the round's
    own arithmetic."""
    server_f32 = decompress_tree(server_params)
    ids = cohort_lib.sample_cohort(key, plan, round_index)
    alive = cohort_lib.survival_mask(key, plan, round_index)
    takes_ef = ef_lib.takes_residual(omc, strategy)
    if client_update is None:
        client_update = make_client_update(family, cfg, specs, omc, sim,
                                           strategy, ste)
    if takes_ef and ef is None:
        raise ValueError(
            f"strategy {strategy.label!r} uses error feedback: pass the "
            f"ef= state (repro.compress.feedback.init_ef_state)"
        )

    models, weights, losses = [], [], []
    up_bytes = 0
    for j in range(plan.cohort_size):
        cid = int(ids[j])
        if not bool(alive[j]):
            continue
        batches = jax.tree_util.tree_map(
            lambda *xs: jnp.stack(xs),
            *[data_fn(cid, round_index, s) for s in range(sim.local_steps)],
        )
        if takes_ef:
            rows = {k: v[cid] for k, v in ef.items()}
            m, l, new_rows = client_update(server_f32, batches,
                                           jnp.int32(round_index),
                                           jnp.int32(cid), rows)
            for k in ef:
                ef[k] = ef[k].at[cid].set(new_rows[k])
        else:
            m, l = client_update(server_f32, batches,
                                 jnp.int32(round_index), jnp.int32(cid))
        models.append(m)
        weights.append(1.0)
        losses.append(float(l))
        if wire_table is not None:
            if strategy is None:
                up_bytes += accounting.client_upload_bytes(
                    wire_table, omc, round_index, cid
                )
            else:
                up_bytes += accounting.client_upload_bytes_strategy(
                    wire_table, omc, strategy, round_index, cid
                )

    w = jnp.asarray(weights, jnp.float32)
    stacked = jax.tree_util.tree_map(lambda *xs: jnp.stack(xs), *models)
    mean_model = cohort_lib.aggregate_weighted(stacked, w)
    # server step: interpolate towards the cohort mean, then re-compress
    new_f32 = jax.tree_util.tree_map(
        lambda old, new: old + sim.server_lr * (new - old), server_f32, mean_model
    )
    new_storage = compress_params(new_f32, specs, omc) if omc.enabled else new_f32
    metrics = dict(
        loss=float(jnp.mean(jnp.asarray(losses))),
        cohort=len(models),
        dropped=int(plan.cohort_size - len(models)),
    )
    if wire_table is not None:
        metrics["down_bytes"] = (
            accounting.download_bytes_train(wire_table, omc, strategy)
            * plan.cohort_size
        )
        metrics["up_bytes"] = int(up_bytes)
    if obs is not None:
        bundle = None
        if obs.collect_metrics:
            bundle = obs_metrics.server_round_bundle(
                specs, server_f32, new_storage, mean_model, sim.server_lr
            )
            bundle["alive"] = jnp.float32(len(models))
            if takes_ef:
                bundle["ef_norm"] = obs_metrics.ef_rows_norm(
                    {k: v[ids] for k, v in ef.items()}
                )
        obs.record("round", bundle, round=int(round_index), **metrics)
    return new_storage, metrics


def run_training(
    family, cfg, omc: OMCConfig, sim: SimConfig, plan: cohort_lib.CohortPlan,
    data_fn, init_key, num_rounds: int,
    eval_fn: Optional[Callable[[Any, int], float]] = None,
    eval_every: int = 10,
    init_params=None,
    log: Optional[Callable[[str], None]] = None,
    wire: bool = False,
    strategy: Optional[CompressionStrategy] = None,
    ste: bool = False,
    ef=None,
    obs=None,
):
    """Full simulation loop.  Returns (final storage params, history).

    ``wire=True`` adds exact per-round wire-byte accounting to the history
    rows (see :func:`run_round`).  ``strategy``/``ste`` train under a zoo
    compressor (§12).  When the strategy uses error feedback, pass
    ``ef=feedback.init_ef_state(...)`` to observe the final residuals —
    the dict is updated in place — or leave it ``None`` to have one
    allocated internally."""
    specs = family.param_specs(cfg)
    params = family.init(init_key, cfg) if init_params is None else init_params
    storage = compress_params(params, specs, omc) if omc.enabled else params
    client_update = make_client_update(family, cfg, specs, omc, sim,
                                       strategy, ste)
    if ef is None and ef_lib.takes_residual(omc, strategy):
        ef = ef_lib.init_ef_state(params, specs, omc, plan.num_clients)
    wire_table = accounting.build_wire_table(params, specs, omc) if wire else None
    key = jax.random.fold_in(init_key, 0xC047)
    history = []
    for r in range(num_rounds):
        with null_span(obs, "round", round=r):
            storage, metrics = run_round(
                family, cfg, specs, omc, sim, storage, data_fn, plan, r, key,
                client_update=client_update, wire_table=wire_table,
                strategy=strategy, ste=ste, ef=ef, obs=obs,
            )
        if eval_fn is not None and (r + 1) % eval_every == 0:
            metrics["eval"] = float(eval_fn(decompress_tree(storage), r))
        history.append(dict(round=r, **metrics))
        if log and ((r + 1) % eval_every == 0 or r == 0):
            log(f"round {r + 1}/{num_rounds}: " +
                ", ".join(f"{k}={v:.4g}" if isinstance(v, float) else f"{k}={v}"
                          for k, v in metrics.items()))
    return storage, history
