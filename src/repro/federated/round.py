"""The jit-able federated round — what the multi-pod dry-run lowers.

One round (paper §1, TPU-native mapping per DESIGN.md §4):

  1. server->client transport: per-layer all-gather of the *compressed*
     bitfield codes over the fsdp axis (u8/u16/u32 — the paper's
     communication saving), decoded + PVT-corrected on the fly under remat,
  2. cohort-parallel local step: every (pod, data) mesh slice is a client
     training on its batch shard; grads w.r.t. the effective (decompressed)
     weights are the client deltas,
  3. client->server aggregation: the batch-mean inside backward *is* the
     cohort mean; the storage-sharding constraint on the grads lowers it to
     a reduce-scatter,
  4. server optimizer applies the mean delta to the decoded values and
     re-compresses — the updated parameters are stored quantized again, so
     the client-side quantized-storage model of the paper holds server-side
     too (no persistent f32 master).

PPQ note: the lowered round quantizes every policy-selected variable
(fraction = 1).  Per-client PPQ masks need per-client effective weights —
exercised faithfully in simulation mode (repro.federated.simulate, one
client at a time) and at scale by the vectorized cohort engine
(repro.federated.engine, DESIGN.md §9); documented as a cohort-granularity
deviation at >=10 B scale (DESIGN.md §6).
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core.formats import decode
from repro.core.omc import OMCConfig
from repro.core.pvt import pvt_apply
from repro.core.store import CompressedVariable, compress_variable, is_compressed
from repro.models.common import Materializer, ParamSpec, _pad_spec, shard_hint
from repro.optim import Optimizer

from .materialize import OMCMaterializer, make_sinks, pack_qparams
from .state import TrainState, n_stack_axes


def _constrain_storage(tree, specs):
    """Pin each leaf to its storage sharding (forces grad reduce-scatter)."""

    def f(spec, leaf):
        return shard_hint(leaf, *_pad_spec(spec.storage, leaf.ndim))

    return jax.tree_util.tree_map(
        f, specs, tree, is_leaf=lambda s: isinstance(s, ParamSpec)
    )


def make_round_fn(
    family,
    cfg,
    omc: OMCConfig,
    server_opt: Optimizer,
    client_lr=1e-2,
    compute_dtype=jnp.float32,
) -> Callable[[TrainState, Any], Tuple[TrainState, Dict[str, jax.Array]]]:
    """Build the federated-round step function (jit / pjit it yourself)."""
    specs = family.param_specs(cfg)

    def round_fn(state: TrainState, batch) -> Tuple[TrainState, Dict[str, jax.Array]]:
        sinks = make_sinks(state.params, specs)

        def loss_fn(sinks_):
            packed = pack_qparams(state.params, sinks_)
            mat = OMCMaterializer(None, compute_dtype)
            return family.loss(cfg, packed, batch, mat)

        loss, grads = jax.value_and_grad(loss_fn)(sinks)
        grads = _constrain_storage(grads, specs)

        lr = client_lr(state.round) if callable(client_lr) else jnp.float32(client_lr)
        # FedOpt: server-grad = -mean_delta = +lr * grads
        server_grads = jax.tree_util.tree_map(lambda g: lr * g, grads)
        upd, new_opt_state = server_opt.update(server_grads, state.opt_state)

        def leaf_update(spec, p, u):
            u = shard_hint(u, *_pad_spec(spec.storage, u.ndim))
            if is_compressed(p):
                v = pvt_apply(decode(p.codes, p.fmt), p.s, p.b) + u
                return compress_variable(
                    v, p.fmt, pvt=omc.pvt, batch_axes=n_stack_axes(spec, u),
                    fast=True,
                )
            return p + u

        new_params = jax.tree_util.tree_map(
            leaf_update, specs, state.params, upd,
            is_leaf=lambda s: isinstance(s, ParamSpec),
        )
        new_state = TrainState(
            params=new_params,
            opt_state=new_opt_state,
            round=state.round + 1,
            rng=jax.random.fold_in(state.rng, state.round),
        )
        # NOTE: per-leaf sum-of-squares, NOT jnp.vdot — vdot ravels to 1-D,
        # which un-shards the stacked grads and forces full-model all-gathers.
        gnorm = jnp.sqrt(
            sum(jnp.sum(jnp.square(g)) for g in jax.tree_util.tree_leaves(grads))
        )
        return new_state, dict(loss=loss, grad_norm=gnorm)

    return round_fn


def make_eval_fn(family, cfg, compute_dtype=jnp.float32):
    """Forward-only loss on the compressed (or f32) server params."""

    def eval_fn(params, batch):
        packed = pack_qparams(params, None)
        mat = OMCMaterializer(None, compute_dtype)
        return family.loss(cfg, packed, batch, mat)

    return eval_fn


def make_serve_fns(family, cfg, compute_dtype=jnp.float32):
    """(prefill_fn, decode_fn) over compressed weights — serving path."""
    mat = OMCMaterializer(None, compute_dtype)

    def prefill_fn(params, batch, cache):
        packed = pack_qparams(params, None)
        return family.prefill(cfg, packed, batch, mat, cache)

    def decode_fn(params, cache, tokens):
        packed = pack_qparams(params, None)
        return family.decode_step(cfg, packed, cache, tokens, mat)

    return prefill_fn, decode_fn
