"""Client availability and latency traces for the async runtime (DESIGN.md §10).

A trace answers the two questions the event-driven runtime
(:mod:`repro.federated.async_engine`) asks about every client:

  * **availability** — after finishing (or at t=0), how long until this
    client next *checks in* (``checkin_delay`` / ``first_checkin``)?
  * **latency** — once checked in, how long does one full client round
    (download + local train + upload) take (``round_latency``)?

All times are *virtual seconds*: the runtime advances a virtual clock over
trace-scheduled events, so a planet-scale diurnal day simulates in
milliseconds of wall time.  Traces are pure, deterministic functions of
``(seed, client_id, event_index)`` — the runtime hands each client a
monotonically increasing event counter, and resuming from a checkpoint
replays the identical schedule (the counters are part of the checkpointed
state; see :func:`repro.checkpoint.save_async_state`).

Built-ins cover the scenario axes the cookbook needs:

  * :class:`FixedTrace` — constant latency/interval (± optional uniform
    jitter).  With zero jitter this is the degenerate *synchronous* trace
    used by the async-vs-sync equivalence gate.
  * :class:`ParetoTrace` — heavy-tail straggler latency (Pareto tail index
    ``alpha``; smaller = heavier).  The canonical "p99 device is 30x the
    median" production distribution.
  * :class:`DiurnalTrace` — sine-modulated availability over a virtual day:
    clients check in eagerly at peak and rarely in the trough.
  * :class:`TieredTrace` — wraps another trace and scales its latency per
    device tier, tier membership following the engine's round-robin
    striping (``client_id % n_tiers`` — the same convention as
    :class:`repro.federated.engine.CohortSpec`), so latency correlates with
    the :class:`~repro.federated.engine.DeviceProfile` bitwidth tiers.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Optional, Sequence, Tuple

import numpy as np


def _uniform(seed: int, client_id: int, event_index: int, salt: int) -> float:
    """Deterministic U[0,1) from a counter-based stream (no global state)."""
    rng = np.random.default_rng(
        (int(seed), int(client_id), int(event_index), int(salt))
    )
    return float(rng.random())


@dataclasses.dataclass(frozen=True)
class ClientTrace:
    """Base trace: constant interval/latency, optional symmetric jitter.

    Subclasses override :meth:`round_latency` and/or :meth:`checkin_delay`;
    both receive the client's event counter (monotone per client) and the
    virtual ``now`` so schedules can be counter-deterministic *and*
    time-of-day aware.
    """

    seed: int = 0
    interval: float = 0.0  # idle gap between upload and next check-in
    latency: float = 1.0  # one full download+train+upload round

    def first_checkin(self, client_id: int) -> float:
        """Virtual time of the client's first check-in (default: t=0)."""
        return 0.0

    def checkin_delay(self, client_id: int, event_index: int,
                      now: float) -> float:
        return float(self.interval)

    def round_latency(self, client_id: int, event_index: int,
                      now: float) -> float:
        return float(self.latency)


@dataclasses.dataclass(frozen=True)
class FixedTrace(ClientTrace):
    """Constant latency ± ``jitter`` (fraction, uniform).  ``jitter=0`` is
    the synchronous degenerate trace of the equivalence gate."""

    jitter: float = 0.0

    def __post_init__(self):
        if not 0.0 <= self.jitter < 1.0:
            raise ValueError(f"jitter must be in [0, 1), got {self.jitter}")

    def round_latency(self, client_id: int, event_index: int,
                      now: float) -> float:
        if self.jitter == 0.0:
            return float(self.latency)
        u = _uniform(self.seed, client_id, event_index, 0x1A7)
        return float(self.latency) * (1.0 + self.jitter * (2.0 * u - 1.0))


@dataclasses.dataclass(frozen=True)
class ParetoTrace(ClientTrace):
    """Heavy-tail straggler latency: ``latency * Pareto(alpha)`` with the
    minimum pinned at ``latency`` (Lomax-shifted).  ``alpha <= 2`` gives the
    infinite-variance tail where sync rounds are makespan-dominated by one
    straggler — the async runtime's motivating regime."""

    alpha: float = 1.5

    def __post_init__(self):
        if self.alpha <= 0:
            raise ValueError(f"alpha must be > 0, got {self.alpha}")

    def round_latency(self, client_id: int, event_index: int,
                      now: float) -> float:
        u = _uniform(self.seed, client_id, event_index, 0x9A3)
        # inverse-CDF Pareto with scale = latency: x = L * (1-u)^(-1/alpha)
        return float(self.latency) * (1.0 - u) ** (-1.0 / self.alpha)


@dataclasses.dataclass(frozen=True)
class DiurnalTrace(ClientTrace):
    """Sine-modulated availability over a virtual day of ``period`` seconds.

    Availability ``a(t) = (1-depth) + depth * (1+sin(2πt/P + φ_c))/2`` swings
    between ``1-depth`` (trough) and 1 (peak); the idle gap before the next
    check-in stretches by ``1/a(t)``.  Each client gets a deterministic
    phase offset ``φ_c`` (timezone spread) so the population's check-ins
    roll around the clock instead of thundering in herd.
    """

    period: float = 24.0
    depth: float = 0.8
    phase_spread: float = 1.0  # fraction of 2π spread across clients

    def __post_init__(self):
        if not 0.0 <= self.depth < 1.0:
            raise ValueError(f"depth must be in [0, 1), got {self.depth}")
        if self.period <= 0:
            raise ValueError(f"period must be > 0, got {self.period}")

    def _availability(self, client_id: int, t: float) -> float:
        phase = 2.0 * math.pi * self.phase_spread * _uniform(
            self.seed, client_id, 0, 0xD1A
        )
        s = 0.5 * (1.0 + math.sin(2.0 * math.pi * t / self.period + phase))
        return (1.0 - self.depth) + self.depth * s

    def first_checkin(self, client_id: int) -> float:
        # stagger starts across the first day so the trough is populated too
        return self.period * _uniform(self.seed, client_id, 0, 0xF1)

    def checkin_delay(self, client_id: int, event_index: int,
                      now: float) -> float:
        base = max(float(self.interval), 1e-3 * self.period)
        return base / self._availability(client_id, now)

    def round_latency(self, client_id: int, event_index: int,
                      now: float) -> float:
        return float(self.latency)


@dataclasses.dataclass(frozen=True)
class TieredTrace(ClientTrace):
    """Latency correlated with device tier (DeviceProfile bitwidths).

    Wraps a ``base`` trace and multiplies its latency by the client tier's
    factor; tier membership is ``client_id % n_tiers`` — the identical
    round-robin striping :class:`repro.federated.engine.CohortSpec` uses, so
    a mixed-bitwidth cohort's slow tier is the *same clients* in both the
    compute model and the transport schedule.  ``multipliers`` defaults from
    the profiles' formats via :func:`tier_multipliers` (coarser format =
    older device = slower).
    """

    base: Optional[ClientTrace] = None  # default: FixedTrace from own fields
    profiles: Tuple = ()  # DeviceProfile per tier (engine.PROFILES values)
    multipliers: Optional[Tuple[float, ...]] = None

    def __post_init__(self):
        if self.base is None:
            # no explicit base: the inherited seed/interval/latency fields
            # seed a FixedTrace, so TieredTrace(latency=5.0, ...) behaves
            # as documented on ClientTrace
            object.__setattr__(
                self, "base",
                FixedTrace(seed=self.seed, interval=self.interval,
                           latency=self.latency),
            )
        elif (self.seed, self.interval, self.latency) != (0, 0.0, 1.0):
            raise ValueError(
                "pass timing via the base trace, not TieredTrace's own "
                "seed/interval/latency fields (they would be ignored)"
            )
        if not self.profiles and self.multipliers is None:
            raise ValueError("TieredTrace needs profiles or multipliers")
        if self.multipliers is None:
            object.__setattr__(
                self, "multipliers", tier_multipliers(self.profiles)
            )
        if self.profiles and len(self.multipliers) != len(self.profiles):
            raise ValueError("one multiplier per profile")

    @property
    def n_tiers(self) -> int:
        return len(self.multipliers)

    def tier_of(self, client_id: int) -> int:
        return int(client_id) % self.n_tiers

    def first_checkin(self, client_id: int) -> float:
        return self.base.first_checkin(client_id)

    def checkin_delay(self, client_id: int, event_index: int,
                      now: float) -> float:
        return self.base.checkin_delay(client_id, event_index, now)

    def round_latency(self, client_id: int, event_index: int,
                      now: float) -> float:
        m = self.multipliers[self.tier_of(client_id)]
        return m * self.base.round_latency(client_id, event_index, now)


def tier_multipliers(profiles: Sequence) -> Tuple[float, ...]:
    """Default tier latency factors from DeviceProfile formats.

    An f32 tier (no transport compression) models the newest hardware at
    1.0x; compressed tiers scale with how much narrower their format is —
    an 8-bit S1E4M3 device runs ~2x slower than flagship, the 11-bit
    S1E3M7 mid-tier ~1.7x.  Purely a simulation default; pass explicit
    ``multipliers`` to calibrate against fleet measurements.
    """
    from repro.core.formats import FloatFormat

    out = []
    for p in profiles:
        fmt = FloatFormat.parse(p.fmt) if p.fmt is not None else None
        if fmt is None or fmt.is_identity:
            out.append(1.0)
        else:
            out.append(1.0 + (32 - fmt.bits) / 24.0)
    return tuple(out)
