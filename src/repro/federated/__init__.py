"""Federated runtime: OMC materialization, jit-able rounds, simulation.

Three execution paths for the paper's loop (DESIGN.md §9 has the guide):
  * :mod:`.simulate` — the per-client reference loop (numerics ground truth),
  * :mod:`.engine` — the vectorized heterogeneous-cohort engine (vmap/scan
    over stacked client states; production-scale cohorts),
  * :mod:`.round` — the jit-able distributed round (multi-pod lowering).
"""

from .materialize import OMCMaterializer, QParam, make_sinks, pack_qparams
from .state import TrainState, init_state, state_bytes_report
from .round import make_round_fn, make_eval_fn
from .cohort import CohortPlan, sample_cohort, survival_mask
from .accounting import WireTable, build_wire_table
from .engine import (
    CohortSpec,
    DeviceProfile,
    PROFILES,
    run_round_vectorized,
    run_training_vectorized,
    sample_tiered_cohort,
)
