"""Federated runtime: OMC materialization, jit-able rounds, simulation."""

from .materialize import OMCMaterializer, QParam, make_sinks, pack_qparams
from .state import TrainState, init_state, state_bytes_report
from .round import make_round_fn, make_eval_fn
from .cohort import CohortPlan, sample_cohort
