"""Federated runtime: OMC materialization, jit-able rounds, simulation.

Four execution paths for the paper's loop (DESIGN.md §9/§10 have the guide):
  * :mod:`.simulate` — the per-client reference loop (numerics ground truth),
  * :mod:`.engine` — the vectorized heterogeneous-cohort engine (vmap/scan
    over stacked client states; production-scale cohorts),
  * :mod:`.async_engine` — the event-driven non-barrier runtime (virtual
    clock, :mod:`.traces` availability/latency models, buffered
    staleness-weighted aggregation; straggler-dominated fleets),
  * :mod:`.round` — the jit-able distributed round (multi-pod lowering).
"""

from .materialize import OMCMaterializer, QParam, make_sinks, pack_qparams
from .state import TrainState, init_state, state_bytes_report
from .round import make_round_fn, make_eval_fn
from .cohort import CohortPlan, sample_cohort, survival_mask
from .accounting import WireTable, build_wire_table
from .cohort import validate_report_goal
from .engine import (
    CohortSpec,
    DeviceProfile,
    PROFILES,
    run_round_vectorized,
    run_training_vectorized,
    sample_tiered_cohort,
)
from .async_engine import (
    AsyncConfig,
    AsyncRunner,
    buffer_weights,
    flush_weights,
    run_async_training,
    staleness_weights,
)
from .traces import (
    ClientTrace,
    DiurnalTrace,
    FixedTrace,
    ParetoTrace,
    TieredTrace,
)
