"""Wire-byte accounting shared by both federated execution paths (DESIGN.md §9).

A :class:`WireTable` is built once per model from the f32 param tree: one row
per policy-selected variable, in the *exact order* ``ppq_mask`` indexes them
(the ``tree_map_with_path`` traversal order used by
:func:`repro.federated.simulate.client_view`).  From it, per-round byte
counts follow from the PPQ masks alone:

  * download — the server's full compressed state (every selected variable
    packed under the server format, everything else f32),
  * upload — a client's transport re-quantization: selected variables whose
    PPQ bit is set travel packed under the *client's* format (heterogeneous
    tiers may use a different bitwidth), masked-out variables travel f32.

The per-leaf sizes are the same ``packed_bytes(n, fmt) + 8 B·(s, b)`` the
wire codec produces, so for any storage tree the table reconciles exactly
with :func:`repro.api.codecs.payload_bytes_report` and with the body of a
serialized full payload (tested in ``tests/test_engine.py``).

Compression strategies (DESIGN.md §11): the same table rows budget any
*shape-determined* strategy from the zoo through
:meth:`WireTable.download_bytes_strategy` /
:meth:`WireTable.upload_bytes_strategy` — per-variable bytes come from
``strategy.plan_wire_bytes(n_elems, stack_entries)``, which the §11
contract obliges to match the serialized body to the byte.
Data-dependent strategies (entropy-coded pipelines) return ``None`` there
and must be measured from an encoded tree via
:func:`repro.compress.tree_wire_bytes` instead; these methods reject them
loudly rather than guessing.

The reference loop (:mod:`repro.federated.simulate`) computes uploads one
scalar ``ppq_mask`` at a time; the vectorized engine
(:mod:`repro.federated.engine`) uses ``ppq_masks_batch`` over the whole
cohort.  The engine equivalence test asserts the two agree to the byte.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Optional, Tuple

import jax
import numpy as np

from repro.core import packing
from repro.core.omc import OMCConfig
from repro.core.partial import ppq_mask, ppq_masks_batch
from repro.core.policy import path_str
from repro.models.common import ParamSpec

from .state import n_stack_axes, selected

_PVT_BYTES_PER_ENTRY = 8  # s and b, f32 each — matches the codec and store

# eager vmap re-traces per call (tens of ms/round — it showed up in
# cohort_scale); the mask computations are pure, so jit them once per shape
_ppq_mask = jax.jit(ppq_mask, static_argnums=(3, 4))
_ppq_masks_batch = jax.jit(ppq_masks_batch, static_argnums=(3, 4))


@dataclasses.dataclass(frozen=True)
class WireTable:
    """Per-selected-variable wire sizes, in PPQ mask-index order."""

    names: Tuple[str, ...]  # selected variable paths
    n_elems: Tuple[int, ...]  # element count per variable
    stack_entries: Tuple[int, ...]  # PVT (s, b) entries (stacked-axis prod)
    raw_bytes: int  # non-selected leaves: f32 wire bytes

    @property
    def num_vars(self) -> int:
        return len(self.names)

    @property
    def fp32_total(self) -> int:
        """Wire bytes of the whole model sent uncompressed."""
        return self.raw_bytes + 4 * sum(self.n_elems)

    def _packed(self, omc: OMCConfig) -> np.ndarray:
        """int64[V]: per-variable bytes when packed under ``omc.fmt``."""
        sb = np.asarray(self.stack_entries if omc.pvt
                        else (1,) * self.num_vars, np.int64)
        packed = np.asarray(
            [packing.packed_bytes(n, omc.fmt) for n in self.n_elems], np.int64
        )
        return packed + _PVT_BYTES_PER_ENTRY * sb

    def _fp32_vars(self) -> np.ndarray:
        return 4 * np.asarray(self.n_elems, np.int64)

    def download_bytes(self, omc: OMCConfig) -> int:
        """One client's full download: the server's compressed-at-rest state."""
        if not omc.enabled:
            return self.fp32_total
        return int(self._packed(omc).sum()) + self.raw_bytes

    def upload_bytes(self, mask, omc: OMCConfig) -> int:
        """One client's transport-compressed upload under its PPQ ``mask``."""
        if not omc.enabled:
            return self.fp32_total
        m = np.asarray(mask, bool)
        if m.shape != (self.num_vars,):
            raise ValueError(
                f"mask has shape {m.shape}, expected ({self.num_vars},)"
            )
        sizes = np.where(m, self._packed(omc), self._fp32_vars())
        return int(sizes.sum()) + self.raw_bytes

    # -- strategy-generic budgeting (DESIGN.md §11) -------------------------

    def strategy_var_bytes(self, strategy) -> np.ndarray:
        """int64[V]: per-variable wire bytes under a zoo strategy.

        Uses ``strategy.plan_wire_bytes`` — exact for shape-determined
        strategies (the §11 contract); raises for data-dependent ones
        (measure those with :func:`repro.compress.tree_wire_bytes`)."""
        rows = [
            strategy.plan_wire_bytes(n, sb)
            for n, sb in zip(self.n_elems, self.stack_entries)
        ]
        if any(r is None for r in rows):
            raise ValueError(
                f"strategy {strategy.name!r} has data-dependent wire bytes; "
                f"measure an encoded tree with repro.compress.tree_wire_bytes"
            )
        return np.asarray(rows, np.int64)

    def download_bytes_strategy(self, strategy) -> int:
        """Full-model download bytes with every selected var under
        ``strategy`` (equals ``download_bytes(omc)`` for the OMC strategy —
        byte-exact, tested)."""
        return int(self.strategy_var_bytes(strategy).sum()) + self.raw_bytes

    def upload_bytes_strategy(self, strategy, mask=None) -> int:
        """Upload bytes under ``strategy``; an optional PPQ-style ``mask``
        sends masked-out variables f32 (OMC transport semantics)."""
        sizes = self.strategy_var_bytes(strategy)
        if mask is not None:
            m = np.asarray(mask, bool)
            if m.shape != (self.num_vars,):
                raise ValueError(
                    f"mask has shape {m.shape}, expected ({self.num_vars},)"
                )
            sizes = np.where(m, sizes, self._fp32_vars())
        return int(sizes.sum()) + self.raw_bytes


def walk_selected(params_f32, specs, omc: OMCConfig):
    """The canonical traversal behind every PPQ mask index.

    Returns ``([(name, spec, leaf)] for selected variables, raw f32 bytes of
    everything else)``.  The list order IS the ``ppq_mask`` index order —
    ``simulate.client_view``, ``engine.masked_upload_tree``, and
    :func:`build_wire_table` all derive from this one function so the three
    can never disagree about which mask bit gates which variable.
    """
    sel, raw = [], 0

    def visit(path, spec, leaf):
        nonlocal raw
        if selected(omc, path_str(path), spec, leaf):
            sel.append((path_str(path), spec, leaf))
        elif hasattr(leaf, "size"):
            raw += 4 * int(leaf.size)
        return leaf

    jax.tree_util.tree_map_with_path(
        visit, specs, params_f32, is_leaf=lambda s: isinstance(s, ParamSpec)
    )
    return sel, raw


def selected_names(params_f32, specs, omc: OMCConfig):
    """Selected variable paths in PPQ mask-index order."""
    return [name for name, _, _ in walk_selected(params_f32, specs, omc)[0]]


def build_wire_table(params_f32, specs, omc: OMCConfig) -> WireTable:
    """One table per model; valid for every round (shapes are static)."""
    sel, raw = walk_selected(params_f32, specs, omc)
    names, n_elems, stacks = [], [], []
    for name, spec, leaf in sel:
        names.append(name)
        n_elems.append(int(leaf.size))
        k = n_stack_axes(spec, leaf)
        stacks.append(int(np.prod(leaf.shape[:k])) if k else 1)
    return WireTable(tuple(names), tuple(n_elems), tuple(stacks), raw)


def client_upload_bytes(
    table: WireTable, omc: OMCConfig, round_index, client_id
) -> int:
    """Scalar path (the reference loop): one client's upload bytes."""
    if not omc.enabled or table.num_vars == 0:
        return table.fp32_total
    mask = _ppq_mask(omc.ppq_key(), round_index, client_id, table.num_vars,
                     omc.quantize_fraction)
    return table.upload_bytes(mask, omc)


# -- training-under-strategy accounting (DESIGN.md §12) ----------------------


def client_upload_bytes_strategy(
    table: WireTable, omc: OMCConfig, strategy, round_index, client_id
) -> int:
    """One client's upload bytes when *training* under a zoo strategy.

    Same PPQ transport semantics as :func:`client_upload_bytes` — variables
    whose mask bit is set travel strategy-encoded, the rest f32 — with the
    per-variable sizes drawn from ``strategy.plan_wire_bytes``.  For
    ``strategy=get_strategy("omc")`` matching ``omc`` this is byte-exact to
    the classic path (gated in ``tests/test_train_strategy.py``).  Raises
    for data-dependent strategies (pipeline): train those with wire
    accounting off and measure encoded payloads instead."""
    if not omc.enabled or table.num_vars == 0:
        return table.fp32_total
    mask = _ppq_mask(omc.ppq_key(), round_index, client_id, table.num_vars,
                     omc.quantize_fraction)
    return table.upload_bytes_strategy(strategy, mask)


def cohort_upload_bytes_strategy(
    table: WireTable, omc: OMCConfig, strategy, round_index, client_ids
) -> np.ndarray:
    """Batched (engine) counterpart of :func:`client_upload_bytes_strategy`."""
    c = int(np.asarray(client_ids).shape[0])
    if not omc.enabled or table.num_vars == 0:
        return np.full((c,), table.fp32_total, np.int64)
    masks = np.asarray(
        _ppq_masks_batch(omc.ppq_key(), round_index, client_ids,
                         table.num_vars, omc.quantize_fraction),
        bool,
    )
    sizes = table.strategy_var_bytes(strategy)
    fp32v = table._fp32_vars()
    per_var = np.where(masks, sizes[None, :], fp32v[None, :])
    return per_var.sum(axis=1) + table.raw_bytes


def download_bytes_train(table: WireTable, omc: OMCConfig, strategy) -> int:
    """Per-client download bytes when training under ``strategy`` (§12).

    Upload-only strategies (top-k / ternary / pipeline) never compress the
    download direction — the client trains on the dense at-rest state, so
    the download costs the ordinary ``download_bytes(omc)``.  Dense
    strategies re-encode the download under their own format."""
    if strategy is None or strategy.upload_only:
        return table.download_bytes(omc)
    return table.download_bytes_strategy(strategy)


@dataclasses.dataclass
class AsyncWireStats:
    """Wire-byte ledger for the non-barrier runtime (DESIGN.md §10).

    The sync paths account per round: a round's bytes are known the moment
    it closes.  The async runtime has no rounds — downloads and uploads
    interleave across server versions — so this ledger tracks bytes at
    event granularity and splits uploads by *staleness*: an upload whose
    base version is behind the server at arrival still costs full wire
    bytes but carries a decayed weight (``stale_up_bytes``), and one past
    ``max_staleness`` is pure waste (``dropped_up_bytes``).  ``in_flight``
    is the byte volume of started-but-unfinished client rounds (download
    issued + the upload it commits to), whose peak bounds the transport
    buffering a deployment must provision.

    Sizes come from the same :class:`WireTable` rows as the sync paths, so
    async totals reconcile byte-exactly with
    :func:`repro.api.codecs.payload_bytes_report` (tested in
    ``tests/test_async_engine.py``).

    ``strategy`` switches the ledger to training-under-strategy wire sizes
    (DESIGN.md §12): uploads are budgeted per ``(round_index, client_id)``
    PPQ mask through :func:`client_upload_bytes_strategy` — exactly what
    the async runtime's client bodies send — and downloads through
    :func:`download_bytes_train` (upload-only strategies download the
    dense at-rest state; dense strategies re-encode it).  For the OMC
    strategy this reproduces the classic ledger byte-exactly.
    """

    table: WireTable
    strategy: Optional[Any] = None
    down_bytes: int = 0
    up_bytes: int = 0  # arrived fresh (staleness == 0), counted in up_bytes
    stale_up_bytes: int = 0  # arrived with staleness > 0 (subset of up_bytes)
    dropped_up_bytes: int = 0  # discarded past max_staleness (NOT in up_bytes)
    in_flight_bytes: int = 0
    peak_in_flight_bytes: int = 0
    n_downloads: int = 0
    n_uploads: int = 0
    n_stale: int = 0
    n_dropped: int = 0
    _pending: dict = dataclasses.field(default_factory=dict, repr=False)

    def _down(self, omc: OMCConfig) -> int:
        return download_bytes_train(self.table, omc, self.strategy)

    def _up(self, omc: OMCConfig, round_index: int, client_id: int) -> int:
        if self.strategy is not None:
            return client_upload_bytes_strategy(
                self.table, omc, self.strategy, round_index, client_id
            )
        return client_upload_bytes(self.table, omc, round_index, client_id)

    def start_round(self, omc: OMCConfig, round_index: int,
                    client_id: int) -> None:
        """Client checked in: full download now, upload bytes committed.

        ``round_index`` is the client's own round counter (it keys the
        PPQ/transport mask), not the server version."""
        down = self._down(omc)
        up = self._up(omc, round_index, client_id)
        self.down_bytes += down
        self.n_downloads += 1
        self._pending[client_id] = down + up
        self.in_flight_bytes += down + up
        self.peak_in_flight_bytes = max(self.peak_in_flight_bytes,
                                        self.in_flight_bytes)

    def finish_round(self, omc: OMCConfig, round_index: int, client_id: int,
                     staleness: int, dropped: bool = False) -> int:
        """Client's upload arrived; returns its wire bytes."""
        up = self._up(omc, round_index, client_id)
        self.in_flight_bytes -= self._pending.pop(client_id)
        if dropped:
            self.dropped_up_bytes += up
            self.n_dropped += 1
            return up
        self.up_bytes += up
        self.n_uploads += 1
        if staleness > 0:
            self.stale_up_bytes += up
            self.n_stale += 1
        return up

    def snapshot(self) -> dict:
        """Point-in-time ledger state with stable derived keys.

        ``stale_fraction`` is the share of *accepted* upload bytes that
        arrived stale; ``dropped_fraction`` the share of all finished
        upload bytes that were discarded past ``max_staleness``.  Both are
        0.0 before any upload finishes.  These keys (plus
        ``peak_in_flight_bytes``) are the stable surface
        ``benchmarks/async_scale.py`` and the obs report read —
        renaming them is a schema break (DESIGN.md §15).
        """
        finished = self.up_bytes + self.dropped_up_bytes
        return dict(
            down_bytes=int(self.down_bytes),
            up_bytes=int(self.up_bytes),
            stale_up_bytes=int(self.stale_up_bytes),
            dropped_up_bytes=int(self.dropped_up_bytes),
            in_flight_bytes=int(self.in_flight_bytes),
            peak_in_flight_bytes=int(self.peak_in_flight_bytes),
            n_downloads=int(self.n_downloads),
            n_uploads=int(self.n_uploads),
            n_stale=int(self.n_stale),
            n_dropped=int(self.n_dropped),
            stale_fraction=(
                float(self.stale_up_bytes / self.up_bytes)
                if self.up_bytes else 0.0
            ),
            dropped_fraction=(
                float(self.dropped_up_bytes / finished) if finished else 0.0
            ),
        )


@dataclasses.dataclass
class StreamLedger:
    """Peak-memory ledger for the fixed-capacity streamed round (§14).

    The :class:`AsyncWireStats` counterpart for *resident bytes* instead of
    wire bytes: the streamed path's contract is that peak live model state
    is a function of the stream ``capacity`` alone — never of the cohort or
    population size.  :meth:`peak_bound_bytes` states that bound
    analytically from the same :class:`WireTable` rows every other ledger
    uses:

      * the compressed-at-rest server storage (``download_bytes``),
      * its transient f32 decode (``fp32_total``),
      * one ``capacity``-wide stacked chunk of client models,
      * one f32 partial-sum accumulator tree.

    ``on_chunk`` records actual streaming (and optionally a measured
    live-bytes sample from the instrumentation hook); the benchmark asserts
    the bound is constant across a 1k→100k population sweep and that
    measured peaks respect it (``benchmarks/population_scale.py``).
    """

    table: WireTable
    omc: OMCConfig
    capacity: int
    chunks: int = 0
    clients_streamed: int = 0
    peak_measured_bytes: int = 0

    def __post_init__(self):
        if self.capacity < 1:
            raise ValueError(f"capacity must be >= 1, got {self.capacity}")

    @property
    def chunk_stack_bytes(self) -> int:
        """One fixed-width stacked chunk of f32 client models."""
        return self.capacity * self.table.fp32_total

    @property
    def accumulator_bytes(self) -> int:
        """The running f32 partial-sum tree (one model's worth)."""
        return self.table.fp32_total

    def peak_bound_bytes(self) -> int:
        """Analytic peak resident model bytes — capacity-determined only."""
        return (self.table.download_bytes(self.omc)  # storage at rest
                + self.table.fp32_total  # transient server decode
                + self.chunk_stack_bytes
                + self.accumulator_bytes)

    def on_chunk(self, n_real: int, measured_bytes: Optional[int] = None
                 ) -> None:
        if not 1 <= n_real <= self.capacity:
            raise ValueError(
                f"chunk holds {n_real} clients, capacity is {self.capacity}"
            )
        self.chunks += 1
        self.clients_streamed += n_real
        if measured_bytes is not None:
            self.peak_measured_bytes = max(self.peak_measured_bytes,
                                           int(measured_bytes))

    def snapshot(self) -> dict:
        return dict(
            capacity=int(self.capacity),
            chunks=int(self.chunks),
            clients_streamed=int(self.clients_streamed),
            chunk_stack_bytes=int(self.chunk_stack_bytes),
            accumulator_bytes=int(self.accumulator_bytes),
            peak_bound_bytes=int(self.peak_bound_bytes()),
            peak_measured_bytes=int(self.peak_measured_bytes),
        )


def cohort_upload_bytes(
    table: WireTable, omc: OMCConfig, round_index, client_ids
) -> np.ndarray:
    """Batched path (the engine): int64[C] upload bytes, one per client."""
    c = int(np.asarray(client_ids).shape[0])
    if not omc.enabled or table.num_vars == 0:
        return np.full((c,), table.fp32_total, np.int64)
    masks = np.asarray(
        _ppq_masks_batch(omc.ppq_key(), round_index, client_ids,
                         table.num_vars, omc.quantize_fraction),
        bool,
    )
    packed = table._packed(omc)
    fp32v = table._fp32_vars()
    per_var = np.where(masks, packed[None, :], fp32v[None, :])
    return per_var.sum(axis=1) + table.raw_bytes
