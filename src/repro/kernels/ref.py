"""Pure-jnp oracles for every Pallas kernel in this package.

Each kernel's test sweeps shapes/dtypes and asserts allclose against these.
They are also the CPU fallback used by ``ops.py`` when Pallas interpret mode
is not wanted (e.g. inside hot benchmark loops).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.formats import FloatFormat, decode, encode, value_quantize
from repro.core.pvt import pvt_apply


def ref_quantize(x: jax.Array, fmt: FloatFormat) -> jax.Array:
    """f32 -> bitfield codes (RNE, subnormal-aware, saturating)."""
    return encode(x, fmt, quantize=True)


def ref_dequantize(codes: jax.Array, fmt: FloatFormat, s=None, b=None) -> jax.Array:
    """codes -> f32, optionally fused with the PVT affine (s·x + b)."""
    out = decode(codes, fmt)
    if s is not None:
        out = pvt_apply(out, s, b if b is not None else jnp.float32(0))
    return out


def ref_dequant_matmul(
    a: jax.Array,
    w_codes: jax.Array,
    fmt: FloatFormat,
    s: jax.Array,
    b: jax.Array,
    *,
    out_dtype=jnp.float32,
) -> jax.Array:
    """a[M,K] @ (s·decode(w_codes[K,N]) + b) with f32 accumulation."""
    w = pvt_apply(decode(w_codes, fmt), s, b)
    return jnp.dot(
        a.astype(jnp.float32), w, preferred_element_type=jnp.float32
    ).astype(out_dtype)


def ref_quantize_stats(x: jax.Array, fmt: FloatFormat):
    """Fused quantize + PVT statistics.

    Returns (codes, sums) where sums = [Σv, Σṽ, Σv·ṽ, Σṽ²] as f32.
    """
    vq = value_quantize(x, fmt)
    codes = encode(vq, fmt, quantize=False)
    v = x.astype(jnp.float32).reshape(-1)
    q = vq.astype(jnp.float32).reshape(-1)
    sums = jnp.stack([v.sum(), q.sum(), (v * q).sum(), (q * q).sum()])
    return codes, sums
