"""Pure-jnp oracles for every Pallas kernel in this package.

Each kernel's test sweeps shapes/dtypes and asserts allclose against these.
They are also the CPU fallback used by ``ops.py`` when Pallas interpret mode
is not wanted (e.g. inside hot benchmark loops).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

import numpy as np

from repro.core.formats import FloatFormat, decode, encode, value_quantize
from repro.core.pvt import pvt_apply, pvt_solve_fast


def ref_quantize(x: jax.Array, fmt: FloatFormat) -> jax.Array:
    """f32 -> bitfield codes (RNE, subnormal-aware, saturating)."""
    return encode(x, fmt, quantize=True)


def ref_dequantize(codes: jax.Array, fmt: FloatFormat, s=None, b=None) -> jax.Array:
    """codes -> f32, optionally fused with the PVT affine (s·x + b)."""
    out = decode(codes, fmt)
    if s is not None:
        out = pvt_apply(out, s, b if b is not None else jnp.float32(0))
    return out


def ref_dequant_matmul(
    a: jax.Array,
    w_codes: jax.Array,
    fmt: FloatFormat,
    s: jax.Array,
    b: jax.Array,
    *,
    out_dtype=jnp.float32,
) -> jax.Array:
    """a[M,K] @ (s·decode(w_codes[K,N]) + b) with f32 accumulation."""
    w = pvt_apply(decode(w_codes, fmt), s, b)
    return jnp.dot(
        a.astype(jnp.float32), w, preferred_element_type=jnp.float32
    ).astype(out_dtype)


def ref_quantize_stats(x: jax.Array, fmt: FloatFormat):
    """Fused quantize + PVT statistics.

    Returns (codes, sums) where sums = [Σv, Σṽ, Σv·ṽ, Σṽ²] as f32.
    """
    vq = value_quantize(x, fmt)
    codes = encode(vq, fmt, quantize=False)
    v = x.astype(jnp.float32).reshape(-1)
    q = vq.astype(jnp.float32).reshape(-1)
    sums = jnp.stack([v.sum(), q.sum(), (v * q).sum(), (q * q).sum()])
    return codes, sums


def ref_pack(codes: jax.Array, width: int) -> jax.Array:
    """Canonical exact-width bitstream — delegates to ``core.packing.pack``."""
    from repro.core import packing

    return packing._pack_jnp(codes, width)


def ref_unpack(words: jax.Array, width: int, n: int) -> jax.Array:
    """Inverse of :func:`ref_pack` — delegates to ``core.packing.unpack``."""
    from repro.core import packing

    return packing._unpack_jnp(words, width, n)


def ref_fused_aggregate(
    srv_codes: jax.Array,
    srv_s: jax.Array,
    srv_b: jax.Array,
    cl_codes: jax.Array,
    cl_s: jax.Array,
    cl_b: jax.Array,
    weights: jax.Array,
    lr,
    fmt: FloatFormat,
    *,
    batch_axes: int = 0,
):
    """Unfused oracle for ``agg.fused_aggregate`` (see DESIGN.md §13).

    Decode every client row (s_c·decode(codes_c) + b_c), zero dead rows,
    weighted-mean, interpolate into the decoded server value, then
    re-quantize and re-solve PVT exactly like
    ``compress_variable(..., fast=True)``.  Element codes match the Pallas
    kernel except for round-to-nearest-even boundary ties, where f32
    reassociation may pick the adjacent code on a tiny fringe; (s, b) may
    differ by f32 reduction-order noise only.
    """
    def bcast(v, ndim):
        # pad trailing axes: per-client/per-entry scalars broadcast from the
        # left (e.g. (C,) against (C,) + leaf shape)
        v = jnp.asarray(v, jnp.float32)
        return v.reshape(v.shape + (1,) * (ndim - v.ndim))

    old = pvt_apply(decode(srv_codes, fmt), bcast(srv_s, srv_codes.ndim),
                    bcast(srv_b, srv_codes.ndim))
    x = pvt_apply(decode(cl_codes, fmt), bcast(cl_s, cl_codes.ndim),
                  bcast(cl_b, cl_codes.ndim))
    w = jnp.asarray(weights, jnp.float32)
    wb = w.reshape((-1,) + (1,) * old.ndim)
    x = jnp.where(wb > 0, x, 0.0)  # dead rows: where, so NaN cannot leak
    acc = jnp.sum(x * wb, axis=0) / jnp.maximum(jnp.sum(w), 1e-9)
    new = old + jnp.float32(lr) * (acc - old)
    vq = value_quantize(new, fmt)
    codes = encode(vq, fmt, quantize=False)
    s, b = pvt_solve_fast(new, vq, batch_axes)
    return codes, s, b
