"""Pallas TPU kernel: A[M,K] @ dequant(Wq[K,N]) — serving's hot matmul.

OMC keeps weights compressed in HBM.  At decode time the matmul is
HBM-bandwidth-bound on the weight stream, so the win is *reading the codes*
(u8/u16/u32) out of HBM and decompressing per-VMEM-tile right before the
MXU — the f32 weights never exist in HBM (paper Fig. 1, TPU-native form;
DESIGN.md §2).

Grid (nm, nn, nk) with k innermost; BlockSpecs stream
    A   (bm, bk) tiles   [M-major]
    Wq  (bk, bn) tiles   (codes, in their uint container)
    out (bm, bn) tiles, f32 accumulation in a VMEM scratch.
Tile defaults (bm=bn=bk=256 for f32/u16) keep the working set
(bm·bk·4 + bk·bn·(2+4) + 2·bm·bn·4 ≈ 2.8 MiB) well inside the ~16 MiB VMEM
with MXU-aligned (128-multiple) dims.

The PVT affine (s, b) is fused into the tile decode.  ``bias=b`` requires
care: W = s·dec(C) + b makes A @ W = s·(A @ dec(C)) + (A·1)·b — the kernel
computes the row-sums of A on the fly for the rank-1 correction.
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core.formats import FloatFormat, decode as _jnp_decode


def _dequant_matmul_kernel(a_ref, w_ref, s_ref, b_ref, o_ref, acc_ref,
                           rowsum_ref, *, fmt: FloatFormat, nk: int):
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        rowsum_ref[...] = jnp.zeros_like(rowsum_ref)

    a = a_ref[...].astype(jnp.float32)
    w = _jnp_decode(w_ref[...], fmt)  # codes tile -> f32 in VMEM
    acc_ref[...] += jax.lax.dot(a, w, preferred_element_type=jnp.float32)
    rowsum_ref[...] += jnp.sum(a, axis=1, keepdims=True)

    @pl.when(k == nk - 1)
    def _done():
        s = s_ref[0, 0]
        b = b_ref[0, 0]
        # A @ (s·W + b·1) = s·(A @ W) + b·rowsum(A)·1^T
        o_ref[...] = s * acc_ref[...] + b * rowsum_ref[...]


def dequant_matmul(
    a: jax.Array,  # [M, K] f32/bf16
    w_codes: jax.Array,  # [K, N] uint container
    fmt: FloatFormat,
    s=None,
    b=None,
    *,
    bm: int = 256,
    bn: int = 256,
    bk: int = 256,
    interpret: bool = False,
) -> jax.Array:
    """A @ (s·decode(w_codes) + b), f32 accumulation, tiled for VMEM/MXU."""
    m, k = a.shape
    k2, n = w_codes.shape
    assert k == k2, (a.shape, w_codes.shape)
    bm_, bn_, bk_ = min(bm, m), min(bn, n), min(bk, k)
    # shrink to divisors (kernel assumes exact tiling; pad if needed)
    pad_m, pad_n, pad_k = (-m) % bm_, (-n) % bn_, (-k) % bk_
    if pad_m or pad_k:
        a = jnp.pad(a, ((0, pad_m), (0, pad_k)))
    if pad_k or pad_n:
        w_codes = jnp.pad(w_codes, ((0, pad_k), (0, pad_n)))
    mp, kp = a.shape
    np_ = w_codes.shape[1]
    nm, nn, nk = mp // bm_, np_ // bn_, kp // bk_
    s_arr = jnp.full((1, 1), 1.0 if s is None else s, jnp.float32)
    b_arr = jnp.full((1, 1), 0.0 if b is None else b, jnp.float32)

    out = pl.pallas_call(
        functools.partial(_dequant_matmul_kernel, fmt=fmt, nk=nk),
        grid=(nm, nn, nk),
        in_specs=[
            pl.BlockSpec((bm_, bk_), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bk_, bn_), lambda i, j, kk: (kk, j)),
            pl.BlockSpec((1, 1), lambda i, j, kk: (0, 0)),
            pl.BlockSpec((1, 1), lambda i, j, kk: (0, 0)),
        ],
        out_specs=pl.BlockSpec((bm_, bn_), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((mp, np_), jnp.float32),
        scratch_shapes=[
            pltpu.VMEM((bm_, bn_), jnp.float32),
            pltpu.VMEM((bm_, 1), jnp.float32),
        ],
        interpret=interpret,
    )(a, w_codes, s_arr, b_arr)
    return out[:m, :n]
