"""Pallas TPU kernels: exact-width bitstream pack/unpack on-device.

``core.packing`` implements the wire bitstream with a scatter-add (pack) and
a dynamic gather (unpack) — fine as a jnp oracle, but scatters serialize on
TPU and the gather defeats fusion.  These kernels reformulate both directions
as fully *static* dataflow so the whole pack/unpack runs as vectorized VPU
work at HBM bandwidth:

  Superblock layout.  For a w-bit field width let L = lcm(32, w).  A block of
  ``P_f = L // w`` consecutive fields occupies exactly ``P_w = L // 32``
  consecutive uint32 words, and *no field crosses a block boundary*.  Within
  a block the field -> (word, shift) mapping is a compile-time constant, so
  both directions unroll into static column slices + scalar shifts:

  * pack:   word j ORs together the in-word contributions of the (statically
    known) fields that land in it — the same ``(f << sh)`` / ``(f >> (31-sh))
    >> 1`` low/high split as ``core.packing.pack``.  Contributed bits are
    disjoint, so the combine is a plain OR — no scatter.
  * unpack: field i reads its containing word and that word's successor
    (clamped to the block edge; the clamp is harmless because a non-crossing
    field's high part is zeroed by the final ``& (2**w - 1)`` mask, mirroring
    the oracle's appended zero word).

Bit-identity with ``core.packing`` is exact by construction: the packed
stream is *canonical* — unique given the field values and zero tail padding —
and both implementations emit it.  Property-tested over every format in the
zoo (and 2-bit ternary) in tests/test_bitpack.py, interpret mode on CPU.

Contract details (bit layout, tail semantics): DESIGN.md §13.
"""

from __future__ import annotations

import functools
import math
from typing import Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl

from repro.core.packing import packed_words

_M32 = np.uint32(0xFFFFFFFF)
# Target lane count per grid step; rounded so blocks stay row-aligned.
_TARGET_LANES = 2048


@functools.lru_cache(maxsize=None)
def _geometry(width: int) -> Tuple[int, int, int]:
    """(fields_per_block, words_per_block, block_rows_per_grid_step)."""
    lcm = (32 * width) // math.gcd(32, width)
    p_f = lcm // width
    p_w = lcm // 32
    rows = max(_TARGET_LANES // p_f, 1)
    rows = -(-rows // 8) * 8  # sublane-aligned
    return p_f, p_w, rows


def _pack_kernel(f_ref, o_ref, *, width: int):
    p_f, p_w, _ = _geometry(width)
    f = f_ref[...]  # (R, P_f) uint32
    cols = []
    for j in range(p_w):
        acc = None
        for i in range(p_f):
            word, sh = (i * width) // 32, (i * width) % 32
            c = f[:, i : i + 1]
            if word == j:
                term = (c << np.uint32(sh)) & _M32
            elif word + 1 == j and sh + width > 32:  # field crosses into j
                # field >> (32-sh) is UB at sh == 0; the two-step shift is safe
                term = (c >> np.uint32(31 - sh)) >> np.uint32(1)
            else:
                continue
            acc = term if acc is None else (acc | term)
        cols.append(acc)
    o_ref[...] = jnp.concatenate(cols, axis=1)


def _unpack_kernel(w_ref, o_ref, *, width: int):
    p_f, p_w, _ = _geometry(width)
    mask = np.uint32((1 << width) - 1) if width < 32 else _M32
    w = w_ref[...]  # (R, P_w) uint32
    cols = []
    for i in range(p_f):
        word, sh = (i * width) // 32, (i * width) % 32
        lo = w[:, word : word + 1] >> np.uint32(sh)
        nxt = min(word + 1, p_w - 1)  # edge clamp; high bits masked off below
        hi = (w[:, nxt : nxt + 1] << np.uint32(31 - sh)) << np.uint32(1)
        cols.append((lo | hi) & mask)
    o_ref[...] = jnp.concatenate(cols, axis=1)


def pack(codes: jax.Array, width: int, *, interpret: bool = False) -> jax.Array:
    """Pack ``codes`` (values < 2**width) into the exact uint32 bitstream.

    Bit-identical to ``core.packing.pack`` (the canonical layout).
    """
    if not (1 <= width <= 32):
        raise ValueError(f"width must be in [1, 32], got {width}")
    p_f, p_w, rows = _geometry(width)
    flat = codes.reshape(-1).astype(jnp.uint32)
    n = flat.shape[0]
    nblocks = -(-max(n, 1) // p_f)
    nblocks = -(-nblocks // rows) * rows
    flat = jnp.pad(flat, (0, nblocks * p_f - n))
    out = pl.pallas_call(
        functools.partial(_pack_kernel, width=width),
        grid=(nblocks // rows,),
        in_specs=[pl.BlockSpec((rows, p_f), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((rows, p_w), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((nblocks, p_w), jnp.uint32),
        interpret=interpret,
    )(flat.reshape(nblocks, p_f))
    return out.reshape(-1)[: packed_words(n, width)]


def unpack(words: jax.Array, width: int, n: int, *, interpret: bool = False) -> jax.Array:
    """Inverse of :func:`pack`: recover ``n`` codes of ``width`` bits (uint32)."""
    if not (1 <= width <= 32):
        raise ValueError(f"width must be in [1, 32], got {width}")
    p_f, p_w, rows = _geometry(width)
    flat = words.reshape(-1).astype(jnp.uint32)
    nblocks = -(-max(n, 1) // p_f)
    nblocks = -(-nblocks // rows) * rows
    # Zero tail padding == the oracle's appended zero word.
    flat = jnp.pad(flat, (0, nblocks * p_w - flat.shape[0]))
    out = pl.pallas_call(
        functools.partial(_unpack_kernel, width=width),
        grid=(nblocks // rows,),
        in_specs=[pl.BlockSpec((rows, p_w), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((rows, p_f), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((nblocks, p_f), jnp.uint32),
        interpret=interpret,
    )(flat.reshape(nblocks, p_w))
    return out.reshape(-1)[:n]


def pack_moved_bytes(n: int, width: int) -> int:
    """HBM bytes the pack kernel actually moves (padded operands + result)."""
    p_f, p_w, rows = _geometry(width)
    nblocks = -(-max(n, 1) // p_f)
    nblocks = -(-nblocks // rows) * rows
    return 4 * nblocks * p_f + 4 * nblocks * p_w


def unpack_moved_bytes(n: int, width: int) -> int:
    """HBM bytes the unpack kernel actually moves (padded operands + result)."""
    return pack_moved_bytes(n, width)
