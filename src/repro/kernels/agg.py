"""Pallas TPU kernel: fused compressed-domain cohort aggregation.

The unfused server round materializes f32 cohort state three times per
selected variable: decode every client upload, weighted-average, interpolate
into the decoded server value, then re-quantize + re-solve PVT.  For a
cohort of C clients that is (C + 1) f32 HBM round trips of the full variable.
This kernel fuses the whole chain —

    dequant(client codes) -> mask dead rows -> weighted mean
        -> server interpolation -> value_quantize -> encode + PVT sums

— into one pass: codes stream HBM->VMEM, every f32 intermediate lives only
in the (C, TILE) VMEM working set, and the outputs are the new server codes
plus the four PVT sums (Σv, Σṽ, Σvṽ, Σṽ²) per stacked entry.  The (s, b)
affine is solved from those sums outside the kernel with the exact
``pvt_solve_fast`` closed form.

Semantics (the contract the engine equivalence gate enforces — DESIGN.md §13):
  * client row c is reconstructed as ``s_c · decode(codes_c) + b_c``;
  * dead clients (weight <= 0) are zeroed *before* the weighted mean — the
    same ``where(alive, x, 0)`` the unfused engine applies, so NaN/garbage
    in failed-client rows never propagates;
  * weighted mean divides by ``max(Σw, 1e-9)`` (``cohort.aggregate_weighted``);
  * the new server value is ``old + lr·(mean − old)`` and is re-quantized
    with round-to-nearest-even via ``value_quantize`` — identical rounding to
    the unfused ``compress_variable`` path;
  * PVT sums are masked to the true element count (tail padding decodes to
    the padded-code value and would otherwise bias the solve).

Validated in interpret mode against ``ref.ref_fused_aggregate`` (and, at the
engine level, against the unfused round) in tests/test_kernels.py.
"""

from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl

from repro.core.formats import (
    FloatFormat,
    decode as _jnp_decode,
    encode as _jnp_encode,
    value_quantize as _jnp_value_quantize,
)

TILE = 1024  # lane-dim tile (multiple of 128), matches kernels/quantize.py


def _fused_kernel(srv_ref, ss_ref, sb_ref, cl_ref, cs_ref, cb_ref, w_ref,
                  lr_ref, o_ref, sums_ref, *, fmt: FloatFormat, m: int):
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        sums_ref[...] = jnp.zeros_like(sums_ref)

    w = w_ref[...]  # (C, 1)
    wsum = jnp.maximum(jnp.sum(w), 1e-9)
    old = _jnp_decode(srv_ref[...], fmt) * ss_ref[0, 0] + sb_ref[0, 0]  # (1, T)
    x = _jnp_decode(cl_ref[...][:, 0, :], fmt)  # (C, T)
    x = x * cs_ref[...] + cb_ref[...]
    # Zero dead rows BEFORE the mean — mirrors engine's where(alive, x, 0);
    # where (not multiply) so NaN in failed-client rows cannot propagate.
    x = jnp.where(w > 0, x, 0.0)
    acc = jnp.sum(x * w, axis=0, keepdims=True) / wsum
    new = old + lr_ref[0, 0] * (acc - old)
    vq = _jnp_value_quantize(new, fmt)
    o_ref[...] = _jnp_encode(vq, fmt, quantize=False)
    # PVT sums over true elements only: the padded tail decodes to the
    # padded-code value, not 0, and would bias the affine solve.
    col = jax.lax.broadcasted_iota(jnp.int32, (1, new.shape[1]), 1)
    valid = col + j * new.shape[1] < m
    nv = jnp.where(valid, new, 0.0)
    qv = jnp.where(valid, vq, 0.0)
    sums_ref[0, 0] += jnp.sum(nv)
    sums_ref[0, 1] += jnp.sum(qv)
    sums_ref[0, 2] += jnp.sum(nv * qv)
    sums_ref[0, 3] += jnp.sum(qv * qv)


def _solve_from_sums(sums: jax.Array, n: int) -> Tuple[jax.Array, jax.Array]:
    """(s, b) per stacked entry from [SB, 4] sums — pvt_solve_fast closed form."""
    s_v, s_q, s_vq, s_qq = sums[:, 0], sums[:, 1], sums[:, 2], sums[:, 3]
    nf = jnp.float32(n)
    den = nf * s_qq - s_q * s_q
    num = nf * s_vq - s_v * s_q
    degenerate = den <= 0
    s = jnp.where(degenerate, 1.0, num / jnp.where(degenerate, 1.0, den))
    b = (s_v - s * s_q) / nf
    return s.astype(jnp.float32), b.astype(jnp.float32)


def _col(x, sb: int) -> jax.Array:
    """PVT scalar (scalar or per-stacked-entry) -> (SB, 1) f32."""
    x = jnp.asarray(x, jnp.float32)
    if x.size == sb:
        return x.reshape(sb, 1)
    return jnp.full((sb, 1), x.reshape(()))


def _ccol(x, c: int, sb: int) -> jax.Array:
    """Per-client PVT scalar (per-client or per-(client, entry)) -> (C, SB)."""
    x = jnp.asarray(x, jnp.float32)
    if x.size == c * sb:
        return x.reshape(c, sb)
    return jnp.broadcast_to(x.reshape(c, 1), (c, sb))


def fused_aggregate(
    srv_codes: jax.Array,
    srv_s: jax.Array,
    srv_b: jax.Array,
    cl_codes: jax.Array,
    cl_s: jax.Array,
    cl_b: jax.Array,
    weights: jax.Array,
    lr,
    fmt: FloatFormat,
    *,
    batch_axes: int = 0,
    interpret: bool = False,
):
    """One variable's server round, entirely in the compressed domain.

    srv_codes: leaf-shaped container codes; cl_codes: (C,) + leaf shape;
    (srv_s, srv_b) / (cl_s, cl_b): the matching PVT scalars (scalar or
    per-stacked-entry with ``batch_axes`` leading stacked axes); weights: (C,)
    f32 aggregation weights (0 == dead client).  Returns (new_codes, s, b)
    shaped exactly like the unfused ``compress_variable(..., fast=True)``
    output on the aggregated tree.
    """
    shape = srv_codes.shape
    sb = int(np.prod(shape[:batch_axes])) if batch_axes else 1
    m = int(srv_codes.size) // sb
    c = int(cl_codes.shape[0])
    m_pad = -(-m // TILE) * TILE

    srv2 = srv_codes.reshape(sb, m).astype(fmt.container_dtype)
    cl2 = cl_codes.reshape(c, sb, m).astype(fmt.container_dtype)
    srv2 = jnp.pad(srv2, ((0, 0), (0, m_pad - m)))
    cl2 = jnp.pad(cl2, ((0, 0), (0, 0), (0, m_pad - m)))
    ss, sbias = _col(srv_s, sb), _col(srv_b, sb)
    cs, cb = _ccol(cl_s, c, sb), _ccol(cl_b, c, sb)
    w2 = jnp.asarray(weights, jnp.float32).reshape(c, 1)
    lr2 = jnp.full((1, 1), lr, jnp.float32)

    grid = (sb, m_pad // TILE)
    new_codes, sums = pl.pallas_call(
        functools.partial(_fused_kernel, fmt=fmt, m=m),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, TILE), lambda i, j: (i, j)),      # server codes
            pl.BlockSpec((1, 1), lambda i, j: (i, 0)),         # server s
            pl.BlockSpec((1, 1), lambda i, j: (i, 0)),         # server b
            pl.BlockSpec((c, 1, TILE), lambda i, j: (0, i, j)),  # client codes
            pl.BlockSpec((c, 1), lambda i, j: (0, i)),         # client s
            pl.BlockSpec((c, 1), lambda i, j: (0, i)),         # client b
            pl.BlockSpec((c, 1), lambda i, j: (0, 0)),         # weights
            pl.BlockSpec((1, 1), lambda i, j: (0, 0)),         # lr
        ],
        out_specs=[
            pl.BlockSpec((1, TILE), lambda i, j: (i, j)),
            pl.BlockSpec((1, 4), lambda i, j: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((sb, m_pad), fmt.container_dtype),
            jax.ShapeDtypeStruct((sb, 4), jnp.float32),
        ],
        interpret=interpret,
    )(srv2, ss, sbias, cl2, cs, cb, w2, lr2)

    codes = new_codes[:, :m].reshape(shape)
    s, b = _solve_from_sums(sums, m)
    if batch_axes:
        bshape = shape[:batch_axes] + (1,) * (len(shape) - batch_axes)
        return codes, s.reshape(bshape), b.reshape(bshape)
    return codes, s.reshape(()), b.reshape(())


def fused_aggregate_moved_bytes(
    cohort: int, n: int, fmt: FloatFormat, *, stack_entries: int = 1
) -> int:
    """HBM bytes the fused pass actually moves: its operand + result buffers.

    A fused kernel reads each operand and writes each result exactly once;
    every f32 intermediate is tile-local VMEM, so the HBM traffic is the sum
    of the (padded) buffer sizes: (C+1) code planes in + 1 out, the per-entry
    PVT scalars, the weights, and the [SB, 4] sums.
    """
    sb = stack_entries
    m = n // sb
    m_pad = -(-m // TILE) * TILE
    cb = fmt.container_bytes_per_value
    codes = (cohort + 1 + 1) * sb * m_pad * cb  # C client + 1 server in, 1 out
    scalars = 4 * (2 * sb + 2 * cohort * sb + cohort + 1)  # s/b, weights, lr
    sums = 4 * sb * 4
    return codes + scalars + sums
