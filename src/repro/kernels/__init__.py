"""Pallas TPU kernels for OMC hot spots (validated via interpret mode).

quantize / dequantize / quantize_stats: HBM-bandwidth elementwise codecs;
dequant_matmul: serving matmul that decompresses weight tiles in VMEM;
bitpack: exact-width wire bitstream pack/unpack (superblock layout);
agg: fused compressed-domain cohort aggregation (DESIGN.md §13).
``ops`` holds the jit'd dispatching wrappers; ``ref`` the pure-jnp oracles.
"""

from . import agg, bitpack, ops, ref
