"""jit'd public wrappers for the Pallas kernels.

Dispatch policy:
  * on TPU: compiled Pallas kernels,
  * elsewhere: pure-jnp reference (``ref.py``) by default — fast on CPU —
    or interpret-mode Pallas when ``force_interpret=True`` (used by the
    correctness tests, which execute the actual kernel bodies).
"""

from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core.formats import FloatFormat

from . import dequant_matmul as _dm
from . import quantize as _q
from . import ref


def _on_tpu() -> bool:
    try:
        return jax.devices()[0].platform == "tpu"
    except Exception:
        return False


@functools.partial(jax.jit, static_argnames=("fmt", "force_interpret"))
def quantize(x, fmt: FloatFormat, force_interpret: bool = False):
    if _on_tpu():
        return _q.quantize(x, fmt)
    if force_interpret:
        return _q.quantize(x, fmt, interpret=True)
    return ref.ref_quantize(x, fmt)


@functools.partial(jax.jit, static_argnames=("fmt", "force_interpret"))
def dequantize(codes, fmt: FloatFormat, s=None, b=None,
               force_interpret: bool = False):
    if _on_tpu():
        return _q.dequantize(codes, fmt, s, b)
    if force_interpret:
        return _q.dequantize(codes, fmt, s, b, interpret=True)
    return ref.ref_dequantize(codes, fmt, s, b)


@functools.partial(jax.jit, static_argnames=("fmt", "force_interpret"))
def quantize_stats(x, fmt: FloatFormat, force_interpret: bool = False):
    if _on_tpu():
        return _q.quantize_stats(x, fmt)
    if force_interpret:
        return _q.quantize_stats(x, fmt, interpret=True)
    return ref.ref_quantize_stats(x, fmt)


@functools.partial(jax.jit,
                   static_argnames=("fmt", "bm", "bn", "bk", "force_interpret"))
def dequant_matmul(a, w_codes, fmt: FloatFormat, s=None, b=None,
                   bm: int = 256, bn: int = 256, bk: int = 256,
                   force_interpret: bool = False):
    if _on_tpu():
        return _dm.dequant_matmul(a, w_codes, fmt, s, b, bm=bm, bn=bn, bk=bk)
    if force_interpret:
        return _dm.dequant_matmul(a, w_codes, fmt, s, b, bm=bm, bn=bn, bk=bk,
                                  interpret=True)
    return ref.ref_dequant_matmul(
        a, w_codes, fmt,
        jnp.float32(1.0) if s is None else s,
        jnp.float32(0.0) if b is None else b,
    )
