"""jit'd public wrappers for the Pallas kernels.

Dispatch policy (DESIGN.md §13):
  * on TPU: compiled Pallas kernels,
  * elsewhere: pure-jnp reference (``ref.py``) by default — fast on CPU —
    or interpret-mode Pallas when ``force_interpret=True`` (used by the
    correctness tests, which execute the actual kernel bodies).

The platform probe runs ONCE at import and is memoized in ``_ON_TPU``.
It used to be a per-call function that swallowed every exception — inside a
jit trace a probe failure silently returned False and could flip dispatch
between retraces; now the decision is a module constant (regression-tested
in tests/test_kernels.py::test_cpu_dispatch_hits_ref).

Every branch also bumps a **dispatch counter** keyed ``(op, backend)``
with backend ∈ {pallas, interpret, ref} (DESIGN.md §15).  The wrappers
are jitted, so the bump executes at *trace* time: counts are per compiled
specialization, not per call — exactly the right granularity for the
regression question "did a CPU run silently trace the compiled path?".
Read with :func:`dispatch_counts`; ``repro.obs`` embeds the counts in its
run meta record.
"""

from __future__ import annotations

import functools
from collections import Counter
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core.formats import FloatFormat

from . import agg as _agg
from . import bitpack as _bp
from . import dequant_matmul as _dm
from . import quantize as _q
from . import ref


def _probe_tpu() -> bool:
    try:
        return jax.devices()[0].platform == "tpu"
    except Exception:
        return False


_ON_TPU: bool = _probe_tpu()

_DISPATCHES: Counter = Counter()


def _record(op: str, backend: str) -> None:
    _DISPATCHES[f"{op}.{backend}"] += 1


def dispatch_counts() -> Dict[str, int]:
    """``{"<op>.<backend>": traces}`` accumulated since import/reset."""
    return dict(_DISPATCHES)


def reset_dispatch_counts() -> None:
    _DISPATCHES.clear()


def _dispatch(op: str, force_interpret: bool) -> str:
    """Pick + record the backend for one traced specialization."""
    backend = ("pallas" if _ON_TPU
               else "interpret" if force_interpret else "ref")
    _record(op, backend)
    return backend


@functools.partial(jax.jit, static_argnames=("fmt", "force_interpret"))
def quantize(x, fmt: FloatFormat, force_interpret: bool = False):
    backend = _dispatch("quantize", force_interpret)
    if backend == "pallas":
        return _q.quantize(x, fmt)
    if backend == "interpret":
        return _q.quantize(x, fmt, interpret=True)
    return ref.ref_quantize(x, fmt)


@functools.partial(jax.jit, static_argnames=("fmt", "force_interpret"))
def dequantize(codes, fmt: FloatFormat, s=None, b=None,
               force_interpret: bool = False):
    backend = _dispatch("dequantize", force_interpret)
    if backend == "pallas":
        return _q.dequantize(codes, fmt, s, b)
    if backend == "interpret":
        return _q.dequantize(codes, fmt, s, b, interpret=True)
    return ref.ref_dequantize(codes, fmt, s, b)


@functools.partial(jax.jit, static_argnames=("fmt", "force_interpret"))
def quantize_stats(x, fmt: FloatFormat, force_interpret: bool = False):
    backend = _dispatch("quantize_stats", force_interpret)
    if backend == "pallas":
        return _q.quantize_stats(x, fmt)
    if backend == "interpret":
        return _q.quantize_stats(x, fmt, interpret=True)
    return ref.ref_quantize_stats(x, fmt)


@functools.partial(jax.jit,
                   static_argnames=("fmt", "bm", "bn", "bk", "force_interpret"))
def dequant_matmul(a, w_codes, fmt: FloatFormat, s=None, b=None,
                   bm: int = 256, bn: int = 256, bk: int = 256,
                   force_interpret: bool = False):
    backend = _dispatch("dequant_matmul", force_interpret)
    if backend == "pallas":
        return _dm.dequant_matmul(a, w_codes, fmt, s, b, bm=bm, bn=bn, bk=bk)
    if backend == "interpret":
        return _dm.dequant_matmul(a, w_codes, fmt, s, b, bm=bm, bn=bn, bk=bk,
                                  interpret=True)
    return ref.ref_dequant_matmul(
        a, w_codes, fmt,
        jnp.float32(1.0) if s is None else s,
        jnp.float32(0.0) if b is None else b,
    )


@functools.partial(jax.jit, static_argnames=("width", "force_interpret"))
def pack_bits(codes, width: int, force_interpret: bool = False):
    """codes (values < 2**width) -> exact uint32 bitstream (wire form)."""
    backend = _dispatch("pack_bits", force_interpret)
    if backend == "pallas":
        return _bp.pack(codes, width)
    if backend == "interpret":
        return _bp.pack(codes, width, interpret=True)
    return ref.ref_pack(codes, width)


@functools.partial(jax.jit, static_argnames=("width", "n", "force_interpret"))
def unpack_bits(words, width: int, n: int, force_interpret: bool = False):
    """Inverse of :func:`pack_bits`: recover ``n`` codes (uint32)."""
    backend = _dispatch("unpack_bits", force_interpret)
    if backend == "pallas":
        return _bp.unpack(words, width, n)
    if backend == "interpret":
        return _bp.unpack(words, width, n, interpret=True)
    return ref.ref_unpack(words, width, n)


@functools.partial(
    jax.jit, static_argnames=("fmt", "batch_axes", "pvt", "force_interpret")
)
def fused_aggregate(srv_codes, srv_s, srv_b, cl_codes, cl_s, cl_b, weights,
                    lr, fmt: FloatFormat, batch_axes: int = 0,
                    pvt: bool = True, force_interpret: bool = False):
    """Compressed-domain server round for one variable (DESIGN.md §13).

    Returns (new_codes, s, b) — the aggregated server variable in storage
    form, without materializing f32 cohort state on the Pallas path.
    """
    backend = _dispatch("fused_aggregate", force_interpret)
    if backend == "pallas":
        out = _agg.fused_aggregate(srv_codes, srv_s, srv_b, cl_codes, cl_s,
                                   cl_b, weights, lr, fmt,
                                   batch_axes=batch_axes)
    elif backend == "interpret":
        out = _agg.fused_aggregate(srv_codes, srv_s, srv_b, cl_codes, cl_s,
                                   cl_b, weights, lr, fmt,
                                   batch_axes=batch_axes, interpret=True)
    else:
        out = ref.ref_fused_aggregate(srv_codes, srv_s, srv_b, cl_codes, cl_s,
                                      cl_b, weights, lr, fmt,
                                      batch_axes=batch_axes)
    if not pvt:
        codes, _, _ = out
        return codes, jnp.float32(1.0), jnp.float32(0.0)
    return out
