"""Pallas TPU kernels: OMC quantize / dequantize (paper's hot elementwise op).

OMC pays an encode+decode per parameter per round ("lightweight operation",
paper §2.2/Tables 1-2) — on TPU this must stream HBM->VMEM->HBM at memory
bandwidth with the bit-twiddling fused, never materializing intermediate
f32 copies in HBM.  Three kernels:

  * ``quantize``        f32 tile -> minifloat bitfield codes (RNE,
                        subnormal-aware, saturating)
  * ``dequantize``      codes -> f32, fused with the PVT affine s·x + b
  * ``quantize_stats``  fused quantize + the four PVT sums (Σv, Σṽ, Σvṽ,
                        Σṽ²) accumulated across the grid — one pass instead
                        of quantize-then-resum (halves HBM traffic of the
                        round's re-compression step)

Tiling: inputs are flattened and tiled as (rows, 1024) VMEM blocks — the
lane dim is a multiple of 128 (VPU-aligned) and the block (8·1024 f32 =
32 KiB) keeps the working set far inside VMEM while saturating HBM.

Validation: interpret=True on CPU against ``ref.py`` (pure-jnp oracle) over
a shape x format sweep — see tests/test_kernels.py.
"""

from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl

from repro.core.formats import FloatFormat, decode as _jnp_decode, encode as _jnp_encode

LANES = 1024  # lane-dim tile (multiple of 128)
SUBLANES = 8  # row-dim tile


def _pad_flatten(x: jax.Array) -> Tuple[jax.Array, int]:
    """[-> (rows, LANES)] zero-padded view + original element count."""
    n = x.size
    rows = -(-n // LANES)
    rows = -(-rows // SUBLANES) * SUBLANES
    flat = jnp.ravel(x)
    flat = jnp.pad(flat, (0, rows * LANES - n))
    return flat.reshape(rows, LANES), n


def _quantize_kernel(x_ref, o_ref, *, fmt: FloatFormat):
    o_ref[...] = _jnp_encode(x_ref[...], fmt, quantize=True)


def _dequantize_kernel(c_ref, s_ref, b_ref, o_ref, *, fmt: FloatFormat):
    s = s_ref[0, 0]
    b = b_ref[0, 0]
    o_ref[...] = _jnp_decode(c_ref[...], fmt) * s + b


def _quantize_stats_kernel(x_ref, o_ref, sums_ref, *, fmt: FloatFormat):
    i = pl.program_id(0)

    @pl.when(i == 0)
    def _init():
        sums_ref[...] = jnp.zeros_like(sums_ref)

    x = x_ref[...]
    codes = _jnp_encode(x, fmt, quantize=True)
    o_ref[...] = codes
    q = _jnp_decode(codes, fmt)
    sums_ref[0, 0] += jnp.sum(x)
    sums_ref[0, 1] += jnp.sum(q)
    sums_ref[0, 2] += jnp.sum(x * q)
    sums_ref[0, 3] += jnp.sum(q * q)


def quantize(x: jax.Array, fmt: FloatFormat, *, interpret: bool = False) -> jax.Array:
    """f32 array -> bitfield codes (same shape, container dtype)."""
    x2, n = _pad_flatten(jnp.asarray(x, jnp.float32))
    rows = x2.shape[0]
    grid = (rows // SUBLANES,)
    out = pl.pallas_call(
        functools.partial(_quantize_kernel, fmt=fmt),
        grid=grid,
        in_specs=[pl.BlockSpec((SUBLANES, LANES), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((SUBLANES, LANES), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((rows, LANES), fmt.container_dtype),
        interpret=interpret,
    )(x2)
    return out.reshape(-1)[:n].reshape(x.shape)


def dequantize(codes: jax.Array, fmt: FloatFormat, s=None, b=None,
               *, interpret: bool = False) -> jax.Array:
    """codes -> f32 with the PVT affine fused (s, b scalars)."""
    c2, n = _pad_flatten(codes.astype(fmt.container_dtype))
    rows = c2.shape[0]
    s_arr = jnp.full((1, 1), 1.0 if s is None else s, jnp.float32)
    b_arr = jnp.full((1, 1), 0.0 if b is None else b, jnp.float32)
    grid = (rows // SUBLANES,)
    out = pl.pallas_call(
        functools.partial(_dequantize_kernel, fmt=fmt),
        grid=grid,
        in_specs=[
            pl.BlockSpec((SUBLANES, LANES), lambda i: (i, 0)),
            pl.BlockSpec((1, 1), lambda i: (0, 0)),
            pl.BlockSpec((1, 1), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((SUBLANES, LANES), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((rows, LANES), jnp.float32),
        interpret=interpret,
    )(c2, s_arr, b_arr)
    return out.reshape(-1)[:n].reshape(codes.shape)


def quantize_stats(x: jax.Array, fmt: FloatFormat, *, interpret: bool = False):
    """(codes, sums[4]) — fused quantize + PVT statistics.

    Padding contributes zeros to every sum, which biases only the count n —
    callers use the true element count (ref.py semantics match exactly).
    """
    x2, n = _pad_flatten(jnp.asarray(x, jnp.float32))
    rows = x2.shape[0]
    grid = (rows // SUBLANES,)
    codes, sums = pl.pallas_call(
        functools.partial(_quantize_stats_kernel, fmt=fmt),
        grid=grid,
        in_specs=[pl.BlockSpec((SUBLANES, LANES), lambda i: (i, 0))],
        out_specs=[
            pl.BlockSpec((SUBLANES, LANES), lambda i: (i, 0)),
            pl.BlockSpec((1, 4), lambda i: (0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((rows, LANES), fmt.container_dtype),
            jax.ShapeDtypeStruct((1, 4), jnp.float32),
        ],
        interpret=interpret,
    )(x2)
    return codes.reshape(-1)[:n].reshape(x.shape), sums[0]
