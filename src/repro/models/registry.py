"""Uniform model API over the zoo.

Each family module exposes::

    init(key, cfg) -> params
    param_specs(cfg) -> ParamSpec pytree (same structure as params)
    loss(cfg, params, batch, mat) -> scalar          # train objective
    prefill(cfg, params, batch, mat, state) -> (state, logits)   (if servable)
    decode_step(cfg, params, state, tokens, mat) -> (state, logits)
    init_decode_state(cfg, batch, max_len, dtype) -> state

``get_family(name)`` returns the module; ``"vlm"`` resolves to the
transformer (the ViT frontend is a stub — DESIGN.md §6) and ``"conformer"``
has no decode step (encoder-only; paper benchmarks only).
"""

from __future__ import annotations

from types import ModuleType
from typing import Dict

from . import conformer, encdec, griffin, moe, transformer, xlstm

_FAMILIES: Dict[str, ModuleType] = {
    "transformer": transformer,
    "vlm": transformer,  # prefix_embeds > 0 in the config
    "moe": moe,
    "xlstm": xlstm,
    "griffin": griffin,
    "encdec": encdec,
    "conformer": conformer,
}

SERVABLE = {"transformer", "vlm", "moe", "xlstm", "griffin", "encdec"}


def get_family(name: str) -> ModuleType:
    if name not in _FAMILIES:
        raise KeyError(f"unknown model family {name!r}; have {sorted(_FAMILIES)}")
    return _FAMILIES[name]


def is_servable(name: str) -> bool:
    return name in SERVABLE
