"""Mixture-of-Experts decoder LM (mixtral-8x7b, dbrx-132b).

Expert parallelism (DESIGN.md §4): activations are replicated over the
`model` axis after attention (standard 2-D TP+DP layout), so MoE dispatch
needs *no* all-to-all — each model shard locally gathers the tokens routed to
the experts it owns (capacity-bounded, gate-priority), runs the expert FFN,
and scatter-adds its contribution; a single psum over `model` combines, which
is the same collective a dense row-parallel FFN already pays.

Expert-to-mesh mapping:
  * E >= model-axis (dbrx 16e on 16): each shard owns E/M experts.
  * E <  model-axis (mixtral 8e on 16): each expert is co-owned by M/E
    shards which split the FFN hidden dim (`ep_partitions`); both owners
    process the same tokens and their partial outputs merge in the psum.
    Expert weights are *stored* in the flattened [E*parts, D, F/parts]
    layout so they are expert-sharded at rest (checkpoints keep the
    canonical [E, D, F] layout — see repro.checkpoint).

When no mesh is active (CPU smoke tests) the dispatch runs as a pure-jnp
single-device reference with identical semantics; a property test asserts the
shard_map path matches it on a multi-device host mesh.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from . import attention as attn
from .common import (
    Materializer,
    ParamSpec,
    RSPEC,
    apply_rope,
    current_mesh,
    dense_init,
    embed_init,
    rms_norm,
    scan_blocks,
    shard_hint,
    softmax_xent_chunked,
    stack_layer_params,
    wspec,
)
from .transformer import TransformerConfig, _embed_lookup, _qkv, param_specs as _dense_param_specs

# jax.shard_map (with check_vma) replaced jax.experimental.shard_map
# (check_rep) after 0.4.x; support both so host-mesh tests run everywhere.
if hasattr(jax, "shard_map"):
    _shard_map, _SM_KW = jax.shard_map, {"check_vma": False}
else:  # pragma: no cover - older jax
    from jax.experimental.shard_map import shard_map as _shard_map

    _SM_KW = {"check_rep": False}


@dataclasses.dataclass(frozen=True)
class MoEConfig(TransformerConfig):
    n_experts: int = 8
    top_k: int = 2
    capacity_factor: float = 1.25
    ep_partitions: int = 1  # FFN-dim split when E < model axis (set by launch)
    router_aux_weight: float = 0.01
    router_z_weight: float = 1e-3

    @property
    def stored_experts(self) -> int:
        return self.n_experts * self.ep_partitions

    @property
    def f_local(self) -> int:
        return self.d_ff // self.ep_partitions

    def param_count(self) -> int:
        d, f, v = self.d_model, self.d_ff, self.vocab
        per_layer = (
            d * (self.q_dim + 2 * self.kv_dim) + self.q_dim * d
            + 3 * d * f * self.n_experts + d * self.n_experts + 2 * d
        )
        emb = v * d * (1 if self.tie_embeddings else 2)
        return self.n_layers * per_layer + emb + d

    def active_param_count(self) -> int:
        d, f, v = self.d_model, self.d_ff, self.vocab
        per_layer = (
            d * (self.q_dim + 2 * self.kv_dim) + self.q_dim * d
            + 3 * d * f * self.top_k + d * self.n_experts + 2 * d
        )
        emb = v * d * (1 if self.tie_embeddings else 2)
        return self.n_layers * per_layer + emb + d


# ---------------------------------------------------------------------------
# init / specs
# ---------------------------------------------------------------------------


def _block_init(key, cfg: MoEConfig) -> Dict[str, Any]:
    ks = jax.random.split(key, 8)
    d, fl, we = cfg.d_model, cfg.f_local, cfg.stored_experts

    def expert_stack(k, d_in, d_out):
        return jnp.stack(
            [dense_init(kk, d_in, d_out) for kk in jax.random.split(k, we)], 0
        )

    return dict(
        attn_norm=jnp.ones((d,), jnp.float32),
        wq=dense_init(ks[0], d, cfg.q_dim),
        wk=dense_init(ks[1], d, cfg.kv_dim),
        wv=dense_init(ks[2], d, cfg.kv_dim),
        wo=dense_init(ks[3], cfg.q_dim, d),
        mlp_norm=jnp.ones((d,), jnp.float32),
        router=dense_init(ks[4], d, cfg.n_experts),
        w1=expert_stack(ks[5], d, fl),
        w3=expert_stack(ks[6], d, fl),
        w2=expert_stack(ks[7], fl, d),
    )


def block_specs(cfg: MoEConfig) -> Dict[str, ParamSpec]:
    return dict(
        attn_norm=RSPEC,
        wq=wspec("fsdp", "tensor"),
        wk=wspec("fsdp", "tensor"),
        wv=wspec("fsdp", "tensor"),
        wo=wspec("tensor", "fsdp"),
        mlp_norm=RSPEC,
        router=wspec("fsdp", None),
        w1=wspec("expert", "fsdp", None),
        w3=wspec("expert", "fsdp", None),
        w2=wspec("expert", "fsdp", None),
    )


def init(key, cfg: MoEConfig) -> Dict[str, Any]:
    kb, ke, kh = jax.random.split(key, 3)
    blocks = stack_layer_params(
        [_block_init(k, cfg) for k in jax.random.split(kb, cfg.n_layers)]
    )
    params = dict(
        embed=embed_init(ke, cfg.vocab, cfg.d_model),
        blocks=blocks,
        final_norm=jnp.ones((cfg.d_model,), jnp.float32),
    )
    if not cfg.tie_embeddings:
        params["lm_head"] = dense_init(kh, cfg.d_model, cfg.vocab)
    return params


def param_specs(cfg: MoEConfig) -> Dict[str, Any]:
    specs = _dense_param_specs(cfg)
    specs["blocks"] = block_specs(cfg)
    return specs


# ---------------------------------------------------------------------------
# MoE FFN — routing + capacity dispatch
# ---------------------------------------------------------------------------


def _route(x2d: jax.Array, router_w: jax.Array, cfg: MoEConfig):
    """[T, D] -> (gate values [T,k], expert ids [T,k], aux losses)."""
    logits = (x2d @ router_w).astype(jnp.float32)  # [T, E]
    probs = jax.nn.softmax(logits, axis=-1)
    gval, gidx = jax.lax.top_k(probs, cfg.top_k)
    gval = gval / jnp.maximum(gval.sum(-1, keepdims=True), 1e-9)
    # load-balance aux (Switch-style): E * sum_e fraction_e * prob_e
    dispatch_frac = jnp.mean(
        jax.nn.one_hot(gidx[:, 0], cfg.n_experts, dtype=jnp.float32), axis=0
    )
    prob_frac = jnp.mean(probs, axis=0)
    aux = cfg.n_experts * jnp.sum(dispatch_frac * prob_frac)
    z = jnp.mean(jnp.square(jax.nn.logsumexp(logits, axis=-1)))
    return gval, gidx, cfg.router_aux_weight * aux + cfg.router_z_weight * z


def _expert_ffn(xe: jax.Array, w1e, w3e, w2e) -> jax.Array:
    """[C, D] @ expert weights -> [C, D] (SwiGLU)."""
    return (jax.nn.silu(xe @ w1e) * (xe @ w3e)) @ w2e


def _dispatch_compute(x2d, gval, gidx, w1, w3, w2, cfg: MoEConfig,
                      local_experts, capacity: int):
    """Gather-compute-scatter for a set of locally-owned experts.

    x2d [T, D]; w1/w3/w2 [n_local, D, F_l] / [n_local, F_l, D];
    local_experts: int32 [n_local] global expert ids.  Returns partial y [T, D]
    (contributions of the local experts only).
    """
    t = x2d.shape[0]
    flat_gv = gval.reshape(-1)  # [T*k]
    flat_eid = gidx.reshape(-1)  # [T*k]
    token_of_pair = jnp.arange(flat_eid.shape[0], dtype=jnp.int32) // cfg.top_k

    def one_expert(y, inputs):
        e, w1e, w3e, w2e = inputs
        score = jnp.where(flat_eid == e, flat_gv, -1.0)
        top_v, top_i = jax.lax.top_k(score, capacity)
        valid = (top_v > 0.0).astype(jnp.float32)  # dropped / unrouted slots
        tok = token_of_pair[top_i]
        xe = x2d[tok] * valid[:, None]
        he = _expert_ffn(xe, w1e, w3e, w2e)
        contrib = he * (top_v * valid)[:, None]
        return y.at[tok].add(contrib, mode="drop"), None

    y0 = jnp.zeros((t, x2d.shape[1]), jnp.float32)
    y, _ = jax.lax.scan(one_expert, y0, (local_experts, w1, w3, w2))
    return y


def moe_ffn(x: jax.Array, w: Dict[str, jax.Array], cfg: MoEConfig):
    """[B, S, D] -> ([B, S, D], aux_loss).  w holds router/w1/w3/w2 (f32)."""
    b, s, d = x.shape
    mesh = current_mesh()
    t = b * s

    if mesh is None or "model" not in mesh.axis_names or cfg.ep_partitions == 0:
        # Single-device reference path.
        x2d = x.reshape(t, d).astype(jnp.float32)
        gval, gidx, aux = _route(x2d, w["router"], cfg)
        cap = _capacity(t, cfg)
        y = _dispatch_compute(
            x2d, gval, gidx, w["w1"], w["w3"], w["w2"], cfg,
            jnp.repeat(jnp.arange(cfg.n_experts, dtype=jnp.int32), cfg.ep_partitions)
            if cfg.ep_partitions > 1 else jnp.arange(cfg.n_experts, dtype=jnp.int32),
            cap,
        )
        return y.reshape(b, s, d).astype(x.dtype), aux

    from jax.sharding import PartitionSpec as P
    from .common import resolve_spec

    m = dict(zip(mesh.axis_names, mesh.devices.shape))["model"]
    batch_spec = resolve_spec(["batch"], [b], mesh)[0]  # axes or None
    b_shards = int(np.prod([dict(zip(mesh.axis_names, mesh.devices.shape))[a]
                            for a in (batch_spec if isinstance(batch_spec, tuple)
                                      else ((batch_spec,) if batch_spec else ()))]))
    t_local = (b // max(b_shards, 1)) * s
    cap = _capacity(t_local, cfg)
    we = cfg.stored_experts
    if we % m == 0:
        n_local = we // m
    else:
        raise ValueError(
            f"stored_experts={we} not divisible by model axis {m}; "
            f"set ep_partitions so that n_experts*ep_partitions % model == 0"
        )

    def shard_fn(x_l, router_w, w1_l, w3_l, w2_l):
        bl, sl, dl = x_l.shape
        x2d = x_l.reshape(bl * sl, dl).astype(jnp.float32)
        gval, gidx, aux = _route(x2d, router_w, cfg)
        midx = jax.lax.axis_index("model")
        # stored-expert rows owned by this shard -> global expert ids
        rows = midx * n_local + jnp.arange(n_local, dtype=jnp.int32)
        local_eids = rows // cfg.ep_partitions
        y = _dispatch_compute(x2d, gval, gidx, w1_l, w3_l, w2_l, cfg,
                              local_eids, cap)
        y = jax.lax.psum(y, "model")
        aux = jax.lax.pmean(aux, "model")
        return y.reshape(bl, sl, dl), aux

    xspec = P(batch_spec, None, None)
    wspec_ = P("model", None, None)
    y, aux = _shard_map(
        shard_fn,
        mesh=mesh,
        in_specs=(xspec, P(None, None), wspec_, wspec_, wspec_),
        out_specs=(xspec, P()),
        **_SM_KW,
    )(x, w["router"], w["w1"], w["w3"], w["w2"])
    return y.astype(x.dtype), aux


def _capacity(tokens: int, cfg: MoEConfig) -> int:
    c = int(np.ceil(tokens * cfg.top_k / cfg.n_experts * cfg.capacity_factor))
    c = max(8, -(-c // 8) * 8)  # pad to multiple of 8, floor 8
    return min(c, tokens * cfg.top_k)  # can't exceed the pair count


# ---------------------------------------------------------------------------
# forward / loss / serve
# ---------------------------------------------------------------------------


def _block_apply(cfg: MoEConfig, w, x, aux, positions, window):
    b, s, d = x.shape
    h = rms_norm(x, w["attn_norm"], cfg.norm_eps)
    q, k, v = _qkv(w, h, cfg)
    q = apply_rope(q, positions, cfg.rope_theta)
    k = apply_rope(k, positions, cfg.rope_theta)
    o = attn.attend(q, k, v, positions, positions, causal=True, window=window)
    o = o.reshape(b, s, cfg.q_dim)
    x = x + shard_hint(o @ w["wo"], "batch", None, None)
    h = rms_norm(x, w["mlp_norm"], cfg.norm_eps)
    y, aux_l = moe_ffn(h, w, cfg)
    return x + y, aux + aux_l


def forward(cfg: MoEConfig, params, batch, mat: Materializer):
    tokens = batch["tokens"]
    b = tokens.shape[0]
    emb_w = mat({"embed": params["embed"]}, {"embed": param_specs(cfg)["embed"]})
    x = _embed_lookup(emb_w["embed"], tokens)
    x = shard_hint(x, "batch", None, None)
    s = x.shape[1]
    positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32), (b, s))
    specs = block_specs(cfg)

    def body(carry, w, _):
        x_, aux = carry
        return _block_apply(cfg, w, x_, aux, positions, cfg.window)

    x, aux = scan_blocks(body, params["blocks"], (x, jnp.float32(0.0)), mat, specs)
    return rms_norm(x, mat.leaf(params["final_norm"]), cfg.norm_eps), aux


def loss(cfg: MoEConfig, params, batch, mat: Materializer) -> jax.Array:
    hidden, aux = forward(cfg, params, batch, mat)
    head = (
        mat({"h": params["lm_head"]}, {"h": wspec("fsdp", "tensor")})["h"]
        if not cfg.tie_embeddings
        else mat({"e": params["embed"]},
                 {"e": ParamSpec(("fsdp", "tensor"), ("tensor", None))})["e"].T
    )
    ce = softmax_xent_chunked(hidden, head, batch["labels"], batch.get("mask"))
    return ce + aux / cfg.n_layers


def init_decode_state(cfg: MoEConfig, batch: int, max_len: int, dtype=jnp.bfloat16):
    buf = max_len if cfg.window is None else min(max_len, cfg.window)
    return attn.init_cache(cfg.n_layers, batch, buf, cfg.n_kv_heads, cfg.hd, dtype)


def prefill(cfg: MoEConfig, params, batch, mat: Materializer, cache):
    x = _embed_lookup(
        mat({"embed": params["embed"]}, {"embed": param_specs(cfg)["embed"]})["embed"],
        batch["tokens"],
    )
    x = shard_hint(x, "batch", None, None)
    b, s = batch["tokens"].shape
    positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32), (b, s))
    specs = block_specs(cfg)
    buf = cache.buf_len

    def body_fn(carry, xs):
        x_, aux = carry
        w = mat(xs[0], specs)
        h = rms_norm(x_, w["attn_norm"], cfg.norm_eps)
        q, k, v = _qkv(w, h, cfg)
        q = apply_rope(q, positions, cfg.rope_theta)
        k = apply_rope(k, positions, cfg.rope_theta)
        o = attn.attend(q, k, v, positions, positions, causal=True, window=cfg.window)
        o = o.reshape(b, s, cfg.q_dim)
        x_ = x_ + shard_hint(o @ w["wo"], "batch", None, None)
        h = rms_norm(x_, w["mlp_norm"], cfg.norm_eps)
        y, aux_l = moe_ffn(h, w, cfg)
        x_ = x_ + y
        t = min(buf, s)
        kc, vc, pc = k[:, -t:], v[:, -t:], positions[:, -t:]
        if t < buf:
            pad = buf - t
            kc = jnp.pad(kc, ((0, 0), (0, pad), (0, 0), (0, 0)))
            vc = jnp.pad(vc, ((0, 0), (0, pad), (0, 0), (0, 0)))
            pc = jnp.pad(pc, ((0, 0), (0, pad)), constant_values=-1)
        return (x_, aux + aux_l), (kc.astype(cache.k.dtype), vc.astype(cache.v.dtype), pc)

    body_fn = jax.checkpoint(body_fn, prevent_cse=False)
    (x, _aux), (ks, vs, ps) = jax.lax.scan(
        body_fn, (x, jnp.float32(0.0)), (params["blocks"], None)
    )
    if cfg.window is not None and s >= buf:
        roll = s % buf
        ks, vs, ps = (jnp.roll(a, roll, axis=2) for a in (ks, vs, ps))
    new_cache = attn.cache_shard_hint(
        attn.KVCache(k=ks, v=vs, pos=ps, length=jnp.asarray(s, jnp.int32))
    )
    x = rms_norm(x, mat.leaf(params["final_norm"]), cfg.norm_eps)
    head = (
        mat({"h": params["lm_head"]}, {"h": wspec("fsdp", "tensor")})["h"]
        if not cfg.tie_embeddings else None
    )
    logits = x[:, -1:] @ head
    return new_cache, shard_hint(logits, "batch", None, "tensor")


def decode_step(cfg: MoEConfig, params, cache, tokens, mat: Materializer):
    b = tokens.shape[0]
    x = _embed_lookup(
        mat({"embed": params["embed"]}, {"embed": param_specs(cfg)["embed"]})["embed"],
        tokens,
    )
    x = shard_hint(x, "batch", None, None)
    position = cache.length
    positions = jnp.full((b, 1), position, jnp.int32)
    specs = block_specs(cfg)
    ring = cfg.window is not None

    def body(carry, xs):
        x_, aux = carry
        w_layer, (kc, vc, pc) = xs
        w = mat(w_layer, specs)
        h = rms_norm(x_, w["attn_norm"], cfg.norm_eps)
        q, k, v = _qkv(w, h, cfg)
        q = apply_rope(q, positions, cfg.rope_theta)
        k = apply_rope(k, positions, cfg.rope_theta)
        kc, vc, pc = attn.cache_insert(kc, vc, pc, k, v, position, ring=ring)
        o = attn.decode_attend(q, kc, vc, pc, position, window=cfg.window)
        o = o.reshape(b, 1, cfg.q_dim)
        x_ = x_ + shard_hint(o @ w["wo"], "batch", None, None)
        h = rms_norm(x_, w["mlp_norm"], cfg.norm_eps)
        y, aux_l = moe_ffn(h, w, cfg)
        return (x_ + y, aux + aux_l), (kc, vc, pc)

    (x, _aux), (ks, vs, ps) = jax.lax.scan(
        body, (x, jnp.float32(0.0)), (params["blocks"], (cache.k, cache.v, cache.pos))
    )
    new_cache = attn.cache_shard_hint(
        attn.KVCache(k=ks, v=vs, pos=ps, length=cache.length + 1)
    )
    x = rms_norm(x, mat.leaf(params["final_norm"]), cfg.norm_eps)
    head = mat({"h": params["lm_head"]}, {"h": wspec("fsdp", "tensor")})["h"]
    logits = x @ head
    return new_cache, shard_hint(logits, "batch", None, "tensor")
