"""Dense decoder-only transformer LM with GQA (llama/qwen/mistral family).

Covers the assigned dense archs (qwen2.5-3b, qwen1.5-110b, mistral-nemo-12b,
h2o-danube-3-4b) and serves as the LM backbone for the VLM (internvl2-1b).

Sharding (DESIGN.md §4):
  * QKV / MLP-in projections: column-parallel (out dim -> `tensor`).
  * Attention-out / MLP-out: row-parallel (in dim -> `tensor`, psum on out).
  * Attention core: q-block dim -> `model` (Ulysses-style; see attention.py).
  * Weight storage: every matrix additionally sharded over `fsdp`; the
    materializer's gather hint removes the fsdp axis per layer under remat.
  * Embedding: vocab -> `tensor`, d -> `fsdp`; lookup on the vocab-sharded
    table (SPMD lowers to masked local gathers + all-reduce).
"""

from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from . import attention as attn
from .common import (
    Materializer,
    ParamSpec,
    RSPEC,
    apply_rope,
    dense_init,
    embed_init,
    rms_norm,
    scan_blocks,
    shard_hint,
    softmax_xent_chunked,
    stack_layer_params,
    swiglu,
    wspec,
)


@dataclasses.dataclass(frozen=True)
class TransformerConfig:
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    head_dim: Optional[int] = None  # defaults to d_model // n_heads
    qkv_bias: bool = False  # qwen family
    window: Optional[int] = None  # sliding-window attention (mistral family)
    swa_every: int = 1  # 1 = every layer windowed; n>1: 1 in n full attention
    rope_theta: float = 10_000.0
    tie_embeddings: bool = False
    norm_eps: float = 1e-6
    # Frontend stubs (vlm/audio): number of pre-embedded positions prepended
    # to the token stream; their embeddings arrive via batch["patches"].
    prefix_embeds: int = 0
    # §Perf: store residual-stream activations sequence-sharded over `model`
    # (Megatron-SP).  Remat-boundary activations shrink by the TP degree and
    # the per-layer TP all-reduces become reduce-scatter + all-gather pairs
    # (half the wire bytes).  Off by default (paper-faithful baseline).
    sp_residuals: bool = False

    @property
    def hd(self) -> int:
        return self.head_dim if self.head_dim is not None else self.d_model // self.n_heads

    @property
    def q_dim(self) -> int:
        return self.n_heads * self.hd

    @property
    def kv_dim(self) -> int:
        return self.n_kv_heads * self.hd

    def layer_window(self, layer_idx: int) -> Optional[int]:
        if self.window is None:
            return None
        if self.swa_every <= 1:
            return self.window
        return None if (layer_idx % self.swa_every == self.swa_every - 1) else self.window

    @property
    def uniform_window(self) -> Optional[int]:
        """Window if identical across layers (lets blocks share one scan)."""
        ws = {self.layer_window(i) for i in range(self.n_layers)}
        return None if len(ws) > 1 else next(iter(ws))

    def param_count(self) -> int:
        d, f, v = self.d_model, self.d_ff, self.vocab
        per_layer = d * (self.q_dim + 2 * self.kv_dim) + self.q_dim * d + 3 * d * f + 2 * d
        if self.qkv_bias:
            per_layer += self.q_dim + 2 * self.kv_dim
        emb = v * d * (1 if self.tie_embeddings else 2)
        return self.n_layers * per_layer + emb + d


# ---------------------------------------------------------------------------
# init / specs
# ---------------------------------------------------------------------------


def _block_init(key, cfg: TransformerConfig) -> Dict[str, Any]:
    ks = jax.random.split(key, 6)
    d, f = cfg.d_model, cfg.d_ff
    p = dict(
        attn_norm=jnp.ones((d,), jnp.float32),
        wq=dense_init(ks[0], d, cfg.q_dim),
        wk=dense_init(ks[1], d, cfg.kv_dim),
        wv=dense_init(ks[2], d, cfg.kv_dim),
        wo=dense_init(ks[3], cfg.q_dim, d),
        mlp_norm=jnp.ones((d,), jnp.float32),
        w1=dense_init(ks[4], d, f),
        w3=dense_init(ks[5], d, f),
        w2=dense_init(ks[4], f, d),
    )
    if cfg.qkv_bias:
        p.update(
            bq=jnp.zeros((cfg.q_dim,), jnp.float32),
            bk=jnp.zeros((cfg.kv_dim,), jnp.float32),
            bv=jnp.zeros((cfg.kv_dim,), jnp.float32),
        )
    return p


def block_specs(cfg: TransformerConfig) -> Dict[str, ParamSpec]:
    s = dict(
        attn_norm=RSPEC,
        wq=wspec("fsdp", "tensor"),
        wk=wspec("fsdp", "tensor"),
        wv=wspec("fsdp", "tensor"),
        wo=wspec("tensor", "fsdp"),
        mlp_norm=RSPEC,
        w1=wspec("fsdp", "tensor"),
        w3=wspec("fsdp", "tensor"),
        w2=wspec("tensor", "fsdp"),
    )
    if cfg.qkv_bias:
        s.update(bq=wspec("tensor"), bk=wspec("tensor"), bv=wspec("tensor"))
    return s


def init(key, cfg: TransformerConfig) -> Dict[str, Any]:
    kb, ke, kh = jax.random.split(key, 3)
    blocks = stack_layer_params(
        [_block_init(k, cfg) for k in jax.random.split(kb, cfg.n_layers)]
    )
    params = dict(
        embed=embed_init(ke, cfg.vocab, cfg.d_model),
        blocks=blocks,
        final_norm=jnp.ones((cfg.d_model,), jnp.float32),
    )
    if not cfg.tie_embeddings:
        params["lm_head"] = dense_init(kh, cfg.d_model, cfg.vocab)
    return params


def param_specs(cfg: TransformerConfig) -> Dict[str, Any]:
    specs = dict(
        embed=ParamSpec(storage=("fsdp", "tensor"), gathered=(None, "tensor")),
        blocks=block_specs(cfg),
        final_norm=RSPEC,
    )
    if not cfg.tie_embeddings:
        specs["lm_head"] = wspec("fsdp", "tensor")
    return specs


# ---------------------------------------------------------------------------
# forward
# ---------------------------------------------------------------------------


def _embed_lookup(table: jax.Array, tokens: jax.Array) -> jax.Array:
    """Row lookup on a (possibly vocab-sharded) table."""
    return jnp.take(table, tokens, axis=0)


def _qkv(w, x, cfg: TransformerConfig):
    b, s, _ = x.shape
    q = x @ w["wq"] + (w["bq"] if "bq" in w else 0.0)
    k = x @ w["wk"] + (w["bk"] if "bk" in w else 0.0)
    v = x @ w["wv"] + (w["bv"] if "bv" in w else 0.0)
    q = shard_hint(q, "batch", None, "tensor").reshape(b, s, cfg.n_heads, cfg.hd)
    k = shard_hint(k, "batch", None, "tensor").reshape(b, s, cfg.n_kv_heads, cfg.hd)
    v = shard_hint(v, "batch", None, "tensor").reshape(b, s, cfg.n_kv_heads, cfg.hd)
    return q, k, v


def _res_hint(x, cfg):
    seq = "seq" if (cfg.sp_residuals and x.shape[1] > 1) else None
    return shard_hint(x, "batch", seq, None)


def _block_apply(cfg: TransformerConfig, w, x, positions, window):
    """One decoder block (pre-norm GQA attention + SwiGLU MLP)."""
    b, s, d = x.shape
    h = rms_norm(x, w["attn_norm"], cfg.norm_eps)
    q, k, v = _qkv(w, h, cfg)
    q = apply_rope(q, positions, cfg.rope_theta)
    k = apply_rope(k, positions, cfg.rope_theta)
    o = attn.attend(q, k, v, positions, positions, causal=True, window=window)
    o = o.reshape(b, s, cfg.q_dim)
    x = _res_hint(x + o @ w["wo"], cfg)
    h = rms_norm(x, w["mlp_norm"], cfg.norm_eps)
    x = _res_hint(x + swiglu(h, w["w1"], w["w3"], w["w2"]), cfg)
    return x


def _input_embeds(cfg: TransformerConfig, params, batch, mat: Materializer):
    """Token (+ optional modality-prefix) embeddings -> (x [B,S,D], positions)."""
    tokens = batch["tokens"]
    b = tokens.shape[0]
    emb_w = mat({"embed": params["embed"]}, {"embed": param_specs(cfg)["embed"]})
    x = _embed_lookup(emb_w["embed"], tokens)
    if cfg.prefix_embeds:
        # Modality frontend stub: precomputed patch/frame embeddings.
        x = jnp.concatenate([batch["patches"].astype(x.dtype), x], axis=1)
    x = _res_hint(x, cfg)
    s = x.shape[1]
    positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32), (b, s))
    return x, positions


def forward(cfg: TransformerConfig, params, batch, mat: Materializer):
    """Token stream -> final hidden states [B, S, D] (pre-head)."""
    x, positions = _input_embeds(cfg, params, batch, mat)
    window = cfg.uniform_window
    specs = block_specs(cfg)
    if window is not None or cfg.window is None:
        # Homogeneous layers: one scan over the stacked block params.
        def body(carry, w, _):
            return _block_apply(cfg, w, carry, positions, window)

        x = scan_blocks(body, params["blocks"], x, mat, specs)
    else:
        # Mixed SWA/full layers: per-layer window, unrolled (rare path; the
        # assigned SWA archs use a uniform window so the scan path is taken).
        for i in range(cfg.n_layers):
            w_i = jax.tree_util.tree_map(lambda a: a[i], params["blocks"])

            def body1(x_, w=w_i, win=cfg.layer_window(i)):
                return _block_apply(cfg, mat(w, specs), x_, positions, win)

            x = jax.checkpoint(body1)(x)
    return rms_norm(x, mat.leaf(params["final_norm"]), cfg.norm_eps)


def _head_weight(cfg: TransformerConfig, params, mat):
    if cfg.tie_embeddings:
        emb = mat({"e": params["embed"]}, {"e": ParamSpec(("fsdp", "tensor"), ("tensor", None))})["e"]
        return emb.T
    return mat({"h": params["lm_head"]}, {"h": wspec("fsdp", "tensor")})["h"]


def loss(cfg: TransformerConfig, params, batch, mat: Materializer) -> jax.Array:
    hidden = forward(cfg, params, batch, mat)
    labels = batch["labels"]
    if cfg.prefix_embeds:
        # Prefix positions carry no next-token target.
        pad = jnp.zeros((labels.shape[0], cfg.prefix_embeds), labels.dtype)
        labels = jnp.concatenate([pad, labels], axis=1)
        mask = jnp.concatenate(
            [jnp.zeros((labels.shape[0], cfg.prefix_embeds), jnp.float32),
             batch.get("mask", jnp.ones_like(batch["labels"], jnp.float32))],
            axis=1,
        )
    else:
        mask = batch.get("mask")
    return softmax_xent_chunked(hidden, _head_weight(cfg, params, mat), labels, mask)


# ---------------------------------------------------------------------------
# serving: prefill + single-token decode against a KV cache
# ---------------------------------------------------------------------------


def init_decode_state(cfg: TransformerConfig, batch: int, max_len: int,
                      dtype=jnp.bfloat16) -> attn.KVCache:
    buf = max_len if cfg.window is None else min(max_len, cfg.window)
    return attn.init_cache(cfg.n_layers, batch, buf, cfg.n_kv_heads, cfg.hd, dtype)


def prefill(cfg: TransformerConfig, params, batch, mat: Materializer,
            cache: attn.KVCache) -> Tuple[attn.KVCache, jax.Array]:
    """Run the prompt, fill the cache, return logits of the last position."""
    x, positions = _input_embeds(cfg, params, batch, mat)
    b, s = positions.shape
    specs = block_specs(cfg)
    window = cfg.uniform_window
    buf = cache.buf_len

    def body(carry, w, _):
        x_ = carry
        h = rms_norm(x_, w["attn_norm"], cfg.norm_eps)
        q, k, v = _qkv(w, h, cfg)
        q = apply_rope(q, positions, cfg.rope_theta)
        k = apply_rope(k, positions, cfg.rope_theta)
        o = attn.attend(q, k, v, positions, positions, causal=True, window=window)
        o = o.reshape(b, s, cfg.q_dim)
        x_ = x_ + shard_hint(o @ w["wo"], "batch", None, None)
        h = rms_norm(x_, w["mlp_norm"], cfg.norm_eps)
        x_ = x_ + swiglu(h, w["w1"], w["w3"], w["w2"])
        # cache tail: last `buf` positions of k/v (ring layout: slot = pos % buf)
        t = min(buf, s)
        kc, vc, pc = k[:, -t:], v[:, -t:], positions[:, -t:]
        if t < buf:  # prompt shorter than the buffer: left-pad empty slots
            pad = buf - t
            kc = jnp.pad(kc, ((0, 0), (0, pad), (0, 0), (0, 0)))
            vc = jnp.pad(vc, ((0, 0), (0, pad), (0, 0), (0, 0)))
            pc = jnp.pad(pc, ((0, 0), (0, pad)), constant_values=-1)
        return x_, (kc.astype(cache.k.dtype), vc.astype(cache.v.dtype), pc)

    def body_fn(carry, xs):
        w_layer, _ = xs
        w = mat(w_layer, specs)
        return body(carry, w, None)

    body_fn = jax.checkpoint(body_fn, prevent_cse=False)
    x, (ks, vs, ps) = jax.lax.scan(body_fn, x, (params["blocks"], None))
    if cfg.window is not None and s >= buf:
        # ring layout: rotate so that slot index == pos % buf
        roll = s % buf
        ks = jnp.roll(ks, roll, axis=2)
        vs = jnp.roll(vs, roll, axis=2)
        ps = jnp.roll(ps, roll, axis=2)
    new_cache = attn.KVCache(
        k=ks, v=vs, pos=ps, length=jnp.asarray(s, jnp.int32)
    )
    new_cache = attn.cache_shard_hint(new_cache)
    x = rms_norm(x, mat.leaf(params["final_norm"]), cfg.norm_eps)
    logits = x[:, -1:] @ _head_weight(cfg, params, mat)
    return new_cache, shard_hint(logits, "batch", None, "tensor")


def decode_step(cfg: TransformerConfig, params, cache: attn.KVCache,
                tokens: jax.Array, mat: Materializer):
    """One new token [B, 1] against the cache -> (cache', logits [B,1,V])."""
    b = tokens.shape[0]
    emb_w = mat({"embed": params["embed"]}, {"embed": param_specs(cfg)["embed"]})
    x = _embed_lookup(emb_w["embed"], tokens)
    x = shard_hint(x, "batch", None, None)
    position = cache.length  # scalar int32
    positions = jnp.full((b, 1), position, jnp.int32)
    specs = block_specs(cfg)
    ring = cfg.window is not None

    def body(x_, xs):
        w_layer, (kc, vc, pc) = xs
        w = mat(w_layer, specs)
        h = rms_norm(x_, w["attn_norm"], cfg.norm_eps)
        q, k, v = _qkv(w, h, cfg)
        q = apply_rope(q, positions, cfg.rope_theta)
        k = apply_rope(k, positions, cfg.rope_theta)
        kc, vc, pc = attn.cache_insert(kc, vc, pc, k, v, position, ring=ring)
        o = attn.decode_attend(q, kc, vc, pc, position, window=cfg.window)
        o = o.reshape(b, 1, cfg.q_dim)
        x_ = x_ + shard_hint(o @ w["wo"], "batch", None, None)
        h = rms_norm(x_, w["mlp_norm"], cfg.norm_eps)
        x_ = x_ + swiglu(h, w["w1"], w["w3"], w["w2"])
        return x_, (kc, vc, pc)

    x, (ks, vs, ps) = jax.lax.scan(body, x, (params["blocks"], (cache.k, cache.v, cache.pos)))
    new_cache = attn.cache_shard_hint(
        attn.KVCache(k=ks, v=vs, pos=ps, length=cache.length + 1)
    )
    x = rms_norm(x, mat.leaf(params["final_norm"]), cfg.norm_eps)
    logits = x @ _head_weight(cfg, params, mat)
    return new_cache, shard_hint(logits, "batch", None, "tensor")
