"""Pure-JAX model zoo: init/apply functions, no framework dependencies."""

from .registry import get_family, is_servable
