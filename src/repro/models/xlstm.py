"""xLSTM language model (sLSTM + mLSTM blocks) — xlstm-350m family.

Structure follows arXiv:2405.04517: residual blocks where the sequence mixer
is either an mLSTM (matrix-memory, no hidden-to-hidden recurrence — the
parallelizable one) or an sLSTM (scalar-memory with true h_{t-1} feedback).
Blocks have no separate FFN (d_ff = 0): the up/down projections inside each
cell block carry the channel mixing.

TPU adaptation (DESIGN.md §2/§4):
  * mLSTM cell state C [B, H, dv, dk] is sharded over `dstate` (the value
    dim) -> `model`.  The recurrence is elementwise in the sharded dims and
    the readout contracts the *replicated* key dim, so the time scan issues
    zero per-step collectives.
  * sLSTM layers are small and have per-step h_{t-1} feedback; sharding the
    head dim would psum every step (latency-bound), so sLSTM compute is
    replicated over `model` and sharded over batch only.
  * The time dimension runs under ``lax.scan`` (recurrent form — the paper's
    own formulation).  A chunkwise-parallel mLSTM is a §Perf candidate.

OMC applicability: all projection matrices (wq/wk/wv, up/down) are ordinary
weight matrices and quantize; per-head gate biases and norm scales are
excluded by the weights-only policy (paper §2.4).
"""

from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from .common import (
    Materializer,
    ParamSpec,
    RSPEC,
    dense_init,
    embed_init,
    rms_norm,
    scan_blocks,
    shard_hint,
    softmax_xent_chunked,
    stack_layer_params,
    wspec,
)


@dataclasses.dataclass(frozen=True)
class XLSTMConfig:
    n_layers: int
    d_model: int
    n_heads: int
    vocab: int
    slstm_every: int = 8  # 1-in-N blocks are sLSTM (xLSTM[7:1] ratio)
    m_proj_factor: int = 2  # mLSTM inner width = factor * d_model
    conv_kernel: int = 4
    mlstm_impl: str = "chunked"  # "chunked" (default; ==recurrent, tested) | "recurrent"
    mlstm_chunk: int = 64
    norm_eps: float = 1e-6
    tie_embeddings: bool = True

    @property
    def d_inner(self) -> int:
        return self.m_proj_factor * self.d_model

    @property
    def m_head_dim(self) -> int:
        return self.d_inner // self.n_heads

    @property
    def s_head_dim(self) -> int:
        return self.d_model // self.n_heads

    @property
    def n_super(self) -> int:
        return self.n_layers // self.slstm_every

    @property
    def m_per_super(self) -> int:
        return self.slstm_every - 1

    @property
    def n_extra_m(self) -> int:
        return self.n_layers - self.n_super * self.slstm_every

    def param_count(self) -> int:
        d, di, h = self.d_model, self.d_inner, self.n_heads
        m = d * 2 * di + self.conv_kernel * di + 3 * di * di + di * 2 * h + di * d + 2 * d + di
        ds = d
        s = d * 4 * ds + h * self.s_head_dim * 4 * self.s_head_dim + 4 * ds + ds * d + d + ds
        n_m = self.n_layers - self.n_slstm
        emb = self.vocab * d * (1 if self.tie_embeddings else 2)
        return n_m * m + self.n_slstm * s + emb + d

    @property
    def n_slstm(self) -> int:
        return self.n_super


# ---------------------------------------------------------------------------
# init / specs
# ---------------------------------------------------------------------------


def _mlstm_init(key, cfg: XLSTMConfig) -> Dict[str, Any]:
    ks = jax.random.split(key, 7)
    d, di, h = cfg.d_model, cfg.d_inner, cfg.n_heads
    return dict(
        norm=jnp.ones((d,), jnp.float32),
        w_up=dense_init(ks[0], d, 2 * di),
        conv_w=(jax.random.normal(ks[1], (cfg.conv_kernel, di)) * 0.1).astype(jnp.float32),
        wq=dense_init(ks[2], di, di),
        wk=dense_init(ks[3], di, di),
        wv=dense_init(ks[4], di, di),
        w_if=dense_init(ks[5], di, 2 * h),  # i/f gate pre-activations per head
        b_if=jnp.concatenate([jnp.zeros((h,)), jnp.ones((h,)) * 3.0]).astype(jnp.float32),
        gn_scale=jnp.ones((di,), jnp.float32),
        w_down=dense_init(ks[6], di, d),
    )


def _slstm_init(key, cfg: XLSTMConfig) -> Dict[str, Any]:
    ks = jax.random.split(key, 3)
    d, h, dh = cfg.d_model, cfg.n_heads, cfg.s_head_dim
    return dict(
        norm=jnp.ones((d,), jnp.float32),
        w_gates=dense_init(ks[0], d, 4 * d),  # i, f, z, o stacked
        r_gates=(jax.random.normal(ks[1], (h, dh, 4 * dh)) / np.sqrt(dh)).astype(jnp.float32),
        b_gates=jnp.zeros((4 * d,), jnp.float32),
        gn_scale=jnp.ones((d,), jnp.float32),
        w_down=dense_init(ks[2], d, d),
    )


def _mlstm_specs() -> Dict[str, ParamSpec]:
    return dict(
        norm=RSPEC,
        w_up=wspec("fsdp", "tensor"),
        conv_w=ParamSpec(storage=(None, "tensor"), gathered=(None, "tensor")),
        wq=wspec("fsdp", None),
        wk=wspec("fsdp", None),
        wv=wspec("fsdp", "dstate"),
        w_if=wspec("fsdp", None),
        b_if=RSPEC,
        gn_scale=RSPEC,
        w_down=wspec("dstate", "fsdp"),
    )


def _slstm_specs() -> Dict[str, ParamSpec]:
    return dict(
        norm=RSPEC,
        w_gates=wspec("fsdp", None),
        r_gates=ParamSpec(storage=(None, None, "fsdp"), gathered=(None, None, None)),
        b_gates=RSPEC,
        gn_scale=RSPEC,
        w_down=wspec("fsdp", None),
    )


def block_specs(cfg: XLSTMConfig) -> Dict[str, Any]:
    return dict(mlstm=_mlstm_specs(), slstm=_slstm_specs())


def init(key, cfg: XLSTMConfig) -> Dict[str, Any]:
    km, ks, ke, kx = jax.random.split(key, 4)
    n_m_stacked = cfg.n_super * cfg.m_per_super
    m_blocks = stack_layer_params(
        [_mlstm_init(k, cfg) for k in jax.random.split(km, max(n_m_stacked, 1))]
    )
    # reshape to [n_super, m_per_super, ...]
    m_blocks = jax.tree_util.tree_map(
        lambda a: a.reshape((cfg.n_super, cfg.m_per_super) + a.shape[1:]), m_blocks
    )
    s_blocks = stack_layer_params(
        [_slstm_init(k, cfg) for k in jax.random.split(ks, max(cfg.n_super, 1))]
    )
    params = dict(
        embed=embed_init(ke, cfg.vocab, cfg.d_model),
        super_blocks=dict(mlstm=m_blocks, slstm=s_blocks),
        final_norm=jnp.ones((cfg.d_model,), jnp.float32),
    )
    if cfg.n_extra_m:
        params["extra_m"] = stack_layer_params(
            [_mlstm_init(k, cfg) for k in jax.random.split(kx, cfg.n_extra_m)]
        )
    if not cfg.tie_embeddings:
        params["lm_head"] = dense_init(ke, cfg.d_model, cfg.vocab)
    return params


def param_specs(cfg: XLSTMConfig) -> Dict[str, Any]:
    specs = dict(
        embed=ParamSpec(storage=("fsdp", "tensor"), gathered=(None, "tensor")),
        super_blocks=dict(mlstm=_mlstm_specs(), slstm=_slstm_specs()),
        final_norm=RSPEC,
    )
    if cfg.n_extra_m:
        specs["extra_m"] = _mlstm_specs()
    if not cfg.tie_embeddings:
        specs["lm_head"] = wspec("fsdp", "tensor")
    return specs


# ---------------------------------------------------------------------------
# cells
# ---------------------------------------------------------------------------


def _causal_conv(x: jax.Array, conv_w: jax.Array, carry: Optional[jax.Array] = None):
    """Depthwise causal conv along seq.  x [B, S, C]; conv_w [K, C].

    With `carry` [B, K-1, C] (decode ring) uses it as left context and
    returns (y, new_carry).
    """
    k = conv_w.shape[0]
    if carry is None:
        xp = jnp.pad(x, ((0, 0), (k - 1, 0), (0, 0)))
    else:
        xp = jnp.concatenate([carry.astype(x.dtype), x], axis=1)
    y = sum(xp[:, i : i + x.shape[1]] * conv_w[i] for i in range(k))
    new_carry = xp[:, -(k - 1):] if k > 1 else None
    return y, new_carry


def _mlstm_scan(q, k, v, i_pre, f_pre, state):
    """Run the mLSTM recurrence over time.

    q/k [B,S,H,dk], v [B,S,H,dv], i_pre/f_pre [B,S,H].
    state (C [B,H,dv,dk], n [B,H,dk], m [B,H]) or None.
    Returns h [B,S,H,dv], new state.
    """
    b, s, h, dk = q.shape
    dv = v.shape[-1]
    if state is None:
        state = (
            jnp.zeros((b, h, dv, dk), jnp.float32),
            jnp.zeros((b, h, dk), jnp.float32),
            jnp.full((b, h), -1e30, jnp.float32),
        )

    def step(carry, xs):
        C, n, m = carry
        qt, kt, vt, it, ft = xs  # [B,H,dk],[B,H,dk],[B,H,dv],[B,H],[B,H]
        f_log = jax.nn.log_sigmoid(ft)
        m_new = jnp.maximum(f_log + m, it)
        i_sc = jnp.exp(it - m_new)
        f_sc = jnp.exp(f_log + m - m_new)
        C = f_sc[..., None, None] * C + i_sc[..., None, None] * (
            vt[..., :, None] * kt[..., None, :]
        )
        n = f_sc[..., None] * n + i_sc[..., None] * kt
        num = jnp.einsum("bhvk,bhk->bhv", C, qt)
        den = jnp.abs(jnp.einsum("bhk,bhk->bh", n, qt))
        ht = num / jnp.maximum(den, 1.0)[..., None]
        return (C, n, m_new), ht

    xs = (
        q.transpose(1, 0, 2, 3),
        k.transpose(1, 0, 2, 3),
        v.transpose(1, 0, 2, 3),
        i_pre.transpose(1, 0, 2),
        f_pre.transpose(1, 0, 2),
    )
    state, hs = jax.lax.scan(step, state, xs)
    return hs.transpose(1, 0, 2, 3), state  # [B,S,H,dv]


def _mlstm_chunked(q, k, v, i_pre, f_pre, state, chunk: int = 64):
    """Chunkwise-parallel mLSTM — same math as :func:`_mlstm_scan`.

    §Perf hillclimb (EXPERIMENTS.md): the recurrent form reads+writes the
    matrix state C [B,H,dv,dk] every timestep — at xlstm-350m/train_4k that
    is ~67 MB x 2 x 4096 steps x 21 layers of HBM traffic (the worst
    roofline cell in the baseline table).  The chunkwise form materializes
    C once per *chunk*: within a chunk the contributions are computed in
    parallel, attention-style, with exact exponential-gating stabilizers:

      F_i   = Σ_{l<=i} logsigmoid(f_l)           (cumulative log-decay)
      D_ij  = F_i - F_j + ĩ_j   (j <= i)         (intra-chunk log-weights)
      m_i   = max(m_prev + F_i, max_j D_ij)      == the sequential m_t
      h_i   = [exp(m_prev+F_i-m_i)·q_i C_prev + Σ_j exp(D_ij-m_i)(q_i·k_j)v_j]
              / max(|n_i·q_i-analogue|, 1)

    The stabilizer recursion m_t = max(m_{t-1}+logσ(f_t), ĩ_t) unrolls to
    exactly this max, so chunked == sequential up to fp reassociation
    (tested).  State HBM traffic drops by the chunk length; the added
    intra-chunk work is MXU-friendly matmuls.
    """
    b, s, h, dk = q.shape
    dv = v.shape[-1]
    if state is None:
        state = (
            jnp.zeros((b, h, dv, dk), jnp.float32),
            jnp.zeros((b, h, dk), jnp.float32),
            jnp.full((b, h), -1e30, jnp.float32),
        )
    c = min(chunk, s)
    while s % c:
        c -= 1
    n_chunks = s // c

    def to_chunks(x, nfeat):
        x = x.reshape((b, n_chunks, c, h) + x.shape[3:])
        perm = (1, 0, 3, 2) + tuple(range(4, 4 + nfeat))
        return x.transpose(perm)  # [n_chunks, B, H, C, ...]

    qs = shard_hint(to_chunks(q.astype(jnp.float32), 1),
                    None, "batch", None, None, None)
    ks = shard_hint(to_chunks(k.astype(jnp.float32), 1),
                    None, "batch", None, None, None)
    vs = shard_hint(to_chunks(v.astype(jnp.float32), 1),
                    None, "batch", None, None, "dstate")
    is_ = shard_hint(to_chunks(i_pre.astype(jnp.float32), 0),
                     None, "batch", None, None)
    fs = shard_hint(to_chunks(f_pre.astype(jnp.float32), 0),
                    None, "batch", None, None)
    tri = jnp.tril(jnp.ones((c, c), bool))

    def one_chunk(carry, xs):
        C_prev, n_prev, m_prev = carry
        qc, kc, vc, ic, fc = xs  # [B,H,C,dk],[B,H,C,dk],[B,H,C,dv],[B,H,C]x2
        f_log = jax.nn.log_sigmoid(fc)
        F = jnp.cumsum(f_log, axis=-1)  # F_i (inclusive)
        D = F[..., :, None] - F[..., None, :] + ic[..., None, :]
        D = jnp.where(tri, D, -jnp.inf)
        b_i = m_prev[..., None] + F
        m_i = jnp.maximum(b_i, jnp.max(D, axis=-1))
        w_inter = jnp.exp(b_i - m_i)  # [B,H,C]
        w_intra = jnp.exp(D - m_i[..., None])  # [B,H,C,C]
        scores = jnp.einsum("bhid,bhjd->bhij", qc, kc)
        p = w_intra * scores
        h_intra = jnp.einsum("bhij,bhjv->bhiv", p, vc)
        h_inter = w_inter[..., None] * jnp.einsum("bhvk,bhik->bhiv", C_prev, qc)
        n_intra = jnp.sum(p, axis=-1)
        n_inter = w_inter * jnp.einsum("bhk,bhik->bhi", n_prev, qc)
        den = jnp.abs(n_inter + n_intra)
        hv = (h_inter + h_intra) / jnp.maximum(den, 1.0)[..., None]
        # chunk-boundary state: contribution of step j decays by F_C - F_j
        F_C = F[..., -1]
        g = F_C[..., None] - F + ic  # [B,H,C]
        m_next = jnp.maximum(m_prev + F_C, jnp.max(g, axis=-1))
        wj = jnp.exp(g - m_next[..., None])
        decay = jnp.exp(m_prev + F_C - m_next)
        C_next = (decay[..., None, None] * C_prev
                  + jnp.einsum("bhj,bhjv,bhjk->bhvk", wj, vc, kc))
        n_next = decay[..., None] * n_prev + jnp.einsum("bhj,bhjk->bhk", wj, kc)
        return (C_next, n_next, m_next), hv

    # remat per chunk: backward recomputes the intra-chunk tiles instead of
    # stacking [n_chunks, B, H, C, C] weight tensors in HBM
    one_chunk = jax.checkpoint(one_chunk, prevent_cse=False)
    state, hs = jax.lax.scan(one_chunk, state, (qs, ks, vs, is_, fs))
    hs = hs.transpose(1, 0, 3, 2, 4).reshape(b, s, h, dv)
    return hs, state


def _group_norm_heads(x: jax.Array, scale: jax.Array, eps: float):
    """Per-head group norm.  x [B, S, H, dh]; scale [H*dh]."""
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.var(x, axis=-1, keepdims=True)
    xn = (x - mu) * jax.lax.rsqrt(var + eps)
    b, s, h, dh = x.shape
    return xn.reshape(b, s, h * dh) * scale


def mlstm_block(cfg: XLSTMConfig, w, x, conv_carry=None, cell_state=None):
    """x [B,S,D] -> (x', (conv_carry', cell_state')).  Decode-compatible."""
    b, s, d = x.shape
    h_heads, dh = cfg.n_heads, cfg.m_head_dim
    hin = rms_norm(x, w["norm"], cfg.norm_eps)
    up = hin @ w["w_up"]
    up = shard_hint(up, "batch", None, "tensor")
    main, z = jnp.split(up, 2, axis=-1)  # [B,S,Di] each
    main_c, conv_carry = _causal_conv(main, w["conv_w"], conv_carry)
    main_c = jax.nn.silu(main_c)
    q = (main_c @ w["wq"]).reshape(b, s, h_heads, dh)
    k = (main_c @ w["wk"]).reshape(b, s, h_heads, dh) / np.sqrt(dh)
    v = shard_hint(main @ w["wv"], "batch", None, "dstate").reshape(b, s, h_heads, dh)
    if_pre = main_c @ w["w_if"] + w["b_if"]  # [B,S,2H]
    i_pre, f_pre = jnp.split(if_pre, 2, axis=-1)
    if cfg.mlstm_impl == "chunked" and s > 1:
        hs, cell_state = _mlstm_chunked(q, k, v, i_pre, f_pre, cell_state,
                                        chunk=cfg.mlstm_chunk)
    else:
        hs, cell_state = _mlstm_scan(q, k, v, i_pre, f_pre, cell_state)
    hs = _group_norm_heads(hs, w["gn_scale"], cfg.norm_eps)
    hs = hs * jax.nn.silu(z)
    out = hs @ w["w_down"]
    return (x + shard_hint(out, "batch", None, None)).astype(x.dtype), (
        conv_carry, cell_state)


def slstm_block(cfg: XLSTMConfig, w, x, state=None):
    """x [B,S,D] -> (x', state').  True recurrence (h_{t-1} feedback)."""
    b, s, d = x.shape
    h_heads, dh = cfg.n_heads, cfg.s_head_dim
    hin = rms_norm(x, w["norm"], cfg.norm_eps)
    gates_x = hin @ w["w_gates"] + w["b_gates"]  # [B,S,4D]
    gates_x = gates_x.reshape(b, s, 4, h_heads, dh)
    if state is None:
        state = (
            jnp.zeros((b, h_heads, dh), jnp.float32),  # c
            jnp.zeros((b, h_heads, dh), jnp.float32),  # n
            jnp.full((b, h_heads, dh), -1e30, jnp.float32),  # m
            jnp.zeros((b, h_heads, dh), jnp.float32),  # h
        )

    def step(carry, gx):
        c, n, m, h_prev = carry  # each [B, H, dh]; gx [B, 4, H, dh]
        # recurrent contribution, block-diagonal per head
        gr = jnp.einsum("bhd,hde->bhe", h_prev, w["r_gates"])
        gr = gr.reshape(b, h_heads, 4, dh).transpose(0, 2, 1, 3)  # [B,4,H,dh]
        g = gx + gr
        gi, gf, gz, go = g[:, 0], g[:, 1], g[:, 2], g[:, 3]
        f_log = jax.nn.log_sigmoid(gf)
        m_new = jnp.maximum(f_log + m, gi)
        i_sc = jnp.exp(gi - m_new)
        f_sc = jnp.exp(f_log + m - m_new)
        c = f_sc * c + i_sc * jnp.tanh(gz)
        n = f_sc * n + i_sc
        h_new = jax.nn.sigmoid(go) * c / jnp.maximum(n, 1.0)
        return (c, n, m_new, h_new), h_new

    state, hs = jax.lax.scan(step, state, gates_x.transpose(1, 0, 2, 3, 4))
    hs = hs.transpose(1, 0, 2, 3)  # [B,S,H,dh]
    hs = _group_norm_heads(hs, w["gn_scale"], cfg.norm_eps)
    out = hs @ w["w_down"]
    return (x + shard_hint(out, "batch", None, None)).astype(x.dtype), state


# ---------------------------------------------------------------------------
# forward / loss
# ---------------------------------------------------------------------------


def forward(cfg: XLSTMConfig, params, batch, mat: Materializer):
    tokens = batch["tokens"]
    emb_w = mat({"embed": params["embed"]}, {"embed": param_specs(cfg)["embed"]})
    x = jnp.take(emb_w["embed"], tokens, axis=0)
    x = shard_hint(x, "batch", None, None)

    def super_body(carry, super_params, _):
        x_ = carry
        m_stack, s_params = super_params["mlstm"], super_params["slstm"]

        def m_body(c, w_layer):
            w = mat(w_layer, _mlstm_specs())
            out, _ = mlstm_block(cfg, w, c)
            return out, None

        x_, _ = jax.lax.scan(jax.checkpoint(m_body, prevent_cse=False), x_, m_stack)
        x_, _ = slstm_block(cfg, mat(s_params, _slstm_specs()), x_)
        return x_

    x = scan_blocks(
        super_body, params["super_blocks"], x, lambda t, s=None: t, None
    )
    if cfg.n_extra_m:
        def m_body(c, w_layer):
            w = mat(w_layer, _mlstm_specs())
            out, _ = mlstm_block(cfg, w, c)
            return out, None

        x, _ = jax.lax.scan(jax.checkpoint(m_body, prevent_cse=False), x, params["extra_m"])
    return rms_norm(x, mat.leaf(params["final_norm"]), cfg.norm_eps)


def _head_weight(cfg, params, mat):
    if cfg.tie_embeddings:
        emb = mat({"e": params["embed"]},
                  {"e": ParamSpec(("fsdp", "tensor"), ("tensor", None))})["e"]
        return emb.T
    return mat({"h": params["lm_head"]}, {"h": wspec("fsdp", "tensor")})["h"]


def loss(cfg: XLSTMConfig, params, batch, mat: Materializer) -> jax.Array:
    hidden = forward(cfg, params, batch, mat)
    return softmax_xent_chunked(
        hidden, _head_weight(cfg, params, mat), batch["labels"], batch.get("mask")
    )


# ---------------------------------------------------------------------------
# serving — constant-size recurrent state
# ---------------------------------------------------------------------------


def init_decode_state(cfg: XLSTMConfig, batch: int, max_len: int, dtype=jnp.float32):
    """State pytree; max_len is irrelevant (O(1) state) — kept for API parity."""
    del max_len
    b, h = batch, cfg.n_heads
    dk = dv = cfg.m_head_dim
    km1 = cfg.conv_kernel - 1
    n_m_stacked = cfg.n_super * cfg.m_per_super

    def m_state(n):
        return dict(
            conv=jnp.zeros((n, b, km1, cfg.d_inner), jnp.float32),
            C=jnp.zeros((n, b, h, dv, dk), jnp.float32),
            n=jnp.zeros((n, b, h, dk), jnp.float32),
            m=jnp.full((n, b, h), -1e30, jnp.float32),
        )

    state = dict(
        mlstm=jax.tree_util.tree_map(
            lambda a: a.reshape((cfg.n_super, cfg.m_per_super) + a.shape[1:]),
            m_state(max(n_m_stacked, 1)),
        ),
        slstm=dict(
            c=jnp.zeros((cfg.n_super, b, h, cfg.s_head_dim), jnp.float32),
            n=jnp.zeros((cfg.n_super, b, h, cfg.s_head_dim), jnp.float32),
            m=jnp.full((cfg.n_super, b, h, cfg.s_head_dim), -1e30, jnp.float32),
            h=jnp.zeros((cfg.n_super, b, h, cfg.s_head_dim), jnp.float32),
        ),
        length=jnp.zeros((), jnp.int32),
    )
    if cfg.n_extra_m:
        state["extra_m"] = m_state(cfg.n_extra_m)
    return state


def state_shard_hint(state):
    f = lambda a, *ax: shard_hint(a, *ax)
    out = dict(state)
    out["mlstm"] = dict(
        conv=f(state["mlstm"]["conv"], None, None, "batch", None, "dstate"),
        C=f(state["mlstm"]["C"], None, None, "batch", None, "dstate", None),
        n=f(state["mlstm"]["n"], None, None, "batch", None, None),
        m=f(state["mlstm"]["m"], None, None, "batch", None),
    )
    if "extra_m" in state:
        out["extra_m"] = dict(
            conv=f(state["extra_m"]["conv"], None, "batch", None, "dstate"),
            C=f(state["extra_m"]["C"], None, "batch", None, "dstate", None),
            n=f(state["extra_m"]["n"], None, "batch", None, None),
            m=f(state["extra_m"]["m"], None, "batch", None),
        )
    return out


def _decode_mlstm_group(cfg, mat, stack_params, stack_state, x):
    """scan one group of stacked mLSTM layers for a single token."""

    def body(carry, xs):
        x_ = carry
        w_layer, st = xs
        w = mat(w_layer, _mlstm_specs())
        out, (conv_c, (C, n, m)) = mlstm_block(
            cfg, w, x_, conv_carry=st["conv"], cell_state=(st["C"], st["n"], st["m"])
        )
        return out, dict(conv=conv_c, C=C, n=n, m=m)

    x, new_state = jax.lax.scan(body, x, (stack_params, stack_state))
    return x, new_state


def prefill(cfg: XLSTMConfig, params, batch, mat: Materializer, state):
    """Process the prompt sequentially, returning (state, last-token logits)."""
    tokens = batch["tokens"]
    b, s = tokens.shape
    emb_w = mat({"embed": params["embed"]}, {"embed": param_specs(cfg)["embed"]})
    x = shard_hint(jnp.take(emb_w["embed"], tokens, axis=0), "batch", None, None)

    new_state = {"length": jnp.asarray(s, jnp.int32)}
    m_states, s_states = [], []
    for g in range(cfg.n_super):
        sub_p = jax.tree_util.tree_map(lambda a: a[g], params["super_blocks"])
        sub_m_st = jax.tree_util.tree_map(lambda a: a[g], state["mlstm"])

        def m_body(carry, xs):
            x_, = carry
            w_layer, st = xs
            w = mat(w_layer, _mlstm_specs())
            out, (conv_c, (C, n, m)) = mlstm_block(
                cfg, w, x_, conv_carry=st["conv"],
                cell_state=(st["C"], st["n"], st["m"]),
            )
            return (out,), dict(conv=conv_c, C=C, n=n, m=m)

        (x,), m_st = jax.lax.scan(
            jax.checkpoint(m_body, prevent_cse=False), (x,), (sub_p["mlstm"], sub_m_st)
        )
        m_states.append(m_st)
        x, s_st = slstm_block(
            cfg, mat(sub_p["slstm"], _slstm_specs()), x,
            state=tuple(state["slstm"][k][g] for k in ("c", "n", "m", "h")),
        )
        s_states.append(s_st)
    new_state["mlstm"] = jax.tree_util.tree_map(lambda *xs: jnp.stack(xs), *m_states)
    new_state["slstm"] = dict(
        zip(("c", "n", "m", "h"), (jnp.stack([st[i] for st in s_states]) for i in range(4)))
    )
    if cfg.n_extra_m:
        def m_body2(carry, xs):
            x_, = carry
            w_layer, st = xs
            w = mat(w_layer, _mlstm_specs())
            out, (conv_c, (C, n, m)) = mlstm_block(
                cfg, w, x_, conv_carry=st["conv"],
                cell_state=(st["C"], st["n"], st["m"]),
            )
            return (out,), dict(conv=conv_c, C=C, n=n, m=m)

        (x,), ex_st = jax.lax.scan(
            jax.checkpoint(m_body2, prevent_cse=False), (x,),
            (params["extra_m"], state["extra_m"]),
        )
        new_state["extra_m"] = ex_st
    x = rms_norm(x, mat.leaf(params["final_norm"]), cfg.norm_eps)
    logits = x[:, -1:] @ _head_weight(cfg, params, mat)
    return state_shard_hint(new_state), shard_hint(logits, "batch", None, "tensor")


def decode_step(cfg: XLSTMConfig, params, state, tokens, mat: Materializer):
    """One token [B,1] through the recurrence -> (state', logits)."""
    batch = dict(tokens=tokens)
    new_state, logits = prefill(cfg, params, batch, mat, state)
    new_state["length"] = state["length"] + 1
    return new_state, logits
