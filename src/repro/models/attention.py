"""Grouped-query attention with flash-style chunking, SWA, and a KV cache.

TPU adaptation notes (DESIGN.md §2):
  * Train/prefill attention is double-chunked (outer scan over Q blocks,
    inner scan over KV blocks with an online softmax) so the score transient
    is a bounded [B, q_blk, H, kv_blk] tile — never the full S x S matrix.
    This is the memory behaviour a fused TPU flash kernel gives; expressing
    it as jnp + lax.scan lets XLA keep it in registers/VMEM-sized chunks and
    keeps the dry-run memory analysis honest at 32k/500k sequence lengths.
  * GQA is computed grouped (q reshaped to [B, S, KVH, G, hd]) instead of
    repeating KV heads — no materialized KV repeat.
  * Sliding-window attention (mistral/danube/mixtral) is a positional mask;
    the decode cache for SWA archs is a ring buffer of width W, which is what
    bounds the ``long_500k`` working set.
"""

from __future__ import annotations

from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp
import numpy as np

from .common import current_mesh, shard_hint

NEG_INF = -1e30


def _qblk_axis_size() -> int:
    """Size of the mesh axis the q-block dim shards over (1 if no mesh)."""
    mesh = current_mesh()
    if mesh is None:
        return 1
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    return sizes.get("model", 1)


def _pick_q_block(sq: int, target: int, m: int) -> int:
    """q_block such that nq = sq/q_block is a multiple of the model axis.

    Without this, head-count-agnostic sequence sharding silently drops
    (e.g. nq=8 on a 16-way axis) and the attention core replicates over
    `model` — 16x wasted compute.
    """
    if m > 1 and sq % m == 0:
        # candidate nq values: multiples of m closest to sq/target
        want_nq = max(1, round(sq / max(target, 1)))
        nq = max(m, ((want_nq + m - 1) // m) * m)
        while sq % nq and nq > m:
            nq -= m
        if sq % nq == 0:
            return sq // nq
    q_block = min(target, sq)
    while sq % q_block:
        q_block -= 1
    return q_block


def _mask_bias(q_pos, k_pos, *, causal: bool, window: Optional[int], k_valid=None):
    """[.., Sq, Sk] additive bias from positional visibility rules."""
    ok = jnp.ones(q_pos.shape[:-1] + (q_pos.shape[-1], k_pos.shape[-1]), bool)
    qp = q_pos[..., :, None]
    kp = k_pos[..., None, :]
    if causal:
        ok = ok & (kp <= qp)
    if window is not None:
        ok = ok & (kp > qp - window)
    if k_valid is not None:
        ok = ok & k_valid[..., None, :]
    return jnp.where(ok, 0.0, NEG_INF).astype(jnp.float32)


def attend(
    q: jax.Array,  # [B, Sq, H, hd]
    k: jax.Array,  # [B, Sk, KVH, hd]
    v: jax.Array,  # [B, Sk, KVH, hd]
    q_pos: jax.Array,  # [B, Sq] int32 absolute positions
    k_pos: jax.Array,  # [B, Sk]
    *,
    causal: bool = True,
    window: Optional[int] = None,
    k_valid: Optional[jax.Array] = None,  # [B, Sk] bool
    q_block: int = 512,
    kv_block: int = 512,
) -> jax.Array:
    """Online-softmax chunked attention. Returns [B, Sq, H, hd] (q dtype).

    Layout: the q-block index is a *tensor dimension* sharded over the model
    axis (Ulysses-style sequence parallelism) — q blocks are independent given
    the KV stream, so this gives the attention core model-parallelism that
    works for any (H, KVH) combination (GQA head counts rarely divide a
    16-way TP axis).  The KV stream is consumed block-by-block with a
    ``lax.scan`` carrying online-softmax stats, so the score transient is a
    bounded [B, nq_shard, q_block, H, kv_block] tile, never the full Sq × Sk
    matrix.
    """
    b, sq, h, hd = q.shape
    _, sk, kvh, _ = k.shape
    g = h // kvh
    scale = 1.0 / np.sqrt(hd)

    # Pin head/hd dims replicated: the 4-D reshape from the tensor-sharded
    # projection otherwise lets GSPMD shard head_dim, which turns the score
    # einsum into a per-kv-block psum (catastrophic wire traffic).
    q = shard_hint(q, "batch", None, None, None)
    k = shard_hint(k, "batch", None, None, None)
    v = shard_hint(v, "batch", None, None, None)

    q_block = _pick_q_block(sq, q_block, _qblk_axis_size())
    kv_block = min(kv_block, sk)
    while sk % kv_block:
        kv_block -= 1
    nq, nk = sq // q_block, sk // kv_block

    qg = (q.astype(jnp.float32) * scale).reshape(b, nq, q_block, kvh, g, hd)
    qg = shard_hint(qg, "batch", "qblk", None, None, None, None)
    qp = q_pos.reshape(b, nq, q_block)
    qp = shard_hint(qp, "batch", "qblk", None)
    kf = k.astype(jnp.float32)
    vf = v.astype(jnp.float32)
    if k_valid is None:
        k_valid = jnp.ones((b, sk), bool)

    # [nk, B, kv_block, ...] scan layouts — each block is replicated over the
    # model axis while it streams past every (sharded) q block.
    k_js = kf.reshape(b, nk, kv_block, kvh, hd).transpose(1, 0, 2, 3, 4)
    v_js = vf.reshape(b, nk, kv_block, kvh, hd).transpose(1, 0, 2, 3, 4)
    kp_js = k_pos.reshape(b, nk, kv_block).transpose(1, 0, 2)
    kv_js = k_valid.reshape(b, nk, kv_block).transpose(1, 0, 2)

    def kv_chunk(carry, kv_xs_j):
        m, l, acc = carry
        kj, vj, kpj, kvj = kv_xs_j
        s = jnp.einsum("bnqhgd,bkhd->bnqhgk", qg, kj)  # [B,nq,qb,KVH,G,kb]
        bias = _mask_bias(qp, kpj[:, None], causal=causal, window=window,
                          k_valid=kvj[:, None])  # [B, nq, qb, kb]
        s = s + bias[:, :, :, None, None, :]
        m_new = jnp.maximum(m, s.max(axis=-1))
        alpha = jnp.exp(m - m_new)
        p = jnp.exp(s - m_new[..., None])
        l_new = l * alpha + p.sum(axis=-1)
        acc_new = acc * alpha[..., None] + jnp.einsum("bnqhgk,bkhd->bnqhgd", p, vj)
        return (m_new, l_new, acc_new), None

    # Flash-attention backward semantics: recompute the score/softmax tiles
    # per kv block instead of letting the scan stack them for backward —
    # without this every layer materializes the full Sq x Sk probability
    # tensor in HBM during the backward pass (measured as the dominant
    # memory-roofline contributor across all attention archs).
    kv_chunk = jax.checkpoint(kv_chunk, prevent_cse=False)

    m0 = jnp.full((b, nq, q_block, kvh, g), NEG_INF, jnp.float32)
    l0 = jnp.zeros((b, nq, q_block, kvh, g), jnp.float32)
    a0 = shard_hint(
        jnp.zeros((b, nq, q_block, kvh, g, hd), jnp.float32),
        "batch", "qblk", None, None, None, None,
    )
    (m, l, acc), _ = jax.lax.scan(kv_chunk, (m0, l0, a0), (k_js, v_js, kp_js, kv_js))
    out = acc / jnp.maximum(l[..., None], 1e-30)
    out = out.reshape(b, sq, h, hd)
    return out.astype(q.dtype)


# ---------------------------------------------------------------------------
# KV cache (decode)
# ---------------------------------------------------------------------------


class KVCache(NamedTuple):
    """Per-layer-stacked KV cache.

    k/v: [L, B, S_buf, KVH, hd].  For SWA archs S_buf = window (ring buffer),
    otherwise S_buf = max context.  ``pos`` holds absolute positions written
    at each slot (-1 = empty); used for masking and ring-buffer decode.
    """

    k: jax.Array
    v: jax.Array
    pos: jax.Array  # [L, B, S_buf] int32, -1 where invalid
    length: jax.Array  # [] int32 — tokens generated so far (absolute)

    @property
    def buf_len(self) -> int:
        return self.k.shape[2]


def init_cache(
    n_layers: int,
    batch: int,
    buf_len: int,
    kv_heads: int,
    head_dim: int,
    dtype=jnp.float32,
) -> KVCache:
    return KVCache(
        k=jnp.zeros((n_layers, batch, buf_len, kv_heads, head_dim), dtype),
        v=jnp.zeros((n_layers, batch, buf_len, kv_heads, head_dim), dtype),
        pos=jnp.full((n_layers, batch, buf_len), -1, jnp.int32),
        length=jnp.zeros((), jnp.int32),
    )


def cache_shard_hint(c: KVCache) -> KVCache:
    """Sharding: batch->data; KV heads->tensor when divisible else seq->model."""
    return KVCache(
        k=shard_hint(c.k, None, "batch", "kv_seq", "tensor", None),
        v=shard_hint(c.v, None, "batch", "kv_seq", "tensor", None),
        pos=shard_hint(c.pos, None, "batch", "kv_seq"),
        length=c.length,
    )


def cache_insert(layer_k, layer_v, layer_pos, k_new, v_new, position, ring: bool):
    """Insert one token's K/V at absolute ``position`` (ring-buffered if SWA).

    layer_k/v: [B, S_buf, KVH, hd]; k_new/v_new: [B, 1, KVH, hd];
    position: [] int32.
    """
    s_buf = layer_k.shape[1]
    slot = jnp.where(ring, position % s_buf, jnp.minimum(position, s_buf - 1))
    k = jax.lax.dynamic_update_slice(layer_k, k_new.astype(layer_k.dtype), (0, slot, 0, 0))
    v = jax.lax.dynamic_update_slice(layer_v, v_new.astype(layer_v.dtype), (0, slot, 0, 0))
    pos = jax.lax.dynamic_update_slice(
        layer_pos,
        jnp.full((layer_pos.shape[0], 1), position, jnp.int32),
        (0, slot),
    )
    return k, v, pos


def decode_attend(
    q: jax.Array,  # [B, 1, H, hd]
    layer_k: jax.Array,  # [B, S_buf, KVH, hd]
    layer_v: jax.Array,
    layer_pos: jax.Array,  # [B, S_buf]
    q_position,  # [] int32 absolute
    *,
    window: Optional[int] = None,
    causal: bool = True,  # False for cross-attention memory
) -> jax.Array:
    """Single-token attention against the cache (no chunking needed: Sq=1)."""
    b, _, h, hd = q.shape
    kvh = layer_k.shape[2]
    g = h // kvh
    scale = 1.0 / np.sqrt(hd)
    qg = (q.astype(jnp.float32) * scale).reshape(b, 1, kvh, g, hd)
    s = jnp.einsum("bqhgd,bkhd->bqhgk", qg, layer_k.astype(jnp.float32))
    q_pos = jnp.full((b, 1), q_position, jnp.int32)
    valid = layer_pos >= 0
    bias = _mask_bias(q_pos, layer_pos, causal=causal, window=window, k_valid=valid)
    s = s + bias[:, :, None, None, :]
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bqhgk,bkhd->bqhgd", p, layer_v.astype(jnp.float32))
    return out.reshape(b, 1, h, hd).astype(q.dtype)
