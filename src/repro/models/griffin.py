"""Griffin / RecurrentGemma hybrid (RG-LRU recurrent blocks + local attention).

Structure follows arXiv:2402.19427: residual blocks in a repeating
(recurrent, recurrent, attention) pattern — 1 attention per 3 mixers — each
followed by a GeGLU MLP.  The recurrent mixer is the RG-LRU: a *diagonal*
gated linear recurrence
    h_t = a_t * h_{t-1} + sqrt(1 - a_t^2) * (i_t * x_t)
which is associative, so train/prefill run it with ``lax.associative_scan``
(log-depth, sequence-parallelizable) instead of a sequential time loop —
this is the TPU-native adaptation of the paper's linear-scan CUDA kernel.

Sharding: the LRU width is sharded over `dstate` -> `model` (recurrence is
elementwise, zero per-step collectives); attention uses the shared GQA/MQA
path (q-block sharding); MLP is column/row-parallel.

OMC applicability (DESIGN.md §6): all projection matrices quantize; the
RG-LRU recurrence parameters (Λ, gate biases — tiny and sensitive) are
excluded via the weights-only policy (they are 1-D).
"""

from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from . import attention as attn
from .common import (
    Materializer,
    ParamSpec,
    RSPEC,
    apply_rope,
    dense_init,
    embed_init,
    rms_norm,
    shard_hint,
    softmax_xent_chunked,
    stack_layer_params,
    wspec,
)


@dataclasses.dataclass(frozen=True)
class GriffinConfig:
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    lru_width: Optional[int] = None  # defaults to d_model
    window: int = 2048
    conv_kernel: int = 4
    pattern_period: int = 3  # 1 attention block per period
    rope_theta: float = 10_000.0
    norm_eps: float = 1e-6
    tie_embeddings: bool = True
    a_param_init: float = 0.95  # initial recurrence magnitude

    @property
    def lru(self) -> int:
        return self.lru_width or self.d_model

    @property
    def hd(self) -> int:
        return self.d_model // self.n_heads

    @property
    def n_super(self) -> int:
        return self.n_layers // self.pattern_period

    @property
    def rec_per_super(self) -> int:
        return self.pattern_period - 1

    @property
    def n_extra_rec(self) -> int:
        return self.n_layers - self.n_super * self.pattern_period

    def param_count(self) -> int:
        d, f, r = self.d_model, self.d_ff, self.lru
        mlp = 3 * d * f + d
        rec = 2 * d * r + self.conv_kernel * r + 2 * r + 2 * r + r * d + d + mlp
        att = d * (self.n_heads + 2 * self.n_kv_heads) * self.hd + self.n_heads * self.hd * d + d + mlp
        n_att = self.n_super
        n_rec = self.n_layers - n_att
        emb = self.vocab * d * (1 if self.tie_embeddings else 2)
        return n_rec * rec + n_att * att + emb + d


# ---------------------------------------------------------------------------
# init / specs
# ---------------------------------------------------------------------------


def _rec_init(key, cfg: GriffinConfig) -> Dict[str, Any]:
    ks = jax.random.split(key, 7)
    d, r, f = cfg.d_model, cfg.lru, cfg.d_ff
    # Λ init so that a_t = exp(-8·softplus(Λ)·r) equals a_param_init at r=1
    s0 = -np.log(cfg.a_param_init) / 8.0
    lam = float(np.log(np.expm1(s0)))
    return dict(
        norm=jnp.ones((d,), jnp.float32),
        w_x=dense_init(ks[0], d, r),  # main branch
        w_gate=dense_init(ks[1], d, r),  # gelu gate branch
        conv_w=(jax.random.normal(ks[2], (cfg.conv_kernel, r)) * 0.1).astype(jnp.float32),
        lam=jnp.full((r,), lam, jnp.float32),  # RG-LRU Λ (excluded from OMC)
        w_rg=dense_init(ks[3], r, r, scale=0.5),  # recurrence gate proj
        b_rg=jnp.zeros((r,), jnp.float32),
        w_ig=dense_init(ks[4], r, r, scale=0.5),  # input gate proj
        b_ig=jnp.zeros((r,), jnp.float32),
        w_out=dense_init(ks[5], r, d),
        mlp_norm=jnp.ones((d,), jnp.float32),
        w1=dense_init(ks[6], d, f),
        w3=dense_init(ks[0], d, f),
        w2=dense_init(ks[1], f, d),
    )


def _att_init(key, cfg: GriffinConfig) -> Dict[str, Any]:
    ks = jax.random.split(key, 7)
    d, f = cfg.d_model, cfg.d_ff
    return dict(
        norm=jnp.ones((d,), jnp.float32),
        wq=dense_init(ks[0], d, cfg.n_heads * cfg.hd),
        wk=dense_init(ks[1], d, cfg.n_kv_heads * cfg.hd),
        wv=dense_init(ks[2], d, cfg.n_kv_heads * cfg.hd),
        wo=dense_init(ks[3], cfg.n_heads * cfg.hd, d),
        mlp_norm=jnp.ones((d,), jnp.float32),
        w1=dense_init(ks[4], d, f),
        w3=dense_init(ks[5], d, f),
        w2=dense_init(ks[6], f, d),
    )


def _rec_specs() -> Dict[str, ParamSpec]:
    return dict(
        norm=RSPEC,
        w_x=wspec("fsdp", "dstate"),
        w_gate=wspec("fsdp", "dstate"),
        conv_w=ParamSpec(storage=(None, "dstate"), gathered=(None, "dstate")),
        lam=ParamSpec(storage=("dstate",), gathered=("dstate",)),
        w_rg=wspec("fsdp", "dstate"),
        b_rg=ParamSpec(storage=("dstate",), gathered=("dstate",)),
        w_ig=wspec("fsdp", "dstate"),
        b_ig=ParamSpec(storage=("dstate",), gathered=("dstate",)),
        w_out=wspec("dstate", "fsdp"),
        mlp_norm=RSPEC,
        w1=wspec("fsdp", "tensor"),
        w3=wspec("fsdp", "tensor"),
        w2=wspec("tensor", "fsdp"),
    )


def _att_specs() -> Dict[str, ParamSpec]:
    return dict(
        norm=RSPEC,
        wq=wspec("fsdp", "tensor"),
        wk=wspec("fsdp", "tensor"),
        wv=wspec("fsdp", "tensor"),
        wo=wspec("tensor", "fsdp"),
        mlp_norm=RSPEC,
        w1=wspec("fsdp", "tensor"),
        w3=wspec("fsdp", "tensor"),
        w2=wspec("tensor", "fsdp"),
    )


def init(key, cfg: GriffinConfig) -> Dict[str, Any]:
    kr, ka, ke, kx = jax.random.split(key, 4)
    n_rec_stacked = cfg.n_super * cfg.rec_per_super
    rec = stack_layer_params(
        [_rec_init(k, cfg) for k in jax.random.split(kr, max(n_rec_stacked, 1))]
    )
    rec = jax.tree_util.tree_map(
        lambda a: a.reshape((cfg.n_super, cfg.rec_per_super) + a.shape[1:]), rec
    )
    att = stack_layer_params(
        [_att_init(k, cfg) for k in jax.random.split(ka, max(cfg.n_super, 1))]
    )
    params = dict(
        embed=embed_init(ke, cfg.vocab, cfg.d_model),
        super_blocks=dict(rec=rec, att=att),
        final_norm=jnp.ones((cfg.d_model,), jnp.float32),
    )
    if cfg.n_extra_rec:
        params["extra_rec"] = stack_layer_params(
            [_rec_init(k, cfg) for k in jax.random.split(kx, cfg.n_extra_rec)]
        )
    if not cfg.tie_embeddings:
        params["lm_head"] = dense_init(ke, cfg.d_model, cfg.vocab)
    return params


def param_specs(cfg: GriffinConfig) -> Dict[str, Any]:
    specs = dict(
        embed=ParamSpec(storage=("fsdp", "tensor"), gathered=(None, "tensor")),
        super_blocks=dict(rec=_rec_specs(), att=_att_specs()),
        final_norm=RSPEC,
    )
    if cfg.n_extra_rec:
        specs["extra_rec"] = _rec_specs()
    if not cfg.tie_embeddings:
        specs["lm_head"] = wspec("fsdp", "tensor")
    return specs


# ---------------------------------------------------------------------------
# RG-LRU
# ---------------------------------------------------------------------------


def _rg_lru(x: jax.Array, w, h0: Optional[jax.Array] = None):
    """x [B, S, R] -> (y [B, S, R], h_last [B, R]) via associative scan.

    a_t = sigmoid(Λ)^(8·r_t),  r_t = sigmoid(x_t @ w_rg + b_rg)
    h_t = a_t h_{t-1} + sqrt(1 - a_t²) · (i_t ⊙ x_t),  i_t = sigmoid(x @ w_ig + b_ig)
    """
    r_gate = jax.nn.sigmoid(x @ w["w_rg"] + w["b_rg"])
    i_gate = jax.nn.sigmoid(x @ w["w_ig"] + w["b_ig"])
    log_a = -8.0 * r_gate * jax.nn.softplus(w["lam"])
    a = jnp.exp(log_a)
    b = jnp.sqrt(jnp.maximum(1.0 - jnp.exp(2.0 * log_a), 1e-12)) * (i_gate * x)

    if h0 is not None:
        # fold the carried state into the first step's additive term
        b = b.at[:, 0].add(a[:, 0] * h0)

    def combine(lhs, rhs):
        a1, b1 = lhs
        a2, b2 = rhs
        return a1 * a2, a2 * b1 + b2

    _, h = jax.lax.associative_scan(combine, (a, b), axis=1)
    return h, h[:, -1]


def rec_block(cfg: GriffinConfig, w, x, positions=None, conv_carry=None, h0=None):
    """Recurrent mixer + MLP.  Returns (x', (conv_carry', h_last))."""
    del positions
    dtype_in = x.dtype
    hin = rms_norm(x, w["norm"], cfg.norm_eps)
    gate = jax.nn.gelu(shard_hint(hin @ w["w_gate"], "batch", None, "dstate"))
    main = shard_hint(hin @ w["w_x"], "batch", None, "dstate")
    main, conv_carry = _causal_conv(main, w["conv_w"], conv_carry)
    y, h_last = _rg_lru(main, w, h0)
    y = y * gate
    x = x + shard_hint(y @ w["w_out"], "batch", None, None)
    h2 = rms_norm(x, w["mlp_norm"], cfg.norm_eps)
    h2 = jax.nn.gelu(shard_hint(h2 @ w["w1"], "batch", None, "tensor")) * (h2 @ w["w3"])
    x = (x + shard_hint(h2 @ w["w2"], "batch", None, None)).astype(dtype_in)
    return x, (conv_carry, h_last)


def _causal_conv(x, conv_w, carry=None):
    k = conv_w.shape[0]
    if carry is None:
        xp = jnp.pad(x, ((0, 0), (k - 1, 0), (0, 0)))
    else:
        xp = jnp.concatenate([carry.astype(x.dtype), x], axis=1)
    y = sum(xp[:, i : i + x.shape[1]] * conv_w[i] for i in range(k))
    new_carry = xp[:, -(k - 1):] if k > 1 else None
    return y, new_carry


def att_block(cfg: GriffinConfig, w, x, positions, cache_slice=None, position=None):
    """Local-attention mixer + MLP.  Train (cache_slice=None) or decode."""
    b, s, d = x.shape
    dtype_in = x.dtype
    hin = rms_norm(x, w["norm"], cfg.norm_eps)
    q = (hin @ w["wq"]).reshape(b, s, cfg.n_heads, cfg.hd)
    k = (hin @ w["wk"]).reshape(b, s, cfg.n_kv_heads, cfg.hd)
    v = (hin @ w["wv"]).reshape(b, s, cfg.n_kv_heads, cfg.hd)
    q = apply_rope(q, positions, cfg.rope_theta)
    k = apply_rope(k, positions, cfg.rope_theta)
    if cache_slice is None:
        o = attn.attend(q, k, v, positions, positions, causal=True, window=cfg.window)
        new_cache = (k, v, positions)
    else:
        kc, vc, pc = cache_slice
        kc, vc, pc = attn.cache_insert(kc, vc, pc, k, v, position, ring=True)
        o = attn.decode_attend(q, kc, vc, pc, position, window=cfg.window)
        new_cache = (kc, vc, pc)
    o = o.reshape(b, s, cfg.n_heads * cfg.hd)
    x = x + shard_hint(o @ w["wo"], "batch", None, None)
    h2 = rms_norm(x, w["mlp_norm"], cfg.norm_eps)
    h2 = jax.nn.gelu(shard_hint(h2 @ w["w1"], "batch", None, "tensor")) * (h2 @ w["w3"])
    x = (x + shard_hint(h2 @ w["w2"], "batch", None, None)).astype(dtype_in)
    return x, new_cache


# ---------------------------------------------------------------------------
# forward / loss
# ---------------------------------------------------------------------------


def forward(cfg: GriffinConfig, params, batch, mat: Materializer):
    tokens = batch["tokens"]
    b, s = tokens.shape
    emb_w = mat({"embed": params["embed"]}, {"embed": param_specs(cfg)["embed"]})
    x = shard_hint(jnp.take(emb_w["embed"], tokens, axis=0), "batch", None, None)
    positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32), (b, s))

    def super_body(x_, super_params):
        def r_body(c, w_layer):
            out, _ = rec_block(cfg, mat(w_layer, _rec_specs()), c)
            return out, None

        x_, _ = jax.lax.scan(
            jax.checkpoint(r_body, prevent_cse=False), x_, super_params["rec"]
        )
        x_, _ = att_block(cfg, mat(super_params["att"], _att_specs()), x_, positions)
        return x_, None

    x, _ = jax.lax.scan(
        jax.checkpoint(super_body, prevent_cse=False), x, params["super_blocks"]
    )
    if cfg.n_extra_rec:
        def r_body2(c, w_layer):
            out, _ = rec_block(cfg, mat(w_layer, _rec_specs()), c)
            return out, None

        x, _ = jax.lax.scan(
            jax.checkpoint(r_body2, prevent_cse=False), x, params["extra_rec"]
        )
    return rms_norm(x, mat.leaf(params["final_norm"]), cfg.norm_eps)


def _head_weight(cfg, params, mat):
    if cfg.tie_embeddings:
        emb = mat({"e": params["embed"]},
                  {"e": ParamSpec(("fsdp", "tensor"), ("tensor", None))})["e"]
        return emb.T
    return mat({"h": params["lm_head"]}, {"h": wspec("fsdp", "tensor")})["h"]


def loss(cfg: GriffinConfig, params, batch, mat: Materializer) -> jax.Array:
    hidden = forward(cfg, params, batch, mat)
    return softmax_xent_chunked(
        hidden, _head_weight(cfg, params, mat), batch["labels"], batch.get("mask")
    )


# ---------------------------------------------------------------------------
# serving — O(window) attention cache + O(1) recurrent state
# ---------------------------------------------------------------------------


def init_decode_state(cfg: GriffinConfig, batch: int, max_len: int, dtype=jnp.bfloat16):
    buf = min(max_len, cfg.window)
    b, km1, r = batch, cfg.conv_kernel - 1, cfg.lru
    n_rec_stacked = max(cfg.n_super * cfg.rec_per_super, 1)
    state = dict(
        rec=dict(
            conv=jnp.zeros((cfg.n_super, cfg.rec_per_super, b, km1, r), jnp.float32),
            h=jnp.zeros((cfg.n_super, cfg.rec_per_super, b, r), jnp.float32),
        ),
        att=dict(
            k=jnp.zeros((cfg.n_super, b, buf, cfg.n_kv_heads, cfg.hd), dtype),
            v=jnp.zeros((cfg.n_super, b, buf, cfg.n_kv_heads, cfg.hd), dtype),
            pos=jnp.full((cfg.n_super, b, buf), -1, jnp.int32),
        ),
        length=jnp.zeros((), jnp.int32),
    )
    del n_rec_stacked
    if cfg.n_extra_rec:
        state["extra_rec"] = dict(
            conv=jnp.zeros((cfg.n_extra_rec, b, km1, r), jnp.float32),
            h=jnp.zeros((cfg.n_extra_rec, b, r), jnp.float32),
        )
    return state


def state_shard_hint(state):
    out = dict(state)
    out["rec"] = dict(
        conv=shard_hint(state["rec"]["conv"], None, None, "batch", None, "dstate"),
        h=shard_hint(state["rec"]["h"], None, None, "batch", "dstate"),
    )
    out["att"] = dict(
        k=shard_hint(state["att"]["k"], None, "batch", "kv_seq", None, None),
        v=shard_hint(state["att"]["v"], None, "batch", "kv_seq", None, None),
        pos=shard_hint(state["att"]["pos"], None, "batch", "kv_seq"),
    )
    if "extra_rec" in state:
        out["extra_rec"] = dict(
            conv=shard_hint(state["extra_rec"]["conv"], None, "batch", None, "dstate"),
            h=shard_hint(state["extra_rec"]["h"], None, "batch", "dstate"),
        )
    return out


def _run(cfg: GriffinConfig, params, state, tokens, mat, start_pos):
    """Shared prefill/decode body: run `tokens` [B,S] from `start_pos`."""
    b, s = tokens.shape
    emb_w = mat({"embed": params["embed"]}, {"embed": param_specs(cfg)["embed"]})
    x = shard_hint(jnp.take(emb_w["embed"], tokens, axis=0), "batch", None, None)
    positions = start_pos + jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32), (b, s))
    new_state = dict(length=start_pos + s)
    decode = s == 1

    def rec_scan(x_, stack_p, stack_st):
        def body(c, xs):
            w_layer, st = xs
            out, (conv_c, h_last) = rec_block(
                cfg, mat(w_layer, _rec_specs()), c,
                conv_carry=st["conv"], h0=st["h"],
            )
            return out, dict(conv=conv_c, h=h_last)

        return jax.lax.scan(jax.checkpoint(body, prevent_cse=False), x_, (stack_p, stack_st))

    rec_states, att_k, att_v, att_p = [], [], [], []
    buf = state["att"]["k"].shape[2]
    for g in range(cfg.n_super):
        sub_p = jax.tree_util.tree_map(lambda a: a[g], params["super_blocks"])
        sub_rst = jax.tree_util.tree_map(lambda a: a[g], state["rec"])
        x, rst = rec_scan(x, sub_p["rec"], sub_rst)
        rec_states.append(rst)
        w_att = mat(sub_p["att"], _att_specs())
        if decode:
            cache_slice = (state["att"]["k"][g], state["att"]["v"][g], state["att"]["pos"][g])
            x, (kc, vc, pc) = att_block(cfg, w_att, x, positions,
                                        cache_slice=cache_slice, position=start_pos)
        else:
            x, (k_full, v_full, p_full) = att_block(cfg, w_att, x, positions)
            t = min(buf, s)
            kc, vc, pc = k_full[:, -t:], v_full[:, -t:], p_full[:, -t:]
            if t < buf:
                pad = buf - t
                kc = jnp.pad(kc, ((0, 0), (0, pad), (0, 0), (0, 0)))
                vc = jnp.pad(vc, ((0, 0), (0, pad), (0, 0), (0, 0)))
                pc = jnp.pad(pc, ((0, 0), (0, pad)), constant_values=-1)
            elif s % buf:
                roll = s % buf
                kc, vc, pc = (jnp.roll(a, roll, axis=1) for a in (kc, vc, pc))
        att_k.append(kc.astype(state["att"]["k"].dtype))
        att_v.append(vc.astype(state["att"]["v"].dtype))
        att_p.append(pc)
    new_state["rec"] = jax.tree_util.tree_map(lambda *xs: jnp.stack(xs), *rec_states)
    new_state["att"] = dict(
        k=jnp.stack(att_k), v=jnp.stack(att_v), pos=jnp.stack(att_p)
    )
    if cfg.n_extra_rec:
        x, ex = rec_scan(x, params["extra_rec"], state["extra_rec"])
        new_state["extra_rec"] = ex
    x = rms_norm(x, mat.leaf(params["final_norm"]), cfg.norm_eps)
    logits = x[:, -1:] @ _head_weight(cfg, params, mat)
    return state_shard_hint(new_state), shard_hint(logits, "batch", None, "tensor")


def prefill(cfg: GriffinConfig, params, batch, mat: Materializer, state):
    return _run(cfg, params, state, batch["tokens"], mat, jnp.int32(0))


def decode_step(cfg: GriffinConfig, params, state, tokens, mat: Materializer):
    return _run(cfg, params, state, tokens, mat, state["length"])
