"""Shared model infrastructure: sharding hints, param specs, norms, losses.

Design notes
------------
* Models are pure-JAX functional modules: ``init(key, cfg) -> params`` (nested
  dicts of f32 arrays) and ``loss(cfg, params, batch, mat) -> scalar``.
* Per-block parameters are stacked along a leading layer axis and consumed by
  ``jax.lax.scan`` — this keeps the HLO compact (compile time matters for the
  512-device dry-run) and gives the OMC materializer a single per-layer hook.
* ``mat`` (a :class:`Materializer`) is called on each scanned layer slice (and
  once on the non-block params).  The FP32 baseline materializer only applies
  the FSDP all-gather sharding hint; the OMC materializer all-gathers the
  *compressed bitfields* and decompresses layer-by-layer under remat — the
  paper's decompress-on-the-fly, realized TPU-natively (DESIGN.md §2).
* Sharding is expressed with *logical axes* resolved against the active mesh
  (MaxText-style).  ``shard_hint`` silently drops a mesh axis when the dim is
  not divisible by it, which uniformly handles kv-heads < model-axis, batch=1
  long-context decode, odd head counts, etc.
"""

from __future__ import annotations

import dataclasses
import threading
from typing import Any, Callable, Dict, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

# ---------------------------------------------------------------------------
# Logical axis rules and the mesh context
# ---------------------------------------------------------------------------

# logical axis -> tuple of mesh axis names (tried in order, divisibility wins)
DEFAULT_RULES: Dict[str, Tuple[str, ...]] = {
    "batch": ("pod", "data"),
    "fsdp": ("pod", "data"),  # weight storage shard (ZeRO-3 style)
    "tensor": ("model",),  # tensor-parallel dim (heads / ffn / vocab)
    "kv_seq": ("model",),  # decode KV-cache sequence sharding (MQA/GQA)
    "expert": ("model",),  # expert-parallel dim (only when divisible)
    "qblk": ("model",),  # train/prefill attention: q-block dim (Ulysses-style)
    "seq": ("model",),  # sequence-sharded residual stream (Megatron-SP)
    "dstate": ("model",),  # recurrent state feature dim (mLSTM/RG-LRU TP)
    "replicated": (),
}


class _MeshCtx(threading.local):
    def __init__(self):
        self.mesh: Optional[jax.sharding.Mesh] = None
        self.rules: Dict[str, Tuple[str, ...]] = dict(DEFAULT_RULES)


_CTX = _MeshCtx()


class activate_mesh:
    """Context manager: resolve logical-axis hints against ``mesh``.

    Outside the context every hint is an identity — models run un-annotated
    on CPU (smoke tests) with zero overhead.
    """

    def __init__(self, mesh, rules: Optional[Dict[str, Tuple[str, ...]]] = None):
        self.mesh = mesh
        self.rules = dict(DEFAULT_RULES)
        if rules:
            self.rules.update(rules)

    def __enter__(self):
        self._old = (_CTX.mesh, _CTX.rules)
        _CTX.mesh, _CTX.rules = self.mesh, self.rules
        return self.mesh

    def __exit__(self, *exc):
        _CTX.mesh, _CTX.rules = self._old
        return False


def current_mesh():
    return _CTX.mesh


def _mesh_axis_sizes(mesh) -> Dict[str, int]:
    return dict(zip(mesh.axis_names, mesh.devices.shape))


def resolve_spec(
    logical: Sequence[Optional[str]],
    shape: Sequence[int],
    mesh=None,
    rules=None,
) -> P:
    """Logical axes -> PartitionSpec, dropping non-divisible mesh axes."""
    mesh = mesh if mesh is not None else _CTX.mesh
    rules = rules if rules is not None else _CTX.rules
    if mesh is None:
        return P()
    sizes = _mesh_axis_sizes(mesh)
    out, used = [], set()
    for dim, name in zip(shape, logical):
        if name is None or name == "replicated":
            out.append(None)
            continue
        axes = []
        prod = 1
        for ax in rules.get(name, ()):
            if ax in used or ax not in sizes:
                continue
            if dim % (prod * sizes[ax]) == 0:
                axes.append(ax)
                prod *= sizes[ax]
        for ax in axes:
            used.add(ax)
        out.append(tuple(axes) if len(axes) > 1 else (axes[0] if axes else None))
    return P(*out)


def shard_hint(x: jax.Array, *logical: Optional[str]) -> jax.Array:
    """with_sharding_constraint via logical axes; identity when no mesh."""
    mesh = _CTX.mesh
    if mesh is None or not hasattr(x, "shape"):
        return x
    spec = resolve_spec(logical, x.shape, mesh)
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))


def named_sharding(logical: Sequence[Optional[str]], shape, mesh=None):
    mesh = mesh if mesh is not None else _CTX.mesh
    return NamedSharding(mesh, resolve_spec(logical, shape, mesh))


# ---------------------------------------------------------------------------
# Param specs — each model exposes the (storage, gathered) logical axes of
# every parameter so that the runtime can build in_shardings and the OMC
# materializer knows what to all-gather.
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class ParamSpec:
    """Logical axes of one parameter.

    storage:  sharding at rest (server state) — includes the fsdp axis.
    gathered: sharding during compute — fsdp axis removed, tensor axis kept.
    """

    storage: Tuple[Optional[str], ...]
    gathered: Tuple[Optional[str], ...]


def wspec(*axes: Optional[str]) -> ParamSpec:
    """Weight spec: storage as given; gathered = with 'fsdp' removed."""
    return ParamSpec(
        storage=tuple(axes),
        gathered=tuple(None if a == "fsdp" else a for a in axes),
    )


RSPEC = ParamSpec(storage=("replicated",), gathered=("replicated",))  # any rank


def spec_leaf_for(path_unused, leaf_spec: ParamSpec, leaf: jax.Array):
    return leaf_spec


# ---------------------------------------------------------------------------
# Materializer
# ---------------------------------------------------------------------------


class Materializer:
    """Maps stored layer params -> full-precision compute weights.

    The baseline ("fp32") materializer applies the gathered sharding hint
    (triggering the FSDP all-gather in f32).  The OMC materializer (see
    ``repro.federated.materialize``) replaces the stored leaf with
    (codes, s, b[, master]) structures, all-gathers the *codes*, and decodes.
    """

    def __init__(self, spec_tree=None):
        self.spec_tree = spec_tree

    def __call__(self, subtree, spec_subtree=None):
        spec_subtree = spec_subtree if spec_subtree is not None else self.spec_tree

        def f(spec, leaf):
            if spec is None or _CTX.mesh is None:
                return leaf
            return shard_hint(leaf, *_pad_spec(spec.gathered, leaf.ndim))

        if spec_subtree is None:
            return subtree
        return jax.tree_util.tree_map(
            f, spec_subtree, subtree, is_leaf=lambda s: isinstance(s, ParamSpec)
        )

    def leaf(self, x):
        """Materialize a single small (replicated) leaf — norms, biases."""
        return x


def _pad_spec(axes: Tuple[Optional[str], ...], ndim: int):
    """Right-align a spec to the leaf rank (scan slicing drops the L dim)."""
    axes = tuple(axes)
    if len(axes) >= ndim:
        return axes[len(axes) - ndim :]
    return (None,) * (ndim - len(axes)) + axes


IDENTITY_MAT = Materializer(None)


# ---------------------------------------------------------------------------
# Initializers / basic layers
# ---------------------------------------------------------------------------


def dense_init(key, d_in: int, d_out: int, scale: float = 1.0) -> jax.Array:
    std = scale / np.sqrt(d_in)
    return (jax.random.normal(key, (d_in, d_out), jnp.float32) * std).astype(
        jnp.float32
    )


def embed_init(key, vocab: int, d: int) -> jax.Array:
    return (jax.random.normal(key, (vocab, d), jnp.float32) * 0.02).astype(jnp.float32)


def rms_norm(x: jax.Array, scale: jax.Array, eps: float = 1e-6) -> jax.Array:
    var = jnp.mean(jnp.square(x.astype(jnp.float32)), axis=-1, keepdims=True)
    return (x * jax.lax.rsqrt(var + eps) * scale).astype(x.dtype)


def layer_norm(x, scale, bias, eps: float = 1e-6):
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.var(xf, axis=-1, keepdims=True)
    return ((xf - mu) * jax.lax.rsqrt(var + eps) * scale + bias).astype(x.dtype)


def group_norm(x, scale, bias, groups: int, eps: float = 1e-6):
    """GroupNorm over the channel dim (paper swaps BN->GN for FL)."""
    *lead, c = x.shape
    xf = x.astype(jnp.float32).reshape(*lead, groups, c // groups)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.var(xf, axis=-1, keepdims=True)
    xn = ((xf - mu) * jax.lax.rsqrt(var + eps)).reshape(*lead, c)
    return (xn * scale + bias).astype(x.dtype)


def swiglu(x, w1, w3, w2):
    """LLaMA-style gated MLP: (silu(x@w1) * (x@w3)) @ w2."""
    h = jax.nn.silu(x @ w1) * (x @ w3)
    h = shard_hint(h, "batch", None, "tensor")
    return h @ w2


def gelu_mlp(x, w1, b1, w2, b2):
    h = jax.nn.gelu(x @ w1 + b1)
    h = shard_hint(h, "batch", None, "tensor")
    return h @ w2 + b2


# ---------------------------------------------------------------------------
# Rotary position embeddings
# ---------------------------------------------------------------------------


def rope_freqs(head_dim: int, theta: float = 10000.0) -> jax.Array:
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, jnp.float32) / head_dim))


def apply_rope(x: jax.Array, positions: jax.Array, theta: float = 10000.0):
    """x: [..., S, H, hd]; positions: [..., S] (int)."""
    hd = x.shape[-1]
    freqs = rope_freqs(hd, theta)  # [hd/2]
    ang = positions[..., None].astype(jnp.float32) * freqs  # [..., S, hd/2]
    cos, sin = jnp.cos(ang)[..., None, :], jnp.sin(ang)[..., None, :]
    x1, x2 = jnp.split(x, 2, axis=-1)
    return jnp.concatenate(
        [x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1
    ).astype(x.dtype)


# ---------------------------------------------------------------------------
# Loss — chunked cross-entropy (bounds the [B, S, V] logits transient)
# ---------------------------------------------------------------------------


def softmax_xent_chunked(
    hidden: jax.Array,  # [B, S, D]
    head_w: jax.Array,  # [D, V]
    labels: jax.Array,  # [B, S] int32
    mask: Optional[jax.Array] = None,  # [B, S] 0/1
    chunk: int = 1024,
) -> jax.Array:
    """Mean CE over (masked) tokens, computing logits in seq chunks."""
    b, s, d = hidden.shape
    chunk = min(chunk, s)
    while s % chunk:
        chunk -= 1
    n_chunks = s // chunk
    if mask is None:
        mask = jnp.ones((b, s), jnp.float32)
    mask = mask.astype(jnp.float32)

    hs = hidden.reshape(b, n_chunks, chunk, d).transpose(1, 0, 2, 3)
    ls = labels.reshape(b, n_chunks, chunk).transpose(1, 0, 2)
    ms = mask.reshape(b, n_chunks, chunk).transpose(1, 0, 2)

    def body(carry, xs):
        h, lab, m = xs
        # Pin h to D-replicated: GSPMD otherwise solves the dot with the
        # *contraction* dim sharded and satisfies the vocab-sharded logits
        # constraint via an all-reduce of the FULL-vocab partial product
        # (measured: 314 GB wire on recurrentgemma train_4k).
        h = shard_hint(h, "batch", None, None)
        logits = (h @ head_w).astype(jnp.float32)  # [B, c, V]
        logits = shard_hint(logits, "batch", None, "tensor")
        lse = jax.nn.logsumexp(logits, axis=-1)
        # label pick via masked sum, NOT take_along_axis: a gather over the
        # vocab-sharded axis makes GSPMD all-gather the full logits (and
        # all-reduce the full-vocab scatter in backward) — measured 314 GB
        # of wire on recurrentgemma train_4k.  The mask is local per shard
        # and its backward is an elementwise product.
        v = logits.shape[-1]
        onehot = (jnp.arange(v, dtype=lab.dtype) == lab[..., None])
        picked = jnp.sum(jnp.where(onehot, logits, 0.0), axis=-1)
        nll = (lse - picked) * m
        loss_sum, cnt = carry
        return (loss_sum + nll.sum(), cnt + m.sum()), None

    body = jax.checkpoint(body)
    (loss_sum, cnt), _ = jax.lax.scan(
        body, (jnp.float32(0.0), jnp.float32(0.0)), (hs, ls, ms)
    )
    return loss_sum / jnp.maximum(cnt, 1.0)


def scan_blocks(
    block_fn: Callable,
    stacked_params,
    x,
    mat: Materializer,
    spec_tree=None,
    extra_xs=None,
):
    """scan over stacked layer params with per-layer materialize + remat.

    ``block_fn(carry, layer_f32_params, extra_slice) -> carry``.
    The remat wrapper is what frees the decompressed per-layer weights after
    use — the paper's transient-copy semantics (Fig. 1), enforced by XLA
    liveness instead of manual deallocation.
    """

    def body(carry, xs):
        layer_params, extra = xs
        w = mat(layer_params, spec_tree)
        return block_fn(carry, w, extra), None

    body = jax.checkpoint(body, prevent_cse=False)
    xs = (stacked_params, extra_xs)
    carry, _ = jax.lax.scan(body, x, xs)
    return carry


def stack_layer_params(layer_list):
    """[{...}, {...}] -> {...} with leaves stacked on a new leading axis."""
    return jax.tree_util.tree_map(lambda *xs: jnp.stack(xs, 0), *layer_list)
