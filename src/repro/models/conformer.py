"""Conformer ASR encoder — the paper's own model family (§3.1).

Block = ½·FFN → MHSA (RoPE, optionally windowed for the streaming variant)
→ Conv module (pointwise-GLU → depthwise causal conv → **GroupNorm** →
swish → pointwise) → ½·FFN → LayerNorm.  The paper swaps BatchNorm for
GroupNorm because batch statistics don't transfer across non-IID federated
clients (their ref [10]); we follow that.

The audio frontend is a stub: ``batch["frames"]`` carries precomputed
filterbank-patch embeddings [B, S, d_in]; a linear input projection maps to
d_model.  The training objective is framewise cross-entropy against
``batch["labels"]`` [B, S] — the synthetic-ASR surrogate task used by the
paper-table benchmarks (DESIGN.md §2: WER -> loss parity).
"""

from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np

from . import attention as attn
from .common import (
    Materializer,
    ParamSpec,
    RSPEC,
    apply_rope,
    dense_init,
    group_norm,
    layer_norm,
    scan_blocks,
    shard_hint,
    softmax_xent_chunked,
    stack_layer_params,
    wspec,
)


@dataclasses.dataclass(frozen=True)
class ConformerConfig:
    n_layers: int
    d_model: int
    n_heads: int
    d_ff: int
    n_classes: int
    d_in: int = 80
    conv_kernel: int = 8
    gn_groups: int = 4
    window: Optional[int] = None  # not None -> streaming variant
    causal_conv: bool = True
    rope_theta: float = 10_000.0
    norm_eps: float = 1e-6

    @property
    def hd(self) -> int:
        return self.d_model // self.n_heads

    def param_count(self) -> int:
        d, f = self.d_model, self.d_ff
        ffn = d * f + f + f * d + d + 2 * d
        att = 4 * d * d + 2 * d
        conv = d * 2 * d + self.conv_kernel * d + d * d + 4 * d + 2 * d
        blk = 2 * ffn + att + conv + 2 * d
        return self.n_layers * blk + self.d_in * d + d + d * self.n_classes + self.n_classes


def _block_init(key, cfg: ConformerConfig) -> Dict[str, Any]:
    ks = jax.random.split(key, 10)
    d, f = cfg.d_model, cfg.d_ff

    def ffn(k1, k2):
        return dict(
            scale=jnp.ones((d,)), bias=jnp.zeros((d,)),
            w1=dense_init(k1, d, f), b1=jnp.zeros((f,)),
            w2=dense_init(k2, f, d), b2=jnp.zeros((d,)),
        )

    p = dict(
        ffn1=ffn(ks[0], ks[1]),
        attn_scale=jnp.ones((d,)), attn_bias=jnp.zeros((d,)),
        wq=dense_init(ks[2], d, d), wk=dense_init(ks[3], d, d),
        wv=dense_init(ks[4], d, d), wo=dense_init(ks[5], d, d),
        conv_scale=jnp.ones((d,)), conv_bias=jnp.zeros((d,)),
        conv_pw1=dense_init(ks[6], d, 2 * d),
        conv_dw=(jax.random.normal(ks[7], (cfg.conv_kernel, d)) * 0.1),
        conv_gn_scale=jnp.ones((d,)), conv_gn_bias=jnp.zeros((d,)),
        conv_pw2=dense_init(ks[8], d, d),
        ffn2=ffn(ks[9], ks[0]),
        out_scale=jnp.ones((d,)), out_bias=jnp.zeros((d,)),
    )
    return jax.tree_util.tree_map(lambda a: jnp.asarray(a, jnp.float32), p)


def _ffn_specs():
    return dict(scale=RSPEC, bias=RSPEC, w1=wspec("fsdp", "tensor"),
                b1=wspec("tensor"), w2=wspec("tensor", "fsdp"), b2=RSPEC)


def block_specs(cfg: ConformerConfig) -> Dict[str, Any]:
    return dict(
        ffn1=_ffn_specs(),
        attn_scale=RSPEC, attn_bias=RSPEC,
        wq=wspec("fsdp", "tensor"), wk=wspec("fsdp", "tensor"),
        wv=wspec("fsdp", "tensor"), wo=wspec("tensor", "fsdp"),
        conv_scale=RSPEC, conv_bias=RSPEC,
        conv_pw1=wspec("fsdp", "tensor"),
        conv_dw=ParamSpec(storage=(None, "tensor"), gathered=(None, "tensor")),
        conv_gn_scale=RSPEC, conv_gn_bias=RSPEC,
        conv_pw2=wspec("tensor", "fsdp"),
        ffn2=_ffn_specs(),
        out_scale=RSPEC, out_bias=RSPEC,
    )


def init(key, cfg: ConformerConfig) -> Dict[str, Any]:
    kb, ki, ko = jax.random.split(key, 3)
    return dict(
        in_proj=dense_init(ki, cfg.d_in, cfg.d_model),
        in_bias=jnp.zeros((cfg.d_model,), jnp.float32),
        blocks=stack_layer_params(
            [_block_init(k, cfg) for k in jax.random.split(kb, cfg.n_layers)]
        ),
        out_proj=dense_init(ko, cfg.d_model, cfg.n_classes),
        out_bias=jnp.zeros((cfg.n_classes,), jnp.float32),
    )


def param_specs(cfg: ConformerConfig) -> Dict[str, Any]:
    return dict(
        in_proj=wspec("fsdp", None), in_bias=RSPEC,
        blocks=block_specs(cfg),
        out_proj=wspec("fsdp", "tensor"), out_bias=wspec("tensor"),
    )


def _half_ffn(x, p, eps):
    h = layer_norm(x, p["scale"], p["bias"], eps)
    h = jax.nn.silu(h @ p["w1"] + p["b1"])
    h = shard_hint(h, "batch", None, "tensor")
    return x + 0.5 * (h @ p["w2"] + p["b2"])


def _conv_module(cfg, w, x):
    h = layer_norm(x, w["conv_scale"], w["conv_bias"], cfg.norm_eps)
    h = h @ w["conv_pw1"]  # [B, S, 2D]
    h = shard_hint(h, "batch", None, "tensor")
    a, g = jnp.split(h, 2, axis=-1)
    h = a * jax.nn.sigmoid(g)  # GLU
    k = cfg.conv_kernel
    if cfg.causal_conv:
        hp = jnp.pad(h, ((0, 0), (k - 1, 0), (0, 0)))
    else:
        hp = jnp.pad(h, ((0, 0), ((k - 1) // 2, k - 1 - (k - 1) // 2), (0, 0)))
    h = sum(hp[:, i : i + x.shape[1]] * w["conv_dw"][i] for i in range(k))
    h = group_norm(h, w["conv_gn_scale"], w["conv_gn_bias"], cfg.gn_groups, cfg.norm_eps)
    h = jax.nn.silu(h)
    return x + h @ w["conv_pw2"]


def _block_apply(cfg: ConformerConfig, w, x, positions):
    b, s, d = x.shape
    x = _half_ffn(x, w["ffn1"], cfg.norm_eps)
    h = layer_norm(x, w["attn_scale"], w["attn_bias"], cfg.norm_eps)
    q = (h @ w["wq"]).reshape(b, s, cfg.n_heads, cfg.hd)
    k = (h @ w["wk"]).reshape(b, s, cfg.n_heads, cfg.hd)
    v = (h @ w["wv"]).reshape(b, s, cfg.n_heads, cfg.hd)
    q = apply_rope(q, positions, cfg.rope_theta)
    k = apply_rope(k, positions, cfg.rope_theta)
    causal = cfg.window is not None  # streaming variant is causal+windowed
    o = attn.attend(q, k, v, positions, positions, causal=causal, window=cfg.window)
    x = x + shard_hint(o.reshape(b, s, d) @ w["wo"], "batch", None, None)
    x = _conv_module(cfg, w, x)
    x = _half_ffn(x, w["ffn2"], cfg.norm_eps)
    return layer_norm(x, w["out_scale"], w["out_bias"], cfg.norm_eps)


def forward(cfg: ConformerConfig, params, batch, mat: Materializer):
    frames = batch["frames"].astype(jnp.float32)
    inw = mat({"in_proj": params["in_proj"]}, {"in_proj": wspec("fsdp", None)})
    x = shard_hint(frames @ inw["in_proj"] + mat.leaf(params["in_bias"]), "batch", None, None)
    b, s, _ = x.shape
    positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32), (b, s))

    def body(carry, w, _):
        return _block_apply(cfg, w, carry, positions)

    return scan_blocks(body, params["blocks"], x, mat, block_specs(cfg))


def loss(cfg: ConformerConfig, params, batch, mat: Materializer) -> jax.Array:
    hidden = forward(cfg, params, batch, mat)
    head = mat({"h": params["out_proj"]}, {"h": wspec("fsdp", "tensor")})["h"]
    # framewise CE; out_bias folded in by augmenting hidden with ones column
    logits_bias = mat.leaf(params["out_bias"])
    return softmax_xent_chunked(
        hidden, head, batch["labels"], batch.get("mask")
    ) if logits_bias is None else _loss_with_bias(cfg, hidden, head, logits_bias, batch)


def _loss_with_bias(cfg, hidden, head, bias, batch):
    b, s, d = hidden.shape
    hidden_aug = jnp.concatenate([hidden, jnp.ones((b, s, 1), hidden.dtype)], -1)
    head_aug = jnp.concatenate([head, bias[None, :]], 0)
    return softmax_xent_chunked(hidden_aug, head_aug, batch["labels"], batch.get("mask"))
