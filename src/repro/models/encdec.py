"""Encoder-decoder transformer (seamless-m4t-medium backbone).

The audio frontend is a STUB per the brief: ``batch["frames"]`` carries
precomputed frame embeddings [B, S_enc, d_model] (what the real model's
fbank+conformer-adaptor stack would emit).  The encoder is bidirectional
MHA; the decoder adds causal self-attention plus cross-attention to the
encoder memory.  Decoder length is seq_len // 4 (speech-to-text ratio;
DESIGN.md §6).

Serving: ``prefill`` runs the encoder once, caches per-layer cross-KV
(compute-once, standard for enc-dec serving) and prefills the decoder
self-cache; ``decode_step`` extends the decoder by one token.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from . import attention as attn
from .common import (
    Materializer,
    ParamSpec,
    RSPEC,
    apply_rope,
    dense_init,
    embed_init,
    gelu_mlp,
    layer_norm,
    scan_blocks,
    shard_hint,
    softmax_xent_chunked,
    stack_layer_params,
    wspec,
)


@dataclasses.dataclass(frozen=True)
class EncDecConfig:
    n_enc_layers: int
    n_dec_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    dec_ratio: int = 4  # dec_len = enc_len // dec_ratio for train shapes
    rope_theta: float = 10_000.0
    norm_eps: float = 1e-6

    @property
    def hd(self) -> int:
        return self.d_model // self.n_heads

    def param_count(self) -> int:
        d, f = self.d_model, self.d_ff
        att = d * (self.n_heads + 2 * self.n_kv_heads) * self.hd + self.n_heads * self.hd * d
        mlp = 2 * d * f + d + f
        enc = att + mlp + 4 * d
        dec = 2 * att + mlp + 6 * d
        return (
            self.n_enc_layers * enc + self.n_dec_layers * dec
            + 2 * self.vocab * d + 2 * d
        )


def _attn_params(key, cfg: EncDecConfig, prefix=""):
    ks = jax.random.split(key, 4)
    d = cfg.d_model
    return {
        prefix + "wq": dense_init(ks[0], d, cfg.n_heads * cfg.hd),
        prefix + "wk": dense_init(ks[1], d, cfg.n_kv_heads * cfg.hd),
        prefix + "wv": dense_init(ks[2], d, cfg.n_kv_heads * cfg.hd),
        prefix + "wo": dense_init(ks[3], cfg.n_heads * cfg.hd, d),
    }


def _attn_specs(prefix=""):
    return {
        prefix + "wq": wspec("fsdp", "tensor"),
        prefix + "wk": wspec("fsdp", "tensor"),
        prefix + "wv": wspec("fsdp", "tensor"),
        prefix + "wo": wspec("tensor", "fsdp"),
    }


def _enc_block_init(key, cfg: EncDecConfig):
    k1, k2, k3 = jax.random.split(key, 3)
    d, f = cfg.d_model, cfg.d_ff
    p = dict(
        attn_scale=jnp.ones((d,)), attn_bias=jnp.zeros((d,)),
        mlp_scale=jnp.ones((d,)), mlp_bias=jnp.zeros((d,)),
        w1=dense_init(k1, d, f), b1=jnp.zeros((f,)),
        w2=dense_init(k2, f, d), b2=jnp.zeros((d,)),
    )
    p.update(_attn_params(k3, cfg))
    return jax.tree_util.tree_map(lambda a: a.astype(jnp.float32), p)


def _dec_block_init(key, cfg: EncDecConfig):
    k1, k2, k3, k4 = jax.random.split(key, 4)
    d, f = cfg.d_model, cfg.d_ff
    p = dict(
        self_scale=jnp.ones((d,)), self_bias=jnp.zeros((d,)),
        cross_scale=jnp.ones((d,)), cross_bias=jnp.zeros((d,)),
        mlp_scale=jnp.ones((d,)), mlp_bias=jnp.zeros((d,)),
        w1=dense_init(k1, d, f), b1=jnp.zeros((f,)),
        w2=dense_init(k2, f, d), b2=jnp.zeros((d,)),
    )
    p.update(_attn_params(k3, cfg))
    p.update(_attn_params(k4, cfg, prefix="c_"))
    return jax.tree_util.tree_map(lambda a: a.astype(jnp.float32), p)


def _enc_specs():
    s = dict(
        attn_scale=RSPEC, attn_bias=RSPEC, mlp_scale=RSPEC, mlp_bias=RSPEC,
        w1=wspec("fsdp", "tensor"), b1=wspec("tensor"),
        w2=wspec("tensor", "fsdp"), b2=RSPEC,
    )
    s.update(_attn_specs())
    return s


def _dec_specs():
    s = dict(
        self_scale=RSPEC, self_bias=RSPEC, cross_scale=RSPEC, cross_bias=RSPEC,
        mlp_scale=RSPEC, mlp_bias=RSPEC,
        w1=wspec("fsdp", "tensor"), b1=wspec("tensor"),
        w2=wspec("tensor", "fsdp"), b2=RSPEC,
    )
    s.update(_attn_specs())
    s.update(_attn_specs("c_"))
    return s


def init(key, cfg: EncDecConfig) -> Dict[str, Any]:
    ke, kd, kt, kh = jax.random.split(key, 4)
    params = dict(
        embed=embed_init(kt, cfg.vocab, cfg.d_model),
        enc_blocks=stack_layer_params(
            [_enc_block_init(k, cfg) for k in jax.random.split(ke, cfg.n_enc_layers)]
        ),
        dec_blocks=stack_layer_params(
            [_dec_block_init(k, cfg) for k in jax.random.split(kd, cfg.n_dec_layers)]
        ),
        enc_norm_scale=jnp.ones((cfg.d_model,), jnp.float32),
        enc_norm_bias=jnp.zeros((cfg.d_model,), jnp.float32),
        dec_norm_scale=jnp.ones((cfg.d_model,), jnp.float32),
        dec_norm_bias=jnp.zeros((cfg.d_model,), jnp.float32),
        lm_head=dense_init(kh, cfg.d_model, cfg.vocab),
    )
    return params


def param_specs(cfg: EncDecConfig) -> Dict[str, Any]:
    return dict(
        embed=ParamSpec(storage=("fsdp", "tensor"), gathered=(None, "tensor")),
        enc_blocks=_enc_specs(),
        dec_blocks=_dec_specs(),
        enc_norm_scale=RSPEC, enc_norm_bias=RSPEC,
        dec_norm_scale=RSPEC, dec_norm_bias=RSPEC,
        lm_head=wspec("fsdp", "tensor"),
    )


# ---------------------------------------------------------------------------
# forward
# ---------------------------------------------------------------------------


def _mha(cfg, w, x, kv_x, q_pos, k_pos, causal, prefix="",
         cache=None, position=None, window=None):
    """Shared attention wrapper; cache (k,v,pos) -> decode path."""
    b, s, d = x.shape
    q = (x @ w[prefix + "wq"]).reshape(b, s, cfg.n_heads, cfg.hd)
    if cache is not None and kv_x is None:
        # cross-attention decode: KV precomputed
        kc, vc, pc = cache
        q = apply_rope(q, q_pos, cfg.rope_theta) if causal else q
        o = attn.decode_attend(q, kc, vc, pc, position, window=window, causal=causal)
        new_cache = cache
    else:
        src = x if kv_x is None else kv_x
        sk = src.shape[1]
        k = (src @ w[prefix + "wk"]).reshape(b, sk, cfg.n_kv_heads, cfg.hd)
        v = (src @ w[prefix + "wv"]).reshape(b, sk, cfg.n_kv_heads, cfg.hd)
        if causal:  # rope only on the causal (self) stream
            q = apply_rope(q, q_pos, cfg.rope_theta)
            k = apply_rope(k, k_pos, cfg.rope_theta)
        if cache is not None:
            kc, vc, pc = cache
            kc, vc, pc = attn.cache_insert(kc, vc, pc, k, v, position, ring=False)
            o = attn.decode_attend(q, kc, vc, pc, position, window=window)
            new_cache = (kc, vc, pc)
        else:
            o = attn.attend(q, k, v, q_pos, k_pos, causal=causal, window=window)
            new_cache = (k, v, k_pos)
    o = o.reshape(b, s, cfg.n_heads * cfg.hd)
    return shard_hint(o @ w[prefix + "wo"], "batch", None, None), new_cache


def encode(cfg: EncDecConfig, params, frames, mat: Materializer):
    """frames [B, S_enc, D] -> encoder memory [B, S_enc, D]."""
    x = shard_hint(frames.astype(jnp.float32), "batch", None, None)
    b, s, _ = x.shape
    pos = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32), (b, s))

    def body(x_, w, _):
        h = layer_norm(x_, w["attn_scale"], w["attn_bias"], cfg.norm_eps)
        o, _ = _mha(cfg, w, h, None, pos, pos, causal=False)
        x_ = x_ + o
        h = layer_norm(x_, w["mlp_scale"], w["mlp_bias"], cfg.norm_eps)
        return x_ + gelu_mlp(h, w["w1"], w["b1"], w["w2"], w["b2"])

    x = scan_blocks(body, params["enc_blocks"], x, mat, _enc_specs())
    return layer_norm(x, mat.leaf(params["enc_norm_scale"]), mat.leaf(params["enc_norm_bias"]), cfg.norm_eps)


def decode_train(cfg: EncDecConfig, params, tokens, memory, mat: Materializer):
    emb_w = mat({"embed": params["embed"]}, {"embed": param_specs(cfg)["embed"]})
    x = shard_hint(jnp.take(emb_w["embed"], tokens, axis=0), "batch", None, None)
    b, s, _ = x.shape
    pos = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32), (b, s))
    mem_pos = jnp.broadcast_to(
        jnp.arange(memory.shape[1], dtype=jnp.int32), (b, memory.shape[1])
    )

    def body(x_, w, _):
        h = layer_norm(x_, w["self_scale"], w["self_bias"], cfg.norm_eps)
        o, _ = _mha(cfg, w, h, None, pos, pos, causal=True)
        x_ = x_ + o
        h = layer_norm(x_, w["cross_scale"], w["cross_bias"], cfg.norm_eps)
        o, _ = _mha(cfg, w, h, memory, pos, mem_pos, causal=False, prefix="c_")
        x_ = x_ + o
        h = layer_norm(x_, w["mlp_scale"], w["mlp_bias"], cfg.norm_eps)
        return x_ + gelu_mlp(h, w["w1"], w["b1"], w["w2"], w["b2"])

    x = scan_blocks(body, params["dec_blocks"], x, mat, _dec_specs())
    return layer_norm(x, mat.leaf(params["dec_norm_scale"]), mat.leaf(params["dec_norm_bias"]), cfg.norm_eps)


def loss(cfg: EncDecConfig, params, batch, mat: Materializer) -> jax.Array:
    memory = encode(cfg, params, batch["frames"], mat)
    hidden = decode_train(cfg, params, batch["tokens"], memory, mat)
    head = mat({"h": params["lm_head"]}, {"h": wspec("fsdp", "tensor")})["h"]
    return softmax_xent_chunked(hidden, head, batch["labels"], batch.get("mask"))


# ---------------------------------------------------------------------------
# serving
# ---------------------------------------------------------------------------


def init_decode_state(cfg: EncDecConfig, batch: int, max_len: int, dtype=jnp.bfloat16):
    """max_len = encoder length; decoder buffer = max_len // dec_ratio."""
    dec_buf = max(max_len // cfg.dec_ratio, 8)
    return dict(
        self_kv=attn.init_cache(cfg.n_dec_layers, batch, dec_buf,
                                cfg.n_kv_heads, cfg.hd, dtype),
        cross_k=jnp.zeros((cfg.n_dec_layers, batch, max_len, cfg.n_kv_heads, cfg.hd), dtype),
        cross_v=jnp.zeros((cfg.n_dec_layers, batch, max_len, cfg.n_kv_heads, cfg.hd), dtype),
        cross_pos=jnp.full((cfg.n_dec_layers, batch, max_len), -1, jnp.int32),
        length=jnp.zeros((), jnp.int32),
    )


def _state_hint(state):
    f = shard_hint
    return dict(
        self_kv=attn.cache_shard_hint(state["self_kv"]),
        cross_k=f(state["cross_k"], None, "batch", "kv_seq", "tensor", None),
        cross_v=f(state["cross_v"], None, "batch", "kv_seq", "tensor", None),
        cross_pos=f(state["cross_pos"], None, "batch", "kv_seq"),
        length=state["length"],
    )


def prefill(cfg: EncDecConfig, params, batch, mat: Materializer, state):
    """Encoder pass + cross-KV precompute + decoder prompt prefill."""
    memory = encode(cfg, params, batch["frames"], mat)
    b, s_enc, _ = memory.shape
    tokens = batch["tokens"]
    s_dec = tokens.shape[1]
    specs = _dec_specs()
    mem_pos = jnp.broadcast_to(jnp.arange(s_enc, dtype=jnp.int32), (b, s_enc))

    emb_w = mat({"embed": params["embed"]}, {"embed": param_specs(cfg)["embed"]})
    x = shard_hint(jnp.take(emb_w["embed"], tokens, axis=0), "batch", None, None)
    pos = jnp.broadcast_to(jnp.arange(s_dec, dtype=jnp.int32), (b, s_dec))
    buf = state["self_kv"].buf_len
    kv_dtype = state["self_kv"].k.dtype

    def body_fn(x_, xs):
        w = mat(xs[0], specs)
        h = layer_norm(x_, w["self_scale"], w["self_bias"], cfg.norm_eps)
        q = (h @ w["wq"]).reshape(b, s_dec, cfg.n_heads, cfg.hd)
        k = (h @ w["wk"]).reshape(b, s_dec, cfg.n_kv_heads, cfg.hd)
        v = (h @ w["wv"]).reshape(b, s_dec, cfg.n_kv_heads, cfg.hd)
        q = apply_rope(q, pos, cfg.rope_theta)
        k = apply_rope(k, pos, cfg.rope_theta)
        o = attn.attend(q, k, v, pos, pos, causal=True)
        x_ = x_ + shard_hint(o.reshape(b, s_dec, -1) @ w["wo"], "batch", None, None)
        h = layer_norm(x_, w["cross_scale"], w["cross_bias"], cfg.norm_eps)
        ck = (memory @ w["c_wk"]).reshape(b, s_enc, cfg.n_kv_heads, cfg.hd)
        cv = (memory @ w["c_wv"]).reshape(b, s_enc, cfg.n_kv_heads, cfg.hd)
        o, _ = _mha(cfg, w, h, memory, pos, mem_pos, causal=False, prefix="c_")
        x_ = x_ + o
        h = layer_norm(x_, w["mlp_scale"], w["mlp_bias"], cfg.norm_eps)
        x_ = x_ + gelu_mlp(h, w["w1"], w["b1"], w["w2"], w["b2"])
        # stack decoder self-KV (left-aligned) and cross-KV
        t = min(buf, s_dec)
        kc, vc, pc = k[:, :t], v[:, :t], pos[:, :t]
        if t < buf:
            pad = buf - t
            kc = jnp.pad(kc, ((0, 0), (0, pad), (0, 0), (0, 0)))
            vc = jnp.pad(vc, ((0, 0), (0, pad), (0, 0), (0, 0)))
            pc = jnp.pad(pc, ((0, 0), (0, pad)), constant_values=-1)
        return x_, (kc.astype(kv_dtype), vc.astype(kv_dtype),
                    pc, ck.astype(kv_dtype), cv.astype(kv_dtype))

    body_fn = jax.checkpoint(body_fn, prevent_cse=False)
    x, (ks, vs, ps, cks, cvs) = jax.lax.scan(body_fn, x, (params["dec_blocks"], None))
    x = layer_norm(x, mat.leaf(params["dec_norm_scale"]), mat.leaf(params["dec_norm_bias"]), cfg.norm_eps)
    head = mat({"h": params["lm_head"]}, {"h": wspec("fsdp", "tensor")})["h"]
    logits = x[:, -1:] @ head
    new_state = _state_hint(dict(
        self_kv=attn.KVCache(k=ks, v=vs, pos=ps, length=jnp.asarray(s_dec, jnp.int32)),
        cross_k=cks, cross_v=cvs,
        cross_pos=jnp.broadcast_to(mem_pos, (cfg.n_dec_layers,) + mem_pos.shape),
        length=jnp.asarray(s_dec, jnp.int32),
    ))
    return new_state, shard_hint(logits, "batch", None, "tensor")


def decode_step(cfg: EncDecConfig, params, state, tokens, mat: Materializer):
    b = tokens.shape[0]
    emb_w = mat({"embed": params["embed"]}, {"embed": param_specs(cfg)["embed"]})
    x = shard_hint(jnp.take(emb_w["embed"], tokens, axis=0), "batch", None, None)
    position = state["length"]
    pos = jnp.full((b, 1), position, jnp.int32)
    specs = _dec_specs()
    sk = state["self_kv"]

    def body(x_, xs):
        w_layer, (kc, vc, pc, ck, cv, cp) = xs
        w = mat(w_layer, specs)
        h = layer_norm(x_, w["self_scale"], w["self_bias"], cfg.norm_eps)
        o, (kc, vc, pc) = _mha(cfg, w, h, h, pos, pos, causal=True,
                               cache=(kc, vc, pc), position=position)
        x_ = x_ + o
        h = layer_norm(x_, w["cross_scale"], w["cross_bias"], cfg.norm_eps)
        o, _ = _mha(cfg, w, h, None, pos, None, causal=False, prefix="c_",
                    cache=(ck, cv, cp), position=position)
        x_ = x_ + o
        h = layer_norm(x_, w["mlp_scale"], w["mlp_bias"], cfg.norm_eps)
        x_ = x_ + gelu_mlp(h, w["w1"], w["b1"], w["w2"], w["b2"])
        return x_, (kc, vc, pc)

    x, (ks, vs, ps) = jax.lax.scan(
        body, x,
        (params["dec_blocks"],
         (sk.k, sk.v, sk.pos, state["cross_k"], state["cross_v"], state["cross_pos"])),
    )
    x = layer_norm(x, mat.leaf(params["dec_norm_scale"]), mat.leaf(params["dec_norm_bias"]), cfg.norm_eps)
    head = mat({"h": params["lm_head"]}, {"h": wspec("fsdp", "tensor")})["h"]
    logits = x @ head
    new_state = _state_hint(dict(
        self_kv=attn.KVCache(k=ks, v=vs, pos=ps, length=sk.length + 1),
        cross_k=state["cross_k"], cross_v=state["cross_v"],
        cross_pos=state["cross_pos"], length=state["length"] + 1,
    ))
    return new_state, shard_hint(logits, "batch", None, "tensor")
