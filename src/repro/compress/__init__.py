"""Pluggable transport-compression strategies (DESIGN.md §11).

One interface — :class:`CompressionStrategy` — behind which the zoo lives:

  * :class:`OMCQuantStrategy` — the paper's minifloat + PVT quantization,
    delegating to ``repro.core`` unchanged (the reference point),
  * :class:`TopKSparseStrategy` — magnitude top-k with index packing
    (Konečný et al., arxiv 1610.05492),
  * :class:`TernaryTNTStrategy` — 2-bit TNT/TWN ternary weights
    (SNIPPETS.md §2–3),
  * :class:`PipelineStrategy` — quantize → sparsify → entropy-code
    (Grativol et al., arxiv 2310.14693).

Every strategy encodes/decodes policy-selected variables to self-describing
wire leaves that the §7 payload codec serializes (with a strategy tag +
per-strategy wire version in the frame), exposes a traceable qdq/STE view
for in-training use, and accounts its wire bytes exactly —
``benchmarks/compress_pareto.py`` sweeps the zoo across model families into
a quality-vs-wire-MB Pareto frontier.
"""

from .base import (  # noqa: F401
    CompressionStrategy,
    StrategyLeaf,
    available_strategies,
    decode_tree,
    default_zoo,
    encode_tree,
    get_strategy,
    is_encoded_leaf,
    is_strategy_leaf,
    qdq_tree,
    register_strategy,
    strategy_class,
    tree_wire_bytes,
)
from . import feedback  # noqa: F401  (error-feedback residuals, DESIGN.md §12)
from .omc_quant import OMCQuantStrategy  # noqa: F401
from .pipeline import PipelineStrategy, PipelineVariable  # noqa: F401
from .ternary import TernaryTNTStrategy, TernaryVariable, ternarize  # noqa: F401
from .topk import TopKSparseStrategy, TopKSparseVariable  # noqa: F401

from . import wire  # noqa: F401  (registers the leaf codecs with repro.api)

__all__ = [
    "CompressionStrategy",
    "OMCQuantStrategy",
    "PipelineStrategy",
    "PipelineVariable",
    "StrategyLeaf",
    "TernaryTNTStrategy",
    "TernaryVariable",
    "TopKSparseStrategy",
    "TopKSparseVariable",
    "available_strategies",
    "decode_tree",
    "default_zoo",
    "encode_tree",
    "feedback",
    "get_strategy",
    "is_encoded_leaf",
    "is_strategy_leaf",
    "qdq_tree",
    "register_strategy",
    "strategy_class",
    "ternarize",
    "tree_wire_bytes",
]
