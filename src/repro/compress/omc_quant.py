"""The paper's OMC quantization as a ``CompressionStrategy`` (DESIGN.md §11).

A thin adapter: encode/decode delegate to the existing
``repro.core.store.compress_variable`` / ``CompressedVariable.dequantize``
path *unchanged* — same minifloat codec, same PVT solvers, same
``packed_bytes + 8 B·(s, b)`` wire size — so the strategy interface costs
the OMC path nothing.  The cross-strategy equivalence gate
(``tests/test_compress.py``) asserts this adapter reproduces the
loop/engine byte accounting byte-exactly and the stored codes bit-exactly.
"""

from __future__ import annotations

import dataclasses

import jax
import numpy as np

from repro.core import packing
from repro.core.formats import FloatFormat, value_quantize
from repro.core.pvt import pvt_apply, pvt_solve, pvt_solve_fast
from repro.core.store import CompressedVariable, compress_variable, is_compressed

from .base import CompressionStrategy, register_strategy

_PVT_BYTES_PER_ENTRY = 8  # s and b, f32 each — matches store/codec/accounting


@register_strategy
@dataclasses.dataclass(frozen=True)
class OMCQuantStrategy(CompressionStrategy):
    """Minifloat quantization with per-variable transformation (paper §2).

    ``fast=True`` selects the distributed-friendly PVT solver — the one
    ``repro.federated.state.compress_params`` uses — so strategy encodes
    are bit-identical to the federated storage path.  The wire leaf is the
    ordinary :class:`CompressedVariable`; its delta rule on repeat sends is
    the §7 sparse XOR-delta.
    """

    fmt: FloatFormat = FloatFormat(3, 7)  # S1E3M7, the paper's 11-bit format
    pvt: bool = True
    fast: bool = True

    name = "omc"
    wire_version = 1
    delta_rule = "xor-sparse"

    @classmethod
    def parse(cls, fmt: str, **kw) -> "OMCQuantStrategy":
        return cls(fmt=FloatFormat.parse(fmt), **kw)

    @property
    def label(self) -> str:
        return f"omc-{self.fmt.name.lower()}" + ("" if self.pvt else "-nopvt")

    def encode_leaf(self, v, *, batch_axes: int = 0) -> CompressedVariable:
        return compress_variable(
            v, self.fmt, pvt=self.pvt, batch_axes=batch_axes, fast=self.fast
        )

    def decode_leaf(self, leaf: CompressedVariable) -> jax.Array:
        return leaf.dequantize()

    def qdq_leaf(self, v, *, batch_axes: int = 0) -> jax.Array:
        vq = value_quantize(v, self.fmt)
        if not self.pvt:
            return vq
        if batch_axes or self.fast:
            s, b = pvt_solve_fast(v, vq, batch_axes)
        else:
            s, b = pvt_solve(v, vq)
        return pvt_apply(vq, s, b)

    def train_qdq_leaf(self, v, *, batch_axes: int = 0) -> jax.Array:
        """Exactly ``core.omc.qdq_pvt_leaf``: the paper's simulation-mode
        view (exact per-variable PVT solve, no stacked-axis split) — what
        ``simulate.client_view`` has always applied, so training with
        ``strategy=OMCQuantStrategy(...)`` is bit-identical to the
        hardcoded-qdq path (gated in ``tests/test_train_strategy.py``)."""
        vq = value_quantize(v, self.fmt)
        if not self.pvt:
            return vq
        s, b = pvt_solve(v, vq)
        return pvt_apply(vq, s, b)

    def leaf_wire_bytes(self, leaf: CompressedVariable) -> int:
        if not is_compressed(leaf):
            raise TypeError(f"expected CompressedVariable, got {type(leaf)}")
        n = int(leaf.codes.size)
        return (packing.packed_bytes(n, leaf.fmt)
                + _PVT_BYTES_PER_ENTRY * int(np.asarray(leaf.s).size))

    def plan_wire_bytes(self, n_elems: int, stack_entries: int) -> int:
        sb = stack_entries if self.pvt else 1
        return packing.packed_bytes(n_elems, self.fmt) + _PVT_BYTES_PER_ENTRY * sb

    def describe(self):
        d = super().describe()
        d.update(fmt=self.fmt.name, pvt=self.pvt)
        return d
