"""Per-strategy leaf codecs for the §7 wire frame (DESIGN.md §11).

``repro.api.codecs`` owns the frame (header, manifest, crc, delta
verification) and ships the two built-in leaf kinds (``omc``, ``raw``);
this module registers the zoo's additional kinds — ``topk``, ``ternary``,
``pipeline`` — so strategy-encoded trees travel through the exact same
``encode_payload`` / ``decode_payload`` path, strategy tag and all.

Byte contract: each kind's body section is exactly
``StrategyLeaf.wire_body_bytes()`` bytes — the number every ledger
(``compress.tree_wire_bytes``, ``codecs.payload_bytes_report``,
``accounting.WireTable``) reports — so wire measurements reconcile with
planned budgets to the byte (tested in ``tests/test_compress.py``).

None of these kinds defines a delta rule: the §7 sparse XOR-delta is the
OMC strategy's delta (codes are positionally stable round-over-round);
top-k/pipeline support sets move every send and ternary re-sends cost 2
bits/param anyway, so they always travel full.
"""

from __future__ import annotations

from typing import Any, Dict, List, Tuple

import numpy as np

from repro.api import codecs
from repro.core import packing
from repro.core.formats import FloatFormat

from .pipeline import PipelineVariable
from .ternary import _TERNARY_BITS, TernaryVariable
from .topk import TopKSparseVariable


def _encode_topk(leaf: TopKSparseVariable, base) -> Tuple[Dict[str, Any], List[bytes]]:
    meta = dict(
        kind="topk",
        shape=list(leaf.shape),
        k=leaf.k,
        vfmt=leaf.value_fmt.name,
        mode="full",
    )
    idx = np.ascontiguousarray(np.asarray(leaf.idx, np.uint32))
    if leaf.value_fmt.is_identity:
        vals = np.ascontiguousarray(np.asarray(leaf.values, np.float32))
    else:
        vals = np.ascontiguousarray(np.asarray(leaf.values, np.uint32))
    return meta, [idx.tobytes(), vals.tobytes()]


def _decode_topk(meta: Dict[str, Any], body: memoryview, off: int, base):
    fmt = FloatFormat.parse(meta["vfmt"])
    k = int(meta["k"])
    idx = np.frombuffer(body, np.uint32, k, off).copy()
    off += 4 * k
    if fmt.is_identity:
        vals = np.frombuffer(body, np.float32, k, off).copy()
        off += 4 * k
    else:
        nwords = packing.packed_words(k, fmt.bits)
        vals = np.frombuffer(body, np.uint32, nwords, off).copy()
        off += 4 * nwords
    return TopKSparseVariable(idx, vals, tuple(meta["shape"]), fmt), off


def _encode_ternary(leaf: TernaryVariable, base) -> Tuple[Dict[str, Any], List[bytes]]:
    scale = np.ascontiguousarray(np.asarray(leaf.scale, np.float32))
    meta = dict(
        kind="ternary",
        shape=list(leaf.shape),
        sb_shape=list(scale.shape),
        mode="full",
    )
    words = np.asarray(
        packing.pack(np.asarray(leaf.codes).reshape(-1), _TERNARY_BITS),
        np.uint32,
    )
    return meta, [words.tobytes(), scale.tobytes()]


def _decode_ternary(meta: Dict[str, Any], body: memoryview, off: int, base):
    shape = tuple(meta["shape"])
    sb_shape = tuple(meta["sb_shape"])
    n = int(np.prod(shape)) if shape else 1
    n_sb = int(np.prod(sb_shape)) if sb_shape else 1
    nwords = packing.packed_words(n, _TERNARY_BITS)
    words = np.frombuffer(body, np.uint32, nwords, off)
    off += 4 * nwords
    scale = np.frombuffer(body, np.float32, n_sb, off).reshape(sb_shape).copy()
    off += 4 * n_sb
    codes = np.asarray(
        packing.unpack(words, _TERNARY_BITS, n), np.uint8
    ).reshape(shape)
    return TernaryVariable(codes, scale, shape), off


def _encode_pipeline(leaf: PipelineVariable, base) -> Tuple[Dict[str, Any], List[bytes]]:
    meta = dict(
        kind="pipeline",
        shape=list(leaf.shape),
        k=int(leaf.k),
        fmt=leaf.fmt.name,
        blen=len(leaf.blob),
        mode="full",
    )
    return meta, [leaf.blob]


def _decode_pipeline(meta: Dict[str, Any], body: memoryview, off: int, base):
    blen = int(meta["blen"])
    blob = bytes(body[off:off + blen])
    if len(blob) != blen:
        raise codecs.CodecError("pipeline blob truncated")
    off += blen
    return PipelineVariable(
        blob, int(meta["k"]), tuple(meta["shape"]), FloatFormat.parse(meta["fmt"])
    ), off


def register() -> None:
    codecs.register_leaf_codec("topk", TopKSparseVariable,
                               _encode_topk, _decode_topk)
    codecs.register_leaf_codec("ternary", TernaryVariable,
                               _encode_ternary, _decode_ternary)
    codecs.register_leaf_codec("pipeline", PipelineVariable,
                               _encode_pipeline, _decode_pipeline)


register()
