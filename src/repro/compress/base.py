"""The ``CompressionStrategy`` interface and the strategy zoo (DESIGN.md §11).

The paper's OMC quantization is one point in a wider design space: top-k
sparsification and structured updates (Konečný et al., arxiv 1610.05492),
ternary TNT weights (SNIPPETS.md §2–3), and stacked pipelines of
quantization + sparsification + entropy coding (Grativol et al., arxiv
2310.14693) all trade model quality against wire bytes along different
curves.  This module defines the one interface they share so that the
transport layer (``repro.api.codecs``), the byte ledgers
(``repro.federated.accounting``), and the benchmarks can treat them
uniformly:

  * :class:`CompressionStrategy` — encode/decode one selected variable to a
    self-describing wire leaf, a *traceable* qdq (and STE) view for
    in-training simulation, and exact byte accounting: shape-determined
    strategies predict their wire bytes from ``(n_elems, stack_entries)``
    alone (:meth:`~CompressionStrategy.plan_wire_bytes`), data-dependent
    ones (entropy coding) report ``None`` there and are measured from the
    encoded leaf (:meth:`~CompressionStrategy.leaf_wire_bytes`).
  * :class:`StrategyLeaf` — base class of the encoded per-variable wire
    leaves.  Each knows how to ``dequantize()`` itself and how many body
    bytes it serializes to (``wire_body_bytes`` — the codec must produce
    exactly this many; tested).
  * the registry — ``register_strategy`` / ``get_strategy`` /
    ``available_strategies`` / ``default_zoo``.  The registered name is
    also the payload's wire strategy tag, and ``wire_version`` is the
    per-strategy format version ``repro.api.codecs.decode_payload`` rejects
    on mismatch (CodecError, never silent corruption).

Tree-level helpers (``encode_tree`` / ``decode_tree`` / ``qdq_tree`` /
``tree_wire_bytes``) apply a strategy under the same weights-only selection
policy OMC uses (``repro.core.policy`` + stacked-axis awareness from
``repro.federated.state``), so every strategy compresses exactly the
variables OMC would and the byte reports stay comparable.
"""

from __future__ import annotations

import abc
import dataclasses
from typing import Any, Dict, List, Optional, Sequence, Tuple, Type

import jax
import numpy as np

from repro.core.omc import OMCConfig
from repro.core.policy import path_str
from repro.core.store import is_compressed
from repro.models.common import ParamSpec


class StrategyLeaf:
    """Base class of encoded per-variable wire leaves (non-OMC strategies).

    Subclasses are plain (non-pytree) dataclasses: they live on the wire /
    host side, like the codec's parsed frames — the traceable in-training
    view is :meth:`CompressionStrategy.qdq_leaf`, not these objects.
    Contract: ``dequantize()`` returns the f32 array the receiver
    materializes; ``wire_body_bytes()`` is the exact number of body bytes
    the §7 codec serializes for this leaf, split into ``index_bytes()``
    (position metadata) and ``meta_bytes()`` (scales/headers) for the
    per-strategy breakdown of ``payload_bytes_report``.
    """

    kind: str = "?"  # manifest leaf kind == strategy name

    def dequantize(self) -> jax.Array:
        raise NotImplementedError

    def wire_body_bytes(self) -> int:
        raise NotImplementedError

    def index_bytes(self) -> int:
        return 0

    def meta_bytes(self) -> int:
        return 0


class CompressionStrategy(abc.ABC):
    """One transport compressor: param tree <-> wire leaves, with exact bytes.

    Implementations must be deterministic (same input, same encoding) and
    lossless *as codecs*: ``decode_leaf(encode_leaf(v))`` is bit-stable
    (encoding the decoded value again yields the identical wire leaf), even
    though the encode step itself is lossy compression.
    """

    #: registry key AND the payload's wire strategy tag
    name: str = "?"
    #: per-strategy wire-format version; bumped on any layout change and
    #: verified by ``decode_payload`` (mismatch -> CodecError)
    wire_version: int = 1
    #: delta rule on repeat sends: "xor-sparse" (the §7 sparse XOR-delta,
    #: OMC's rule) or None (full-only)
    delta_rule: Optional[str] = None
    #: training-direction contract (DESIGN.md §12).  ``upload_only=True``
    #: marks strategies that compress only the client->server direction
    #: (sparse codes destroy a downloaded model, so the client trains on
    #: the dense server state and the qdq applies to its *update*);
    #: ``False`` means the qdq is also the client's in-memory view of the
    #: download, as in the paper's OMC simulation mode.
    upload_only: bool = False
    #: whether the training paths carry a per-client error-feedback
    #: residual for this strategy (DESIGN.md §12; Konečný et al., arxiv
    #: 1610.05492).  Always False for dense strategies — EF compensates
    #: what the sparsifier dropped, and a dense qdq drops nothing worth
    #: accumulating.  Sparse strategies expose it as a constructor field.
    error_feedback: bool = False

    # -- per-variable codec -------------------------------------------------
    @abc.abstractmethod
    def encode_leaf(self, v: jax.Array, *, batch_axes: int = 0):
        """f32 array -> wire leaf (StrategyLeaf or CompressedVariable)."""

    @abc.abstractmethod
    def decode_leaf(self, leaf) -> jax.Array:
        """Wire leaf -> the f32 array the receiver materializes."""

    # -- in-training view ---------------------------------------------------
    @abc.abstractmethod
    def qdq_leaf(self, v: jax.Array, *, batch_axes: int = 0) -> jax.Array:
        """Traceable quantize->dequantize view: numerically identical to
        ``decode_leaf(encode_leaf(v))`` but jit/vmap/grad-composable, for
        simulation-mode training under the strategy."""

    def qdq_ste_leaf(self, v: jax.Array, *, batch_axes: int = 0) -> jax.Array:
        """qdq with a straight-through gradient (QAT-style training)."""
        return v + jax.lax.stop_gradient(
            self.qdq_leaf(v, batch_axes=batch_axes) - v
        )

    def train_qdq_leaf(self, v: jax.Array, *, batch_axes: int = 0) -> jax.Array:
        """The qdq the *training* client view applies (DESIGN.md §12).

        Defaults to the wire qdq.  Strategies whose historical simulation
        numerics differ from the wire encode override this — notably OMC,
        whose in-training view uses the exact per-variable PVT solve
        (``core.omc.qdq_pvt_leaf``) while the wire path uses the fast
        distributed solver; the override keeps ``strategy="omc"`` training
        bit-identical to the pre-strategy hardcoded path.
        """
        return self.qdq_leaf(v, batch_axes=batch_axes)

    def train_qdq_ste_leaf(self, v: jax.Array, *,
                           batch_axes: int = 0) -> jax.Array:
        """:meth:`train_qdq_leaf` with a straight-through gradient."""
        return v + jax.lax.stop_gradient(
            self.train_qdq_leaf(v, batch_axes=batch_axes) - v
        )

    # -- byte accounting ----------------------------------------------------
    @abc.abstractmethod
    def leaf_wire_bytes(self, leaf) -> int:
        """Exact wire body bytes of one *encoded* leaf (measured)."""

    def plan_wire_bytes(self, n_elems: int, stack_entries: int) -> Optional[int]:
        """Wire body bytes predicted from the shape alone, or None when the
        size is data-dependent (entropy-coded strategies).  When not None it
        MUST equal ``leaf_wire_bytes`` of any encode of that shape — this is
        what lets :class:`repro.federated.accounting.WireTable` budget a
        round without materializing payloads."""
        return None

    def describe(self) -> Dict[str, Any]:
        """Identification row for benchmark artifacts and reports."""
        return dict(strategy=self.name, wire_version=self.wire_version,
                    label=self.label)

    @property
    def label(self) -> str:
        """Human-readable point label (subclasses append their params)."""
        return self.name


# ---------------------------------------------------------------------------
# registry — the strategy zoo
# ---------------------------------------------------------------------------

_REGISTRY: Dict[str, Type[CompressionStrategy]] = {}


def register_strategy(cls: Type[CompressionStrategy]) -> Type[CompressionStrategy]:
    """Class decorator: add a strategy to the zoo under ``cls.name``."""
    if not cls.name or cls.name == "?":
        raise ValueError(f"{cls.__name__} must declare a registry name")
    if not isinstance(cls.wire_version, int) or cls.wire_version < 1:
        raise ValueError(f"{cls.__name__} must declare wire_version >= 1")
    prev = _REGISTRY.get(cls.name)
    if prev is not None and prev is not cls:
        raise ValueError(f"strategy name {cls.name!r} already registered")
    _REGISTRY[cls.name] = cls
    return cls


def get_strategy(name: str, **params) -> CompressionStrategy:
    """Instantiate a registered strategy by name."""
    if name not in _REGISTRY:
        raise KeyError(
            f"unknown compression strategy {name!r}; "
            f"registered: {sorted(_REGISTRY)}"
        )
    return _REGISTRY[name](**params)


def strategy_class(name: str) -> Type[CompressionStrategy]:
    if name not in _REGISTRY:
        raise KeyError(f"unknown compression strategy {name!r}")
    return _REGISTRY[name]


def available_strategies() -> List[str]:
    return sorted(_REGISTRY)


def default_zoo() -> List[CompressionStrategy]:
    """The benchmark sweep's default strategy instances (one per family)."""
    from .omc_quant import OMCQuantStrategy
    from .pipeline import PipelineStrategy
    from .ternary import TernaryTNTStrategy
    from .topk import TopKSparseStrategy

    return [
        OMCQuantStrategy(),                    # the paper's S1E3M7 + PVT
        OMCQuantStrategy.parse("S1E4M3"),      # aggressive 8-bit minifloat
        TopKSparseStrategy(density=0.1),
        TernaryTNTStrategy(),
        PipelineStrategy(),                    # quant -> top-k -> DEFLATE
    ]


def is_strategy_leaf(x: Any) -> bool:
    return isinstance(x, StrategyLeaf)


def is_encoded_leaf(x: Any) -> bool:
    """True for any wire leaf: OMC ``CompressedVariable`` or StrategyLeaf."""
    return is_compressed(x) or isinstance(x, StrategyLeaf)


# ---------------------------------------------------------------------------
# tree-level application under the OMC selection policy
# ---------------------------------------------------------------------------


def _selected(omc: OMCConfig, path: str, spec, leaf) -> bool:
    # stacked-axis-aware weights-only policy; one canonical implementation
    from repro.federated.state import selected

    return selected(omc, path, spec, leaf)


def _n_stack_axes(spec, leaf) -> int:
    from repro.federated.state import n_stack_axes

    return n_stack_axes(spec, leaf)


def _map_selected(fn, params, omc: OMCConfig, specs=None):
    if specs is None:
        # policy-only selection (no stacked-axis info): batch_axes = 0
        def f(path, leaf):
            if omc.enabled and omc.policy.selects(path_str(path), leaf):
                return fn(leaf, 0)
            return leaf

        return jax.tree_util.tree_map_with_path(f, params)

    def g(path, spec, leaf):
        if _selected(omc, path_str(path), spec, leaf):
            return fn(leaf, _n_stack_axes(spec, leaf))
        return leaf

    return jax.tree_util.tree_map_with_path(
        g, specs, params, is_leaf=lambda s: isinstance(s, ParamSpec)
    )


def encode_tree(strategy: CompressionStrategy, params, omc: OMCConfig,
                specs=None):
    """f32 tree -> wire tree: policy-selected leaves encoded under
    ``strategy``, everything else passed through (travels raw f32).

    ``omc`` supplies the *selection policy* (weights-only, exclusions) —
    the strategy replaces only the transport representation, so every
    strategy compresses the same variables and byte reports compare
    like-for-like.  ``specs`` (the family's ParamSpec tree) enables
    stacked-axis-aware selection and per-entry scales, exactly as
    :func:`repro.federated.state.compress_params` does for OMC.
    """
    return _map_selected(
        lambda leaf, ax: strategy.encode_leaf(leaf, batch_axes=ax),
        params, omc, specs,
    )


def decode_tree(tree):
    """Wire tree -> f32 tree (every encoded leaf dequantized)."""
    return jax.tree_util.tree_map(
        lambda x: x.dequantize() if is_encoded_leaf(x) else x,
        tree,
        is_leaf=is_encoded_leaf,
    )


def qdq_tree(strategy: CompressionStrategy, params, omc: OMCConfig,
             specs=None):
    """Traceable quantize->dequantize view of the whole tree — the
    simulation-mode counterpart of ``decode_tree(encode_tree(...))``."""
    return _map_selected(
        lambda leaf, ax: strategy.qdq_leaf(leaf, batch_axes=ax),
        params, omc, specs,
    )


def tree_wire_bytes(tree) -> Dict[str, Any]:
    """Exact wire body bytes of an encoded tree, split per strategy kind.

    Returns the same totals a serialized full payload's body measures and
    the same per-kind split :func:`repro.api.codecs.payload_bytes_report`
    reports (reconciliation tested): ``wire_bytes`` is the sum over leaves
    of their exact body size; ``per_strategy[kind]`` carries payload bytes
    plus the index/metadata overhead split.
    """
    from repro.core import packing

    total = dict(wire_bytes=0, fp32_bytes=0, num_params=0)
    per: Dict[str, Dict[str, int]] = {}

    def bucket(kind):
        return per.setdefault(kind, dict(
            payload_bytes=0, index_bytes=0, meta_bytes=0,
            num_leaves=0, num_params=0,
        ))

    for leaf in jax.tree_util.tree_leaves(tree, is_leaf=is_encoded_leaf):
        if is_compressed(leaf):
            n = int(leaf.codes.size)
            meta = 8 * int(np.asarray(leaf.s).size)
            body = packing.packed_bytes(n, leaf.fmt) + meta
            b = bucket("omc")
            b["payload_bytes"] += body
            b["meta_bytes"] += meta
            b["num_leaves"] += 1
            b["num_params"] += n
        elif isinstance(leaf, StrategyLeaf):
            n = int(np.prod(leaf.shape)) if leaf.shape else 1
            body = leaf.wire_body_bytes()
            b = bucket(leaf.kind)
            b["payload_bytes"] += body
            b["index_bytes"] += leaf.index_bytes()
            b["meta_bytes"] += leaf.meta_bytes()
            b["num_leaves"] += 1
            b["num_params"] += n
        else:
            arr = np.asarray(leaf)
            n = int(arr.size)
            body = int(arr.nbytes)
            b = bucket("raw")
            b["payload_bytes"] += body
            b["num_leaves"] += 1
            b["num_params"] += n
        total["wire_bytes"] += body
        total["fp32_bytes"] += 4 * n
        total["num_params"] += n
    total["wire_ratio"] = total["wire_bytes"] / max(total["fp32_bytes"], 1)
    total["per_strategy"] = per
    return total
