"""Stacked quantize → sparsify → entropy-code pipeline (arxiv 2310.14693).

Grativol et al. show the three classic lossy/lossless stages compose: keep
the top-k magnitudes (sparsify), quantize the survivors to a minifloat
(quantize), then squeeze the residual statistical redundancy out of the
index+code stream with a lossless entropy coder.  Here the entropy stage
is DEFLATE (``zlib`` — already in every Python) over delta-encoded
positions and the bit-packed codes: gaps between sorted top-k positions
are small and code distributions are peaked, which is exactly what a
dictionary+Huffman coder eats.

The entropy stage makes the wire size *data-dependent*: the strategy
reports ``plan_wire_bytes = None`` and byte accounting must measure the
encoded leaf (``leaf_wire_bytes`` / ``tree_wire_bytes``), per the §11
accounting obligations.  The lossy numerics are exactly the first two
stages — the qdq view is top-k followed by value quantization, and DEFLATE
never changes a decoded bit (roundtrip-tested).
"""

from __future__ import annotations

import dataclasses
import zlib
from typing import Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import packing
from repro.core.formats import FloatFormat, decode, encode, value_quantize

from .base import CompressionStrategy, StrategyLeaf, register_strategy
from .topk import num_kept


@dataclasses.dataclass
class PipelineVariable(StrategyLeaf):
    """One variable as a DEFLATE blob of (delta positions, packed codes)."""

    blob: bytes
    k: int
    shape: Tuple[int, ...]
    fmt: FloatFormat

    kind = "pipeline"

    def dequantize(self) -> jax.Array:
        raw = zlib.decompress(self.blob)
        idx_delta = np.frombuffer(raw, np.uint32, self.k)
        nwords = packing.packed_words(self.k, self.fmt.bits)
        words = np.frombuffer(raw, np.uint32, nwords, 4 * self.k)
        idx = np.cumsum(idx_delta.astype(np.int64))
        codes = packing.unpack(jnp.asarray(words), self.fmt.bits, self.k)
        vals = np.asarray(decode(codes, self.fmt), np.float32)
        n = int(np.prod(self.shape)) if self.shape else 1
        out = np.zeros((n,), np.float32)
        out[idx] = vals
        return jnp.asarray(out.reshape(self.shape))

    def wire_body_bytes(self) -> int:
        return len(self.blob)


@register_strategy
@dataclasses.dataclass(frozen=True)
class PipelineStrategy(CompressionStrategy):
    """quantize(fmt) ∘ top-k(density) ∘ DEFLATE(level)."""

    fmt: FloatFormat = FloatFormat(3, 7)  # stage 1: the paper's minifloat
    density: float = 0.1  # stage 2: magnitude top-k
    level: int = 6  # stage 3: DEFLATE effort
    #: the lossy stages are top-k + quantize, so error feedback applies
    #: exactly as for ``topk`` (DESIGN.md §12)
    error_feedback: bool = True

    name = "pipeline"
    wire_version = 1
    delta_rule = None
    upload_only = True  # sparse: compresses the client->server direction

    def __post_init__(self):
        if not (0.0 < self.density <= 1.0):
            raise ValueError(f"density must be in (0, 1], got {self.density}")
        if not (1 <= self.level <= 9):
            raise ValueError(f"level must be in [1, 9], got {self.level}")

    @classmethod
    def parse(cls, fmt: str, **kw) -> "PipelineStrategy":
        return cls(fmt=FloatFormat.parse(fmt), **kw)

    @property
    def label(self) -> str:
        return f"pipe-{self.fmt.name.lower()}-{self.density:g}"

    def encode_leaf(self, v, *, batch_axes: int = 0) -> PipelineVariable:
        flat = np.asarray(v, np.float32).reshape(-1)
        n = flat.size
        k = num_kept(n, self.density)
        idx = np.argpartition(np.abs(flat), n - k)[n - k:]
        idx = np.sort(idx)
        vals = flat[idx]
        vq = np.asarray(value_quantize(jnp.asarray(vals), self.fmt))
        codes = encode(jnp.asarray(vq), self.fmt, quantize=False)
        words = np.asarray(packing.pack(codes, self.fmt.bits))
        # delta-encode the sorted positions: small gaps compress far better
        # than absolute u32 offsets under DEFLATE
        idx_delta = np.diff(idx, prepend=0).astype(np.uint32)
        raw = idx_delta.tobytes() + words.tobytes()
        blob = zlib.compress(raw, self.level)
        return PipelineVariable(blob, k, tuple(np.shape(v)), self.fmt)

    def decode_leaf(self, leaf: PipelineVariable) -> jax.Array:
        return leaf.dequantize()

    def qdq_leaf(self, v, *, batch_axes: int = 0) -> jax.Array:
        # the lossy stages only — DEFLATE is bit-lossless by construction
        flat = jnp.reshape(v, (-1,))
        n = int(flat.shape[0])
        k = num_kept(n, self.density)
        mag = jnp.abs(flat)
        thr = jnp.sort(mag)[n - k]
        kept = jnp.where(mag >= thr, value_quantize(flat, self.fmt), 0.0)
        return jnp.reshape(kept, jnp.shape(v))

    def leaf_wire_bytes(self, leaf: PipelineVariable) -> int:
        return leaf.wire_body_bytes()

    # plan_wire_bytes stays None: DEFLATE output is data-dependent.  Budget
    # with `compress.tree_wire_bytes` over an actual encode instead.

    def describe(self):
        d = super().describe()
        d.update(fmt=self.fmt.name, density=self.density, level=self.level)
        return d
