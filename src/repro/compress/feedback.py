"""Per-client error-feedback residual accumulators (DESIGN.md §12).

Sparse upload strategies (top-k, ternary, the top-k pipeline) drop most of
a client's update on every send.  Error feedback (Konečný et al., arxiv
1610.05492; convergence under compression pipelines: arxiv 2310.14693)
keeps training unbiased in the long run: the client accumulates what the
compressor dropped in a local residual ``e`` and adds it back before the
next send::

    comp  = delta + e          # compensated update
    sent  = qdq(comp)          # what actually travels
    e'    = comp - sent        # carried to the client's next round

The invariant ``sent + e' == comp`` (exact for value-preserving sparsifiers
like f32 top-k, one rounding step otherwise) means no coordinate is ever
lost — only delayed.  Dense strategies drop nothing worth accumulating, so
EF is a structural no-op for them (``CompressionStrategy.error_feedback``
is ``False`` and the training paths never allocate a residual).

The residual state is one pytree per *population*: a dict keyed by the
selected-variable paths (the same canonical
:func:`repro.federated.accounting.walk_selected` order every PPQ mask
uses), each leaf shaped ``[num_clients, *var_shape]``.  All three training
paths (loop / engine / async) share this layout, so a residual state is
checkpointable with the ordinary :mod:`repro.checkpoint` pytree machinery
and transfers between paths.  Property tests: ``tests/test_feedback.py``.
"""

from __future__ import annotations

from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp

from repro.core.omc import OMCConfig

from .base import CompressionStrategy


def takes_residual(omc: OMCConfig, strategy: Optional[CompressionStrategy]
                   ) -> bool:
    """True when training under ``strategy`` threads an EF residual.

    Requires all three: a strategy is actually plugged in, the OMC config
    selects variables at all (``omc.enabled`` — the selection policy is
    OMC's even under zoo strategies), and the strategy is a sparse
    upload-direction compressor that opted into error feedback.
    """
    return (strategy is not None and omc.enabled
            and strategy.upload_only and bool(strategy.error_feedback))


def init_ef_state(params_f32, specs, omc: OMCConfig,
                  num_clients: int) -> Dict[str, jax.Array]:
    """Zeroed residuals: ``{selected-var path: f32[num_clients, *shape]}``."""
    from repro.federated import accounting

    sel, _ = accounting.walk_selected(params_f32, specs, omc)
    return {
        name: jnp.zeros((int(num_clients),) + tuple(leaf.shape), jnp.float32)
        for name, _, leaf in sel
    }


def gather_rows(ef: Dict[str, jax.Array], client_ids) -> Dict[str, jax.Array]:
    """Per-cohort residual rows (traceable gather; ids may be a traced
    int array — the engine gathers inside its compiled round program)."""
    return {k: v[client_ids] for k, v in ef.items()}


def scatter_rows(ef: Dict[str, jax.Array], client_ids,
                 rows: Dict[str, jax.Array]) -> Dict[str, jax.Array]:
    """New population state with ``rows`` written at ``client_ids``.

    ``client_ids`` must be unique (cohorts are sampled without
    replacement; duplicate indices would make the scatter order-dependent).
    """
    return {k: ef[k].at[client_ids].set(rows[k]) for k in ef}


def ef_bytes(ef: Optional[Dict[str, jax.Array]]) -> int:
    """Client-state memory the residuals cost (f32), for byte reports."""
    if not ef:
        return 0
    return sum(4 * int(v.size) for v in ef.values())


def ef_norms(ef: Dict[str, jax.Array]) -> Dict[str, float]:
    """Per-variable L2 norm over the whole population (diagnostics; the
    boundedness property tests assert these don't grow without bound)."""
    return {k: float(jnp.sqrt(jnp.sum(jnp.square(v)))) for k, v in ef.items()}


def total_norm(ef: Optional[Dict[str, jax.Array]]) -> float:
    if not ef:
        return 0.0
    return float(jnp.sqrt(sum(jnp.sum(jnp.square(v)) for v in ef.values())))


def compensate_leaf(strategy: CompressionStrategy, delta, residual, mask_bit,
                    *, batch_axes: int = 0, ste: bool = False):
    """One variable's EF send rule: ``(sent, new_residual)``.

    ``mask_bit`` is the client's PPQ bit for this variable: when unset the
    variable travels f32 (OMC transport semantics generalized to the zoo),
    the compensated update arrives exactly, and the residual drains to 0.
    """
    comp = delta + residual
    qdq = strategy.train_qdq_ste_leaf if ste else strategy.train_qdq_leaf
    sent = jnp.where(mask_bit, qdq(comp, batch_axes=batch_axes), comp)
    return sent, comp - sent
