"""Ternary TNT weight strategy (SNIPPETS.md §2–3; Zhang & Zhu's
"Target Non-retraining Ternary" quantization, the TWN closed form).

Each selected variable collapses to ``w ≈ scale · t`` with
``t ∈ {-1, 0, +1}``: threshold ``Δ = 0.7·mean(|v|)``, ``t = sign(v)`` where
``|v| > Δ`` else 0, and ``scale`` the L2-optimal mean magnitude of the
surviving entries.  Stacked variables (scan layers / experts) get one
``(Δ, scale)`` per stacked entry, mirroring OMC's per-variable PVT scalars.

The wire form is 2 bits/param: codes ``{0, 1, 2}`` (for −1, 0, +1) through
the exact-width bit packer, plus one f32 scale per stacked entry — the
cheapest point of the zoo (16x vs f32), at the largest quality cost.
"""

from __future__ import annotations

import dataclasses
from typing import Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import packing

from .base import CompressionStrategy, StrategyLeaf, register_strategy

_TERNARY_BITS = 2


def ternarize(v: jax.Array, batch_axes: int = 0, threshold_factor: float = 0.7):
    """(t, scale): t ∈ {-1, 0, +1} same shape as v, scale per stacked entry.

    Traceable — this single function backs both the wire encode and the
    in-training qdq view, so the two can never disagree.
    """
    v = jnp.asarray(v, jnp.float32)
    axes = tuple(range(batch_axes, v.ndim))
    mag = jnp.abs(v)
    delta = threshold_factor * jnp.mean(mag, axis=axes, keepdims=True)
    mask = mag > delta
    kept = jnp.sum(jnp.where(mask, mag, 0.0), axis=axes, keepdims=True)
    count = jnp.sum(mask, axis=axes, keepdims=True).astype(jnp.float32)
    scale = kept / jnp.maximum(count, 1.0)
    t = jnp.where(mask, jnp.sign(v), 0.0)
    return t, jnp.squeeze(scale, axis=axes)


@dataclasses.dataclass
class TernaryVariable(StrategyLeaf):
    """One variable as 2-bit ternary codes + per-stacked-entry scale."""

    codes: np.ndarray  # u8, original shape, values in {0, 1, 2}
    scale: np.ndarray  # f32, shape = leading batch_axes of codes
    shape: Tuple[int, ...]

    kind = "ternary"

    def dequantize(self) -> jax.Array:
        t = np.asarray(self.codes, np.float32) - 1.0
        scale = np.asarray(self.scale, np.float32)
        bshape = scale.shape + (1,) * (len(self.shape) - scale.ndim)
        return jnp.asarray(t * scale.reshape(bshape))

    def wire_body_bytes(self) -> int:
        n = int(np.prod(self.shape)) if self.shape else 1
        return packing.packed_bytes_width(n, _TERNARY_BITS) + self.meta_bytes()

    def meta_bytes(self) -> int:
        return 4 * int(np.asarray(self.scale).size)


@register_strategy
@dataclasses.dataclass(frozen=True)
class TernaryTNTStrategy(CompressionStrategy):
    """TNT/TWN ternary weights: 2-bit codes + one scale per stacked entry."""

    threshold_factor: float = 0.7  # the TWN Δ = 0.7·E|v| heuristic
    #: accumulate the ternarization error in a per-client residual
    #: (training paths only — see DESIGN.md §12)
    error_feedback: bool = True

    name = "ternary"
    wire_version = 1
    delta_rule = None
    upload_only = True  # a ternarized download would destroy the model

    @property
    def label(self) -> str:
        return "ternary-tnt"

    def encode_leaf(self, v, *, batch_axes: int = 0) -> TernaryVariable:
        t, scale = ternarize(v, batch_axes, self.threshold_factor)
        codes = np.asarray(t + 1.0, np.uint8)
        return TernaryVariable(
            codes, np.asarray(scale, np.float32), tuple(np.shape(v))
        )

    def decode_leaf(self, leaf: TernaryVariable) -> jax.Array:
        return leaf.dequantize()

    def qdq_leaf(self, v, *, batch_axes: int = 0) -> jax.Array:
        t, scale = ternarize(v, batch_axes, self.threshold_factor)
        bshape = scale.shape + (1,) * (t.ndim - scale.ndim)
        return t * jnp.reshape(scale, bshape)

    def leaf_wire_bytes(self, leaf: TernaryVariable) -> int:
        return leaf.wire_body_bytes()

    def plan_wire_bytes(self, n_elems: int, stack_entries: int) -> int:
        return (packing.packed_bytes_width(n_elems, _TERNARY_BITS)
                + 4 * stack_entries)

    def describe(self):
        d = super().describe()
        d.update(threshold_factor=self.threshold_factor)
        return d
