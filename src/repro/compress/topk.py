"""Magnitude top-k sparsification strategy (Konečný et al., arxiv 1610.05492).

Each selected variable travels as the ``k = max(1, round(density·n))``
entries of largest magnitude: sorted u32 positions plus their values,
optionally quantized to a minifloat ``value_fmt`` and bit-packed (the
structured-update recipe: subsample, then quantize what survives).  The
receiver scatters into zeros — the strategy's model view IS the sparse
tree, matching the paper's sparsification baselines where the server only
ever sees the surviving coordinates.

Wire size is shape-determined: ``4·k`` index bytes + value bytes (+ no
per-variable scales), so :class:`repro.federated.accounting.WireTable` can
budget rounds without materializing payloads.
"""

from __future__ import annotations

import dataclasses
from typing import Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import packing
from repro.core.formats import FP32, FloatFormat, decode, encode, value_quantize

from .base import CompressionStrategy, StrategyLeaf, register_strategy


def num_kept(n: int, density: float) -> int:
    """k for an n-element variable — shared by encode, qdq, and planning."""
    return max(1, min(n, int(round(n * float(density)))))


@dataclasses.dataclass
class TopKSparseVariable(StrategyLeaf):
    """One variable as (sorted positions, surviving values)."""

    idx: np.ndarray  # u32[k], sorted ascending
    values: np.ndarray  # f32[k] (value_fmt identity) or packed u32 words
    shape: Tuple[int, ...]
    value_fmt: FloatFormat

    kind = "topk"

    @property
    def k(self) -> int:
        return int(self.idx.size)

    def dequantize(self) -> jax.Array:
        n = int(np.prod(self.shape)) if self.shape else 1
        if self.value_fmt.is_identity:
            vals = np.asarray(self.values, np.float32)
        else:
            codes = packing.unpack(
                jnp.asarray(self.values), self.value_fmt.bits, self.k
            )
            vals = np.asarray(decode(codes, self.value_fmt), np.float32)
        out = np.zeros((n,), np.float32)
        out[np.asarray(self.idx, np.int64)] = vals
        return jnp.asarray(out.reshape(self.shape))

    def wire_body_bytes(self) -> int:
        return self.index_bytes() + self._value_bytes()

    def _value_bytes(self) -> int:
        if self.value_fmt.is_identity:
            return 4 * self.k
        return packing.packed_bytes(self.k, self.value_fmt)

    def index_bytes(self) -> int:
        return 4 * self.k


@register_strategy
@dataclasses.dataclass(frozen=True)
class TopKSparseStrategy(CompressionStrategy):
    """Keep the ``density`` fraction of largest-magnitude entries."""

    density: float = 0.1
    value_fmt: FloatFormat = FP32  # identity: raw f32 values on the wire
    #: carry the dropped coordinates in a per-client residual and add them
    #: back before the next send (error feedback, arxiv 1610.05492) —
    #: training paths only; the wire format is unaffected
    error_feedback: bool = True

    name = "topk"
    wire_version = 1
    delta_rule = None  # full-only: the support set changes every send
    upload_only = True  # sparse codes compress the client->server direction

    def __post_init__(self):
        if not (0.0 < self.density <= 1.0):
            raise ValueError(f"density must be in (0, 1], got {self.density}")

    @property
    def label(self) -> str:
        tag = f"topk-{self.density:g}"
        return tag if self.value_fmt.is_identity else (
            f"{tag}-{self.value_fmt.name.lower()}"
        )

    def encode_leaf(self, v, *, batch_axes: int = 0) -> TopKSparseVariable:
        flat = np.asarray(v, np.float32).reshape(-1)
        n = flat.size
        k = num_kept(n, self.density)
        # argpartition: O(n) selection of the k largest magnitudes
        idx = np.argpartition(np.abs(flat), n - k)[n - k:]
        idx = np.sort(idx).astype(np.uint32)
        vals = flat[idx.astype(np.int64)]
        if not self.value_fmt.is_identity:
            vq = np.asarray(value_quantize(vals, self.value_fmt))
            codes = encode(jnp.asarray(vq), self.value_fmt, quantize=False)
            vals = np.asarray(packing.pack(codes, self.value_fmt.bits))
        return TopKSparseVariable(idx, vals, tuple(np.shape(v)), self.value_fmt)

    def decode_leaf(self, leaf: TopKSparseVariable) -> jax.Array:
        return leaf.dequantize()

    def qdq_leaf(self, v, *, batch_axes: int = 0) -> jax.Array:
        flat = jnp.reshape(v, (-1,))
        n = int(flat.shape[0])
        k = num_kept(n, self.density)
        mag = jnp.abs(flat)
        # threshold at the k-th largest magnitude; ties may keep a few extra
        # entries — the encode path breaks ties by position, the traceable
        # view must stay a pure elementwise mask
        thr = jnp.sort(mag)[n - k]
        kept = jnp.where(mag >= thr, flat, 0.0)
        if not self.value_fmt.is_identity:
            kept = value_quantize(kept, self.value_fmt)
        return jnp.reshape(kept, jnp.shape(v))

    def leaf_wire_bytes(self, leaf: TopKSparseVariable) -> int:
        return leaf.wire_body_bytes()

    def plan_wire_bytes(self, n_elems: int, stack_entries: int) -> int:
        k = num_kept(n_elems, self.density)
        vb = 4 * k if self.value_fmt.is_identity else packing.packed_bytes(
            k, self.value_fmt
        )
        return 4 * k + vb

    def describe(self):
        d = super().describe()
        d.update(density=self.density, value_fmt=self.value_fmt.name)
        return d
