"""Optimizers as (init, update) pairs over pytrees.

``update(grads, state, params) -> (updates, new_state)`` returns *additive*
updates (apply as ``params + updates``), matching the optax convention so the
federated server can treat the aggregated client delta as a "gradient"
(sign-flipped) for the server optimizer — the FedOpt framework.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable, NamedTuple, Optional, Union

import jax
import jax.numpy as jnp

Schedule = Union[float, Callable[[jax.Array], jax.Array]]


def _lr_at(lr: Schedule, step) -> jax.Array:
    return jnp.asarray(lr(step) if callable(lr) else lr, jnp.float32)


@dataclasses.dataclass(frozen=True)
class Optimizer:
    init: Callable[[Any], Any]
    update: Callable[[Any, Any, Any], Any]  # (grads, state, params) -> (upd, st)


class _CountState(NamedTuple):
    count: jax.Array


def sgd(lr: Schedule) -> Optimizer:
    def init(params):
        return _CountState(jnp.zeros((), jnp.int32))

    def update(grads, state, params=None):
        s = _lr_at(lr, state.count)
        upd = jax.tree_util.tree_map(lambda g: -s * g, grads)
        return upd, _CountState(state.count + 1)

    return Optimizer(init, update)


class _MomentumState(NamedTuple):
    count: jax.Array
    mu: Any


def momentum(lr: Schedule, beta: float = 0.9, nesterov: bool = False) -> Optimizer:
    def init(params):
        return _MomentumState(
            jnp.zeros((), jnp.int32),
            jax.tree_util.tree_map(jnp.zeros_like, params),
        )

    def update(grads, state, params=None):
        s = _lr_at(lr, state.count)
        mu = jax.tree_util.tree_map(lambda m, g: beta * m + g, state.mu, grads)
        if nesterov:
            upd = jax.tree_util.tree_map(lambda m, g: -s * (beta * m + g), mu, grads)
        else:
            upd = jax.tree_util.tree_map(lambda m: -s * m, mu)
        return upd, _MomentumState(state.count + 1, mu)

    return Optimizer(init, update)


class _AdamState(NamedTuple):
    count: jax.Array
    mu: Any
    nu: Any


def adamw(lr: Schedule, b1: float = 0.9, b2: float = 0.999, eps: float = 1e-8,
          weight_decay: float = 0.0) -> Optimizer:
    def init(params):
        z = jax.tree_util.tree_map(jnp.zeros_like, params)
        return _AdamState(jnp.zeros((), jnp.int32), z,
                          jax.tree_util.tree_map(jnp.zeros_like, params))

    def update(grads, state, params=None):
        c = state.count + 1
        s = _lr_at(lr, state.count)
        mu = jax.tree_util.tree_map(lambda m, g: b1 * m + (1 - b1) * g, state.mu, grads)
        nu = jax.tree_util.tree_map(
            lambda n, g: b2 * n + (1 - b2) * jnp.square(g), state.nu, grads
        )
        bc1 = 1 - b1 ** c.astype(jnp.float32)
        bc2 = 1 - b2 ** c.astype(jnp.float32)

        def u(m, n, p):
            upd = -s * (m / bc1) / (jnp.sqrt(n / bc2) + eps)
            if weight_decay and p is not None:
                upd = upd - s * weight_decay * p
            return upd

        if params is None:
            upd = jax.tree_util.tree_map(lambda m, n: u(m, n, None), mu, nu)
        else:
            upd = jax.tree_util.tree_map(u, mu, nu, params)
        return upd, _AdamState(c, mu, nu)

    return Optimizer(init, update)


# ---------------------------------------------------------------------------
# Server optimizers (FedOpt family) — consume the *negated mean client delta*
# as the gradient: grads = -mean_delta.
# ---------------------------------------------------------------------------


def fedavg(server_lr: Schedule = 1.0, server_momentum: float = 0.0) -> Optimizer:
    """FedAvg: params += server_lr * mean_delta (optionally with momentum)."""
    return momentum(server_lr, server_momentum) if server_momentum else sgd(server_lr)


def fedadam(server_lr: Schedule = 1e-2, b1: float = 0.9, b2: float = 0.99,
            eps: float = 1e-3) -> Optimizer:
    return adamw(server_lr, b1, b2, eps)


def fedadagrad(server_lr: Schedule = 1e-2, eps: float = 1e-3) -> Optimizer:
    class _State(NamedTuple):
        count: jax.Array
        nu: Any

    def init(params):
        return _State(jnp.zeros((), jnp.int32),
                      jax.tree_util.tree_map(jnp.zeros_like, params))

    def update(grads, state, params=None):
        s = _lr_at(server_lr, state.count)
        nu = jax.tree_util.tree_map(lambda n, g: n + jnp.square(g), state.nu, grads)
        upd = jax.tree_util.tree_map(
            lambda g, n: -s * g / (jnp.sqrt(n) + eps), grads, nu
        )
        return upd, _State(state.count + 1, nu)

    return Optimizer(init, update)
