"""Learning-rate schedules as step -> lr callables."""

from __future__ import annotations

import jax.numpy as jnp


def constant(lr: float):
    return lambda step: jnp.float32(lr)


def cosine_decay(lr: float, total_steps: int, final_frac: float = 0.0):
    def f(step):
        t = jnp.clip(step.astype(jnp.float32) / max(total_steps, 1), 0.0, 1.0)
        c = 0.5 * (1 + jnp.cos(jnp.pi * t))
        return jnp.float32(lr) * (final_frac + (1 - final_frac) * c)

    return f


def warmup_cosine(lr: float, warmup_steps: int, total_steps: int,
                  final_frac: float = 0.0):
    cd = cosine_decay(lr, max(total_steps - warmup_steps, 1), final_frac)

    def f(step):
        s = step.astype(jnp.float32)
        warm = jnp.float32(lr) * s / max(warmup_steps, 1)
        return jnp.where(step < warmup_steps, warm, cd(step - warmup_steps))

    return f
