"""Pure-JAX optimizers (optax is not available offline).

Client optimizers (paper: plain SGD, 1 local step) and server optimizers
(FedAvg = server-side SGD on the aggregated delta, optionally with momentum;
FedAdam/FedAdagrad for the adaptive variants from Reddi et al.).
"""

from .optimizers import (
    Optimizer,
    adamw,
    fedadagrad,
    fedadam,
    fedavg,
    momentum,
    sgd,
)
from .schedules import constant, cosine_decay, warmup_cosine

__all__ = [
    "Optimizer", "sgd", "momentum", "adamw",
    "fedavg", "fedadam", "fedadagrad",
    "constant", "cosine_decay", "warmup_cosine",
]
