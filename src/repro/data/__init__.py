"""Deterministic synthetic data pipelines (no external datasets offline)."""

from .synthetic import (
    LMTask,
    FrameTask,
    Partitioner,
    lm_batch,
    frame_batch,
    make_lm_task,
    make_frame_task,
)
from .partition import (
    DirichletPartition,
    DomainPartition,
    IIDPartition,
    ShardPartition,
    make_partitioned_batch_fn,
)
