"""Pluggable non-IID client partitioners (DESIGN.md §9; paper §3.5 scenarios).

A *partitioner* decides how the global data distribution is split across the
client population.  Every partitioner is a frozen dataclass exposing

  ``source_weights(key, client_id, num_sources) -> f32[num_sources]``
      the client's mixing distribution over latent "sources" (speakers /
      label shards / topic clusters — the unit of statistical heterogeneity),
  ``domain_of(client_id) -> int``
      which task domain the client lives in (for adaptation scenarios).

Both are pure functions of ``(seed, client_id)`` and traceable in
``client_id``, so the vectorized engine can ``vmap`` per-client data
generation straight into its per-tier XLA program — heterogeneity costs no
host round-trips.  The classic FL splits are provided:

  * :class:`IIDPartition` — uniform mixing; every client sees the same
    distribution (paper Table 1 conditions),
  * :class:`DirichletPartition` — per-client Dirichlet(α) source weights,
    the standard label-skew benchmark (smaller α = more skew); the
    per-speaker LibriSpeech partition analogue (paper Table 3),
  * :class:`ShardPartition` — each client holds exactly
    ``shards_per_client`` of the sources (the pathological FedAvg split of
    Konečný et al. 2016 / McMahan et al.),
  * :class:`DomainPartition` — clients split across task domains
    (Multi-Domain dataset analogue, paper Table 2).

``make_partitioned_batch_fn`` binds a partitioner to a synthetic
:class:`~repro.data.synthetic.FrameTask`: each example samples a source from
the client's mixing weights and shifts its frames by that source's bias
vector (the "speaker" signature), and the label probe follows the client's
domain.  The result has the engine's ``data_fn(client_id, round, step)``
signature and is vmappable.
"""

from __future__ import annotations

import dataclasses
from typing import Protocol

import jax
import jax.numpy as jnp

from .synthetic import FrameTask


class Partitioner(Protocol):
    """Structural interface — any frozen dataclass with these two methods
    (traceable in ``client_id``) plugs into the engine's data path."""

    def source_weights(self, key: jax.Array, client_id,
                       num_sources: int) -> jax.Array: ...

    def domain_of(self, client_id): ...


@dataclasses.dataclass(frozen=True)
class IIDPartition:
    seed: int = 0

    def source_weights(self, key, client_id, num_sources):
        del client_id
        return jnp.full((num_sources,), 1.0 / num_sources)

    def domain_of(self, client_id):
        return 0


@dataclasses.dataclass(frozen=True)
class DirichletPartition:
    alpha: float = 0.3
    seed: int = 0

    def source_weights(self, key, client_id, num_sources):
        kc = jax.random.fold_in(jax.random.fold_in(key, self.seed), client_id)
        return jax.random.dirichlet(kc, jnp.full((num_sources,), self.alpha))

    def domain_of(self, client_id):
        return 0


@dataclasses.dataclass(frozen=True)
class ShardPartition:
    shards_per_client: int = 2
    seed: int = 0

    def source_weights(self, key, client_id, num_sources):
        kc = jax.random.fold_in(jax.random.fold_in(key, self.seed), client_id)
        scores = jax.random.uniform(kc, (num_sources,))
        ranks = jnp.argsort(jnp.argsort(scores))  # exact-k selection
        held = ranks < self.shards_per_client
        return held / jnp.maximum(held.sum(), 1)

    def domain_of(self, client_id):
        return 0


@dataclasses.dataclass(frozen=True)
class DomainPartition:
    """Clients striped across ``num_domains`` task domains; within a domain
    sources mix by an inner partitioner (default IID)."""

    num_domains: int = 2
    inner: Partitioner = IIDPartition()

    def source_weights(self, key, client_id, num_sources):
        return self.inner.source_weights(key, client_id, num_sources)

    def domain_of(self, client_id):
        return client_id % self.num_domains


def make_partitioned_batch_fn(
    task: FrameTask,
    part: Partitioner,
    batch_size: int,
    num_sources: int = 16,
):
    """Engine-compatible ``data_fn(client_id, round_index, step) -> batch``.

    Per example: draw a source from the client's mixing weights, add that
    source's fixed bias vector to the frames (scaled by
    ``task.speaker_bias``), label with the client's domain probe.  Pure in
    (task.seed, part, client_id, round, step) and traceable in all three
    call arguments — the engine vmaps it over the cohort axis.
    """
    src_key = jax.random.PRNGKey(task.seed + 5)
    # fixed per-source signatures — the heterogeneity the clients disagree on
    source_bias = jax.random.normal(src_key, (num_sources, task.d_in))

    def data_fn(client_id, round_index, step):
        k = jax.random.fold_in(
            jax.random.fold_in(
                jax.random.fold_in(jax.random.PRNGKey(task.seed + 6),
                                   client_id),
                round_index,
            ),
            step,
        )
        kf, ks = jax.random.split(k)
        frames = jax.random.normal(kf, (batch_size, task.seq_len, task.d_in))
        w = part.source_weights(jax.random.PRNGKey(task.seed + 7), client_id,
                                num_sources)
        srcs = jax.random.categorical(
            ks, jnp.log(w + 1e-9), shape=(batch_size,)
        )
        frames = frames + task.speaker_bias * source_bias[srcs][:, None, :]
        probe = task.probe(part.domain_of(client_id))
        c = task.context
        padded = jnp.pad(frames, ((0, 0), (c, c), (0, 0)))
        windows = jnp.concatenate(
            [padded[:, i: i + task.seq_len] for i in range(2 * c + 1)],
            axis=-1,
        )
        labels = jnp.argmax(windows @ probe, axis=-1)
        return dict(frames=frames, labels=labels.astype(jnp.int32))

    return data_fn
