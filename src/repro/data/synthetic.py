"""Synthetic learnable tasks with IID / non-IID client partitions.

LibriSpeech/Multi-Domain are not available offline (DESIGN.md §2): the
convergence benchmarks instead compare FP32-vs-OMC loss curves on
deterministic synthetic tasks that a small model can actually learn, so the
quantization-error effects the paper measures (stability, accuracy gap) are
visible.

  * :class:`LMTask` — a random first-order Markov chain over the vocab.
    Per-client non-IIDness re-weights the transition rows with a
    client-specific Dirichlet draw (the "partition by speaker" analogue).
  * :class:`FrameTask` — synthetic ASR: frame embeddings whose labels are
    the argmax of a fixed random linear probe over a local context window;
    non-IID clients add a per-speaker bias vector to the frames; a second
    "domain" uses a different probe (the MD-dataset domain-adaptation
    analogue).

Everything is a pure function of (seed, client, round, step) — restart-safe
and reproducible across hosts, which checkpoint/restart tests rely on.
"""

from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass(frozen=True)
class Partitioner:
    """Client data distribution control."""

    num_clients: int
    iid: bool = True
    alpha: float = 0.3  # Dirichlet concentration for non-IID skew


# ---------------------------------------------------------------------------
# Language-model task (token streams)
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class LMTask:
    vocab: int
    seq_len: int
    part: Partitioner
    seed: int = 0
    temperature: float = 1.5

    def _logits(self) -> jax.Array:
        k = jax.random.PRNGKey(self.seed)
        return jax.random.normal(k, (self.vocab, self.vocab)) * self.temperature

    def client_logits(self, client_id) -> jax.Array:
        base = self._logits()
        if self.part.iid:
            return base
        kc = jax.random.fold_in(jax.random.PRNGKey(self.seed + 1), client_id)
        # per-client sparse re-weighting of next-token preferences
        bias = jnp.log(
            jax.random.dirichlet(kc, jnp.full((self.vocab,), self.part.alpha))
            + 1e-8
        )
        return base + bias[None, :]

    def batch(self, client_id, round_index, step, batch_size: int):
        return lm_batch(self, client_id, round_index, step, batch_size)


def lm_batch(task: LMTask, client_id, round_index, step, batch_size: int):
    """Sample [B, S+1] Markov tokens -> {tokens, labels} (next-token LM)."""
    logits = task.client_logits(client_id)
    k = jax.random.fold_in(
        jax.random.fold_in(
            jax.random.fold_in(jax.random.PRNGKey(task.seed + 2), client_id),
            round_index,
        ),
        step,
    )
    k0, kseq = jax.random.split(k)
    first = jax.random.randint(k0, (batch_size,), 0, task.vocab)

    def gen(tok, kk):
        nxt = jax.random.categorical(kk, logits[tok])
        return nxt, nxt

    keys = jax.random.split(kseq, task.seq_len)
    _, rest = jax.lax.scan(gen, first, keys)
    seq = jnp.concatenate([first[None], rest], 0).T  # [B, S+1]
    return dict(tokens=seq[:, :-1], labels=seq[:, 1:])


def make_lm_task(vocab=256, seq_len=64, num_clients=16, iid=True,
                 alpha=0.3, seed=0) -> LMTask:
    return LMTask(vocab, seq_len, Partitioner(num_clients, iid, alpha), seed)


# ---------------------------------------------------------------------------
# Frame-classification task (synthetic ASR)
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class FrameTask:
    d_in: int
    n_classes: int
    seq_len: int
    part: Partitioner
    seed: int = 0
    domain: int = 0  # domain id: different probe = different domain (MD)
    context: int = 2  # label depends on +-context frames
    speaker_bias: float = 1.0  # non-IID frame shift magnitude

    def probe(self, domain=None) -> jax.Array:
        """Label probe for ``domain`` (default: the task's own).  ``domain``
        may be traced — partitioners route clients to domains inside the
        vectorized engine's program (repro.data.partition)."""
        d = self.domain if domain is None else domain
        k = jax.random.fold_in(jax.random.PRNGKey(self.seed + 10), d)
        return jax.random.normal(
            k, (self.d_in * (2 * self.context + 1), self.n_classes)
        )

    def batch(self, client_id, round_index, step, batch_size: int):
        return frame_batch(self, client_id, round_index, step, batch_size)


def frame_batch(task: FrameTask, client_id, round_index, step, batch_size: int):
    k = jax.random.fold_in(
        jax.random.fold_in(
            jax.random.fold_in(jax.random.PRNGKey(task.seed + 3), client_id),
            round_index,
        ),
        step,
    )
    frames = jax.random.normal(k, (batch_size, task.seq_len, task.d_in))
    if not task.part.iid:
        kb = jax.random.fold_in(jax.random.PRNGKey(task.seed + 4), client_id)
        frames = frames + task.speaker_bias * jax.random.normal(
            kb, (task.d_in,)
        )
    # window the frames and probe for labels
    c = task.context
    padded = jnp.pad(frames, ((0, 0), (c, c), (0, 0)))
    windows = jnp.concatenate(
        [padded[:, i : i + task.seq_len] for i in range(2 * c + 1)], axis=-1
    )
    labels = jnp.argmax(windows @ task.probe(), axis=-1)
    return dict(frames=frames, labels=labels.astype(jnp.int32))


def make_frame_task(d_in=16, n_classes=32, seq_len=48, num_clients=16,
                    iid=True, alpha=0.3, seed=0, domain=0) -> FrameTask:
    return FrameTask(d_in, n_classes, seq_len,
                     Partitioner(num_clients, iid, alpha), seed, domain)
