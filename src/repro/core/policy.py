"""Which parameters get quantized — 'weight matrices only' (paper §2.4).

The paper found normalization scales/biases quantization-sensitive while the
weight matrices of matmul-bearing layers (>=99.8% of Conformer parameters) are
robust.  The default policy therefore selects leaves with ndim >= 2 (weight
matrices, embedding tables, conv kernels) and excludes everything matching an
exclusion regex (used e.g. for RG-LRU recurrence parameters, see DESIGN.md §6).

The policy is shared by every transport compressor in the strategy zoo
(DESIGN.md §11): ``repro.compress.encode_tree`` applies any
``CompressionStrategy`` under this same selection, so top-k / ternary /
pipeline payloads compress exactly the variables OMC would and the
quality-vs-wire-bytes comparisons stay like-for-like.
"""

from __future__ import annotations

import dataclasses
import re
from typing import Any, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp


def path_str(path) -> str:
    """Render a jax tree path as 'a/b/0/c'."""
    parts = []
    for p in path:
        if hasattr(p, "key"):
            parts.append(str(p.key))
        elif hasattr(p, "idx"):
            parts.append(str(p.idx))
        elif hasattr(p, "name"):
            parts.append(str(p.name))
        else:
            parts.append(str(p))
    return "/".join(parts)


@dataclasses.dataclass(frozen=True)
class QuantizePolicy:
    """Selects quantizable variables by shape and name.

    weights_only: if True, only leaves with ndim >= min_ndim are candidates.
    min_ndim:     minimum rank for the weights-only rule (2 = matrices).
    min_size:     skip tiny variables (their s/b overhead isn't worth it).
    exclude_re:   path regexes never quantized (sensitive params).
    include_re:   if set, only matching paths are candidates.
    """

    weights_only: bool = True
    min_ndim: int = 2
    min_size: int = 256
    exclude_re: Tuple[str, ...] = ()
    include_re: Optional[Tuple[str, ...]] = None

    def selects(self, path: str, leaf: Any) -> bool:
        if not hasattr(leaf, "shape") or not hasattr(leaf, "dtype"):
            return False
        if not jnp.issubdtype(leaf.dtype, jnp.floating):
            return False
        if self.weights_only and leaf.ndim < self.min_ndim:
            return False
        if leaf.size < self.min_size:
            return False
        for pat in self.exclude_re:
            if re.search(pat, path):
                return False
        if self.include_re is not None:
            return any(re.search(p, path) for p in self.include_re)
        return True


def quantizable_names(params, policy: QuantizePolicy) -> List[str]:
    """Deterministically ordered names of the selected leaves."""
    leaves = jax.tree_util.tree_flatten_with_path(params)[0]
    return [path_str(p) for p, leaf in leaves if policy.selects(path_str(p), leaf)]


def selection_mask_tree(params, policy: QuantizePolicy):
    """Pytree of python bools: True where the policy selects the leaf."""
    return jax.tree_util.tree_map_with_path(
        lambda p, leaf: policy.selects(path_str(p), leaf), params
    )


def coverage(params, policy: QuantizePolicy) -> float:
    """Fraction of parameters (by count) selected by the policy."""
    sel = tot = 0
    for p, leaf in jax.tree_util.tree_flatten_with_path(params)[0]:
        if not hasattr(leaf, "size"):
            continue
        tot += leaf.size
        if policy.selects(path_str(p), leaf):
            sel += leaf.size
    return sel / max(tot, 1)
