"""Exact-width bit packing of minifloat codes into a uint32 bitstream.

The container dtype used by the codec (uint8/16/32) wastes padding bits for
odd widths like 11 (S1E3M7) or 19 (S1E4M14).  On the wire — the federated
server<->client transport — OMC sends the exact ``ceil(n * bits / 32)`` words.
This module implements the pack/unpack pair as vectorized JAX ops.

Packing trick: each w-bit field (w <= 32) spans at most two consecutive words.
Contributions from different fields to the same word occupy *disjoint* bits,
so a scatter-ADD of the low/high word parts is equivalent to a scatter-OR.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from .formats import FloatFormat


def packed_words(n: int, width: int) -> int:
    return -(-n * width // 32)


def pack(codes: jax.Array, width: int) -> jax.Array:
    """Pack ``codes`` (any uint dtype, values < 2**width) into uint32 words."""
    if not (1 <= width <= 32):
        raise ValueError(f"width must be in [1, 32], got {width}")
    flat = codes.reshape(-1).astype(jnp.uint32)
    n = flat.shape[0]
    nwords = packed_words(n, width)
    offs = (jnp.arange(n, dtype=jnp.uint32) * np.uint32(width))
    word = (offs >> 5).astype(jnp.int32)
    sh = offs & np.uint32(31)
    low = (flat << sh) & np.uint32(0xFFFFFFFF)
    # field >> (32 - sh) is UB when sh == 0; (f >> (31 - sh)) >> 1 is safe.
    high = (flat >> (np.uint32(31) - sh)) >> np.uint32(1)
    out = jnp.zeros((nwords + 1,), jnp.uint32)  # +1 slot absorbs last high word
    out = out.at[word].add(low)
    out = out.at[word + 1].add(high)
    return out[:nwords]


def unpack(words: jax.Array, width: int, n: int) -> jax.Array:
    """Inverse of :func:`pack`: recover ``n`` codes of ``width`` bits."""
    if not (1 <= width <= 32):
        raise ValueError(f"width must be in [1, 32], got {width}")
    w = jnp.concatenate([words.astype(jnp.uint32), jnp.zeros((1,), jnp.uint32)])
    offs = (jnp.arange(n, dtype=jnp.uint32) * np.uint32(width))
    word = (offs >> 5).astype(jnp.int32)
    sh = offs & np.uint32(31)
    lo = w[word] >> sh
    hi = (w[word + 1] << (np.uint32(31) - sh)) << np.uint32(1)
    mask = np.uint32((1 << width) - 1) if width < 32 else np.uint32(0xFFFFFFFF)
    return (lo | hi) & mask


def packed_bytes(n: int, fmt: FloatFormat) -> int:
    """Exact wire bytes for ``n`` values of ``fmt`` (uint32-word granularity)."""
    return 4 * packed_words(n, fmt.bits)


def packed_bytes_width(n: int, width: int) -> int:
    """Exact wire bytes for ``n`` values of an arbitrary bit width (e.g. the
    2-bit ternary codes of ``repro.compress.ternary``)."""
    return 4 * packed_words(n, width)
