"""Exact-width bit packing of minifloat codes into a uint32 bitstream.

The container dtype used by the codec (uint8/16/32) wastes padding bits for
odd widths like 11 (S1E3M7) or 19 (S1E4M14).  On the wire — the federated
server<->client transport — OMC sends the exact ``ceil(n * bits / 32)`` words.

The public :func:`pack` / :func:`unpack` dispatch through
``repro.kernels.ops`` — compiled Pallas superblock kernels on TPU
(``kernels/bitpack.py``), the pure-jnp bodies below elsewhere.  Both emit the
same canonical bitstream (little-endian bit order within uint32 words, zero
tail padding), so the two paths are bit-identical — property-tested in
tests/test_bitpack.py.  Bit-layout contract: DESIGN.md §13.

Packing trick (jnp oracle): each w-bit field (w <= 32) spans at most two
consecutive words.  Contributions from different fields to the same word
occupy *disjoint* bits, so a scatter-ADD of the low/high word parts is
equivalent to a scatter-OR.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from .formats import FloatFormat


def packed_words(n: int, width: int) -> int:
    return -(-n * width // 32)


def _check_width(width: int) -> None:
    if not (1 <= width <= 32):
        raise ValueError(f"width must be in [1, 32], got {width}")


def _pack_jnp(codes: jax.Array, width: int) -> jax.Array:
    """jnp oracle for :func:`pack` (the CPU path of ``kernels.ops.pack_bits``)."""
    _check_width(width)
    flat = codes.reshape(-1).astype(jnp.uint32)
    n = flat.shape[0]
    nwords = packed_words(n, width)
    offs = (jnp.arange(n, dtype=jnp.uint32) * np.uint32(width))
    word = (offs >> 5).astype(jnp.int32)
    sh = offs & np.uint32(31)
    low = (flat << sh) & np.uint32(0xFFFFFFFF)
    # field >> (32 - sh) is UB when sh == 0; (f >> (31 - sh)) >> 1 is safe.
    high = (flat >> (np.uint32(31) - sh)) >> np.uint32(1)
    out = jnp.zeros((nwords + 1,), jnp.uint32)  # +1 slot absorbs last high word
    out = out.at[word].add(low)
    out = out.at[word + 1].add(high)
    return out[:nwords]


def _unpack_jnp(words: jax.Array, width: int, n: int) -> jax.Array:
    """jnp oracle for :func:`unpack`."""
    _check_width(width)
    w = jnp.concatenate([words.astype(jnp.uint32), jnp.zeros((1,), jnp.uint32)])
    offs = (jnp.arange(n, dtype=jnp.uint32) * np.uint32(width))
    word = (offs >> 5).astype(jnp.int32)
    sh = offs & np.uint32(31)
    lo = w[word] >> sh
    hi = (w[word + 1] << (np.uint32(31) - sh)) << np.uint32(1)
    mask = np.uint32((1 << width) - 1) if width < 32 else np.uint32(0xFFFFFFFF)
    return (lo | hi) & mask


def pack(codes: jax.Array, width: int) -> jax.Array:
    """Pack ``codes`` (any uint dtype, values < 2**width) into uint32 words.

    Dispatches via ``kernels.ops.pack_bits``: Pallas on TPU, the jnp oracle
    elsewhere — bit-identical either way.
    """
    _check_width(width)
    from repro.kernels import ops  # deferred: kernels imports this module

    return ops.pack_bits(codes, width)


def unpack(words: jax.Array, width: int, n: int) -> jax.Array:
    """Inverse of :func:`pack`: recover ``n`` codes of ``width`` bits."""
    _check_width(width)
    from repro.kernels import ops  # deferred: kernels imports this module

    return ops.unpack_bits(words, width, int(n))


def packed_bytes(n: int, fmt: FloatFormat) -> int:
    """Exact wire bytes for ``n`` values of ``fmt`` (uint32-word granularity)."""
    return 4 * packed_words(n, fmt.bits)


def packed_bytes_width(n: int, width: int) -> int:
    """Exact wire bytes for ``n`` values of an arbitrary bit width (e.g. the
    2-bit ternary codes of ``repro.compress.ternary``)."""
    return 4 * packed_words(n, width)
