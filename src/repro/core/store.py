"""Compressed parameter storage (paper Fig. 1).

``CompressedVariable`` holds one variable in OMC storage form: the minifloat
bitfield codes (smallest uint container — the in-HBM resident form), plus the
per-variable transformation scalars ``s, b``.  A model is a pytree in which
policy-selected leaves are ``CompressedVariable`` and the rest stay float32 —
``compress_tree`` / ``decompress_tree`` convert in bulk, and byte accounting
backs the paper's memory/communication tables.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np

from . import packing
from .formats import FloatFormat, decode, encode, value_quantize
from .policy import QuantizePolicy, path_str
from .pvt import pvt_apply, pvt_solve, pvt_solve_fast


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class CompressedVariable:
    """One variable in OMC storage form."""

    codes: jax.Array  # uint container, original shape
    s: jax.Array  # f32 scalar — PVT scale
    b: jax.Array  # f32 scalar — PVT bias
    fmt: FloatFormat = dataclasses.field(metadata=dict(static=True))

    @property
    def shape(self):
        return self.codes.shape

    @property
    def size(self):
        return self.codes.size

    def dequantize(self) -> jax.Array:
        return pvt_apply(decode(self.codes, self.fmt), self.s, self.b)


def compress_variable(
    v: jax.Array, fmt: FloatFormat, *, pvt: bool = True, batch_axes: int = 0,
    fast: bool = False,
) -> CompressedVariable:
    """Quantize one variable to OMC storage form.

    batch_axes > 0 treats the leading axes as stacked independent variables
    (layer-stacked scan params / per-expert matrices): s, b are solved per
    stacked entry with the distributed-friendly solver.  ``fast`` selects
    that solver even for batch_axes == 0 — the distributed round must use
    it: the exact compensated solver lowers to a sequential chunk scan
    (~130k iterations for a 100M-element embedding), which is both a
    runtime and a compile-graph disaster under pjit.  The compensated
    solver remains the default for the simulation / numerics path.
    """
    vq = value_quantize(v, fmt)
    if pvt and (batch_axes or fast):
        s, b = pvt_solve_fast(v, vq, batch_axes)
    elif pvt:
        s, b = pvt_solve(v, vq)
    else:
        s, b = jnp.float32(1.0), jnp.float32(0.0)
    return CompressedVariable(encode(vq, fmt, quantize=False), s, b, fmt)


def is_compressed(x: Any) -> bool:
    return isinstance(x, CompressedVariable)


def compress_tree(
    params,
    fmt: FloatFormat,
    policy: QuantizePolicy,
    *,
    pvt: bool = True,
):
    """Compress the policy-selected leaves; the rest pass through unchanged."""

    def f(path, leaf):
        if policy.selects(path_str(path), leaf):
            return compress_variable(leaf, fmt, pvt=pvt)
        return leaf

    return jax.tree_util.tree_map_with_path(f, params)


def decompress_tree(ctree):
    return jax.tree_util.tree_map(
        lambda x: x.dequantize() if is_compressed(x) else x,
        ctree,
        is_leaf=is_compressed,
    )


# ---------------------------------------------------------------------------
# Byte accounting — backs the paper's "Parameter Memory / Communication" cols.
# ---------------------------------------------------------------------------

_PVT_OVERHEAD_BYTES = 8  # s and b, FP32 each


def tree_bytes_report(
    params,
    fmt: FloatFormat,
    policy: QuantizePolicy,
    *,
    fraction: float = 1.0,
) -> Dict[str, Any]:
    """Theoretical parameter memory / communication for a model under OMC.

    fraction < 1 models Partial Parameter Quantization: the expected bytes
    when each client quantizes `fraction` of the selected variables and keeps
    the rest in FP32 (paper §3.5.3 'increases the average bitwidth by ~2
    bits').  Three sizes are reported per storage flavor:
      fp32_bytes       everything FP32 (the baseline),
      container_bytes  codes in their uint8/16/32 containers (in-HBM form),
      packed_bytes     exact bitstream (the wire form).
    """
    n_sel = n_tot = 0
    container = packed = fp32 = overhead = 0
    num_vars = 0
    for path, leaf in jax.tree_util.tree_flatten_with_path(params)[0]:
        if not hasattr(leaf, "size"):
            continue
        sz = int(leaf.size)
        n_tot += sz
        fp32 += 4 * sz
        if policy.selects(path_str(path), leaf):
            n_sel += sz
            num_vars += 1
            container += fmt.container_bytes_per_value * sz
            packed += packing.packed_bytes(sz, fmt)
            overhead += _PVT_OVERHEAD_BYTES
        else:
            container += 4 * sz
            packed += 4 * sz
    # PPQ expectation: (1-fraction) of the selected vars stay FP32 this round.
    q = float(fraction)
    container_ppq = q * container + (1 - q) * fp32
    packed_ppq = q * packed + (1 - q) * fp32
    return dict(
        fmt=fmt.name,
        num_params=n_tot,
        num_quantizable=n_sel,
        num_quantizable_vars=num_vars,
        coverage=n_sel / max(n_tot, 1),
        fp32_bytes=fp32,
        container_bytes=int(container_ppq) + overhead,
        packed_bytes=int(packed_ppq) + overhead,
        container_ratio=(container_ppq + overhead) / max(fp32, 1),
        packed_ratio=(packed_ppq + overhead) / max(fp32, 1),
        avg_bits_packed=8 * (packed_ppq + overhead) / max(n_tot, 1),
    )


def pack_for_transport(cv: CompressedVariable) -> Dict[str, Any]:
    """Exact wire encoding of one compressed variable (uint32 bitstream)."""
    words = packing.pack(cv.codes, cv.fmt.bits)
    return dict(
        words=words,
        s=cv.s,
        b=cv.b,
        fmt=cv.fmt.name,
        shape=tuple(cv.codes.shape),
        nbytes=int(words.size) * 4 + _PVT_OVERHEAD_BYTES,
    )


def unpack_from_transport(blob: Dict[str, Any]) -> CompressedVariable:
    fmt = FloatFormat.parse(blob["fmt"])
    n = int(np.prod(blob["shape"])) if blob["shape"] else 1
    codes = packing.unpack(blob["words"], fmt.bits, n).reshape(blob["shape"])
    return CompressedVariable(
        codes.astype(fmt.container_dtype), blob["s"], blob["b"], fmt
    )
