"""OMC core: the paper's contribution as a composable JAX module."""

from .formats import FP32, FloatFormat, decode, encode, qdq, qdq_ste, value_quantize
from .omc import (
    OMCConfig,
    bytes_report,
    compress,
    decompress,
    effective_params,
    qdq_pvt_leaf,
)
from .packing import pack, packed_bytes, packed_words, unpack
from .partial import ppq_mask, ppq_masks_batch
from .policy import QuantizePolicy, coverage, quantizable_names, selection_mask_tree
from .pvt import pvt_apply, pvt_solve, pvt_solve_fast, qdq_pvt
from .store import (
    CompressedVariable,
    compress_tree,
    compress_variable,
    decompress_tree,
    is_compressed,
    pack_for_transport,
    tree_bytes_report,
    unpack_from_transport,
)

__all__ = [
    "FP32",
    "FloatFormat",
    "OMCConfig",
    "QuantizePolicy",
    "CompressedVariable",
    "bytes_report",
    "compress",
    "compress_tree",
    "compress_variable",
    "coverage",
    "decode",
    "decompress",
    "decompress_tree",
    "effective_params",
    "encode",
    "is_compressed",
    "pack",
    "pack_for_transport",
    "packed_bytes",
    "packed_words",
    "ppq_mask",
    "ppq_masks_batch",
    "pvt_apply",
    "pvt_solve",
    "pvt_solve_fast",
    "qdq",
    "qdq_pvt",
    "qdq_pvt_leaf",
    "qdq_ste",
    "quantizable_names",
    "selection_mask_tree",
    "tree_bytes_report",
    "unpack",
    "unpack_from_transport",
    "value_quantize",
]
