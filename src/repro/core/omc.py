"""Online Model Compression — public API (paper §2).

``OMCConfig`` bundles the four mechanisms:
  * minifloat format (``S1E3M7`` etc.) — §2.2
  * per-variable transformation — §2.3
  * weights-only policy — §2.4
  * partial parameter quantization (fraction < 1) — §2.5

Two execution modes:
  * ``effective_params`` — *simulation* mode: FP32 master weights pass through
    quantize→dequantize(+PVT) per (round, client) PPQ mask.  Used for
    convergence experiments and as the numerics reference.
  * ``compress_tree``/``decompress_tree`` (re-exported from ``store``) —
    *storage* mode: weights live as uint bitfields and are decompressed
    layer-by-layer under remat.  Used by the distributed runtime.
"""

from __future__ import annotations

import dataclasses
from typing import Optional, Sequence, Tuple

import jax
import jax.numpy as jnp

from .formats import FloatFormat, value_quantize
from .partial import ppq_mask
from .policy import QuantizePolicy, path_str, quantizable_names
from .pvt import pvt_apply, pvt_solve
from .store import (
    CompressedVariable,
    compress_tree,
    compress_variable,
    decompress_tree,
    tree_bytes_report,
)

DEFAULT_POLICY = QuantizePolicy()


@dataclasses.dataclass(frozen=True)
class OMCConfig:
    """Configuration of Online Model Compression."""

    fmt: FloatFormat = FloatFormat(3, 7)  # S1E3M7 — the paper's 11-bit format
    pvt: bool = True
    quantize_fraction: float = 0.9  # PPQ; 1.0 = all selected vars quantized
    policy: QuantizePolicy = DEFAULT_POLICY
    ppq_seed: int = 1729  # deterministic PPQ stream

    @classmethod
    def parse(cls, fmt: str, **kw) -> "OMCConfig":
        return cls(fmt=FloatFormat.parse(fmt), **kw)

    @property
    def enabled(self) -> bool:
        return not self.fmt.is_identity or self.quantize_fraction < 1.0

    def ppq_key(self) -> jax.Array:
        return jax.random.PRNGKey(self.ppq_seed)

    def strategy(self):
        """This config as a zoo :class:`repro.compress.OMCQuantStrategy` —
        the pluggable-strategy view of the paper's path (DESIGN.md §11).
        Lazy import: ``core`` stays importable without the zoo."""
        from repro.compress import OMCQuantStrategy

        return OMCQuantStrategy(fmt=self.fmt, pvt=self.pvt)


def qdq_pvt_leaf(v: jax.Array, cfg: OMCConfig) -> jax.Array:
    """quantize→dequantize one variable with optional PVT correction."""
    vq = value_quantize(v, cfg.fmt)
    if not cfg.pvt:
        return vq
    s, b = pvt_solve(v, vq)
    return pvt_apply(vq, s, b)


def effective_params(
    params,
    cfg: OMCConfig,
    round_index=0,
    client_id=0,
):
    """Simulation-mode view of the params a client would train on.

    Applies qdq(+PVT) to each policy-selected variable, gated by the
    per-(round, client) PPQ mask.  round_index/client_id may be traced.
    """
    if not cfg.enabled:
        return params
    names = quantizable_names(params, cfg.policy)
    if not names:
        return params
    mask = ppq_mask(
        cfg.ppq_key(), round_index, client_id, len(names), cfg.quantize_fraction
    )
    index = {n: i for i, n in enumerate(names)}

    def f(path, leaf):
        name = path_str(path)
        i = index.get(name)
        if i is None:
            return leaf
        return jnp.where(mask[i], qdq_pvt_leaf(leaf, cfg), leaf)

    return jax.tree_util.tree_map_with_path(f, params)


def compress(params, cfg: OMCConfig):
    """Storage-mode compression of a parameter pytree (full selection)."""
    return compress_tree(params, cfg.fmt, cfg.policy, pvt=cfg.pvt)


def decompress(ctree):
    return decompress_tree(ctree)


def bytes_report(params, cfg: OMCConfig):
    return tree_bytes_report(
        params, cfg.fmt, cfg.policy, fraction=cfg.quantize_fraction
    )
