"""Partial Parameter Quantization (paper §2.5).

Each client quantizes only a subset (default 90%) of the quantizable weight
matrices; the selection varies per federated round and per client, so the
server keeps receiving full-precision updates of every parameter from the
clients that didn't quantize it.

The selection is an *exact-fraction* pseudo-random choice (rank of per-variable
uniform scores), deterministic in (seed, round, client): any participant — or a
restarted job — recomputes the identical mask, which is what makes the
transport protocol stateless and checkpoint/restart bit-exact.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def num_selected(num_vars: int, fraction: float) -> int:
    return int(round(num_vars * float(fraction)))


def ppq_mask(
    seed_key: jax.Array,
    round_index,
    client_id,
    num_vars: int,
    fraction: float,
) -> jax.Array:
    """bool[num_vars]: True = quantize this variable for this (round, client).

    ``round_index`` / ``client_id`` may be traced int32 scalars (fold_in
    accepts traced values), so the mask can be computed inside a jitted round.
    """
    if fraction >= 1.0:
        return jnp.ones((num_vars,), bool)
    if fraction <= 0.0:
        return jnp.zeros((num_vars,), bool)
    k = num_selected(num_vars, fraction)
    key = jax.random.fold_in(jax.random.fold_in(seed_key, round_index), client_id)
    scores = jax.random.uniform(key, (num_vars,))
    ranks = jnp.argsort(jnp.argsort(scores))  # rank of each score
    return ranks < k


def ppq_masks_batch(
    seed_key: jax.Array,
    round_index,
    client_ids: jax.Array,
    num_vars: int,
    fraction: float,
) -> jax.Array:
    """bool[num_clients, num_vars] — vmapped per-client masks."""
    return jax.vmap(
        lambda c: ppq_mask(seed_key, round_index, c, num_vars, fraction)
    )(client_ids)
