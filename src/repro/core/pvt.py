"""Per-Variable Transformation (paper §2.3).

After dequantization OMC applies an affine correction ``V̄ = s·Ṽ + b`` per
variable (weight matrix), with ``(s, b)`` the least-squares minimizer of
``‖s·Ṽ + b − V‖₂²`` where ``V`` is the pre-quantization FP32 variable and
``Ṽ = dequant(quant(V))``.

Closed form (ordinary least squares of V on Ṽ):

    s = (n·ΣVṼ − ΣV·ΣṼ) / (n·ΣṼ² − (ΣṼ)²)
    b = (ΣV − s·ΣṼ) / n

Note: the paper's printed denominator reads ``n·ΣV² − (ΣṼ)²`` — a typo; the
least-squares solution (and the paper's own degeneracy discussion) require
``n·ΣṼ² − (ΣṼ)²`` = n²·Var(Ṽ).  Degenerate case (constant Ṽ): s = 1, and b
then absorbs the mean error, matching the paper's prescription.

The paper computes the sums in float64 and stores s, b as FP32.  X64 is
disabled under JAX by default, so we use compensated (two-float / Kahan-style)
accumulation to get float64-grade sums while staying in f32 — validated in
tests against numpy float64.
"""

from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp
import numpy as np

from .formats import FloatFormat, value_quantize


def _comp_sum(x: jax.Array) -> Tuple[jax.Array, jax.Array]:
    """Compensated sum: returns (head, tail) with head+tail ≈ float64 sum.

    Splits the pairwise f32 reduction error by summing per-row partials with a
    TwoSum cascade.  x is 1-D.
    """
    n = x.shape[0]
    # Pad to a multiple of 1024 and reduce in chunks: per-chunk f32 sums are
    # accurate (pairwise within jnp.sum), the cross-chunk cascade is TwoSum.
    chunk = 1024
    pad = (-n) % chunk
    xp = jnp.concatenate([x, jnp.zeros((pad,), x.dtype)]) if pad else x
    partials = jnp.sum(xp.reshape(-1, chunk), axis=1)

    def two_sum(carry, p):
        s, c = carry
        t = s + p
        # Neumaier compensation
        c = c + jnp.where(
            jnp.abs(s) >= jnp.abs(p), (s - t) + p, (p - t) + s
        )
        return (t, c), None

    (s, c), _ = jax.lax.scan(two_sum, (jnp.float32(0), jnp.float32(0)), partials)
    return s, c


def _csum(x: jax.Array) -> jax.Array:
    s, c = _comp_sum(x)
    return s + c


def pvt_solve(v: jax.Array, v_tilde: jax.Array) -> Tuple[jax.Array, jax.Array]:
    """Solve for (s, b) minimizing ‖s·Ṽ + b − V‖₂².  Returns f32 scalars."""
    vf = v.reshape(-1).astype(jnp.float32)
    qf = v_tilde.reshape(-1).astype(jnp.float32)
    n = jnp.float32(vf.shape[0])
    s_v = _csum(vf)
    s_q = _csum(qf)
    s_vq = _csum(vf * qf)
    s_qq = _csum(qf * qf)
    den = n * s_qq - s_q * s_q
    num = n * s_vq - s_v * s_q
    degenerate = den <= 0  # Var(Ṽ) == 0 (all elements equal), or numerically so
    s = jnp.where(degenerate, jnp.float32(1.0), num / jnp.where(degenerate, 1.0, den))
    b = (s_v - s * s_q) / n
    return s.astype(jnp.float32), b.astype(jnp.float32)


def pvt_solve_fast(
    v: jax.Array, v_tilde: jax.Array, batch_axes: int = 0
) -> Tuple[jax.Array, jax.Array]:
    """Distributed-friendly PVT solve: plain f32 sums, optional batch axes.

    The compensated-scan solver above is exact but lowers to a long
    sequential scan — unusable inside a 512-device pjit round.  XLA's tree
    reductions give ~log2(n)·eps relative error on the sums (~2e-6 at 10^8
    elements), far below what s/b need; tests bound the difference.

    With ``batch_axes=k`` the leading k axes are treated as independent
    variables (stacked layers / experts) and s, b come back with shape
    ``v.shape[:k] + (1,) * (v.ndim - k)`` — broadcastable for ``pvt_apply``.
    Sums reduce over the variable axes only, so sharded inputs reduce with
    tiny collectives under pjit.
    """
    vf = v.astype(jnp.float32)
    qf = v_tilde.astype(jnp.float32)
    axes = tuple(range(batch_axes, vf.ndim))
    n = jnp.float32(np.prod([vf.shape[a] for a in axes])) if axes else jnp.float32(1)
    s_v = jnp.sum(vf, axis=axes)
    s_q = jnp.sum(qf, axis=axes)
    s_vq = jnp.sum(vf * qf, axis=axes)
    s_qq = jnp.sum(qf * qf, axis=axes)
    den = n * s_qq - s_q * s_q
    num = n * s_vq - s_v * s_q
    degenerate = den <= 0
    s = jnp.where(degenerate, 1.0, num / jnp.where(degenerate, 1.0, den))
    b = (s_v - s * s_q) / n
    # scalars for whole-tensor solve (matches pvt_solve); broadcastable
    # [d0,..,dk-1, 1, ..] for batched solves
    shape = (vf.shape[:batch_axes] + (1,) * (vf.ndim - batch_axes)
             if batch_axes else ())
    return s.reshape(shape).astype(jnp.float32), b.reshape(shape).astype(jnp.float32)


def pvt_apply(v_tilde: jax.Array, s: jax.Array, b: jax.Array) -> jax.Array:
    """V̄ = s·Ṽ + b (s, b broadcast against Ṽ)."""
    return v_tilde * s + b


def qdq_pvt(v: jax.Array, fmt: FloatFormat) -> jax.Array:
    """Quantize-dequantize with the PVT correction applied (simulation path)."""
    vt = value_quantize(v, fmt)
    s, b = pvt_solve(v, vt)
    return pvt_apply(vt, s, b)
