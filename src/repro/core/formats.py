"""SxEyMz minifloat formats and the bitfield codec used by OMC.

The paper stores parameters as reduced-bitwidth floating point (sign /
exponent / mantissa), e.g. S1E3M7 (11 bits) or S1E4M14 (19 bits).  This module
implements:

  * ``FloatFormat`` — the format descriptor (parse/format "S1E3M7" strings).
  * ``value_quantize`` — round a float32 array to the nearest representable
    value of the format (round-to-nearest-even, flush-to-zero below the
    format's min normal, *saturating* at max normal).
  * ``encode`` / ``decode`` — exact conversion between representable float32
    values and the packed integer bitfield (stored in the smallest uint
    container; see ``packing.py`` for the exact-width bitstream).

Semantics notes (see DESIGN.md §2):
  * Subnormals of the *target* format are fully supported.  This matters for
    real weight tensors: S1E4 formats have min-normal 2**-6 ≈ 0.016, and a
    flush-to-zero quantizer would zero out a large share of typically
    initialized weights (std ~0.02) — training would collapse.  The paper's
    formats therefore must (and here do) extend down to the subnormal step
    2**(1 - bias - M).
  * ``jax.lax.reduce_precision(x, E, M)`` is the oracle for RNE on *normal*
    values, but it flushes target subnormals to zero and overflows to inf.
    ``value_quantize`` uses it for the normal range, a scaled
    round-half-even for the subnormal range, and clamps to ±max_normal
    (OMC storage must never hold inf).  For (5, 10) this reproduces the
    float16 cast bit-for-bit, subnormals included (tested).
  * The bitfield layout is IEEE-like: exponent bias ``2**(E-1)-1``, top
    exponent field reserved for inf/NaN (NaN is propagated so that a poisoned
    training state stays visible; inf is saturated away).
"""

from __future__ import annotations

import dataclasses
import re

import jax
import jax.numpy as jnp
import numpy as np

_FMT_RE = re.compile(r"^S1E(\d+)M(\d+)$")


@dataclasses.dataclass(frozen=True)
class FloatFormat:
    """A 1-sign / `exp_bits`-exponent / `mant_bits`-mantissa float format."""

    exp_bits: int
    mant_bits: int

    def __post_init__(self):
        if not (2 <= self.exp_bits <= 8):
            raise ValueError(f"exp_bits must be in [2, 8], got {self.exp_bits}")
        if not (1 <= self.mant_bits <= 23):
            raise ValueError(f"mant_bits must be in [1, 23], got {self.mant_bits}")

    # -- identity ----------------------------------------------------------
    @property
    def bits(self) -> int:
        return 1 + self.exp_bits + self.mant_bits

    @property
    def name(self) -> str:
        return f"S1E{self.exp_bits}M{self.mant_bits}"

    @classmethod
    def parse(cls, s: str) -> "FloatFormat":
        m = _FMT_RE.match(s.strip().upper())
        if not m:
            raise ValueError(f"bad float format {s!r}; expected e.g. 'S1E3M7'")
        return cls(int(m.group(1)), int(m.group(2)))

    # -- numeric range ------------------------------------------------------
    @property
    def bias(self) -> int:
        return (1 << (self.exp_bits - 1)) - 1

    @property
    def max_exp_field(self) -> int:
        """Largest exponent field for a *normal* value (top field = inf/NaN)."""
        return (1 << self.exp_bits) - 2

    @property
    def max_normal(self) -> float:
        return float(
            (2.0 - 2.0 ** (-self.mant_bits)) * 2.0 ** (self.max_exp_field - self.bias)
        )

    @property
    def min_normal(self) -> float:
        return float(2.0 ** (1 - self.bias))

    @property
    def subnormal_step(self) -> float:
        """Spacing of subnormals — the smallest positive representable value."""
        return float(2.0 ** (1 - self.bias - self.mant_bits))

    @property
    def container_dtype(self):
        if self.bits <= 8:
            return jnp.uint8
        if self.bits <= 16:
            return jnp.uint16
        return jnp.uint32

    @property
    def container_bytes_per_value(self) -> int:
        return jnp.dtype(self.container_dtype).itemsize

    @property
    def is_identity(self) -> bool:
        return self.exp_bits == 8 and self.mant_bits == 23


FP32 = FloatFormat(8, 23)


def _value_quantize_e8(x: jax.Array, fmt: FloatFormat) -> jax.Array:
    """Integer-bit RNE for exp_bits == 8 formats (bf16-family, incl. FP32).

    E8 formats share float32's exponent range, so their subnormals ARE f32
    subnormals — XLA CPU flushes those in float arithmetic (FTZ/DAZ), which
    breaks the float-path quantizer.  The classic add-half-and-truncate trick
    on the raw bits handles normals and subnormals uniformly and exactly.
    """
    sh = 23 - fmt.mant_bits
    xc = jnp.clip(x, -fmt.max_normal, fmt.max_normal)  # NaN propagates
    b = jax.lax.bitcast_convert_type(xc, jnp.uint32)
    lsb = (b >> sh) & np.uint32(1)
    rb = b + (np.uint32((1 << (sh - 1)) - 1) + lsb) if sh > 0 else b
    rb = rb & np.uint32(~((1 << sh) - 1) & 0xFFFFFFFF)
    out = jax.lax.bitcast_convert_type(rb, jnp.float32)
    # The carry can only round magnitudes upward within the clipped range
    # (max_normal has zero low bits), so no overflow to inf is possible.
    return jnp.where(jnp.isnan(x), x, out)


def value_quantize(x: jax.Array, fmt: FloatFormat) -> jax.Array:
    """Nearest representable value: RNE, subnormal-aware, saturating. f32->f32."""
    x = jnp.asarray(x, jnp.float32)
    if fmt.is_identity:
        return x
    if fmt.exp_bits == 8:
        return _value_quantize_e8(x, fmt)
    xc = jnp.clip(x, -fmt.max_normal, fmt.max_normal)  # NaN propagates
    normal = jax.lax.reduce_precision(xc, fmt.exp_bits, fmt.mant_bits)
    # Subnormal range: |x| < min_normal rounds (half-to-even) to a multiple of
    # the subnormal step.  For exp_bits <= 7 the step is a normal f32
    # (>= 2**-85), so the division/round/multiply chain is exact.
    step = np.float32(fmt.subnormal_step)
    sub = jnp.round(xc / step) * step
    return jnp.where(jnp.abs(xc) < fmt.min_normal, sub, normal)


def encode(x: jax.Array, fmt: FloatFormat, *, quantize: bool = True) -> jax.Array:
    """float32 -> bitfield in the format's container dtype.

    With ``quantize=True`` (default) the input is first rounded with
    ``value_quantize``; with ``quantize=False`` the caller asserts the values
    are already exactly representable (the repack is then exact).
    """
    if quantize:
        x = value_quantize(x, fmt)
    x = jnp.asarray(x, jnp.float32)
    y, z = fmt.exp_bits, fmt.mant_bits
    b32 = jax.lax.bitcast_convert_type(x, jnp.uint32)
    sign = b32 >> 31
    mag = b32 & np.uint32(0x7FFFFFFF)

    is_zero = mag == 0
    is_nan = mag > np.uint32(0x7F800000)

    e32 = (mag >> 23).astype(jnp.int32)
    ef = e32 - 127 + fmt.bias  # target exponent field (normals: 1..max_exp_field)
    m = (mag & np.uint32(0x7FFFFF)) >> (23 - z)

    sign_sh = sign << (y + z)
    normal = sign_sh | (ef.astype(jnp.uint32) << z) | m
    # Subnormal range (ef <= 0): mantissa field = |v| / subnormal_step, an
    # exact integer in [0, 2**z) for representable inputs.  Two sub-cases:
    #   * normal f32 input (e32 > 0): safe float division — for exp_bits <= 7
    #     the step is a normal f32, and E8 formats never hit this case (their
    #     exponent range equals f32's, so normal inputs map to normal codes).
    #   * f32-subnormal input (e32 == 0): float arithmetic is flushed on XLA
    #     CPU; the field is m32 >> (150 - bias - mant_bits) exactly (low bits
    #     are zero for representable inputs).
    absx = jax.lax.bitcast_convert_type(mag, jnp.float32)
    m_sub = jnp.round(absx / np.float32(fmt.subnormal_step)).astype(jnp.uint32)
    m_sub = jnp.minimum(m_sub, np.uint32((1 << z) - 1))
    sub_shift = 150 - fmt.bias - z  # >= 0 for every supported format
    m_sub_tiny = (mag >> min(sub_shift, 31)) if sub_shift < 32 else jnp.zeros_like(mag)
    m_sub = jnp.where(e32 == 0, m_sub_tiny, m_sub)
    subnormal = sign_sh | m_sub
    # Above max_normal: saturate (defensive; value_quantize already clamps).
    too_big = ef > fmt.max_exp_field
    max_code = sign_sh | np.uint32((fmt.max_exp_field << z) | ((1 << z) - 1))
    nan_code = sign_sh | np.uint32((((1 << y) - 1) << z) | (1 << max(z - 1, 0)))

    out = jnp.where(ef <= 0, subnormal, normal)
    out = jnp.where(too_big, max_code, out)
    out = jnp.where(is_zero, sign_sh, out)
    out = jnp.where(is_nan, nan_code, out)
    return out.astype(fmt.container_dtype)


def decode(code: jax.Array, fmt: FloatFormat) -> jax.Array:
    """bitfield -> float32 (exact for every code the format can hold)."""
    y, z = fmt.exp_bits, fmt.mant_bits
    c = jnp.asarray(code).astype(jnp.uint32)
    sign = (c >> (y + z)) & np.uint32(1)
    ef = (c >> z) & np.uint32((1 << y) - 1)
    m = c & np.uint32((1 << z) - 1)

    sign31 = sign << 31
    # Normal path: rebias exponent, shift mantissa up — exact bit assembly.
    nrm_bits = sign31 | ((ef + np.uint32(127 - fmt.bias)) << 23) | (m << (23 - z))
    nrm = jax.lax.bitcast_convert_type(nrm_bits, jnp.float32)
    # Target-format subnormals: m * 2**(1 - bias - mant_bits).
    if fmt.exp_bits == 8:
        # E8 subnormals ARE f32 subnormals — assemble the bits directly
        # (float arithmetic would be flushed to zero on XLA CPU).
        sub = jax.lax.bitcast_convert_type(sign31 | (m << (23 - z)), jnp.float32)
    else:
        # exp_bits <= 7: the step 2**(1-bias-z) >= 2**-85 is a normal f32, so
        # integer-times-power-of-two is exact.
        sub = m.astype(jnp.float32) * np.float32(2.0 ** (1 - fmt.bias - z))
        sub = jnp.where(sign == 1, -sub, sub)
    # Specials.
    inf_bits = sign31 | np.uint32(0x7F800000)
    nan_bits = sign31 | np.uint32(0x7FC00000)
    special = jax.lax.bitcast_convert_type(
        jnp.where(m == 0, inf_bits, nan_bits), jnp.float32
    )
    signed_zero = jax.lax.bitcast_convert_type(sign31, jnp.float32)

    out = jnp.where(ef == 0, jnp.where(m == 0, signed_zero, sub), nrm)
    out = jnp.where(ef == ((1 << y) - 1), special, out)
    return out


def qdq(x: jax.Array, fmt: FloatFormat) -> jax.Array:
    """Quantize-dequantize simulation (equals value_quantize; kept for API)."""
    return value_quantize(x, fmt)


def qdq_ste(x: jax.Array, fmt: FloatFormat) -> jax.Array:
    """Quantize-dequantize with a straight-through gradient (QAT baseline)."""
    return x + jax.lax.stop_gradient(value_quantize(x, fmt) - x)
