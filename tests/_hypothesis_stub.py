"""Optional-hypothesis shim: property tests skip when hypothesis is absent.

Mixed test modules (unit tests + hypothesis property tests) import
``given``/``settings``/``st`` from here instead of hard-importing
``hypothesis`` — with hypothesis installed this is a transparent re-export;
without it the ``@given`` decorator marks the test skipped and the strategy
namespace returns inert placeholders, so the *unit* tests in the module
still collect and run.  Modules that are 100% property tests use
``pytest.importorskip("hypothesis")`` directly instead.
"""

try:
    from hypothesis import given, settings
    from hypothesis import strategies as st

    HAVE_HYPOTHESIS = True
except ImportError:  # pragma: no cover - exercised only without hypothesis
    import pytest

    HAVE_HYPOTHESIS = False

    def given(*args, **kwargs):
        return pytest.mark.skip(reason="hypothesis not installed")

    def settings(*args, **kwargs):
        return lambda fn: fn

    class _Strategies:
        """Inert stand-in: any strategy constructor returns None."""

        def __getattr__(self, name):
            return lambda *a, **k: None

    st = _Strategies()

__all__ = ["HAVE_HYPOTHESIS", "given", "settings", "st"]
