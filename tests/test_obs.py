"""Telemetry subsystem gates (DESIGN.md §15).

The contract under test:

  * **off-by-default**: ``obs=None`` is a strict no-op — same program
    signatures, no spans, no records (every other tier-1 gate runs with
    obs off, so this is implicitly re-proven suite-wide);
  * **enabling metrics changes nothing**: with a live ``Obs`` at cohort 8
    the trained trees and byte ledgers are bit/byte-identical to
    ``obs=None`` on the loop, engine, and async paths — metric bundles
    are assembled eagerly on the host AFTER each compiled step, never
    inside it;
  * tracer span ordering on both clocks (wall + virtual under a
    ``FixedTrace``), JSONL/Perfetto export schema roundtrip, and the
    ``python -m repro.obs.report`` CLI rendering a run without error.
"""

import io
import json

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.omc import OMCConfig
from repro.data.synthetic import make_frame_task
from repro.federated import async_engine, engine, simulate, traces
from repro.federated.cohort import CohortPlan
from repro.models import conformer as cf
from repro.obs import Obs, null_span
from repro.obs import metrics as obs_metrics
from repro.obs import report as obs_report
from repro.obs.export import (
    JSONL_KINDS,
    read_jsonl,
    span_record,
    to_perfetto,
)
from repro.obs.log import Logger
from repro.obs.trace import VIRTUAL, WALL, Span, Tracer, maybe_span
from repro.scale import ShardLayout, run_training_sharded

CFG = cf.ConformerConfig(
    n_layers=1, d_model=16, n_heads=2, d_ff=32, n_classes=8, d_in=4
)
OMC = OMCConfig.parse("S1E3M7")
PLAN = CohortPlan(num_clients=16, cohort_size=8, failure_rate=0.25)
TASK = make_frame_task(d_in=CFG.d_in, n_classes=CFG.n_classes, seq_len=12,
                       num_clients=PLAN.num_clients)
DATA_FN = lambda c, r, s: TASK.batch(c, r, s, 4)
SIM = simulate.SimConfig(local_steps=2, client_lr=0.1)
KEY = jax.random.PRNGKey(0)


def _assert_bit_identical(a_storage, b_storage):
    la = jax.tree_util.tree_leaves(a_storage)
    lb = jax.tree_util.tree_leaves(b_storage)
    assert len(la) == len(lb)
    for x, y in zip(la, lb):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


def _assert_ledgers_equal(h0, h1):
    assert len(h0) == len(h1)
    for r0, r1 in zip(h0, h1):
        for k in ("down_bytes", "up_bytes", "loss", "cohort", "dropped"):
            if k in r0 or k in r1:
                assert r0.get(k) == r1.get(k), (k, r0, r1)


# ---------------------------------------------------------------------------
# The §15 acceptance gate: metrics-enabled == metrics-disabled, bitwise
# ---------------------------------------------------------------------------


def _run_loop(obs):
    return simulate.run_training(cf, CFG, OMC, SIM, PLAN, DATA_FN, KEY,
                                 num_rounds=2, eval_every=100, wire=True,
                                 obs=obs)


def _run_engine(obs):
    return engine.run_training_vectorized(
        cf, CFG, OMC, SIM, engine.CohortSpec(PLAN), DATA_FN, KEY,
        num_rounds=2, eval_every=100, obs=obs,
    )


def _run_async(obs):
    st, hist, _ = async_engine.run_async_training(
        cf, CFG, OMC, SIM, async_engine.AsyncConfig(buffer_goal=8),
        traces.ParetoTrace(seed=1), DATA_FN, KEY, num_clients=16,
        flushes=2, wire=True, obs=obs,
    )
    return st, hist


@pytest.mark.tier1
@pytest.mark.parametrize("path,run", [
    ("loop", _run_loop),
    ("engine", _run_engine),
    ("async", _run_async),
], ids=["loop", "engine", "async"])
def test_metrics_on_is_bit_identical(tmp_path, path, run):
    """Cohort 8, two rounds/flushes: enabling obs must not move one bit of
    trained state nor one byte of the wire ledgers (DESIGN.md §15)."""
    s0, h0 = run(None)
    obs = Obs(run_name=path, out_dir=str(tmp_path))
    s1, h1 = run(obs)
    _assert_bit_identical(s0, s1)
    _assert_ledgers_equal(h0, h1)
    # and the run actually produced telemetry, not a silent no-op
    kind = "flush" if path == "async" else "round"
    recs = obs.sink.records(kind)
    assert len(recs) == 2
    assert all(np.isfinite(r["update_norm"]) for r in recs)


@pytest.mark.tier1
def test_metrics_on_is_bit_identical_sharded(tmp_path):
    """The streamed path: chunk metric partials ride the fixed-capacity
    program as extra outputs; main outputs must stay bit-identical."""
    def run(obs):
        return run_training_sharded(
            cf, CFG, OMC, SIM, PLAN, ShardLayout(16, 2), DATA_FN, KEY, 2,
            capacity=3, obs=obs,
        )

    s0, h0, _ = run(None)
    obs = Obs(run_name="sharded", out_dir=str(tmp_path))
    s1, h1, _ = run(obs)
    _assert_bit_identical(s0, s1)
    _assert_ledgers_equal(h0, h1)
    recs = obs.sink.records("round")
    assert len(recs) == 2
    assert all("update_sq_wsum" in r for r in recs)  # folded chunk partials


def test_round_record_schema(tmp_path):
    """Engine round records carry the §15 bundle: loss, alive, update and
    per-leaf quantization-error norms, plus the byte ledger fields."""
    obs = Obs(run_name="schema", out_dir=str(tmp_path))
    _run_engine(obs)
    rec = obs.sink.records("round")[0]
    assert rec["kind"] == "round"
    for k in ("round", "loss", "alive", "update_norm", "qerr_norm",
              "down_bytes", "up_bytes"):
        assert k in rec, rec.keys()
    assert any(k.startswith("qerr/") for k in rec)  # per-leaf series
    # wall span per round, including the compile-bearing round 0
    assert len(obs.tracer.spans(WALL, "round")) == 2


# ---------------------------------------------------------------------------
# Tracer: two clocks
# ---------------------------------------------------------------------------


def test_tracer_wall_spans_nest_and_order():
    tr = Tracer()
    with tr.span("outer", idx=0) as args:
        with tr.span("inner"):
            pass
        args["bytes"] = 123
    inner, outer = tr.spans()
    assert (inner.name, outer.name) == ("inner", "outer")
    assert outer.args == {"idx": 0, "bytes": 123}
    assert outer.ts <= inner.ts and inner.end <= outer.end + 1e-9
    assert all(s.cat == WALL for s in tr.spans())


def test_tracer_virtual_vs_wall_under_fixed_trace(tmp_path):
    """FixedTrace(latency=2): every async client round is a virtual span of
    exactly that duration, stacked deterministically on the virtual clock;
    wall flush spans live on the wall clock, independent of it."""
    obs = Obs(run_name="fixed", out_dir=str(tmp_path))
    async_engine.run_async_training(
        cf, CFG, OMC, SIM, async_engine.AsyncConfig(buffer_goal=4),
        traces.FixedTrace(latency=2.0), DATA_FN, KEY, num_clients=4,
        flushes=2, wire=False, obs=obs,
    )
    v = obs.tracer.spans(VIRTUAL, "client_round")
    assert len(v) >= 8  # 4 clients x >= 2 completed rounds
    assert all(s.dur == pytest.approx(2.0) for s in v)
    # virtual timestamps advance with the simulated clock, in event order
    ts = [s.ts for s in v]
    assert ts == sorted(ts)
    w = obs.tracer.spans(WALL, "flush")
    assert len(w) == 2
    # the two clocks never mix categories
    assert not obs.tracer.spans(WALL, "client_round")
    summary = obs.tracer.summary()
    assert summary["virtual:client_round"]["count"] == len(v)
    assert summary["virtual:client_round"]["mean_s"] == pytest.approx(2.0)


# ---------------------------------------------------------------------------
# Export: JSONL + Perfetto schema
# ---------------------------------------------------------------------------


def test_export_roundtrip_schema(tmp_path):
    obs = Obs(run_name="export", out_dir=str(tmp_path))
    obs.record("round", {"loss": jnp.float32(1.5)}, round=0, up_bytes=10)
    with obs.span("encode_payload", bytes=42):
        pass
    obs.vspan("client_round", 1.0, 2.0, client=3)
    paths = obs.flush()

    records = read_jsonl(paths["jsonl"])
    assert all(r["kind"] in JSONL_KINDS for r in records)
    kinds = [r["kind"] for r in records]
    assert "meta" in kinds and "round" in kinds and "span" in kinds
    meta = records[kinds.index("meta")]
    assert "dispatch_counts" in meta  # kernels.ops counters ride the meta
    rnd = records[kinds.index("round")]
    assert rnd["loss"] == 1.5 and rnd["up_bytes"] == 10  # jax scalar -> float

    with open(paths["perfetto"]) as f:
        doc = json.load(f)
    assert doc["displayTimeUnit"] == "ms"
    events = doc["traceEvents"]
    names = {e["args"]["name"] for e in events if e["ph"] == "M"}
    assert names == {"wall clock", "virtual clock"}
    xs = [e for e in events if e["ph"] == "X"]
    assert {e["name"] for e in xs} == {"encode_payload", "client_round"}
    virt = next(e for e in xs if e["name"] == "client_round")
    assert virt["pid"] == 2 and virt["ts"] == 1.0 * 1e6
    assert virt["dur"] == 2.0 * 1e6
    # span_record <-> Span: seconds preserved through the JSONL form
    sp = Span("x", ts=0.5, dur=0.25, args={"n": 1})
    rec = span_record(sp)
    assert rec == {"kind": "span", "name": "x", "cat": WALL, "ts": 0.5,
                   "dur": 0.25, "args": {"n": 1.0}}
    assert to_perfetto([sp])["traceEvents"][-1]["dur"] == 0.25 * 1e6


def test_null_span_and_maybe_span_are_noops():
    with null_span(None, "anything", a=1) as args:
        args["b"] = 2  # must accept writes like the live version
    with maybe_span(None, "anything") as args:
        pass
    tr = Tracer()
    with maybe_span(tr, "live"):
        pass
    assert len(tr.spans()) == 1


def test_logger_quiet_and_structured(tmp_path):
    obs = Obs(run_name="log", out_dir=str(tmp_path), trace=False)
    err = io.StringIO()
    log = Logger(quiet=False, obs=obs, stream=err)
    log.info("hello", n=3)
    log.warn("careful")
    assert "[info] hello n=3" in err.getvalue()
    assert "[warn] careful" in err.getvalue()
    quiet_err = io.StringIO()
    Logger(quiet=True, obs=obs, stream=quiet_err).info("silent", n=4)
    assert quiet_err.getvalue() == ""  # text suppressed...
    logs = obs.sink.records("log")
    assert [r["msg"] for r in logs] == ["hello", "careful", "silent"]
    assert logs[-1]["n"] == 4  # ...but the structured record still lands


# ---------------------------------------------------------------------------
# Metric math
# ---------------------------------------------------------------------------


def test_server_round_bundle_matches_manual_norms():
    specs = cf.param_specs(CFG)
    params = cf.init(KEY, CFG)
    storage = engine.compress_params(params, specs, OMC)
    old_f32 = jax.tree_util.tree_map(jnp.asarray, params)
    # a synthetic "mean" one small step away from the server
    mean = jax.tree_util.tree_map(lambda x: x + 0.01, old_f32)
    new_storage = engine.apply_server_step(old_f32, mean, specs, OMC, 1.0)
    bundle = obs_metrics.server_round_bundle(specs, old_f32, new_storage,
                                             mean, 1.0)
    assert float(bundle["update_norm"]) > 0
    assert float(bundle["qerr_norm"]) >= 0
    per_leaf = [v for k, v in bundle.items() if k.startswith("qerr/")]
    assert per_leaf
    total = float(jnp.sqrt(sum(jnp.asarray(v) ** 2 for v in per_leaf)))
    assert total == pytest.approx(float(bundle["qerr_norm"]), rel=1e-5)
    # degraded form (fused paths): mean unavailable -> no qerr series
    degraded = obs_metrics.server_round_bundle(specs, old_f32, new_storage,
                                               None, 1.0)
    assert "qerr_norm" not in degraded and "update_norm" in degraded


def test_fold_partial_bundles():
    a = {"update_sq_wsum": jnp.float32(1.0)}
    b = {"update_sq_wsum": jnp.float32(2.5)}
    acc = obs_metrics.fold_partial_bundles(None, a)
    acc = obs_metrics.fold_partial_bundles(acc, b)
    assert float(acc["update_sq_wsum"]) == pytest.approx(3.5)


# ---------------------------------------------------------------------------
# Report CLI
# ---------------------------------------------------------------------------


def test_report_cli_smoke(tmp_path, capsys):
    obs = Obs(run_name="cli", out_dir=str(tmp_path))
    _run_engine(obs)
    obs.record("serve", queries=16, query_ms_p50=1.0, query_ms_p95=2.0,
               swap_ms_mean=3.0, swaps=2)
    # guarantee at least one kernel dispatch count in the meta record
    from repro.kernels import ops as kernel_ops
    kernel_ops.pack_bits(jnp.arange(521, dtype=jnp.uint32) & np.uint32(0x7), 3)
    paths = obs.flush()
    assert obs_report.main([paths["jsonl"]]) == 0
    out = capsys.readouterr().out
    for section in ("rounds", "serve", "spans", "dispatch"):
        assert section in out, out
    assert "qerr_norm" in out and "wire_mb" in out


def test_report_cli_missing_file():
    assert obs_report.main(["/nonexistent/run.obs.jsonl"]) != 0
