"""Strategy zoo: roundtrips, byte accounting, version gates (DESIGN.md §11).

Four contracts under test:
  * every zoo strategy round-trips bit-exactly through the §7 wire codec,
    with its traceable qdq view numerically identical to decode∘encode;
  * byte accounting reconciles three ways — ``tree_wire_bytes`` ==
    serialized payload body == ``payload_bytes_report`` (and, for
    shape-determined strategies, the per-leaf ``plan_wire_bytes``);
  * wire-format versioning: a payload carrying a strategy tag whose
    ``wire_version`` differs from the local zoo's is rejected with a
    ``CodecError`` — never silently decoded;
  * the cross-strategy equivalence gate: ``OMCQuantStrategy`` reproduces
    the existing loop path (``federated.state.compress_params`` storage,
    ``WireTable`` ledgers, ``run_training`` wire history) bit- and
    byte-exactly, so the zoo refactor cannot drift the paper's numbers.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from _hypothesis_stub import given, settings, st

from repro import compress
from repro.api import codecs
from repro.api.codecs import CodecError
from repro.core.omc import OMCConfig
from repro.core.store import decompress_tree, is_compressed
from repro.data.synthetic import make_frame_task
from repro.federated import accounting, simulate
from repro.federated.cohort import CohortPlan
from repro.federated.state import compress_params
from repro.models import conformer as cf

OMC = OMCConfig.parse("S1E3M7")
ZOO = compress.default_zoo()
ZOO_IDS = [s.label for s in ZOO]


def _tree(seed=0):
    """Two policy-selected matrices + one raw (too small / 1-D) leaf."""
    rng = np.random.default_rng(seed)
    return {
        "w": jnp.asarray(rng.normal(size=(32, 24)), jnp.float32),
        "emb": jnp.asarray(rng.normal(size=(40, 16)), jnp.float32),
        "bias": jnp.asarray(rng.normal(size=(8,)), jnp.float32),
    }


def _leaves(tree):
    return {k: np.asarray(v) for k, v in compress.decode_tree(tree).items()}


# ---------------------------------------------------------------------------
# per-strategy roundtrips
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("strategy", ZOO, ids=ZOO_IDS)
def test_wire_roundtrip_bit_exact(strategy):
    params = _tree()
    tree = compress.encode_tree(strategy, params, OMC)
    payload = codecs.encode_payload(tree, strategy=strategy)
    info = codecs.peek_payload(payload)
    assert info.strategy == strategy.name
    assert info.strategy_version == strategy.wire_version

    decoded, dinfo = codecs.decode_payload(payload)
    assert dinfo.strategy == strategy.name
    assert codecs.tree_digest(decoded) == codecs.tree_digest(tree)
    a, b = _leaves(tree), _leaves(decoded)
    for k in params:
        assert np.array_equal(a[k], b[k]), k
    # the unselected leaf travels raw and untouched
    assert np.array_equal(b["bias"], np.asarray(params["bias"]))


@pytest.mark.parametrize("strategy", ZOO, ids=ZOO_IDS)
def test_qdq_matches_decode(strategy):
    params = _tree(seed=1)
    via_wire = _leaves(compress.encode_tree(strategy, params, OMC))
    via_qdq = {k: np.asarray(v)
               for k, v in compress.qdq_tree(strategy, params, OMC).items()}
    for k in params:
        assert np.array_equal(via_wire[k], via_qdq[k]), k


@pytest.mark.parametrize("strategy", ZOO, ids=ZOO_IDS)
def test_qdq_ste_gradient_is_straight_through(strategy):
    v = jnp.asarray(np.random.default_rng(2).normal(size=(24, 16)),
                    jnp.float32)
    g = jax.grad(lambda x: jnp.sum(strategy.qdq_ste_leaf(x)))(v)
    assert np.allclose(np.asarray(g), 1.0)


# ---------------------------------------------------------------------------
# byte accounting
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("strategy", ZOO, ids=ZOO_IDS)
def test_bytes_reconcile_three_ways(strategy):
    params = _tree(seed=3)
    tree = compress.encode_tree(strategy, params, OMC)
    twb = compress.tree_wire_bytes(tree)
    rep = codecs.payload_bytes_report(tree)
    info = codecs.peek_payload(codecs.encode_payload(tree, strategy=strategy))
    assert twb["wire_bytes"] == rep["wire_bytes"] == info.body_bytes
    # the per-kind split sums back to the total
    assert sum(b["payload_bytes"] for b in twb["per_strategy"].values()) \
        == twb["wire_bytes"]
    assert set(rep["per_strategy"]) == set(twb["per_strategy"])
    for kind, b in twb["per_strategy"].items():
        r = rep["per_strategy"][kind]
        for key in ("payload_bytes", "index_bytes", "meta_bytes",
                    "num_leaves", "num_params"):
            assert r[key] == b[key], (kind, key)


@pytest.mark.parametrize("strategy", ZOO, ids=ZOO_IDS)
def test_plan_matches_measured(strategy):
    """Shape-determined strategies must predict exactly what they encode."""
    v = jnp.asarray(np.random.default_rng(4).normal(size=(20, 24)),
                    jnp.float32)
    leaf = strategy.encode_leaf(v)
    measured = strategy.leaf_wire_bytes(leaf)
    plan = strategy.plan_wire_bytes(v.size, 1)
    if plan is None:  # data-dependent (entropy-coded): measured only
        assert strategy.name == "pipeline"
    else:
        assert plan == measured


def test_topk_overhead_split():
    s = next(z for z in ZOO if z.name == "topk")
    tree = compress.encode_tree(s, _tree(), OMC)
    b = compress.tree_wire_bytes(tree)["per_strategy"]["topk"]
    assert b["index_bytes"] > 0
    assert b["payload_bytes"] > b["index_bytes"]


def test_ternary_meta_split():
    s = next(z for z in ZOO if z.name == "ternary")
    tree = compress.encode_tree(s, _tree(), OMC)
    b = compress.tree_wire_bytes(tree)["per_strategy"]["ternary"]
    assert b["meta_bytes"] == 4 * 2  # one f32 scale per selected matrix


# ---------------------------------------------------------------------------
# wire-format versioning (tier-1: mismatch -> CodecError, never corruption)
# ---------------------------------------------------------------------------


def test_zoo_declares_wire_versions():
    for name in compress.available_strategies():
        cls = compress.strategy_class(name)
        assert isinstance(cls.wire_version, int) and cls.wire_version >= 1
        assert cls.name == name


@pytest.mark.parametrize("strategy", ZOO, ids=ZOO_IDS)
def test_wire_version_mismatch_rejected(strategy, monkeypatch):
    tree = compress.encode_tree(strategy, _tree(), OMC)
    payload = codecs.encode_payload(tree, strategy=strategy)
    monkeypatch.setattr(type(strategy), "wire_version",
                        strategy.wire_version + 1)
    with pytest.raises(CodecError, match="wire version mismatch"):
        codecs.decode_payload(payload)
    with pytest.raises(CodecError, match="wire version mismatch"):
        codecs.peek_payload(payload)


def test_unknown_strategy_tag_rejected(monkeypatch):
    s = next(z for z in ZOO if z.name == "topk")
    payload = codecs.encode_payload(compress.encode_tree(s, _tree(), OMC),
                                    strategy=s)
    from repro.compress import base

    monkeypatch.delitem(base._REGISTRY, "topk")
    with pytest.raises(CodecError, match="unknown compression strategy"):
        codecs.decode_payload(payload)


def test_registry_lookup():
    assert set(compress.available_strategies()) >= {
        "omc", "topk", "ternary", "pipeline"
    }
    assert compress.get_strategy("topk", density=0.25).density == 0.25
    with pytest.raises(KeyError):
        compress.get_strategy("nope")


# ---------------------------------------------------------------------------
# property tests (skip cleanly when hypothesis is absent)
# ---------------------------------------------------------------------------


@settings(max_examples=20, deadline=None)
@given(st.integers(0, 2**31 - 1), st.integers(257, 800),
       st.floats(0.02, 0.5))
def test_topk_keeps_the_k_largest(seed, n, density):
    from repro.compress.topk import TopKSparseStrategy, num_kept

    rng = np.random.default_rng(seed)
    # distinct integer magnitudes: the top-k set is unambiguous
    mag = rng.permutation(np.arange(1, n + 1)).astype(np.float32)
    v = mag * rng.choice(np.asarray([-1.0, 1.0], np.float32), n)
    s = TopKSparseStrategy(density=density)
    leaf = s.encode_leaf(jnp.asarray(v))
    k = num_kept(n, density)
    assert leaf.k == k
    expected = np.sort(np.argsort(mag)[-k:])
    assert np.array_equal(np.asarray(leaf.idx, np.int64), expected)
    decoded = np.asarray(leaf.dequantize()).ravel()
    assert np.array_equal(decoded[expected], v[expected])  # values exact
    dropped = np.setdiff1d(np.arange(n), expected)
    assert np.all(decoded[dropped] == 0.0)


@settings(max_examples=20, deadline=None)
@given(st.integers(0, 2**31 - 1), st.integers(257, 600))
def test_ternary_decodes_to_three_levels(seed, n):
    from repro.compress.ternary import TernaryTNTStrategy

    v = jnp.asarray(np.random.default_rng(seed).normal(size=n), jnp.float32)
    s = TernaryTNTStrategy()
    leaf = s.encode_leaf(v)
    assert set(np.unique(np.asarray(leaf.codes))) <= {0, 1, 2}
    scale = float(np.asarray(leaf.scale))
    levels = {-scale, 0.0, scale}
    assert set(np.unique(np.asarray(leaf.dequantize()))) <= levels
    assert np.array_equal(np.asarray(s.qdq_leaf(v)),
                          np.asarray(leaf.dequantize()))


# ---------------------------------------------------------------------------
# cross-strategy equivalence gate (the refactor cannot drift OMC numbers)
# ---------------------------------------------------------------------------

CFG = cf.ConformerConfig(
    n_layers=1, d_model=32, n_heads=4, d_ff=64, n_classes=16, d_in=8
)


def test_omc_strategy_reproduces_loop_path():
    task = make_frame_task(d_in=CFG.d_in, n_classes=CFG.n_classes,
                           seq_len=16, num_clients=4)
    plan = CohortPlan(num_clients=4, cohort_size=2)
    sim = simulate.SimConfig(local_steps=1, client_lr=0.1)
    storage, hist = simulate.run_training(
        cf, CFG, OMC, sim, plan, lambda c, r, s: task.batch(c, r, s, 2),
        jax.random.PRNGKey(0), num_rounds=2, eval_every=100, wire=True,
    )

    f32 = decompress_tree(storage)
    specs = cf.param_specs(CFG)
    strategy = OMC.strategy()

    # storage bit-equality: the adapter IS compress_params
    via_state = compress_params(f32, specs, OMC)
    via_zoo = compress.encode_tree(strategy, f32, OMC, specs)
    sl = jax.tree_util.tree_leaves(via_state, is_leaf=is_compressed)
    zl = jax.tree_util.tree_leaves(via_zoo, is_leaf=is_compressed)
    assert len(sl) == len(zl)
    n_comp = 0
    for a, b in zip(sl, zl):
        assert is_compressed(a) == is_compressed(b)
        if is_compressed(a):
            n_comp += 1
            assert np.array_equal(np.asarray(a.codes), np.asarray(b.codes))
            assert np.array_equal(np.asarray(a.s), np.asarray(b.s))
            assert np.array_equal(np.asarray(a.b), np.asarray(b.b))
        else:
            assert np.array_equal(np.asarray(a), np.asarray(b))
    assert n_comp > 0

    # wire-byte equality: payloads, planning ledger, training history
    assert len(codecs.encode_payload(via_zoo)) \
        == len(codecs.encode_payload(via_state))
    wt = accounting.build_wire_table(f32, specs, OMC)
    assert wt.download_bytes_strategy(strategy) == wt.download_bytes(OMC)
    mask = np.zeros(wt.num_vars, bool)
    mask[::2] = True
    assert wt.upload_bytes_strategy(strategy, mask) \
        == wt.upload_bytes(mask, OMC)
    assert hist[0]["down_bytes"] \
        == wt.download_bytes_strategy(strategy) * plan.cohort_size

    # model view equality: within one quantization step (here: bit-exact)
    via_zoo_f32 = compress.decode_tree(via_zoo)
    for a, b in zip(jax.tree_util.tree_leaves(decompress_tree(via_state)),
                    jax.tree_util.tree_leaves(via_zoo_f32)):
        assert np.array_equal(np.asarray(a), np.asarray(b))


def test_wiretable_rejects_data_dependent_strategy():
    pipe = next(z for z in ZOO if z.name == "pipeline")
    params = cf.init(jax.random.PRNGKey(0), CFG)
    wt = accounting.build_wire_table(params, cf.param_specs(CFG), OMC)
    with pytest.raises(ValueError, match="data-dependent"):
        wt.download_bytes_strategy(pipe)
