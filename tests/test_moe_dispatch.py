"""MoE dispatch: shard_map path == single-device reference; capacity rules."""

import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.launch.mesh import compat_make_mesh
from repro.models import moe
from repro.models.common import activate_mesh

CFG = moe.MoEConfig(n_layers=1, d_model=32, n_heads=4, n_kv_heads=2,
                    d_ff=64, vocab=64, n_experts=4, top_k=2)


def _ffn_weights(key):
    blk = moe._block_init(key, CFG)
    return {k: blk[k] for k in ("router", "w1", "w3", "w2")}


def test_shard_map_matches_reference_1x1():
    w = _ffn_weights(jax.random.PRNGKey(0))
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 16, 32))
    y_ref, aux_ref = moe.moe_ffn(x, w, CFG)
    mesh = compat_make_mesh((1, 1), ("data", "model"))
    with activate_mesh(mesh):
        y_sm, aux_sm = jax.jit(lambda x, w: moe.moe_ffn(x, w, CFG))(x, w)
    np.testing.assert_allclose(np.asarray(y_ref), np.asarray(y_sm),
                               rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(float(aux_ref), float(aux_sm), rtol=1e-5)


_MULTIDEV_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax, numpy as np
import jax.numpy as jnp
from repro.launch.mesh import compat_make_mesh
from repro.models import moe
from repro.models.common import activate_mesh

cfg = moe.MoEConfig(n_layers=1, d_model=32, n_heads=4, n_kv_heads=2,
                    d_ff=64, vocab=64, n_experts=4, top_k=2)
blk = moe._block_init(jax.random.PRNGKey(0), cfg)
w = {k: blk[k] for k in ("router", "w1", "w3", "w2")}
x = jax.random.normal(jax.random.PRNGKey(1), (4, 16, 32))
y_ref, aux_ref = moe.moe_ffn(x, w, cfg)
mesh = compat_make_mesh((2, 4), ("data", "model"))
with activate_mesh(mesh):
    y_sm, aux_sm = jax.jit(lambda x, w: moe.moe_ffn(x, w, cfg))(x, w)
# capacity differs per-shard (T_local < T), so token drops may differ around
# the capacity boundary; with cf=1.25 at these sizes none should drop.
np.testing.assert_allclose(np.asarray(y_ref), np.asarray(y_sm),
                           rtol=1e-4, atol=1e-4)
print("MULTIDEV-OK")
"""


def test_shard_map_matches_reference_8dev():
    """Real expert-parallel dispatch over a (2, 4) host mesh (subprocess:
    device count must be set before jax init)."""
    r = subprocess.run(
        [sys.executable, "-c", _MULTIDEV_SCRIPT],
        capture_output=True, text=True,
        env={"PYTHONPATH": "src", "PATH": "/usr/bin:/bin"},
        cwd="/root/repo", timeout=600,
    )
    assert "MULTIDEV-OK" in r.stdout, r.stdout + r.stderr


def test_capacity_bounds():
    assert moe._capacity(1, CFG) == CFG.top_k  # can't exceed pairs
    c = moe._capacity(1000, CFG)
    assert c % 8 == 0
    assert c >= 1000 * CFG.top_k / CFG.n_experts


def test_expert_weights_shapes_with_partitions():
    cfg2 = moe.MoEConfig(n_layers=1, d_model=32, n_heads=4, n_kv_heads=2,
                         d_ff=64, vocab=64, n_experts=4, top_k=2,
                         ep_partitions=2)
    blk = moe._block_init(jax.random.PRNGKey(0), cfg2)
    assert blk["w1"].shape == (8, 32, 32)  # [E*parts, D, F/parts]
    assert blk["w2"].shape == (8, 32, 32)
