"""Error-feedback invariants: unit tests + hypothesis property tests.

The residual contract behind DESIGN.md §12: at every send,
``compensate_leaf`` splits the compensated delta ``comp = delta + residual``
into ``(sent, residual')`` with ``sent + residual' == comp`` — nothing is
ever silently dropped, only deferred.  Property-tested here (via the
optional-hypothesis shim, so the unit half still runs without hypothesis):
exact reconstruction for identity-valued top-k, bounded residual norm, and
the no-op guarantee for dense strategies.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from _hypothesis_stub import HAVE_HYPOTHESIS, given, settings, st
from repro.compress import feedback, get_strategy
from repro.core.omc import OMCConfig
from repro.models import conformer as cf

OMC = OMCConfig.parse("S1E3M7")
CFG = cf.ConformerConfig(
    n_layers=1, d_model=16, n_heads=2, d_ff=32, n_classes=8, d_in=4
)

pytestmark = pytest.mark.tier1


def _arr(values):
    return jnp.asarray(np.asarray(values, np.float32))


# ---------------------------------------------------------------------------
# Unit half: state lifecycle
# ---------------------------------------------------------------------------


def test_takes_residual_matches_strategy_flags():
    """EF state is owed exactly to enabled sparse strategies with the
    error_feedback flag up; dense strategies and disabled OMC get none."""
    assert feedback.takes_residual(OMC, get_strategy("topk"))
    assert feedback.takes_residual(OMC, get_strategy("ternary"))
    assert feedback.takes_residual(OMC, get_strategy("pipeline"))
    assert not feedback.takes_residual(OMC, None)
    assert not feedback.takes_residual(OMC, get_strategy("omc"))
    assert not feedback.takes_residual(
        OMC, get_strategy("topk", error_feedback=False))
    off = OMCConfig.parse("S1E8M23", quantize_fraction=1.0)  # identity: disabled
    assert not off.enabled
    assert not feedback.takes_residual(off, get_strategy("topk"))


def test_init_gather_scatter_roundtrip():
    specs = cf.param_specs(CFG)
    params = cf.init(jax.random.PRNGKey(0), CFG)
    ef = feedback.init_ef_state(params, specs, OMC, num_clients=5)
    assert ef  # the conformer has selected (weight) variables
    for name, v in ef.items():
        assert v.shape[0] == 5 and v.dtype == jnp.float32
        assert not np.asarray(v).any()  # zero-initialised
    assert feedback.total_norm(ef) == 0.0
    assert feedback.ef_bytes(ef) == sum(4 * v.size for v in ef.values())

    ids = jnp.asarray([3, 1])
    rows = feedback.gather_rows(ef, ids)
    rows = {k: v + 1.0 for k, v in rows.items()}
    ef2 = feedback.scatter_rows(ef, ids, rows)
    for k, v in ef2.items():
        got = np.asarray(v)
        assert got[1].min() == 1.0 and got[3].min() == 1.0
        assert not got[[0, 2, 4]].any()
    # norms reflect the scatter
    assert feedback.total_norm(ef2) > 0.0
    assert set(feedback.ef_norms(ef2)) == set(ef2)


def test_compensate_respects_ppq_mask_bit():
    """mask_bit=False (PPQ left this var f32) sends comp verbatim and the
    residual fully drains."""
    strategy = get_strategy("topk", density=0.25)
    delta = _arr([1.0, -2.0, 0.5, 4.0])
    residual = _arr([0.25, 0.0, -0.5, 0.0])
    sent, new_r = feedback.compensate_leaf(
        strategy, delta, residual, jnp.asarray(False))
    np.testing.assert_array_equal(np.asarray(sent),
                                  np.asarray(delta + residual))
    assert not np.asarray(new_r).any()


def test_dense_strategy_is_ef_noop():
    """A dense strategy run through compensate_leaf leaves no residual worth
    keeping: sent == qdq(comp) everywhere and the residual is pure
    quantization error, bounded by one S1E3M7 step."""
    strategy = get_strategy("omc")
    delta = jnp.asarray(
        jax.random.normal(jax.random.PRNGKey(1), (32,)), jnp.float32)
    sent, new_r = feedback.compensate_leaf(
        strategy, delta, jnp.zeros_like(delta), jnp.asarray(True))
    np.testing.assert_allclose(np.asarray(sent + new_r), np.asarray(delta),
                               rtol=0, atol=1e-6)
    # what's left behind is pure qdq rounding: within a relative half-ulp of
    # the S1E3M7 mantissa (plus PVT headroom), not accumulated signal
    bound = 0.02 * float(np.abs(np.asarray(delta)).max())
    assert np.abs(np.asarray(new_r)).max() <= bound


# ---------------------------------------------------------------------------
# Property half (skips without hypothesis; see tests/_hypothesis_stub.py)
# ---------------------------------------------------------------------------

floats_st = st.floats(-16.0, 16.0, allow_nan=False, width=32) \
    if HAVE_HYPOTHESIS else None
vec_st = st.lists(floats_st, min_size=4, max_size=96) if HAVE_HYPOTHESIS \
    else None


@settings(max_examples=40, deadline=None)
@given(vec_st, st.integers(1, 4))
def test_topk_reconstruction_is_exact(values, denom):
    """Identity-valued top-k: sent + residual' reconstructs comp bit for
    bit — kept coordinates ship verbatim, dropped ones move whole into the
    residual."""
    strategy = get_strategy("topk", density=1.0 / denom)
    comp = _arr(values)
    sent, new_r = feedback.compensate_leaf(
        strategy, comp, jnp.zeros_like(comp), jnp.asarray(True))
    np.testing.assert_array_equal(np.asarray(sent) + np.asarray(new_r),
                                  np.asarray(comp))
    # and each coordinate went one way or the other, never both
    assert not (np.asarray(sent) * np.asarray(new_r)).any()


@settings(max_examples=40, deadline=None)
@given(vec_st, st.integers(0, 2**31 - 1))
def test_ternary_reconstruction_within_float_eps(values, seed):
    """Non-identity values (ternary scales): reconstruction holds to f32
    rounding of the subtraction, not bitwise."""
    strategy = get_strategy("ternary")
    comp = _arr(values)
    residual = jnp.asarray(
        jax.random.normal(jax.random.PRNGKey(seed), comp.shape), jnp.float32)
    total = comp + residual
    sent, new_r = feedback.compensate_leaf(
        strategy, comp, residual, jnp.asarray(True))
    np.testing.assert_allclose(np.asarray(sent) + np.asarray(new_r),
                               np.asarray(total), rtol=1e-6, atol=1e-5)


@settings(max_examples=40, deadline=None)
@given(vec_st, st.integers(2, 8))
def test_topk_residual_norm_bounded(values, denom):
    """Dropping the smallest-magnitude coordinates never grows the vector:
    ||residual'|| <= ||comp||, with equality only when everything was
    dropped."""
    strategy = get_strategy("topk", density=1.0 / denom)
    comp = _arr(values)
    _, new_r = feedback.compensate_leaf(
        strategy, comp, jnp.zeros_like(comp), jnp.asarray(True))
    assert float(jnp.linalg.norm(new_r)) <= float(jnp.linalg.norm(comp)) + 1e-6


@settings(max_examples=25, deadline=None)
@given(st.integers(0, 2**31 - 1), st.integers(2, 6))
def test_residual_telescopes_across_rounds(seed, rounds):
    """Over any number of sends, sum(sent) == sum(delta) - final residual:
    the server is eventually owed exactly what the residual still holds."""
    strategy = get_strategy("topk", density=0.25)
    key = jax.random.PRNGKey(seed)
    residual = jnp.zeros((24,), jnp.float32)
    total_delta = jnp.zeros_like(residual)
    total_sent = jnp.zeros_like(residual)
    for r in range(rounds):
        delta = jax.random.normal(jax.random.fold_in(key, r), (24,),
                                  jnp.float32)
        sent, residual = feedback.compensate_leaf(
            strategy, delta, residual, jnp.asarray(True))
        total_delta = total_delta + delta
        total_sent = total_sent + sent
    np.testing.assert_allclose(np.asarray(total_sent + residual),
                               np.asarray(total_delta), rtol=1e-5, atol=1e-5)
