"""Vectorized engine vs reference loop: equivalence + wire accounting.

The contract under test (DESIGN.md §9): with a single default tier the
engine consumes the identical cohort sample, survival mask, PPQ masks, and
data stream as the per-client reference loop; aggregated server trees agree
within batched-op reassociation tolerance (at most ~one quantization step on
boundary elements, tiny mean drift) and wire-byte accounting agrees to the
byte — the loop computes it one scalar mask at a time, the engine in one
batched pass, and both must reconcile exactly with the wire codec.
"""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.api import codecs
from repro.core.omc import OMCConfig
from repro.core.store import decompress_tree
from repro.data.partition import (
    DirichletPartition,
    DomainPartition,
    IIDPartition,
    make_partitioned_batch_fn,
)
from repro.data.synthetic import make_frame_task
from repro.federated import accounting, engine, simulate
from repro.federated.cohort import CohortPlan
from repro.federated.state import compress_params
from repro.models import conformer as cf

CFG = cf.ConformerConfig(
    n_layers=2, d_model=32, n_heads=4, d_ff=64, n_classes=16, d_in=8
)
OMC = OMCConfig.parse("S1E3M7")  # PPQ on: default quantize_fraction = 0.9
PLAN = CohortPlan(num_clients=16, cohort_size=8, failure_rate=0.25)
TASK = make_frame_task(d_in=CFG.d_in, n_classes=CFG.n_classes, seq_len=24,
                       num_clients=PLAN.num_clients)
DATA_FN = lambda c, r, s: TASK.batch(c, r, s, 4)


def _train_both(num_rounds=2, local_steps=2):
    sim = simulate.SimConfig(local_steps=local_steps, client_lr=0.1)
    key = jax.random.PRNGKey(0)
    ref_storage, ref_hist = simulate.run_training(
        cf, CFG, OMC, sim, PLAN, DATA_FN, key, num_rounds=num_rounds,
        eval_every=100, wire=True,
    )
    eng_storage, eng_hist = engine.run_training_vectorized(
        cf, CFG, OMC, sim, engine.CohortSpec(PLAN), DATA_FN, key,
        num_rounds=num_rounds, eval_every=100,
    )
    return ref_storage, ref_hist, eng_storage, eng_hist


def test_engine_matches_reference_loop():
    """Same seed, cohort of 8 with failures + PPQ: aggregated server trees
    within tolerance, wire-byte accounting exactly equal (ISSUE 3)."""
    ref_storage, ref_hist, eng_storage, eng_hist = _train_both()

    for rh, eh in zip(ref_hist, eng_hist):
        # identical cohort semantics: same survivors, same drop count
        assert rh["cohort"] == eh["cohort"]
        assert rh["dropped"] == eh["dropped"]
        # wire accounting is byte-exact between the scalar and batched paths
        assert rh["down_bytes"] == eh["down_bytes"]
        assert rh["up_bytes"] == eh["up_bytes"]
        assert abs(rh["loss"] - eh["loss"]) < 1e-3

    ref = decompress_tree(ref_storage)
    eng = decompress_tree(eng_storage)
    for a, b in zip(jax.tree_util.tree_leaves(ref),
                    jax.tree_util.tree_leaves(eng)):
        d = np.abs(np.asarray(a) - np.asarray(b))
        # boundary elements may round to the adjacent S1E3M7 code (one
        # quantization step, ~0.8% relative); the bulk must be identical
        assert d.max() <= 6e-3, d.max()
        assert d.mean() <= 1e-4, d.mean()


def test_fused_aggregation_matches_unfused_engine():
    """The compressed-domain server path (DESIGN.md §13) vs the unfused
    decompress→FedAvg→recompress engine at cohort 8: identical cohort
    semantics, byte-exact `WireTable` ledgers, server trees within one
    transport-quantization step (the fused path's only extra rounding)."""
    sim = simulate.SimConfig(local_steps=2, client_lr=0.1)
    key = jax.random.PRNGKey(0)
    out = {}
    for fused in (False, True):
        out[fused] = engine.run_training_vectorized(
            cf, CFG, OMC, sim, engine.CohortSpec(PLAN), DATA_FN, key,
            num_rounds=2, eval_every=100, fused_agg=fused,
        )
    (ref_storage, ref_hist), (f_storage, f_hist) = out[False], out[True]
    for rh, fh in zip(ref_hist, f_hist):
        assert rh["cohort"] == fh["cohort"]
        assert rh["dropped"] == fh["dropped"]
        # the ledger is mask-based and transport-independent: byte-exact
        assert rh["down_bytes"] == fh["down_bytes"]
        assert rh["up_bytes"] == fh["up_bytes"]
        assert abs(rh["loss"] - fh["loss"]) < 1e-3
    a, b = decompress_tree(ref_storage), decompress_tree(f_storage)
    for x, y in zip(jax.tree_util.tree_leaves(a),
                    jax.tree_util.tree_leaves(b)):
        d = np.abs(np.asarray(x) - np.asarray(y))
        # fused uploads are transport-requantized (one extra RNE per element
        # per round); bound = one S1E3M7 step at unit scale, tiny mean drift
        assert d.max() <= 6e-3, d.max()
        assert d.mean() <= 1e-3, d.mean()


def test_fused_aggregation_gating():
    """`fused_aggregation_supported` picks the path; unsupported configs
    must refuse loudly rather than silently fall back."""
    spec = engine.CohortSpec(PLAN)
    assert engine.fused_aggregation_supported(spec, OMC)
    f32 = engine.profile("f32").resolve(OMC)  # identity format: OMC disabled
    assert not f32.enabled and not engine.fused_aggregation_supported(spec, f32)
    assert not engine.fused_aggregation_supported(spec, OMC, strategy=object())
    hetero = engine.CohortSpec(
        CohortPlan(num_clients=16, cohort_size=8),
        tiers=(engine.profile("s1e3m7"), engine.profile("f32")),
    )
    assert not engine.fused_aggregation_supported(hetero, OMC)
    with pytest.raises(ValueError):
        engine.run_training_vectorized(
            cf, CFG, OMC, simulate.SimConfig(), hetero, DATA_FN,
            jax.random.PRNGKey(0), num_rounds=1, fused_agg=True,
        )


def test_download_accounting_reconciles_with_codec():
    params = cf.init(jax.random.PRNGKey(0), CFG)
    specs = cf.param_specs(CFG)
    table = accounting.build_wire_table(params, specs, OMC)
    storage = compress_params(params, specs, OMC)
    rep = codecs.payload_bytes_report(storage)
    assert table.download_bytes(OMC) == rep["wire_bytes"]
    assert table.fp32_total == rep["fp32_bytes"]
    # the serialized full payload's body is exactly the reported wire bytes
    info = codecs.peek_payload(codecs.encode_payload(storage))
    assert info.body_bytes == rep["wire_bytes"]


def test_upload_accounting_reconciles_with_codec():
    """A client's PPQ-masked transport payload serializes to exactly the
    bytes the accounting table predicts (round, client arbitrary)."""
    params = cf.init(jax.random.PRNGKey(1), CFG)
    specs = cf.param_specs(CFG)
    table = accounting.build_wire_table(params, specs, OMC)
    for rnd, cid in [(0, 3), (5, 11)]:
        tree = engine.masked_upload_tree(params, specs, OMC, rnd, cid)
        predicted = accounting.client_upload_bytes(table, OMC, rnd, cid)
        assert codecs.payload_bytes_report(tree)["wire_bytes"] == predicted
        info = codecs.peek_payload(codecs.encode_payload(tree))
        assert info.body_bytes == predicted
    # PPQ actually bites: masked uploads sit strictly between all-quantized
    # and all-f32
    assert table.download_bytes(OMC) < predicted < table.fp32_total


def test_batched_upload_accounting_matches_scalar():
    params = cf.init(jax.random.PRNGKey(0), CFG)
    table = accounting.build_wire_table(params, cf.param_specs(CFG), OMC)
    ids = jnp.asarray([0, 3, 7, 12], jnp.int32)
    batched = accounting.cohort_upload_bytes(table, OMC, 4, ids)
    scalar = [accounting.client_upload_bytes(table, OMC, 4, int(c))
              for c in ids]
    np.testing.assert_array_equal(batched, scalar)


def test_hetero_tiers_round():
    plan = CohortPlan(num_clients=24, cohort_size=6)
    spec = engine.CohortSpec(
        plan,
        tiers=(engine.profile("s1e3m7"), engine.profile("s1e4m3"),
               engine.profile("f32")),
        quotas=(3, 2, 1),
    )
    sim = simulate.SimConfig(local_steps=1, client_lr=0.1)
    specs = cf.param_specs(CFG)
    params = cf.init(jax.random.PRNGKey(0), CFG)
    storage = compress_params(params, specs, OMC)
    table = accounting.build_wire_table(params, specs, OMC)
    key = jax.random.PRNGKey(2)

    ids = engine.sample_tiered_cohort(key, spec, 0)
    # stratified sampling: tier t draws only from its own (round-robin)
    # population, and quotas are honored with static shapes
    for t, ids_t in enumerate(ids):
        assert ids_t.shape == (spec.quotas[t],)
        assert bool((ids_t % 3 == t).all())

    new_storage, m = engine.run_round_vectorized(
        cf, CFG, specs, OMC, sim, storage, DATA_FN, spec, 0, key,
        wire_table=table,
    )
    assert m["cohort"] >= 1
    assert m["down_bytes"] == table.download_bytes(OMC) * plan.cohort_size
    # the f32 tier uploads uncompressed; quantized tiers upload less
    f32_omc = engine.profile("f32").resolve(OMC)
    assert not f32_omc.enabled
    assert accounting.cohort_upload_bytes(table, f32_omc, 0, ids[2])[0] == (
        table.fp32_total
    )
    tiny_omc = engine.profile("s1e4m3").resolve(OMC)
    assert accounting.cohort_upload_bytes(table, tiny_omc, 0, ids[1]).max() < (
        table.fp32_total
    )


def test_client_chunk_matches_full_vmap():
    """lax.map over client chunks (the scan-of-vmapped-blocks memory mode)
    reproduces the pure-vmap result."""
    sim = simulate.SimConfig(local_steps=1, client_lr=0.1)
    specs = cf.param_specs(CFG)
    params = cf.init(jax.random.PRNGKey(0), CFG)
    storage = compress_params(params, specs, OMC)
    key = jax.random.PRNGKey(0)
    out = {}
    for chunk in (None, 4):
        spec = engine.CohortSpec(PLAN, client_chunk=chunk)
        new_storage, m = engine.run_round_vectorized(
            cf, CFG, specs, OMC, sim, storage, DATA_FN, spec, 0, key,
        )
        out[chunk] = (decompress_tree(new_storage), m)
    assert out[None][1] == pytest.approx(out[4][1], rel=1e-5)
    for a, b in zip(jax.tree_util.tree_leaves(out[None][0]),
                    jax.tree_util.tree_leaves(out[4][0])):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=6e-3)


def test_cohort_spec_validation():
    plan = CohortPlan(num_clients=24, cohort_size=6)
    tiers = (engine.profile("s1e3m7"), engine.profile("f32"))
    with pytest.raises(ValueError):
        engine.CohortSpec(plan, tiers=tiers, quotas=(3, 4))  # sum != 6
    with pytest.raises(ValueError):
        engine.CohortSpec(plan, quotas=(3, 3))  # quotas without tiers
    with pytest.raises(ValueError):
        engine.CohortSpec(plan, client_chunk=4)  # 4 does not divide 6
    spec = engine.CohortSpec(plan, tiers=tiers)  # default even split
    assert spec.quotas == (3, 3)


def test_partitioners_vectorize_and_skew():
    part = DirichletPartition(alpha=0.1)
    fn = make_partitioned_batch_fn(TASK, part, batch_size=4, num_sources=8)
    ids = jnp.asarray([0, 1, 2], jnp.int32)
    batch = jax.vmap(lambda c: fn(c, 0, 0))(ids)  # engine's cohort axis
    assert batch["frames"].shape == (3, 4, TASK.seq_len, TASK.d_in)
    # vmapped generation is bit-identical to scalar generation
    solo = fn(1, 0, 0)
    np.testing.assert_array_equal(np.asarray(batch["frames"][1]),
                                  np.asarray(solo["frames"]))
    # non-IID: different clients draw from visibly different mixtures
    a = np.asarray(batch["frames"][0]).mean(axis=(0, 1))
    b = np.asarray(batch["frames"][2]).mean(axis=(0, 1))
    assert np.abs(a - b).max() > 0.1
    # IID partition: weights are uniform for every client
    w = IIDPartition().source_weights(jax.random.PRNGKey(0), 5, 8)
    np.testing.assert_allclose(np.asarray(w), 1 / 8)
    # domain partition routes clients to different label probes
    dom = DomainPartition(num_domains=2)
    fn_d = make_partitioned_batch_fn(TASK, dom, batch_size=4)
    b0, b1 = fn_d(0, 0, 0), fn_d(1, 0, 0)
    assert int(dom.domain_of(0)) == 0 and int(dom.domain_of(1)) == 1
    assert not np.array_equal(np.asarray(b0["labels"]),
                              np.asarray(b1["labels"]))
