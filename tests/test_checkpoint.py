"""Checkpoint/restart: atomicity, GC, resume, bit-exact replay."""

import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import checkpoint as ck
from repro.core.omc import OMCConfig
from repro.data.synthetic import make_lm_task
from repro.federated.round import make_round_fn
from repro.federated.state import init_state
from repro.models import transformer as tr
from repro.optim import fedavg

CFG = tr.TransformerConfig(
    n_layers=2, d_model=32, n_heads=2, n_kv_heads=1, d_ff=64, vocab=64
)


def _state():
    return init_state(jax.random.PRNGKey(0), tr, CFG,
                      OMCConfig.parse("S1E3M7"), fedavg(1.0))


def _assert_trees_equal(a, b):
    for x, y in zip(jax.tree_util.tree_leaves(a), jax.tree_util.tree_leaves(b)):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


def test_roundtrip_compressed_state(tmp_path):
    st = _state()
    ck.save_state(str(tmp_path), 3, st)
    found = ck.latest_checkpoint(str(tmp_path))
    assert found and found[1] == 3
    st2, manifest = ck.restore_state(found[0], st)
    assert manifest["step"] == 3
    _assert_trees_equal(st, st2)


def test_gc_keeps_k_latest(tmp_path):
    st = _state()
    for step in (1, 2, 3, 4, 5):
        ck.save_state(str(tmp_path), step, st, keep=2)
    names = sorted(n for n in os.listdir(tmp_path) if n.startswith("ckpt_"))
    assert names == ["ckpt_4", "ckpt_5"]


def test_stale_tmp_dirs_cleaned(tmp_path):
    os.makedirs(tmp_path / "tmp.99.garbage")
    st = _state()
    ck.save_state(str(tmp_path), 1, st)
    assert not any(n.startswith("tmp.") for n in os.listdir(tmp_path))


def test_resume_replays_bit_exact(tmp_path):
    """Train 3 rounds, checkpoint, train 2 more; restore + 2 == same state."""
    omc = OMCConfig.parse("S1E3M7")
    opt = fedavg(1.0)
    task = make_lm_task(vocab=64, seq_len=16, num_clients=4)
    fn = jax.jit(make_round_fn(tr, CFG, omc, opt, client_lr=0.05))

    st = _state()
    for r in range(3):
        st, _ = fn(st, task.batch(r % 4, r, 0, 4))
    ck.save_state(str(tmp_path), 3, st)

    cont = st
    for r in (3, 4):
        cont, _ = fn(cont, task.batch(r % 4, r, 0, 4))

    restored, _ = ck.restore_state(ck.latest_checkpoint(str(tmp_path))[0], st)
    for r in (3, 4):
        restored, _ = fn(restored, task.batch(r % 4, r, 0, 4))
    _assert_trees_equal(cont, restored)


def _async_runner():
    from repro.data.synthetic import make_frame_task
    from repro.federated import async_engine, simulate, traces
    from repro.models import conformer as cf

    ccfg = cf.ConformerConfig(n_layers=2, d_model=32, n_heads=4, d_ff=64,
                              n_classes=16, d_in=8)
    task = make_frame_task(d_in=8, n_classes=16, seq_len=16, num_clients=8)
    return async_engine.AsyncRunner(
        cf, ccfg, OMCConfig.parse("S1E3M7"),
        simulate.SimConfig(local_steps=1, client_lr=0.1),
        async_engine.AsyncConfig(buffer_goal=4, decay=0.5),
        traces.ParetoTrace(seed=3, latency=1.0, alpha=1.5),
        num_clients=8, data_fn=lambda c, r, s: task.batch(c, r, s, 4),
        init_key=jax.random.PRNGKey(0),
    )


def test_async_resume_mid_buffer(tmp_path):
    """Kill an async run mid-buffer; restore must continue identically —
    buffer contents, server version, pending version-stamped tickets, trace
    counters, and the wire ledger all round-trip (DESIGN.md §10)."""
    runner = _async_runner()
    runner.run_until(uploads=6)  # 6 uploads, K=4: buffer is mid-fill
    assert len(runner.buffer) > 0, "test wants a partially-filled buffer"
    assert runner.pending, "test wants in-flight version-stamped tickets"
    ck.save_async_state(str(tmp_path), runner)

    cont = runner  # continue the original in place
    cont.run_until(flushes=2)

    fresh = _async_runner()
    extra = ck.restore_async_state(
        ck.latest_checkpoint(str(tmp_path))[0], fresh
    )
    assert extra["kind"] == "async_runner"
    assert fresh.version == extra["version"]
    fresh.run_until(flushes=2)

    assert fresh.version == cont.version
    assert fresh.clock == cont.clock
    assert fresh.completed == cont.completed
    assert fresh.stats.snapshot() == cont.stats.snapshot()
    _assert_trees_equal(cont.storage, fresh.storage)
    assert [h["version"] for h in fresh.history] == [
        h["version"] for h in cont.history
    ]


def test_async_restore_rejects_sync_checkpoint(tmp_path):
    ck.save_state(str(tmp_path), 1, _state())
    with pytest.raises(ValueError):
        ck.restore_async_state(ck.latest_checkpoint(str(tmp_path))[0],
                               _async_runner())


def test_structure_mismatch_raises(tmp_path):
    st = _state()
    ck.save_state(str(tmp_path), 1, st)
    other = init_state(jax.random.PRNGKey(0), tr,
                       tr.TransformerConfig(n_layers=3, d_model=32, n_heads=2,
                                            n_kv_heads=1, d_ff=64, vocab=64),
                       OMCConfig.parse("S1E3M7"), fedavg(1.0))
    with pytest.raises(Exception):
        ck.restore_state(ck.latest_checkpoint(str(tmp_path))[0], other)
