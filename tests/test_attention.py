"""Chunked online-softmax attention vs a naive reference; cache semantics."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models import attention as attn


def _naive(q, k, v, causal=True, window=None):
    b, sq, h, hd = q.shape
    _, sk, kvh, _ = k.shape
    g = h // kvh
    qg = q.reshape(b, sq, kvh, g, hd) / np.sqrt(hd)
    s = jnp.einsum("bqhgd,bkhd->bqhgk", qg, k)
    qi = jnp.arange(sq)[:, None]
    ki = jnp.arange(sk)[None, :]
    mask = jnp.ones((sq, sk), bool)
    if causal:
        mask &= ki <= qi
    if window is not None:
        mask &= ki > qi - window
    s = jnp.where(mask[None, :, None, None, :], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bqhgk,bkhd->bqhgd", p, v)
    return o.reshape(b, sq, h, hd)


@pytest.mark.parametrize("causal,window", [(True, None), (True, 8), (False, None)])
@pytest.mark.parametrize("hkv", [(4, 4), (4, 2), (6, 1)])
def test_attend_matches_naive(causal, window, hkv):
    h, kvh = hkv
    b, s, hd = 2, 32, 16
    key = jax.random.PRNGKey(0)
    q = jax.random.normal(key, (b, s, h, hd))
    k = jax.random.normal(jax.random.fold_in(key, 1), (b, s, kvh, hd))
    v = jax.random.normal(jax.random.fold_in(key, 2), (b, s, kvh, hd))
    pos = jnp.broadcast_to(jnp.arange(s), (b, s))
    got = attn.attend(q, k, v, pos, pos, causal=causal, window=window,
                      q_block=8, kv_block=8)
    want = _naive(q, k, v, causal, window)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-5, atol=2e-5)


def test_decode_attend_matches_last_row_of_train_attention():
    b, s, h, kvh, hd = 2, 16, 4, 2, 8
    key = jax.random.PRNGKey(3)
    q = jax.random.normal(key, (b, s, h, hd))
    k = jax.random.normal(jax.random.fold_in(key, 1), (b, s, kvh, hd))
    v = jax.random.normal(jax.random.fold_in(key, 2), (b, s, kvh, hd))
    pos = jnp.broadcast_to(jnp.arange(s), (b, s))
    full = _naive(q, k, v, causal=True)
    got = attn.decode_attend(q[:, -1:], k, v, pos, jnp.int32(s - 1))
    np.testing.assert_allclose(np.asarray(got), np.asarray(full[:, -1:]),
                               rtol=2e-5, atol=2e-5)


def test_ring_cache_insert_overwrites_oldest():
    b, buf, kvh, hd = 1, 4, 1, 2
    k = jnp.zeros((b, buf, kvh, hd))
    v = jnp.zeros((b, buf, kvh, hd))
    pos = jnp.full((b, buf), -1, jnp.int32)
    for p in range(6):
        newk = jnp.full((b, 1, kvh, hd), float(p))
        k, v, pos = attn.cache_insert(k, v, pos, newk, newk, jnp.int32(p),
                                      ring=True)
    # positions 2..5 should be resident; slot = pos % buf
    assert sorted(np.asarray(pos[0]).tolist()) == [2, 3, 4, 5]
    for slot in range(buf):
        p = int(pos[0, slot])
        assert p % buf == slot
        assert float(k[0, slot, 0, 0]) == float(p)


def test_pick_q_block_divisibility():
    from repro.models.attention import _pick_q_block
    # nq must be a multiple of the mesh axis when divisible
    assert 4096 % _pick_q_block(4096, 512, 16) == 0
    assert (4096 // _pick_q_block(4096, 512, 16)) % 16 == 0
    assert (32768 // _pick_q_block(32768, 512, 16)) % 16 == 0
    # no mesh: plain target
    assert _pick_q_block(4096, 512, 1) == 512
    # awkward sizes fall back to any divisor
    assert 24 % _pick_q_block(24, 512, 16) == 0
