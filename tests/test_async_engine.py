"""Async runtime vs sync engine: equivalence gate, staleness weights, wire.

The contract under test (DESIGN.md §10): with ``buffer_goal == cohort
size``, a zero-jitter FixedTrace, and staleness decay disabled, the
event-driven runtime degenerates to barrier-synchronous rounds — every
version's buffer holds exactly one fresh update per client — and must
reproduce the sync engine's server tree within the documented
one-quantization-step tolerance, with wire-byte accounting reconciling
byte-exactly against both the sync paths and the wire codec.  Plus: the
staleness-weight contract (property-tested), buffer-goal validation shared
with CohortPlan, trace determinism, max-staleness drops, and the
version-stamped async session protocol.
"""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from _hypothesis_stub import given, settings, st
from repro.api import codecs
from repro.api.session import FLSession
from repro.core.omc import OMCConfig
from repro.core.store import decompress_tree
from repro.data.synthetic import make_frame_task
from repro.federated import accounting, async_engine, engine, simulate, traces
from repro.federated.cohort import (
    CohortPlan,
    aggregate_weighted,
    validate_report_goal,
)
from repro.federated.state import compress_params

from repro.models import conformer as cf

CFG = cf.ConformerConfig(
    n_layers=2, d_model=32, n_heads=4, d_ff=64, n_classes=16, d_in=8
)
OMC = OMCConfig.parse("S1E3M7")
SIM = simulate.SimConfig(local_steps=2, client_lr=0.1)
C = 6  # equivalence cohort: population == cohort == buffer goal
TASK = make_frame_task(d_in=CFG.d_in, n_classes=CFG.n_classes, seq_len=16,
                       num_clients=64)
DATA_FN = lambda c, r, s: TASK.batch(c, r, s, 4)


def _async_run(num_clients, acfg, trace, flushes, wire=True, local_steps=2):
    sim = dataclasses.replace(SIM, local_steps=local_steps)
    return async_engine.run_async_training(
        cf, CFG, OMC, sim, acfg, trace, DATA_FN, jax.random.PRNGKey(0),
        num_clients=num_clients, flushes=flushes, wire=wire,
    )


# ---------------------------------------------------------------------------
# The equivalence gate
# ---------------------------------------------------------------------------


def test_async_matches_sync_engine_at_degenerate_trace():
    """buffer == cohort, zero jitter, decay off -> sync engine semantics."""
    plan = CohortPlan(num_clients=C, cohort_size=C)
    key = jax.random.PRNGKey(0)
    eng_storage, eng_hist = engine.run_training_vectorized(
        cf, CFG, OMC, SIM, engine.CohortSpec(plan), DATA_FN, key,
        num_rounds=3,
    )
    st_, hist, runner = _async_run(
        C, async_engine.AsyncConfig(buffer_goal=C),
        traces.FixedTrace(latency=1.0), flushes=3,
    )

    # every flush was a full fresh cohort: K updates, zero staleness
    for eh, ah in zip(eng_hist, hist):
        assert ah["buffer"] == C and ah["staleness_max"] == 0
        assert abs(eh["loss"] - ah["loss"]) < 1e-3
    # wire bytes reconcile byte-exactly with the sync engine's accounting
    assert hist[-1]["down_bytes"] == sum(h["down_bytes"] for h in eng_hist)
    assert hist[-1]["up_bytes"] == sum(h["up_bytes"] for h in eng_hist)
    assert hist[-1]["stale_up_bytes"] == 0
    assert hist[-1]["in_flight_bytes"] == 0

    # server trees agree within the one-quantization-step tolerance
    a, b = decompress_tree(eng_storage), decompress_tree(st_)
    for x, y in zip(jax.tree_util.tree_leaves(a),
                    jax.tree_util.tree_leaves(b)):
        d = np.abs(np.asarray(x) - np.asarray(y))
        assert d.max() <= 6e-3, d.max()
        assert d.mean() <= 1e-4, d.mean()


def test_async_fused_flush_matches_unfused():
    """fused_agg=True: the buffer holds transport-encoded uploads and the
    flush aggregates in the compressed domain (DESIGN.md §13).  Vs the
    unfused runtime: byte-exact ledgers, and server trees within ~one
    transport-quantization step *per flush* at each leaf's own scale (the
    per-element metric is meaningless here — the re-solved PVT offset shifts
    near-zero elements by many of their own tiny steps)."""
    sim = dataclasses.replace(SIM, local_steps=1)
    out = {}
    for fused in (False, True):
        out[fused] = async_engine.run_async_training(
            cf, CFG, OMC, sim, async_engine.AsyncConfig(buffer_goal=8),
            traces.FixedTrace(latency=1.0), DATA_FN, jax.random.PRNGKey(0),
            num_clients=8, flushes=2, wire=True, fused_agg=fused,
        )
    (u_st, u_hist, u_run), (f_st, f_hist, f_run) = out[False], out[True]
    for uh, fh in zip(u_hist, f_hist):
        assert uh["buffer"] == fh["buffer"]
        assert abs(uh["loss"] - fh["loss"]) < 1e-3
    assert u_run.stats.down_bytes == f_run.stats.down_bytes
    assert u_run.stats.up_bytes == f_run.stats.up_bytes
    a, b = decompress_tree(u_st), decompress_tree(f_st)
    for x, y in zip(jax.tree_util.tree_leaves(a),
                    jax.tree_util.tree_leaves(b)):
        x, y = np.asarray(x), np.asarray(y)
        d = np.abs(x - y)
        scale = max(np.abs(x).max(), np.abs(y).max(), 2.0 ** -6)
        # one S1E3M7 mantissa step at the leaf's magnitude
        step = 2.0 ** (np.floor(np.log2(scale)) - 7)
        assert d.max() <= 4 * step, (d.max(), step)  # ~1 step/flush + margin
        assert d.mean() <= step, (d.mean(), step)


def test_async_fused_validation():
    """Unsupported configs refuse loudly instead of silently falling back."""
    from repro.compress import TopKSparseStrategy

    with pytest.raises(ValueError):  # zoo strategy: incompatible wire form
        async_engine.AsyncRunner(
            cf, CFG, OMC, SIM, async_engine.AsyncConfig(buffer_goal=2),
            traces.FixedTrace(), num_clients=4, data_fn=DATA_FN,
            init_key=jax.random.PRNGKey(0), fused_agg=True,
            strategy=TopKSparseStrategy(),
        )
    f32 = OMCConfig.parse("S1E8M23", quantize_fraction=1.0)
    assert not f32.enabled
    with pytest.raises(ValueError):  # OMC disabled: nothing to fuse
        async_engine.AsyncRunner(
            cf, CFG, f32, SIM, async_engine.AsyncConfig(buffer_goal=2),
            traces.FixedTrace(), num_clients=4, data_fn=DATA_FN,
            init_key=jax.random.PRNGKey(0), fused_agg=True,
        )


def test_async_accounting_reconciles_with_codec():
    """The ledger's totals are codec payload sizes, byte for byte."""
    _, hist, runner = _async_run(
        C, async_engine.AsyncConfig(buffer_goal=C),
        traces.FixedTrace(latency=1.0), flushes=2,
    )
    table = runner.stats.table
    # 2 flushes x C clients, every download the full compressed state
    rep = codecs.payload_bytes_report(runner.storage)
    assert runner.stats.down_bytes == 2 * C * rep["wire_bytes"]
    assert rep["wire_bytes"] == table.download_bytes(OMC)
    # uploads: per-(version, client) PPQ-masked payloads — serialize one and
    # compare against what the ledger charged
    up = sum(
        accounting.client_upload_bytes(table, OMC, v, c)
        for v in (0, 1) for c in range(C)
    )
    assert runner.stats.up_bytes == up
    tree = engine.masked_upload_tree(
        decompress_tree(runner.storage), runner.specs, OMC, 1, 3
    )
    assert codecs.peek_payload(codecs.encode_payload(tree)).body_bytes == (
        accounting.client_upload_bytes(table, OMC, 1, 3)
    )


# ---------------------------------------------------------------------------
# Staleness-weight contract
# ---------------------------------------------------------------------------


@given(
    st.lists(st.integers(min_value=0, max_value=50), min_size=1, max_size=16),
    st.floats(min_value=0.0, max_value=4.0, allow_nan=False),
    st.sampled_from(["poly", "exp"]),
)
@settings(max_examples=50, deadline=None)
def test_buffer_weights_contract(staleness, decay, mode):
    """Non-negative, sum to 1 over the buffer, monotone in staleness."""
    s = np.asarray(staleness, np.float32)
    w = np.asarray(async_engine.buffer_weights(s, decay, mode))
    assert (w >= 0).all()
    assert w.sum() == pytest.approx(1.0, rel=1e-5)
    # monotone: staler entries never outweigh fresher ones
    order = np.argsort(s)
    assert (np.diff(w[order]) <= 1e-7).all()
    if (s == 0).all() or decay == 0.0:
        np.testing.assert_allclose(w, 1.0 / len(s), rtol=1e-6)


def test_zero_staleness_reduces_to_fedavg():
    """All-fresh buffer: the weighted aggregate IS the zero-weight FedAvg
    mean, bit-for-bit (weights are exactly 1.0, same op as the sync path)."""
    raw = np.asarray(
        async_engine.staleness_weights(np.zeros(5, np.float32), 1.5, "poly")
    )
    np.testing.assert_array_equal(raw, np.ones(5, np.float32))
    stacked = {"w": jnp.asarray(np.random.default_rng(0).normal(size=(5, 7)),
                                jnp.float32)}
    ones = jnp.ones((5,), jnp.float32)
    agg = aggregate_weighted(stacked, jnp.asarray(raw))
    ref = aggregate_weighted(stacked, ones)
    np.testing.assert_array_equal(np.asarray(agg["w"]), np.asarray(ref["w"]))


# ---------------------------------------------------------------------------
# Validation (regression: report_goal / buffer goal 0 or negative)
# ---------------------------------------------------------------------------


def test_report_goal_validation_regression():
    for bad in (0, -1, -100):
        with pytest.raises(ValueError):
            CohortPlan(num_clients=8, cohort_size=4, report_goal=bad)
        with pytest.raises(ValueError):
            validate_report_goal(bad, 4)
    with pytest.raises(ValueError):
        CohortPlan(num_clients=8, cohort_size=4, report_goal=5)  # > cohort
    with pytest.raises(ValueError):
        CohortPlan(num_clients=4, cohort_size=8)  # cohort > population
    assert CohortPlan(num_clients=8, cohort_size=4).report_goal == 4


def test_async_buffer_goal_uses_same_validation():
    for bad in (0, -3, 99):  # 99 > population of 4
        with pytest.raises(ValueError):
            async_engine.AsyncRunner(
                cf, CFG, OMC, SIM,
                async_engine.AsyncConfig(buffer_goal=bad),
                traces.FixedTrace(), num_clients=4, data_fn=DATA_FN,
                init_key=jax.random.PRNGKey(0),
            )
    with pytest.raises(ValueError):
        async_engine.AsyncConfig(buffer_goal=2, decay=-1.0)
    with pytest.raises(ValueError):
        async_engine.AsyncConfig(buffer_goal=2, decay_mode="nope")


# ---------------------------------------------------------------------------
# Traces
# ---------------------------------------------------------------------------


def test_traces_deterministic_and_shaped():
    p = traces.ParetoTrace(seed=7, latency=2.0, alpha=1.2)
    xs = [p.round_latency(3, k, 0.0) for k in range(200)]
    assert xs == [p.round_latency(3, k, 0.0) for k in range(200)]  # replay
    assert min(xs) >= 2.0  # scale-pinned minimum
    assert max(xs) > 3 * np.median(xs)  # heavy tail actually bites

    d = traces.DiurnalTrace(seed=1, interval=1.0, period=24.0, depth=0.9)
    delays = [d.checkin_delay(0, 0, t) for t in np.linspace(0, 24, 25)]
    assert max(delays) > 3 * min(delays)  # trough vs peak swing

    t = traces.TieredTrace(
        base=traces.FixedTrace(latency=1.0),
        profiles=(engine.profile("f32"), engine.profile("s1e3m7")),
    )
    assert t.round_latency(0, 0, 0.0) == pytest.approx(1.0)  # f32 tier
    assert t.round_latency(1, 0, 0.0) > 1.5  # compressed tier is slower
    assert t.tier_of(4) == 0 and t.tier_of(5) == 1  # engine striping


def test_repeat_rounds_under_one_version_draw_fresh_data():
    """Regression: a fast client's second round under an unchanged server
    version must key data/PPQ by its own round counter, not the version —
    otherwise the buffer aggregates bit-identical duplicate updates."""
    runner = async_engine.AsyncRunner(
        cf, CFG, OMC, dataclasses.replace(SIM, local_steps=1),
        async_engine.AsyncConfig(buffer_goal=4),
        # odd clients 10x slower: the fast pair cycles twice under v0
        # before the buffer ever reaches K
        traces.TieredTrace(latency=1.0, multipliers=(1.0, 10.0)),
        num_clients=4, data_fn=DATA_FN, init_key=jax.random.PRNGKey(0),
    )
    runner.run_until(uploads=3)  # one short of the flush: inspect the buffer
    assert runner.version == 0  # nothing flushed; all rounds under v0
    assert runner.round_counters[0] == 2  # fast client started 2 rounds
    by_client = {}
    for e in runner.buffer:
        by_client.setdefault(e.client_id, []).append(e.model)
    pair = next(ms for ms in by_client.values() if len(ms) == 2)
    diffs = [
        float(np.abs(np.asarray(a) - np.asarray(b)).max())
        for a, b in zip(jax.tree_util.tree_leaves(pair[0]),
                        jax.tree_util.tree_leaves(pair[1]))
    ]
    assert max(diffs) > 0.0, "second round produced a bit-identical update"


def test_tiered_trace_forwards_own_fields():
    t = traces.TieredTrace(latency=5.0, multipliers=(1.0, 2.0))
    assert t.round_latency(0, 0, 0.0) == pytest.approx(5.0)
    assert t.round_latency(1, 0, 0.0) == pytest.approx(10.0)
    with pytest.raises(ValueError):  # explicit base + own timing fields
        traces.TieredTrace(latency=5.0, base=traces.FixedTrace(),
                           multipliers=(1.0, 2.0))


def test_max_staleness_drops_and_stale_bytes():
    """A 2-tier trace where odd clients are 40x slower: their uploads arrive
    stale; with max_staleness=0 they are dropped (bytes ledgered as waste),
    without it they land with decayed weight."""
    trace = traces.TieredTrace(base=traces.FixedTrace(latency=1.0),
                               multipliers=(1.0, 3.5))
    _, hist, runner = _async_run(
        4, async_engine.AsyncConfig(buffer_goal=2, decay=1.0,
                                    max_staleness=0),
        trace, flushes=6, local_steps=1,
    )
    assert runner.dropped_stale > 0
    assert runner.stats.dropped_up_bytes > 0
    assert runner.stats.n_stale == 0  # dropped, never aggregated

    _, hist2, runner2 = _async_run(
        4, async_engine.AsyncConfig(buffer_goal=2, decay=1.0), trace,
        flushes=6, local_steps=1,
    )
    assert runner2.dropped_stale == 0
    assert runner2.stats.stale_up_bytes > 0  # aggregated, flagged stale
    assert any(h["staleness_max"] > 0 for h in hist2)


def test_in_flight_accounting():
    _, _, runner = _async_run(
        4, async_engine.AsyncConfig(buffer_goal=4),
        traces.FixedTrace(latency=1.0), flushes=1,
    )
    # quiescent right after the flush: nothing in flight, peak was the
    # full concurrent cohort (download + committed upload per client)
    assert runner.stats.in_flight_bytes == 0
    table = runner.stats.table
    expect_peak = sum(
        table.download_bytes(OMC)
        + accounting.client_upload_bytes(table, OMC, 0, c)
        for c in range(4)
    )
    assert runner.stats.peak_in_flight_bytes == expect_peak
    # drive half a generation: 4 check-ins land, uploads not yet arrived
    runner.run_until(time_limit=1.5)
    assert len(runner.pending) == 4
    assert runner.stats.in_flight_bytes > 0


# ---------------------------------------------------------------------------
# Async session protocol (version-stamped tickets over the real codec)
# ---------------------------------------------------------------------------


def _client_train(tree, factor=0.9):
    # perturb only the first leaf: round-over-round change stays sparse, so
    # delta downloads genuinely beat full payloads (the case under test)
    params = decompress_tree(tree)
    leaves, treedef = jax.tree_util.tree_flatten(params)
    return jax.tree_util.tree_unflatten(
        treedef, [leaves[0] * factor] + leaves[1:]
    )


def test_async_session_full_and_delta_reconcile():
    from repro.models import transformer as tr

    tcfg = tr.TransformerConfig(n_layers=1, d_model=32, n_heads=2,
                                n_kv_heads=1, d_ff=64, vocab=64)
    omc = OMCConfig.parse("S1E3M7")
    specs = tr.param_specs(tcfg)
    sess = FLSession(tr, tcfg, omc,
                     plan=CohortPlan(num_clients=4, cohort_size=3))
    sess.enable_async(2, decay=1.0)

    def upload_for(ticket, held=None):
        held_digest = codecs.tree_digest(held) if held is not None else 0
        blob = ticket.payload_for(held_digest=held_digest)
        tree, info = codecs.decode_payload(blob, base=held)
        trained = _client_train(tree)
        up_tree = compress_params(trained, specs, omc)
        up = codecs.encode_payload(up_tree, base=tree,
                                   round_index=ticket.server_version)
        return tree, blob, up, info

    # --- version 0: two fresh clients fill the buffer --------------------
    t0, t1, t2 = sess.checkin(0), sess.checkin(1), sess.checkin(2)
    assert t0.server_version == 0 and t0.delta_payload is None
    tree0, blob0, up0, info0 = upload_for(t0)
    assert not info0.is_delta  # first download is a full payload
    # full download body == the codec's byte report of the server state
    assert codecs.peek_payload(blob0).body_bytes == (
        codecs.payload_bytes_report(sess._version_storages[0])["wire_bytes"]
    )
    _, blob1, up1, _ = upload_for(t1)
    sess.ingest_async(0, up0)
    assert sess.server_version == 0  # buffer at 1/2
    sess.ingest_async(1, up1)
    assert sess.server_version == 1  # flushed
    down_so_far = len(blob0) + len(blob1)
    assert sess.traffic["down_bytes"] == down_so_far
    assert sess.traffic["up_bytes"] == len(up0) + len(up1)

    # --- client 2's ticket (v0) is now stale; its upload still decodes
    # against the v0 base the ticket pinned --------------------------------
    tree2, blob2, up2, _ = upload_for(t2)
    sess.ingest_async(2, up2)
    assert sess.server_version == 1 and len(sess._async_buffer) == 1

    # --- returning client takes a delta against its held version ---------
    t0b = sess.checkin(0, held_version=0)
    assert t0b.delta_payload is not None
    held = tree0  # what client 0 decoded at v0
    blob = t0b.payload_for(held_digest=codecs.tree_digest(held))
    assert t0b.took_delta and len(blob) < len(t0b.payload)
    tree, info = codecs.decode_payload(blob, base=held)
    assert info.is_delta
    # delta decodes to exactly the current server state
    full_now = codecs.decode_payload(t0b.payload)[0]
    for x, y in zip(jax.tree_util.tree_leaves(tree),
                    jax.tree_util.tree_leaves(full_now)):
        a, b = x, y
        if hasattr(a, "codes"):
            np.testing.assert_array_equal(np.asarray(a.codes),
                                          np.asarray(b.codes))
        else:
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    # issued bytes are the delta's, folded into traffic at ingestion
    up_tree = compress_params(_client_train(tree), specs, omc)
    before = sess.traffic["down_bytes"]
    sess.ingest_async(0, codecs.encode_payload(
        up_tree, base=tree, round_index=t0b.server_version))
    assert sess.traffic["down_bytes"] == before + len(blob)


def test_async_session_guards():
    from repro.models import transformer as tr

    tcfg = tr.TransformerConfig(n_layers=1, d_model=32, n_heads=2,
                                n_kv_heads=1, d_ff=64, vocab=64)
    sess = FLSession(tr, tcfg, OMCConfig.parse("S1E3M7"),
                     plan=CohortPlan(num_clients=4, cohort_size=2))
    with pytest.raises(RuntimeError):
        sess.checkin(0)  # enable_async first
    with pytest.raises(ValueError):
        sess.enable_async(0)  # same gate as report_goal
    with pytest.raises(ValueError):
        sess.enable_async(3)  # > plan.cohort_size
    sess.enable_async(2)
    sess.checkin(0)
    with pytest.raises(RuntimeError):
        sess.checkin(0)  # one open ticket per client
    with pytest.raises(KeyError):
        sess.ingest_async(3, b"")  # never checked in
