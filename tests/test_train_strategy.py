"""Training under zoo strategies: equivalence gates across all three paths.

The contract under test (DESIGN.md §12): every training path — the
reference loop, the vectorized engine, and the async runtime — accepts a
``strategy=`` and the following must hold:

* ``get_strategy("omc")`` is **bit-identical** to the hardcoded OMC qdq
  path: same server storage trees (codes, PVT scalars), same history rows,
  same wire-byte ledgers.  The strategy seam costs nothing.
* every zoo strategy trains equivalently on the loop and the engine at a
  failure-prone cohort of 8 (batched-op reassociation tolerance on trees,
  byte-exact wire accounting where the plan is shape-determined);
* error-feedback residuals are per-client state: identical across paths,
  checkpointable on the async runner, and required (a sparse EF strategy
  without residual state is a hard error, not a silent drop).
"""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import ckpt
from repro.compress import feedback, get_strategy
from repro.core.omc import OMCConfig
from repro.core.store import decompress_tree, is_compressed
from repro.data.synthetic import make_frame_task
from repro.federated import accounting, async_engine, engine, simulate, traces
from repro.federated.cohort import CohortPlan
from repro.federated.state import compress_params
from repro.models import conformer as cf

CFG = cf.ConformerConfig(
    n_layers=2, d_model=32, n_heads=4, d_ff=64, n_classes=16, d_in=8
)
OMC = OMCConfig.parse("S1E3M7")  # PPQ on: default quantize_fraction = 0.9
SIM = simulate.SimConfig(local_steps=2, client_lr=0.1)
PLAN = CohortPlan(num_clients=16, cohort_size=8, failure_rate=0.25)
TASK = make_frame_task(d_in=CFG.d_in, n_classes=CFG.n_classes, seq_len=24,
                       num_clients=PLAN.num_clients)
DATA_FN = lambda c, r, s: TASK.batch(c, r, s, 4)

C = 6  # async equivalence cohort: population == cohort == buffer goal


def assert_trees_bit_identical(a_storage, b_storage):
    """Storage trees agree bit for bit: codes, PVT scalars, raw leaves."""
    la = jax.tree_util.tree_leaves(a_storage, is_leaf=is_compressed)
    lb = jax.tree_util.tree_leaves(b_storage, is_leaf=is_compressed)
    assert len(la) == len(lb)
    for a, b in zip(la, lb):
        if is_compressed(a):
            assert is_compressed(b)
            assert np.array_equal(np.asarray(a.codes), np.asarray(b.codes))
            assert np.array_equal(np.asarray(a.s), np.asarray(b.s))
            assert np.array_equal(np.asarray(a.b), np.asarray(b.b))
        else:
            assert np.array_equal(np.asarray(a), np.asarray(b))


def assert_trees_close(a_f32, b_f32, max_abs=6e-3, mean_abs=1e-4):
    for a, b in zip(jax.tree_util.tree_leaves(a_f32),
                    jax.tree_util.tree_leaves(b_f32)):
        d = np.abs(np.asarray(a) - np.asarray(b))
        assert d.max() <= max_abs, d.max()
        assert d.mean() <= mean_abs, d.mean()


# ---------------------------------------------------------------------------
# Tentpole gate: strategy="omc" is bit-identical to the hardcoded path
# ---------------------------------------------------------------------------


@pytest.mark.tier1
def test_loop_omc_strategy_bit_identical():
    """Reference loop: OMCQuantStrategy reproduces today's bits exactly —
    storage trees, losses, and the per-round wire ledger."""
    key = jax.random.PRNGKey(0)
    base, hist0 = simulate.run_training(
        cf, CFG, OMC, SIM, PLAN, DATA_FN, key, num_rounds=2,
        eval_every=100, wire=True,
    )
    strat, hist1 = simulate.run_training(
        cf, CFG, OMC, SIM, PLAN, DATA_FN, key, num_rounds=2,
        eval_every=100, wire=True, strategy=get_strategy("omc"),
    )
    assert hist0 == hist1  # cohorts, losses, down_bytes, up_bytes — all of it
    assert_trees_bit_identical(base, strat)


@pytest.mark.tier1
def test_engine_omc_strategy_bit_identical():
    """Vectorized engine: same gate through the vmapped client body."""
    key = jax.random.PRNGKey(0)
    base, hist0 = engine.run_training_vectorized(
        cf, CFG, OMC, SIM, engine.CohortSpec(PLAN), DATA_FN, key,
        num_rounds=2, eval_every=100,
    )
    strat, hist1 = engine.run_training_vectorized(
        cf, CFG, OMC, SIM, engine.CohortSpec(PLAN), DATA_FN, key,
        num_rounds=2, eval_every=100, strategy=get_strategy("omc"),
    )
    assert hist0 == hist1
    assert_trees_bit_identical(base, strat)


@pytest.mark.tier1
def test_async_omc_strategy_bit_identical():
    """Async runtime at the degenerate trace: bit-identical storage and an
    identical AsyncWireStats ledger snapshot."""
    def run(strategy):
        return async_engine.run_async_training(
            cf, CFG, OMC, SIM, async_engine.AsyncConfig(buffer_goal=C),
            traces.FixedTrace(latency=1.0), DATA_FN, jax.random.PRNGKey(0),
            num_clients=C, flushes=2, wire=True, strategy=strategy,
        )
    st0, hist0, r0 = run(None)
    st1, hist1, r1 = run(get_strategy("omc"))
    assert hist0 == hist1
    assert r0.stats.snapshot() == r1.stats.snapshot()
    assert_trees_bit_identical(st0, st1)


# ---------------------------------------------------------------------------
# Zoo gate: every registered strategy, loop vs engine, cohort of 8
# ---------------------------------------------------------------------------

ZOO = {
    "omc": lambda: get_strategy("omc"),
    "topk": lambda: get_strategy("topk", density=0.25),
    "ternary": lambda: get_strategy("ternary"),
    "pipeline": lambda: get_strategy("pipeline"),
}


@pytest.mark.tier1
@pytest.mark.parametrize("name", sorted(ZOO))
def test_zoo_strategy_loop_engine_equivalence(name):
    """Loop and engine agree under every zoo strategy: trees within the
    batched-op tolerance, wire ledgers byte-exact (where shape-determined),
    error-feedback residuals shared bit-for-bit between the paths."""
    strategy = ZOO[name]()
    # pipeline's DEFLATE stage is data-dependent: no shape-determined wire
    # plan, so the accounting layer refuses it (tested below) — train wireless
    wire = name != "pipeline"
    key = jax.random.PRNGKey(0)
    specs = cf.param_specs(CFG)
    params = cf.init(key, CFG)
    takes_ef = feedback.takes_residual(OMC, strategy)
    if takes_ef:
        ef_loop = feedback.init_ef_state(params, specs, OMC, PLAN.num_clients)
        ef_eng = feedback.init_ef_state(params, specs, OMC, PLAN.num_clients)

    ref, hist_l = simulate.run_training(
        cf, CFG, OMC, SIM, PLAN, DATA_FN, key, num_rounds=2, eval_every=100,
        wire=wire, strategy=strategy,
        ef=ef_loop if takes_ef else None,
    )
    eng, hist_e = engine.run_training_vectorized(
        cf, CFG, OMC, SIM, engine.CohortSpec(PLAN), DATA_FN, key,
        num_rounds=2, eval_every=100, wire=wire, strategy=strategy,
        ef=ef_eng if takes_ef else None,
    )
    for rl, re in zip(hist_l, hist_e):
        assert rl["cohort"] == re["cohort"]
        assert rl["dropped"] == re["dropped"]
        assert abs(rl["loss"] - re["loss"]) < 1e-3
        if wire:
            assert rl["down_bytes"] == re["down_bytes"]
            assert rl["up_bytes"] == re["up_bytes"]
    assert_trees_close(decompress_tree(ref), decompress_tree(eng))
    if takes_ef:
        assert set(ef_loop) == set(ef_eng)
        for k in ef_loop:
            d = np.abs(np.asarray(ef_loop[k]) - np.asarray(ef_eng[k]))
            assert d.max() <= 1e-6, (k, d.max())


@pytest.mark.tier1
def test_sparse_strategy_upload_cheaper_than_dense():
    """The ledger shows sparsification: top-k at density 0.05 (5% of
    coordinates, 8 bytes each) uploads fewer bytes per round than the dense
    OMC plan (11 bits for every coordinate) for the same model."""
    key = jax.random.PRNGKey(0)
    _, h_omc = simulate.run_training(
        cf, CFG, OMC, SIM, PLAN, DATA_FN, key, num_rounds=1,
        eval_every=100, wire=True,
    )
    _, h_topk = simulate.run_training(
        cf, CFG, OMC, SIM, PLAN, DATA_FN, key, num_rounds=1,
        eval_every=100, wire=True,
        strategy=get_strategy("topk", density=0.05),
    )
    assert h_topk[0]["up_bytes"] < h_omc[0]["up_bytes"]
    # downloads are the at-rest OMC state either way (upload-only strategy)
    assert h_topk[0]["down_bytes"] == h_omc[0]["down_bytes"]


@pytest.mark.tier1
def test_pipeline_wire_accounting_refused():
    """Data-dependent plans (DEFLATE) cannot be shape-priced: wire=True under
    the pipeline strategy is a loud ValueError, not a silent wrong number."""
    with pytest.raises(ValueError, match="[Dd]ata-dependent|DEFLATE|pipeline"):
        simulate.run_training(
            cf, CFG, OMC, SIM, PLAN, DATA_FN, jax.random.PRNGKey(0),
            num_rounds=1, eval_every=100, wire=True,
            strategy=ZOO["pipeline"](),
        )


@pytest.mark.tier1
def test_run_round_requires_ef_state():
    """An EF strategy handed to run_round without residual state is a hard
    error — dropping the residuals would silently change the math."""
    key = jax.random.PRNGKey(0)
    specs = cf.param_specs(CFG)
    params = cf.init(key, CFG)
    storage = compress_params(params, specs, OMC)
    with pytest.raises(ValueError, match="error.feedback|ef"):
        simulate.run_round(
            cf, CFG, specs, OMC, SIM, storage, DATA_FN, PLAN, 0, key,
            strategy=ZOO["topk"](), ef=None,
        )


# ---------------------------------------------------------------------------
# Error-feedback state is checkpointable on the async runner
# ---------------------------------------------------------------------------


@pytest.mark.tier1
def test_async_ef_checkpoint_roundtrip(tmp_path):
    """Save mid-run with EF residuals, restore into a fresh runner, continue:
    bit-identical to the uninterrupted run."""
    def make_runner():
        return async_engine.AsyncRunner(
            cf, CFG, OMC, SIM, async_engine.AsyncConfig(buffer_goal=C),
            traces.FixedTrace(latency=1.0), num_clients=C, data_fn=DATA_FN,
            init_key=jax.random.PRNGKey(0), wire=True,
            strategy=ZOO["topk"](),
        )

    ref = make_runner()
    ref.run_until(flushes=1)
    path = ckpt.save_async_state(str(tmp_path), ref)
    ref.run_until(flushes=1)

    res = make_runner()
    ckpt.restore_async_state(path, res)
    res.run_until(flushes=1)

    assert_trees_bit_identical(ref.storage, res.storage)
    assert set(ref.ef) == set(res.ef)
    for k in ref.ef:
        assert np.array_equal(np.asarray(ref.ef[k]), np.asarray(res.ef[k]))
    assert ref.stats.snapshot() == res.stats.snapshot()

    # a strategy-less runner must refuse an EF checkpoint (and vice versa)
    plain = async_engine.AsyncRunner(
        cf, CFG, OMC, SIM, async_engine.AsyncConfig(buffer_goal=C),
        traces.FixedTrace(latency=1.0), num_clients=C, data_fn=DATA_FN,
        init_key=jax.random.PRNGKey(0), wire=True,
    )
    with pytest.raises(ValueError, match="strategy"):
        ckpt.restore_async_state(path, plain)


# ---------------------------------------------------------------------------
# Slow convergence gate: error feedback earns its residual memory
# ---------------------------------------------------------------------------


@pytest.mark.slow
def test_ef_topk_beats_plain_topk_convergence():
    """At matched wire bytes (same density), EF top-k reaches a lower loss
    than plain top-k — the residual memory recovers the discarded mass."""
    rounds = 16
    key = jax.random.PRNGKey(0)
    plan = CohortPlan(num_clients=16, cohort_size=8)
    spec = engine.CohortSpec(plan)

    def run(error_feedback):
        strategy = get_strategy("topk", density=0.05,
                                error_feedback=error_feedback)
        _, hist = engine.run_training_vectorized(
            cf, CFG, OMC, SIM, spec, DATA_FN, key, num_rounds=rounds,
            eval_every=100, strategy=strategy,
        )
        return float(np.mean([h["loss"] for h in hist[-4:]]))

    loss_ef = run(True)
    loss_plain = run(False)
    assert loss_ef < loss_plain, (loss_ef, loss_plain)
