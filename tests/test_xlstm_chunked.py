"""Chunkwise-parallel mLSTM == sequential recurrence (§Perf hillclimb 1)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models.xlstm import XLSTMConfig, _mlstm_chunked, _mlstm_scan


def _inputs(b=2, s=48, h=3, dk=16, dv=16, seed=0):
    key = jax.random.PRNGKey(seed)
    q = jax.random.normal(key, (b, s, h, dk))
    k = jax.random.normal(jax.random.fold_in(key, 1), (b, s, h, dk)) / 4
    v = jax.random.normal(jax.random.fold_in(key, 2), (b, s, h, dv))
    ip = jax.random.normal(jax.random.fold_in(key, 3), (b, s, h))
    fp = jax.random.normal(jax.random.fold_in(key, 4), (b, s, h)) + 2.0
    return q, k, v, ip, fp


@pytest.mark.parametrize("chunk", [1, 7, 16, 48])
def test_chunked_equals_sequential(chunk):
    q, k, v, ip, fp = _inputs()
    h1, st1 = _mlstm_scan(q, k, v, ip, fp, None)
    h2, st2 = _mlstm_chunked(q, k, v, ip, fp, None, chunk=chunk)
    np.testing.assert_allclose(np.asarray(h1), np.asarray(h2),
                               rtol=2e-4, atol=2e-5)
    for a, c in zip(st1, st2):
        np.testing.assert_allclose(np.asarray(a), np.asarray(c),
                                   rtol=2e-4, atol=2e-5)


def test_state_carryover_matches():
    q, k, v, ip, fp = _inputs(s=64)
    _, stA = _mlstm_scan(q[:, :40], k[:, :40], v[:, :40], ip[:, :40],
                         fp[:, :40], None)
    _, stB = _mlstm_chunked(q[:, :40], k[:, :40], v[:, :40], ip[:, :40],
                            fp[:, :40], None, chunk=8)
    hA, _ = _mlstm_scan(q[:, 40:], k[:, 40:], v[:, 40:], ip[:, 40:],
                        fp[:, 40:], stA)
    hB, _ = _mlstm_chunked(q[:, 40:], k[:, 40:], v[:, 40:], ip[:, 40:],
                           fp[:, 40:], stB, chunk=8)
    np.testing.assert_allclose(np.asarray(hA), np.asarray(hB),
                               rtol=2e-4, atol=2e-5)


def test_full_model_forward_equivalence():
    """End-to-end: chunked config loss == recurrent config loss."""
    import dataclasses
    from repro.models import xlstm
    from repro.models.common import IDENTITY_MAT

    cfg_r = XLSTMConfig(n_layers=3, d_model=32, n_heads=2, vocab=64,
                        slstm_every=3, mlstm_impl="recurrent")
    cfg_c = dataclasses.replace(cfg_r, mlstm_impl="chunked", mlstm_chunk=8)
    params = xlstm.init(jax.random.PRNGKey(0), cfg_r)
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 25), 0, 64)
    batch = dict(tokens=toks[:, :-1], labels=toks[:, 1:])
    l_r = xlstm.loss(cfg_r, params, batch, IDENTITY_MAT)
    l_c = xlstm.loss(cfg_c, params, batch, IDENTITY_MAT)
    np.testing.assert_allclose(float(l_r), float(l_c), rtol=1e-4)
