"""Unit + property tests for the OMC minifloat codec and bit packing."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_stub import given, settings, st

from repro.core.formats import FP32, FloatFormat, decode, encode, qdq_ste, value_quantize
from repro.core.packing import pack, packed_bytes, packed_words, unpack

FORMATS = [
    FloatFormat.parse(s)
    for s in ["S1E2M3", "S1E3M7", "S1E4M8", "S1E5M7", "S1E3M9", "S1E4M14", "S1E5M10", "S1E8M7", "S1E8M23"]
]


def _rand(n=4096, seed=0, scale=4.0):
    rng = np.random.default_rng(seed)
    return jnp.asarray(rng.normal(size=(n,)).astype(np.float32) * scale)


@pytest.mark.parametrize("fmt", FORMATS, ids=lambda f: f.name)
def test_codec_roundtrip_exact(fmt):
    """decode(encode(x)) must equal the reduce_precision value oracle."""
    x = _rand()
    vq = value_quantize(x, fmt)
    back = decode(encode(x, fmt), fmt)
    np.testing.assert_array_equal(np.asarray(vq), np.asarray(back))


@pytest.mark.parametrize("fmt", FORMATS, ids=lambda f: f.name)
def test_codec_idempotent(fmt):
    x = _rand(seed=1)
    once = value_quantize(x, fmt)
    twice = value_quantize(once, fmt)
    np.testing.assert_array_equal(np.asarray(once), np.asarray(twice))


def test_fp16_and_bf16_equivalence():
    x = _rand(seed=2, scale=100.0)
    f16 = np.asarray(x).astype(np.float16).astype(np.float32)
    np.testing.assert_array_equal(
        np.asarray(value_quantize(x, FloatFormat(5, 10))), f16
    )
    bf16 = np.asarray(x).astype(jnp.bfloat16).astype(np.float32)
    np.testing.assert_array_equal(
        np.asarray(value_quantize(x, FloatFormat(8, 7))), bf16
    )


def test_identity_format_is_lossless():
    x = _rand(seed=3, scale=1e20)
    np.testing.assert_array_equal(np.asarray(value_quantize(x, FP32)), np.asarray(x))
    np.testing.assert_array_equal(np.asarray(decode(encode(x, FP32), FP32)), np.asarray(x))


def test_saturation_not_inf():
    fmt = FloatFormat(5, 10)
    x = jnp.asarray([1e9, -1e9, np.inf, -np.inf], jnp.float32)
    vq = np.asarray(value_quantize(x, fmt))
    assert np.all(np.isfinite(vq))
    np.testing.assert_array_equal(vq, [65504.0, -65504.0, 65504.0, -65504.0])


def test_nan_propagates():
    fmt = FloatFormat(4, 3)
    x = jnp.asarray([np.nan, 1.0], jnp.float32)
    vq = np.asarray(decode(encode(x, fmt), fmt))
    assert np.isnan(vq[0]) and vq[1] == 1.0


def test_subnormals_supported():
    fmt = FloatFormat(5, 10)  # min normal 2^-14, subnormal step 2^-24
    x = jnp.asarray(
        [2.0**-15, -(2.0**-15), 2.0**-24, 2.0**-26, -(2.0**-26), 2.0**-14],
        jnp.float32,
    )
    vq = np.asarray(value_quantize(x, fmt))
    np.testing.assert_array_equal(
        vq, [2.0**-15, -(2.0**-15), 2.0**-24, 0.0, -0.0, 2.0**-14]
    )
    back = np.asarray(decode(encode(x, fmt), fmt))
    np.testing.assert_array_equal(back, vq)
    assert np.signbit(back[4]) and back[4] == 0.0  # signed zero survives


def test_subnormals_matter_for_small_weights():
    """S1E4 formats: min-normal 2^-6 would flush init-scale weights under FTZ."""
    fmt = FloatFormat(4, 14)
    rng = np.random.default_rng(0)
    w = jnp.asarray(rng.normal(size=(4096,), scale=0.02).astype(np.float32))
    vq = np.asarray(value_quantize(w, fmt))
    zero_frac = float(np.mean(vq == 0))
    assert zero_frac < 1e-3  # with FTZ this would be ~50%
    rel = np.abs(vq - np.asarray(w)) / np.maximum(np.abs(np.asarray(w)), 1e-12)
    assert float(np.median(rel)) < 2.0**-13


def test_rne_rounding():
    fmt = FloatFormat(8, 1)  # mantissa {1.0, 1.5} × 2^e
    x = jnp.asarray([1.25, 1.75, 1.2499999, 1.7500001], jnp.float32)
    vq = np.asarray(value_quantize(x, fmt))
    np.testing.assert_array_equal(vq, [1.0, 2.0, 1.0, 2.0])  # ties to even


def test_container_dtypes():
    assert FloatFormat(2, 3).container_dtype == jnp.uint8
    assert FloatFormat(3, 7).container_dtype == jnp.uint16
    assert FloatFormat(4, 14).container_dtype == jnp.uint32
    assert FloatFormat(8, 23).container_dtype == jnp.uint32


def test_parse_and_name():
    f = FloatFormat.parse("s1e3m7")
    assert f.name == "S1E3M7" and f.bits == 11
    with pytest.raises(ValueError):
        FloatFormat.parse("E3M7")
    with pytest.raises(ValueError):
        FloatFormat(9, 3)


def test_qdq_ste_gradient_is_identity():
    fmt = FloatFormat(2, 3)
    g = jax.grad(lambda x: jnp.sum(qdq_ste(x, fmt) ** 2))(jnp.asarray([0.3, 1.7]))
    # d/dx sum(qdq(x)^2) with STE = 2*qdq(x)
    np.testing.assert_allclose(
        np.asarray(g), 2 * np.asarray(value_quantize(jnp.asarray([0.3, 1.7]), fmt))
    )


# ---------------------------------------------------------------------------
# Property tests (hypothesis)
# ---------------------------------------------------------------------------

finite_f32 = st.floats(allow_nan=False, allow_infinity=False, width=32)


@settings(max_examples=200, deadline=None)
@given(st.lists(finite_f32, min_size=1, max_size=64), st.sampled_from(FORMATS))
def test_prop_roundtrip_matches_oracle(vals, fmt):
    x = jnp.asarray(np.array(vals, np.float32))
    np.testing.assert_array_equal(
        np.asarray(decode(encode(x, fmt), fmt)), np.asarray(value_quantize(x, fmt))
    )


@settings(max_examples=100, deadline=None)
@given(st.lists(finite_f32, min_size=1, max_size=64))
def test_prop_error_shrinks_with_more_mantissa_bits(vals):
    """More mantissa bits at equal exponent bits never increases max error."""
    x = np.array(vals, np.float32)
    xj = jnp.asarray(x)
    errs = []
    for z in (3, 7, 14):
        fmt = FloatFormat(4, z)
        xc = np.clip(x, -fmt.max_normal, fmt.max_normal)
        errs.append(float(np.max(np.abs(np.asarray(value_quantize(xj, fmt)) - xc))))
    assert errs[0] >= errs[1] >= errs[2]


@settings(max_examples=100, deadline=None)
@given(
    st.integers(min_value=1, max_value=300),
    st.integers(min_value=1, max_value=32),
    st.integers(min_value=0, max_value=2**32 - 1),
)
def test_prop_pack_unpack_roundtrip(n, width, seed):
    rng = np.random.default_rng(seed)
    maxv = (1 << width) - 1 if width < 32 else 0xFFFFFFFF
    codes = jnp.asarray(rng.integers(0, maxv + 1, size=(n,), dtype=np.uint64).astype(np.uint32))
    words = pack(codes, width)
    assert words.shape[0] == packed_words(n, width)
    out = unpack(words, width, n)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(codes))
