"""Hypothesis property tests on system invariants."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis", reason="property-test module; all tests need hypothesis")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.core.formats import FloatFormat, decode, encode, value_quantize
from repro.core.packing import pack, packed_words, unpack
from repro.federated.cohort import CohortPlan, survival_mask
from repro.models.common import resolve_spec

fmt_st = st.builds(FloatFormat, st.integers(2, 8), st.integers(1, 23))


@settings(max_examples=40, deadline=None)
@given(fmt_st, st.integers(1, 300), st.integers(0, 2**31 - 1))
def test_encode_decode_roundtrip(fmt, n, seed):
    """decode(encode(q)) == q for every representable value."""
    x = jax.random.normal(jax.random.PRNGKey(seed), (n,)) * 4.0
    q = value_quantize(x, fmt)
    rt = decode(encode(q, fmt, quantize=False), fmt)
    np.testing.assert_array_equal(np.asarray(rt), np.asarray(q))


@settings(max_examples=40, deadline=None)
@given(fmt_st, st.integers(1, 200), st.integers(0, 2**31 - 1))
def test_quantize_idempotent_and_bounded(fmt, n, seed):
    x = jax.random.normal(jax.random.PRNGKey(seed), (n,)) * 10.0
    q = value_quantize(x, fmt)
    # idempotent
    np.testing.assert_array_equal(np.asarray(value_quantize(q, fmt)),
                                  np.asarray(q))
    # saturating: no infs, and |q| <= max_normal
    assert np.isfinite(np.asarray(q)).all()
    assert (np.abs(np.asarray(q)) <= fmt.max_normal + 1e-30).all()
    # error bounded by one subnormal step or relative half-ulp
    err = np.abs(np.asarray(q) - np.clip(np.asarray(x), -fmt.max_normal,
                                         fmt.max_normal))
    bound = np.maximum(np.abs(np.asarray(x)) * 2.0 ** (-fmt.mant_bits),
                       fmt.subnormal_step)
    assert (err <= bound * 0.5 + 1e-30).all()


@settings(max_examples=40, deadline=None)
@given(st.integers(1, 32), st.integers(1, 500), st.integers(0, 2**31 - 1))
def test_pack_unpack_roundtrip(width, n, seed):
    mask = jnp.uint32((1 << width) - 1 if width < 32 else 0xFFFFFFFF)
    codes = jax.random.bits(jax.random.PRNGKey(seed), (n,), jnp.uint32) & mask
    words = pack(codes, width)
    assert words.shape[0] == packed_words(n, width)
    rt = unpack(words, width, n)
    np.testing.assert_array_equal(np.asarray(rt), np.asarray(codes))


@settings(max_examples=30, deadline=None)
@given(st.integers(2, 64), st.integers(1, 64),
       st.floats(0, 0.9), st.floats(0, 0.9), st.integers(0, 100))
def test_survival_mask_invariants(cohort, goal, fail, straggle, rnd):
    goal = min(goal, cohort)
    plan = CohortPlan(num_clients=cohort * 2, cohort_size=cohort,
                      report_goal=goal, failure_rate=fail,
                      straggler_rate=straggle)
    m = survival_mask(jax.random.PRNGKey(7), plan, rnd)
    assert 1 <= int(m.sum()) <= goal


@settings(max_examples=30, deadline=None)
@given(st.integers(1, 4096), st.integers(1, 4096))
def test_resolve_spec_divisibility(a, b):
    """resolve_spec never assigns a mesh axis that doesn't divide the dim."""
    import os
    mesh = jax.sharding.Mesh(
        np.array(jax.devices()[:1]).reshape(1, 1), ("data", "model")
    )
    spec = resolve_spec(["batch", "tensor"], [a, b], mesh)
    # on a (1,1) mesh everything resolves (1 divides everything)
    assert spec is not None
