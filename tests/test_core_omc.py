"""Tests for PVT, policy, PPQ, the compressed store, and the OMC API."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_stub import given, settings, st

from repro.core import (
    CompressedVariable,
    OMCConfig,
    QuantizePolicy,
    bytes_report,
    compress,
    compress_variable,
    coverage,
    decompress,
    effective_params,
    pack_for_transport,
    ppq_mask,
    ppq_masks_batch,
    pvt_apply,
    pvt_solve,
    quantizable_names,
    unpack_from_transport,
    value_quantize,
)
from repro.core.formats import FloatFormat


def _toy_params(seed=0):
    rng = np.random.default_rng(seed)
    f = lambda *s: jnp.asarray(rng.normal(size=s).astype(np.float32))
    return {
        "embed": {"table": f(512, 32)},
        "block0": {
            "attn": {"wq": f(32, 32), "wk": f(32, 32), "bias_q": f(32)},
            "norm": {"scale": jnp.ones((32,)), "bias": jnp.zeros((32,))},
            "mlp": {"w1": f(32, 128), "w2": f(128, 32)},
        },
        "rglru": {"a_param": f(4, 64)},
        "step": jnp.asarray(0, jnp.int32),
    }


# ---------------------------------------------------------------------------
# PVT
# ---------------------------------------------------------------------------

def test_pvt_matches_float64_lstsq():
    rng = np.random.default_rng(7)
    v = rng.normal(size=20000).astype(np.float32) * 2.5 + 0.3
    fmt = FloatFormat(2, 3)
    vq = np.asarray(value_quantize(jnp.asarray(v), fmt))
    s, b = pvt_solve(jnp.asarray(v), jnp.asarray(vq))
    A = np.stack([vq.astype(np.float64), np.ones_like(vq, np.float64)], 1)
    (s_ref, b_ref), *_ = np.linalg.lstsq(A, v.astype(np.float64), rcond=None)
    np.testing.assert_allclose(float(s), s_ref, rtol=1e-5)
    np.testing.assert_allclose(float(b), b_ref, atol=1e-5)


def test_pvt_degenerate_constant():
    v = jnp.full((100,), 3.3, jnp.float32)
    vq = jnp.full((100,), 3.25, jnp.float32)
    s, b = pvt_solve(v, vq)
    assert float(s) == 1.0
    np.testing.assert_allclose(float(b), 3.3 - 3.25, atol=1e-6)  # b absorbs error


@settings(max_examples=60, deadline=None)
@given(
    st.integers(min_value=2, max_value=400),
    st.integers(0, 2**31 - 1),
    st.sampled_from(["S1E2M3", "S1E3M7", "S1E5M10"]),
)
def test_prop_pvt_never_increases_l2_error(n, seed, fname):
    rng = np.random.default_rng(seed)
    v = jnp.asarray(rng.normal(size=(n,)).astype(np.float32) * rng.uniform(0.01, 10))
    fmt = FloatFormat.parse(fname)
    vq = value_quantize(v, fmt)
    s, b = pvt_solve(v, vq)
    e_raw = float(jnp.sum((vq - v) ** 2))
    e_pvt = float(jnp.sum((pvt_apply(vq, s, b) - v) ** 2))
    assert e_pvt <= e_raw * (1 + 1e-4) + 1e-10  # least squares is optimal


# ---------------------------------------------------------------------------
# Policy
# ---------------------------------------------------------------------------

def test_policy_weights_only():
    params = _toy_params()
    pol = QuantizePolicy(min_size=64)
    names = quantizable_names(params, pol)
    assert "embed/table" in names and "block0/mlp/w1" in names
    assert not any("norm" in n for n in names)
    assert not any("bias" in n for n in names)
    assert "step" not in names


def test_policy_exclusion_regex():
    params = _toy_params()
    pol = QuantizePolicy(min_size=64, exclude_re=(r"rglru/",))
    names = quantizable_names(params, pol)
    assert not any(n.startswith("rglru") for n in names)


def test_policy_coverage_dominated_by_matrices():
    params = _toy_params()
    cov = coverage(params, QuantizePolicy(min_size=64))
    assert cov > 0.95  # matches the paper's "99.8% of model size" observation


# ---------------------------------------------------------------------------
# PPQ masks
# ---------------------------------------------------------------------------

def test_ppq_exact_fraction_and_determinism():
    key = jax.random.PRNGKey(0)
    m1 = ppq_mask(key, 5, 17, 200, 0.9)
    m2 = ppq_mask(key, 5, 17, 200, 0.9)
    assert int(m1.sum()) == 180
    np.testing.assert_array_equal(np.asarray(m1), np.asarray(m2))


def test_ppq_varies_by_round_and_client():
    key = jax.random.PRNGKey(0)
    a = np.asarray(ppq_mask(key, 1, 0, 300, 0.9))
    b = np.asarray(ppq_mask(key, 2, 0, 300, 0.9))
    c = np.asarray(ppq_mask(key, 1, 1, 300, 0.9))
    assert not np.array_equal(a, b) and not np.array_equal(a, c)


def test_ppq_every_var_sometimes_unquantized():
    """Across many clients each var must be left FP32 by someone (paper §2.5)."""
    key = jax.random.PRNGKey(3)
    masks = np.asarray(ppq_masks_batch(key, 0, jnp.arange(128), 64, 0.9))
    assert masks.shape == (128, 64)
    unquantized_somewhere = (~masks).any(axis=0)
    assert unquantized_somewhere.all()


def test_ppq_edge_fractions():
    key = jax.random.PRNGKey(0)
    assert int(ppq_mask(key, 0, 0, 50, 1.0).sum()) == 50
    assert int(ppq_mask(key, 0, 0, 50, 0.0).sum()) == 0


# ---------------------------------------------------------------------------
# Store / transport
# ---------------------------------------------------------------------------

def test_compress_decompress_tree_close():
    params = _toy_params()
    cfg = OMCConfig.parse("S1E4M14", quantize_fraction=1.0)
    ct = compress(params, cfg)
    assert isinstance(ct["embed"]["table"], CompressedVariable)
    assert not isinstance(ct["block0"]["norm"]["scale"], CompressedVariable)
    dt = decompress(ct)
    err = np.max(np.abs(np.asarray(dt["embed"]["table"] - params["embed"]["table"])))
    assert err < 1e-3  # 14 mantissa bits
    np.testing.assert_array_equal(
        np.asarray(dt["block0"]["norm"]["scale"]),
        np.asarray(params["block0"]["norm"]["scale"]),
    )


def test_transport_roundtrip_bit_exact():
    rng = np.random.default_rng(11)
    v = jnp.asarray(rng.normal(size=(37, 53)).astype(np.float32))
    cv = compress_variable(v, FloatFormat(3, 7))
    blob = pack_for_transport(cv)
    cv2 = unpack_from_transport(blob)
    np.testing.assert_array_equal(np.asarray(cv.codes), np.asarray(cv2.codes))
    assert blob["nbytes"] < v.size * 4 * 0.4  # 11/32 + padding


def test_bytes_report_matches_paper_ratios():
    """S1E4M14 @ 90% PPQ ≈ 64% (Table 1); S1E3M7 ≈ 41% (Table 2)."""
    params = {"w": jnp.zeros((4096, 4096))}
    pol = QuantizePolicy(min_size=1)
    r19 = bytes_report(params, OMCConfig.parse("S1E4M14", policy=pol))
    assert abs(r19["packed_ratio"] - 0.64) < 0.02
    r11 = bytes_report(params, OMCConfig.parse("S1E3M7", policy=pol))
    assert abs(r11["packed_ratio"] - 0.41) < 0.02
    r6 = bytes_report(params, OMCConfig.parse("S1E2M3", policy=pol))
    assert abs(r6["packed_ratio"] - 0.27) < 0.03  # Table 2 reports 29%


# ---------------------------------------------------------------------------
# effective_params (simulation mode)
# ---------------------------------------------------------------------------

def test_effective_params_respects_policy_and_ppq():
    params = _toy_params()
    cfg = OMCConfig.parse("S1E2M3", quantize_fraction=1.0)
    eff = effective_params(params, cfg, 0, 0)
    assert not np.allclose(np.asarray(eff["embed"]["table"]), np.asarray(params["embed"]["table"]))
    np.testing.assert_array_equal(
        np.asarray(eff["block0"]["norm"]["scale"]),
        np.asarray(params["block0"]["norm"]["scale"]),
    )


def test_effective_params_identity_when_disabled():
    params = _toy_params()
    cfg = OMCConfig.parse("S1E8M23", quantize_fraction=1.0)
    eff = effective_params(params, cfg, 0, 0)
    for a, b in zip(jax.tree_util.tree_leaves(eff), jax.tree_util.tree_leaves(params)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_effective_params_jittable():
    params = _toy_params()
    cfg = OMCConfig.parse("S1E3M7")

    @jax.jit
    def f(p, r, c):
        return effective_params(p, cfg, r, c)

    # Across several clients the PPQ selections must differ somewhere (with
    # K=6 vars two specific clients can coincide by chance — check a batch).
    trees = [f(params, jnp.int32(0), jnp.int32(c)) for c in range(8)]
    leaves = [jax.tree_util.tree_leaves(t) for t in trees]
    diffs = [
        not np.array_equal(np.asarray(a), np.asarray(b))
        for c in range(1, 8)
        for a, b in zip(leaves[0], leaves[c])
    ]
    assert any(diffs)
