"""pvt_solve_fast: agreement with the exact solver / numpy float64."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_stub import given, settings, st

from repro.core.formats import FloatFormat, value_quantize
from repro.core.pvt import pvt_apply, pvt_solve, pvt_solve_fast


def _np_solve(v, q):
    v = np.asarray(v, np.float64).ravel()
    q = np.asarray(q, np.float64).ravel()
    n = v.size
    den = n * (q * q).sum() - q.sum() ** 2
    if den <= 0:
        s = 1.0
    else:
        s = (n * (v * q).sum() - v.sum() * q.sum()) / den
    b = (v.sum() - s * q.sum()) / n
    return s, b


@pytest.mark.parametrize("n", [100, 4097, 100_000])
def test_fast_matches_float64(n):
    v = jax.random.normal(jax.random.PRNGKey(n), (n,)) * 0.2
    q = value_quantize(v, FloatFormat(3, 7))
    s_f, b_f = pvt_solve_fast(v, q)
    s_np, b_np = _np_solve(v, q)
    np.testing.assert_allclose(float(s_f), s_np, rtol=5e-4)
    np.testing.assert_allclose(float(b_f), b_np, atol=5e-6)


def test_fast_matches_exact_solver():
    v = jax.random.normal(jax.random.PRNGKey(0), (5000,))
    q = value_quantize(v, FloatFormat(4, 8))
    s1, b1 = pvt_solve(v, q)
    s2, b2 = pvt_solve_fast(v, q)
    np.testing.assert_allclose(float(s1), float(s2), rtol=1e-4)
    np.testing.assert_allclose(float(b1), float(b2), atol=1e-5)


def test_batch_axes_match_per_slice():
    v = jax.random.normal(jax.random.PRNGKey(1), (3, 4, 64, 32))
    q = value_quantize(v, FloatFormat(3, 7))
    s, b = pvt_solve_fast(v, q, batch_axes=2)
    assert s.shape == (3, 4, 1, 1) and b.shape == (3, 4, 1, 1)
    for i in range(3):
        for j in range(4):
            si, bi = pvt_solve_fast(v[i, j], q[i, j])
            np.testing.assert_allclose(float(s[i, j, 0, 0]), float(si), rtol=1e-5)
            np.testing.assert_allclose(float(b[i, j, 0, 0]), float(bi), atol=1e-6)


def test_degenerate_constant_variable():
    v = jnp.full((512,), 0.017)
    q = value_quantize(v, FloatFormat(2, 3))
    s, b = pvt_solve_fast(v, q)
    assert float(s) == 1.0  # paper's prescription for the degenerate case
    # b absorbs the mean error exactly
    np.testing.assert_allclose(
        np.asarray(pvt_apply(q, s, b)), np.asarray(v), atol=1e-7
    )


@settings(max_examples=25, deadline=None)
@given(st.integers(1, 4000), st.integers(0, 2**31 - 1))
def test_pvt_never_increases_l2_error(n, seed):
    """The least-squares property: ||s·q+b - v|| <= ||q - v||."""
    v = jax.random.normal(jax.random.PRNGKey(seed), (n,)) * 0.5
    q = value_quantize(v, FloatFormat(2, 3))
    s, b = pvt_solve_fast(v, q)
    e_pvt = float(jnp.sum((pvt_apply(q, s, b) - v) ** 2))
    e_raw = float(jnp.sum((q - v) ** 2))
    assert e_pvt <= e_raw * (1 + 1e-5) + 1e-10
