"""Pallas bitstream pack/unpack vs the canonical ``core.packing`` oracle.

The contract under test (DESIGN.md §13): the packed wire bitstream is
*canonical* — little-endian bit order within uint32 words, zero tail
padding — so the Pallas superblock kernels (``kernels/bitpack.py``, run in
interpret mode here) must be bit-identical to the jnp scatter/gather oracle
for every width in the format zoo (6/11/16/19/32 bits) plus the 2-bit
ternary codes, at every tail length.  Property-tested with hypothesis when
available; a deterministic sweep keeps coverage without it.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from _hypothesis_stub import given, settings, st
from repro.core import packing
from repro.core.formats import FloatFormat
from repro.kernels import bitpack, ops, ref

# Every zoo format width + the 2-bit ternary codes of repro.compress.ternary.
ZOO_WIDTHS = sorted({FloatFormat(2, 3).bits, FloatFormat(3, 7).bits,
                     FloatFormat(4, 14).bits, FloatFormat(5, 10).bits,
                     FloatFormat(8, 23).bits} | {2})
# Tail lengths that straddle word, block, and grid-row boundaries.
LENGTHS = [1, 3, 31, 32, 33, 257, 1000, 2048, 5001]


def _codes(n: int, width: int, seed: int = 0) -> jax.Array:
    rng = np.random.default_rng(seed)
    hi = (1 << width) - 1 if width < 32 else 0xFFFFFFFF
    return jnp.asarray(rng.integers(0, hi, size=n, endpoint=True,
                                    dtype=np.uint64).astype(np.uint32))


@pytest.mark.parametrize("width", ZOO_WIDTHS)
@pytest.mark.parametrize("n", LENGTHS)
def test_pack_bit_identical_to_oracle(width, n):
    codes = _codes(n, width, seed=n * 37 + width)
    got = bitpack.pack(codes, width, interpret=True)
    want = packing._pack_jnp(codes, width)
    assert got.shape == (packing.packed_words(n, width),)
    assert got.dtype == jnp.uint32
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


@pytest.mark.parametrize("width", ZOO_WIDTHS)
@pytest.mark.parametrize("n", LENGTHS)
def test_unpack_bit_identical_and_roundtrip(width, n):
    codes = _codes(n, width, seed=n * 13 + width)
    words = packing._pack_jnp(codes, width)
    got = bitpack.unpack(words, width, n, interpret=True)
    want = packing._unpack_jnp(words, width, n)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))
    # pack∘unpack is the identity on the code stream
    np.testing.assert_array_equal(np.asarray(got), np.asarray(codes))


@given(st.integers(min_value=0, max_value=len(ZOO_WIDTHS) - 1),
       st.integers(min_value=1, max_value=4096),
       st.integers(min_value=0, max_value=2**31 - 1))
@settings(max_examples=40, deadline=None)
def test_pack_roundtrip_property(width_idx, n, seed):
    """∀ width ∈ zoo, ∀ tail length: Pallas pack == oracle pack (bit-exact)
    and unpack(pack(x)) == x."""
    width = ZOO_WIDTHS[width_idx]
    codes = _codes(n, width, seed=seed)
    packed = bitpack.pack(codes, width, interpret=True)
    np.testing.assert_array_equal(np.asarray(packed),
                                  np.asarray(packing._pack_jnp(codes, width)))
    back = bitpack.unpack(packed, width, n, interpret=True)
    np.testing.assert_array_equal(np.asarray(back), np.asarray(codes))


def test_pack_accepts_container_dtypes():
    """Codes arrive in their container dtype (u8/u16/u32) from the codec;
    the kernel casts internally and the stream must not depend on it."""
    for width, dt in [(6, jnp.uint8), (11, jnp.uint16), (19, jnp.uint32)]:
        codes = _codes(213, width, seed=width)
        narrow = codes.astype(dt)
        np.testing.assert_array_equal(
            np.asarray(bitpack.pack(narrow, width, interpret=True)),
            np.asarray(bitpack.pack(codes, width, interpret=True)),
        )


def test_public_packing_routes_through_ops(monkeypatch):
    """core.packing.pack/unpack are thin veneers over kernels.ops — the
    dispatch layer (and on TPU, the Pallas kernels) sees every wire call."""
    calls = []
    real_pack, real_unpack = ops.pack_bits, ops.unpack_bits
    monkeypatch.setattr(ops, "pack_bits",
                        lambda c, w: calls.append("pack") or real_pack(c, w))
    monkeypatch.setattr(ops, "unpack_bits",
                        lambda w_, w, n: calls.append("unpack")
                        or real_unpack(w_, w, n))
    codes = _codes(100, 11)
    words = packing.pack(codes, 11)
    back = packing.unpack(words, 11, 100)
    assert calls == ["pack", "unpack"]
    np.testing.assert_array_equal(np.asarray(back), np.asarray(codes))


def test_ref_oracles_delegate_to_canonical_packer():
    codes = _codes(77, 19)
    np.testing.assert_array_equal(
        np.asarray(ref.ref_pack(codes, 19)),
        np.asarray(packing._pack_jnp(codes, 19)))
    words = packing._pack_jnp(codes, 19)
    np.testing.assert_array_equal(
        np.asarray(ref.ref_unpack(words, 19, 77)),
        np.asarray(packing._unpack_jnp(words, 19, 77)))


def test_width_validation():
    codes = jnp.zeros((4,), jnp.uint32)
    for bad in (0, 33, -1):
        with pytest.raises(ValueError):
            bitpack.pack(codes, bad)
        with pytest.raises(ValueError):
            bitpack.unpack(codes, bad, 4)
        with pytest.raises(ValueError):
            packing.pack(codes, bad)


def test_moved_bytes_tight_at_aligned_sizes():
    """The padded HBM traffic of the kernel stays within 2x of the minimal
    in+out bytes for realistic sizes (the roofline acceptance bound)."""
    from repro.roofline.analysis import packbits_bound_bytes

    for width in ZOO_WIDTHS:
        for n in (1 << 16, 1 << 20, 12_345):
            moved = bitpack.pack_moved_bytes(n, width)
            bound = packbits_bound_bytes(n, width)
            assert bound <= moved <= 2 * bound, (width, n, moved, bound)
            assert bitpack.unpack_moved_bytes(n, width) == moved
