"""Pallas kernel correctness: interpret-mode sweep vs pure-jnp oracles."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.formats import FloatFormat
from repro.kernels import dequant_matmul as dm
from repro.kernels import quantize as qk
from repro.kernels import ref

FMTS = [FloatFormat(2, 3), FloatFormat(3, 7), FloatFormat(4, 14),
        FloatFormat(5, 10), FloatFormat(8, 23)]
SHAPES = [(8,), (129,), (37, 53), (2, 3, 65), (256, 128)]


@pytest.mark.parametrize("fmt", FMTS, ids=lambda f: f.name)
@pytest.mark.parametrize("shape", SHAPES, ids=str)
def test_quantize_kernel_matches_ref(fmt, shape):
    x = jax.random.normal(jax.random.PRNGKey(hash(shape) % 2**31), shape)
    x = x * jnp.float32(3.0)
    got = qk.quantize(x, fmt, interpret=True)
    want = ref.ref_quantize(x, fmt)
    assert got.dtype == want.dtype == fmt.container_dtype
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


@pytest.mark.parametrize("fmt", FMTS, ids=lambda f: f.name)
@pytest.mark.parametrize("shape", [(64,), (33, 40)], ids=str)
def test_dequantize_kernel_matches_ref(fmt, shape):
    x = jax.random.normal(jax.random.PRNGKey(7), shape)
    codes = ref.ref_quantize(x, fmt)
    s, b = jnp.float32(1.05), jnp.float32(-0.01)
    got = qk.dequantize(codes, fmt, s, b, interpret=True)
    want = ref.ref_dequantize(codes, fmt, s, b)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-6, atol=1e-7)


@pytest.mark.parametrize("fmt", [FloatFormat(3, 7), FloatFormat(4, 14)],
                         ids=lambda f: f.name)
def test_quantize_stats_kernel(fmt):
    x = jax.random.normal(jax.random.PRNGKey(3), (1000,)) * 0.3
    codes, sums = qk.quantize_stats(x, fmt, interpret=True)
    rcodes, rsums = ref.ref_quantize_stats(x, fmt)
    np.testing.assert_array_equal(np.asarray(codes), np.asarray(rcodes))
    np.testing.assert_allclose(np.asarray(sums), np.asarray(rsums),
                               rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("fmt", [FloatFormat(3, 7), FloatFormat(5, 10)],
                         ids=lambda f: f.name)
@pytest.mark.parametrize("mnk", [(48, 80, 96), (32, 32, 32), (100, 60, 70)],
                         ids=str)
def test_dequant_matmul_kernel(fmt, mnk):
    m, n, k = mnk
    a = jax.random.normal(jax.random.PRNGKey(1), (m, k))
    w = jax.random.normal(jax.random.PRNGKey(2), (k, n)) * 0.1
    codes = ref.ref_quantize(w, fmt)
    s, b = jnp.float32(0.98), jnp.float32(0.004)
    got = dm.dequant_matmul(a, codes, fmt, s, b, bm=32, bn=32, bk=32,
                            interpret=True)
    want = ref.ref_dequant_matmul(a, codes, fmt, s, b)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-5, atol=2e-5)


def test_dequant_matmul_bias_rank1_correction():
    """The b-term folds as b * rowsum(A) — check against explicit compute."""
    fmt = FloatFormat(3, 7)
    a = jax.random.normal(jax.random.PRNGKey(4), (16, 24))
    w = jax.random.normal(jax.random.PRNGKey(5), (24, 8)) * 0.2
    codes = ref.ref_quantize(w, fmt)
    s, b = jnp.float32(1.1), jnp.float32(0.05)
    got = dm.dequant_matmul(a, codes, fmt, s, b, bm=8, bn=8, bk=8,
                            interpret=True)
    w_eff = s * ref.ref_dequantize(codes, fmt) + b
    want = a @ w_eff
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-5, atol=2e-5)
