"""Pallas kernel correctness: interpret-mode sweep vs pure-jnp oracles."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.formats import FloatFormat
from repro.kernels import agg
from repro.kernels import dequant_matmul as dm
from repro.kernels import ops
from repro.kernels import quantize as qk
from repro.kernels import ref

FMTS = [FloatFormat(2, 3), FloatFormat(3, 7), FloatFormat(4, 14),
        FloatFormat(5, 10), FloatFormat(8, 23)]
SHAPES = [(8,), (129,), (37, 53), (2, 3, 65), (256, 128)]


@pytest.mark.parametrize("fmt", FMTS, ids=lambda f: f.name)
@pytest.mark.parametrize("shape", SHAPES, ids=str)
def test_quantize_kernel_matches_ref(fmt, shape):
    x = jax.random.normal(jax.random.PRNGKey(hash(shape) % 2**31), shape)
    x = x * jnp.float32(3.0)
    got = qk.quantize(x, fmt, interpret=True)
    want = ref.ref_quantize(x, fmt)
    assert got.dtype == want.dtype == fmt.container_dtype
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


@pytest.mark.parametrize("fmt", FMTS, ids=lambda f: f.name)
@pytest.mark.parametrize("shape", [(64,), (33, 40)], ids=str)
def test_dequantize_kernel_matches_ref(fmt, shape):
    x = jax.random.normal(jax.random.PRNGKey(7), shape)
    codes = ref.ref_quantize(x, fmt)
    s, b = jnp.float32(1.05), jnp.float32(-0.01)
    got = qk.dequantize(codes, fmt, s, b, interpret=True)
    want = ref.ref_dequantize(codes, fmt, s, b)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-6, atol=1e-7)


@pytest.mark.parametrize("fmt", [FloatFormat(3, 7), FloatFormat(4, 14)],
                         ids=lambda f: f.name)
def test_quantize_stats_kernel(fmt):
    x = jax.random.normal(jax.random.PRNGKey(3), (1000,)) * 0.3
    codes, sums = qk.quantize_stats(x, fmt, interpret=True)
    rcodes, rsums = ref.ref_quantize_stats(x, fmt)
    np.testing.assert_array_equal(np.asarray(codes), np.asarray(rcodes))
    np.testing.assert_allclose(np.asarray(sums), np.asarray(rsums),
                               rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("fmt", [FloatFormat(3, 7), FloatFormat(5, 10)],
                         ids=lambda f: f.name)
@pytest.mark.parametrize("mnk", [(48, 80, 96), (32, 32, 32), (100, 60, 70)],
                         ids=str)
def test_dequant_matmul_kernel(fmt, mnk):
    m, n, k = mnk
    a = jax.random.normal(jax.random.PRNGKey(1), (m, k))
    w = jax.random.normal(jax.random.PRNGKey(2), (k, n)) * 0.1
    codes = ref.ref_quantize(w, fmt)
    s, b = jnp.float32(0.98), jnp.float32(0.004)
    got = dm.dequant_matmul(a, codes, fmt, s, b, bm=32, bn=32, bk=32,
                            interpret=True)
    want = ref.ref_dequant_matmul(a, codes, fmt, s, b)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-5, atol=2e-5)


# ---------------------------------------------------------------------------
# Fused compressed-domain aggregation (DESIGN.md §13)
# ---------------------------------------------------------------------------


def _fused_case(fmt, shape, batch_axes, cohort=5, seed=0, dead=(1,)):
    """Random server/client storage-form variables + a survival mask.

    Dead clients get garbage codes — including a genuine NaN code for
    formats with an exponent field — so the test proves the kernel's
    where-guard, not just numerical luck."""
    keys = jax.random.split(jax.random.PRNGKey(seed), 4)
    srv_val = jax.random.normal(keys[0], shape)
    cl_val = jax.random.normal(keys[1], (cohort,) + shape) * 0.7
    srv_codes = ref.ref_quantize(srv_val, fmt)
    cl_codes = np.array(ref.ref_quantize(cl_val, fmt))
    w = np.ones((cohort,), np.float32)
    # all-ones exponent + nonzero mantissa: a genuine NaN code
    nan_code = (((1 << fmt.exp_bits) - 1) << fmt.mant_bits) | (
        1 << (fmt.mant_bits - 1))
    for c in dead:
        w[c] = 0.0
        cl_codes[c] = np.asarray(nan_code, cl_codes.dtype)
    sb = int(np.prod(shape[:batch_axes])) if batch_axes else 1
    rng = np.random.default_rng(seed + 1)
    srv_s = jnp.asarray(rng.normal(1.0, 0.05, sb).astype(np.float32))
    srv_b = jnp.asarray(rng.normal(0.0, 0.01, sb).astype(np.float32))
    cl_s = jnp.asarray(rng.normal(1.0, 0.05, (cohort, sb)).astype(np.float32))
    cl_b = jnp.asarray(rng.normal(0.0, 0.01, (cohort, sb)).astype(np.float32))
    if batch_axes:
        srv_s = srv_s.reshape(shape[:batch_axes])
        srv_b = srv_b.reshape(shape[:batch_axes])
        cl_s = cl_s.reshape((cohort,) + shape[:batch_axes])
        cl_b = cl_b.reshape((cohort,) + shape[:batch_axes])
    else:
        srv_s, srv_b = srv_s.reshape(()), srv_b.reshape(())
        cl_s, cl_b = cl_s.reshape(cohort), cl_b.reshape(cohort)
    return (srv_codes, srv_s, srv_b, jnp.asarray(cl_codes), cl_s, cl_b,
            jnp.asarray(w))


@pytest.mark.parametrize("fmt", [FloatFormat(3, 7), FloatFormat(4, 14)],
                         ids=lambda f: f.name)
@pytest.mark.parametrize("shape,batch_axes",
                         [((37, 19), 0), ((3, 40, 17), 1), ((5,), 0),
                          ((2, 3, 130), 2)],
                         ids=["flat2d", "stacked1", "tiny", "stacked2"])
def test_fused_aggregate_kernel_matches_ref(fmt, shape, batch_axes):
    """Interpret-mode Pallas vs the unfused oracle: server codes bit-equal,
    PVT affine equal up to f32 reduction-order noise, dead-client NaN rows
    discarded by the where-guard."""
    case = _fused_case(fmt, shape, batch_axes)
    got = agg.fused_aggregate(*case, 0.5, fmt, batch_axes=batch_axes,
                              interpret=True)
    want = ref.ref_fused_aggregate(*case, 0.5, fmt, batch_axes=batch_axes)
    g = np.asarray(got[0]).astype(np.int64)
    w = np.asarray(want[0]).astype(np.int64)
    # f32 reassociation between the tiled kernel and the oracle can flip a
    # round-to-nearest-even tie: allow adjacent codes on a <=0.5% fringe,
    # everything else bit-equal
    diff = g != w
    assert diff.mean() <= 5e-3, f"{diff.sum()}/{diff.size} codes differ"
    assert np.abs(g - w)[diff].max(initial=0) <= 1, "non-adjacent code drift"
    from repro.core.formats import decode
    np.testing.assert_allclose(
        np.asarray(decode(got[0], fmt)), np.asarray(decode(want[0], fmt)),
        rtol=2.0 ** -fmt.mant_bits, atol=fmt.subnormal_step)
    assert np.isfinite(np.asarray(got[1])).all()
    np.testing.assert_allclose(np.asarray(got[1]), np.asarray(want[1]),
                               rtol=2e-5, atol=2e-6)
    np.testing.assert_allclose(np.asarray(got[2]), np.asarray(want[2]),
                               rtol=2e-5, atol=2e-6)


def test_fused_aggregate_all_dead_is_pure_server_decay():
    """Every client dead: the mean is 0 and the round is old + lr·(0 − old),
    still finite despite all-NaN client rows."""
    fmt = FloatFormat(3, 7)
    case = _fused_case(fmt, (64,), 0, cohort=4, dead=(0, 1, 2, 3))
    codes, s, b = agg.fused_aggregate(*case, 0.25, fmt, interpret=True)
    srv_codes, srv_s, srv_b = case[0], case[1], case[2]
    from repro.core.formats import decode
    old = np.asarray(decode(srv_codes, fmt)) * float(srv_s) + float(srv_b)
    got = np.asarray(decode(codes, fmt)) * np.asarray(s) + np.asarray(b)
    assert np.isfinite(got).all()
    np.testing.assert_allclose(got, 0.75 * old, atol=6e-3)


def test_fused_aggregate_pvt_off_returns_identity_affine():
    fmt = FloatFormat(3, 7)
    case = _fused_case(fmt, (33,), 0)
    codes, s, b = ops.fused_aggregate(*case, 0.5, fmt, pvt=False)
    assert s.shape == () and b.shape == ()
    assert float(s) == 1.0 and float(b) == 0.0
    want = ref.ref_fused_aggregate(*case, 0.5, fmt)
    np.testing.assert_array_equal(np.asarray(codes), np.asarray(want[0]))


# ---------------------------------------------------------------------------
# Dispatch policy (regression: per-call TPU probe swallowed exceptions and
# could flip between retraces — now a module constant, ref.py on CPU)
# ---------------------------------------------------------------------------


def test_cpu_dispatch_hits_ref(monkeypatch):
    assert isinstance(ops._ON_TPU, bool)  # memoized at import, not a callable
    if ops._ON_TPU:
        pytest.skip("host has a TPU: the compiled-Pallas branch is correct")
    calls = []
    real = ref.ref_pack
    monkeypatch.setattr(ref, "ref_pack",
                        lambda c, w: calls.append(w) or real(c, w))
    ops.reset_dispatch_counts()
    # fresh (shape, width) -> fresh trace of the jit'd wrapper -> the spy
    # fires iff the CPU branch routes through the ref oracle
    codes = jnp.arange(9973, dtype=jnp.uint32) & np.uint32(0x7FF)
    got = ops.pack_bits(codes, 11)
    assert calls == [11], "CPU dispatch did not route through kernels/ref.py"
    np.testing.assert_array_equal(np.asarray(got), np.asarray(real(codes, 11)))
    # the dispatch counter (DESIGN.md §15) agrees with the spy: the trace
    # was counted against the ref backend and never against pallas
    counts = ops.dispatch_counts()
    assert counts.get("pack_bits.ref") == 1, counts
    assert not any(k.endswith(".pallas") for k in counts), counts


def test_dispatch_counter_counts_traces_not_calls():
    """Counts are per compiled specialization: repeat calls with the same
    shape hit the jit cache and add nothing; a new shape retraces.  Prime
    sizes keep the specializations fresh regardless of test order."""
    if ops._ON_TPU:
        pytest.skip("backend split differs on TPU")
    ops.reset_dispatch_counts()
    codes = jnp.arange(1013, dtype=jnp.uint32) & np.uint32(0xF)
    ops.pack_bits(codes, 4)
    first = ops.dispatch_counts()
    assert first.get("pack_bits.ref") == 1, first
    ops.pack_bits(codes, 4)  # cache hit: no retrace, no count
    assert ops.dispatch_counts() == first
    ops.pack_bits(jnp.arange(1031, dtype=jnp.uint32) & np.uint32(0xF), 4)
    assert ops.dispatch_counts()["pack_bits.ref"] == 2
    # interpret mode is its own backend bucket, never 'ref'
    words = ops.pack_bits(jnp.arange(1013, dtype=jnp.uint32) & np.uint32(0x3F),
                          6)
    ops.unpack_bits(words, 6, 1013, force_interpret=True)
    counts = ops.dispatch_counts()
    assert counts.get("unpack_bits.interpret") == 1, counts
    assert "unpack_bits.ref" not in counts, counts


def test_interpret_dispatch_runs_kernel_body(monkeypatch):
    """force_interpret must execute the Pallas body, not the oracle."""
    if ops._ON_TPU:
        pytest.skip("on TPU the compiled branch wins by design")
    calls = []
    monkeypatch.setattr(
        ref, "ref_unpack",
        lambda *a, **k: calls.append(1) or (_ for _ in ()).throw(AssertionError))
    codes = jnp.arange(517, dtype=jnp.uint32) & np.uint32(0x3F)
    words = ops.pack_bits(codes, 6)
    back = ops.unpack_bits(words, 6, 517, force_interpret=True)
    assert not calls
    np.testing.assert_array_equal(np.asarray(back), np.asarray(codes))


def test_dequant_matmul_bias_rank1_correction():
    """The b-term folds as b * rowsum(A) — check against explicit compute."""
    fmt = FloatFormat(3, 7)
    a = jax.random.normal(jax.random.PRNGKey(4), (16, 24))
    w = jax.random.normal(jax.random.PRNGKey(5), (24, 8)) * 0.2
    codes = ref.ref_quantize(w, fmt)
    s, b = jnp.float32(1.1), jnp.float32(0.05)
    got = dm.dequant_matmul(a, codes, fmt, s, b, bm=8, bn=8, bk=8,
                            interpret=True)
    w_eff = s * ref.ref_dequantize(codes, fmt) + b
    want = a @ w_eff
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-5, atol=2e-5)
