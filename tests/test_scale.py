"""Sharded population runtime (DESIGN.md §14, ISSUE 9).

Gates, in dependency order:
  * ShardLayout partition math,
  * PopulationStore EF rows: raw (exact) and packed-at-rest roundtrips,
  * tree_aggregate == aggregate_weighted (the tree algebra alone),
  * padding/capacity invariance of the streamed round,
  * **tier-1 equivalence**: the sharded round reproduces the flat engine
    (unfused and fused) within one quantization step with byte-exact wire
    ledgers,
  * StreamLedger's capacity-determined peak bound,
  * population checkpoints: layout stamp + cross-layout refusal,
  * AsyncRunner backed by a PopulationStore.
"""

import json
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import checkpoint as ckpt
from repro.compress import get_strategy
from repro.core.omc import OMCConfig
from repro.core.store import decompress_tree
from repro.data.synthetic import make_frame_task
from repro.federated import accounting, engine, simulate
from repro.federated.async_engine import AsyncConfig, AsyncRunner
from repro.federated.cohort import CohortPlan, aggregate_weighted
from repro.federated.traces import FixedTrace
from repro.models import conformer as cf
from repro.scale import (
    ArrayCounters,
    PopulationStore,
    ShardLayout,
    pad_chunk,
    run_training_sharded,
    tree_aggregate,
)

CFG = cf.ConformerConfig(
    n_layers=2, d_model=32, n_heads=4, d_ff=64, n_classes=16, d_in=8
)
OMC = OMCConfig.parse("S1E3M7")
PLAN = CohortPlan(num_clients=16, cohort_size=8, failure_rate=0.25)
TASK = make_frame_task(d_in=CFG.d_in, n_classes=CFG.n_classes, seq_len=24,
                       num_clients=PLAN.num_clients)
DATA_FN = lambda c, r, s: TASK.batch(c, r, s, 4)
SIM = simulate.SimConfig(local_steps=2, client_lr=0.1)
KEY = jax.random.PRNGKey(0)


# ---------------------------------------------------------------------------
# ShardLayout
# ---------------------------------------------------------------------------


def test_shard_layout_partition():
    lay = ShardLayout(10, 3)
    assert lay.shard_sizes == (4, 3, 3)
    assert list(lay.starts) == [0, 4, 7, 10]
    assert list(lay.shard_of([0, 3, 4, 6, 7, 9])) == [0, 0, 1, 1, 2, 2]
    # clients_of tiles the id space exactly once
    all_ids = np.concatenate([lay.clients_of(i) for i in range(3)])
    assert list(all_ids) == list(range(10))
    assert lay.describe() == dict(num_clients=10, num_shards=3)


def test_shard_layout_validation():
    with pytest.raises(ValueError):
        ShardLayout(4, 5)  # more shards than clients
    with pytest.raises(ValueError):
        ShardLayout(4, 0)
    with pytest.raises(ValueError):
        ShardLayout(4, 2).shard_of([4])  # id out of range


def test_pad_chunk_contract():
    cids, w = pad_chunk([5, 6], [True, False], 4)
    assert list(cids) == [5, 6, 5, 5]  # pads repeat the first real client
    assert list(w) == [1.0, 0.0, 0.0, 0.0]  # dead + pad lanes weigh 0
    with pytest.raises(ValueError):
        pad_chunk([], [], 4)
    with pytest.raises(ValueError):
        pad_chunk([1, 2, 3], [1, 1, 1], 2)


# ---------------------------------------------------------------------------
# PopulationStore EF rows
# ---------------------------------------------------------------------------


def _fresh_store(n=8, shards=2, ef_fmt=None):
    store = PopulationStore(ShardLayout(n, shards))
    params = cf.init(KEY, CFG)
    store.init_ef(params, cf.param_specs(CFG), OMC, ef_fmt=ef_fmt)
    return store, params


def test_store_ef_raw_roundtrip_exact():
    store, _ = _fresh_store(ef_fmt=None)
    rows = store.gather_ef([1, 3])
    rng = np.random.default_rng(0)
    new = {k: jnp.asarray(rng.standard_normal(v.shape), jnp.float32)
           for k, v in rows.items()}
    store.scatter_ef([1, 3], new)
    back = store.gather_ef([1, 3])
    for k in new:
        np.testing.assert_array_equal(np.asarray(back[k]),
                                      np.asarray(new[k]))
    # untouched clients stay zero
    for v in store.gather_ef([0]).values():
        assert np.all(np.asarray(v) == 0.0)


def test_store_ef_packed_roundtrip_bounded():
    store, _ = _fresh_store(ef_fmt="S1E4M14")
    rows = store.gather_ef([0, 5])
    for v in rows.values():  # fresh packed rows decode to exact zero
        assert np.all(np.asarray(v) == 0.0)
    rng = np.random.default_rng(1)
    new = {k: jnp.asarray(0.1 * rng.standard_normal(v.shape), jnp.float32)
           for k, v in rows.items()}
    store.scatter_ef([0, 5], new)
    back = store.gather_ef([0, 5])
    for k in new:
        d = np.abs(np.asarray(back[k]) - np.asarray(new[k]))
        # one 19-bit PVT quantization step on values in ~[-0.5, 0.5]
        assert d.max() <= 1e-4, (k, d.max())
    rep = store.bytes_report()
    assert rep["ef_at_rest_bytes"] < rep["ef_fp32_bytes"]
    assert rep["ef_fmt"] == "S1E4M14"


def test_store_scatter_alive_mask():
    store, _ = _fresh_store()
    rows = store.gather_ef([2, 4])
    new = {k: jnp.ones_like(v) for k, v in rows.items()}
    store.scatter_ef([2, 4], new, mask=[True, False])
    after = store.gather_ef([2, 4])
    for v in after.values():
        assert np.all(np.asarray(v)[0] == 1.0)  # alive row moved
        assert np.all(np.asarray(v)[1] == 0.0)  # dead row kept


def test_store_counters_and_views():
    store = PopulationStore(ShardLayout(6, 2))
    store.note_round([0, 1, 2], alive=[True, False, True])
    assert list(store.round_counters[:3]) == [1, 1, 1]
    assert list(store.event_counters[:3]) == [1, 0, 1]
    view = store.event_view()
    assert isinstance(view, ArrayCounters)
    view[5] = 7
    assert store.event_counters[5] == 7
    assert view.get(5) == 7 and view.get(99, -1) == -1
    assert dict(view.items())[5] == 7
    assert len(view) == 6


# ---------------------------------------------------------------------------
# Tree-aggregation algebra
# ---------------------------------------------------------------------------


def test_tree_aggregate_matches_flat():
    rng = np.random.default_rng(2)
    stacked = dict(
        a=jnp.asarray(rng.standard_normal((10, 4, 3)), jnp.float32),
        b=jnp.asarray(rng.standard_normal((10, 5)), jnp.float32),
    )
    w = jnp.asarray(rng.random(10), jnp.float32)
    flat = aggregate_weighted(stacked, w)
    for shards in (1, 2, 3, 10):
        treed = tree_aggregate(stacked, w, shards)
        for k in stacked:
            d = np.abs(np.asarray(flat[k]) - np.asarray(treed[k]))
            assert d.max() <= 1e-6, (shards, k, d.max())


# ---------------------------------------------------------------------------
# Tier-1 equivalence gate: sharded round == flat engine round
# ---------------------------------------------------------------------------


def _engine_run(num_rounds=2, **kw):
    return engine.run_training_vectorized(
        cf, CFG, OMC, SIM, engine.CohortSpec(PLAN), DATA_FN, KEY,
        num_rounds=num_rounds, **kw,
    )


def _sharded_run(num_rounds=2, shards=2, capacity=3, **kw):
    return run_training_sharded(
        cf, CFG, OMC, SIM, PLAN, ShardLayout(PLAN.num_clients, shards),
        DATA_FN, KEY, num_rounds, capacity=capacity, **kw,
    )


def _assert_trees_close(a_storage, b_storage, max_tol, mean_tol):
    a = decompress_tree(a_storage)
    b = decompress_tree(b_storage)
    for x, y in zip(jax.tree_util.tree_leaves(a),
                    jax.tree_util.tree_leaves(b)):
        d = np.abs(np.asarray(x) - np.asarray(y))
        assert d.max() <= max_tol, d.max()
        assert d.mean() <= mean_tol, d.mean()


def test_sharded_matches_engine_unfused():
    """Cohort of 8 with failures + PPQ across 2 shards and capacity-3
    chunks: identical cohort semantics, byte-exact wire ledger, server
    trees within the engine-vs-loop tolerance (f32 reassociation only)."""
    eng_storage, eng_hist = _engine_run()
    sh_storage, sh_hist, ledger = _sharded_run()
    for eh, sh in zip(eng_hist, sh_hist):
        assert eh["cohort"] == sh["cohort"]
        assert eh["dropped"] == sh["dropped"]
        assert eh["down_bytes"] == sh["down_bytes"]  # byte-exact
        assert eh["up_bytes"] == sh["up_bytes"]
        assert abs(eh["loss"] - sh["loss"]) < 1e-3
        assert sh["shards"] >= 1 and sh["chunks"] >= sh["shards"]
    _assert_trees_close(eng_storage, sh_storage, 6e-3, 1e-4)
    assert ledger.clients_streamed == sum(h["cohort"] + h["dropped"]
                                          for h in sh_hist)


def test_sharded_matches_engine_fused():
    """fused_agg: one transport RNE per upload (the §13 profile), still
    byte-exact ledgers and one-quant-step server trees."""
    eng_storage, eng_hist = _engine_run(fused_agg=True)
    sh_storage, sh_hist, _ = _sharded_run(fused_agg=True)
    for eh, sh in zip(eng_hist, sh_hist):
        assert eh["down_bytes"] == sh["down_bytes"]
        assert eh["up_bytes"] == sh["up_bytes"]
    _assert_trees_close(eng_storage, sh_storage, 6e-3, 1e-3)


def test_sharded_capacity_invariance():
    """The streamed result must not depend on how the cohort is chunked:
    capacity 2 / 5 / cohort-size all land on the same server tree."""
    base, _, _ = _sharded_run(num_rounds=1, capacity=8)
    for cap in (2, 5):
        other, _, _ = _sharded_run(num_rounds=1, capacity=cap)
        _assert_trees_close(base, other, 1e-6, 1e-7)


def test_sharded_shard_count_invariance():
    one, _, _ = _sharded_run(num_rounds=1, shards=1)
    many, _, _ = _sharded_run(num_rounds=1, shards=8)
    _assert_trees_close(one, many, 1e-6, 1e-7)


def test_sharded_ef_strategy_matches_engine():
    """Store-backed error feedback (topk strategy) reproduces the engine's
    dense-EF run; the store's counters advance."""
    strat = get_strategy("topk", density=0.25)
    eng_storage, _ = _engine_run(strategy=strat, wire=False)
    store = PopulationStore(ShardLayout(PLAN.num_clients, 2))
    params = cf.init(KEY, CFG)
    store.init_ef(params, cf.param_specs(CFG), OMC)
    sh_storage, _, _ = _sharded_run(strategy=strat, wire=False, store=store)
    _assert_trees_close(eng_storage, sh_storage, 1e-5, 1e-6)
    assert store.round_counters.sum() == 2 * PLAN.cohort_size
    assert 0 < store.event_counters.sum() <= store.round_counters.sum()


def test_stream_ledger_bound_capacity_determined():
    """peak_bound_bytes is a function of capacity alone — identical across
    population sizes — and on_chunk validates the capacity contract."""
    params = cf.init(KEY, CFG)
    table = accounting.build_wire_table(params, cf.param_specs(CFG), OMC)
    bounds = {
        n: accounting.StreamLedger(table, OMC, 16).peak_bound_bytes()
        for n in (1_000, 100_000)
    }
    assert len(set(bounds.values())) == 1
    small = accounting.StreamLedger(table, OMC, 4)
    assert small.peak_bound_bytes() < accounting.StreamLedger(
        table, OMC, 64
    ).peak_bound_bytes()
    small.on_chunk(4)
    with pytest.raises(ValueError):
        small.on_chunk(5)
    snap = small.snapshot()
    assert snap["chunks"] == 1 and snap["clients_streamed"] == 4


# ---------------------------------------------------------------------------
# Checkpointing: layout stamp + refusal
# ---------------------------------------------------------------------------


def test_population_checkpoint_roundtrip_and_refusal(tmp_path):
    store, params = _fresh_store(ef_fmt="S1E4M14")
    rows = store.gather_ef([1])
    store.scatter_ef([1], {k: jnp.ones_like(v) for k, v in rows.items()})
    store.note_round([0, 1], alive=[True, True])
    path = ckpt.save_population_state(str(tmp_path), 3, store)
    with open(os.path.join(path, "manifest.json")) as f:
        extra = json.load(f)["extra"]
    assert extra["kind"] == "population_store"
    assert extra["layout"] == store.layout.describe()
    assert extra["ef"]["fmt"] == "S1E4M14"

    fresh, _ = _fresh_store(ef_fmt="S1E4M14")
    ckpt.restore_population_state(path, fresh)
    assert list(fresh.round_counters) == list(store.round_counters)
    for k, v in fresh.gather_ef([1]).items():
        d = np.abs(np.asarray(v) - 1.0)
        assert d.max() <= 1e-4, (k, d.max())

    wrong_layout = PopulationStore(ShardLayout(8, 4))
    wrong_layout.init_ef(params, cf.param_specs(CFG), OMC,
                         ef_fmt="S1E4M14")
    with pytest.raises(ValueError, match="layout"):
        ckpt.restore_population_state(path, wrong_layout)

    wrong_fmt, _ = _fresh_store(ef_fmt=None)
    with pytest.raises(ValueError, match="EF"):
        ckpt.restore_population_state(path, wrong_fmt)


# ---------------------------------------------------------------------------
# Async runtime over a PopulationStore
# ---------------------------------------------------------------------------


def _async_runner(population=None, num_clients=8):
    task = make_frame_task(d_in=CFG.d_in, n_classes=CFG.n_classes,
                           seq_len=24, num_clients=num_clients)
    return AsyncRunner(
        cf, CFG, OMC, SIM, AsyncConfig(buffer_goal=4), FixedTrace(),
        num_clients=num_clients, data_fn=lambda c, r, s: task.batch(c, r, s, 4),
        init_key=KEY, population=population,
    )


def test_async_runner_population_backed(tmp_path):
    """Counters live in the store's arrays; checkpoints stamp the layout
    and refuse a cross-layout (or dict-backed) restore."""
    store = PopulationStore(ShardLayout(8, 2))
    r1 = _async_runner(population=store)
    r1.run_until(flushes=2)
    assert store.round_counters.sum() > 0  # event loop wrote through
    assert isinstance(r1.event_counters, ArrayCounters)

    path = ckpt.save_async_state(str(tmp_path), r1, keep=1)
    with open(os.path.join(path, "manifest.json")) as f:
        extra = json.load(f)["extra"]
    assert extra["population_layout"] == dict(num_clients=8, num_shards=2)
    assert extra["event_counters"] is None  # arrays, not JSON dicts

    store2 = PopulationStore(ShardLayout(8, 2))
    r2 = _async_runner(population=store2)
    ckpt.restore_async_state(path, r2)
    assert list(store2.round_counters) == list(store.round_counters)
    assert r2.version == r1.version

    r3 = _async_runner(population=PopulationStore(ShardLayout(8, 4)))
    with pytest.raises(ValueError, match="layout"):
        ckpt.restore_async_state(path, r3)
    with pytest.raises(ValueError, match="layout"):
        ckpt.restore_async_state(path, _async_runner())  # dict-backed

    with pytest.raises(ValueError, match="num_clients"):
        _async_runner(population=store, num_clients=12)
