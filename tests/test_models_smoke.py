"""Per-architecture smoke tests (deliverable f).

Each assigned arch instantiates its REDUCED config and runs one forward /
train step on CPU, asserting output shapes and no NaNs; servable archs also
run prefill + one decode step and check prefill/decode consistency.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.registry import ARCHS
from repro.models.common import IDENTITY_MAT
from repro.models.registry import get_family, is_servable

ARCH_IDS = sorted(ARCHS)


def _smoke_batch(arch, cfg, key, batch=2, seq=24):
    fam = arch.FAMILY
    if fam in ("transformer", "moe", "xlstm", "griffin"):
        toks = jax.random.randint(key, (batch, seq), 0, cfg.vocab)
        return dict(tokens=toks[:, :-1], labels=toks[:, 1:])
    if fam == "vlm":
        npatch = cfg.prefix_embeds
        toks = jax.random.randint(key, (batch, seq), 0, cfg.vocab)
        return dict(
            patches=jax.random.normal(key, (batch, npatch, cfg.d_model)),
            tokens=toks[:, :-1], labels=toks[:, 1:],
        )
    if fam == "encdec":
        toks = jax.random.randint(key, (batch, seq // 2), 0, cfg.vocab)
        return dict(
            frames=jax.random.normal(key, (batch, seq, cfg.d_model)),
            tokens=toks[:, :-1], labels=toks[:, 1:],
        )
    if fam == "conformer":
        return dict(
            frames=jax.random.normal(key, (batch, seq, cfg.d_in)),
            labels=jax.random.randint(key, (batch, seq), 0, cfg.n_classes),
        )
    raise ValueError(fam)


@pytest.mark.parametrize("arch_id", ARCH_IDS)
def test_smoke_train_step(arch_id):
    arch = ARCHS[arch_id]
    cfg = arch.smoke_config()
    family = get_family(arch.FAMILY)
    key = jax.random.PRNGKey(0)
    params = family.init(key, cfg)
    batch = _smoke_batch(arch, cfg, jax.random.PRNGKey(1))

    loss, grads = jax.jit(jax.value_and_grad(
        lambda p: family.loss(cfg, p, batch, IDENTITY_MAT)
    ))(params)
    assert loss.shape == ()
    assert jnp.isfinite(loss), f"{arch_id}: non-finite loss"
    leaves = jax.tree_util.tree_leaves(grads)
    assert all(bool(jnp.isfinite(g).all()) for g in leaves), f"{arch_id}: bad grads"
    assert any(float(jnp.abs(g).sum()) > 0 for g in leaves), f"{arch_id}: zero grads"
    # param structure matches the spec tree
    specs = family.param_specs(cfg)
    jax.tree_util.tree_map(
        lambda s, p: None, specs, params,
        is_leaf=lambda s: hasattr(s, "storage"),
    )


@pytest.mark.parametrize(
    "arch_id", [a for a in ARCH_IDS if is_servable(ARCHS[a].FAMILY)]
)
def test_smoke_serve_consistency(arch_id):
    """prefill(n+1) last logits == prefill(n) + decode_step(token n)."""
    arch = ARCHS[arch_id]
    cfg = arch.smoke_config()
    family = get_family(arch.FAMILY)
    params = family.init(jax.random.PRNGKey(0), cfg)
    b, s = 2, 12
    key = jax.random.PRNGKey(2)
    toks = jax.random.randint(key, (b, s + 1), 0, cfg.vocab)

    def mk_batch(t):
        if arch.FAMILY == "vlm":
            return dict(
                patches=jax.random.normal(key, (b, cfg.prefix_embeds, cfg.d_model)),
                tokens=t,
            )
        if arch.FAMILY == "encdec":
            return dict(frames=jax.random.normal(key, (b, 4 * (s + 4), cfg.d_model)),
                        tokens=t)
        return dict(tokens=t)

    max_len = 4 * (s + 4)
    st0 = family.init_decode_state(cfg, b, max_len, dtype=jnp.float32)
    stA, lgA = jax.jit(
        lambda p, bt, st: family.prefill(cfg, p, bt, IDENTITY_MAT, st)
    )(params, mk_batch(toks), st0)
    stB, _ = jax.jit(
        lambda p, bt, st: family.prefill(cfg, p, bt, IDENTITY_MAT, st)
    )(params, mk_batch(toks[:, :s]), st0)
    stB, lgB = jax.jit(
        lambda p, st, t: family.decode_step(cfg, p, st, t, IDENTITY_MAT)
    )(params, stB, toks[:, s:s + 1])
    assert lgA.shape == lgB.shape == (b, 1, cfg.vocab)
    assert not bool(jnp.isnan(lgB).any()), f"{arch_id}: NaN decode logits"
    np.testing.assert_allclose(
        np.asarray(lgA), np.asarray(lgB), rtol=5e-4, atol=5e-4,
        err_msg=f"{arch_id}: prefill/decode mismatch",
    )


@pytest.mark.parametrize("arch_id", ARCH_IDS)
def test_full_config_constructs(arch_id):
    """The FULL config builds and eval_shape'd init matches the spec tree
    (no allocation — the real sizes are exercised by the dry-run)."""
    arch = ARCHS[arch_id]
    cfg = arch.config()
    family = get_family(arch.FAMILY)
    struct = jax.eval_shape(lambda k: family.init(k, cfg), jax.random.PRNGKey(0))
    specs = family.param_specs(cfg)
    jax.tree_util.tree_map(
        lambda s, p: None, specs, struct,
        is_leaf=lambda s: hasattr(s, "storage"),
    )
    n = sum(x.size for x in jax.tree_util.tree_leaves(struct))
    assert n > 1e6  # full configs are real-sized
