"""Wire-format codec + session tests (repro.api, DESIGN.md §7)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.api import codecs
from repro.api.session import FLClient, FLSession, ServeSession
from repro.core.omc import OMCConfig
from repro.core.policy import QuantizePolicy
from repro.core.store import compress_tree, is_compressed
from repro.data.synthetic import make_lm_task
from repro.federated.cohort import CohortPlan
from repro.federated.state import state_bytes_report
from repro.models import transformer as tr
from repro.models.common import IDENTITY_MAT

# one format per uint container: u8 (6 bits), u16 (11), u32 (19)
FORMATS = ["S1E2M3", "S1E3M7", "S1E4M14"]
POLICY = QuantizePolicy(min_size=64)


def _tree(seed=0):
    key = jax.random.PRNGKey(seed)
    return dict(
        emb=jax.random.normal(key, (64, 32)) * 0.02,
        blocks=[
            dict(
                w=jax.random.normal(jax.random.fold_in(key, i), (32, 32)),
                scale=jnp.ones((32,)),  # 1-D: stays raw f32
            )
            for i in range(3)
        ],
    )


def assert_trees_bit_equal(a_tree, b_tree):
    a_flat = jax.tree_util.tree_flatten_with_path(a_tree, is_leaf=is_compressed)[0]
    b_flat = jax.tree_util.tree_flatten_with_path(b_tree, is_leaf=is_compressed)[0]
    assert len(a_flat) == len(b_flat)
    for (pa, a), (pb, b) in zip(a_flat, b_flat):
        assert pa == pb
        if is_compressed(a):
            assert is_compressed(b)
            assert a.fmt == b.fmt
            assert b.codes.dtype == a.codes.dtype
            np.testing.assert_array_equal(np.asarray(a.codes), np.asarray(b.codes))
            np.testing.assert_array_equal(np.asarray(a.s), np.asarray(b.s))
            np.testing.assert_array_equal(np.asarray(a.b), np.asarray(b.b))
        else:
            assert not is_compressed(b)
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


@pytest.mark.parametrize("fmt", FORMATS)
def test_roundtrip_bit_exact(fmt):
    """decode(encode(compress_tree(t))) == compress_tree(t), code-for-code."""
    omc = OMCConfig.parse(fmt, policy=POLICY)
    ct = compress_tree(_tree(), omc.fmt, omc.policy)
    back, info = codecs.decode_payload(codecs.encode_payload(ct, round_index=7))
    assert_trees_bit_equal(ct, back)
    assert info.round_index == 7
    assert not info.is_delta
    assert info.num_compressed == 4  # emb + 3 block matrices


@pytest.mark.parametrize("fmt", FORMATS)
def test_body_bytes_reconcile_with_store_accounting(fmt):
    omc = OMCConfig.parse(fmt, policy=POLICY)
    ct = compress_tree(_tree(), omc.fmt, omc.policy)
    info = codecs.peek_payload(codecs.encode_payload(ct))
    rep = codecs.payload_bytes_report(ct)
    assert rep["wire_bytes"] == state_bytes_report(ct)["packed_bytes"]
    assert info.body_bytes == rep["wire_bytes"]


def test_delta_identity_and_size():
    """apply(delta(a, b), a) == b bit-exactly; sparse delta beats full."""
    omc = OMCConfig.parse("S1E3M7", policy=POLICY)
    t1 = _tree()
    t2 = dict(t1)
    t2["emb"] = t1["emb"].at[0, :4].add(0.5)  # few codes change
    a = compress_tree(t1, omc.fmt, omc.policy)
    b = compress_tree(t2, omc.fmt, omc.policy)
    delta = codecs.encode_payload(b, base=a)
    full = codecs.encode_payload(b)
    back, info = codecs.decode_payload(delta, base=a)
    assert info.is_delta
    assert_trees_bit_equal(b, back)
    assert len(delta) < len(full) // 4


def test_delta_never_worse_than_full():
    """A fully-changed tree falls back to per-leaf full encoding."""
    omc = OMCConfig.parse("S1E3M7", policy=POLICY)
    a = compress_tree(_tree(0), omc.fmt, omc.policy)
    b = compress_tree(_tree(1), omc.fmt, omc.policy)  # unrelated values
    delta = codecs.encode_payload(b, base=a)
    full = codecs.encode_payload(b)
    back, _ = codecs.decode_payload(delta, base=a)
    assert_trees_bit_equal(b, back)
    assert len(delta) <= len(full) + 64 * 4  # at most per-leaf mode metadata


def test_delta_requires_base():
    omc = OMCConfig.parse("S1E3M7", policy=POLICY)
    a = compress_tree(_tree(0), omc.fmt, omc.policy)
    t2 = dict(_tree(0))
    t2["emb"] = t2["emb"].at[0, 0].add(0.5)
    b = compress_tree(t2, omc.fmt, omc.policy)
    delta = codecs.encode_payload(b, base=a)
    with pytest.raises(codecs.CodecError):
        codecs.decode_payload(delta)


def test_delta_wrong_base_rejected_by_digest():
    """Applying a delta to a same-shaped but different tree must fail loudly
    (silent wrong-base XOR would hand the receiver the wrong model)."""
    omc = OMCConfig.parse("S1E3M7", policy=POLICY)
    a = compress_tree(_tree(0), omc.fmt, omc.policy)
    wrong = compress_tree(_tree(1), omc.fmt, omc.policy)  # same shapes
    t2 = dict(_tree(0))
    t2["emb"] = t2["emb"].at[0, 0].add(0.5)
    b = compress_tree(t2, omc.fmt, omc.policy)
    delta = codecs.encode_payload(b, base=a)
    with pytest.raises(codecs.CodecError, match="base mismatch"):
        codecs.decode_payload(delta, base=wrong)
    # the right base still decodes bit-exactly
    back, _ = codecs.decode_payload(delta, base=a)
    assert_trees_bit_equal(b, back)


def test_tuple_containers_roundtrip():
    """Tuples must come back as tuples — hot_swap relies on an unchanged
    treedef to avoid retracing."""
    key = jax.random.PRNGKey(3)
    t = dict(
        pair=(jax.random.normal(key, (16, 16)),
              jax.random.normal(jax.random.fold_in(key, 1), (16, 16))),
        lst=[jax.random.normal(jax.random.fold_in(key, 2), (16, 16))],
    )
    omc = OMCConfig.parse("S1E3M7", policy=POLICY)
    ct = compress_tree(t, omc.fmt, omc.policy)
    back, _ = codecs.decode_payload(codecs.encode_payload(ct))
    assert isinstance(back["pair"], tuple)
    assert isinstance(back["lst"], list)
    assert (jax.tree_util.tree_structure(ct, is_leaf=is_compressed)
            == jax.tree_util.tree_structure(back, is_leaf=is_compressed))
    assert_trees_bit_equal(ct, back)


def test_corrupt_payload_rejected():
    omc = OMCConfig.parse("S1E3M7", policy=POLICY)
    buf = bytearray(
        codecs.encode_payload(compress_tree(_tree(), omc.fmt, omc.policy))
    )
    for pos in (6, len(buf) // 2, len(buf) - 1):  # header, manifest/body, tail
        bad = bytearray(buf)
        bad[pos] ^= 0xFF
        with pytest.raises(codecs.CodecError):
            codecs.decode_payload(bytes(bad))
    with pytest.raises(codecs.CodecError):
        codecs.decode_payload(bytes(buf[: len(buf) // 2]))  # truncated


def test_version_negotiation():
    assert codecs.negotiate_version([1, 5, 9]) == 1
    with pytest.raises(codecs.CodecError):
        codecs.negotiate_version([99])


CFG = tr.TransformerConfig(
    n_layers=2, d_model=32, n_heads=2, n_kv_heads=1, d_ff=64, vocab=128
)


def _make_clients(omc, task, lr=0.05):
    @jax.jit
    def sgd(params, batch):
        _, g = jax.value_and_grad(
            lambda p: tr.loss(CFG, p, batch, IDENTITY_MAT)
        )(params)
        return jax.tree_util.tree_map(lambda w, gg: w - lr * gg, params, g)

    def train_fn(params, cid, r):
        return sgd(params, task.batch(cid, r, 0, 2))

    return {c: FLClient(c, tr, CFG, omc, train_fn) for c in range(4)}


def test_fl_session_two_round_loopback():
    """2 rounds of download -> train -> upload -> aggregate over the wire."""
    omc = OMCConfig.parse("S1E3M7")
    task = make_lm_task(vocab=CFG.vocab, seq_len=16, num_clients=4)
    sess = FLSession(
        tr, CFG, omc, plan=CohortPlan(num_clients=4, cohort_size=2)
    )
    clients = _make_clients(omc, task)

    def first_cv_codes(tree):
        return np.asarray(next(
            l for l in jax.tree_util.tree_leaves(tree, is_leaf=is_compressed)
            if is_compressed(l)
        ).codes)

    before = first_cv_codes(sess.storage).copy()
    for r in range(2):
        ticket = sess.begin_round()
        assert ticket.round_index == r
        assert len(ticket.client_ids) == 2
        assert (ticket.delta_payload is not None) == (r > 0)
        for cid in ticket.client_ids:
            info = sess.ingest(cid, clients[cid].run_round(ticket))
            assert info.total_bytes > 0
        assert len(ticket.issued_bytes) == 2
        metrics = sess.close_round()
        assert metrics["reports"] == 2
    assert sess.round_index == 2
    after = first_cv_codes(sess.storage)
    assert (before != after).any()  # training actually moved the model
    # compressed download stayed under the paper's ~59%-reduction envelope
    t = sess.traffic
    assert t["down_bytes"] <= 0.60 * t["down_fp32_bytes"]


def test_client_delta_choice_by_cache_digest():
    """A client whose cache matches round r-1 takes the delta download; a
    client with a stale cache (skipped a round) falls back to full."""
    omc = OMCConfig.parse("S1E3M7")
    task = make_lm_task(vocab=CFG.vocab, seq_len=16, num_clients=4)
    sess = FLSession(tr, CFG, omc)  # plan=None: client 0 every round
    fresh = _make_clients(omc, task)[0]
    stale = _make_clients(omc, task)[0]

    # round 0: both decode the full payload (no cache yet)
    ticket = sess.begin_round()
    sess.ingest(0, fresh.run_round(ticket))
    stale.run_round(ticket)  # participates but we only ingest one report
    assert ticket.issued_bytes == [len(ticket.payload)] * 2
    sess.close_round()

    # round 1: only `fresh` participates; its cache == round-0 model == the
    # delta base, so it takes the delta
    ticket = sess.begin_round()
    sess.ingest(0, fresh.run_round(ticket))
    assert ticket.issued_bytes == [len(ticket.delta_payload)]
    sess.close_round()

    # round 2: `stale` last saw round 0; the delta base is the round-1 model,
    # so the digest mismatches and it must take the full payload
    ticket = sess.begin_round()
    sess.ingest(0, stale.run_round(ticket))
    assert ticket.issued_bytes == [len(ticket.payload)]
    sess.close_round()


def test_fl_session_guards():
    omc = OMCConfig.parse("S1E3M7")
    sess = FLSession(tr, CFG, omc, plan=CohortPlan(num_clients=4, cohort_size=2))
    with pytest.raises(RuntimeError):
        sess.ingest(0, b"")
    ticket = sess.begin_round()
    with pytest.raises(RuntimeError):
        sess.begin_round()
    outsider = [c for c in range(4) if c not in ticket.client_ids][0]
    with pytest.raises(KeyError):
        sess.ingest(outsider, b"")
    with pytest.raises(RuntimeError):
        sess.close_round()  # zero reports


def test_serve_session_hot_swap_bit_transparent():
    """hot_swap(encode(storage)) leaves the served tree bit-identical."""
    omc = OMCConfig.parse("S1E3M7")
    sess = FLSession(tr, CFG, omc)
    serve = ServeSession(tr, CFG, sess.storage)
    payload = sess.server_payload()
    info = serve.hot_swap(payload)
    assert not info.is_delta
    assert_trees_bit_equal(sess.storage, serve.storage)
    cache = serve.init_cache(1, 16)
    _, gen = serve.generate(dict(tokens=jnp.zeros((1, 4), jnp.int32)), cache, 3)
    assert gen.shape == (1, 3)
