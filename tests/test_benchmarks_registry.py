"""benchmarks/run.py registry audit (ISSUE 9 satellite).

Two invariants:
  * every benchmark script on disk is registered in ``BENCHES`` (and vice
    versa) — a bench that skips the registry silently falls out of
    ``python -m benchmarks.run``,
  * every *committed* ``experiments/bench/*.json`` artifact names a
    registered generator in ``ARTIFACTS`` — a stale artifact nobody can
    regenerate is worse than no artifact.
"""

import os
import subprocess
import sys

import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
sys.path.insert(0, REPO)

from benchmarks.run import ARTIFACTS, BENCHES  # noqa: E402

_NON_BENCH = {"run.py", "common.py", "__init__.py"}


def _scripts_on_disk():
    bench_dir = os.path.join(REPO, "benchmarks")
    return {
        f[:-3] for f in os.listdir(bench_dir)
        if f.endswith(".py") and f not in _NON_BENCH
    }


def test_every_script_registered():
    on_disk = _scripts_on_disk()
    registered = set(BENCHES)
    assert on_disk == registered, (
        f"unregistered scripts: {sorted(on_disk - registered)}; "
        f"registry entries without a script: {sorted(registered - on_disk)}"
    )


def test_registry_modules_resolve():
    for name, module in BENCHES.items():
        assert module == f"benchmarks.{name}"
        path = os.path.join(REPO, *module.split(".")) + ".py"
        assert os.path.exists(path), f"{name} -> missing {path}"


def test_artifact_generators_registered():
    for artifact, bench in ARTIFACTS.items():
        assert bench in BENCHES, f"{artifact} names unknown bench {bench!r}"


def test_committed_artifacts_have_generators():
    """git-tracked experiments/bench JSONs must each name a generator."""
    try:
        out = subprocess.run(
            ["git", "ls-files", "experiments/bench"],
            cwd=REPO, capture_output=True, text=True, timeout=30,
        )
    except (OSError, subprocess.TimeoutExpired):
        pytest.skip("git unavailable")
    if out.returncode != 0:
        pytest.skip("not a git checkout")
    committed = {
        os.path.basename(p) for p in out.stdout.split()
        if p.endswith(".json")
    }
    missing = committed - set(ARTIFACTS)
    assert not missing, (
        f"committed artifacts with no registered generator: {sorted(missing)}"
    )
