"""Distributed federated round: learning, OMC-vs-FP32 parity, accounting."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.omc import OMCConfig
from repro.core.store import is_compressed
from repro.data.synthetic import make_lm_task
from repro.federated.round import make_eval_fn, make_round_fn
from repro.federated.state import init_state, state_bytes_report
from repro.models import transformer as tr
from repro.optim import fedadam, fedavg

CFG = tr.TransformerConfig(
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, d_ff=128, vocab=128
)


def _run(omc, rounds=10, opt=None, lr=0.05):
    opt = opt or fedavg(1.0)
    state = init_state(jax.random.PRNGKey(0), tr, CFG, omc, opt)
    task = make_lm_task(vocab=128, seq_len=32, num_clients=8)
    fn = jax.jit(make_round_fn(tr, CFG, omc, opt, client_lr=lr))
    losses = []
    for r in range(rounds):
        state, m = fn(state, task.batch(r % 8, r, 0, 8))
        losses.append(float(m["loss"]))
        assert np.isfinite(losses[-1])
    return state, losses


def test_compressed_round_learns():
    state, losses = _run(OMCConfig.parse("S1E4M14"))
    assert losses[-1] < losses[0]
    assert int(state.round) == 10
    # params stayed compressed
    kinds = [is_compressed(l) for l in jax.tree_util.tree_leaves(
        state.params, is_leaf=is_compressed)]
    assert any(kinds)


def test_omc_tracks_fp32_loss():
    """S1E4M14 (19-bit) stays close to FP32 — paper Table 1's claim at
    simulation scale."""
    _, l_fp32 = _run(OMCConfig.parse("S1E8M23"))
    _, l_omc = _run(OMCConfig.parse("S1E4M14"))
    # same trajectory within a small tolerance
    np.testing.assert_allclose(l_omc, l_fp32, rtol=0.05)


def test_aggressive_format_still_trains():
    _, losses = _run(OMCConfig.parse("S1E2M3"))
    assert losses[-1] < losses[0] * 1.05


def test_fedadam_server_opt():
    _, losses = _run(OMCConfig.parse("S1E3M7"), opt=fedadam(5e-3))
    assert np.isfinite(losses).all()


def test_bytes_report_ratios():
    omc = OMCConfig.parse("S1E3M7")
    state = init_state(jax.random.PRNGKey(0), tr, CFG, omc, fedavg(1.0))
    rep = state_bytes_report(state.params)
    # 11-bit format in u16 containers: at high weight coverage the container
    # ratio approaches 0.5 and the packed ratio 11/32
    assert rep["num_compressed"] / rep["num_params"] > 0.9
    assert 0.45 < rep["container_ratio"] < 0.60
    assert 0.30 < rep["packed_ratio"] < 0.45


def test_eval_fn_runs_on_compressed():
    omc = OMCConfig.parse("S1E3M7")
    state = init_state(jax.random.PRNGKey(0), tr, CFG, omc, fedavg(1.0))
    task = make_lm_task(vocab=128, seq_len=32, num_clients=8)
    ev = jax.jit(make_eval_fn(tr, CFG))
    loss = ev(state.params, task.batch(0, 0, 0, 4))
    assert jnp.isfinite(loss)


def test_round_deterministic_replay():
    """Same state + batch -> bit-identical next state (checkpoint/restart
    replay guarantee, DESIGN.md §5)."""
    omc = OMCConfig.parse("S1E3M7")
    opt = fedavg(1.0)
    state = init_state(jax.random.PRNGKey(0), tr, CFG, omc, opt)
    task = make_lm_task(vocab=128, seq_len=32, num_clients=8)
    fn = jax.jit(make_round_fn(tr, CFG, omc, opt, client_lr=0.05))
    batch = task.batch(0, 0, 0, 4)
    s1, _ = fn(state, batch)
    s2, _ = fn(state, batch)
    for a, b in zip(jax.tree_util.tree_leaves(s1), jax.tree_util.tree_leaves(s2)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
