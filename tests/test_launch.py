"""launch/ mesh + sharding-spec unit tests (ISSUE 9 satellite).

The production mesh shapes (16x16, 2x16x16) exceed any test host, so
``make_production_mesh`` / the compat shim are tested by monkeypatching
``jax.make_mesh`` and capturing the arguments; host- and population-mesh
tests run for real on the local devices.
"""

import jax
import numpy as np
import pytest
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.launch import mesh as mesh_lib
from repro.launch import specs as specs_lib


class _Capture:
    def __init__(self):
        self.calls = []

    def __call__(self, shape, axes, **kw):
        self.calls.append((tuple(shape), tuple(axes), dict(kw)))
        return ("mesh", tuple(shape), tuple(axes))


def test_compat_make_mesh_axis_types(monkeypatch):
    """When jax.sharding.AxisType exists every axis is explicitly Auto;
    otherwise no kwargs are passed (older jax defaults to Auto anyway)."""
    cap = _Capture()
    monkeypatch.setattr(jax, "make_mesh", cap)
    mesh_lib.compat_make_mesh((2, 3), ("data", "model"))
    (shape, axes, kw), = cap.calls
    assert shape == (2, 3) and axes == ("data", "model")
    if hasattr(jax.sharding, "AxisType"):
        assert kw == {"axis_types": (jax.sharding.AxisType.Auto,) * 2}
    else:
        assert kw == {}


def test_make_production_mesh_shapes(monkeypatch):
    cap = _Capture()
    monkeypatch.setattr(jax, "make_mesh", cap)
    mesh_lib.make_production_mesh()
    mesh_lib.make_production_mesh(multi_pod=True)
    assert cap.calls[0][:2] == ((16, 16), ("data", "model"))
    assert cap.calls[1][:2] == ((2, 16, 16), ("pod", "data", "model"))


def test_make_host_mesh_real():
    m = mesh_lib.make_host_mesh()
    assert m.axis_names == ("data", "model")
    assert m.devices.shape == (1, 1)


def test_make_population_mesh_real():
    m = mesh_lib.make_population_mesh()
    assert m.axis_names == ("clients",)
    assert 1 <= m.devices.size <= len(jax.devices())
    # logical shard counts beyond the device count clamp, never raise
    m2 = mesh_lib.make_population_mesh(num_shards=10_000)
    assert m2.devices.size <= len(jax.devices())
    assert mesh_lib.make_population_mesh(num_shards=1).devices.size == 1


def test_population_sharding_fallbacks():
    """No 'clients' axis, a 1-wide axis, or a non-dividing leading dim all
    fall back to replication; a dividing leading dim partitions axis 0."""
    host = mesh_lib.make_host_mesh()
    assert specs_lib.population_sharding(host, 2, 8).spec == P()

    pop = mesh_lib.make_population_mesh()
    sh = specs_lib.population_sharding(pop, 3, 8)
    n = pop.devices.size
    assert isinstance(sh, NamedSharding)
    if n <= 1:  # single-device topology: replicate
        assert sh.spec == P()
    else:
        assert sh.spec == P("clients", None, None)
        # non-divisible leading dim replicates instead of raising
        assert specs_lib.population_sharding(pop, 3, n + 1).spec == P()


def test_annotate_population_places_tree():
    pop = mesh_lib.make_population_mesh()
    tree = dict(a=np.zeros((8, 3), np.float32), b=np.zeros((8,), np.float32))
    placed = specs_lib.annotate_population(tree, pop)
    for v in placed.values():
        assert isinstance(v.sharding, NamedSharding)
        assert v.sharding.mesh.axis_names == ("clients",)


def test_population_mesh_hosts_store_rows():
    """End to end: PopulationStore.device_ef places rows via the spec."""
    from repro.core.omc import OMCConfig
    from repro.models import conformer as cf
    from repro.scale import PopulationStore, ShardLayout

    cfg = cf.ConformerConfig(n_layers=1, d_model=16, n_heads=2, d_ff=32,
                             n_classes=8, d_in=4)
    store = PopulationStore(ShardLayout(4, 2))
    params = cf.init(jax.random.PRNGKey(0), cfg)
    store.init_ef(params, cf.param_specs(cfg), OMCConfig.parse("S1E3M7"))
    mesh = mesh_lib.make_population_mesh(num_shards=2)
    rows = store.device_ef(mesh)
    assert rows and all(v.shape[0] == 4 for v in rows.values())
