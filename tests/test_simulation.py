"""Faithful simulation mode: PPQ per-client masks, failures, convergence."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.omc import OMCConfig
from repro.core.partial import ppq_mask
from repro.data.synthetic import make_frame_task
from repro.federated import simulate
from repro.federated.cohort import CohortPlan, aggregate_weighted, survival_mask
from repro.models import conformer as cf

CFG = cf.ConformerConfig(
    n_layers=2, d_model=32, n_heads=4, d_ff=64, n_classes=16, d_in=8
)


def test_ppq_masks_vary_per_client_and_round():
    key = jax.random.PRNGKey(0)
    m1 = ppq_mask(key, 0, 0, 50, 0.9)
    m2 = ppq_mask(key, 0, 1, 50, 0.9)
    m3 = ppq_mask(key, 1, 0, 50, 0.9)
    assert int(m1.sum()) == int(m2.sum()) == 45  # exact fraction
    assert not bool((m1 == m2).all())
    assert not bool((m1 == m3).all())
    # deterministic
    np.testing.assert_array_equal(np.asarray(m1),
                                  np.asarray(ppq_mask(key, 0, 0, 50, 0.9)))


def test_client_view_applies_mask():
    omc = OMCConfig.parse("S1E2M3")  # coarse -> visible changes
    specs = cf.param_specs(CFG)
    params = cf.init(jax.random.PRNGKey(0), CFG)
    v0 = simulate.client_view(params, specs, omc, 0, 0)
    v1 = simulate.client_view(params, specs, omc, 0, 1)
    d01 = sum(float(jnp.abs(a - b).sum()) for a, b in zip(
        jax.tree_util.tree_leaves(v0), jax.tree_util.tree_leaves(v1)))
    assert d01 > 0  # different PPQ masks -> different views


def test_simulation_converges_and_handles_drops():
    omc = OMCConfig.parse("S1E4M14")
    task = make_frame_task(d_in=8, n_classes=16, seq_len=24, num_clients=8)
    sim = simulate.SimConfig(local_steps=1, client_lr=0.1)
    plan = CohortPlan(num_clients=8, cohort_size=4, failure_rate=0.25,
                      straggler_rate=0.25)
    params, hist = simulate.run_training(
        cf, CFG, omc, sim, plan,
        lambda c, r, s: task.batch(c, r, s, 4),
        jax.random.PRNGKey(0), num_rounds=10, eval_every=100,
    )
    assert hist[-1]["loss"] < hist[0]["loss"]
    assert sum(h["dropped"] for h in hist) > 0  # failures actually happened
    assert all(h["cohort"] >= 1 for h in hist)  # never an empty round


def test_survival_mask_respects_report_goal():
    plan = CohortPlan(num_clients=32, cohort_size=16, report_goal=10)
    m = survival_mask(jax.random.PRNGKey(1), plan, 3)
    assert int(m.sum()) <= 10
    assert int(m.sum()) >= 1


def test_survival_mask_total_failure_keeps_fastest():
    """failure_rate=1.0: exactly one survivor per round — the fastest client
    by raw latency, not a fixed index (regression: the fallback used to rank
    the inf-masked latencies, which always elected client 0)."""
    plan = CohortPlan(num_clients=32, cohort_size=8, failure_rate=1.0)
    key = jax.random.PRNGKey(7)
    survivors = []
    for r in range(20):
        m = survival_mask(key, plan, r)
        assert int(m.sum()) == 1  # the docstring's ">= 1 survivor" guarantee
        survivors.append(int(jnp.argmax(m)))
    # the retried report comes from the fastest client, which varies with the
    # per-round latency draw — a constant index means the fallback is broken
    assert len(set(survivors)) > 1


def test_aggregate_weighted_renormalizes():
    deltas = {"w": jnp.stack([jnp.ones((4,)), 3 * jnp.ones((4,)),
                              100 * jnp.ones((4,))])}
    w = jnp.asarray([1.0, 1.0, 0.0])  # third client dropped
    out = aggregate_weighted(deltas, w)
    np.testing.assert_allclose(np.asarray(out["w"]), 2.0)
