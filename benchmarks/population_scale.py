"""Sharded population runtime scale sweep (DESIGN.md §14, ISSUE 9).

    PYTHONPATH=src python benchmarks/population_scale.py            # full
    PYTHONPATH=src python benchmarks/population_scale.py --smoke    # CI-sized

Three sections, one committed artifact (``experiments/bench/
population_scale.json``):

  * **sweep** — streamed tree-aggregated rounds at populations 1k -> 100k
    (one fixed-capacity compiled program for the whole sweep): client
    updates/s, round wall time, the StreamLedger's analytic peak bound and
    the measured live device bytes sampled from the ``on_chunk`` hook.
    Acceptance: the bound is *identical* across the sweep and measured
    peaks stay flat (within 1.5x of the smallest population) — peak memory
    is a function of stream capacity, never of population size.
  * **ef_at_rest** — PopulationStore residual bytes, packed vs f32, at a
    small population (the at-rest ratio is population-independent).
  * **serve** — hot-swap under synthetic query traffic
    (:func:`repro.scale.serve_driver.run_serve_under_swap`): steady-state
    latency, swap wall time, and the swap-stall ratio.  Acceptance: the
    first query after a swap stays within 10x of the steady-state median
    (a recompile would be orders of magnitude).
"""

import argparse
import sys
import time

sys.path.insert(0, __file__.rsplit("/", 2)[0])

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import print_table, save_result
from repro.api import codecs
from repro.api.session import ServeSession
from repro.core.omc import OMCConfig
from repro.data.synthetic import make_frame_task
from repro.federated import accounting, engine, simulate
from repro.federated.cohort import CohortPlan
from repro.federated.state import compress_params
from repro.models import conformer as cf
from repro.models import transformer as tr
from repro.scale import (
    PopulationStore,
    ShardLayout,
    make_root_fn,
    run_round_sharded,
    run_serve_under_swap,
    synthetic_token_batch,
)
from repro.scale.stream import make_stream_fn

OMC = OMCConfig.parse("S1E3M7")
CFG = cf.ConformerConfig(
    n_layers=2, d_model=32, n_heads=4, d_ff=64, n_classes=16, d_in=8
)
SIM = simulate.SimConfig(local_steps=2, client_lr=0.1)


def _live_device_bytes() -> int:
    return sum(int(np.prod(a.shape)) * a.dtype.itemsize
               for a in jax.live_arrays())


def sweep_section(populations, cohort, capacity, shards, rounds):
    specs = cf.param_specs(CFG)
    key = jax.random.PRNGKey(0)
    params = cf.init(key, CFG)
    table = accounting.build_wire_table(params, specs, OMC)
    storage0 = compress_params(params, specs, OMC)
    # ONE compiled program pair for every population in the sweep — the
    # traced shapes depend on capacity alone, which is the §14 point
    task = make_frame_task(d_in=CFG.d_in, n_classes=CFG.n_classes,
                           seq_len=24, num_clients=max(populations))
    data_fn = lambda c, r, s: task.batch(c, r, s, 4)
    stream_fn = make_stream_fn(cf, CFG, specs, OMC, SIM, data_fn, capacity)
    root_fn = make_root_fn(specs, OMC, SIM)

    rows = []
    for population in populations:
        plan = CohortPlan(num_clients=population, cohort_size=cohort,
                          failure_rate=0.1)
        layout = ShardLayout(population, shards)
        store = PopulationStore(layout)
        ledger = accounting.StreamLedger(table, OMC, capacity)
        peak = [0]

        def on_chunk(shard, n_real, n_chunks):
            peak[0] = max(peak[0], _live_device_bytes())

        storage = storage0
        # round 0 warms the jit cache; timed rounds follow
        storage, _ = run_round_sharded(
            cf, CFG, specs, OMC, SIM, storage, data_fn, plan, layout, 0,
            key, capacity=capacity, stream_fn=stream_fn, root_fn=root_fn,
            store=store, wire_table=table, ledger=ledger, on_chunk=on_chunk,
        )
        t0 = time.perf_counter()
        streamed = 0
        for r in range(1, rounds + 1):
            storage, m = run_round_sharded(
                cf, CFG, specs, OMC, SIM, storage, data_fn, plan, layout, r,
                key, capacity=capacity, stream_fn=stream_fn, root_fn=root_fn,
                store=store, wire_table=table, ledger=ledger,
                on_chunk=on_chunk,
            )
            streamed += m["cohort"] + m["dropped"]
        dt = time.perf_counter() - t0
        rows.append(dict(
            population=population,
            shards=shards,
            cohort=cohort,
            capacity=capacity,
            rounds=rounds,
            round_wall_s=round(dt / rounds, 3),
            updates_per_s=round(streamed / dt, 1),
            chunks=int(ledger.chunks),
            peak_bound_bytes=int(ledger.peak_bound_bytes()),
            peak_measured_device_bytes=int(peak[0]),
            host_counter_bytes=int(store.bytes_report()["counter_bytes"]),
        ))

    bounds = {r["peak_bound_bytes"] for r in rows}
    assert len(bounds) == 1, (
        f"StreamLedger bound must be population-independent, got {bounds}"
    )
    measured = [r["peak_measured_device_bytes"] for r in rows]
    assert max(measured) <= 1.5 * min(measured), (
        f"measured device peak grew with population: {measured}"
    )
    print_table(
        "streamed rounds: population sweep (fixed capacity "
        f"{rows[0]['capacity']})", rows,
        ["population", "shards", "cohort", "chunks", "round_wall_s",
         "updates_per_s", "peak_bound_bytes", "peak_measured_device_bytes",
         "host_counter_bytes"],
    )
    return rows


def ef_section(population=1_000, shards=8):
    specs = cf.param_specs(CFG)
    params = cf.init(jax.random.PRNGKey(0), CFG)
    out = {}
    for fmt in (None, "S1E4M14", "S1E3M7"):
        store = PopulationStore(ShardLayout(population, shards))
        store.init_ef(params, specs, OMC, ef_fmt=fmt)
        rep = store.bytes_report()
        out[fmt or "f32"] = dict(
            ef_at_rest_bytes=rep["ef_at_rest_bytes"],
            ratio_vs_f32=round(
                rep["ef_at_rest_bytes"] / max(rep["ef_fp32_bytes"], 1), 3
            ),
        )
    rows = [dict(fmt=k, **v) for k, v in out.items()]
    print_table(f"EF residuals at rest ({population} clients)", rows,
                ["fmt", "ef_at_rest_bytes", "ratio_vs_f32"])
    assert out["S1E3M7"]["ratio_vs_f32"] < 0.5  # ~11/32 + per-row PVT
    return out


def serve_section(swaps, queries_per_swap, decode_steps):
    cfg = tr.TransformerConfig(n_layers=2, d_model=32, n_heads=2,
                               n_kv_heads=1, d_ff=64, vocab=128)
    specs = tr.param_specs(cfg)
    key = jax.random.PRNGKey(1)
    params = tr.init(key, cfg)
    session = ServeSession(tr, cfg, compress_params(params, specs, OMC))
    payloads = []
    for i in range(swaps):
        k = jax.random.fold_in(key, i + 1)
        perturbed = jax.tree_util.tree_map(
            lambda p, kk=k: p + 0.01 * jax.random.normal(kk, p.shape,
                                                         p.dtype),
            params,
        )
        payloads.append(
            codecs.encode_payload(compress_params(perturbed, specs, OMC),
                                  round_index=i + 1)
        )
    stats = run_serve_under_swap(
        session, payloads,
        make_query=lambda i: synthetic_token_batch(1, 4, cfg.vocab, seed=i),
        queries_per_swap=queries_per_swap, decode_steps=decode_steps,
    )
    print_table("serve under hot-swap", [stats],
                ["queries", "swaps", "query_ms_p50", "query_ms_p95",
                 "swap_ms_mean", "swap_ms_max", "swap_stall_ratio"])
    assert stats["swaps"] == swaps
    assert stats["swap_stall_ratio"] < 10.0, (
        f"post-swap query stalled {stats['swap_stall_ratio']:.1f}x — did "
        "hot_swap trigger a recompile?"
    )
    return stats


def run(smoke: bool = False):
    if smoke:
        populations, cohort, capacity, shards, rounds = (
            [200, 1_000], 16, 8, 2, 1
        )
        swaps, qps, steps = 2, 4, 3
    else:
        populations, cohort, capacity, shards, rounds = (
            [1_000, 10_000, 100_000], 128, 32, 8, 2
        )
        swaps, qps, steps = 4, 8, 4
    payload = dict(
        config=dict(
            model="conformer-tiny", omc=OMC.fmt.name, cohort=cohort,
            capacity=capacity, shards=shards, smoke=bool(smoke),
        ),
        sweep=sweep_section(populations, cohort, capacity, shards, rounds),
        ef_at_rest=ef_section(),
        serve=serve_section(swaps, qps, steps),
    )
    path = save_result("population_scale", payload)
    print(f"\nwrote {path}")


if __name__ == "__main__":
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--smoke", action="store_true",
                    help="CI-sized run (small populations, 1 round)")
    args = ap.parse_args()
    run(smoke=args.smoke)
