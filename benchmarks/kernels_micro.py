"""Kernel micro-bench: ref-vs-interpret correctness timing + bytes math."""

import time

import jax
import jax.numpy as jnp

from repro.core.formats import FloatFormat
from repro.kernels import ops, ref

from .common import print_table, save_result


def _time(f, *args, n=5):
    f(*args).block_until_ready() if hasattr(f(*args), "block_until_ready") \
        else jax.block_until_ready(f(*args))
    t0 = time.time()
    for _ in range(n):
        out = f(*args)
    jax.block_until_ready(out)
    return (time.time() - t0) / n


def run():
    rows = []
    for fmt_s in ("S1E3M7", "S1E4M14"):
        fmt = FloatFormat.parse(fmt_s)
        x = jax.random.normal(jax.random.PRNGKey(0), (1024, 1024))
        t_q = _time(lambda a: ops.quantize(a, fmt), x)
        codes = ops.quantize(x, fmt)
        t_d = _time(lambda c: ops.dequantize(c, fmt), codes)
        a = jax.random.normal(jax.random.PRNGKey(1), (256, 1024))
        t_mm = _time(lambda a_, c: ops.dequant_matmul(a_, c, fmt), a, codes)
        gbps = 2 * x.size * 4 / t_q / 1e9
        rows.append(dict(fmt=fmt_s, quant_ms=round(t_q * 1e3, 2),
                         dequant_ms=round(t_d * 1e3, 2),
                         dqmm_ms=round(t_mm * 1e3, 2),
                         host_gbps=round(gbps, 2)))
    print_table("Kernel micro-bench (host reference path)", rows,
                ["fmt", "quant_ms", "dequant_ms", "dqmm_ms", "host_gbps"])
    save_result("kernels_micro", rows)
    return rows
