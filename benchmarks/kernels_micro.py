"""Kernel micro-bench: codec, bitpack, and fused-aggregate bytes + timing.

Besides the original quant/dequant/dequant-matmul timings this now measures
the two wire-path kernels of DESIGN.md §13:

  * pack/unpack — exact-width bitstream, per zoo width (+ 2-bit ternary):
    host-path latency, effective GB/s over the bytes the kernel actually
    moves, and the ratio of moved bytes to the roofline minimum
    (`roofline.analysis.packbits_bound_bytes`);
  * fused aggregate — one compressed-domain server round at cohort 8:
    latency vs the unfused oracle and moved-vs-bound byte ratio
    (`fused_aggregate_bound_bytes`).

Acceptance (asserted here, exercised by CI's bench-smoke job via
``--smoke``): every measured/moved byte count stays within 2x of its
roofline bound — tile padding and superblock rounding must never dominate
the wire-path byte budget.
"""

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.formats import FloatFormat
from repro.kernels import agg, bitpack, ops, ref
from repro.roofline.analysis import (
    fused_aggregate_bound_bytes,
    packbits_bound_bytes,
)

try:
    from .common import print_table, save_result
except ImportError:  # run as a script: python benchmarks/kernels_micro.py
    import os
    import sys

    sys.path.insert(0, os.path.dirname(os.path.abspath(__file__)))
    from common import print_table, save_result

# (label, width): every zoo format width + the ternary 2-bit codes
PACK_WIDTHS = [("ternary", 2), ("S1E2M3", 6), ("S1E3M7", 11),
               ("S1E5M10", 16), ("S1E4M14", 19), ("S1E8M23", 32)]
MAX_MOVED_OVER_BOUND = 2.0


def _time(f, *args, n=5):
    jax.block_until_ready(f(*args))
    t0 = time.time()
    for _ in range(n):
        out = f(*args)
    jax.block_until_ready(out)
    return (time.time() - t0) / n


def _codec_rows():
    rows = []
    for fmt_s in ("S1E3M7", "S1E4M14"):
        fmt = FloatFormat.parse(fmt_s)
        x = jax.random.normal(jax.random.PRNGKey(0), (1024, 1024))
        t_q = _time(lambda a: ops.quantize(a, fmt), x)
        codes = ops.quantize(x, fmt)
        t_d = _time(lambda c: ops.dequantize(c, fmt), codes)
        a = jax.random.normal(jax.random.PRNGKey(1), (256, 1024))
        t_mm = _time(lambda a_, c: ops.dequant_matmul(a_, c, fmt), a, codes)
        gbps = 2 * x.size * 4 / t_q / 1e9
        rows.append(dict(fmt=fmt_s, quant_ms=round(t_q * 1e3, 2),
                         dequant_ms=round(t_d * 1e3, 2),
                         dqmm_ms=round(t_mm * 1e3, 2),
                         host_gbps=round(gbps, 2)))
    print_table("Kernel micro-bench (host reference path)", rows,
                ["fmt", "quant_ms", "dequant_ms", "dqmm_ms", "host_gbps"])
    return rows


def _pack_rows(n):
    rows = []
    for label, width in PACK_WIDTHS:
        rng = np.random.default_rng(width)
        codes = jnp.asarray(rng.integers(
            0, (1 << width) - 1 if width < 32 else 0xFFFFFFFF, size=n,
            endpoint=True, dtype=np.uint64).astype(np.uint32))
        t_p = _time(lambda c: ops.pack_bits(c, width), codes)
        words = ops.pack_bits(codes, width)
        t_u = _time(lambda w: ops.unpack_bits(w, width, n), words)
        moved = bitpack.pack_moved_bytes(n, width)
        bound = packbits_bound_bytes(n, width)
        ratio = moved / bound
        assert ratio <= MAX_MOVED_OVER_BOUND, (
            f"pack width={width}: moved {moved} B > {MAX_MOVED_OVER_BOUND}x "
            f"roofline bound {bound} B")
        rows.append(dict(fmt=label, width=width, n=n,
                         pack_ms=round(t_p * 1e3, 2),
                         unpack_ms=round(t_u * 1e3, 2),
                         pack_gbps=round(moved / t_p / 1e9, 2),
                         moved_bytes=moved, bound_bytes=bound,
                         moved_over_bound=round(ratio, 3)))
    print_table("Exact-width bitpack (bytes vs roofline bound)", rows,
                ["fmt", "width", "n", "pack_ms", "unpack_ms", "pack_gbps",
                 "moved_bytes", "bound_bytes", "moved_over_bound"])
    return rows


def _fused_rows(n, cohort=8):
    rows = []
    for fmt_s in ("S1E3M7", "S1E4M14"):
        fmt = FloatFormat.parse(fmt_s)
        keys = jax.random.split(jax.random.PRNGKey(3), 2)
        srv = ref.ref_quantize(jax.random.normal(keys[0], (n,)), fmt)
        cl = ref.ref_quantize(
            jax.random.normal(keys[1], (cohort, n)) * 0.7, fmt)
        s1 = jnp.ones((cohort,), jnp.float32)
        b0 = jnp.zeros((cohort,), jnp.float32)
        w = jnp.ones((cohort,), jnp.float32)
        args = (srv, jnp.float32(1.0), jnp.float32(0.0), cl, s1, b0, w,
                jnp.float32(0.5), fmt)
        t_f = _time(lambda *a: ops.fused_aggregate(*a), *args)
        t_r = _time(lambda *a: ref.ref_fused_aggregate(*a), *args)
        moved = agg.fused_aggregate_moved_bytes(cohort, n, fmt)
        bound = fused_aggregate_bound_bytes(cohort, n,
                                            fmt.container_bytes_per_value)
        ratio = moved / bound
        assert ratio <= MAX_MOVED_OVER_BOUND, (
            f"fused {fmt_s}: moved {moved} B > {MAX_MOVED_OVER_BOUND}x "
            f"roofline bound {bound} B")
        # the f32 traffic the unfused path would add on top of `bound`
        unfused_extra = (cohort + 1) * n * 4
        rows.append(dict(fmt=fmt_s, cohort=cohort, n=n,
                         fused_ms=round(t_f * 1e3, 2),
                         oracle_ms=round(t_r * 1e3, 2),
                         fused_gbps=round(moved / t_f / 1e9, 2),
                         moved_bytes=moved, bound_bytes=bound,
                         moved_over_bound=round(ratio, 3),
                         unfused_extra_f32_bytes=unfused_extra))
    print_table("Fused compressed-domain aggregate (cohort round)", rows,
                ["fmt", "cohort", "n", "fused_ms", "oracle_ms", "fused_gbps",
                 "moved_bytes", "bound_bytes", "moved_over_bound",
                 "unfused_extra_f32_bytes"])
    return rows


def run(smoke: bool = False):
    n_pack = 1 << 16 if smoke else 1 << 20
    n_fused = 1 << 14 if smoke else 1 << 18
    payload = dict(codec=_codec_rows(), bitpack=_pack_rows(n_pack),
                   fused_aggregate=_fused_rows(n_fused))
    save_result("kernels_micro", payload)
    return payload


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true",
                    help="small sizes for CI (same assertions)")
    run(smoke=ap.parse_args().smoke)


if __name__ == "__main__":
    raise SystemExit(main())
