"""Paper Table 4: ablation — quantize -> +PVT -> +weights-only -> +PPQ.

Reproduces the ordering: raw S1E3M7 hurts, each mechanism recovers loss.
"""

import dataclasses

from repro.core.omc import OMCConfig
from repro.core.policy import QuantizePolicy

from .common import conformer_setup, print_table, run_fl, save_result


def run():
    fam, cfg, task, data_fn, evalb = conformer_setup(iid=True)
    all_params_policy = QuantizePolicy(weights_only=False, min_ndim=0,
                                       min_size=1)
    variants = [
        ("fp32", OMCConfig.parse("S1E8M23")),
        ("quant", OMCConfig.parse("S1E3M7", pvt=False, quantize_fraction=1.0,
                                  policy=all_params_policy)),
        ("quant+pvt", OMCConfig.parse("S1E3M7", pvt=True,
                                      quantize_fraction=1.0,
                                      policy=all_params_policy)),
        ("quant+pvt+weights", OMCConfig.parse("S1E3M7", pvt=True,
                                              quantize_fraction=1.0)),
        ("quant+pvt+weights+ppq", OMCConfig.parse("S1E3M7", pvt=True,
                                                  quantize_fraction=0.9)),
    ]
    rows = []
    for name, omc in variants:
        r = run_fl(fam, cfg, omc, data_fn, evalb)
        r["variant"] = name
        rows.append(r)
    print_table("Table 4: ablation (S1E3M7)", rows, ["variant", "final_eval"])
    save_result("table4_ablation", rows)
    return rows
