"""Paper Table 3: Non-Streaming Conformer on Non-IID LibriSpeech (surrogate).

Same formats as Table 1, with the per-speaker (non-IID) partition.
"""

import dataclasses

from repro.core.omc import OMCConfig

from .common import conformer_setup, print_table, run_fl, save_result


def run():
    fam, cfg_s, task, data_fn, evalb = conformer_setup(iid=False)
    cfg = dataclasses.replace(cfg_s, window=None, causal_conv=False)
    rows = []
    for fmt in ("S1E8M23", "S1E4M14"):
        r = run_fl(fam, cfg, OMCConfig.parse(fmt), data_fn, evalb)
        rows.append(r)
    print_table("Table 3: Non-Streaming Conformer, Non-IID",
                rows, ["fmt", "final_eval"])
    save_result("table3_noniid", rows)
    return rows
