"""Paper §3.4: measured parameter-memory reduction (compiled analysis).

No Pixel 4 offline: we report (a) exact byte accounting of the OMC state
(container + packed forms) and (b) ``compiled.memory_analysis()``
argument/temp bytes of the jitted round at FP32 vs OMC on the host device.
"""

import jax

from repro.core.omc import OMCConfig
from repro.federated.round import make_round_fn
from repro.federated.state import init_state, state_bytes_report
from repro.models import transformer as tr
from repro.optim import fedavg

from .common import print_table, save_result

CFG = tr.TransformerConfig(n_layers=4, d_model=128, n_heads=8, n_kv_heads=4,
                           d_ff=256, vocab=512)


def run():
    rows = []
    for fmt in ("S1E8M23", "S1E5M10", "S1E3M7"):
        omc = OMCConfig.parse(fmt)
        state = init_state(jax.random.PRNGKey(0), tr, CFG, omc, fedavg(1.0))
        rep = state_bytes_report(state.params)
        import jax.numpy as jnp
        batch = dict(tokens=jnp.ones((4, 32), jnp.int32),
                     labels=jnp.ones((4, 32), jnp.int32))
        fn = jax.jit(make_round_fn(tr, CFG, omc, fedavg(1.0)),
                     donate_argnums=(0,))
        compiled = fn.lower(state, batch).compile()
        try:
            ma = compiled.memory_analysis()
            arg_mb = ma.argument_size_in_bytes / 1e6
            tmp_mb = ma.temp_size_in_bytes / 1e6
        except Exception:
            arg_mb = tmp_mb = float("nan")
        rows.append(dict(fmt=fmt,
                         container_pct=round(100 * rep["container_ratio"]),
                         packed_pct=round(100 * rep["packed_ratio"]),
                         arg_mb=round(arg_mb, 2), temp_mb=round(tmp_mb, 2)))
    print_table("Measured memory (paper §3.4 analogue)", rows,
                ["fmt", "container_pct", "packed_pct", "arg_mb", "temp_mb"])
    save_result("memory_measured", rows)
    return rows
