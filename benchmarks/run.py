"""Benchmark driver: one entry per paper table/figure.

    PYTHONPATH=src python -m benchmarks.run [names...]
Environment: BENCH_ROUNDS / BENCH_CLIENTS / BENCH_COHORT / BENCH_BATCH.
"""

import sys
import time


def main() -> None:
    from . import (api_wire, async_scale, cohort_scale, compress_pareto,
                   fig3_pvt_stability, fig4_ppq_vs_apq, kernels_micro,
                   memory_measured, roofline_report, table1_iid,
                   table2_adaptation, table3_noniid, table4_ablation)

    all_benches = {
        "table1_iid": table1_iid.run,
        "table2_adaptation": table2_adaptation.run,
        "table3_noniid": table3_noniid.run,
        "table4_ablation": table4_ablation.run,
        "fig3_pvt_stability": fig3_pvt_stability.run,
        "fig4_ppq_vs_apq": fig4_ppq_vs_apq.run,
        "memory_measured": memory_measured.run,
        "kernels_micro": kernels_micro.run,
        "roofline_report": roofline_report.run,
        "api_wire": api_wire.run,
        "compress_pareto": compress_pareto.run,
        "cohort_scale": cohort_scale.run,
        "async_scale": async_scale.run,
    }
    names = sys.argv[1:] or list(all_benches)
    for name in names:
        t0 = time.time()
        print(f"\n######## {name} ########")
        all_benches[name]()
        print(f"[{name}: {time.time() - t0:.0f}s]")


if __name__ == "__main__":
    main()
