"""Benchmark driver: one entry per paper table/figure.

    PYTHONPATH=src python -m benchmarks.run [names...]
Environment: BENCH_ROUNDS / BENCH_CLIENTS / BENCH_COHORT / BENCH_BATCH.

``BENCHES`` is the module-level registry (name -> module, each exposing
``run()``); ``ARTIFACTS`` maps every committed ``experiments/bench/*.json``
to the bench that regenerates it.  ``tests/test_benchmarks_registry.py``
audits both against the scripts on disk and the committed artifacts, so a
new benchmark (or a new committed artifact) that skips the registry fails
tier-1 instead of silently falling out of ``python -m benchmarks.run``.
"""

import importlib
import sys
import time

#: name -> module path (lazy: importing a bench may touch jax device state).
BENCHES = {
    "table1_iid": "benchmarks.table1_iid",
    "table2_adaptation": "benchmarks.table2_adaptation",
    "table3_noniid": "benchmarks.table3_noniid",
    "table4_ablation": "benchmarks.table4_ablation",
    "fig3_pvt_stability": "benchmarks.fig3_pvt_stability",
    "fig4_ppq_vs_apq": "benchmarks.fig4_ppq_vs_apq",
    "memory_measured": "benchmarks.memory_measured",
    "kernels_micro": "benchmarks.kernels_micro",
    "roofline_report": "benchmarks.roofline_report",
    "api_wire": "benchmarks.api_wire",
    "compress_pareto": "benchmarks.compress_pareto",
    "cohort_scale": "benchmarks.cohort_scale",
    "async_scale": "benchmarks.async_scale",
    "population_scale": "benchmarks.population_scale",
}

#: committed experiments/bench artifact -> the bench that regenerates it.
ARTIFACTS = {
    "async_scale.json": "async_scale",
    "compress_strategies.json": "compress_pareto",
    "kernels_micro.json": "kernels_micro",
    "population_scale.json": "population_scale",
}


def run_bench(name: str) -> None:
    importlib.import_module(BENCHES[name]).run()


def main() -> None:
    names = sys.argv[1:] or list(BENCHES)
    unknown = [n for n in names if n not in BENCHES]
    if unknown:
        raise SystemExit(f"unknown bench(es) {unknown}; known: "
                         f"{sorted(BENCHES)}")
    for name in names:
        t0 = time.time()
        print(f"\n######## {name} ########")
        run_bench(name)
        print(f"[{name}: {time.time() - t0:.0f}s]")


if __name__ == "__main__":
    main()
