"""Paper Fig. 3: PVT stabilizes from-scratch training at S1E5M10."""

from repro.core.omc import OMCConfig

from .common import conformer_setup, print_table, run_fl, save_result


def run():
    fam, cfg, task, data_fn, evalb = conformer_setup(iid=True)
    rows = []
    # S1E5M10 is the paper's format (its instability shows over ~12k rounds);
    # S1E2M3 makes the PVT effect visible at benchmark scale.
    for fmt in ("S1E5M10", "S1E2M3"):
        for pvt in (False, True):
            omc = OMCConfig.parse(fmt, pvt=pvt, quantize_fraction=1.0)
            r = run_fl(fam, cfg, omc, data_fn, evalb)
            r["pvt"] = pvt
            rows.append(r)
    print_table("Fig 3: from-scratch training, with/without PVT",
                rows, ["fmt", "pvt", "final_eval"])
    save_result("fig3_pvt_stability", rows)
    return rows
