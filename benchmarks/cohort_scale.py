"""Cohort-scale benchmark: loop vs vectorized rounds/sec (DESIGN.md §9).

Times the per-client reference loop (:mod:`repro.federated.simulate`)
against the vectorized engine (:mod:`repro.federated.engine`) on identical
rounds — same cohort sample, survival mask, PPQ masks, and data stream —
across growing cohort sizes.  Compilation is excluded (one warm-up round per
path per size); timed rounds are *interleaved* between the two paths so
shared-host CPU noise hits both equally, and the reported number is the
per-path median s/round.  Each row also carries the engine's exact
wire-byte accounting and its reconciliation against the wire codec
(``payload_bytes_report`` must equal the table's download bytes).

The model and per-client batch are deliberately small (the tier-1 test
Conformer): FL *simulation* throughput at research scale is overhead-bound
— per-client jit dispatches, eager data generation, and the fixed
per-thunk cost of running a small program once per client — which is
precisely what the engine amortizes by executing the whole round as one
XLA program.  Raise ``--batch``/``--seq`` to study the compute-bound
regime (the gap narrows toward the pure-compute ratio).

    PYTHONPATH=src python benchmarks/cohort_scale.py            # full sweep
    PYTHONPATH=src python benchmarks/cohort_scale.py --smoke    # CI-sized
    PYTHONPATH=src python benchmarks/cohort_scale.py --tiers s1e3m7,s1e4m3,f32

Emits ``experiments/bench/cohort_scale.json``.
"""

from __future__ import annotations

import argparse
import os
import time

import jax
import jax.numpy as jnp

try:
    from .common import print_table, save_result
except ImportError:  # run as a script: python benchmarks/cohort_scale.py
    import os
    import sys

    sys.path.insert(0, os.path.dirname(os.path.abspath(__file__)))
    from common import print_table, save_result

from repro.api.codecs import payload_bytes_report
from repro.core.omc import OMCConfig
from repro.data.synthetic import make_frame_task
from repro.federated import accounting, engine, simulate
from repro.federated.cohort import CohortPlan
from repro.models import conformer as cf
from repro.obs import Obs

CFG = cf.ConformerConfig(
    n_layers=2, d_model=32, n_heads=4, d_ff=64, n_classes=16, d_in=8
)


def _setup(cohort: int, batch: int, seq: int):
    plan = CohortPlan(num_clients=2 * cohort, cohort_size=cohort)
    task = make_frame_task(d_in=CFG.d_in, n_classes=CFG.n_classes,
                           seq_len=seq, num_clients=plan.num_clients)
    data_fn = lambda c, r, s: task.batch(c, r, s, batch)
    return plan, data_fn


def _median(xs):
    xs = sorted(xs)
    n = len(xs)
    return xs[n // 2] if n % 2 else 0.5 * (xs[n // 2 - 1] + xs[n // 2])


def bench_size(cohort: int, rounds: int, batch: int, seq: int,
               fmt: str, seed: int) -> dict:
    omc = OMCConfig.parse(fmt)
    sim = simulate.SimConfig(local_steps=1, client_lr=0.1)
    plan, data_fn = _setup(cohort, batch, seq)
    specs = cf.param_specs(CFG)
    key = jax.random.PRNGKey(seed)
    params = cf.init(key, CFG)
    storage0 = engine.compress_params(params, specs, omc)
    table = accounting.build_wire_table(params, specs, omc)
    rkey = jax.random.fold_in(key, 0xC047)

    client_update = simulate.make_client_update(cf, CFG, specs, omc, sim)
    spec = engine.CohortSpec(plan)
    round_fn = engine.make_round_fn(cf, CFG, specs, omc, sim, spec, data_fn)
    # compile both paths (round 0, untimed)
    simulate.run_round(cf, CFG, specs, omc, sim, storage0, data_fn, plan,
                       0, rkey, client_update=client_update)
    engine.run_round_vectorized(cf, CFG, specs, omc, sim, storage0, data_fn,
                                spec, 0, rkey, round_fn=round_fn)

    # interleave the two paths round-by-round so shared-host CPU noise hits
    # both equally; report per-path medians
    loop_t, vec_t = [], []
    loop_storage = vec_storage = storage0
    for r in range(1, rounds + 1):
        t0 = time.perf_counter()
        loop_storage, loop_metrics = simulate.run_round(
            cf, CFG, specs, omc, sim, loop_storage, data_fn, plan, r, rkey,
            client_update=client_update, wire_table=table,
        )
        loop_t.append(time.perf_counter() - t0)
        t0 = time.perf_counter()
        vec_storage, vec_metrics = engine.run_round_vectorized(
            cf, CFG, specs, omc, sim, vec_storage, data_fn, spec, r, rkey,
            round_fn=round_fn, wire_table=table,
        )
        vec_t.append(time.perf_counter() - t0)
    loop_s, vec_s = _median(loop_t), _median(vec_t)

    # --- cross-checks: identical accounting, codec reconciliation ---------
    wire_match = (
        loop_metrics["down_bytes"] == vec_metrics["down_bytes"]
        and loop_metrics["up_bytes"] == vec_metrics["up_bytes"]
    )
    codec_match = (
        payload_bytes_report(storage0)["wire_bytes"]
        == table.download_bytes(omc)
    )
    return dict(
        cohort=cohort,
        loop_s_per_round=round(loop_s, 4),
        vec_s_per_round=round(vec_s, 4),
        loop_rounds_per_s=round(1.0 / loop_s, 3),
        vec_rounds_per_s=round(1.0 / vec_s, 3),
        speedup=round(loop_s / vec_s, 2),
        down_bytes=vec_metrics["down_bytes"],
        up_bytes=vec_metrics["up_bytes"],
        wire_match=wire_match,
        codec_match=codec_match,
    )


def bench_tiers(cohort: int, rounds: int, batch: int, seq: int,
                tier_names, fmt: str, seed: int) -> dict:
    """Engine-only timing of a mixed-bitwidth cohort (no loop counterpart —
    the reference loop has no tier concept)."""
    omc = OMCConfig.parse(fmt)
    sim = simulate.SimConfig(local_steps=1, client_lr=0.1)
    plan, data_fn = _setup(cohort, batch, seq)
    specs = cf.param_specs(CFG)
    key = jax.random.PRNGKey(seed)
    params = cf.init(key, CFG)
    storage0 = engine.compress_params(params, specs, omc)
    table = accounting.build_wire_table(params, specs, omc)
    rkey = jax.random.fold_in(key, 0xC047)
    spec = engine.CohortSpec(
        plan, tiers=tuple(engine.profile(n) for n in tier_names)
    )
    round_fn = engine.make_round_fn(cf, CFG, specs, omc, sim, spec, data_fn)
    engine.run_round_vectorized(cf, CFG, specs, omc, sim, storage0, data_fn,
                                spec, 0, rkey, round_fn=round_fn)
    t0 = time.perf_counter()
    storage = storage0
    for r in range(1, rounds + 1):
        storage, m = engine.run_round_vectorized(
            cf, CFG, specs, omc, sim, storage, data_fn, spec, r, rkey,
            round_fn=round_fn, wire_table=table,
        )
    vec_s = (time.perf_counter() - t0) / rounds
    return dict(
        cohort=cohort, tiers=",".join(tier_names), quotas=list(spec.quotas),
        vec_s_per_round=round(vec_s, 4),
        vec_rounds_per_s=round(1.0 / vec_s, 3),
        down_bytes=m["down_bytes"], up_bytes=m["up_bytes"],
    )


def bench_obs_overhead(cohort: int, rounds: int, batch: int, seq: int,
                       fmt: str, seed: int) -> dict:
    """Wall cost of enabling telemetry on the vectorized engine.

    Times identical engine rounds with ``obs=None`` against rounds with a
    live :class:`repro.obs.Obs` handle (metric bundles + spans), rounds
    interleaved so host noise hits both equally.  The §15 budget is
    <= 5% median overhead at cohort 64: the compiled program only gains
    one already-computed output (the cohort mean), and bundle norms are
    small host-side reductions.  The obs handle is never flushed — this
    measures recording cost, not file I/O.
    """
    omc = OMCConfig.parse(fmt)
    sim = simulate.SimConfig(local_steps=1, client_lr=0.1)
    plan, data_fn = _setup(cohort, batch, seq)
    specs = cf.param_specs(CFG)
    key = jax.random.PRNGKey(seed)
    params = cf.init(key, CFG)
    storage0 = engine.compress_params(params, specs, omc)
    table = accounting.build_wire_table(params, specs, omc)
    rkey = jax.random.fold_in(key, 0xC047)
    spec = engine.CohortSpec(plan)
    obs = Obs(run_name="cohort_overhead")

    fn_off = engine.make_round_fn(cf, CFG, specs, omc, sim, spec, data_fn)
    fn_on = engine.make_round_fn(cf, CFG, specs, omc, sim, spec, data_fn,
                                 collect_metrics=True)
    # compile both variants (round 0, untimed)
    engine.run_round_vectorized(cf, CFG, specs, omc, sim, storage0, data_fn,
                                spec, 0, rkey, round_fn=fn_off)
    engine.run_round_vectorized(cf, CFG, specs, omc, sim, storage0, data_fn,
                                spec, 0, rkey, round_fn=fn_on, obs=obs)

    off_t, on_t = [], []
    off_storage = on_storage = storage0
    for r in range(1, rounds + 1):
        t0 = time.perf_counter()
        off_storage, _ = engine.run_round_vectorized(
            cf, CFG, specs, omc, sim, off_storage, data_fn, spec, r, rkey,
            round_fn=fn_off, wire_table=table,
        )
        off_t.append(time.perf_counter() - t0)
        t0 = time.perf_counter()
        on_storage, _ = engine.run_round_vectorized(
            cf, CFG, specs, omc, sim, on_storage, data_fn, spec, r, rkey,
            round_fn=fn_on, wire_table=table, obs=obs,
        )
        on_t.append(time.perf_counter() - t0)
    off_s, on_s = _median(off_t), _median(on_t)
    return dict(
        cohort=cohort,
        obs_off_s_per_round=round(off_s, 4),
        obs_on_s_per_round=round(on_s, 4),
        overhead_pct=round(100.0 * (on_s / off_s - 1.0), 2),
        records=len(obs.sink.records()),
    )


def run(cohorts=(4, 16, 64), rounds=5, batch=1, seq=8, fmt="S1E3M7",
        seed=0, tiers=None, smoke=False, obs_overhead=False):
    # suite budget knob (DESIGN.md §8): a reduced BENCH_ROUNDS caps the
    # timed rounds too, so `BENCH_ROUNDS=2 python -m benchmarks.run` shrinks
    # this benchmark along with the others; cohort sizes / batch / seq have
    # their own flags (they set the measurement regime, not the budget)
    rounds = max(1, min(rounds, int(os.environ.get("BENCH_ROUNDS", rounds))))
    rows = [bench_size(c, rounds, batch, seq, fmt, seed) for c in cohorts]
    print_table(
        "Cohort scaling: loop vs vectorized (steady-state s/round)",
        rows,
        ["cohort", "loop_s_per_round", "vec_s_per_round", "speedup",
         "wire_match", "codec_match"],
    )
    payload = dict(smoke=smoke, fmt=fmt, rounds=rounds, batch=batch,
                   seq_len=seq, sizes=rows)
    if tiers:
        hrow = bench_tiers(max(cohorts), rounds, batch, seq, tiers, fmt, seed)
        print_table("Mixed-bitwidth cohort (engine only)", [hrow],
                    ["cohort", "tiers", "vec_s_per_round", "up_bytes"])
        payload["hetero"] = hrow
    if obs_overhead:
        orow = bench_obs_overhead(max(cohorts), rounds, batch, seq, fmt, seed)
        print_table("Telemetry overhead (engine, obs on vs off)", [orow],
                    ["cohort", "obs_off_s_per_round", "obs_on_s_per_round",
                     "overhead_pct", "records"])
        payload["obs_overhead"] = orow
    path = save_result("cohort_scale", payload)
    print(f"wrote {path}")
    assert all(r["wire_match"] and r["codec_match"] for r in rows), rows
    return rows


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--smoke", action="store_true",
                    help="CI-sized: cohorts 4,8 and 2 timed rounds")
    ap.add_argument("--cohorts", default=None,
                    help="comma-separated cohort sizes (default 4,16,64)")
    ap.add_argument("--rounds", type=int, default=None)
    ap.add_argument("--batch", type=int, default=1)
    ap.add_argument("--seq", type=int, default=8)
    ap.add_argument("--fmt", default="S1E3M7")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--tiers", default=None,
                    help="comma-separated profile names for a hetero row, "
                         "e.g. s1e3m7,s1e4m3,f32")
    ap.add_argument("--obs-overhead", action="store_true",
                    help="also time engine rounds with telemetry enabled "
                         "at the largest cohort (DESIGN.md §15 <=5% budget)")
    args = ap.parse_args(argv)
    if args.smoke:
        cohorts = (4, 8)
        rounds = args.rounds or 2
    else:
        cohorts = tuple(int(c) for c in (args.cohorts or "4,16,64").split(","))
        rounds = args.rounds or 5
    tiers = args.tiers.split(",") if args.tiers else None
    run(cohorts=cohorts, rounds=rounds, batch=args.batch, seq=args.seq,
        fmt=args.fmt, seed=args.seed, tiers=tiers, smoke=args.smoke,
        obs_overhead=args.obs_overhead)
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
