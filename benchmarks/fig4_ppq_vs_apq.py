"""Paper Fig. 4: PPQ@11-bit (90%%) vs APQ@13-bit (100%%) formats."""

from repro.core.omc import OMCConfig

from .common import conformer_setup, print_table, run_fl, save_result


def run():
    fam, cfg, task, data_fn, evalb = conformer_setup(iid=True)
    variants = [
        ("PPQ S1E3M7 @90%", OMCConfig.parse("S1E3M7", quantize_fraction=0.9)),
        ("APQ S1E3M9", OMCConfig.parse("S1E3M9", quantize_fraction=1.0)),
        ("APQ S1E4M8", OMCConfig.parse("S1E4M8", quantize_fraction=1.0)),
        ("APQ S1E5M7", OMCConfig.parse("S1E5M7", quantize_fraction=1.0)),
    ]
    rows = []
    for name, omc in variants:
        r = run_fl(fam, cfg, omc, data_fn, evalb)
        r["variant"] = name
        rows.append(r)
    print_table("Fig 4: PPQ@11b vs APQ@13b", rows, ["variant", "final_eval"])
    save_result("fig4_ppq_vs_apq", rows)
    return rows
