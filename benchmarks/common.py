"""Shared benchmark harness: paper-table reproductions at simulation scale.

Every benchmark follows the same recipe (DESIGN.md §8): train the paper's
Conformer (reduced, CPU-trainable) or a small LM under the *faithful*
federated simulation (per-client PPQ, transport re-quantization) and compare
FP32 vs OMC on loss curves + exact byte accounting — WER -> loss parity
(no LibriSpeech offline).

Budget knobs (BENCH_ROUNDS etc.) keep ``python -m benchmarks.run`` tractable
on one CPU core; raise them for tighter curves.
"""

from __future__ import annotations

import json
import os
import time
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp

from repro.configs.registry import get_arch
from repro.core.omc import OMCConfig
from repro.core.policy import QuantizePolicy
from repro.core.store import decompress_tree
from repro.data.synthetic import make_frame_task
from repro.federated import simulate
from repro.federated.cohort import CohortPlan
from repro.federated.state import compress_params
from repro.models import conformer as cf
from repro.models.common import IDENTITY_MAT
from repro.models.registry import get_family

BENCH_ROUNDS = int(os.environ.get("BENCH_ROUNDS", 24))
BENCH_CLIENTS = int(os.environ.get("BENCH_CLIENTS", 8))
BENCH_COHORT = int(os.environ.get("BENCH_COHORT", 4))
BENCH_BATCH = int(os.environ.get("BENCH_BATCH", 4))
OUT_DIR = os.path.join(os.path.dirname(__file__), "..", "experiments", "bench")


def conformer_setup(iid: bool = True, domain: int = 0, seed: int = 0):
    arch = get_arch("conformer_s")
    cfg = arch.smoke_config()
    task = make_frame_task(d_in=cfg.d_in, n_classes=cfg.n_classes, seq_len=32,
                           num_clients=BENCH_CLIENTS, iid=iid, seed=seed,
                           domain=domain)
    data_fn = lambda c, r, s: task.batch(c, r, s, BENCH_BATCH)
    eval_batches = [task.batch(100 + i, 10_000, 0, BENCH_BATCH) for i in range(4)]
    return cf, cfg, task, data_fn, eval_batches


def eval_loss(family, cfg, params, batches) -> float:
    f = jax.jit(lambda p, b: family.loss(cfg, p, b, IDENTITY_MAT))
    return float(sum(f(params, b) for b in batches) / len(batches))


def run_fl(family, cfg, omc: OMCConfig, data_fn, eval_batches,
           rounds: int = None, seed: int = 0, local_steps: int = 1,
           client_lr: float = 0.1) -> Dict:
    rounds = rounds or BENCH_ROUNDS
    sim = simulate.SimConfig(local_steps=local_steps, client_lr=client_lr)
    plan = CohortPlan(num_clients=BENCH_CLIENTS, cohort_size=BENCH_COHORT)
    t0 = time.time()
    evals = []

    def eval_fn(params_f32, r):
        return eval_loss(family, cfg, params_f32, eval_batches)

    params, hist = simulate.run_training(
        family, cfg, omc, sim, plan, data_fn, jax.random.PRNGKey(seed),
        num_rounds=rounds, eval_fn=eval_fn,
        eval_every=max(rounds // 6, 1),
    )
    dt = time.time() - t0
    final_eval = eval_loss(family, cfg, decompress_tree(params), eval_batches)
    return dict(
        fmt=omc.fmt.name,
        pvt=omc.pvt,
        fraction=omc.quantize_fraction,
        weights_only=omc.policy.weights_only,
        rounds=rounds,
        final_eval=final_eval,
        train_curve=[h["loss"] for h in hist],
        eval_curve=[h.get("eval") for h in hist if "eval" in h],
        wall_s=round(dt, 1),
        rounds_per_min=round(60 * rounds / dt, 2),
    )


def bytes_summary(family, cfg, omc: OMCConfig) -> Dict:
    from repro.core.omc import bytes_report
    params = family.init(jax.random.PRNGKey(0), cfg)
    return bytes_report(params, omc)


def save_result(name: str, payload) -> str:
    os.makedirs(OUT_DIR, exist_ok=True)
    path = os.path.join(OUT_DIR, f"{name}.json")
    with open(path, "w") as f:
        json.dump(payload, f, indent=1)
    return path


def print_table(title: str, rows: List[Dict], cols: List[str]):
    print(f"\n== {title} ==")
    widths = {c: max(len(c), max((len(_fmt(r.get(c))) for r in rows),
                                 default=0)) for c in cols}
    print("  ".join(c.ljust(widths[c]) for c in cols))
    for r in rows:
        print("  ".join(_fmt(r.get(c)).ljust(widths[c]) for c in cols))


def _fmt(v) -> str:
    if isinstance(v, float):
        return f"{v:.4f}"
    return str(v)
