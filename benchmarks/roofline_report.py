"""Aggregate the dry-run JSONs into the §Roofline table."""

import glob
import json
import os

from .common import print_table, save_result

DRYRUN_DIR = os.path.join(os.path.dirname(__file__), "..", "experiments",
                          "dryrun")


def run():
    rows = []
    for path in sorted(glob.glob(os.path.join(DRYRUN_DIR, "*_pod*.json"))):
        d = json.load(open(path))
        r = d["roofline"]
        base = os.path.basename(path)
        tag = base.split("_pod", 1)[1].replace(".json", "").lstrip("_") or "base"
        rows.append(dict(
            tag=tag,
            arch=d["arch"], shape=d["shape"],
            mesh="x".join(map(str, d["mesh"])),
            fmt=d["fmt"],
            compute_ms=round(r["compute_s"] * 1e3, 1),
            memory_ms=round(r["memory_s"] * 1e3, 1),
            coll_ms=round(r["collective_s"] * 1e3, 1),
            dominant=r["dominant"],
            useful=round(r["useful_flops_ratio"], 2),
        ))
    print_table("Roofline terms per (arch x shape x mesh)", rows,
                ["arch", "shape", "mesh", "fmt", "tag", "compute_ms",
                 "memory_ms", "coll_ms", "dominant", "useful"])
    save_result("roofline_report", rows)
    return rows
