"""Async vs sync throughput under straggler traces (DESIGN.md §10).

Compares the event-driven buffered runtime
(:mod:`repro.federated.async_engine`) against the barrier-synchronous
vectorized engine (:mod:`repro.federated.engine`) on the same population,
model, data stream, and Pareto heavy-tail latency trace:

  * **completed-client-updates per virtual second** — the sync engine's
    round makespan is the *max* latency over the invited cohort (the
    barrier); the async runtime keeps aggregating while stragglers are
    still in flight.  This is the headline number: the acceptance gate
    requires async >= 2x sync at cohort 64 under Pareto(alpha=1.5).
  * **wall-clock per aggregate** — sync rounds and async flushes timed
    *interleaved* (one of each per iteration, medians reported) so
    shared-host CPU noise hits both paths equally; shows the async host
    event loop + padded-vmap batching keeps the hot path compiled.
  * **model-quality-per-wire-byte at a matched update budget** — both
    paths run the same number of completed client updates (the same local
    token budget); reported as loss drop per wire MB, where async wire
    bytes come from the event-granular
    :class:`repro.federated.accounting.AsyncWireStats` ledger.

    PYTHONPATH=src python benchmarks/async_scale.py            # cohort 64
    PYTHONPATH=src python benchmarks/async_scale.py --smoke    # CI-sized

Emits ``experiments/bench/async_scale.json``.
"""

from __future__ import annotations

import argparse
import os
import time

import jax
import numpy as np

try:
    from .common import print_table, save_result
except ImportError:  # run as a script: python benchmarks/async_scale.py
    import sys

    sys.path.insert(0, os.path.dirname(os.path.abspath(__file__)))
    from common import print_table, save_result

from repro.core.omc import OMCConfig
from repro.data.synthetic import make_frame_task
from repro.federated import async_engine, engine, simulate, traces
from repro.federated.cohort import CohortPlan
from repro.models import conformer as cf
from repro.obs import Obs, null_span

CFG = cf.ConformerConfig(
    n_layers=2, d_model=32, n_heads=4, d_ff=64, n_classes=16, d_in=8
)


def _median(xs):
    xs = sorted(xs)
    n = len(xs)
    return xs[n // 2] if n % 2 else 0.5 * (xs[n // 2 - 1] + xs[n // 2])


def bench(cohort: int, buffer_goal: int, rounds: int, batch: int, seq: int,
          alpha: float, fmt: str, seed: int, obs=None) -> dict:
    """One comparison row: the whole population participates in both paths;
    sync invites everyone each round, async buffers K uploads.

    ``obs`` (DESIGN.md §15) traces the run: wall spans per sync round and
    async flush segment, virtual-clock spans per async client round, and
    per-flush metric bundles — exported by the caller via ``obs.flush()``.
    """
    omc = OMCConfig.parse(fmt)
    sim = simulate.SimConfig(local_steps=1, client_lr=0.1)
    task = make_frame_task(d_in=CFG.d_in, n_classes=CFG.n_classes,
                           seq_len=seq, num_clients=cohort)
    data_fn = lambda c, r, s: task.batch(c, r, s, batch)
    plan = CohortPlan(num_clients=cohort, cohort_size=cohort)
    spec = engine.CohortSpec(plan)
    trace = traces.ParetoTrace(seed=seed, latency=1.0, alpha=alpha)
    key = jax.random.PRNGKey(seed)
    specs = cf.param_specs(CFG)
    params = cf.init(key, CFG)
    storage0 = engine.compress_params(params, specs, omc)
    table = engine.accounting.build_wire_table(params, specs, omc)
    rkey = jax.random.fold_in(key, 0xC047)
    budget = cohort * rounds  # matched completed-client-update budget

    # --- sync path: barrier rounds; virtual makespan = slowest client -----
    round_fn = engine.make_round_fn(cf, CFG, specs, omc, sim, spec, data_fn)
    runner = async_engine.AsyncRunner(
        cf, CFG, omc, sim,
        async_engine.AsyncConfig(buffer_goal=buffer_goal, decay=0.5),
        trace, num_clients=cohort, data_fn=data_fn, init_key=key, obs=obs,
    )
    # warm-up (compile) both paths, untimed; the warm-up round trains from
    # the initial model, so its loss is the init-quality baseline both
    # paths' quality-per-byte deltas are measured against
    _, warm = engine.run_round_vectorized(
        cf, CFG, specs, omc, sim, storage0, data_fn, spec, 0, rkey,
        round_fn=round_fn,
    )
    init_loss = float(warm["loss"])
    runner.run_until(flushes=1)

    sync_makespans = [
        max(trace.round_latency(c, r, 0.0) for c in range(cohort))
        for r in range(rounds)
    ]
    # interleaved wall timing: one sync round, one async flush, repeat
    sync_t, flush_t = [], []
    sync_storage = storage0
    sync_metrics = None
    r = 1
    while r <= rounds or runner.completed < budget:
        if r <= rounds:
            t0 = time.perf_counter()
            with null_span(obs, "sync_round", round=r):
                sync_storage, sync_metrics = engine.run_round_vectorized(
                    cf, CFG, specs, omc, sim, sync_storage, data_fn, spec,
                    r, rkey, round_fn=round_fn, wire_table=table,
                )
            sync_t.append(time.perf_counter() - t0)
        if runner.completed < budget:
            t0 = time.perf_counter()
            runner.run_until(flushes=1)
            flush_t.append(time.perf_counter() - t0)
        r += 1

    # --- virtual-time throughput (the barrier vs no-barrier story) --------
    sync_vtime = float(np.sum(sync_makespans))
    sync_ups = cohort * rounds / sync_vtime
    async_vtime = runner.clock
    async_ups = runner.completed / async_vtime
    speedup = async_ups / sync_ups

    # --- quality per wire byte at the matched update budget ---------------
    sync_loss = float(sync_metrics["loss"])
    sync_wire = (table.download_bytes(omc) * cohort * rounds
                 + sum(  # all clients alive: full-cohort uploads per round;
                     # timed rounds are 1..rounds (warm-up consumed index 0)
                     # and PPQ upload masks are round-index-dependent
                     int(engine.accounting.cohort_upload_bytes(
                         table, omc, rr,
                         np.arange(cohort, dtype=np.int32)).sum())
                     for rr in range(1, rounds + 1)))
    async_loss = runner.history[-1]["loss"]
    snap = runner.stats.snapshot()  # stable derived keys (DESIGN.md §15)
    async_wire = snap["down_bytes"] + snap["up_bytes"]
    mb = 1024.0 * 1024.0

    return dict(
        cohort=cohort,
        buffer_goal=buffer_goal,
        alpha=alpha,
        update_budget=budget,
        sync_updates_per_vs=round(sync_ups, 4),
        async_updates_per_vs=round(async_ups, 4),
        vtime_speedup=round(speedup, 2),
        sync_wall_s_per_round=round(_median(sync_t), 4),
        async_wall_s_per_flush=round(_median(flush_t), 4),
        sync_wall_updates_per_s=round(cohort / _median(sync_t), 2),
        async_wall_updates_per_s=round(buffer_goal / _median(flush_t), 2),
        init_loss=round(init_loss, 4),
        sync_loss=round(sync_loss, 4),
        async_loss=round(async_loss, 4),
        sync_wire_mb=round(sync_wire / mb, 3),
        async_wire_mb=round(async_wire / mb, 3),
        sync_quality_per_mb=round((init_loss - sync_loss) / (sync_wire / mb), 5),
        async_quality_per_mb=round(
            (init_loss - async_loss) / (async_wire / mb), 5),
        async_stale_fraction=round(snap["stale_fraction"], 4),
        async_dropped_fraction=round(snap["dropped_fraction"], 4),
        peak_in_flight_mb=round(snap["peak_in_flight_bytes"] / mb, 3),
    )


def run(cohort=64, buffer_goal=16, rounds=5, batch=1, seq=8, alpha=1.5,
        fmt="S1E3M7", seed=0, smoke=False, trace=False):
    rounds = max(1, min(rounds, int(os.environ.get("BENCH_ROUNDS", rounds))))
    obs = Obs(run_name="async_scale") if trace else None
    row = bench(cohort, buffer_goal, rounds, batch, seq, alpha, fmt, seed,
                obs=obs)
    print_table(
        "Async vs sync under Pareto stragglers (virtual + wall clock)",
        [row],
        ["cohort", "buffer_goal", "sync_updates_per_vs",
         "async_updates_per_vs", "vtime_speedup", "sync_wall_s_per_round",
         "async_wall_s_per_flush", "async_stale_fraction",
         "async_dropped_fraction", "peak_in_flight_mb"],
    )
    print_table(
        "Quality per wire byte at matched update budget",
        [row],
        ["update_budget", "init_loss", "sync_loss", "async_loss", "sync_wire_mb",
         "async_wire_mb", "sync_quality_per_mb", "async_quality_per_mb"],
    )
    path = save_result("async_scale", dict(
        smoke=smoke, fmt=fmt, rounds=rounds, batch=batch, seq_len=seq,
        rows=[row],
    ))
    print(f"wrote {path}")
    if obs is not None:
        paths = obs.flush()
        print(f"wrote {paths['jsonl']} and {paths['perfetto']}")
    # acceptance gate: non-barrier aggregation must beat the straggler
    # barrier by >= 2x in completed updates per virtual second
    assert row["vtime_speedup"] >= 2.0, row
    return [row]


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--smoke", action="store_true",
                    help="CI-sized: cohort 8, buffer 4, 3 rounds")
    ap.add_argument("--cohort", type=int, default=64)
    ap.add_argument("--buffer", type=int, default=16)
    ap.add_argument("--rounds", type=int, default=None)
    ap.add_argument("--batch", type=int, default=1)
    ap.add_argument("--seq", type=int, default=8)
    ap.add_argument("--alpha", type=float, default=1.5,
                    help="Pareto tail index (smaller = heavier stragglers)")
    ap.add_argument("--fmt", default="S1E3M7")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--trace", action="store_true",
                    help="record obs telemetry (JSONL + Perfetto under "
                         "experiments/obs/)")
    args = ap.parse_args(argv)
    if args.smoke:
        cohort, buffer_goal, rounds = 8, 4, args.rounds or 3
    else:
        cohort, buffer_goal = args.cohort, args.buffer
        rounds = args.rounds or 5
    run(cohort=cohort, buffer_goal=buffer_goal, rounds=rounds,
        batch=args.batch, seq=args.seq, alpha=args.alpha, fmt=args.fmt,
        seed=args.seed, smoke=args.smoke, trace=args.trace)
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
