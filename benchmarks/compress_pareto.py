"""Quality-vs-wire-bytes Pareto frontier across the strategy zoo (DESIGN.md §11).

For each model family (a reduced Conformer and a small transformer LM), this
benchmark briefly trains FP32 reference weights, then pushes them through
every strategy in :func:`repro.compress.default_zoo` — the paper's OMC
minifloats, top-k sparsification, ternary TNT, and the quantize→top-k→DEFLATE
pipeline — and records the resulting (eval-loss, wire-bytes) point.  Points
that no other strategy beats on *both* axes are flagged ``pareto=True``; the
FP32 uncompressed model is included as the anchor point.

Every row's wire bytes are reconciled three ways before being reported
(byte-exact, asserted, see ``reconciled``):

  * ``repro.compress.tree_wire_bytes`` over the encoded tree,
  * the serialized §7 payload's actual ``body_bytes``
    (``repro.api.codecs``), decoded back bit-exactly (digest-checked),
  * for shape-determined strategies, the planning-side ledger
    ``repro.federated.accounting.WireTable.download_bytes_strategy`` —
    and for the paper's own S1E3M7+PVT point additionally the historical
    ``WireTable.download_bytes(omc)``, which must stay inside the ~59%
    byte-reduction envelope (wire_ratio <= 0.6).

    PYTHONPATH=src python benchmarks/compress_pareto.py            # full
    PYTHONPATH=src python benchmarks/compress_pareto.py --smoke    # CI-sized

A second section (``--trained``) moves the frontier from *transport of
frozen weights* to *training to convergence*: each zoo strategy drives the
vectorized engine (DESIGN.md §12) for N rounds and the recorded point is
(final eval loss, cumulative wire MB).  This is where error feedback earns
its keep — EF top-k must reach a strictly lower eval loss than plain top-k
at byte-identical wire cost — and where the strategy seam is re-gated:
``strategy="omc"`` must land on exactly the hardcoded path's loss and bytes.

Emits ``experiments/bench/compress_strategies.json`` (sections merge, so
``--static`` and ``--trained`` runs update one artifact).
"""

from __future__ import annotations

import argparse
import json
import os
import time

import jax
import jax.numpy as jnp

try:
    from .common import (BENCH_CLIENTS, BENCH_COHORT, OUT_DIR,
                         conformer_setup, eval_loss, print_table, save_result)
except ImportError:  # run as a script: python benchmarks/compress_pareto.py
    import sys

    sys.path.insert(0, os.path.dirname(os.path.abspath(__file__)))
    from common import (BENCH_CLIENTS, BENCH_COHORT, OUT_DIR,
                        conformer_setup, eval_loss, print_table, save_result)

from repro import compress
from repro.api import codecs
from repro.core.omc import OMCConfig
from repro.core.store import decompress_tree
from repro.data.synthetic import make_lm_task
from repro.federated import accounting, engine, simulate
from repro.federated.cohort import CohortPlan
from repro.models import transformer as tr
from repro.models.common import IDENTITY_MAT

LM_CFG = tr.TransformerConfig(n_layers=2, d_model=64, n_heads=4, n_kv_heads=2,
                              d_ff=128, vocab=256)


def _pretrain(family, cfg, task, steps: int, batch: int, lr: float = 0.1,
              seed: int = 0):
    """A few jitted SGD steps — enough structure in the weights that lossy
    transport visibly moves the eval loss."""
    params = family.init(jax.random.PRNGKey(seed), cfg)

    @jax.jit
    def step(p, b):
        loss, g = jax.value_and_grad(
            lambda q: family.loss(cfg, q, b, IDENTITY_MAT))(p)
        return jax.tree_util.tree_map(lambda w, gg: w - lr * gg, p, g), loss

    for i in range(steps):
        params, _ = step(params, task.batch(i % 4, i, 0, batch))
    return params


def _model_setups(smoke: bool, seed: int):
    """(name, family, cfg, params_f32, eval_batches) per model family."""
    steps = 6 if smoke else 40
    batch = 2 if smoke else 4
    out = []

    cf, ccfg, ctask, _, c_eval = conformer_setup(seed=seed)
    c_eval = c_eval[:2] if smoke else c_eval
    out.append(("conformer_s", cf, ccfg,
                _pretrain(cf, ccfg, ctask, steps, batch, seed=seed), c_eval))

    ltask = make_lm_task(vocab=LM_CFG.vocab, seq_len=32, num_clients=4,
                         seed=seed)
    l_eval = [ltask.batch(100 + i, 10_000, 0, batch)
              for i in range(2 if smoke else 4)]
    out.append(("transformer_lm", tr, LM_CFG,
                _pretrain(tr, LM_CFG, ltask, steps, batch, seed=seed), l_eval))
    return out


def _measure(strategy, family, cfg, params_f32, eval_batches, omc, wt):
    """One Pareto point: encode, reconcile bytes three ways, eval quality."""
    specs = family.param_specs(cfg)
    t0 = time.time()
    tree = compress.encode_tree(strategy, params_f32, omc, specs)
    t_encode = time.time() - t0
    twb = compress.tree_wire_bytes(tree)

    # wire reconciliation: serialized body == tree accounting == codec report
    payload = codecs.encode_payload(tree, strategy=strategy)
    info = codecs.peek_payload(payload)
    rep = codecs.payload_bytes_report(tree)
    assert info.body_bytes == twb["wire_bytes"] == rep["wire_bytes"], (
        strategy.label, info.body_bytes, twb["wire_bytes"], rep["wire_bytes"])
    assert info.strategy == strategy.name
    decoded, _ = codecs.decode_payload(payload)
    assert codecs.tree_digest(decoded) == codecs.tree_digest(tree)

    # planning-side ledger (shape-determined strategies only)
    plan = strategy.plan_wire_bytes(1, 1)
    planned = plan is not None
    if planned:
        assert wt.download_bytes_strategy(strategy) == twb["wire_bytes"], (
            strategy.label, wt.download_bytes_strategy(strategy),
            twb["wire_bytes"])

    loss = eval_loss(family, cfg, compress.decode_tree(tree), eval_batches)
    return dict(
        strategy=strategy.name,
        label=strategy.label,
        wire_version=strategy.wire_version,
        delta_rule=strategy.delta_rule,
        wire_bytes=twb["wire_bytes"],
        wire_mb=round(twb["wire_bytes"] / 2**20, 4),
        wire_ratio=round(twb["wire_ratio"], 4),
        loss=loss,
        planned=planned,
        reconciled=True,
        encode_ms=round(t_encode * 1e3, 1),
        per_strategy=twb["per_strategy"],
    )


def _pareto_flags(rows):
    """Non-dominated on (wire_bytes, loss): smaller is better on both."""
    for r in rows:
        r["pareto"] = not any(
            o is not r
            and o["wire_bytes"] <= r["wire_bytes"] and o["loss"] <= r["loss"]
            and (o["wire_bytes"] < r["wire_bytes"] or o["loss"] < r["loss"])
            for o in rows
        )
    return rows


def _train_point(label, strategy, family, cfg, data_fn, eval_batches, omc,
                 sim, spec, rounds, seed):
    """Train to convergence under one strategy; return the frontier point."""
    t0 = time.time()
    storage, hist = engine.run_training_vectorized(
        family, cfg, omc, sim, spec, data_fn, jax.random.PRNGKey(seed),
        num_rounds=rounds, eval_every=10_000, strategy=strategy,
    )
    dt = time.time() - t0
    up = sum(h["up_bytes"] for h in hist)
    down = sum(h["down_bytes"] for h in hist)
    final = eval_loss(family, cfg, decompress_tree(storage), eval_batches)
    return dict(
        label=label,
        strategy=strategy.name if strategy is not None else "omc",
        error_feedback=bool(getattr(strategy, "error_feedback", False)),
        rounds=rounds,
        final_eval=round(final, 6),
        up_mb=round(up / 2**20, 4),
        down_mb=round(down / 2**20, 4),
        wire_mb=round((up + down) / 2**20, 4),
        up_bytes=up,
        down_bytes=down,
        train_curve=[round(h["loss"], 5) for h in hist],
        wall_s=round(dt, 1),
    )


def run_trained(smoke: bool = False, seed: int = 0):
    """Trained-to-convergence frontier: eval loss vs cumulative wire MB."""
    family, cfg, task, data_fn, eval_batches = conformer_setup(seed=seed)
    eval_batches = eval_batches[:2] if smoke else eval_batches
    rounds = 4 if smoke else 30
    omc = OMCConfig.parse("S1E3M7")
    sim = simulate.SimConfig(local_steps=2, client_lr=0.1)
    spec = engine.CohortSpec(CohortPlan(num_clients=BENCH_CLIENTS,
                                        cohort_size=BENCH_COHORT))
    density = 0.1
    points = [
        ("omc-hardcoded", None),
        ("omc-strategy", compress.get_strategy("omc")),
        ("topk-ef", compress.get_strategy("topk", density=density)),
        ("topk-plain", compress.get_strategy("topk", density=density,
                                             error_feedback=False)),
        ("ternary-ef", compress.get_strategy("ternary")),
    ]
    rows = [_train_point(lbl, s, family, cfg, data_fn, eval_batches, omc,
                         sim, spec, rounds, seed) for lbl, s in points]
    by = {r["label"]: r for r in rows}

    # the strategy seam costs nothing: strategy="omc" is the hardcoded path
    assert by["omc-strategy"]["final_eval"] == by["omc-hardcoded"]["final_eval"]
    assert by["omc-strategy"]["up_bytes"] == by["omc-hardcoded"]["up_bytes"]
    assert by["omc-strategy"]["down_bytes"] == by["omc-hardcoded"]["down_bytes"]
    # matched wire cost: EF and plain top-k ship byte-identical payloads
    assert by["topk-ef"]["up_bytes"] == by["topk-plain"]["up_bytes"]
    ef_wins = by["topk-ef"]["final_eval"] < by["topk-plain"]["final_eval"]
    if not smoke:
        # the acceptance gate: the residual memory must pay off at this budget
        assert ef_wins, (by["topk-ef"]["final_eval"],
                         by["topk-plain"]["final_eval"])

    # Pareto flags on (cumulative wire, final eval)
    for r in rows:
        r["wire_bytes"], r["loss"] = r["up_bytes"] + r["down_bytes"], r["final_eval"]
    _pareto_flags(rows)
    for r in rows:
        del r["wire_bytes"], r["loss"]

    print_table("Trained-to-convergence frontier (eval loss vs wire MB)",
                rows, ["label", "rounds", "final_eval", "up_mb", "down_mb",
                       "wire_mb", "error_feedback", "pareto", "wall_s"])
    return dict(smoke=smoke, seed=seed, rounds=rounds, density=density,
                cohort=spec.plan.cohort_size, num_clients=spec.plan.num_clients,
                local_steps=sim.local_steps, client_lr=sim.client_lr,
                ef_wins=bool(ef_wins), points=rows)


def _merge_save(section_updates):
    """Update sections of compress_strategies.json, preserving the others."""
    path = os.path.join(OUT_DIR, "compress_strategies.json")
    payload = {}
    if os.path.exists(path):
        with open(path) as f:
            payload = json.load(f)
    payload.update(section_updates)
    save_result("compress_strategies", payload)
    return payload


def run(smoke: bool = False, seed: int = 0, static: bool = True,
        trained: bool = True):
    sections = {}
    if static:
        sections.update(run_static(smoke=smoke, seed=seed))
    if trained:
        sections["trained"] = run_trained(smoke=smoke, seed=seed)
    return _merge_save(sections)


def run_static(smoke: bool = False, seed: int = 0):
    zoo = compress.default_zoo()
    omc = OMCConfig.parse("S1E3M7")  # selection policy shared by every point
    models = {}
    all_rows = []

    for name, family, cfg, params_f32, eval_batches in _model_setups(
            smoke, seed):
        specs = family.param_specs(cfg)
        wt = accounting.build_wire_table(params_f32, specs, omc)
        baseline = eval_loss(family, cfg, params_f32, eval_batches)
        fp32_bytes = wt.fp32_total

        rows = [dict(strategy="fp32", label="fp32", wire_version=0,
                     delta_rule=None, wire_bytes=fp32_bytes,
                     wire_mb=round(fp32_bytes / 2**20, 4), wire_ratio=1.0,
                     loss=baseline, planned=True, reconciled=True,
                     encode_ms=0.0, per_strategy={})]
        for s in zoo:
            rows.append(_measure(s, family, cfg, params_f32, eval_batches,
                                 omc, wt))

        # the paper's own point must stay inside the ~59%-reduction envelope
        paper = next(r for r in rows if r["label"] == "omc-s1e3m7")
        assert paper["wire_bytes"] == wt.download_bytes(omc)
        assert paper["wire_ratio"] <= 0.6, paper["wire_ratio"]

        _pareto_flags(rows)
        for r in rows:
            r["model"] = name
            r["delta_loss"] = round(r["loss"] - baseline, 6)
        models[name] = dict(baseline_loss=baseline, fp32_bytes=fp32_bytes,
                            points=rows)
        all_rows.extend(rows)

    print_table("Quality vs wire bytes (Pareto frontier)", all_rows,
                ["model", "label", "wire_mb", "wire_ratio", "loss",
                 "delta_loss", "pareto", "planned", "encode_ms"])
    return dict(
        smoke=smoke, seed=seed,
        strategies=[s.describe() for s in zoo],
        selection_fmt=omc.fmt.name,
        models=models,
    )


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--smoke", action="store_true",
                    help="CI-sized: fewer pretrain steps, eval batches, rounds")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--static", action="store_true",
                    help="only the frozen-weights transport frontier")
    ap.add_argument("--trained", action="store_true",
                    help="only the trained-to-convergence frontier")
    args = ap.parse_args(argv)
    both = args.static == args.trained  # neither flag (or both) = everything
    run(smoke=args.smoke, seed=args.seed,
        static=both or args.static, trained=both or args.trained)
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
