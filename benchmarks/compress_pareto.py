"""Quality-vs-wire-bytes Pareto frontier across the strategy zoo (DESIGN.md §11).

For each model family (a reduced Conformer and a small transformer LM), this
benchmark briefly trains FP32 reference weights, then pushes them through
every strategy in :func:`repro.compress.default_zoo` — the paper's OMC
minifloats, top-k sparsification, ternary TNT, and the quantize→top-k→DEFLATE
pipeline — and records the resulting (eval-loss, wire-bytes) point.  Points
that no other strategy beats on *both* axes are flagged ``pareto=True``; the
FP32 uncompressed model is included as the anchor point.

Every row's wire bytes are reconciled three ways before being reported
(byte-exact, asserted, see ``reconciled``):

  * ``repro.compress.tree_wire_bytes`` over the encoded tree,
  * the serialized §7 payload's actual ``body_bytes``
    (``repro.api.codecs``), decoded back bit-exactly (digest-checked),
  * for shape-determined strategies, the planning-side ledger
    ``repro.federated.accounting.WireTable.download_bytes_strategy`` —
    and for the paper's own S1E3M7+PVT point additionally the historical
    ``WireTable.download_bytes(omc)``, which must stay inside the ~59%
    byte-reduction envelope (wire_ratio <= 0.6).

    PYTHONPATH=src python benchmarks/compress_pareto.py            # full
    PYTHONPATH=src python benchmarks/compress_pareto.py --smoke    # CI-sized

Emits ``experiments/bench/compress_strategies.json``.
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

try:
    from .common import conformer_setup, eval_loss, print_table, save_result
except ImportError:  # run as a script: python benchmarks/compress_pareto.py
    import os
    import sys

    sys.path.insert(0, os.path.dirname(os.path.abspath(__file__)))
    from common import conformer_setup, eval_loss, print_table, save_result

from repro import compress
from repro.api import codecs
from repro.core.omc import OMCConfig
from repro.data.synthetic import make_lm_task
from repro.federated import accounting
from repro.models import transformer as tr
from repro.models.common import IDENTITY_MAT

LM_CFG = tr.TransformerConfig(n_layers=2, d_model=64, n_heads=4, n_kv_heads=2,
                              d_ff=128, vocab=256)


def _pretrain(family, cfg, task, steps: int, batch: int, lr: float = 0.1,
              seed: int = 0):
    """A few jitted SGD steps — enough structure in the weights that lossy
    transport visibly moves the eval loss."""
    params = family.init(jax.random.PRNGKey(seed), cfg)

    @jax.jit
    def step(p, b):
        loss, g = jax.value_and_grad(
            lambda q: family.loss(cfg, q, b, IDENTITY_MAT))(p)
        return jax.tree_util.tree_map(lambda w, gg: w - lr * gg, p, g), loss

    for i in range(steps):
        params, _ = step(params, task.batch(i % 4, i, 0, batch))
    return params


def _model_setups(smoke: bool, seed: int):
    """(name, family, cfg, params_f32, eval_batches) per model family."""
    steps = 6 if smoke else 40
    batch = 2 if smoke else 4
    out = []

    cf, ccfg, ctask, _, c_eval = conformer_setup(seed=seed)
    c_eval = c_eval[:2] if smoke else c_eval
    out.append(("conformer_s", cf, ccfg,
                _pretrain(cf, ccfg, ctask, steps, batch, seed=seed), c_eval))

    ltask = make_lm_task(vocab=LM_CFG.vocab, seq_len=32, num_clients=4,
                         seed=seed)
    l_eval = [ltask.batch(100 + i, 10_000, 0, batch)
              for i in range(2 if smoke else 4)]
    out.append(("transformer_lm", tr, LM_CFG,
                _pretrain(tr, LM_CFG, ltask, steps, batch, seed=seed), l_eval))
    return out


def _measure(strategy, family, cfg, params_f32, eval_batches, omc, wt):
    """One Pareto point: encode, reconcile bytes three ways, eval quality."""
    specs = family.param_specs(cfg)
    t0 = time.time()
    tree = compress.encode_tree(strategy, params_f32, omc, specs)
    t_encode = time.time() - t0
    twb = compress.tree_wire_bytes(tree)

    # wire reconciliation: serialized body == tree accounting == codec report
    payload = codecs.encode_payload(tree, strategy=strategy)
    info = codecs.peek_payload(payload)
    rep = codecs.payload_bytes_report(tree)
    assert info.body_bytes == twb["wire_bytes"] == rep["wire_bytes"], (
        strategy.label, info.body_bytes, twb["wire_bytes"], rep["wire_bytes"])
    assert info.strategy == strategy.name
    decoded, _ = codecs.decode_payload(payload)
    assert codecs.tree_digest(decoded) == codecs.tree_digest(tree)

    # planning-side ledger (shape-determined strategies only)
    plan = strategy.plan_wire_bytes(1, 1)
    planned = plan is not None
    if planned:
        assert wt.download_bytes_strategy(strategy) == twb["wire_bytes"], (
            strategy.label, wt.download_bytes_strategy(strategy),
            twb["wire_bytes"])

    loss = eval_loss(family, cfg, compress.decode_tree(tree), eval_batches)
    return dict(
        strategy=strategy.name,
        label=strategy.label,
        wire_version=strategy.wire_version,
        delta_rule=strategy.delta_rule,
        wire_bytes=twb["wire_bytes"],
        wire_mb=round(twb["wire_bytes"] / 2**20, 4),
        wire_ratio=round(twb["wire_ratio"], 4),
        loss=loss,
        planned=planned,
        reconciled=True,
        encode_ms=round(t_encode * 1e3, 1),
        per_strategy=twb["per_strategy"],
    )


def _pareto_flags(rows):
    """Non-dominated on (wire_bytes, loss): smaller is better on both."""
    for r in rows:
        r["pareto"] = not any(
            o is not r
            and o["wire_bytes"] <= r["wire_bytes"] and o["loss"] <= r["loss"]
            and (o["wire_bytes"] < r["wire_bytes"] or o["loss"] < r["loss"])
            for o in rows
        )
    return rows


def run(smoke: bool = False, seed: int = 0):
    zoo = compress.default_zoo()
    omc = OMCConfig.parse("S1E3M7")  # selection policy shared by every point
    models = {}
    all_rows = []

    for name, family, cfg, params_f32, eval_batches in _model_setups(
            smoke, seed):
        specs = family.param_specs(cfg)
        wt = accounting.build_wire_table(params_f32, specs, omc)
        baseline = eval_loss(family, cfg, params_f32, eval_batches)
        fp32_bytes = wt.fp32_total

        rows = [dict(strategy="fp32", label="fp32", wire_version=0,
                     delta_rule=None, wire_bytes=fp32_bytes,
                     wire_mb=round(fp32_bytes / 2**20, 4), wire_ratio=1.0,
                     loss=baseline, planned=True, reconciled=True,
                     encode_ms=0.0, per_strategy={})]
        for s in zoo:
            rows.append(_measure(s, family, cfg, params_f32, eval_batches,
                                 omc, wt))

        # the paper's own point must stay inside the ~59%-reduction envelope
        paper = next(r for r in rows if r["label"] == "omc-s1e3m7")
        assert paper["wire_bytes"] == wt.download_bytes(omc)
        assert paper["wire_ratio"] <= 0.6, paper["wire_ratio"]

        _pareto_flags(rows)
        for r in rows:
            r["model"] = name
            r["delta_loss"] = round(r["loss"] - baseline, 6)
        models[name] = dict(baseline_loss=baseline, fp32_bytes=fp32_bytes,
                            points=rows)
        all_rows.extend(rows)

    print_table("Quality vs wire bytes (Pareto frontier)", all_rows,
                ["model", "label", "wire_mb", "wire_ratio", "loss",
                 "delta_loss", "pareto", "planned", "encode_ms"])
    payload = dict(
        smoke=smoke, seed=seed,
        strategies=[s.describe() for s in zoo],
        selection_fmt=omc.fmt.name,
        models=models,
    )
    save_result("compress_strategies", payload)
    return payload


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--smoke", action="store_true",
                    help="CI-sized: fewer pretrain steps and eval batches")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)
    run(smoke=args.smoke, seed=args.seed)
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
