"""Paper Table 2: Streaming Conformer domain adaptation (surrogate).

Pretrain on the Non-MF analogue (domain 0), adapt on MF (domain 1).
Domain adaptation tolerates smaller bitwidths: S1E3M7 matches FP32; even
S1E2M3 improves over the before-adaptation baseline.
"""

import jax

from repro.core.omc import OMCConfig
from repro.core.store import decompress_tree
from repro.data.synthetic import make_frame_task
from repro.federated import simulate
from repro.federated.cohort import CohortPlan
from repro.models import conformer as cf

from .common import (BENCH_BATCH, BENCH_CLIENTS, BENCH_COHORT, BENCH_ROUNDS,
                     bytes_summary, conformer_setup, eval_loss, print_table,
                     run_fl, save_result)


def run():
    fam, cfg, _, _, _ = conformer_setup()
    # domain 0 = source (Non-MF analogue); domain 1 = target (MF analogue)
    src = make_frame_task(d_in=cfg.d_in, n_classes=cfg.n_classes, seq_len=32,
                          num_clients=BENCH_CLIENTS, iid=True, domain=0)
    tgt = make_frame_task(d_in=cfg.d_in, n_classes=cfg.n_classes, seq_len=32,
                          num_clients=BENCH_CLIENTS, iid=True, domain=1)
    tgt_eval = [tgt.batch(100 + i, 10_000, 0, BENCH_BATCH) for i in range(4)]

    # pretrain once in FP32 on the source domain
    omc_fp = OMCConfig.parse("S1E8M23")
    sim = simulate.SimConfig(local_steps=1, client_lr=0.1)
    plan = CohortPlan(num_clients=BENCH_CLIENTS, cohort_size=BENCH_COHORT)
    pre_params, _ = simulate.run_training(
        fam, cfg, omc_fp, sim, plan,
        lambda c, r, s: src.batch(c, r, s, BENCH_BATCH),
        jax.random.PRNGKey(0), num_rounds=BENCH_ROUNDS, eval_every=10**9)
    before = eval_loss(fam, cfg, decompress_tree(pre_params), tgt_eval)

    rows = [dict(fmt="before-adaptation", final_eval=before)]
    for fmt in ("S1E8M23", "S1E3M7", "S1E2M3"):
        omc = OMCConfig.parse(fmt)
        params, _ = simulate.run_training(
            fam, cfg, omc, sim, plan,
            lambda c, r, s: tgt.batch(c, r, s, BENCH_BATCH),
            jax.random.PRNGKey(1), num_rounds=BENCH_ROUNDS, eval_every=10**9,
            init_params=decompress_tree(pre_params))
        byt = bytes_summary(fam, cfg, omc)
        rows.append(dict(fmt=fmt,
                         final_eval=eval_loss(fam, cfg, decompress_tree(params),
                                              tgt_eval),
                         mem_pct=round(100 * byt["packed_ratio"])))
    print_table("Table 2: Streaming Conformer, domain adaptation",
                rows, ["fmt", "final_eval", "mem_pct"])
    assert rows[-1]["final_eval"] < rows[0]["final_eval"], \
        "S1E2M3 should still improve over before-adaptation"
    save_result("table2_adaptation", rows)
    return rows
