"""Wire-format benchmark: payload size + codec latency (DESIGN.md §7).

Measures, per minifloat format, the serialized download payload of a small
transformer server state (full and round-over-round delta), encode/decode
wall time, and the reconciliation against the core byte accounting
(``state_bytes_report`` packed bytes must equal the payload body exactly).
Emits ``experiments/bench/api_wire.json``.
"""

import time

import jax

from repro.api.codecs import decode_payload, payload_bytes_report
from repro.api.session import FLClient, FLSession
from repro.core.omc import OMCConfig
from repro.data.synthetic import make_lm_task
from repro.federated.cohort import CohortPlan
from repro.federated.state import state_bytes_report
from repro.models import transformer as tr
from repro.models.common import IDENTITY_MAT

from .common import print_table, save_result

CFG = tr.TransformerConfig(n_layers=4, d_model=128, n_heads=8, n_kv_heads=4,
                           d_ff=256, vocab=512)


def _one_wire_round(fmt: str, client_lr: float = 0.05):
    """Run one loopback round; return sizes + timings for the next round's
    delta download (what a million repeat clients would fetch)."""
    omc = OMCConfig.parse(fmt)
    task = make_lm_task(vocab=CFG.vocab, seq_len=32, num_clients=4)

    @jax.jit
    def sgd_step(params, batch):
        loss, g = jax.value_and_grad(
            lambda p: tr.loss(CFG, p, batch, IDENTITY_MAT))(params)
        return jax.tree_util.tree_map(
            lambda w, gg: w - client_lr * gg, params, g), loss

    def train_fn(params, cid, r):
        params, _ = sgd_step(params, task.batch(cid, r, 0, 4))
        return params

    plan = CohortPlan(num_clients=4, cohort_size=2)
    sess = FLSession(tr, CFG, omc, plan=plan)
    clients = {c: FLClient(c, tr, CFG, omc, train_fn) for c in range(4)}

    t0 = time.time()
    full = sess.server_payload()
    t_encode = time.time() - t0
    t0 = time.time()
    decode_payload(full)
    t_decode = time.time() - t0

    ticket = sess.begin_round()
    for cid in ticket.client_ids:
        sess.ingest(cid, clients[cid].run_round(ticket))
    sess.close_round()

    t0 = time.time()
    delta = sess.server_payload(delta=True)
    t_delta = time.time() - t0

    rep = payload_bytes_report(sess.storage)
    state_rep = state_bytes_report(sess.storage)
    assert rep["wire_bytes"] == state_rep["packed_bytes"]
    return dict(
        fmt=fmt,
        full_bytes=len(full),
        delta_bytes=len(delta),
        fp32_bytes=rep["fp32_bytes"],
        full_pct=round(100 * len(full) / rep["fp32_bytes"], 1),
        delta_pct=round(100 * len(delta) / rep["fp32_bytes"], 1),
        encode_ms=round(t_encode * 1e3, 1),
        decode_ms=round(t_decode * 1e3, 1),
        delta_encode_ms=round(t_delta * 1e3, 1),
        reconciled=True,
    )


def run():
    rows = [_one_wire_round(fmt) for fmt in ("S1E5M10", "S1E4M8", "S1E3M7")]
    print_table("Wire payloads (download; delta = round-over-round)", rows,
                ["fmt", "full_bytes", "full_pct", "delta_bytes", "delta_pct",
                 "encode_ms", "decode_ms", "delta_encode_ms"])
    save_result("api_wire", rows)
    return rows
