"""Paper Table 1: Non-Streaming Conformer on IID LibriSpeech (surrogate).

FP32 (S1E8M23) vs OMC S1E4M14: comparable loss at 64%% parameter
memory/communication, with round-speed overhead <= ~10%%.
"""

from repro.core.omc import OMCConfig

from .common import (bytes_summary, conformer_setup, print_table, run_fl,
                     save_result)


def run():
    import dataclasses
    fam, cfg_s, task, data_fn, evalb = conformer_setup(iid=True)
    cfg = dataclasses.replace(cfg_s, window=None, causal_conv=False)  # non-streaming
    rows = []
    for fmt in ("S1E8M23", "S1E4M14"):
        omc = OMCConfig.parse(fmt)
        r = run_fl(fam, cfg, omc, data_fn, evalb)
        byt = bytes_summary(fam, cfg, omc)
        r["mem_ratio"] = byt["packed_ratio"]
        rows.append(r)
    base = rows[0]
    for r in rows:
        r["speed_pct"] = round(100 * r["rounds_per_min"] /
                               max(base["rounds_per_min"], 1e-9))
        r["mem_pct"] = round(100 * r["mem_ratio"])
    print_table("Table 1: Non-Streaming Conformer, IID",
                rows, ["fmt", "final_eval", "mem_pct", "speed_pct",
                       "rounds_per_min"])
    save_result("table1_iid", rows)
    return rows
