"""Train a federated model under any zoo compressor (README cookbook 12).

Demonstrates the §12 training contract end to end: pick a path (reference
loop / vectorized engine / async runtime) and a strategy from the zoo, and
train a small Conformer with the chosen compressor on the wire — with
per-client error-feedback residuals for the sparse strategies, and the
exact byte ledger alongside the loss curve.

    PYTHONPATH=src python examples/train_under_strategy.py                      # engine + EF top-k
    PYTHONPATH=src python examples/train_under_strategy.py --strategy ternary
    PYTHONPATH=src python examples/train_under_strategy.py --strategy omc --path loop
    PYTHONPATH=src python examples/train_under_strategy.py --path async --rounds 6
    PYTHONPATH=src python examples/train_under_strategy.py --no-error-feedback  # plain top-k
    PYTHONPATH=src python examples/train_under_strategy.py --smoke

``--strategy none`` trains the hardcoded OMC path (the baseline the
``omc`` strategy must reproduce bit for bit — try both and diff the
output).  ``--strategy pipeline`` implies ``--no-wire``: its DEFLATE stage
is data-dependent, so there is no shape-determined byte plan to report.
"""

from __future__ import annotations

import argparse

import jax
import numpy as np

from repro import compress
from repro.compress import feedback
from repro.core.omc import OMCConfig
from repro.core.store import decompress_tree
from repro.data.synthetic import make_frame_task
from repro.federated import async_engine, engine, simulate, traces
from repro.federated.cohort import CohortPlan
from repro.models import conformer as cf
from repro.models.common import IDENTITY_MAT

CFG = cf.ConformerConfig(
    n_layers=2, d_model=32, n_heads=4, d_ff=64, n_classes=16, d_in=8
)
OMC = OMCConfig.parse("S1E3M7")


def _strategy(args):
    if args.strategy == "none":
        return None
    kw = {}
    if args.strategy == "topk":
        kw = dict(density=args.density,
                  error_feedback=not args.no_error_feedback)
    elif args.strategy in ("ternary", "pipeline"):
        kw = dict(error_feedback=not args.no_error_feedback)
    return compress.get_strategy(args.strategy, **kw)


def _eval(params_f32, batches):
    f = jax.jit(lambda p, b: cf.loss(CFG, p, b, IDENTITY_MAT))
    return float(sum(f(params_f32, b) for b in batches) / len(batches))


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--strategy", default="topk",
                    choices=["none"] + compress.available_strategies())
    ap.add_argument("--path", default="engine",
                    choices=["loop", "engine", "async"])
    ap.add_argument("--rounds", type=int, default=8)
    ap.add_argument("--density", type=float, default=0.1)
    ap.add_argument("--no-error-feedback", action="store_true",
                    help="sparse strategies: drop the residual accumulator")
    ap.add_argument("--no-wire", action="store_true",
                    help="skip byte accounting (forced for pipeline)")
    ap.add_argument("--smoke", action="store_true", help="2 rounds, tiny eval")
    args = ap.parse_args(argv)
    if args.smoke:
        args.rounds = 2

    strategy = _strategy(args)
    wire = not (args.no_wire or args.strategy == "pipeline")
    rounds = args.rounds
    plan = CohortPlan(num_clients=8, cohort_size=4)
    task = make_frame_task(d_in=CFG.d_in, n_classes=CFG.n_classes, seq_len=24,
                           num_clients=plan.num_clients)
    data_fn = lambda c, r, s: task.batch(c, r, s, 4)
    eval_batches = [task.batch(100 + i, 10_000, 0, 4)
                    for i in range(1 if args.smoke else 4)]
    sim = simulate.SimConfig(local_steps=2, client_lr=0.1)
    key = jax.random.PRNGKey(0)
    label = strategy.label if strategy is not None else "omc (hardcoded)"
    print(f"path={args.path}  strategy={label}  rounds={rounds}  wire={wire}")

    ef = None
    if feedback.takes_residual(OMC, strategy):
        specs = cf.param_specs(CFG)
        ef = feedback.init_ef_state(cf.init(key, CFG), specs, OMC,
                                    plan.num_clients)
        print(f"error-feedback state: {len(ef)} vars, "
              f"{feedback.ef_bytes(ef) / 2**20:.2f} MiB resident")

    if args.path == "loop":
        storage, hist = simulate.run_training(
            cf, CFG, OMC, sim, plan, data_fn, key, num_rounds=rounds,
            eval_every=10_000, wire=wire, strategy=strategy, ef=ef)
    elif args.path == "engine":
        storage, hist = engine.run_training_vectorized(
            cf, CFG, OMC, sim, engine.CohortSpec(plan), data_fn, key,
            num_rounds=rounds, eval_every=10_000, wire=wire,
            strategy=strategy, ef=ef)
    else:
        storage, hist, runner = async_engine.run_async_training(
            cf, CFG, OMC, sim,
            async_engine.AsyncConfig(buffer_goal=plan.cohort_size),
            traces.ParetoTrace(alpha=1.5), data_fn, key,
            num_clients=plan.num_clients, flushes=rounds, wire=wire,
            strategy=strategy)
        ef = runner.ef

    for h in hist:
        line = f"  round {h.get('round', h.get('version', '?'))}: " \
               f"loss={h['loss']:.4f}"
        if wire and "up_bytes" in h:
            line += f"  up={h['up_bytes'] / 2**20:.3f}MiB" \
                    f"  down={h['down_bytes'] / 2**20:.3f}MiB"
        print(line)

    print(f"final eval loss: {_eval(decompress_tree(storage), eval_batches):.4f}")
    if wire:
        up = sum(h.get("up_bytes", 0) for h in hist)
        down = sum(h.get("down_bytes", 0) for h in hist)
        if args.path == "async":  # ledger rows are cumulative there
            up, down = hist[-1]["up_bytes"], hist[-1]["down_bytes"]
        print(f"cumulative wire: up={up / 2**20:.2f}MiB "
              f"down={down / 2**20:.2f}MiB")
    if ef is not None:
        print(f"residual norm after training: {feedback.total_norm(ef):.4f}")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
