"""Sharded population runtime walkthrough (README cookbook 14).

Runs tree-aggregated, streamed federated rounds over a population far
larger than any cohort the flat engine could stack (DESIGN.md §14):

  * the population's per-client state (counters + optional packed-at-rest
    error-feedback residuals) lives in a
    :class:`repro.scale.store.PopulationStore` partitioned by a
    :class:`~repro.scale.store.ShardLayout`,
  * each round streams the cohort through ONE fixed-capacity compiled
    program per shard chunk (peak memory = f(capacity), not population),
  * per-shard partial sums combine at the root with the exact server
    algebra of the flat engine (equivalence-gated in tests/test_scale.py).

    PYTHONPATH=src python examples/population_scale.py
    PYTHONPATH=src python examples/population_scale.py \
        --population 50000 --shards 16 --capacity 64 --rounds 3 --fused

``--fused`` aggregates in the fused transport-encoded mode (DESIGN.md
§13/§14); ``--ef-fmt S1E4M14`` keeps topk error-feedback residuals packed
at rest and reports the at-rest byte ratio.
"""

from __future__ import annotations

import argparse

import jax

from repro.compress import get_strategy
from repro.core.omc import OMCConfig
from repro.data.synthetic import make_frame_task
from repro.federated import simulate
from repro.federated.cohort import CohortPlan
from repro.models import conformer as cf
from repro.scale import PopulationStore, ShardLayout, run_training_sharded

CFG = cf.ConformerConfig(
    n_layers=2, d_model=32, n_heads=4, d_ff=64, n_classes=16, d_in=8
)
OMC = OMCConfig.parse("S1E3M7")


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--population", type=int, default=10_000)
    ap.add_argument("--shards", type=int, default=8)
    ap.add_argument("--cohort", type=int, default=64)
    ap.add_argument("--capacity", type=int, default=16,
                    help="stream chunk width (bounds peak memory)")
    ap.add_argument("--rounds", type=int, default=2)
    ap.add_argument("--fused", action="store_true",
                    help="compressed-domain aggregation (DESIGN.md §13/§14)")
    ap.add_argument("--ef-fmt", default=None,
                    help="train under EF top-k with residuals packed at "
                         "rest in this format (e.g. S1E4M14)")
    args = ap.parse_args()

    plan = CohortPlan(num_clients=args.population, cohort_size=args.cohort,
                      failure_rate=0.1)
    layout = ShardLayout(args.population, args.shards)
    task = make_frame_task(d_in=CFG.d_in, n_classes=CFG.n_classes,
                           seq_len=24, num_clients=args.population)
    data_fn = lambda c, r, s: task.batch(c, r, s, 4)
    sim = simulate.SimConfig(local_steps=2, client_lr=0.1)
    key = jax.random.PRNGKey(0)

    strategy = None
    store = None
    if args.ef_fmt:
        if args.fused:
            raise SystemExit("--fused and --ef-fmt are mutually exclusive "
                             "(zoo strategies gate fused off, DESIGN.md §13)")
        strategy = get_strategy("topk", density=0.25)
        store = PopulationStore(layout)
        store.init_ef(cf.init(key, CFG), cf.param_specs(CFG), OMC,
                      ef_fmt=args.ef_fmt)

    print(f"population={args.population} shards={args.shards} "
          f"cohort={args.cohort} capacity={args.capacity} "
          f"fused={args.fused} ef_fmt={args.ef_fmt}")
    storage, history, ledger = run_training_sharded(
        cf, CFG, OMC, sim, plan, layout, data_fn, key, args.rounds,
        capacity=args.capacity, fused_agg=args.fused, strategy=strategy,
        store=store, wire=strategy is None, log=print,
    )
    for h in history:
        print(f"round {h['round']}: loss={h['loss']:.4f} "
              f"cohort={h['cohort']} shards={h['shards']} "
              f"chunks={h['chunks']}")
    if ledger is not None:
        snap = ledger.snapshot()
        print(f"streamed {snap['clients_streamed']} client updates in "
              f"{snap['chunks']} chunks; peak resident model bytes bounded "
              f"by {snap['peak_bound_bytes']:,} (capacity-determined)")
    if store is not None:
        rep = store.bytes_report()
        print(f"EF at rest: {rep['ef_at_rest_bytes']:,} B "
              f"({rep['ef_fmt']}) vs f32 {rep['ef_fp32_bytes']:,} B "
              f"-> x{rep['ef_at_rest_bytes'] / rep['ef_fp32_bytes']:.2f}")


if __name__ == "__main__":
    main()
