"""Quickstart: OMC in 40 lines — compress, train a round, inspect savings.

    PYTHONPATH=src python examples/quickstart.py
"""

import jax
import jax.numpy as jnp

from repro.core.omc import OMCConfig
from repro.data.synthetic import make_lm_task
from repro.federated.round import make_round_fn
from repro.federated.state import init_state, state_bytes_report
from repro.models import transformer as tr
from repro.optim import fedavg

# a small GQA transformer LM
cfg = tr.TransformerConfig(n_layers=4, d_model=128, n_heads=8, n_kv_heads=4,
                           d_ff=256, vocab=512)

# Online Model Compression: 11-bit S1E3M7 storage, per-variable
# transformation, weights-only policy (paper §2)
omc = OMCConfig.parse("S1E3M7")

state = init_state(jax.random.PRNGKey(0), tr, cfg, omc, fedavg(1.0))
report = state_bytes_report(state.params)
print(f"parameters:       {report['num_params'] / 1e6:.2f} M")
print(f"storage (u16):    {report['container_ratio']:.0%} of FP32")
print(f"wire (19-bit):    {report['packed_ratio']:.0%} of FP32")

# one federated round = compressed transport -> local step -> aggregate ->
# re-compress; all inside a single jit
task = make_lm_task(vocab=512, seq_len=64, num_clients=8)
round_fn = jax.jit(make_round_fn(tr, cfg, omc, fedavg(1.0), client_lr=0.05))
for r in range(10):
    state, metrics = round_fn(state, task.batch(r % 8, r, 0, 8))
    print(f"round {r}: loss={float(metrics['loss']):.4f}")
