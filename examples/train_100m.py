"""End-to-end driver: federated-train a ~100M-parameter model.

The full conformer_s config is ~130M parameters (the paper's streaming
Conformer).  On real hardware run it as-is; on this CPU container pass
--smoke for the reduced config (the default below keeps CPU feasibility).

    PYTHONPATH=src python examples/train_100m.py [--full]
"""

import subprocess
import sys

full = "--full" in sys.argv
args = [sys.executable, "-m", "repro.launch.train",
        "--arch", "conformer_s", "--rounds", "200" if full else "30",
        "--batch", "8", "--fmt", "S1E3M7",
        "--ckpt-dir", "/tmp/omc_train_100m", "--ckpt-every", "10"]
if not full:
    args.append("--smoke")
subprocess.run(args, check=True)
