"""Strategy-zoo walkthrough: pick a compressor, ship a model (README cookbook 11).

Demonstrates the pluggable transport layer of DESIGN.md §11 end to end on a
small Conformer: encode the parameter tree under any registered
:class:`repro.compress.CompressionStrategy`, serialize it through the §7
wire codec (strategy tag + per-strategy wire version in the frame), decode
it back bit-exactly, and print the reconciled byte ledger and the eval-loss
cost of the lossy transport.

    PYTHONPATH=src python examples/compress_strategies.py                # zoo sweep
    PYTHONPATH=src python examples/compress_strategies.py --strategy topk --density 0.05
    PYTHONPATH=src python examples/compress_strategies.py --strategy omc --fmt S1E4M3
    PYTHONPATH=src python examples/compress_strategies.py --smoke

``--strategy`` accepts any name from ``repro.compress.available_strategies``
(omc / topk / ternary / pipeline); omit it to sweep the default zoo.
"""

from __future__ import annotations

import argparse

import jax
import numpy as np

from repro import compress
from repro.api import codecs
from repro.core.omc import OMCConfig
from repro.data.synthetic import make_frame_task
from repro.models import conformer as cf
from repro.models.common import IDENTITY_MAT

CFG = cf.ConformerConfig(
    n_layers=2, d_model=32, n_heads=4, d_ff=64, n_classes=16, d_in=8
)
OMC = OMCConfig.parse("S1E3M7")  # supplies the weights-only selection policy


def _pick(args) -> list:
    if args.strategy is None:
        return compress.default_zoo()
    kw = {}
    if args.strategy == "omc":
        return [compress.OMCQuantStrategy.parse(args.fmt)]
    if args.strategy == "pipeline":
        return [compress.PipelineStrategy.parse(args.fmt,
                                                density=args.density)]
    if args.strategy == "topk":
        kw["density"] = args.density
    return [compress.get_strategy(args.strategy, **kw)]


def _train(task, steps: int, batch: int):
    params = cf.init(jax.random.PRNGKey(0), CFG)

    @jax.jit
    def step(p, b):
        loss, g = jax.value_and_grad(
            lambda q: cf.loss(CFG, q, b, IDENTITY_MAT))(p)
        return jax.tree_util.tree_map(lambda w, gg: w - 0.1 * gg, p, g), loss

    for i in range(steps):
        params, _ = step(params, task.batch(i % 4, i, 0, batch))
    return params


def _eval(params, batches) -> float:
    f = jax.jit(lambda p, b: cf.loss(CFG, p, b, IDENTITY_MAT))
    return float(sum(f(params, b) for b in batches) / len(batches))


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--strategy", choices=compress.available_strategies(),
                    default=None, help="one strategy (default: sweep the zoo)")
    ap.add_argument("--fmt", default="S1E3M7",
                    help="minifloat for omc/pipeline strategies")
    ap.add_argument("--density", type=float, default=0.1,
                    help="kept fraction for topk/pipeline strategies")
    ap.add_argument("--smoke", action="store_true")
    args = ap.parse_args(argv)

    steps = 4 if args.smoke else 30
    task = make_frame_task(d_in=CFG.d_in, n_classes=CFG.n_classes,
                           seq_len=32, num_clients=4)
    params = _train(task, steps, batch=2 if args.smoke else 4)
    eval_batches = [task.batch(100 + i, 10_000, 0, 4) for i in range(2)]
    baseline = _eval(params, eval_batches)
    specs = cf.param_specs(CFG)
    fp32_mb = sum(4 * x.size for x in jax.tree_util.tree_leaves(params)) / 2**20
    print(f"baseline: loss={baseline:.4f}  fp32={fp32_mb:.3f} MiB")

    for s in _pick(args):
        tree = compress.encode_tree(s, params, OMC, specs)
        payload = codecs.encode_payload(tree, strategy=s)
        info = codecs.peek_payload(payload)
        twb = compress.tree_wire_bytes(tree)
        assert info.body_bytes == twb["wire_bytes"]  # ledger == payload body

        decoded, _ = codecs.decode_payload(payload)
        assert codecs.tree_digest(decoded) == codecs.tree_digest(tree)
        loss = _eval(compress.decode_tree(decoded), eval_batches)

        over = {k: f"idx={v['index_bytes']}B meta={v['meta_bytes']}B"
                for k, v in twb["per_strategy"].items() if k != "raw"}
        print(
            f"{s.label:<18} tag={info.strategy} v{info.strategy_version}  "
            f"wire={twb['wire_bytes'] / 2**20:.3f} MiB "
            f"({100 * twb['wire_ratio']:.1f}% of fp32)  "
            f"loss={loss:.4f} (Δ{loss - baseline:+.4f})  "
            f"overhead={over}"
        )
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
