"""Serve a model with OMC-compressed weights and batched requests.

Weights live compressed (u16 codes) and are decompressed layer-by-layer
inside the jitted decode step — the serving-side realization of the paper's
storage model.

    PYTHONPATH=src python examples/serve_omc.py
"""

import subprocess
import sys

subprocess.run(
    [sys.executable, "-m", "repro.launch.serve",
     "--arch", "qwen2.5-3b", "--smoke", "--batch", "4",
     "--prompt-len", "32", "--gen", "16", "--fmt", "S1E3M7"],
    check=True,
)
