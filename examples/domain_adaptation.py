"""Domain adaptation under OMC (paper Table 2 scenario).

Pretrains a streaming Conformer on a source domain in FP32, then adapts to
a target domain with aggressive 6-bit (S1E2M3) OMC — adaptation tolerates
much coarser formats than from-scratch training.

    PYTHONPATH=src python examples/domain_adaptation.py [--smoke]
"""

import argparse

import jax

from repro.configs.registry import get_arch
from repro.core.omc import OMCConfig
from repro.core.store import decompress_tree
from repro.data.synthetic import make_frame_task
from repro.federated import simulate
from repro.federated.cohort import CohortPlan
from repro.models import conformer as cf
from repro.models.common import IDENTITY_MAT

cfg = get_arch("conformer_s").smoke_config()
src = make_frame_task(d_in=cfg.d_in, n_classes=cfg.n_classes, seq_len=32,
                      num_clients=8, domain=0)
tgt = make_frame_task(d_in=cfg.d_in, n_classes=cfg.n_classes, seq_len=32,
                      num_clients=8, domain=1)

sim = simulate.SimConfig(local_steps=1, client_lr=0.1)
plan = CohortPlan(num_clients=8, cohort_size=4)


def evaluate(params):
    f = jax.jit(lambda p, b: cf.loss(cfg, p, b, IDENTITY_MAT))
    batches = [tgt.batch(100 + i, 9999, 0, 4) for i in range(4)]
    return float(sum(f(params, b) for b in batches) / len(batches))


ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
ap.add_argument("--smoke", action="store_true", help="2 rounds per phase (CI-sized)")
ap.add_argument("--rounds", type=int, default=None)
args = ap.parse_args()
rounds = args.rounds or (2 if args.smoke else 16)

print("pretraining on source domain (FP32)...")
pre, _ = simulate.run_training(
    cf, cfg, OMCConfig.parse("S1E8M23"), sim, plan,
    lambda c, r, s: src.batch(c, r, s, 4), jax.random.PRNGKey(0),
    num_rounds=rounds, log=print)
print(f"target-domain loss before adaptation: {evaluate(decompress_tree(pre)):.4f}")

print("adapting on target domain with 6-bit OMC (S1E2M3)...")
adapted, _ = simulate.run_training(
    cf, cfg, OMCConfig.parse("S1E2M3"), sim, plan,
    lambda c, r, s: tgt.batch(c, r, s, 4), jax.random.PRNGKey(1),
    num_rounds=rounds, init_params=decompress_tree(pre), log=print)
print(f"target-domain loss after 6-bit adaptation: "
      f"{evaluate(decompress_tree(adapted)):.4f}")
