"""Scenario driver for the vectorized cohort engine (README cookbook).

Each scenario is a self-contained federated run on the vectorized engine
(:mod:`repro.federated.engine`) exercising one of the situations the paper
and its related work care about:

  * ``noniid``     — Dirichlet(α) label/speaker skew via the pluggable
                     partitioner (paper Table 3; DESIGN.md §9)
  * ``mixed``      — heterogeneous cohort: S1E3M7 + S1E4M3 + f32 device
                     tiers with per-tier wire accounting (paper §2.2 formats;
                     DESIGN.md §9)
  * ``stragglers`` — over-provisioned cohort with failures + a report-goal
                     deadline dropping the slowest clients (DESIGN.md §5)
  * ``shards``     — pathological shard partition (2 sources/client, the
                     Konečný et al. 2016 / McMahan et al. split)

    PYTHONPATH=src python examples/cohort_scenarios.py --scenario noniid
    PYTHONPATH=src python examples/cohort_scenarios.py --scenario mixed --smoke

``--smoke`` shrinks rounds for CI; every run prints per-round loss, cohort
survival, and exact down/up wire bytes.
"""

from __future__ import annotations

import argparse

import jax

from repro.core.omc import OMCConfig
from repro.data.partition import (
    DirichletPartition,
    IIDPartition,
    ShardPartition,
    make_partitioned_batch_fn,
)
from repro.data.synthetic import make_frame_task
from repro.federated import engine, simulate
from repro.federated.cohort import CohortPlan
from repro.models import conformer as cf

CFG = cf.ConformerConfig(
    n_layers=2, d_model=32, n_heads=4, d_ff=64, n_classes=16, d_in=8
)
SCENARIOS = {}


def scenario(fn):
    SCENARIOS[fn.__name__] = fn
    return fn


def _run(spec, data_fn, omc, rounds, label, local_steps=1):
    sim = simulate.SimConfig(local_steps=local_steps, client_lr=0.1)
    _, hist = engine.run_training_vectorized(
        cf, CFG, omc, sim, spec, data_fn, jax.random.PRNGKey(0),
        num_rounds=rounds, eval_every=max(rounds // 4, 1), log=print,
    )
    first, last = hist[0], hist[-1]
    print(f"[{label}] loss {first['loss']:.4f} -> {last['loss']:.4f}; "
          f"last round: {last['cohort']} reports, "
          f"down={last['down_bytes']}B up={last['up_bytes']}B")
    return hist


@scenario
def noniid(rounds: int):
    """Dirichlet(0.1) speaker skew vs IID, same format (paper Table 3)."""
    task = make_frame_task(d_in=CFG.d_in, n_classes=CFG.n_classes, seq_len=24,
                           num_clients=32)
    plan = CohortPlan(num_clients=32, cohort_size=8)
    omc = OMCConfig.parse("S1E3M7")
    for name, part in [("iid", IIDPartition()),
                       ("dirichlet(0.1)", DirichletPartition(alpha=0.1))]:
        data_fn = make_partitioned_batch_fn(task, part, batch_size=4)
        _run(engine.CohortSpec(plan), data_fn, omc, rounds, f"noniid/{name}")


@scenario
def shards(rounds: int):
    """Each client holds 2 of 16 sources — the pathological non-IID split."""
    task = make_frame_task(d_in=CFG.d_in, n_classes=CFG.n_classes, seq_len=24,
                           num_clients=32)
    plan = CohortPlan(num_clients=32, cohort_size=8)
    data_fn = make_partitioned_batch_fn(
        task, ShardPartition(shards_per_client=2), batch_size=4
    )
    _run(engine.CohortSpec(plan), data_fn, OMCConfig.parse("S1E3M7"), rounds,
         "shards")


@scenario
def mixed(rounds: int):
    """Mixed-bitwidth cohort: 11-bit, 8-bit, and f32 device tiers."""
    task = make_frame_task(d_in=CFG.d_in, n_classes=CFG.n_classes, seq_len=24,
                           num_clients=48)
    plan = CohortPlan(num_clients=48, cohort_size=12)
    spec = engine.CohortSpec(
        plan,
        tiers=(engine.profile("s1e3m7"), engine.profile("s1e4m3"),
               engine.profile("f32")),
        quotas=(6, 3, 3),
    )
    data_fn = lambda c, r, s: task.batch(c, r, s, 4)
    print(f"tiers: {[t.name for t in spec.tiers]}, quotas {spec.quotas} "
          f"(population striped round-robin)")
    _run(spec, data_fn, OMCConfig.parse("S1E3M7"), rounds, "mixed")


@scenario
def stragglers(rounds: int):
    """Over-provisioned cohort, 20% failures, report goal at 6 of 12."""
    task = make_frame_task(d_in=CFG.d_in, n_classes=CFG.n_classes, seq_len=24,
                           num_clients=48)
    plan = CohortPlan(num_clients=48, cohort_size=12, report_goal=6,
                      failure_rate=0.2, straggler_rate=0.25)
    data_fn = lambda c, r, s: task.batch(c, r, s, 4)
    hist = _run(engine.CohortSpec(plan), data_fn, OMCConfig.parse("S1E3M7"),
                rounds, "stragglers")
    drops = sum(h["dropped"] for h in hist)
    print(f"[stragglers] {drops} reports dropped across {rounds} rounds "
          f"(goal 6/12 + failures); every round still aggregated >= 1 report")


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--scenario", choices=sorted(SCENARIOS) + ["all"],
                    default="all")
    ap.add_argument("--rounds", type=int, default=None)
    ap.add_argument("--smoke", action="store_true", help="2 rounds, CI-sized")
    args = ap.parse_args(argv)
    rounds = args.rounds or (2 if args.smoke else 8)
    names = sorted(SCENARIOS) if args.scenario == "all" else [args.scenario]
    for name in names:
        print(f"\n=== scenario: {name} ===")
        SCENARIOS[name](rounds)
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
