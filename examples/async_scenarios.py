"""Scenario driver for the async federated runtime (README cookbook 8-10).

Each scenario runs the event-driven buffered runtime
(:mod:`repro.federated.async_engine`, DESIGN.md §10) under one of the
availability/latency regimes production fleets actually see:

  * ``heavytail``     — Pareto straggler latency: the sync barrier waits
                        for the p99 device, the async buffer does not
                        (Konečný et al. 2016 frame the transport, not the
                        compute, as the FL bottleneck)
  * ``diurnal``       — sine-modulated availability over a virtual day
                        with per-client timezone phase: check-ins roll
                        around the clock, buffers fill slower at night
  * ``async_vs_sync`` — same population, matched update budget: wire
                        bytes, staleness profile, and loss for the
                        buffered runtime vs the barrier engine

    PYTHONPATH=src python examples/async_scenarios.py --scenario heavytail
    PYTHONPATH=src python examples/async_scenarios.py --smoke

``--smoke`` shrinks flush counts for CI; every run prints per-flush loss,
staleness, virtual clock, and the exact async wire ledger.
"""

from __future__ import annotations

import argparse

import jax

from repro.core.omc import OMCConfig
from repro.data.synthetic import make_frame_task
from repro.federated import async_engine, engine, simulate, traces
from repro.federated.cohort import CohortPlan
from repro.models import conformer as cf

CFG = cf.ConformerConfig(
    n_layers=2, d_model=32, n_heads=4, d_ff=64, n_classes=16, d_in=8
)
OMC = OMCConfig.parse("S1E3M7")
SCENARIOS = {}


def scenario(fn):
    SCENARIOS[fn.__name__] = fn
    return fn


def _run(trace, acfg, flushes, label, num_clients=32, local_steps=1):
    sim = simulate.SimConfig(local_steps=local_steps, client_lr=0.1)
    task = make_frame_task(d_in=CFG.d_in, n_classes=CFG.n_classes, seq_len=24,
                           num_clients=num_clients)
    data_fn = lambda c, r, s: task.batch(c, r, s, 4)
    _, hist, runner = async_engine.run_async_training(
        cf, CFG, OMC, sim, acfg, trace, data_fn, jax.random.PRNGKey(0),
        num_clients=num_clients, flushes=flushes, log=print,
    )
    first, last = hist[0], hist[-1]
    print(f"[{label}] loss {first['loss']:.4f} -> {last['loss']:.4f}; "
          f"virtual clock {last['clock']:.1f}s, "
          f"{last['completed']} updates, staleness_max {last['staleness_max']}, "
          f"down={last['down_bytes']}B up={last['up_bytes']}B "
          f"(stale {last['stale_up_bytes']}B)")
    return hist, runner


@scenario
def heavytail(flushes: int):
    """Pareto(1.3) stragglers, buffer K=8 of 32 clients, staleness decay."""
    hist, runner = _run(
        traces.ParetoTrace(latency=1.0, alpha=1.3),
        async_engine.AsyncConfig(buffer_goal=8, decay=0.5),
        flushes, "heavytail",
    )
    stale = runner.stats.n_stale / max(runner.stats.n_uploads, 1)
    print(f"[heavytail] {stale:.0%} of uploads arrived stale and were "
          f"decay-weighted instead of blocking a barrier")


@scenario
def diurnal(flushes: int):
    """Virtual day of 24s, 90% availability swing, timezone phase spread."""
    hist, _ = _run(
        traces.DiurnalTrace(interval=1.0, period=24.0, depth=0.9),
        async_engine.AsyncConfig(buffer_goal=8),
        flushes, "diurnal",
    )
    gaps = [round(b["clock"] - a["clock"], 2)
            for a, b in zip(hist, hist[1:])]
    print(f"[diurnal] inter-flush gaps (virtual s): {gaps} — buffers fill "
          f"slower through the trough of the day")


@scenario
def async_vs_sync(flushes: int):
    """Same 32 clients, matched update budget: buffered vs barrier."""
    trace = traces.ParetoTrace(latency=1.0, alpha=1.5)
    acfg = async_engine.AsyncConfig(buffer_goal=8, decay=0.5)
    hist, runner = _run(trace, acfg, flushes, "async")

    plan = CohortPlan(num_clients=32, cohort_size=32)
    sim = simulate.SimConfig(local_steps=1, client_lr=0.1)
    task = make_frame_task(d_in=CFG.d_in, n_classes=CFG.n_classes, seq_len=24,
                           num_clients=32)
    data_fn = lambda c, r, s: task.batch(c, r, s, 4)
    rounds = max(runner.completed // 32, 1)
    _, sync_hist = engine.run_training_vectorized(
        cf, CFG, OMC, sim, engine.CohortSpec(plan), data_fn,
        jax.random.PRNGKey(0), num_rounds=rounds,
    )
    sync_vtime = sum(
        max(trace.round_latency(c, r, 0.0) for c in range(32))
        for r in range(rounds)
    )
    down = sum(h["down_bytes"] for h in sync_hist)
    up = sum(h["up_bytes"] for h in sync_hist)
    print(f"[sync]  loss {sync_hist[0]['loss']:.4f} -> "
          f"{sync_hist[-1]['loss']:.4f}; virtual time {sync_vtime:.1f}s for "
          f"{rounds * 32} updates, down={down}B up={up}B")
    print(f"[async_vs_sync] updates/virtual-s: "
          f"async {runner.completed / runner.clock:.2f} vs "
          f"sync {rounds * 32 / sync_vtime:.2f}")


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--scenario", choices=sorted(SCENARIOS) + ["all"],
                    default="all")
    ap.add_argument("--flushes", type=int, default=None)
    ap.add_argument("--smoke", action="store_true", help="3 flushes, CI-sized")
    args = ap.parse_args(argv)
    flushes = args.flushes or (3 if args.smoke else 12)
    names = sorted(SCENARIOS) if args.scenario == "all" else [args.scenario]
    for name in names:
        print(f"\n=== scenario: {name} ===")
        SCENARIOS[name](flushes)
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
